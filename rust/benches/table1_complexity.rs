//! Table 1: per-layer complexity.  Measures wall time of each evaluation
//! strategy across n and d sweeps and fits the log-log slope, checking the
//! paper's asymptotic rows:
//!
//!   RNN (LSTM fwd)  O(n dx^2)   sequential
//!   Attention       O(n^2 dx)   parallel
//!   DN eq.(19)      O(n d^2 dx) sequential
//!   DN eq.(24)      O(n^2 d dx) parallel
//!   DN eq.(25)      O(n d dx)   parallel (last state)
//!   DN eq.(26)      O(n log n d dx) parallel
//!
//! Run: cargo bench --bench table1_complexity

use plmu::autograd::ParamStore;
use plmu::benchlib::{bench, BenchConfig, Table};
use plmu::dn::DelayNetwork;
use plmu::layers::{LstmLayer, SelfAttention};
use plmu::util::Rng;
use plmu::Tensor;

fn loglog_slope(xs: &[f64], ys: &[f64]) -> f64 {
    let lx: Vec<f64> = xs.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = ys.iter().map(|v| v.ln()).collect();
    let n = lx.len() as f64;
    let mx = lx.iter().sum::<f64>() / n;
    let my = ly.iter().sum::<f64>() / n;
    let cov: f64 = lx.iter().zip(&ly).map(|(a, b)| (a - mx) * (b - my)).sum();
    let var: f64 = lx.iter().map(|a| (a - mx) * (a - mx)).sum();
    cov / var
}

fn main() {
    let cfg = BenchConfig { warmup_secs: 0.05, measure_secs: 0.25, max_iters: 200, min_iters: 3 };
    let d = 16usize;
    let ns = [128usize, 256, 512, 1024];
    let mut rng = Rng::new(0);

    // per-strategy timings over n
    let mut rows: Vec<(&str, &str, &str, Vec<f64>)> = Vec::new();

    // DN strategies
    let mut t19 = Vec::new();
    let mut t24 = Vec::new();
    let mut t25 = Vec::new();
    let mut t26 = Vec::new();
    for &n in &ns {
        let dn = DelayNetwork::new(d, n as f64);
        let u = Tensor::randn(&[n, 1], 1.0, &mut rng);
        t19.push(bench("dn19", cfg, || { std::hint::black_box(dn.scan_sequential(&u)); }).mean);
        if n <= 512 {
            t24.push(bench("dn24", cfg, || { std::hint::black_box(dn.parallel_toeplitz(&u)); }).mean);
        }
        t25.push(bench("dn25", cfg, || { std::hint::black_box(dn.parallel_last(&u)); }).mean);
        let op = plmu::dn::DnFftOperator::new(&dn, n);
        t26.push(bench("dn26", cfg, || { std::hint::black_box(op.apply(&u)); }).mean);
    }
    rows.push(("DN eq.19 (sequential scan)", "O(n d^2 dx)", "yes", t19.clone()));
    rows.push(("DN eq.24 (Toeplitz matmul)", "O(n^2 d dx)", "no", t24.clone()));
    rows.push(("DN eq.25 (final state)", "O(n d dx)", "no", t25.clone()));
    rows.push(("DN eq.26 (FFT)", "O(n log n d dx)", "no", t26.clone()));

    // LSTM forward (RNN row)
    let mut t_rnn = Vec::new();
    for &n in &ns {
        let mut store = ParamStore::new();
        let lstm = LstmLayer::new(16, 16, &mut store, &mut rng, "b");
        let x = Tensor::randn(&[n, 16], 1.0, &mut rng);
        t_rnn.push(
            bench("rnn", cfg, || {
                let mut g = plmu::autograd::Graph::new();
                let xi = g.input(x.clone());
                std::hint::black_box(lstm.forward_last(&mut g, &store, xi, 1, n));
            })
            .mean,
        );
    }
    rows.push(("RNN (LSTM forward)", "O(n dx^2)", "yes", t_rnn.clone()));

    // Attention
    let mut t_att = Vec::new();
    for &n in &ns {
        let att = SelfAttention::new(16, false, &mut rng);
        let x = Tensor::randn(&[n, 16], 1.0, &mut rng);
        t_att.push(bench("att", cfg, || { std::hint::black_box(att.forward(&x)); }).mean);
    }
    rows.push(("Self-attention", "O(n^2 dx)", "no", t_att.clone()));

    // print
    let mut table = Table::new(&["layer type", "paper complexity", "seq ops", "n=128", "n=256", "n=512", "n=1024", "slope(n)"]);
    for (name, cx, seq, times) in &rows {
        let ns_used: Vec<f64> = ns.iter().take(times.len()).map(|&v| v as f64).collect();
        let slope = loglog_slope(&ns_used, times);
        let mut cells = vec![name.to_string(), cx.to_string(), seq.to_string()];
        for i in 0..4 {
            cells.push(times.get(i).map(|t| format!("{:.2}ms", t * 1e3)).unwrap_or("-".into()));
        }
        cells.push(format!("{slope:.2}"));
        table.row(&cells);
    }
    table.print("Table 1 — complexity per layer (measured, d=16, dx=1/16)");

    // d-sweep for DN(19) vs DN(25): quadratic vs linear in d
    let n = 256usize;
    let ds = [8usize, 16, 32, 64];
    let mut t19d = Vec::new();
    let mut t25d = Vec::new();
    for &dd in &ds {
        let dn = DelayNetwork::new(dd, n as f64);
        let u = Tensor::randn(&[n, 1], 1.0, &mut rng);
        t19d.push(bench("dn19d", cfg, || { std::hint::black_box(dn.scan_sequential(&u)); }).mean);
        t25d.push(bench("dn25d", cfg, || { std::hint::black_box(dn.parallel_last(&u)); }).mean);
    }
    let dsf: Vec<f64> = ds.iter().map(|&v| v as f64).collect();
    println!("\nd-scaling (n=256): eq.19 slope {:.2} (paper: 2 = d^2), eq.25 slope {:.2} (paper: 1 = d)",
        loglog_slope(&dsf, &t19d), loglog_slope(&dsf, &t25d));

    println!("\nexpected slopes(n): eq.19≈1, eq.24≈2, eq.25≈1, eq.26≈1+, RNN≈1, attention≈2");
}
