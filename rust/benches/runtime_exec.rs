//! Runtime bench: PJRT artifact execution rates — the serving/training
//! throughput of the AOT path (compile once, execute many).
//!
//! Requires `make artifacts`; prints a notice and exits cleanly otherwise.

use plmu::benchlib::{bench_report, BenchConfig};
use plmu::error::Result;
use plmu::runtime::{ArtifactInput, Runtime};
use plmu::util::Timer;
use plmu::Tensor;

fn main() -> Result<()> {
    let dir = std::path::Path::new("artifacts");
    let mut rt = match Runtime::open(dir) {
        Ok(rt) => rt,
        Err(e) => {
            println!("runtime_exec skipped: {e}");
            return Ok(());
        }
    };
    let n = rt.manifest.config_usize("n").unwrap();
    let dx = rt.manifest.config_usize("dx").unwrap();
    let du = rt.manifest.config_usize("du").unwrap();
    let d = rt.manifest.config_usize("d").unwrap();
    let batch = rt.manifest.config_usize("batch").unwrap();
    let params = rt.init_params()?;
    let p_len = params.len();
    let cfg = BenchConfig { warmup_secs: 0.3, measure_secs: 1.5, max_iters: 300, min_iters: 3 };

    println!("\n=== artifact compile times (one-off) ===");
    for name in ["dn_fwd_fft", "dn_fwd_pallas", "fwd", "train_step", "recurrent_step"] {
        let t = Timer::start();
        rt.artifact(name)?;
        println!("  compile {name:<16} {:.2}s", t.elapsed());
    }

    println!("\n=== execution rates ===");
    {
        let art = rt.artifact("dn_fwd_fft")?;
        let u = Tensor::zeros(&[n, du]);
        let s = bench_report("dn_fwd_fft (n=256)", cfg, || {
            let _ = art.run(&[ArtifactInput::F32(u.clone())]).unwrap();
        });
        println!("    -> {:.0} sequences/s", 1.0 / s.mean);
    }
    {
        let art = rt.artifact("fwd")?;
        let x = Tensor::zeros(&[batch, n, dx]);
        let s = bench_report("fwd (batched classifier)", cfg, || {
            let _ = art
                .run(&[ArtifactInput::F32(params.clone()), ArtifactInput::F32(x.clone())])
                .unwrap();
        });
        println!("    -> {:.0} samples/s", batch as f64 / s.mean);
    }
    {
        let art = rt.artifact("train_step")?;
        let x = Tensor::zeros(&[batch, n, dx]);
        let y = vec![0i32; batch];
        let m = Tensor::zeros(&[p_len]);
        let s = bench_report("train_step (fwd+bwd+Adam)", cfg, || {
            let _ = art
                .run(&[
                    ArtifactInput::F32(params.clone()),
                    ArtifactInput::F32(m.clone()),
                    ArtifactInput::F32(m.clone()),
                    ArtifactInput::F32(Tensor::scalar(1.0)),
                    ArtifactInput::F32(x.clone()),
                    ArtifactInput::I32(y.clone()),
                ])
                .unwrap();
        });
        println!("    -> {:.1} train steps/s = {:.0} samples/s", 1.0 / s.mean, batch as f64 / s.mean);
    }
    {
        let art = rt.artifact("recurrent_step")?;
        let m = Tensor::zeros(&[d, du]);
        let x = Tensor::zeros(&[dx]);
        let s = bench_report("recurrent_step (streaming)", cfg, || {
            let _ = art
                .run(&[
                    ArtifactInput::F32(params.clone()),
                    ArtifactInput::F32(m.clone()),
                    ArtifactInput::F32(x.clone()),
                ])
                .unwrap();
        });
        println!("    -> {:.0} tokens/s/stream", 1.0 / s.mean);
    }
    Ok(())
}
