//! Scan-vs-FFT crossover for the DN memory: the chunked parallel scan
//! (`PLMU_SCAN=scan`) against the whole-sequence FFT convolution
//! (eq. 26) over the fig1 sequence-length sweep, forward and adjoint.
//! Emits `BENCH_scan.json` at the repo root (validated by `plmu
//! bench-check` in the CI bench stage).
//!
//! Before timing, every shape runs the correctness gates: scan-vs-FFT
//! inside the cross-strategy ~2e-4 budget, the last-state short-circuit
//! bit-identical to the full evaluation's final row, and the streaming
//! mode bit-identical to the batch mode (the exhaustive version is
//! `rust/tests/scan_equivalence.rs`).
//!
//! Run: cargo bench --bench scan
//! Smoke mode (CI): PLMU_BENCH_SMOKE=1 cargo bench --bench scan

use plmu::benchlib::{
    bench, checksum_f32 as checksum, repo_root, BenchConfig, JsonValue, PerfJson, Table,
};
use plmu::dn::{scan, DelayNetwork, DnFftOperator, DnScanOperator};
use plmu::exec;
use plmu::util::Rng;
use plmu::Tensor;

fn main() {
    let smoke = std::env::var("PLMU_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let cfg = if smoke {
        BenchConfig { warmup_secs: 0.02, measure_secs: 0.06, max_iters: 20, min_iters: 2 }
    } else {
        BenchConfig { warmup_secs: 0.1, measure_secs: 0.5, max_iters: 100, min_iters: 3 }
    };
    let (d, du) = (16usize, 1usize);
    let block = scan::DEFAULT_BLOCK;
    let ns: &[usize] = if smoke { &[64, 128] } else { &[64, 128, 256, 512, 1024] };
    let threads = exec::threads();
    println!(
        "scan-vs-fft crossover, d={d} du={du} L={block}, {threads} thread(s){}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut record = PerfJson::new("scan");
    let mut table =
        Table::new(&["n", "fft fwd (µs)", "scan fwd (µs)", "fwd ratio", "fft adj (µs)", "scan adj (µs)"]);
    let mut rng = Rng::new(0);
    let mut first_ratio = None;
    let mut last_ratio = None;

    for &n in ns {
        let dn = DelayNetwork::new(d, n as f64);
        let fft = DnFftOperator::new(&dn, n);
        let sc = DnScanOperator::new(&dn, n, block);
        let u = Tensor::randn(&[n, du], 1.0, &mut rng);
        let dm = Tensor::randn(&[n, d, du], 1.0, &mut rng);

        // ---- gates before timing -------------------------------------
        let m_fft = fft.apply(&u);
        let m_scan = sc.apply(&u);
        let err = m_fft.max_abs_diff(&m_scan);
        assert!(err < 2e-4, "n={n}: scan-vs-fft err {err} outside the strategy budget");
        let last = sc.apply_last(&u, None);
        for (c, lv) in last.iter().enumerate().take(du * d) {
            let (ch, s) = (c / d, c % d);
            assert_eq!(
                lv.to_bits(),
                m_scan.data()[((n - 1) * d + s) * du + ch].to_bits(),
                "n={n}: apply_last drifted from apply's final row"
            );
        }
        let streamed = sc.stream(du).push(&u);
        assert_eq!(
            checksum(streamed.data()),
            checksum(m_scan.data()),
            "n={n}: streaming mode drifted from batch mode"
        );

        // ---- timings -------------------------------------------------
        let fft_fwd = bench("fft_fwd", cfg, || {
            std::hint::black_box(fft.apply(&u));
        });
        let scan_fwd = bench("scan_fwd", cfg, || {
            std::hint::black_box(sc.apply(&u));
        });
        let fft_adj = bench("fft_adj", cfg, || {
            std::hint::black_box(fft.apply_adjoint(&dm));
        });
        let scan_adj = bench("scan_adj", cfg, || {
            std::hint::black_box(sc.apply_adjoint(&dm));
        });

        let ratio = scan_fwd.mean / fft_fwd.mean;
        if first_ratio.is_none() {
            first_ratio = Some(ratio);
        }
        last_ratio = Some(ratio);
        table.row(&[
            n.to_string(),
            format!("{:.2}", fft_fwd.mean * 1e6),
            format!("{:.2}", scan_fwd.mean * 1e6),
            format!("{ratio:.2}x"),
            format!("{:.2}", fft_adj.mean * 1e6),
            format!("{:.2}", scan_adj.mean * 1e6),
        ]);
        for (case, stats) in [
            (format!("fft_fwd_n{n}"), &fft_fwd),
            (format!("scan_fwd_n{n}"), &scan_fwd),
            (format!("fft_adj_n{n}"), &fft_adj),
            (format!("scan_adj_n{n}"), &scan_adj),
        ] {
            record.push(&[
                ("case", JsonValue::Str(case)),
                ("threads", JsonValue::Int(threads as i64)),
                ("wall_ns", JsonValue::Int((stats.mean * 1e9) as i64)),
                ("mean_s", JsonValue::Num(stats.mean)),
                ("p50_s", JsonValue::Num(stats.p50)),
                ("n", JsonValue::Int(n as i64)),
                ("d", JsonValue::Int(d as i64)),
                ("scan_block", JsonValue::Int(block as i64)),
                ("scan_over_fft_fwd", JsonValue::Num(ratio)),
                ("smoke", JsonValue::Bool(smoke)),
            ]);
        }
    }

    table.print("scan vs fft — DN memory evaluation vs sequence length");
    println!(
        "\ncrossover shape: scan/fft forward ratio {:.2}x at n={} vs {:.2}x at n={} \
         (the FFT's n log n catches up as n grows; the scan wins where chunks \
         amortize and is the only path that streams)",
        first_ratio.unwrap_or(0.0),
        ns.first().unwrap(),
        last_ratio.unwrap_or(0.0),
        ns.last().unwrap()
    );

    let out = repo_root().join("BENCH_scan.json");
    match record.write(&out) {
        Ok(()) => println!("wrote {} ({} records)", out.display(), record.len()),
        Err(e) => eprintln!("failed to write {}: {e}", out.display()),
    }
}
