//! Figure 1 (right): effect of sequence length on training time for the
//! LTI (sequential) vs parallel versions of our model.  The paper shows
//! the LTI version growing linearly with n while the parallel version
//! stays ~flat (GPU); on CPU the parallel version grows sub-linearly
//! (FFT work grows n log n but avoids the n-step dependency chain).
//! The parallel version is run under both DN evaluation paths —
//! `PLMU_SCAN=fft` (eq. 26) and `PLMU_SCAN=scan` (the chunked parallel
//! scan) — so the strategy crossover shows up on the same axis
//! (`cargo bench --bench scan` is the operator-level version).
//!
//! Run: cargo bench --bench fig1_seqlen

use plmu::autograd::{Graph, ParamStore};
use plmu::dn::scan::{self, ScanMode};
use plmu::benchlib::{bench, BenchConfig, Table};
use plmu::data::batcher::{BatchIter, SeqDataset};
use plmu::optim::{Adam, Optimizer};
use plmu::train::{ModelKind, SeqClassifier, TrainableModel};
use plmu::util::Rng;
use plmu::Tensor;

fn batch_step_time(kind: ModelKind, n: usize) -> f64 {
    let (d, hidden, batch) = (16usize, 32usize, 8usize);
    let mut store = ParamStore::new();
    let mut rng = Rng::new(0);
    let model = SeqClassifier::new(kind, n, 1, d, hidden, 4, &mut store, &mut rng);
    let xs: Vec<Tensor> = (0..batch).map(|_| Tensor::randn(&[n, 1], 1.0, &mut rng)).collect();
    let ys: Vec<usize> = (0..batch).map(|i| i % 4).collect();
    let ds = SeqDataset::classification(xs, ys);
    let b = BatchIter::sequential(&ds, batch).next().unwrap();
    let mut opt = Adam::new(1e-3);
    let cfg = BenchConfig { warmup_secs: 0.1, measure_secs: 0.6, max_iters: 30, min_iters: 2 };
    bench("step", cfg, || {
        let mut g = Graph::new();
        let loss = model.loss(&mut g, &store, &b);
        g.backward(loss);
        let grads = g.param_grads();
        opt.step(&mut store, &grads);
    })
    .mean
}

fn main() {
    let ns = [64usize, 128, 256, 512, 1024];
    let mut table =
        Table::new(&["n", "LTI (ms/step)", "par-fft (ms/step)", "par-scan (ms/step)", "ratio"]);
    let mut first_ratio = None;
    let mut last_ratio = None;
    let was = scan::mode();
    for &n in &ns {
        println!("n = {n}...");
        let t_lti = batch_step_time(ModelKind::LmuSequential, n);
        // the parallel model captures its DN operator at construction,
        // so the knob is flipped around each build+measure
        scan::set_mode(ScanMode::Fft);
        let t_par = batch_step_time(ModelKind::LmuParallel, n);
        scan::set_mode(ScanMode::Scan { block: scan::DEFAULT_BLOCK });
        let t_scan = batch_step_time(ModelKind::LmuParallel, n);
        scan::set_mode(was);
        let r = t_lti / t_par;
        if first_ratio.is_none() {
            first_ratio = Some(r);
        }
        last_ratio = Some(r);
        table.row(&[
            n.to_string(),
            format!("{:.2}", t_lti * 1e3),
            format!("{:.2}", t_par * 1e3),
            format!("{:.2}", t_scan * 1e3),
            format!("{r:.1}x"),
        ]);
    }
    table.print("Figure 1 (right) — step time vs sequence length");
    println!(
        "\nshape check (paper): the LTI/parallel gap widens with n — here {:.1}x at n=64 vs {:.1}x at n=1024",
        first_ratio.unwrap(),
        last_ratio.unwrap()
    );
}
