//! Thread-scaling bench for the exec substrate: sweeps the worker-thread
//! count over the two kernels that dominate parallel-LMU training wall
//! clock — blocked matmul and the batched FFT causal convolution — on
//! shapes drawn from `table1_complexity` (d=16, n up to 1024), plus the
//! full DnFftOperator apply.  Emits a machine-readable perf record to
//! `BENCH_threads.json` at the repo root (the perf trajectory file).
//!
//! Also asserts, per sweep point, that the parallel result is
//! bit-identical to the single-thread reference — the substrate's core
//! invariant.
//!
//! Run: cargo bench --bench fig1_threads
//! Smoke mode (CI): PLMU_BENCH_SMOKE=1 cargo bench --bench fig1_threads

use plmu::benchlib::{bench, checksum_f32 as checksum, repo_root, BenchConfig, JsonValue, PerfJson, Table};
use plmu::dn::{DelayNetwork, DnFftOperator};
use plmu::exec;
use plmu::fft::{next_pow2, RfftCache};
use plmu::util::Rng;
use plmu::Tensor;

struct Case {
    name: &'static str,
    /// items processed per run (for throughput)
    items: f64,
    /// run the kernel, return a fingerprint of the result
    run: Box<dyn Fn() -> u64>,
}

fn main() {
    let smoke = std::env::var("PLMU_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let cfg = if smoke {
        BenchConfig { warmup_secs: 0.02, measure_secs: 0.08, max_iters: 20, min_iters: 2 }
    } else {
        BenchConfig { warmup_secs: 0.1, measure_secs: 0.6, max_iters: 200, min_iters: 3 }
    };
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let mut sweep = vec![1usize, 2, 4];
    if hw >= 8 && !smoke {
        sweep.push(8);
    }
    println!(
        "thread-scaling sweep {:?} on {} hardware threads{} (shapes from table1_complexity: d=16, n<=1024)",
        sweep,
        hw,
        if smoke { " [smoke]" } else { "" }
    );

    let mut rng = Rng::new(0);

    // ---- case 1/2: matmul + matmul_tn (training fwd + weight-grad) -----
    let (m, k, n) = if smoke { (256usize, 128usize, 128usize) } else { (1024, 256, 256) };
    let a = Tensor::randn(&[m, k], 1.0, &mut rng);
    let b = Tensor::randn(&[k, n], 1.0, &mut rng);
    let at = Tensor::randn(&[k, m], 1.0, &mut rng);

    // ---- case 3: batched causal convolution over B·dx rows -------------
    let conv_n = if smoke { 512usize } else { 1024 };
    let conv_rows = if smoke { 16usize } else { 64 };
    let kernel: Vec<f32> = (0..conv_n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let cache = RfftCache::new(&kernel, next_pow2(2 * conv_n));
    let rows: Vec<Vec<f32>> = (0..conv_rows)
        .map(|_| (0..conv_n).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();

    // ---- case 4: full DN FFT operator (eq. 26) -------------------------
    let (dn_n, dn_d, dn_du) = if smoke { (256usize, 8usize, 8usize) } else { (512, 16, 16) };
    let dn = DelayNetwork::new(dn_d, dn_n as f64);
    let op = DnFftOperator::new(&dn, dn_n);
    let u = Tensor::randn(&[dn_n, dn_du], 1.0, &mut rng);

    // ---- case 5: matvec (RNN-mode streaming inference hot path) --------
    let (mv_r, mv_c) = if smoke { (512usize, 512usize) } else { (1024, 1024) };
    let mv_m = Tensor::randn(&[mv_r, mv_c], 1.0, &mut rng);
    let mv_x: Vec<f32> = (0..mv_c).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    let cases: Vec<Case> = vec![
        Case {
            name: "matmul",
            items: (m * k * n) as f64,
            run: {
                let (a, b) = (a.clone(), b.clone());
                Box::new(move || checksum(a.matmul(&b).data()))
            },
        },
        Case {
            name: "matmul_packed",
            items: (m * k * n) as f64,
            run: {
                let (a, b) = (a.clone(), b.clone());
                Box::new(move || {
                    // packed path for this run only; per-chunk packing
                    // must scale like (and match bits with) the default
                    use plmu::tensor::packed::{set_gemm_path, GemmPath};
                    set_gemm_path(GemmPath::Packed);
                    let h = checksum(a.matmul(&b).data());
                    set_gemm_path(GemmPath::Axpy);
                    h
                })
            },
        },
        Case {
            name: "matmul_tn",
            items: (m * k * n) as f64,
            run: {
                let (at, b) = (at.clone(), b.clone());
                Box::new(move || checksum(at.matmul_tn(&b).data()))
            },
        },
        Case {
            name: "conv_batch",
            items: (conv_rows * conv_n) as f64,
            run: {
                let rows = rows.clone();
                Box::new(move || {
                    let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
                    let outs = cache.conv_batch(&refs, conv_n);
                    // order-sensitive fold so row reordering is detected
                    let mut h = 0u64;
                    for o in &outs {
                        h = h.wrapping_mul(0x100000001b3) ^ checksum(o);
                    }
                    h
                })
            },
        },
        Case {
            name: "dn_fft_apply",
            items: (dn_n * dn_d * dn_du) as f64,
            run: Box::new(move || checksum(op.apply(&u).data())),
        },
        Case {
            name: "matvec",
            items: (mv_r * mv_c) as f64,
            run: Box::new(move || checksum(&plmu::tensor::matmul::matvec(&mv_m, &mv_x))),
        },
    ];

    let mut record = PerfJson::new("fig1_threads");
    let mut table = Table::new(&["case", "threads", "mean (ms)", "items/s", "speedup vs 1t"]);
    // speedup of matmul-family and conv-family at 4 threads (acceptance:
    // >1.5x each)
    let mut speedup_at_4: Vec<(String, f64)> = Vec::new();

    for case in &cases {
        let mut base_mean = 0.0f64;
        let mut ref_sum: Option<u64> = None;
        for &t in &sweep {
            exec::set_threads(t);
            // correctness first: parallel must be bit-identical to serial
            let sum = (case.run)();
            match ref_sum {
                None => ref_sum = Some(sum),
                Some(r) => assert_eq!(
                    r, sum,
                    "{}: result at {t} threads differs from 1-thread reference",
                    case.name
                ),
            }
            let stats = bench(case.name, cfg, || {
                std::hint::black_box((case.run)());
            });
            if t == 1 {
                base_mean = stats.mean;
            }
            let speedup = base_mean / stats.mean;
            if t == 4 {
                speedup_at_4.push((case.name.to_string(), speedup));
            }
            table.row(&[
                case.name.to_string(),
                t.to_string(),
                format!("{:.2}", stats.mean * 1e3),
                format!("{:.3e}", case.items / stats.mean),
                format!("{speedup:.2}x"),
            ]);
            record.push(&[
                ("case", JsonValue::Str(case.name.to_string())),
                ("threads", JsonValue::Int(t as i64)),
                ("wall_ns", JsonValue::Int((stats.mean * 1e9) as i64)),
                ("mean_s", JsonValue::Num(stats.mean)),
                ("p50_s", JsonValue::Num(stats.p50)),
                ("items_per_s", JsonValue::Num(case.items / stats.mean)),
                ("speedup_vs_1t", JsonValue::Num(speedup)),
                ("smoke", JsonValue::Bool(smoke)),
                ("hw_threads", JsonValue::Int(hw as i64)),
            ]);
        }
    }
    exec::set_threads(1);

    table.print("thread scaling — exec substrate hot kernels");

    let out = repo_root().join("BENCH_threads.json");
    match record.write(&out) {
        Ok(()) => println!("\nwrote {} ({} records)", out.display(), record.len()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }

    if sweep.contains(&4) {
        println!("\nacceptance (>1.5x at 4 threads vs 1):");
        for (name, s) in &speedup_at_4 {
            let verdict = if *s > 1.5 { "PASS" } else { "MISS" };
            println!("  {name:<14} {s:.2}x  {verdict}");
        }
        if hw < 4 {
            println!("  (only {hw} hardware threads available — scaling is bounded by the machine)");
        }
    }
}
