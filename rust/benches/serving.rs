//! Serving-stack bench, recorded to `BENCH_serving.json`:
//!
//! An open-loop load generator (deterministic LCG: Poisson session
//! arrivals, heavy-tailed Pareto session lengths) drives the session
//! store + continuous-batching kernel in **virtual time** — latency is
//! measured in whole batch windows, so every reported number except the
//! wall clock is a pure function of (seed, config), independent of
//! thread count and machine speed.  The CI determinism stage byte-diffs
//! the `serving fingerprint:` line across two runs.
//!
//! Cases:
//!
//!  1. **steady_1e5** — ~3·10^5 sessions arrive over 2000 windows and
//!     >10^5 are concurrently live at the peak, against a session-store
//!     byte budget sized for 1.2·10^5 resident sessions, so LRU +
//!     idle-deadline eviction runs hot while latency holds at one
//!     window.  This is the 10^5-concurrent-sessions acceptance case.
//!  2. **overload_reject / overload_drop** — service capacity is set
//!     below the offered token rate, so the bounded queue fills and the
//!     two shed policies (reject-with-retry vs drop-oldest) are
//!     exercised under real backpressure; p95/p99 degrade visibly.
//!
//! Run: cargo bench --bench serving
//! Smoke mode (CI): PLMU_BENCH_SMOKE=1 cargo bench --bench serving

use plmu::autograd::ParamStore;
use plmu::benchlib::{repo_root, JsonValue, PerfJson, Table};
use plmu::coordinator::sessions::{
    run_load_sim, session_bytes, LoadSimConfig, ShedPolicy, SESSION_OVERHEAD_BYTES,
};
use plmu::coordinator::{NativeStreamingEngine, StreamingEngine};
use plmu::exec;
use plmu::layers::lmu::{LmuParallelLayer, LmuSpec};
use plmu::util::{Rng, Timer};

fn main() {
    let smoke = std::env::var("PLMU_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = hw.min(8);
    exec::set_threads(threads);
    let mut record = PerfJson::new("serving");

    // A d=8 engine: serving cost is dominated by per-session state, so
    // the smallest useful DN keeps the 10^5-session profile fast while
    // exercising the full store/queue/batching machinery.
    let mut rng = Rng::new(0);
    let mut store = ParamStore::new();
    let spec = LmuSpec::new(1, 1, 8, 64.0, 16);
    let layer = LmuParallelLayer::new(spec.clone(), 64, &mut store, &mut rng, "srv");
    let eng = NativeStreamingEngine::from_store(&spec, &layer.params, &store);
    let per_session = session_bytes(eng.state_size());
    // N bytes/session x 10^6 sessions = N MB
    println!(
        "session cost: {per_session} B/session ({} B state + {SESSION_OVERHEAD_BYTES} B overhead) \
         — 10^6 concurrent sessions = {per_session} MB of state",
        eng.state_size() * 4
    );

    // resident-session budgets (sessions, not bytes) per profile
    let steady_budget_sessions = if smoke { 64usize } else { 120_000 };
    let overload_budget_sessions = if smoke { 64usize } else { 40_000 };

    let steady = LoadSimConfig {
        seed: 42,
        windows: if smoke { 120 } else { 2000 },
        window_us: 500,
        arrivals_per_window: if smoke { 4.0 } else { 150.0 },
        session_tokens_mean: if smoke { 3.0 } else { 4.0 },
        token_gap_windows: if smoke { 10 } else { 300 },
        dx: 1,
        queue_cap: if smoke { 128 } else { 4096 },
        batch_cap: if smoke { 64 } else { 2048 },
        session_mem_bytes: steady_budget_sessions * per_session,
        idle_deadline_windows: Some(if smoke { 30 } else { 600 }),
        shed: ShedPolicy::RejectNew,
        retry_windows: 3,
        slo_us: 1500,
    };
    let overload = LoadSimConfig {
        seed: 42,
        windows: if smoke { 100 } else { 600 },
        window_us: 500,
        arrivals_per_window: if smoke { 10.0 } else { 80.0 },
        session_tokens_mean: if smoke { 4.0 } else { 6.0 },
        token_gap_windows: if smoke { 4 } else { 20 },
        dx: 1,
        queue_cap: if smoke { 48 } else { 512 },
        batch_cap: if smoke { 16 } else { 256 },
        session_mem_bytes: overload_budget_sessions * per_session,
        idle_deadline_windows: None,
        shed: ShedPolicy::RejectNew,
        retry_windows: 5,
        slo_us: 1500,
    };
    let overload_drop =
        LoadSimConfig { shed: ShedPolicy::DropOldest, ..overload.clone() };

    // reproducibility gate before timing anything: two runs of the same
    // (seed, config) must agree to the last output bit
    {
        let probe = LoadSimConfig { windows: 40, ..steady.clone() };
        let a = run_load_sim(&eng, &probe);
        let b = run_load_sim(&eng, &probe);
        assert_eq!(a.checksum, b.checksum, "load sim not reproducible for one seed");
    }

    println!(
        "\n=== serving under load ({threads} threads on {hw} hw{}) ===",
        if smoke { ", smoke" } else { "" }
    );
    let mut table = Table::new(&[
        "case",
        "served",
        "shed",
        "peak live",
        "store peak",
        "evicted",
        "p50/p95/p99 us",
        "slo viol",
        "tokens/s",
    ]);
    let mut fingerprints: Vec<String> = Vec::new();
    for (name, cfg) in [
        ("steady_1e5", &steady),
        ("overload_reject", &overload),
        ("overload_drop", &overload_drop),
    ] {
        exec::reset_dispatch_counts();
        let t = Timer::start();
        let rep = run_load_sim(&eng, cfg);
        let wall = t.elapsed();
        let (pooled, serial) = exec::dispatch_counts();
        assert!(
            !rep.budget_exceeded,
            "{name}: session store exceeded its byte budget — LRU invariant broken"
        );
        let offered = rep.served + rep.shed;
        let shed_rate = rep.shed as f64 / offered.max(1) as f64;
        let evict_rate =
            (rep.evicted_lru + rep.evicted_idle) as f64 / rep.sessions_started.max(1) as f64;
        table.row(&[
            name.to_string(),
            rep.served.to_string(),
            rep.shed.to_string(),
            rep.peak_live_sessions.to_string(),
            format!("{} sess / {} B", rep.peak_store_sessions, rep.peak_store_bytes),
            format!("{}+{}", rep.evicted_lru, rep.evicted_idle),
            format!("{}/{}/{}", rep.p50_us, rep.p95_us, rep.p99_us),
            rep.slo_violations.to_string(),
            format!("{:.0}", rep.served as f64 / wall),
        ]);
        record.push(&[
            ("case", JsonValue::Str(name.into())),
            ("threads", JsonValue::Int(threads as i64)),
            ("wall_ns", JsonValue::Int((wall * 1e9) as i64)),
            ("tokens_per_s", JsonValue::Num(rep.served as f64 / wall)),
            ("served", JsonValue::Int(rep.served as i64)),
            ("shed_rate", JsonValue::Num(shed_rate)),
            ("evict_rate", JsonValue::Num(evict_rate)),
            ("sessions_started", JsonValue::Int(rep.sessions_started as i64)),
            ("peak_live_sessions", JsonValue::Int(rep.peak_live_sessions as i64)),
            ("peak_store_sessions", JsonValue::Int(rep.peak_store_sessions as i64)),
            ("session_bytes", JsonValue::Int(per_session as i64)),
            ("peak_store_bytes", JsonValue::Int(rep.peak_store_bytes as i64)),
            ("session_mem_bytes", JsonValue::Int(cfg.session_mem_bytes as i64)),
            ("evicted_lru", JsonValue::Int(rep.evicted_lru as i64)),
            ("evicted_idle", JsonValue::Int(rep.evicted_idle as i64)),
            ("p50_us", JsonValue::Int(rep.p50_us as i64)),
            ("p95_us", JsonValue::Int(rep.p95_us as i64)),
            ("p99_us", JsonValue::Int(rep.p99_us as i64)),
            ("max_us", JsonValue::Int(rep.max_us as i64)),
            ("mean_us", JsonValue::Num(rep.mean_us)),
            ("slo_us", JsonValue::Int(cfg.slo_us as i64)),
            ("slo_violations", JsonValue::Int(rep.slo_violations as i64)),
            ("pooled_dispatches", JsonValue::Int(pooled as i64)),
            ("serial_dispatches", JsonValue::Int(serial as i64)),
            ("checksum", JsonValue::Str(format!("{:016x}", rep.checksum))),
            ("smoke", JsonValue::Bool(smoke)),
            ("hw_threads", JsonValue::Int(hw as i64)),
        ]);
        fingerprints.push(format!("{name}={:016x}", rep.checksum));
        if name == "steady_1e5" {
            if smoke {
                println!(
                    "steady_1e5 (smoke): {} peak live sessions — full profile targets >= 1e5",
                    rep.peak_live_sessions
                );
            } else if rep.peak_live_sessions >= 100_000 {
                println!(
                    "PASS: {} concurrent sessions at peak (>= 1e5) in {} B of store \
                     (budget {} B)",
                    rep.peak_live_sessions, rep.peak_store_bytes, cfg.session_mem_bytes
                );
            } else {
                println!(
                    "MISS: only {} concurrent sessions at peak (< 1e5)",
                    rep.peak_live_sessions
                );
            }
        }
        if name != "steady_1e5" {
            assert!(rep.shed > 0, "{name}: overload profile produced no shedding");
        }
    }
    table.print("serving under load (latencies in virtual time)");
    // the determinism witness: pure function of (seed, config)
    println!("serving fingerprint: {}", fingerprints.join(" "));
    exec::set_threads(1);

    let out = repo_root().join("BENCH_serving.json");
    match record.write(&out) {
        Ok(()) => println!("\nwrote {} ({} records)", out.display(), record.len()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }
}
