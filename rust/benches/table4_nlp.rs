//! Table 4: IMDB / QQP / SNLI with DN-only sentence encoders (d=1,
//! theta=maxlen, no nonlinearities) on frozen embeddings vs LSTM
//! baselines with orders of magnitude more trainable parameters.
//!
//! Two-sentence tasks use the paper's feature construction: encode both
//! sentences to u, v and classify [u; v; |u-v|; u*v].
//!
//! Corpora are seeded synthetic with planted structure (DESIGN.md
//! §Substitutions).

use plmu::autograd::{Graph, ParamStore};
use plmu::benchlib::Table;
use plmu::data::nlp::SynthLang;
use plmu::layers::lmu::{LmuParallelLayer, LmuSpec};
use plmu::layers::{Activation, Dense, LstmLayer};
use plmu::metrics::accuracy;
use plmu::optim::{Adam, Optimizer};
use plmu::util::{human_count, Rng};
use plmu::Tensor;

const DIM: usize = 32; // frozen embedding dim (GloVe stand-in)

fn embed(ids: &[usize], emb: &Tensor) -> Tensor {
    let mut out = Tensor::zeros(&[ids.len(), DIM]);
    for (i, &w) in ids.iter().enumerate() {
        out.data_mut()[i * DIM..(i + 1) * DIM].copy_from_slice(&emb.data()[w * DIM..(w + 1) * DIM]);
    }
    out
}

/// DN-only encoder shared by all three tasks.
struct DnEncoder {
    layer: LmuParallelLayer,
    len: usize,
}

impl DnEncoder {
    fn new(len: usize, store: &mut ParamStore, rng: &mut Rng) -> Self {
        let spec = LmuSpec { dx: DIM, du: DIM, d: 1, theta: len as f64, hidden: 1, nonlin_u: false, nonlin_o: false };
        DnEncoder { layer: LmuParallelLayer::new(spec, len, store, rng, "dn"), len }
    }

    /// ids -> (1, DIM) feature node in g
    fn encode(&self, g: &mut Graph, x: Tensor) -> plmu::autograd::NodeId {
        let xi = g.input(x);
        self.layer.dn_only_last(g, xi, 1)
    }

    fn seq_len(&self) -> usize {
        self.len
    }
}

/// One-sentence task: sentiment (IMDB row).
fn run_sentiment(lang: &SynthLang, emb: &Tensor, steps: usize) -> (f64, usize) {
    let len = 48usize;
    let (tx, ty) = lang.sentiment_dataset(400, len, 1);
    let (ex, ey) = lang.sentiment_dataset(150, len, 2);
    let mut rng = Rng::new(10);
    let mut store = ParamStore::new();
    let enc = DnEncoder::new(len, &mut store, &mut rng);
    let base = store.num_scalars();
    let head = Dense::new(DIM, 2, Activation::Linear, &mut store, &mut rng, "h");
    let trainable = store.num_scalars() - base;
    let mut opt = Adam::new(1e-2);
    for s in 0..steps {
        let i = s % tx.len();
        let mut g = Graph::new();
        let f = enc.encode(&mut g, embed(&tx[i], emb));
        let logits = head.forward(&mut g, &store, f);
        let loss = g.softmax_xent(logits, &[ty[i]]);
        g.backward(loss);
        let grads = g.param_grads();
        opt.step(&mut store, &grads);
    }
    let mut preds = Vec::new();
    for x in &ex {
        let mut g = Graph::new();
        let f = enc.encode(&mut g, embed(x, emb));
        let logits = head.forward(&mut g, &store, f);
        preds.push(g.value(logits).argmax_rows()[0]);
    }
    let _ = enc.seq_len();
    (accuracy(&preds, &ey), trainable)
}

/// Two-sentence tasks: features [u; v; |u-v|; u*v] -> classes.
fn run_pair_task(
    pairs: &[(Vec<usize>, Vec<usize>)],
    labels: &[usize],
    test_pairs: &[(Vec<usize>, Vec<usize>)],
    test_labels: &[usize],
    classes: usize,
    len: usize,
    emb: &Tensor,
    steps: usize,
) -> (f64, usize) {
    let mut rng = Rng::new(11);
    let mut store = ParamStore::new();
    let enc = DnEncoder::new(len, &mut store, &mut rng);
    let base = store.num_scalars();
    let head = Dense::new(4 * DIM, classes, Activation::Linear, &mut store, &mut rng, "h");
    let trainable = store.num_scalars() - base;
    let mut opt = Adam::new(1e-2);
    let features = |g: &mut Graph, a: &[usize], b: &[usize]| {
        let u = enc.encode(g, embed(a, emb));
        let v = enc.encode(g, embed(b, emb));
        let diff = g.sub(u, v);
        let adiff = g.abs(diff);
        let prod = g.mul(u, v);
        g.concat_cols(&[u, v, adiff, prod])
    };
    for s in 0..steps {
        let i = s % pairs.len();
        let mut g = Graph::new();
        let f = features(&mut g, &pairs[i].0, &pairs[i].1);
        let logits = head.forward(&mut g, &store, f);
        let loss = g.softmax_xent(logits, &[labels[i]]);
        g.backward(loss);
        let grads = g.param_grads();
        opt.step(&mut store, &grads);
    }
    let mut preds = Vec::new();
    for (a, b) in test_pairs {
        let mut g = Graph::new();
        let f = features(&mut g, a, b);
        let logits = head.forward(&mut g, &store, f);
        preds.push(g.value(logits).argmax_rows()[0]);
    }
    (accuracy(&preds, test_labels), trainable)
}

/// LSTM baseline for the sentiment row (param count comparison).
fn run_sentiment_lstm(lang: &SynthLang, emb: &Tensor, steps: usize) -> (f64, usize) {
    let len = 48usize;
    let (tx, ty) = lang.sentiment_dataset(400, len, 1);
    let (ex, ey) = lang.sentiment_dataset(150, len, 2);
    let mut rng = Rng::new(12);
    let mut store = ParamStore::new();
    let lstm = LstmLayer::new(DIM, 24, &mut store, &mut rng, "l");
    let head = Dense::new(24, 2, Activation::Linear, &mut store, &mut rng, "h");
    let trainable = store.num_scalars();
    let mut opt = Adam::new(1e-3);
    for s in 0..steps {
        let i = s % tx.len();
        let mut g = Graph::new();
        let xi = g.input(embed(&tx[i], emb)); // batch 1: layouts coincide
        let h = lstm.forward_last(&mut g, &store, xi, 1, len);
        let logits = head.forward(&mut g, &store, h);
        let loss = g.softmax_xent(logits, &[ty[i]]);
        g.backward(loss);
        let grads = g.param_grads();
        opt.step(&mut store, &grads);
    }
    let mut preds = Vec::new();
    for x in &ex {
        let mut g = Graph::new();
        let xi = g.input(embed(x, emb));
        let h = lstm.forward_last(&mut g, &store, xi, 1, len);
        let logits = head.forward(&mut g, &store, h);
        preds.push(g.value(logits).argmax_rows()[0]);
    }
    (accuracy(&preds, &ey), trainable)
}

fn main() {
    let lang = SynthLang::new(400, 10, 0);
    let mut rng = Rng::new(5);
    let emb = Tensor::randn(&[lang.vocab_size(), DIM], 1.0, &mut rng);
    let steps = 600usize;

    println!("IMDB row (sentiment)...");
    let (acc_dn, p_dn) = run_sentiment(&lang, &emb, steps);
    let (acc_lstm, p_lstm) = run_sentiment_lstm(&lang, &emb, steps / 2);

    println!("QQP row (paraphrase)...");
    let len = 16usize;
    let (px, py) = lang.paraphrase_dataset(400, len, 1);
    let (qx, qy) = lang.paraphrase_dataset(150, len, 2);
    let (acc_qqp, p_qqp) = run_pair_task(&px, &py, &qx, &qy, 2, len, &emb, steps);

    println!("SNLI row (inference)...");
    let (nx, ny) = lang.nli_dataset(450, len, 3);
    let (mx, my) = lang.nli_dataset(150, len, 4);
    let (acc_nli, p_nli) = run_pair_task(&nx, &ny, &mx, &my, 3, len, &emb, steps);

    let mut table = Table::new(&["task", "model", "trainable params", "acc % (ours)", "acc % (paper)"]);
    table.row(&["IMDB".into(), "DN-only".into(), human_count(p_dn), format!("{acc_dn:.2}"), "89.10 (301)".into()]);
    table.row(&["IMDB".into(), "LSTM".into(), human_count(p_lstm), format!("{acc_lstm:.2}"), "87.29 (50k)".into()]);
    table.row(&["QQP".into(), "DN-only".into(), human_count(p_qqp), format!("{acc_qqp:.2}"), "86.95 (1.2k)".into()]);
    table.row(&["SNLI".into(), "DN-only".into(), human_count(p_nli), format!("{acc_nli:.2}"), "78.85 (3.6k)".into()]);
    table.print("Table 4 — sentiment / paraphrase / NLI with DN-only encoders");
    println!("\nparam-ratio check (paper: 60-650x fewer than LSTM): LSTM/DN = {:.0}x", p_lstm as f64 / p_dn as f64);
}
