//! Figure 1 (left): training-time speedup of our model over the original
//! LMU, in both the sequential "LTI version" and the parallel version.
//!
//! One training step = forward + backward + Adam update, identical batch.
//! The paper reports 220x (psMNIST shape, n=784) and 64-200x (MG shape)
//! on a GTX 1080; we report the same ratios measured on this CPU.
//!
//! Run: cargo bench --bench fig1_speedup

use plmu::autograd::{Graph, ParamStore};
use plmu::benchlib::{bench, BenchConfig, Table};
use plmu::data::batcher::{BatchIter, SeqDataset, Targets};
use plmu::optim::{Adam, Optimizer};
use plmu::train::{ModelKind, SeqClassifier, TrainableModel};
use plmu::util::Rng;
use plmu::Tensor;

fn step_time(kind: ModelKind, n: usize, d: usize, hidden: usize, batch: usize) -> f64 {
    let mut store = ParamStore::new();
    let mut rng = Rng::new(0);
    let model = SeqClassifier::new(kind, n, 1, d, hidden, 10, &mut store, &mut rng);
    let xs: Vec<Tensor> = (0..batch).map(|_| Tensor::randn(&[n, 1], 1.0, &mut rng)).collect();
    let ys: Vec<usize> = (0..batch).map(|i| i % 10).collect();
    let ds = SeqDataset::classification(xs, ys);
    let batch_data = BatchIter::sequential(&ds, batch).next().unwrap();
    let _ = match &batch_data.targets {
        Targets::Labels(l) => l.len(),
        _ => 0,
    };
    let mut opt = Adam::new(1e-3);
    let cfg = BenchConfig { warmup_secs: 0.2, measure_secs: 1.0, max_iters: 50, min_iters: 3 };
    bench("step", cfg, || {
        let mut g = Graph::new();
        let loss = model.loss(&mut g, &store, &batch_data);
        g.backward(loss);
        let grads = g.param_grads();
        opt.step(&mut store, &grads);
    })
    .mean
}

fn main() {
    // psMNIST-shaped (paper: n=784, d=468; scaled so the ORIGINAL cell
    // finishes in bench time — ratios are what matters)
    let shapes = [
        ("psMNIST-shaped", 256usize, 32usize, 64usize, 16usize),
        ("Mackey-Glass-shaped", 128, 16, 28, 32),
    ];
    let mut table = Table::new(&[
        "workload", "original LMU", "ours (LTI)", "ours (parallel)",
        "LTI speedup", "parallel speedup", "paper (parallel)",
    ]);
    for (name, n, d, hidden, batch) in shapes {
        println!("measuring {name} (n={n}, d={d}, h={hidden}, B={batch})...");
        let t_orig = step_time(ModelKind::LmuOriginal, n, d, hidden, batch);
        let t_lti = step_time(ModelKind::LmuSequential, n, d, hidden, batch);
        let t_par = step_time(ModelKind::LmuParallel, n, d, hidden, batch);
        let paper = if name.starts_with("psMNIST") { "220x" } else { "~200x" };
        table.row(&[
            name.into(),
            format!("{:.1} ms", t_orig * 1e3),
            format!("{:.1} ms", t_lti * 1e3),
            format!("{:.1} ms", t_par * 1e3),
            format!("{:.1}x", t_orig / t_lti),
            format!("{:.1}x", t_orig / t_par),
            paper.into(),
        ]);
    }
    table.print("Figure 1 (left) — training-step speedup vs the original LMU");
    println!("\nshape check: parallel >> LTI > original (paper); absolute ratios are hardware-dependent (paper: GTX 1080, here: CPU)");
}
