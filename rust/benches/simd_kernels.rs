//! A/B bench for the `plmu::simd` 8-lane kernel layer: vector path vs
//! scalar reference wall time for dot, axpy, elementwise add, the f64
//! complex kernels behind the FFT (`f64_cmul`, `f64_conj_cmul`,
//! `f64_cmul_add`, `f64_butterfly`), full matmul through the
//! `PLMU_SIMD` knob, and the packed-vs-axpy GEMM paths (`gemm_*`,
//! Table 1 training shapes) through the `PLMU_GEMM` knob, at sizes
//! spanning the lane remainder cases (8k-1 / 8k / 8k+1).  Emits
//! `BENCH_simd.json` at the repo root (validated by `plmu bench-check`
//! in the CI bench stage, which requires the `f64_*` and `gemm_*`
//! speedup records to be present, finite, and positive).
//!
//! Before timing each case, the two paths are asserted bit-identical —
//! the layer's core contract (`rust/tests/simd_equivalence.rs` is the
//! exhaustive version).  Timing runs serial (`threads = 1`): this bench
//! measures single-thread kernel throughput, the quantity the SIMD
//! layer exists to raise; thread scaling stays `fig1_threads`' job.
//!
//! Run: cargo bench --bench simd_kernels
//! Smoke mode (CI): PLMU_BENCH_SMOKE=1 cargo bench --bench simd_kernels

use plmu::benchlib::{
    bench, checksum_f32 as checksum, checksum_f64, repo_root, BenchConfig, JsonValue, PerfJson,
    Table,
};
use plmu::exec;
use plmu::simd;
use plmu::tensor::packed::{set_gemm_path, GemmPath};
use plmu::util::Rng;
use plmu::Tensor;

struct Case {
    name: String,
    /// scalar ops per run (for throughput)
    items: f64,
    /// run the vector path, returning a result fingerprint
    vec: Box<dyn Fn() -> u64>,
    /// run the scalar reference, returning a result fingerprint
    scalar: Box<dyn Fn() -> u64>,
}

fn main() {
    let smoke = std::env::var("PLMU_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let cfg = if smoke {
        BenchConfig { warmup_secs: 0.02, measure_secs: 0.06, max_iters: 30, min_iters: 2 }
    } else {
        BenchConfig { warmup_secs: 0.1, measure_secs: 0.5, max_iters: 400, min_iters: 3 }
    };
    // single-thread kernel throughput: keep the exec pool out of the frame
    exec::set_threads(1);
    println!(
        "simd kernel A/B (vector vs scalar reference), serial{}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut rng = Rng::new(0);
    let mut cases: Vec<Case> = Vec::new();

    // ---- dot + axpy + elementwise at lane-remainder lengths ------------
    let lens: &[usize] =
        if smoke { &[63, 64, 65, 4095, 4096, 4097] } else { &[63, 64, 65, 4095, 4096, 4097, 65535, 65536, 65537] };
    for &n in lens {
        let a: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        {
            let (a, b) = (a.clone(), b.clone());
            let (a2, b2) = (a.clone(), b.clone());
            cases.push(Case {
                name: format!("dot_{n}"),
                items: (2 * n) as f64,
                vec: Box::new(move || simd::dot_vec(&a, &b).to_bits() as u64),
                scalar: Box::new(move || simd::dot_scalar(&a2, &b2).to_bits() as u64),
            });
        }
        {
            let (a, b) = (a.clone(), b.clone());
            let (a2, b2) = (a.clone(), b.clone());
            cases.push(Case {
                name: format!("axpy_{n}"),
                items: (2 * n) as f64,
                vec: Box::new(move || {
                    let mut y = b.clone();
                    simd::axpy_vec(1.25, &a, &mut y);
                    checksum(&y)
                }),
                scalar: Box::new(move || {
                    let mut y = b2.clone();
                    simd::axpy_scalar(1.25, &a2, &mut y);
                    checksum(&y)
                }),
            });
        }
        {
            let (a, b) = (a.clone(), b.clone());
            let (a2, b2) = (a.clone(), b.clone());
            cases.push(Case {
                name: format!("add_{n}"),
                items: n as f64,
                vec: Box::new(move || {
                    let mut out = vec![0.0f32; a.len()];
                    simd::add_vec(&a, &b, &mut out);
                    checksum(&out)
                }),
                scalar: Box::new(move || {
                    let mut out = vec![0.0f32; a2.len()];
                    simd::add_scalar(&a2, &b2, &mut out);
                    checksum(&out)
                }),
            });
        }
    }

    // ---- f64 complex kernels (the FFT / RfftCache inner loops) ---------
    let clens: &[usize] = if smoke { &[127, 128, 129] } else { &[127, 128, 129, 4095, 4096, 4097] };
    for &n in clens {
        let a: Vec<f64> = (0..2 * n).map(|_| rng.normal()).collect();
        let b: Vec<f64> = (0..2 * n).map(|_| rng.normal()).collect();
        {
            let (a, b) = (a.clone(), b.clone());
            let (a2, b2) = (a.clone(), b.clone());
            cases.push(Case {
                name: format!("f64_cmul_{n}"),
                items: (6 * n) as f64,
                vec: Box::new(move || {
                    let mut out = vec![0.0f64; a.len()];
                    simd::cmul_vec(&a, &b, &mut out);
                    checksum_f64(&out)
                }),
                scalar: Box::new(move || {
                    let mut out = vec![0.0f64; a2.len()];
                    simd::cmul_scalar(&a2, &b2, &mut out);
                    checksum_f64(&out)
                }),
            });
        }
        {
            let (a, b) = (a.clone(), b.clone());
            let (a2, b2) = (a.clone(), b.clone());
            cases.push(Case {
                name: format!("f64_conj_cmul_{n}"),
                items: (6 * n) as f64,
                vec: Box::new(move || {
                    let mut out = vec![0.0f64; a.len()];
                    simd::conj_cmul_vec(&a, &b, &mut out);
                    checksum_f64(&out)
                }),
                scalar: Box::new(move || {
                    let mut out = vec![0.0f64; a2.len()];
                    simd::conj_cmul_scalar(&a2, &b2, &mut out);
                    checksum_f64(&out)
                }),
            });
        }
        {
            let (a, b) = (a.clone(), b.clone());
            let (a2, b2) = (a.clone(), b.clone());
            cases.push(Case {
                name: format!("f64_cmul_add_{n}"),
                items: (8 * n) as f64,
                vec: Box::new(move || {
                    let mut out = vec![0.5f64; a.len()];
                    simd::cmul_add_vec(&a, &b, &mut out);
                    checksum_f64(&out)
                }),
                scalar: Box::new(move || {
                    let mut out = vec![0.5f64; a2.len()];
                    simd::cmul_add_scalar(&a2, &b2, &mut out);
                    checksum_f64(&out)
                }),
            });
        }
        {
            // one radix-2 stage at `n` butterflies (tw = a, hi = b)
            let (tw, hi0) = (a.clone(), b.clone());
            let lo0: Vec<f64> = (0..2 * n).map(|_| rng.normal()).collect();
            let (tw2, hi2, lo2) = (tw.clone(), hi0.clone(), lo0.clone());
            cases.push(Case {
                name: format!("f64_butterfly_{n}"),
                items: (10 * n) as f64,
                vec: Box::new(move || {
                    let mut lo = lo0.clone();
                    let mut hi = hi0.clone();
                    simd::butterfly_vec(&tw, &mut lo, &mut hi);
                    checksum_f64(&lo) ^ checksum_f64(&hi).rotate_left(1)
                }),
                scalar: Box::new(move || {
                    let mut lo = lo2.clone();
                    let mut hi = hi2.clone();
                    simd::butterfly_scalar(&tw2, &mut lo, &mut hi);
                    checksum_f64(&lo) ^ checksum_f64(&hi).rotate_left(1)
                }),
            });
        }
    }

    // ---- full matmul through the runtime knob --------------------------
    let shapes: &[(usize, usize, usize)] =
        if smoke { &[(32, 31, 33), (64, 64, 64)] } else { &[(64, 63, 65), (128, 128, 128), (256, 255, 257)] };
    for &(m, k, n) in shapes {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let (a2, b2) = (a.clone(), b.clone());
        cases.push(Case {
            name: format!("matmul_{m}x{k}x{n}"),
            items: (2 * m * k * n) as f64,
            vec: Box::new(move || {
                simd::set_enabled(true);
                checksum(a.matmul(&b).data())
            }),
            scalar: Box::new(move || {
                simd::set_enabled(false);
                let h = checksum(a2.matmul(&b2).data());
                simd::set_enabled(true);
                h
            }),
        });
    }

    // ---- packed vs axpy GEMM at Table 1 training shapes ----------------
    // (m = batch·seq rows against the paper's d=16..1024 hidden sizes;
    // "vec" is the PLMU_GEMM=packed micro-kernel, "scalar" the axpy
    // default, both on the same simd backend)
    let gemm_shapes: &[(usize, usize, usize)] = if smoke {
        &[(64, 64, 64), (128, 96, 33)]
    } else {
        &[(256, 256, 256), (1024, 256, 256), (512, 1024, 16)]
    };
    for &(m, k, n) in gemm_shapes {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let (a2, b2) = (a.clone(), b.clone());
        cases.push(Case {
            name: format!("gemm_{m}x{k}x{n}"),
            items: (2 * m * k * n) as f64,
            vec: Box::new(move || {
                set_gemm_path(GemmPath::Packed);
                checksum(a.matmul(&b).data())
            }),
            scalar: Box::new(move || {
                set_gemm_path(GemmPath::Axpy);
                checksum(a2.matmul(&b2).data())
            }),
        });
    }

    let mut record = PerfJson::new("simd_kernels");
    let mut table = Table::new(&["case", "vector (µs)", "scalar (µs)", "speedup"]);
    let mut worst: Option<(String, f64)> = None;

    for case in &cases {
        // contract first: the two paths must be bit-identical
        let (v, s) = ((case.vec)(), (case.scalar)());
        assert_eq!(v, s, "{}: vector and scalar paths disagree", case.name);

        let vec_stats = bench(&case.name, cfg, || {
            std::hint::black_box((case.vec)());
        });
        let scalar_stats = bench(&case.name, cfg, || {
            std::hint::black_box((case.scalar)());
        });
        let speedup = scalar_stats.mean / vec_stats.mean;
        if worst.as_ref().map(|(_, w)| speedup < *w).unwrap_or(true) {
            worst = Some((case.name.clone(), speedup));
        }
        table.row(&[
            case.name.clone(),
            format!("{:.2}", vec_stats.mean * 1e6),
            format!("{:.2}", scalar_stats.mean * 1e6),
            format!("{speedup:.2}x"),
        ]);
        record.push(&[
            ("case", JsonValue::Str(case.name.clone())),
            ("threads", JsonValue::Int(1)),
            ("wall_ns", JsonValue::Int((vec_stats.mean * 1e9) as i64)),
            ("simd_s", JsonValue::Num(vec_stats.mean)),
            ("scalar_s", JsonValue::Num(scalar_stats.mean)),
            ("p50_s", JsonValue::Num(vec_stats.p50)),
            ("items_per_s", JsonValue::Num(case.items / vec_stats.mean)),
            ("speedup_vs_scalar", JsonValue::Num(speedup)),
            ("smoke", JsonValue::Bool(smoke)),
        ]);
    }

    table.print("simd kernels — vector vs scalar reference (serial)");

    let out = repo_root().join("BENCH_simd.json");
    match record.write(&out) {
        Ok(()) => println!("\nwrote {} ({} records)", out.display(), record.len()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }

    // acceptance: the vector path must never lose badly to the scalar
    // reference (with the portable backend both lower to similar code,
    // so ~1.0x is expected; a large regression means the vector path
    // grew overhead)
    if let Some((name, w)) = worst {
        let verdict = if w > 0.8 { "PASS" } else { "MISS" };
        println!("\nacceptance (worst vector-vs-scalar ratio > 0.8x): {name} {w:.2}x  {verdict}");
    }
}
