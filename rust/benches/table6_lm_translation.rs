//! Table 6: character-level language modelling (text8 stand-in, bits per
//! character) and translation (IWSLT stand-in, BLEU).
//!
//!  * LM: 3 stacked blocks with theta=15 each (the paper's text8 config,
//!    effective context sum theta_i = 45) vs an LSTM LM, bpc on held-out
//!    text; the paper reports 1.61 vs 1.65 at 3.2M params.
//!  * Translation: LMU encoder + cross-attention decoder on the synthetic
//!    deterministic translation task; corpus BLEU-4 vs an LSTM encoder
//!    with the same decoder.  Paper: 25.5 BLEU vs 23.3.

use plmu::autograd::{Graph, ParamStore};
use plmu::benchlib::Table;
use plmu::data::nlp::SynthLang;
use plmu::data::CharTokenizer;
use plmu::layers::{Activation, Dense, Embedding, LstmLayer};
use plmu::metrics::{bleu4, bpc_from_nats};
use plmu::optim::{Adam, LrSchedule, Optimizer};
use plmu::train::{LmModel, Translator};
use plmu::util::{human_count, Rng, Timer};

fn main() {
    let lang = SynthLang::new(300, 8, 0);

    // ================= text8-style char LM ==============================
    let n = 60usize; // paper: 180; scaled for bench budget
    let chars = lang.char_stream(40_000, 3);
    let split = chars.len() * 9 / 10;
    let (train_cs, test_cs) = chars.split_at(split);
    let vocab = CharTokenizer::ALPHABET;
    let steps = 400usize;

    // ---- ours: 3 blocks, theta=15 (paper's text8 setting) --------------
    let mut store = ParamStore::new();
    let mut rng = Rng::new(0);
    let lm = LmModel::new(vocab, 32, 3, 8, 15.0, n, &mut store, &mut rng);
    // paper: lr x0.1 halfway through training (text8 is the only dataset
    // with a schedule)
    let sched = LrSchedule::step_decay(2e-3, 1, 0.1);
    let mut opt = Adam::new(sched.lr_at(0));
    let timer = Timer::start();
    for s in 0..steps {
        if s == steps / 2 {
            opt.set_lr(sched.lr_at(1));
        }
        let ofs = (s * 17) % (train_cs.len() - n - 1);
        let window = train_cs[ofs..ofs + n + 1].to_vec();
        let mut g = Graph::new();
        let loss = lm.lm_loss(&mut g, &store, &[window]);
        g.backward(loss);
        let grads = g.param_grads();
        opt.step(&mut store, &grads);
    }
    let t_ours = timer.elapsed();
    // held-out bpc
    let mut nll = 0.0f64;
    let evals = 20usize;
    for e in 0..evals {
        let ofs = (e * 97) % (test_cs.len() - n - 1);
        nll += lm.eval_nll(&store, &[test_cs[ofs..ofs + n + 1].to_vec()]);
    }
    let bpc_ours = bpc_from_nats(nll / evals as f64);
    let p_ours = store.num_scalars();
    println!("ours: {bpc_ours:.3} bpc ({t_ours:.1}s, {} params)", human_count(p_ours));

    // ---- LSTM LM baseline ----------------------------------------------
    let mut store_l = ParamStore::new();
    let mut rng_l = Rng::new(1);
    let emb = Embedding::new(vocab, 32, &mut store_l, &mut rng_l, "lm");
    let lstm = LstmLayer::new(32, 48, &mut store_l, &mut rng_l, "lm.lstm");
    let head = Dense::new(48, vocab, Activation::Linear, &mut store_l, &mut rng_l, "lm.head");
    let mut opt_l = Adam::new(2e-3);
    let timer = Timer::start();
    for s in 0..steps / 2 {
        // LSTM steps cost more; budget-matched wall-clock-ish
        let ofs = (s * 17) % (train_cs.len() - n - 1);
        let inputs = &train_cs[ofs..ofs + n];
        let labels: Vec<usize> = train_cs[ofs + 1..ofs + n + 1].to_vec();
        let mut g = Graph::new();
        let e = emb.forward(&mut g, &store_l, inputs);
        let h = lstm.forward_all(&mut g, &store_l, e, 1, n);
        let logits = head.forward(&mut g, &store_l, h);
        let loss = g.softmax_xent(logits, &labels);
        g.backward(loss);
        let grads = g.param_grads();
        opt_l.step(&mut store_l, &grads);
    }
    let t_lstm = timer.elapsed();
    let mut nll_l = 0.0f64;
    for e in 0..evals {
        let ofs = (e * 97) % (test_cs.len() - n - 1);
        let inputs = &test_cs[ofs..ofs + n];
        let labels: Vec<usize> = test_cs[ofs + 1..ofs + n + 1].to_vec();
        let mut g = Graph::new();
        let emb_n = emb.forward(&mut g, &store_l, inputs);
        let h = lstm.forward_all(&mut g, &store_l, emb_n, 1, n);
        let logits = head.forward(&mut g, &store_l, h);
        let loss = g.softmax_xent(logits, &labels);
        nll_l += g.value(loss).item() as f64;
    }
    let bpc_lstm = bpc_from_nats(nll_l / evals as f64);
    println!("LSTM: {bpc_lstm:.3} bpc ({t_lstm:.1}s, {} params)", human_count(store_l.num_scalars()));

    // ================= translation ======================================
    // a smaller vocabulary keeps the bench budget sane (the example-scale
    // run uses the full 300-word language)
    let tlang = SynthLang::new(80, 8, 1);
    let tlen = 12usize;
    let pairs = tlang.translation_dataset(600, tlen, 4, 9);
    let (train_p, test_p) = pairs.split_at(520);
    let t_steps = 8000usize;

    let mut store_t = ParamStore::new();
    let mut rng_t = Rng::new(2);
    let tr = Translator::new(tlang.vocab_size(), tlang.vocab_size(), 48, 10, tlen, &mut store_t, &mut rng_t);
    let mut opt_t = Adam::new(3e-3);
    let timer = Timer::start();
    for s in 0..t_steps {
        let (src, tgt) = &train_p[s % train_p.len()];
        let mut g = Graph::new();
        let loss = tr.loss(&mut g, &store_t, src, tgt);
        g.backward(loss);
        let grads = g.param_grads();
        opt_t.step(&mut store_t, &grads);
    }
    let t_tr = timer.elapsed();
    let cands: Vec<Vec<usize>> = test_p.iter().map(|(s, _)| tr.translate(&store_t, s)).collect();
    let refs: Vec<Vec<usize>> = test_p.iter().map(|(_, t)| t.clone()).collect();
    let bleu_ours = bleu4(&cands, &refs);
    println!("translation (ours): BLEU {bleu_ours:.1} ({t_tr:.1}s, {} params)", human_count(store_t.num_scalars()));

    let mut table = Table::new(&["task", "model", "params", "metric (ours)", "metric (paper)"]);
    table.row(&["text8 (bpc)".into(), "Our Model (3 blocks, theta=15)".into(), human_count(p_ours), format!("{bpc_ours:.3}"), "1.61".into()]);
    table.row(&["text8 (bpc)".into(), "LSTM".into(), human_count(store_l.num_scalars()), format!("{bpc_lstm:.3}"), "1.65".into()]);
    table.row(&["IWSLT-like (BLEU)".into(), "Our Model enc-dec + attn".into(), human_count(store_t.num_scalars()), format!("{bleu_ours:.1}"), "25.5".into()]);
    table.print("Table 6 — language modelling & translation");
    println!(
        "\nshape check (paper: ours <= LSTM bpc): {}",
        if bpc_ours <= bpc_lstm + 0.05 { "HOLDS" } else { "VIOLATED (budget too small)" }
    );
}
