//! Table 3: Mackey-Glass NRMSE — quick-budget version of
//! examples/mackey_glass.rs (which carries the full experiment).

use plmu::autograd::ParamStore;
use plmu::benchlib::Table;
use plmu::data::{MackeyGlass, SeqDataset};
use plmu::optim::Adam;
use plmu::train::{evaluate, fit, FitOptions, RegressorKind, SeqRegressor};
use plmu::util::{human_count, Rng, Timer};

fn main() {
    let mg = MackeyGlass::generate(2400, 0);
    let (mean, std) = mg.stats();
    let mut mgz = mg;
    for v in mgz.series.iter_mut() {
        *v = (*v - mean) / std;
    }
    let seq = 48usize;
    let (xs, ys) = mgz.windows(seq, 15, 2);
    let (train, test) = SeqDataset::regression(xs, ys).split(0.25);
    println!("Mackey-Glass: {} train / {} test windows (n={seq}, predict t+15)", train.len(), test.len());

    let mut table = Table::new(&["model", "params", "train s", "NRMSE (ours)", "NRMSE (paper)"]);
    for (kind, name, paper, d, theta, hidden) in [
        (RegressorKind::Lstm, "LSTM", "0.059", 4usize, 4.0f64, 28usize),
        (RegressorKind::LmuOriginal, "LMU", "0.049", 4, 4.0, 28),
        (RegressorKind::Hybrid, "Hybrid", "0.045", 4, 4.0, 28),
        (RegressorKind::LmuParallel, "Our Model", "0.044", 40, 50.0, 140),
    ] {
        let mut store = ParamStore::new();
        let mut rng = Rng::new(7);
        let model = SeqRegressor::new(kind, seq, d, theta, hidden, &mut store, &mut rng);
        let mut opt = Adam::new(1e-3);
        let opts = FitOptions { epochs: 25, batch_size: 32, ..Default::default() };
        let timer = Timer::start();
        fit(&model, &mut store, &mut opt, &train, None, &opts);
        let nrmse = evaluate(&model, &store, &test, 32);
        table.row(&[
            name.into(),
            human_count(store.num_scalars()),
            format!("{:.1}", timer.elapsed()),
            format!("{nrmse:.4}"),
            paper.into(),
        ]);
        println!("  {name}: NRMSE {nrmse:.4}");
    }
    table.print("Table 3 — Mackey-Glass NRMSE (quick bench)");
}
