//! Table 2: psMNIST accuracy (scaled-down synthetic; see DESIGN.md).
//! A quick-budget version of examples/psmnist.rs suited to `cargo bench`;
//! run the example with --side 16 --epochs 10 for the fuller experiment.

use plmu::autograd::ParamStore;
use plmu::benchlib::Table;
use plmu::data::{PsMnist, SeqDataset};
use plmu::optim::Adam;
use plmu::train::{fit, FitOptions, ModelKind, SeqClassifier};
use plmu::util::{human_count, Rng, Timer};

fn main() {
    let side = 10usize;
    let task = PsMnist::new(side, 10, 0);
    let (xs, ys) = task.dataset(400, 1);
    let (train, test) = SeqDataset::classification(xs, ys).split(0.25);
    println!("synthetic psMNIST {side}x{side} (n={}), {} train / {} test", task.seq_len(), train.len(), test.len());

    let mut table = Table::new(&["model", "params", "train s", "acc % (ours)", "acc % (paper)"]);
    for (kind, name, paper) in [
        (ModelKind::Lstm, "LSTM", "89.86"),
        (ModelKind::LmuParallel, "Our Model", "98.49"),
    ] {
        let mut store = ParamStore::new();
        let mut rng = Rng::new(4);
        let model = SeqClassifier::new(kind, task.seq_len(), 1, 24, 40, 10, &mut store, &mut rng);
        let mut opt = Adam::new(1e-3);
        let opts = FitOptions { epochs: 4, batch_size: 32, ..Default::default() };
        let timer = Timer::start();
        let res = fit(&model, &mut store, &mut opt, &train, Some(&test), &opts);
        let acc = res.epochs.last().unwrap().eval_metric.unwrap();
        table.row(&[
            name.into(),
            human_count(store.num_scalars()),
            format!("{:.1}", timer.elapsed()),
            format!("{acc:.2}"),
            paper.into(),
        ]);
        println!("  {name}: {acc:.2}%");
    }
    table.print("Table 2 — psMNIST (quick bench; paper column = full-scale result)");
}
