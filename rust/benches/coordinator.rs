//! Coordinator benches, recorded to `BENCH_coordinator.json`:
//!
//!  1. **Pipelined vs synchronous data-parallel training** — the same
//!     workload run with `pipeline` off (bulk-synchronous: every step
//!     barriers on the all-reduce) and on (staleness-1: the optimizer
//!     stage of step k overlaps batch k+1's replica forward/backward as
//!     an async pool job), across ≥ 2 replica counts.  The pipelined
//!     run is asserted reproducible (two runs bit-identical) before it
//!     is timed.
//!  2. **Streaming-server throughput vs batching window** — the dynamic
//!     batcher's latency/throughput trade-off, with the batch-pipelining
//!     knob exercised at the widest window.
//!
//! Run: cargo bench --bench coordinator
//! Smoke mode (CI): PLMU_BENCH_SMOKE=1 cargo bench --bench coordinator

use plmu::autograd::ParamStore;
use plmu::benchlib::{repo_root, JsonValue, PerfJson, Table};
use plmu::coordinator::data_parallel::{
    shard_dataset, DataParallelConfig, DataParallelCoordinator,
};
use plmu::coordinator::{NativeStreamingEngine, ServerConfig, StreamingServer};
use plmu::data::PsMnist;
use plmu::exec;
use plmu::layers::lmu::{LmuParallelLayer, LmuSpec};
use plmu::optim::Adam;
use plmu::train::{ModelKind, SeqClassifier};
use plmu::util::{Rng, Timer};
use std::time::Duration;

fn main() {
    let smoke = std::env::var("PLMU_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let threads = hw.min(8);
    let mut record = PerfJson::new("coordinator");

    // ------------------- 1. pipelined vs synchronous data parallelism --
    let side = if smoke { 8usize } else { 14 };
    let examples = if smoke { 64usize } else { 384 };
    let epochs = if smoke { 1usize } else { 2 };
    let (d, hidden) = if smoke { (8usize, 16usize) } else { (32, 64) };
    let seq = side * side;
    let task = PsMnist::new(side, 10, 0);
    exec::set_threads(threads);
    println!(
        "=== data-parallel: pipelined vs synchronous ({threads} threads on {hw} hw{}) ===",
        if smoke { ", smoke" } else { "" }
    );
    let mut table =
        Table::new(&["replicas", "mode", "steps", "wall s", "steps/s", "pipeline speedup"]);
    for replicas in [2usize, 4] {
        let mut sync_wall: Option<f64> = None;
        for pipeline in [false, true] {
            let factory = move || {
                let mut store = ParamStore::new();
                let mut r = Rng::new(42);
                let model = SeqClassifier::new(
                    ModelKind::LmuParallel,
                    seq,
                    1,
                    d,
                    hidden,
                    10,
                    &mut store,
                    &mut r,
                );
                (store, model)
            };
            let cfg = DataParallelConfig {
                workers: replicas,
                epochs,
                batch_size: 16,
                grad_clip: None,
                seed: 0,
                pipeline,
            };
            let run = || {
                let (xs, ys) = task.dataset(examples, 1);
                let shards = shard_dataset(xs, ys, replicas);
                let mut opt = Adam::new(1e-3);
                DataParallelCoordinator::run(factory, shards, &mut opt, &cfg)
            };
            if pipeline {
                // reproducibility gate before timing: two pipelined runs
                // must agree bit-for-bit
                let a = run();
                let b = run();
                assert_eq!(a.final_params.len(), b.final_params.len());
                for (i, (x, y)) in a.final_params.iter().zip(&b.final_params).enumerate() {
                    assert!(
                        x.to_bits() == y.to_bits(),
                        "pipelined run not reproducible (replicas={replicas}, param {i})"
                    );
                }
            }
            let t = Timer::start();
            let res = run();
            let wall = t.elapsed();
            let mode = if pipeline { "pipelined" } else { "sync" };
            let speedup = match (pipeline, sync_wall) {
                (true, Some(s)) => s / wall,
                _ => {
                    sync_wall = Some(wall);
                    1.0
                }
            };
            table.row(&[
                replicas.to_string(),
                mode.to_string(),
                res.steps.to_string(),
                format!("{wall:.2}"),
                format!("{:.1}", res.steps as f64 / wall),
                format!("{speedup:.2}x"),
            ]);
            record.push(&[
                ("case", JsonValue::Str(format!("dp_{mode}"))),
                ("threads", JsonValue::Int(threads as i64)),
                ("wall_ns", JsonValue::Int((wall * 1e9) as i64)),
                ("replicas", JsonValue::Int(replicas as i64)),
                ("steps", JsonValue::Int(res.steps as i64)),
                ("steps_per_s", JsonValue::Num(res.steps as f64 / wall)),
                ("pipeline", JsonValue::Bool(pipeline)),
                ("pipeline_speedup", JsonValue::Num(speedup)),
                ("smoke", JsonValue::Bool(smoke)),
                ("hw_threads", JsonValue::Int(hw as i64)),
            ]);
        }
    }
    table.print("data-parallel training — pipelined vs synchronous");

    // ------------------- 2. streaming server: throughput vs window ------
    println!("\n=== streaming server: throughput vs batch window ===");
    let mut rng = Rng::new(0);
    let mut store = ParamStore::new();
    let spec = LmuSpec::new(1, 1, 32, 64.0, 32);
    let layer = LmuParallelLayer::new(spec.clone(), 64, &mut store, &mut rng, "b");
    let mut table = Table::new(&[
        "window (us)",
        "max batch",
        "pipelined",
        "tokens/s",
        "mean latency (us)",
        "mean batch",
    ]);
    let (sessions, tokens) = if smoke { (4u64, 60usize) } else { (8, 300) };
    for (window_us, max_batch, pipeline) in
        [(0u64, 1usize, false), (200, 8, false), (500, 32, false), (2000, 64, false), (2000, 64, true)]
    {
        let server = StreamingServer::new(
            1,
            ServerConfig {
                max_batch,
                window: Duration::from_micros(window_us),
                pipeline,
                ..Default::default()
            },
            || Box::new(NativeStreamingEngine::from_store(&spec, &layer.params, &store)),
        );
        let t = Timer::start();
        std::thread::scope(|scope| {
            for sid in 0..sessions {
                let router = &server.router;
                scope.spawn(move || {
                    for k in 0..tokens {
                        let _ = router.step_blocking(sid, vec![(k as f32).sin()]);
                    }
                });
            }
        });
        let wall = t.elapsed();
        let total = server.router.total_requests();
        let m = server.router.metrics_of(0);
        table.row(&[
            window_us.to_string(),
            max_batch.to_string(),
            pipeline.to_string(),
            format!("{:.0}", total as f64 / wall),
            format!("{:.0}", m.mean_latency_us()),
            format!("{:.2}", m.mean_batch_size()),
        ]);
        record.push(&[
            ("case", JsonValue::Str("serving".into())),
            ("threads", JsonValue::Int(threads as i64)),
            ("wall_ns", JsonValue::Int((wall * 1e9) as i64)),
            ("window_us", JsonValue::Int(window_us as i64)),
            ("max_batch", JsonValue::Int(max_batch as i64)),
            ("pipeline", JsonValue::Bool(pipeline)),
            ("tokens_per_s", JsonValue::Num(total as f64 / wall)),
            ("mean_latency_us", JsonValue::Num(m.mean_latency_us())),
            ("mean_batch", JsonValue::Num(m.mean_batch_size())),
            ("smoke", JsonValue::Bool(smoke)),
            ("hw_threads", JsonValue::Int(hw as i64)),
        ]);
    }
    table.print("streaming throughput/latency trade-off");
    exec::set_threads(1);

    let out = repo_root().join("BENCH_coordinator.json");
    match record.write(&out) {
        Ok(()) => println!("\nwrote {} ({} records)", out.display(), record.len()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }
}
