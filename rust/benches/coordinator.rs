//! Coordinator benches: (a) streaming-server throughput vs batching
//! window, (b) data-parallel scaling across worker threads.

use plmu::autograd::ParamStore;
use plmu::benchlib::Table;
use plmu::coordinator::data_parallel::{shard_dataset, DataParallelConfig, DataParallelCoordinator};
use plmu::coordinator::{NativeStreamingEngine, ServerConfig, StreamingServer};
use plmu::data::PsMnist;
use plmu::layers::lmu::{LmuParallelLayer, LmuSpec};
use plmu::optim::Adam;
use plmu::train::{ModelKind, SeqClassifier};
use plmu::util::{Rng, Timer};
use std::time::Duration;

fn main() {
    // ---------------- streaming server ---------------------------------
    println!("=== streaming server: throughput vs batch window ===");
    let mut rng = Rng::new(0);
    let mut store = ParamStore::new();
    let spec = LmuSpec::new(1, 1, 32, 64.0, 32);
    let layer = LmuParallelLayer::new(spec.clone(), 64, &mut store, &mut rng, "b");
    let mut table = Table::new(&["window (us)", "max batch", "tokens/s", "mean latency (us)", "mean batch"]);
    for (window_us, max_batch) in [(0u64, 1usize), (200, 8), (500, 32), (2000, 64)] {
        let server = StreamingServer::new(
            1,
            ServerConfig { max_batch, window: Duration::from_micros(window_us) },
            || Box::new(NativeStreamingEngine::from_store(&spec, &layer.params, &store)),
        );
        let (sessions, tokens) = (8u64, 300usize);
        let t = Timer::start();
        std::thread::scope(|scope| {
            for sid in 0..sessions {
                let router = &server.router;
                scope.spawn(move || {
                    for k in 0..tokens {
                        let _ = router.step_blocking(sid, vec![(k as f32).sin()]);
                    }
                });
            }
        });
        let wall = t.elapsed();
        let total = server.router.total_requests();
        let b0 = &server.router;
        let _ = b0;
        let m = server.router.metrics_of(0);
        table.row(&[
            window_us.to_string(),
            max_batch.to_string(),
            format!("{:.0}", total as f64 / wall),
            format!("{:.0}", m.mean_latency_us()),
            format!("{:.2}", m.mean_batch_size()),
        ]);
    }
    table.print("streaming throughput/latency trade-off");

    // ---------------- data-parallel scaling -----------------------------
    println!("\n=== data-parallel training scaling ===");
    let side = 14usize;
    let task = PsMnist::new(side, 10, 0);
    let mut table = Table::new(&["workers", "sync steps", "wall s", "worker-batches/s", "speedup"]);
    let mut base: Option<f64> = None;
    for workers in [1usize, 2, 4] {
        let (xs, ys) = task.dataset(384, 1);
        let shards = shard_dataset(xs, ys, workers);
        let seq = side * side;
        let factory = move || {
            let mut store = ParamStore::new();
            let mut r = Rng::new(42);
            let model = SeqClassifier::new(ModelKind::LmuParallel, seq, 1, 32, 64, 10, &mut store, &mut r);
            (store, model)
        };
        let mut opt = Adam::new(1e-3);
        let cfg = DataParallelConfig { workers, epochs: 2, batch_size: 16, grad_clip: None, seed: 0 };
        let t = Timer::start();
        let res = DataParallelCoordinator::run(factory, shards, &mut opt, &cfg);
        let wall = t.elapsed();
        // per sync step each worker processes one batch: samples/s scales
        let sps = res.steps as f64 / wall * workers as f64; // worker-batches per second
        if base.is_none() {
            base = Some(sps);
        }
        table.row(&[
            workers.to_string(),
            res.steps.to_string(),
            format!("{wall:.2}"),
            format!("{sps:.1}"),
            format!("{:.2}x", sps / base.unwrap()),
        ]);
    }
    table.print("data-parallel scaling (worker-batches/s)");
}
