//! Table 5: semi-supervised sentiment — pretrain a language model on an
//! unlabeled corpus (Amazon-reviews stand-in), then finetune a sentiment
//! classifier on the deep (weighted-block) representations; compare with
//! training the same architecture from scratch.
//!
//! The paper's claim: pretraining lifts IMDB accuracy above both the
//! from-scratch model and larger baselines (92.88 LSTM / 92.82 DistilBERT
//! / 93.20 ours, with ours at half the parameters).

use plmu::autograd::{Graph, ParamStore};
use plmu::benchlib::Table;
use plmu::data::nlp::SynthLang;
use plmu::layers::{Activation, Dense};
use plmu::metrics::accuracy;
use plmu::optim::{Adam, Optimizer};
use plmu::train::LmModel;
use plmu::util::{human_count, Rng, Timer};

fn finetune_and_eval(
    lm: &LmModel,
    store: &mut ParamStore,
    lang: &SynthLang,
    steps: usize,
    seed: u64,
) -> f64 {
    let n = lm.n;
    let (tx, ty) = lang.sentiment_dataset(300, n, seed);
    let (ex, ey) = lang.sentiment_dataset(120, n, seed + 1);
    let mut rng = Rng::new(seed);
    let head = Dense::new(lm.dim, 2, Activation::Linear, store, &mut rng, "ft.head");
    let mut opt = Adam::new(1e-3); // paper: Adam defaults even when finetuning
    for s in 0..steps {
        let i = s % tx.len();
        let mut g = Graph::new();
        let h = lm.encode_deep(&mut g, store, &tx[i], 1); // (n, dim)
        let last = g.slice_rows(h, n - 1, n);
        let logits = head.forward(&mut g, store, last);
        let loss = g.softmax_xent(logits, &[ty[i]]);
        g.backward(loss);
        let grads = g.param_grads();
        opt.step(store, &grads);
    }
    let mut preds = Vec::new();
    for x in &ex {
        let mut g = Graph::new();
        let h = lm.encode_deep(&mut g, store, x, 1);
        let last = g.slice_rows(h, n - 1, n);
        let logits = head.forward(&mut g, store, last);
        preds.push(g.value(logits).argmax_rows()[0]);
    }
    accuracy(&preds, &ey)
}

fn main() {
    let lang = SynthLang::new(300, 8, 0);
    let (vocab, dim, blocks, d, theta, n) = (300usize, 24usize, 3usize, 6usize, 6.0f64, 24usize);
    let pretrain_steps = 500usize;
    let finetune_steps = 400usize;

    // ---------------- pretrained path ----------------------------------
    let mut store_a = ParamStore::new();
    let mut rng = Rng::new(0);
    let lm_a = LmModel::new(vocab, dim, blocks, d, theta, n, &mut store_a, &mut rng);
    let stream = lang.lm_stream(pretrain_steps * (n + 1) + 64, 7);
    let mut opt = Adam::new(1e-3);
    let timer = Timer::start();
    let mut lm_losses = Vec::new();
    for s in 0..pretrain_steps {
        let ofs = s * (n + 1) % (stream.len() - n - 1);
        let window = stream[ofs..ofs + n + 1].to_vec();
        let mut g = Graph::new();
        let loss = lm_a.lm_loss(&mut g, &store_a, &[window]);
        lm_losses.push(g.value(loss).item());
        g.backward(loss);
        let grads = g.param_grads();
        opt.step(&mut store_a, &grads);
    }
    let pre_time = timer.elapsed();
    println!(
        "pretrained LM {pretrain_steps} steps in {pre_time:.1}s: loss {:.3} -> {:.3} (ln V = {:.2})",
        lm_losses[0],
        lm_losses.last().unwrap(),
        (vocab as f32).ln()
    );
    let acc_pre = finetune_and_eval(&lm_a, &mut store_a, &lang, finetune_steps, 21);

    // ---------------- from-scratch path ---------------------------------
    let mut store_b = ParamStore::new();
    let mut rng_b = Rng::new(0);
    let lm_b = LmModel::new(vocab, dim, blocks, d, theta, n, &mut store_b, &mut rng_b);
    let acc_scratch = finetune_and_eval(&lm_b, &mut store_b, &lang, finetune_steps, 21);

    let mut table = Table::new(&["model", "params", "acc % (ours)", "acc % (paper)"]);
    table.row(&["from scratch".into(), human_count(store_b.num_scalars()), format!("{acc_scratch:.2}"), "-".into()]);
    table.row(&["pretrained + finetune".into(), human_count(store_a.num_scalars()), format!("{acc_pre:.2}"), "93.20 (34M)".into()]);
    table.print("Table 5 — sentiment with LM pretraining (Amazon stand-in)");
    println!(
        "\nshape check (paper: pretraining helps): {}",
        if acc_pre >= acc_scratch { "HOLDS" } else { "VIOLATED (budget too small)" }
    );
}
