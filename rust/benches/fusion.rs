//! A/B bench for the elementwise fusion pass (`PLMU_FUSION`): fused
//! graph builders (`affine_act` matmul epilogue, `add2_row_act`,
//! `add3_act`) vs the unfused node chains they replace, plus a full
//! end-to-end training step, at table-1-ish shapes.  Emits
//! `BENCH_fusion.json` at the repo root (validated by `plmu
//! bench-check` in the CI bench stage).
//!
//! Each record carries measured wall time for both paths AND a
//! bytes-moved figure: analytic traffic estimates for the kernel
//! chains (the unfused chain re-reads and re-writes every
//! intermediate; the fused kernel touches each element once), and
//! *measured* cold-step arena allocation for the train-step case.
//! Before timing, each case asserts the two paths bit-identical —
//! the fusion contract (`rust/tests/fusion_equivalence.rs` is the
//! exhaustive version).
//!
//! Run: cargo bench --bench fusion
//! Smoke mode (CI): PLMU_BENCH_SMOKE=1 cargo bench --bench fusion

use plmu::autograd::{Act, Graph, NodeId, ParamStore};
use plmu::benchlib::{
    bench, checksum_f32 as checksum, repo_root, BenchConfig, JsonValue, PerfJson, Table,
};
use plmu::data::batcher::BatchIter;
use plmu::data::SeqDataset;
use plmu::exec;
use plmu::exec::arena::Arena;
use plmu::fusion;
use plmu::optim::Adam;
use plmu::train::{train_step, ModelKind, SeqClassifier};
use plmu::util::Rng;
use plmu::Tensor;
use std::rc::Rc;

struct Case {
    name: String,
    /// run with fusion on, returning a result fingerprint
    fused: Box<dyn Fn() -> u64>,
    /// run with fusion off (knob restored after), same fingerprint
    unfused: Box<dyn Fn() -> u64>,
    /// analytic bytes moved per run, fused path
    bytes_fused: f64,
    /// analytic bytes moved per run, unfused chain
    bytes_unfused: f64,
}

/// Record one forward chain and fingerprint its output.
fn run_chain(store: &ParamStore, build: &dyn Fn(&mut Graph, &ParamStore) -> NodeId) -> u64 {
    let mut g = Graph::new();
    let out = build(&mut g, store);
    checksum(g.value(out).data())
}

fn chain_case(
    name: String,
    store: ParamStore,
    build: Rc<dyn Fn(&mut Graph, &ParamStore) -> NodeId>,
    bytes_fused: f64,
    bytes_unfused: f64,
) -> Case {
    let store = Rc::new(store);
    let (s1, b1) = (Rc::clone(&store), Rc::clone(&build));
    let (s2, b2) = (store, build);
    Case {
        name,
        fused: Box::new(move || {
            fusion::set_enabled(true);
            run_chain(&s1, b1.as_ref())
        }),
        unfused: Box::new(move || {
            fusion::set_enabled(false);
            let h = run_chain(&s2, b2.as_ref());
            fusion::set_enabled(true);
            h
        }),
        bytes_fused,
        bytes_unfused,
    }
}

/// Balanced ±-mean toy classification set (same recipe as the
/// equivalence suite) — enough signal that losses stay finite.
fn toy_dataset(n_examples: usize, seq_len: usize, seed: u64) -> SeqDataset {
    let mut rng = Rng::new(seed);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..n_examples {
        let sign = if i % 2 == 0 { 1.0f32 } else { -1.0 };
        let mut x = Tensor::randn(&[seq_len, 1], 0.5, &mut rng);
        x.map_inplace(|v| v + sign * 0.4);
        xs.push(x);
        ys.push(usize::from(sign > 0.0));
    }
    SeqDataset::classification(xs, ys)
}

/// One fused-or-unfused training measurement: first-step loss (for the
/// bit-equality gate), cold-step arena allocation in bytes (the
/// measured traffic figure), and warm steady-state step timing.
fn measure_train(
    fused: bool,
    cfg: BenchConfig,
    seq_len: usize,
    hidden: usize,
    order: usize,
    batch_sz: usize,
) -> (f32, f64, plmu::benchlib::Stats) {
    fusion::set_enabled(fused);
    let ds = toy_dataset(batch_sz, seq_len, 21);
    let mut store = ParamStore::new();
    let mut rng = Rng::new(11);
    let model =
        SeqClassifier::new(ModelKind::LmuParallel, seq_len, 1, order, hidden, 2, &mut store, &mut rng);
    let batch = BatchIter::sequential(&ds, batch_sz).next().unwrap();
    let mut opt = Adam::new(1e-3);
    let mut g = Graph::new();
    let mut arena = Arena::new();

    let before = arena.stats();
    let first_loss = train_step(&model, &mut store, &mut opt, &mut g, &mut arena, &batch, None);
    let cold_bytes = arena.stats().since(&before).fresh_bytes as f64;
    // one more step so the arena + Adam state reach steady state
    train_step(&model, &mut store, &mut opt, &mut g, &mut arena, &batch, None);
    let stats = bench("train_step", cfg, || {
        std::hint::black_box(train_step(
            &model, &mut store, &mut opt, &mut g, &mut arena, &batch, None,
        ));
    });
    fusion::set_enabled(true);
    (first_loss, cold_bytes, stats)
}

fn main() {
    let smoke = std::env::var("PLMU_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let cfg = if smoke {
        BenchConfig { warmup_secs: 0.02, measure_secs: 0.06, max_iters: 30, min_iters: 2 }
    } else {
        BenchConfig { warmup_secs: 0.1, measure_secs: 0.5, max_iters: 200, min_iters: 3 }
    };
    // single-thread: this bench measures memory traffic saved by
    // fusion, not thread scaling (fig1_threads' job)
    exec::set_threads(1);
    println!(
        "fusion A/B (fused builders vs unfused chains), serial{}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut rng = Rng::new(0);
    let mut cases: Vec<Case> = Vec::new();
    const F: f64 = 4.0; // sizeof f32

    // ---- affine_act: matmul + bias row + tanh, fused epilogue ----------
    // shapes echo the paper's table-1 workloads: 784 = psMNIST sequence
    // length, 212/128 = hidden widths used in the reproductions
    let affine_shapes: &[(usize, usize, usize)] =
        if smoke { &[(32, 63, 33)] } else { &[(128, 256, 128), (512, 129, 65), (256, 784, 212)] };
    for &(m, k, n) in affine_shapes {
        let mut store = ParamStore::new();
        let x = store.add("x", Tensor::randn(&[m, k], 1.0, &mut rng));
        let w = store.add("w", Tensor::randn(&[k, n], 0.5, &mut rng));
        let b = store.add("b", Tensor::randn(&[n], 0.1, &mut rng));
        let (mk, kn, mn, nn) = (m * k, k * n, m * n, n);
        cases.push(chain_case(
            format!("affine_tanh_{m}x{k}x{n}"),
            store,
            Rc::new(move |g, s| {
                let (xn, wn, bn) = (g.param(s, x), g.param(s, w), g.param(s, b));
                g.affine_act(xn, wn, bn, Some(Act::Tanh))
            }),
            // fused: read x, w, bias; write out once, epilogue in-tile
            F * (mk + kn + nn + mn) as f64,
            // unfused: + add_row pass (mn+n read, mn write) + tanh pass
            // (mn read, mn write) over materialized intermediates
            F * (mk + kn + nn + 5 * mn) as f64,
        ));
    }

    // ---- add2_row_act: a + b + bias row + tanh (LMU output merge) ------
    let add2_shapes: &[(usize, usize)] = if smoke { &[(256, 33)] } else { &[(4096, 128), (2048, 257)] };
    for &(m, n) in add2_shapes {
        let mut store = ParamStore::new();
        let a = store.add("a", Tensor::randn(&[m, n], 1.0, &mut rng));
        let b = store.add("b", Tensor::randn(&[m, n], 1.0, &mut rng));
        let bias = store.add("bias", Tensor::randn(&[n], 0.2, &mut rng));
        let (mn, nn) = (m * n, n);
        cases.push(chain_case(
            format!("add2_row_tanh_{m}x{n}"),
            store,
            Rc::new(move |g, s| {
                let (an, bn, biasn) = (g.param(s, a), g.param(s, b), g.param(s, bias));
                g.add2_row_act(an, bn, biasn, Some(Act::Tanh))
            }),
            // fused: read a, b, bias; write out once
            F * (3 * mn + nn) as f64,
            // unfused: add (2mn r, mn w) + add_row (mn+n r, mn w) + tanh
            F * (7 * mn + nn) as f64,
        ));
    }

    // ---- add3_act: three-way sum + tanh (original LMU cell update) -----
    let add3_shapes: &[(usize, usize)] = if smoke { &[(256, 33)] } else { &[(4096, 128), (2048, 257)] };
    for &(m, n) in add3_shapes {
        let mut store = ParamStore::new();
        let a = store.add("a", Tensor::randn(&[m, n], 1.0, &mut rng));
        let b = store.add("b", Tensor::randn(&[m, n], 1.0, &mut rng));
        let c = store.add("c", Tensor::randn(&[m, n], 1.0, &mut rng));
        let mn = m * n;
        cases.push(chain_case(
            format!("add3_tanh_{m}x{n}"),
            store,
            Rc::new(move |g, s| {
                let (an, bn, cn) = (g.param(s, a), g.param(s, b), g.param(s, c));
                g.add3_act(an, bn, cn, Some(Act::Tanh))
            }),
            F * 4 * mn as f64,
            // unfused: add + add + tanh, each materializing
            F * 8 * mn as f64,
        ));
    }

    let mut record = PerfJson::new("fusion");
    let mut table = Table::new(&["case", "fused (µs)", "unfused (µs)", "speedup", "bytes f/u"]);
    let mut worst: Option<(String, f64)> = None;
    let mut track = |name: &str, speedup: f64, worst: &mut Option<(String, f64)>| {
        if worst.as_ref().map(|(_, w)| speedup < *w).unwrap_or(true) {
            *worst = Some((name.to_string(), speedup));
        }
    };

    for case in &cases {
        // contract first: the two paths must be bit-identical
        let (f, u) = ((case.fused)(), (case.unfused)());
        assert_eq!(f, u, "{}: fused and unfused paths disagree", case.name);
        assert!(
            case.bytes_fused < case.bytes_unfused,
            "{}: fused traffic estimate not below unfused",
            case.name
        );

        let fused_stats = bench(&case.name, cfg, || {
            std::hint::black_box((case.fused)());
        });
        let unfused_stats = bench(&case.name, cfg, || {
            std::hint::black_box((case.unfused)());
        });
        let speedup = unfused_stats.mean / fused_stats.mean;
        track(&case.name, speedup, &mut worst);
        table.row(&[
            case.name.clone(),
            format!("{:.2}", fused_stats.mean * 1e6),
            format!("{:.2}", unfused_stats.mean * 1e6),
            format!("{speedup:.2}x"),
            format!("{:.2}", case.bytes_fused / case.bytes_unfused),
        ]);
        record.push(&[
            ("case", JsonValue::Str(case.name.clone())),
            ("threads", JsonValue::Int(1)),
            ("wall_ns", JsonValue::Int((fused_stats.mean * 1e9) as i64)),
            ("fused_s", JsonValue::Num(fused_stats.mean)),
            ("unfused_s", JsonValue::Num(unfused_stats.mean)),
            ("p50_s", JsonValue::Num(fused_stats.p50)),
            ("speedup_vs_unfused", JsonValue::Num(speedup)),
            ("bytes_moved_fused", JsonValue::Num(case.bytes_fused)),
            ("bytes_moved_unfused", JsonValue::Num(case.bytes_unfused)),
            ("smoke", JsonValue::Bool(smoke)),
        ]);
    }

    // ---- end-to-end: one training step of the parallel LMU classifier --
    // fused chains + warm arena vs unfused chains + warm arena; bytes
    // here are *measured* cold-step arena allocation (the intermediates
    // the unfused chain materializes show up as extra fresh buffers)
    let (seq_len, hidden, order, batch_sz) =
        if smoke { (16, 16, 8, 8) } else { (64, 64, 32, 32) };
    let name = format!("train_step_lmu_T{seq_len}_h{hidden}_q{order}_B{batch_sz}");
    let (loss_f, bytes_f, stats_f) = measure_train(true, cfg, seq_len, hidden, order, batch_sz);
    let (loss_u, bytes_u, stats_u) = measure_train(false, cfg, seq_len, hidden, order, batch_sz);
    assert_eq!(
        loss_f.to_bits(),
        loss_u.to_bits(),
        "{name}: first-step loss differs across fusion: {loss_f} vs {loss_u}"
    );
    assert!(
        bytes_f < bytes_u,
        "{name}: fused cold-step allocation ({bytes_f}) not below unfused ({bytes_u})"
    );
    let speedup = stats_u.mean / stats_f.mean;
    track(&name, speedup, &mut worst);
    table.row(&[
        name.clone(),
        format!("{:.2}", stats_f.mean * 1e6),
        format!("{:.2}", stats_u.mean * 1e6),
        format!("{speedup:.2}x"),
        format!("{:.2}", bytes_f / bytes_u),
    ]);
    record.push(&[
        ("case", JsonValue::Str(name)),
        ("threads", JsonValue::Int(1)),
        ("wall_ns", JsonValue::Int((stats_f.mean * 1e9) as i64)),
        ("fused_s", JsonValue::Num(stats_f.mean)),
        ("unfused_s", JsonValue::Num(stats_u.mean)),
        ("p50_s", JsonValue::Num(stats_f.p50)),
        ("speedup_vs_unfused", JsonValue::Num(speedup)),
        ("bytes_moved_fused", JsonValue::Num(bytes_f)),
        ("bytes_moved_unfused", JsonValue::Num(bytes_u)),
        ("smoke", JsonValue::Bool(smoke)),
    ]);

    table.print("fusion — fused builders vs unfused chains (serial)");

    let out = repo_root().join("BENCH_fusion.json");
    match record.write(&out) {
        Ok(()) => println!("\nwrote {} ({} records)", out.display(), record.len()),
        Err(e) => eprintln!("\nfailed to write {}: {e}", out.display()),
    }

    // acceptance: every case already asserted bytes_fused < bytes_unfused;
    // the fused path must also not lose on wall time (graph-recording
    // overhead is shared, so the kernel saving should show through)
    if let Some((name, w)) = worst {
        let verdict = if w > 1.0 { "PASS" } else { "MISS" };
        println!("\nacceptance (worst fused-vs-unfused speedup > 1.0x): {name} {w:.2}x  {verdict}");
    }
}
