//! Scheduler bench for the work-stealing pool.  Three experiments, all
//! recorded to `BENCH_pool.json` at the repo root:
//!
//!  1. **Uniform crossover sweep** — the serial/parallel *crossover
//!     point* (smallest job where fanning out beats staying serial) for
//!     the shipped work-stealing dispatch (`Plan::sized`), the previous
//!     static one-chunk-per-worker partition (`Plan::static_partition`),
//!     and a per-call scoped-spawn baseline (a faithful copy of the
//!     pre-pool substrate's `std::thread::scope` dispatch).  On uniform
//!     rows stealing must be no slower than static — the finer chunks
//!     cost one atomic claim each, amortized by `CHUNK_WORK_TARGET`.
//!  2. **Ragged-tail workload** — rows with linearly growing cost (a
//!     batch of variable-length sequences).  The static partition stalls
//!     on the chunk holding the longest rows; stealing rebalances.
//!  3. **Nested crossover** — an outer 2-replica fan-out whose chunks
//!     each run a matmul, with nested kernels serialized (the old
//!     degenerate path) vs fanning out under hierarchical sub-budgets
//!     (`threads / 2` per replica).  This is the data-parallel
//!     R < threads scenario the scheduler overhaul unblocks.
//!
//! Per experiment the pool results are asserted bit-identical to the
//! serial reference.
//!
//! Run: cargo bench --bench pool_crossover
//! Smoke mode (CI): PLMU_BENCH_SMOKE=1 cargo bench --bench pool_crossover

use plmu::benchlib::{bench, checksum_f32 as checksum, repo_root, BenchConfig, JsonValue, PerfJson, Table};
use plmu::exec::{self, Plan};
use plmu::util::Rng;
use plmu::Tensor;

/// The scoped-spawn dispatch the pool replaced (verbatim partition logic
/// of the pre-pool exec substrate) — the bench baseline.
fn scoped_rows_mut<T, F>(out: &mut [T], row_len: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    if workers <= 1 || rows <= 1 {
        f(0, out);
        return;
    }
    let workers = workers.min(rows);
    let chunk_rows = rows.div_ceil(workers);
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = out;
        let mut row0 = 0usize;
        let mut first: Option<(usize, &mut [T])> = None;
        while !rest.is_empty() {
            let take = (chunk_rows * row_len).min(rest.len());
            let (head, tail) = {
                let tmp = rest;
                tmp.split_at_mut(take)
            };
            if first.is_none() {
                first = Some((row0, head));
            } else {
                scope.spawn(move || f(row0, head));
            }
            row0 += take / row_len;
            rest = tail;
        }
        if let Some((r0, block)) = first {
            f(r0, block);
        }
    });
}

/// One output row of the m×k · k×n matmul (identical op order in every
/// substrate, so results are bit-comparable).
fn matmul_block(a: &[f32], b: &[f32], k: usize, n: usize, r0: usize, block: &mut [f32]) {
    for (i, row) in block.chunks_mut(n).enumerate() {
        let arow = &a[(r0 + i) * k..(r0 + i + 1) * k];
        for (j, o) in row.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (kk, &av) in arow.iter().enumerate() {
                acc += av * b[kk * n + j];
            }
            *o = acc;
        }
    }
}

/// Ragged workload: row i multiplies over a prefix of k that grows
/// linearly with the row index — a batch of variable-length sequences
/// sorted by length.  A static partition hands the longest rows to one
/// worker; stealing splits them finer.
fn ragged_block(
    a: &[f32],
    b: &[f32],
    k: usize,
    n: usize,
    rows_total: usize,
    r0: usize,
    block: &mut [f32],
) {
    for (i, row) in block.chunks_mut(n).enumerate() {
        let r = r0 + i;
        let ki = (((r + 1) * k) / rows_total).max(1);
        let arow = &a[r * k..r * k + ki];
        for (j, o) in row.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (kk, &av) in arow.iter().enumerate() {
                acc += av * b[kk * n + j];
            }
            *o = acc;
        }
    }
}

fn main() {
    let smoke = std::env::var("PLMU_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let cfg = if smoke {
        BenchConfig { warmup_secs: 0.01, measure_secs: 0.04, max_iters: 400, min_iters: 3 }
    } else {
        BenchConfig { warmup_secs: 0.05, measure_secs: 0.25, max_iters: 4000, min_iters: 5 }
    };
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = hw.min(4);
    let mut record = PerfJson::new("pool_crossover");

    // ---------------------------------------- 1. uniform crossover sweep
    // fixed k=n=32, m sweeps the total work m*k*n from 2^12 to 2^19 —
    // spanning the pool threshold (2^14) and the old scoped one (2^18)
    let (k, n) = (32usize, 32usize);
    let ms: &[usize] = if smoke { &[4, 16, 64, 256] } else { &[4, 8, 16, 32, 64, 128, 256, 512] };
    println!(
        "uniform crossover sweep: k={k} n={n}, m in {ms:?}, {t} workers on {hw} hw threads{}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut rng = Rng::new(0);
    let m_max = *ms.last().unwrap();
    let a: Vec<f32> = (0..m_max * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    let mut table = Table::new(&[
        "work (ops)",
        "m",
        "serial (us)",
        "steal (us)",
        "static (us)",
        "scoped (us)",
        "steal x",
        "static x",
        "scoped x",
    ]);
    let mut steal_crossover: Option<usize> = None;
    let mut scoped_crossover: Option<usize> = None;
    let mut uniform_ok = true;

    for &m in ms {
        let work = m * k * n;
        let mut out = vec![0.0f32; m * n];

        // correctness first: both pool partitions must be bit-identical
        // to serial
        matmul_block(&a, &b, k, n, 0, &mut out);
        let ref_sum = checksum(&out);
        for plan in [Plan::sized(t, m, work), Plan::static_partition(t)] {
            out.iter_mut().for_each(|v| *v = 0.0);
            exec::parallel_rows_mut(&mut out, n, plan, |r0, block| {
                matmul_block(&a, &b, k, n, r0, block)
            });
            assert_eq!(checksum(&out), ref_sum, "pool result differs from serial at m={m}");
        }

        let s_serial = bench("serial", cfg, || {
            matmul_block(&a, &b, k, n, 0, std::hint::black_box(&mut out));
        });
        let s_steal = bench("steal", cfg, || {
            exec::parallel_rows_mut(std::hint::black_box(&mut out), n, Plan::sized(t, m, work), |r0, block| {
                matmul_block(&a, &b, k, n, r0, block)
            });
        });
        let s_static = bench("static", cfg, || {
            exec::parallel_rows_mut(
                std::hint::black_box(&mut out),
                n,
                Plan::static_partition(t),
                |r0, block| matmul_block(&a, &b, k, n, r0, block),
            );
        });
        let s_scoped = bench("scoped", cfg, || {
            scoped_rows_mut(std::hint::black_box(&mut out), n, t, |r0, block| {
                matmul_block(&a, &b, k, n, r0, block)
            });
        });

        let steal_x = s_serial.mean / s_steal.mean;
        let static_x = s_serial.mean / s_static.mean;
        let scoped_x = s_serial.mean / s_scoped.mean;
        if steal_x > 1.0 && steal_crossover.is_none() {
            steal_crossover = Some(work);
        }
        if scoped_x > 1.0 && scoped_crossover.is_none() {
            scoped_crossover = Some(work);
        }
        // acceptance: stealing within 10% of static on uniform loads
        // (only meaningful where parallelism wins at all)
        if static_x > 1.0 && s_steal.mean > s_static.mean * 1.10 {
            uniform_ok = false;
        }
        table.row(&[
            work.to_string(),
            m.to_string(),
            format!("{:.1}", s_serial.mean * 1e6),
            format!("{:.1}", s_steal.mean * 1e6),
            format!("{:.1}", s_static.mean * 1e6),
            format!("{:.1}", s_scoped.mean * 1e6),
            format!("{steal_x:.2}x"),
            format!("{static_x:.2}x"),
            format!("{scoped_x:.2}x"),
        ]);
        record.push(&[
            ("case", JsonValue::Str("small_matmul".into())),
            ("threads", JsonValue::Int(t as i64)),
            ("wall_ns", JsonValue::Int((s_steal.mean * 1e9) as i64)),
            ("work", JsonValue::Int(work as i64)),
            ("m", JsonValue::Int(m as i64)),
            ("k", JsonValue::Int(k as i64)),
            ("n", JsonValue::Int(n as i64)),
            ("workers", JsonValue::Int(t as i64)),
            ("serial_s", JsonValue::Num(s_serial.mean)),
            ("pool_s", JsonValue::Num(s_steal.mean)),
            ("static_s", JsonValue::Num(s_static.mean)),
            ("scoped_s", JsonValue::Num(s_scoped.mean)),
            ("pool_speedup", JsonValue::Num(steal_x)),
            ("static_speedup", JsonValue::Num(static_x)),
            ("scoped_speedup", JsonValue::Num(scoped_x)),
            ("smoke", JsonValue::Bool(smoke)),
            ("hw_threads", JsonValue::Int(hw as i64)),
        ]);
    }

    // summary: the crossover points (smallest job where parallel wins)
    record.push(&[
        ("case", JsonValue::Str("crossover".into())),
        ("threads", JsonValue::Int(t as i64)),
        ("wall_ns", JsonValue::Int(0)),
        ("pool_crossover_work", JsonValue::Int(steal_crossover.map(|w| w as i64).unwrap_or(-1))),
        (
            "scoped_crossover_work",
            JsonValue::Int(scoped_crossover.map(|w| w as i64).unwrap_or(-1)),
        ),
        ("min_parallel_work", JsonValue::Int(exec::MIN_PARALLEL_WORK as i64)),
        ("scoped_min_parallel_work", JsonValue::Int(1i64 << 18)),
        ("workers", JsonValue::Int(t as i64)),
        ("hw_threads", JsonValue::Int(hw as i64)),
        ("smoke", JsonValue::Bool(smoke)),
    ]);

    table.print("uniform crossover — work stealing vs static partition vs scoped spawn");
    match (steal_crossover, scoped_crossover) {
        (Some(p), Some(s)) => {
            let verdict = if p <= s { "PASS (steal crossover <= scoped)" } else { "MISS" };
            println!("\ncrossover: steal at {p} ops, scoped at {s} ops — {verdict}");
        }
        (Some(p), None) => {
            println!("\ncrossover: steal at {p} ops; scoped never won on this sweep — PASS")
        }
        (None, _) => println!(
            "\ncrossover: parallel never won (only {hw} hardware threads?) — scaling is machine-bound"
        ),
    }
    println!(
        "uniform loads: stealing {} static partition",
        if uniform_ok { "matches (PASS, within 10%)" } else { "slower than (MISS)" }
    );

    // ------------------------------------------- 2. ragged-tail workload
    let rag_rows = if smoke { 48usize } else { 96 };
    let rag_k = 512usize;
    let rag_n = 32usize;
    // total work = sum_i ceil((i+1)k/rows) * n ≈ rows*k*n/2
    let rag_work = rag_rows * rag_k * rag_n / 2;
    let ar: Vec<f32> = (0..rag_rows * rag_k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let br: Vec<f32> = (0..rag_k * rag_n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let mut rout = vec![0.0f32; rag_rows * rag_n];

    ragged_block(&ar, &br, rag_k, rag_n, rag_rows, 0, &mut rout);
    let rag_ref = checksum(&rout);
    for plan in [Plan::sized(t, rag_rows, rag_work), Plan::static_partition(t)] {
        rout.iter_mut().for_each(|v| *v = 0.0);
        exec::parallel_rows_mut(&mut rout, rag_n, plan, |r0, block| {
            ragged_block(&ar, &br, rag_k, rag_n, rag_rows, r0, block)
        });
        assert_eq!(checksum(&rout), rag_ref, "ragged pool result differs from serial");
    }

    let rg_serial = bench("ragged serial", cfg, || {
        ragged_block(&ar, &br, rag_k, rag_n, rag_rows, 0, std::hint::black_box(&mut rout));
    });
    let rg_steal = bench("ragged steal", cfg, || {
        exec::parallel_rows_mut(
            std::hint::black_box(&mut rout),
            rag_n,
            Plan::sized(t, rag_rows, rag_work),
            |r0, block| ragged_block(&ar, &br, rag_k, rag_n, rag_rows, r0, block),
        );
    });
    let rg_static = bench("ragged static", cfg, || {
        exec::parallel_rows_mut(
            std::hint::black_box(&mut rout),
            rag_n,
            Plan::static_partition(t),
            |r0, block| ragged_block(&ar, &br, rag_k, rag_n, rag_rows, r0, block),
        );
    });
    let rag_steal_x = rg_serial.mean / rg_steal.mean;
    let rag_static_x = rg_serial.mean / rg_static.mean;
    println!(
        "\nragged tail ({rag_rows} rows, linear cost): serial {:.0}us, steal {:.0}us ({rag_steal_x:.2}x), static {:.0}us ({rag_static_x:.2}x) — {}",
        rg_serial.mean * 1e6,
        rg_steal.mean * 1e6,
        rg_static.mean * 1e6,
        if rg_steal.mean <= rg_static.mean { "PASS (steal faster)" } else { "MISS" }
    );
    record.push(&[
        ("case", JsonValue::Str("ragged".into())),
        ("threads", JsonValue::Int(t as i64)),
        ("wall_ns", JsonValue::Int((rg_steal.mean * 1e9) as i64)),
        ("rows", JsonValue::Int(rag_rows as i64)),
        ("k", JsonValue::Int(rag_k as i64)),
        ("n", JsonValue::Int(rag_n as i64)),
        ("workers", JsonValue::Int(t as i64)),
        ("serial_s", JsonValue::Num(rg_serial.mean)),
        ("pool_s", JsonValue::Num(rg_steal.mean)),
        ("static_s", JsonValue::Num(rg_static.mean)),
        ("pool_speedup", JsonValue::Num(rag_steal_x)),
        ("static_speedup", JsonValue::Num(rag_static_x)),
        ("smoke", JsonValue::Bool(smoke)),
        ("hw_threads", JsonValue::Int(hw as i64)),
    ]);

    // -------------------------------------------- 3. nested crossover
    // 2 "replicas" on a t-thread budget, each running one matmul: the old
    // scheduler serialized the nested kernels (sub-budget 1 everywhere);
    // hierarchical budgets hand each replica t/2 threads' worth.
    exec::set_threads(t);
    let (nm, nk, nn) = if smoke { (64usize, 64usize, 48usize) } else { (128, 96, 64) };
    let reps: Vec<(Tensor, Tensor)> = (0..2)
        .map(|_| {
            let mut r = Rng::new(7);
            (Tensor::randn(&[nm, nk], 1.0, &mut r), Tensor::randn(&[nk, nn], 1.0, &mut r))
        })
        .collect();
    let run_nested = |serialize: bool| -> u64 {
        let sums: Vec<u64> = exec::parallel_map(2, exec::plan_for(2, usize::MAX), |i| {
            let (a, b) = &reps[i];
            if serialize {
                exec::run_serialized(|| checksum(a.matmul(b).data()))
            } else {
                checksum(a.matmul(b).data())
            }
        });
        sums[0] ^ sums[1].rotate_left(1)
    };
    let nested_ref = run_nested(true);
    assert_eq!(run_nested(false), nested_ref, "nested fan-out changed results");
    let ns_old = bench("nested serialized", cfg, || {
        std::hint::black_box(run_nested(true));
    });
    let ns_new = bench("nested sub-budget", cfg, || {
        std::hint::black_box(run_nested(false));
    });
    exec::set_threads(1);
    let nested_x = ns_old.mean / ns_new.mean;
    println!(
        "nested 2-replica ({nm}x{nk}x{nn} each, {t} threads): serialized-nested {:.0}us, sub-budget {:.0}us ({nested_x:.2}x){}",
        ns_old.mean * 1e6,
        ns_new.mean * 1e6,
        if hw < 4 { " — only meaningful with >=4 hw threads" } else { "" }
    );
    record.push(&[
        ("case", JsonValue::Str("nested".into())),
        ("wall_ns", JsonValue::Int((ns_new.mean * 1e9) as i64)),
        ("replicas", JsonValue::Int(2)),
        ("m", JsonValue::Int(nm as i64)),
        ("k", JsonValue::Int(nk as i64)),
        ("n", JsonValue::Int(nn as i64)),
        ("threads", JsonValue::Int(t as i64)),
        ("serialized_nested_s", JsonValue::Num(ns_old.mean)),
        ("sub_budget_s", JsonValue::Num(ns_new.mean)),
        ("nested_speedup", JsonValue::Num(nested_x)),
        ("smoke", JsonValue::Bool(smoke)),
        ("hw_threads", JsonValue::Int(hw as i64)),
    ]);

    let out_path = repo_root().join("BENCH_pool.json");
    match record.write(&out_path) {
        Ok(()) => println!("wrote {} ({} records)", out_path.display(), record.len()),
        Err(e) => eprintln!("failed to write {}: {e}", out_path.display()),
    }
}
