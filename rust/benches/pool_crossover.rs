//! Dispatch-overhead bench for the persistent worker pool: finds the
//! serial/parallel *crossover point* — the smallest job (total scalar
//! ops) where fanning out beats staying serial — for
//!
//!  * the persistent parked pool (`exec::parallel_rows_mut`, the shipped
//!    dispatch), and
//!  * a per-call scoped-spawn baseline (a faithful copy of the old exec
//!    substrate's `std::thread::scope` dispatch, kept here for
//!    comparison),
//!
//! by sweeping small matmul shapes across both substrates' thresholds
//! (the scoped substrate gated at 2^18 scalar ops; the pool ships with
//! `MIN_PARALLEL_WORK = 2^14`).  Emits `BENCH_pool.json` at the repo
//! root; per sweep point the pool result is asserted bit-identical to
//! the serial reference.
//!
//! Run: cargo bench --bench pool_crossover
//! Smoke mode (CI): PLMU_BENCH_SMOKE=1 cargo bench --bench pool_crossover

use plmu::benchlib::{bench, BenchConfig, JsonValue, PerfJson, Table};
use plmu::exec;
use plmu::util::Rng;

/// Walk up from cwd looking for the repo root (ROADMAP.md marker).
fn repo_root() -> std::path::PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    for _ in 0..5 {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        match dir.parent() {
            Some(p) => dir = p.to_path_buf(),
            None => break,
        }
    }
    std::env::current_dir().unwrap_or_else(|_| ".".into())
}

fn checksum(xs: &[f32]) -> u64 {
    // order-sensitive bit-level fingerprint: equal iff bit-identical
    let mut h = 0xcbf29ce484222325u64;
    for v in xs {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// The scoped-spawn dispatch the pool replaced (verbatim partition logic
/// of the old exec substrate) — the bench baseline.
fn scoped_rows_mut<T, F>(out: &mut [T], row_len: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    if workers <= 1 || rows <= 1 {
        f(0, out);
        return;
    }
    let workers = workers.min(rows);
    let chunk_rows = rows.div_ceil(workers);
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = out;
        let mut row0 = 0usize;
        let mut first: Option<(usize, &mut [T])> = None;
        while !rest.is_empty() {
            let take = (chunk_rows * row_len).min(rest.len());
            let (head, tail) = {
                let tmp = rest;
                tmp.split_at_mut(take)
            };
            if first.is_none() {
                first = Some((row0, head));
            } else {
                scope.spawn(move || f(row0, head));
            }
            row0 += take / row_len;
            rest = tail;
        }
        if let Some((r0, block)) = first {
            f(r0, block);
        }
    });
}

/// One output row of the m×k · k×n matmul (identical op order in every
/// substrate, so results are bit-comparable).
fn matmul_block(a: &[f32], b: &[f32], k: usize, n: usize, r0: usize, block: &mut [f32]) {
    for (i, row) in block.chunks_mut(n).enumerate() {
        let arow = &a[(r0 + i) * k..(r0 + i + 1) * k];
        for (j, o) in row.iter_mut().enumerate() {
            let mut acc = 0.0f32;
            for (kk, &av) in arow.iter().enumerate() {
                acc += av * b[kk * n + j];
            }
            *o = acc;
        }
    }
}

fn main() {
    let smoke = std::env::var("PLMU_BENCH_SMOKE").map(|v| v != "0").unwrap_or(false);
    let cfg = if smoke {
        BenchConfig { warmup_secs: 0.01, measure_secs: 0.04, max_iters: 400, min_iters: 3 }
    } else {
        BenchConfig { warmup_secs: 0.05, measure_secs: 0.25, max_iters: 4000, min_iters: 5 }
    };
    let hw = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let t = hw.min(4);
    // fixed k=n=32, m sweeps the total work m*k*n from 2^12 to 2^19 —
    // spanning the pool threshold (2^14) and the old scoped one (2^18)
    let (k, n) = (32usize, 32usize);
    let ms: &[usize] = if smoke { &[4, 16, 64, 256] } else { &[4, 8, 16, 32, 64, 128, 256, 512] };
    println!(
        "pool-vs-scoped crossover sweep: k={k} n={n}, m in {ms:?}, {t} workers on {hw} hw threads{}",
        if smoke { " [smoke]" } else { "" }
    );

    let mut rng = Rng::new(0);
    let m_max = *ms.last().unwrap();
    let a: Vec<f32> = (0..m_max * k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let b: Vec<f32> = (0..k * n).map(|_| rng.normal_f32(0.0, 1.0)).collect();

    let mut record = PerfJson::new("pool_crossover");
    let mut table =
        Table::new(&["work (ops)", "m", "serial (us)", "pool (us)", "scoped (us)", "pool x", "scoped x"]);
    let mut pool_crossover: Option<usize> = None;
    let mut scoped_crossover: Option<usize> = None;

    for &m in ms {
        let work = m * k * n;
        let mut out = vec![0.0f32; m * n];

        // correctness first: pool result must be bit-identical to serial
        matmul_block(&a, &b, k, n, 0, &mut out);
        let ref_sum = checksum(&out);
        out.iter_mut().for_each(|v| *v = 0.0);
        exec::parallel_rows_mut(&mut out, n, t, |r0, block| {
            matmul_block(&a, &b, k, n, r0, block)
        });
        assert_eq!(checksum(&out), ref_sum, "pool result differs from serial at m={m}");

        let s_serial = bench("serial", cfg, || {
            matmul_block(&a, &b, k, n, 0, std::hint::black_box(&mut out));
        });
        let s_pool = bench("pool", cfg, || {
            exec::parallel_rows_mut(std::hint::black_box(&mut out), n, t, |r0, block| {
                matmul_block(&a, &b, k, n, r0, block)
            });
        });
        let s_scoped = bench("scoped", cfg, || {
            scoped_rows_mut(std::hint::black_box(&mut out), n, t, |r0, block| {
                matmul_block(&a, &b, k, n, r0, block)
            });
        });

        let pool_x = s_serial.mean / s_pool.mean;
        let scoped_x = s_serial.mean / s_scoped.mean;
        if pool_x > 1.0 && pool_crossover.is_none() {
            pool_crossover = Some(work);
        }
        if scoped_x > 1.0 && scoped_crossover.is_none() {
            scoped_crossover = Some(work);
        }
        table.row(&[
            work.to_string(),
            m.to_string(),
            format!("{:.1}", s_serial.mean * 1e6),
            format!("{:.1}", s_pool.mean * 1e6),
            format!("{:.1}", s_scoped.mean * 1e6),
            format!("{pool_x:.2}x"),
            format!("{scoped_x:.2}x"),
        ]);
        record.push(&[
            ("case", JsonValue::Str("small_matmul".into())),
            ("work", JsonValue::Int(work as i64)),
            ("m", JsonValue::Int(m as i64)),
            ("k", JsonValue::Int(k as i64)),
            ("n", JsonValue::Int(n as i64)),
            ("workers", JsonValue::Int(t as i64)),
            ("serial_s", JsonValue::Num(s_serial.mean)),
            ("pool_s", JsonValue::Num(s_pool.mean)),
            ("scoped_s", JsonValue::Num(s_scoped.mean)),
            ("pool_speedup", JsonValue::Num(pool_x)),
            ("scoped_speedup", JsonValue::Num(scoped_x)),
            ("smoke", JsonValue::Bool(smoke)),
            ("hw_threads", JsonValue::Int(hw as i64)),
        ]);
    }

    // summary: the crossover points (smallest job where parallel wins)
    record.push(&[
        ("case", JsonValue::Str("crossover".into())),
        ("pool_crossover_work", JsonValue::Int(pool_crossover.map(|w| w as i64).unwrap_or(-1))),
        (
            "scoped_crossover_work",
            JsonValue::Int(scoped_crossover.map(|w| w as i64).unwrap_or(-1)),
        ),
        ("min_parallel_work", JsonValue::Int(exec::MIN_PARALLEL_WORK as i64)),
        ("scoped_min_parallel_work", JsonValue::Int(1i64 << 18)),
        ("workers", JsonValue::Int(t as i64)),
        ("hw_threads", JsonValue::Int(hw as i64)),
        ("smoke", JsonValue::Bool(smoke)),
    ]);

    table.print("serial/parallel crossover — persistent pool vs per-call scoped spawn");
    match (pool_crossover, scoped_crossover) {
        (Some(p), Some(s)) => {
            let verdict = if p <= s { "PASS (pool crossover <= scoped)" } else { "MISS" };
            println!("\ncrossover: pool at {p} ops, scoped at {s} ops — {verdict}");
        }
        (Some(p), None) => {
            println!("\ncrossover: pool at {p} ops; scoped never won on this sweep — PASS")
        }
        (None, _) => println!(
            "\ncrossover: parallel never won (only {hw} hardware threads?) — scaling is machine-bound"
        ),
    }

    let out_path = repo_root().join("BENCH_pool.json");
    match record.write(&out_path) {
        Ok(()) => println!("wrote {} ({} records)", out_path.display(), record.len()),
        Err(e) => eprintln!("failed to write {}: {e}", out_path.display()),
    }
}
