//! Differential-testing harness for the chunked parallel-scan DN path
//! (`plmu::dn::scan`): the pool-dispatched production operator — batch
//! forward, adjoint, last-state, last-state adjoint, the autograd ops
//! built on them, and the overlap-save stream — is A/B'd against a
//! **naive serial reference written independently in this file**,
//! asserting **bit-equality, not tolerance** (the `simd_equivalence.rs`
//! discipline).
//!
//! The reference is the block-table schedule of
//! `python/compile/kernels/dn_scan.py` as the most obvious possible
//! loops: build `TH (d, L, L)` / `APows (L, d, d)` from the same f64
//! sources the production operator uses, then walk the chunks
//! sequentially evaluating the module's one canonical element op
//!
//! ```text
//! m[t0+i, s, c] = ref_dot(TH[s][i][0..=i], uᵀ[c][0..=i])
//!              + ref_dot(APows[i][s][..], carryᵀ[c][..])
//! ```
//!
//! with `ref_dot` re-implementing the canonical blocked accumulation
//! order (eight accumulators, element `i` into lane `i % 8`, one fixed
//! reduction tree).  If the production path ever drifts — a
//! reassociated dot, a skipped zero-carry dot, a pool partition that
//! changes evaluation order, a streaming seam handled differently from
//! the batch seam — the order-sensitive inputs here (±1e8 cancellation
//! patterns, NaN/±Inf planted at chunk boundaries) flip bits and the
//! diff fails.
//!
//! What is deliberately NOT asserted bitwise: scan-vs-FFT.  The two
//! strategies associate f32 differently and are pinned at the same
//! ~2e-4 tolerance as the repo's other cross-strategy checks (see the
//! module doc of `rust/src/dn/scan.rs`).
//!
//! The `PLMU_SIMD` / `PLMU_SCAN` knobs are process-global, so tests
//! that flip them serialize on a mutex and restore the prior setting;
//! CI additionally runs this whole binary under `PLMU_SCAN` ∈
//! {fft, scan} × the thread/simd/fusion matrix.

use plmu::autograd::{Graph, ParamStore};
use plmu::dn::scan::{self, ScanMode};
use plmu::dn::{DelayNetwork, DnFftOperator, DnOperator, DnScanOperator, ScanState};
use plmu::optim::Adam;
use plmu::simd;
use plmu::train::{fit, fit_streaming, FitOptions, ModelKind, SeqClassifier};
use plmu::util::{bit_fingerprint, Rng};
use plmu::Tensor;
use std::sync::{Arc, Mutex};

/// Global-knob guard: scan mode and the simd dispatch knob are
/// process-wide, so tests that flip either serialize here.
static KNOB: Mutex<()> = Mutex::new(());

/// Run `f` under simd on and off (prior setting restored) and return
/// both results — the scan kernels must not care which dot is live.
fn with_simd_both<T>(f: impl Fn() -> T) -> (T, T) {
    let _guard = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let was = simd::enabled();
    simd::set_enabled(true);
    let on = f();
    simd::set_enabled(false);
    let off = f();
    simd::set_enabled(was);
    (on, off)
}

fn assert_bits_equal(label: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{label}: element {i} differs: {g} ({:#010x}) vs {w} ({:#010x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// The shape sweep: (n, d, du) spanning n=1, du=1, odd everything, the
/// simd lane boundaries, and fig1-ish sizes.
const SHAPES: &[(usize, usize, usize)] =
    &[(1, 1, 1), (1, 4, 2), (7, 4, 1), (8, 8, 2), (9, 3, 3), (32, 8, 1), (33, 5, 2), (64, 16, 2)];

/// Chunk lengths for a sequence of length n: L=1 (every step a carry),
/// lane straddlers, L=n−1 (ragged single-row tail), L=n (one chunk,
/// the "whole" evaluation), L>n (chunk longer than the data).
fn blocks_for(n: usize) -> Vec<usize> {
    let mut ls = vec![1, 7, 8, n.saturating_sub(1), n, n + 7];
    ls.retain(|&l| l >= 1);
    ls.sort_unstable();
    ls.dedup();
    ls
}

/// Order-sensitive fill: large ±1e8 cancellation terms mixed with
/// small-magnitude noise, so any reassociation flips bits.
fn order_sensitive(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n)
        .map(|i| match i % 4 {
            0 => 1e8,
            2 => -1e8,
            _ => rng.normal_f32(0.0, 1.0),
        })
        .collect()
}

// ------------------------------------------------ canonical references

/// The canonical blocked dot as naive loops: lane accumulators, element
/// `i` into lane `i % 8`, fixed adjacent-pairs reduction tree.
fn ref_dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    for i in 0..a.len() {
        acc[i % 8] += a[i] * b[i];
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// The dn_scan.py block tables, rebuilt here with plain loops from the
/// same f64 sources (`impulse_response`, `abar` powers) the production
/// operator rounds from — so reference and production share the one
/// f64→f32 rounding and differ only if the *schedule* differs.
struct RefTables {
    d: usize,
    l: usize,
    /// (d, L, L): th[(s·L+i)·L+j] = H[i−j, s] for j ≤ i, else 0
    th: Vec<f32>,
    /// (L, d, d): apows[(i·d+s)·d+k] = (Ā^{i+1})[s, k]
    apows: Vec<f32>,
    /// (d, L, d): apt[(k·L+i)·d+s] = (Ā^{i+1})[s, k]
    apt: Vec<f32>,
    /// (L, d): hflat[t·d+s] = H[t, s]
    hflat: Vec<f32>,
}

impl RefTables {
    fn new(dn: &DelayNetwork, l: usize) -> RefTables {
        let d = dn.d;
        let h = dn.impulse_response(l);
        let hflat = h.data().to_vec();
        let mut th = vec![0.0f32; d * l * l];
        for s in 0..d {
            for i in 0..l {
                for j in 0..=i {
                    th[(s * l + i) * l + j] = hflat[(i - j) * d + s];
                }
            }
        }
        let mut apows = vec![0.0f32; l * d * d];
        let mut apt = vec![0.0f32; d * l * d];
        let mut p = dn.abar.clone();
        for i in 0..l {
            let pf = p.to_f32();
            apows[i * d * d..(i + 1) * d * d].copy_from_slice(&pf);
            for s in 0..d {
                for k in 0..d {
                    apt[(k * l + i) * d + s] = pf[s * d + k];
                }
            }
            p = p.matmul(&dn.abar);
        }
        RefTables { d, l, th, apows, apt, hflat }
    }
}

/// Naive serial chunked scan: walk the chunks in order, evaluate the
/// canonical element op for every (t, s, c), thread the carry as the
/// (du, d) transpose of each chunk's last output row.  Returns the
/// (n·d·du) output and the final carryᵀ.
fn ref_apply(t: &RefTables, u: &Tensor, carry0: Option<&[f32]>) -> (Vec<f32>, Vec<f32>) {
    let (n, du) = (u.rows(), u.cols());
    let (d, l) = (t.d, t.l);
    let ud = u.data();
    let mut out = vec![0.0f32; n * d * du];
    let mut carry = vec![0.0f32; du * d];
    if let Some(c0) = carry0 {
        carry.copy_from_slice(c0);
    }
    let mut t0 = 0usize;
    while t0 < n {
        let len = l.min(n - t0);
        // uᵀ chunk prefix buffers, per channel
        let mut ut = vec![0.0f32; du * l];
        for c in 0..du {
            for j in 0..len {
                ut[c * l + j] = ud[(t0 + j) * du + c];
            }
        }
        for i in 0..len {
            for s in 0..d {
                let trow = &t.th[(s * l + i) * l..(s * l + i) * l + i + 1];
                let ap = &t.apows[(i * d + s) * d..(i * d + s + 1) * d];
                for c in 0..du {
                    out[((t0 + i) * d + s) * du + c] = ref_dot(trow, &ut[c * l..c * l + i + 1])
                        + ref_dot(ap, &carry[c * d..(c + 1) * d]);
                }
            }
        }
        let mut next = vec![0.0f32; du * d];
        for c in 0..du {
            for s in 0..d {
                next[c * d + s] = out[((t0 + len - 1) * d + s) * du + c];
            }
        }
        carry = next;
        t0 += len;
    }
    (out, carry)
}

/// Naive serial adjoint, mirroring the production decomposition
/// exactly: per-chunk propagator dots against raw dm, reverse carry
/// chain, Toeplitz-transpose dots with the downstream gradient folded
/// into the last row.  dm: (n·d·du) -> gu: (n·du).
fn ref_adjoint(t: &RefTables, dmd: &[f32], n: usize, du: usize) -> Vec<f32> {
    let (d, l) = (t.d, t.l);
    let nb = n.div_ceil(l);
    // dmᵀ scratch per chunk: vt[c·L·d + i·d + s] = dm[t0+i, s, c]
    let fill_vt = |vt: &mut [f32], t0: usize, len: usize| {
        for c in 0..du {
            for i in 0..len {
                for s in 0..d {
                    vt[c * l * d + i * d + s] = dmd[((t0 + i) * d + s) * du + c];
                }
            }
        }
    };
    let mut p = vec![0.0f32; nb * du * d];
    let mut vt = vec![0.0f32; du * l * d];
    for k in 0..nb {
        let t0 = k * l;
        let len = l.min(n - t0);
        fill_vt(&mut vt, t0, len);
        for c in 0..du {
            let v = &vt[c * l * d..c * l * d + len * d];
            for s2 in 0..d {
                p[(k * du + c) * d + s2] = ref_dot(&t.apt[s2 * l * d..s2 * l * d + len * d], v);
            }
        }
    }
    let mut ghats = vec![0.0f32; (nb + 1) * du * d];
    for k in (0..nb).rev() {
        let len = l.min(n - k * l);
        let (gk, gnext) = ghats[k * du * d..(k + 2) * du * d].split_at_mut(du * d);
        for c in 0..du {
            for s2 in 0..d {
                let alt = &t.apt[(s2 * l + len - 1) * d..(s2 * l + len) * d];
                gk[c * d + s2] =
                    p[(k * du + c) * d + s2] + ref_dot(alt, &gnext[c * d..(c + 1) * d]);
            }
        }
    }
    let mut gu = vec![0.0f32; n * du];
    for k in 0..nb {
        let t0 = k * l;
        let len = l.min(n - t0);
        fill_vt(&mut vt, t0, len);
        for c in 0..du {
            let gnext = &ghats[(k + 1) * du * d + c * d..(k + 1) * du * d + (c + 1) * d];
            for s in 0..d {
                vt[c * l * d + (len - 1) * d + s] =
                    dmd[((t0 + len - 1) * d + s) * du + c] + gnext[s];
            }
            let v = &vt[c * l * d..c * l * d + len * d];
            for j in 0..len {
                gu[(t0 + j) * du + c] = ref_dot(&t.hflat[..(len - j) * d], &v[j * d..]);
            }
        }
    }
    gu
}

/// Naive adjoint of the last-state map: the (du, d) last-state gradient
/// flows back through the reverse carry chain; each chunk's rows see it
/// through the time-reversed impulse response.
fn ref_last_adjoint(t: &RefTables, n: usize, du: usize, dlast: &[f32]) -> Vec<f32> {
    let (d, l) = (t.d, t.l);
    let nb = n.div_ceil(l);
    let mut ghats = vec![0.0f32; (nb + 1) * du * d];
    ghats[nb * du * d..].copy_from_slice(dlast);
    for k in (0..nb).rev() {
        let len = l.min(n - k * l);
        let (gk, gnext) = ghats[k * du * d..(k + 2) * du * d].split_at_mut(du * d);
        for c in 0..du {
            for s2 in 0..d {
                let alt = &t.apt[(s2 * l + len - 1) * d..(s2 * l + len) * d];
                gk[c * d + s2] = ref_dot(alt, &gnext[c * d..(c + 1) * d]);
            }
        }
    }
    let mut gu = vec![0.0f32; n * du];
    for k in 0..nb {
        let t0 = k * l;
        let len = l.min(n - t0);
        for j in 0..len {
            for c in 0..du {
                let gnext = &ghats[(k + 1) * du * d + c * d..(k + 1) * du * d + (c + 1) * d];
                gu[(t0 + j) * du + c] = ref_dot(&t.hflat[(len - 1 - j) * d..(len - j) * d], gnext);
            }
        }
    }
    gu
}

fn theta_for(n: usize) -> f64 {
    (n as f64).max(4.0)
}

// ------------------------------------------------------- forward sweep

#[test]
fn apply_matches_naive_reference_bit_for_bit() {
    let mut rng = Rng::new(200);
    for &(n, d, du) in SHAPES {
        let dn = DelayNetwork::new(d, theta_for(n));
        let u = Tensor::new(&[n, du], order_sensitive(n * du, &mut rng));
        for l in blocks_for(n) {
            let t = RefTables::new(&dn, l);
            let (want, want_carry) = ref_apply(&t, &u, None);
            let op = DnScanOperator::new(&dn, n, l);
            let label = format!("n={n} d={d} du={du} L={l}");
            // the pool-dispatched operator under both simd settings
            let (on, off) = with_simd_both(|| op.apply(&u));
            assert_bits_equal(&format!("apply {label} simd=on"), on.data(), &want);
            assert_bits_equal(&format!("apply {label} simd=off"), off.data(), &want);
            // last-state short-circuit == the full evaluation's carry
            let last = op.apply_last(&u, None);
            assert_bits_equal(&format!("apply_last {label}"), &last, &want_carry);
        }
    }
}

#[test]
fn apply_from_nonzero_carry_matches_reference_bit_for_bit() {
    // the resume path: a random entering carry must round through the
    // same canonical carry dot as the zero carry (None ≡ Some(zeros)
    // is asserted separately below)
    let mut rng = Rng::new(201);
    for &(n, d, du) in &[(9usize, 3usize, 3usize), (33, 5, 2), (8, 8, 2)] {
        let dn = DelayNetwork::new(d, theta_for(n));
        let u = Tensor::new(&[n, du], order_sensitive(n * du, &mut rng));
        let carry: Vec<f32> = (0..du * d).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for l in blocks_for(n) {
            let t = RefTables::new(&dn, l);
            let (want, want_carry) = ref_apply(&t, &u, Some(&carry));
            let op = DnScanOperator::new(&dn, n, l);
            let label = format!("n={n} d={d} du={du} L={l} carried");
            let got = op.apply_from(&u, Some(&carry));
            assert_bits_equal(&format!("apply_from {label}"), got.data(), &want);
            let last = op.apply_last(&u, Some(&carry));
            assert_bits_equal(&format!("apply_last {label}"), &last, &want_carry);
        }
        // None vs explicit zeros: bit-identical (the carry dot always runs)
        let op = DnScanOperator::new(&dn, n, 8);
        let zeros = vec![0.0f32; du * d];
        assert_bits_equal(
            "None ≡ Some(zeros)",
            op.apply_from(&u, None).data(),
            op.apply_from(&u, Some(&zeros)).data(),
        );
    }
}

#[test]
fn nan_and_inf_at_chunk_boundaries_propagate_like_the_reference() {
    // a non-finite input on either side of a chunk seam must poison
    // exactly the elements the naive serial schedule poisons — scan is
    // causal, so upstream rows stay finite and downstream rows go bad
    // only through the carry chain.  (This is exactly where the FFT
    // path CANNOT match: its spectral mix poisons everything.)
    let mut rng = Rng::new(202);
    let (n, d, du, l) = (23usize, 4usize, 2usize, 8usize);
    let dn = DelayNetwork::new(d, theta_for(n));
    let base: Vec<f32> = (0..n * du).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    // last row of chunk 0, first row of chunk 1, mid-chunk, the ragged
    // tail's last row, and row 0
    for pos in [0usize, l - 1, l, l + 3, 2 * l, n - 1] {
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            let mut data = base.clone();
            data[pos * du] = bad;
            let u = Tensor::new(&[n, du], data);
            let t = RefTables::new(&dn, l);
            let (want, want_carry) = ref_apply(&t, &u, None);
            let op = DnScanOperator::new(&dn, n, l);
            let label = format!("bad={bad} at t={pos}");
            let got = op.apply(&u);
            assert_bits_equal(&format!("apply {label}"), got.data(), &want);
            assert_bits_equal(&format!("apply_last {label}"), &op.apply_last(&u, None), &want_carry);
            // causality: rows strictly before the planted row are finite
            for tt in 0..pos {
                for v in &got.data()[tt * d * du..(tt + 1) * d * du] {
                    assert!(v.is_finite(), "{label}: poisoned upstream row {tt}");
                }
            }
            // and the adjoint seam handling matches too
            let mut dmd = vec![0.0f32; n * d * du];
            for (i, v) in dmd.iter_mut().enumerate() {
                *v = ((i % 13) as f32) * 0.25 - 1.0;
            }
            dmd[pos * d * du] = bad;
            let want_gu = ref_adjoint(&t, &dmd, n, du);
            let got_gu = op.apply_adjoint(&Tensor::new(&[n, d, du], dmd));
            assert_bits_equal(&format!("adjoint {label}"), got_gu.data(), &want_gu);
        }
    }
}

// ------------------------------------------------------- adjoint sweep

#[test]
fn adjoint_matches_naive_reference_bit_for_bit() {
    let mut rng = Rng::new(203);
    for &(n, d, du) in SHAPES {
        let dn = DelayNetwork::new(d, theta_for(n));
        let dm = Tensor::new(&[n, d, du], order_sensitive(n * d * du, &mut rng));
        for l in blocks_for(n) {
            let t = RefTables::new(&dn, l);
            let want = ref_adjoint(&t, dm.data(), n, du);
            let op = DnScanOperator::new(&dn, n, l);
            let label = format!("n={n} d={d} du={du} L={l}");
            let (on, off) = with_simd_both(|| op.apply_adjoint(&dm));
            assert_bits_equal(&format!("adjoint {label} simd=on"), on.data(), &want);
            assert_bits_equal(&format!("adjoint {label} simd=off"), off.data(), &want);
        }
    }
}

#[test]
fn last_adjoint_matches_naive_reference_bit_for_bit() {
    let mut rng = Rng::new(204);
    for &(n, d, du) in SHAPES {
        let dn = DelayNetwork::new(d, theta_for(n));
        let dlast: Vec<f32> = order_sensitive(du * d, &mut rng);
        for l in blocks_for(n) {
            let t = RefTables::new(&dn, l);
            let want = ref_last_adjoint(&t, n, du, &dlast);
            let op = DnScanOperator::new(&dn, n, l);
            let got = op.apply_last_adjoint(n, du, &dlast);
            assert_bits_equal(&format!("last_adjoint n={n} d={d} du={du} L={l}"), got.data(), &want);
        }
    }
}

// ------------------------------------------------------ streaming mode

#[test]
fn stream_any_granularity_matches_batch_bit_for_bit() {
    let mut rng = Rng::new(205);
    for &(n, d, du) in &[(1usize, 1usize, 1usize), (9, 3, 3), (33, 5, 2), (32, 8, 1)] {
        let dn = DelayNetwork::new(d, theta_for(n));
        let u = Tensor::new(&[n, du], order_sensitive(n * du, &mut rng));
        for l in blocks_for(n) {
            let op = DnScanOperator::new(&dn, n, l);
            let whole = op.apply(&u);
            let label = format!("n={n} d={d} du={du} L={l}");
            // one push of everything
            let got = op.stream(du).push(&u);
            assert_bits_equal(&format!("stream one-push {label}"), got.data(), whole.data());
            // one row at a time
            let mut s = op.stream(du);
            let mut rows = Vec::new();
            for t in 0..n {
                rows.extend_from_slice(s.push(&u.slice_rows(t, t + 1)).data());
            }
            assert_bits_equal(&format!("stream row-wise {label}"), &rows, whole.data());
            assert_eq!(s.state().pos, n);
        }
    }
}

#[test]
fn stream_state_save_restore_mid_chunk_is_invisible() {
    // snapshot at EVERY cut point (including mid-chunk, where the
    // pending partial-chunk buffer matters) and resume in a fresh
    // stream: the tail output must be bit-identical to the
    // uninterrupted run
    let mut rng = Rng::new(206);
    let (n, d, du, l) = (21usize, 4usize, 2usize, 8usize);
    let dn = DelayNetwork::new(d, theta_for(n));
    let u = Tensor::new(&[n, du], order_sensitive(n * du, &mut rng));
    let op = DnScanOperator::new(&dn, n, l);
    let whole = op.apply(&u);
    for cut in 0..n {
        let mut head = op.stream(du);
        head.push(&u.slice_rows(0, cut));
        let saved: ScanState = head.state();
        assert_eq!(saved.pos, cut);
        let mut tail = op.resume(du, saved.clone());
        let got = tail.push(&u.slice_rows(cut, n));
        assert_bits_equal(
            &format!("resume at t={cut}"),
            got.data(),
            &whole.data()[cut * d * du..],
        );
        // the round trip itself is lossless
        assert_eq!(op.resume(du, saved.clone()).state(), saved);
    }
}

#[test]
fn chunk_boundary_state_is_the_carry_alone() {
    // at a chunk seam the pending buffer is empty: a state rebuilt from
    // just the (du·d) carry floats resumes bit-identically — this is
    // the bounded-memory contract the streaming trainer relies on
    let mut rng = Rng::new(207);
    let (n, d, du, l) = (24usize, 5usize, 2usize, 8usize);
    let dn = DelayNetwork::new(d, theta_for(n));
    let u = Tensor::new(&[n, du], order_sensitive(n * du, &mut rng));
    let op = DnScanOperator::new(&dn, n, l);
    let whole = op.apply(&u);
    let cut = 2 * l;
    let mut head = op.stream(du);
    head.push(&u.slice_rows(0, cut));
    let saved = head.state();
    assert_eq!(saved.pending_len, 0, "cut at a multiple of L must leave no pending rows");
    let rebuilt = ScanState {
        pos: cut,
        carry: saved.carry.clone(),
        pending: vec![0.0f32; du * l],
        pending_len: 0,
    };
    let got = op.resume(du, rebuilt).push(&u.slice_rows(cut, n));
    assert_bits_equal("carry-only resume", got.data(), &whole.data()[cut * d * du..]);
    // and that carry is exactly apply_last over the prefix
    assert_bits_equal(
        "carry == apply_last(prefix)",
        &saved.carry,
        &op.apply_last(&u.slice_rows(0, cut), None),
    );
}

// ----------------------------------------------------- autograd wiring

#[test]
fn graph_dn_conv_scan_values_and_grads_match_reference_bit_for_bit() {
    // the training-path op: forward repack and backward adjoint must
    // reproduce the naive reference per sample, bitwise, at B > 1
    let mut rng = Rng::new(208);
    let (batch, n, d, du, l) = (3usize, 17usize, 4usize, 2usize, 5usize);
    let dn = DelayNetwork::new(d, theta_for(n));
    let t = RefTables::new(&dn, l);
    let op = Arc::new(DnScanOperator::new(&dn, n, l));
    let u = Tensor::new(&[batch * n, du], order_sensitive(batch * n * du, &mut rng));
    let w = Tensor::randn(&[batch * n, du * d], 1.0, &mut rng);

    let mut g = Graph::new();
    let u_id = g.input(u.clone());
    let w_id = g.input(w.clone());
    let y = g.dn_conv(u_id, Arc::new(DnOperator::Scan(op.clone())), batch);
    let yw = g.mul(y, w_id);
    let loss = g.sum_all(yw);
    g.backward(loss);

    for b in 0..batch {
        let u_b = u.slice_rows(b * n, (b + 1) * n);
        let (m, _) = ref_apply(&t, &u_b, None);
        // forward: graph rows are channel-major (t, c·d+s) repacks of m
        let got = &g.value(y).data()[b * n * du * d..(b + 1) * n * du * d];
        for tt in 0..n {
            for c in 0..du {
                for s in 0..d {
                    let gv = got[tt * du * d + c * d + s];
                    let wv = m[(tt * d + s) * du + c];
                    assert!(
                        gv.to_bits() == wv.to_bits(),
                        "dn_conv fwd b={b} t={tt} s={s} c={c}: {gv} vs {wv}"
                    );
                }
            }
        }
        // backward: incoming grad is w (loss = Σ y⊙w); repack to (n,d,du)
        let mut dm = vec![0.0f32; n * d * du];
        for tt in 0..n {
            for c in 0..du {
                for s in 0..d {
                    dm[(tt * d + s) * du + c] = w.data()[(b * n + tt) * du * d + c * d + s];
                }
            }
        }
        let want_gu = ref_adjoint(&t, &dm, n, du);
        let got_gu = &g.grad(u_id).expect("no grad to u").data()[b * n * du..(b + 1) * n * du];
        assert_bits_equal(&format!("dn_conv grad b={b}"), got_gu, &want_gu);
    }
}

#[test]
fn graph_dn_last_scan_values_and_grads_match_reference_bit_for_bit() {
    // the classification-path op, with a NONZERO entering carry (the
    // streaming trainer's case): values thread the carry, gradients
    // flow to u only
    let mut rng = Rng::new(209);
    let (batch, n, d, du, l) = (2usize, 13usize, 3usize, 2usize, 4usize);
    let dn = DelayNetwork::new(d, theta_for(n));
    let t = RefTables::new(&dn, l);
    let op = Arc::new(DnScanOperator::new(&dn, n, l));
    let u = Tensor::new(&[batch * n, du], order_sensitive(batch * n * du, &mut rng));
    let carry = Tensor::randn(&[batch, du * d], 0.5, &mut rng);
    let w = Tensor::randn(&[batch, du * d], 1.0, &mut rng);

    let mut g = Graph::new();
    let u_id = g.input(u.clone());
    let w_id = g.input(w.clone());
    let y = g.dn_last_scan(u_id, op.clone(), batch, Some(&carry));
    let yw = g.mul(y, w_id);
    let loss = g.sum_all(yw);
    g.backward(loss);

    for b in 0..batch {
        let u_b = u.slice_rows(b * n, (b + 1) * n);
        let c0 = &carry.data()[b * du * d..(b + 1) * du * d];
        let (_, want_last) = ref_apply(&t, &u_b, Some(c0));
        let got = &g.value(y).data()[b * du * d..(b + 1) * du * d];
        assert_bits_equal(&format!("dn_last_scan fwd b={b}"), got, &want_last);
        let dlast = &w.data()[b * du * d..(b + 1) * du * d];
        let want_gu = ref_last_adjoint(&t, n, du, dlast);
        let got_gu = &g.grad(u_id).expect("no grad to u").data()[b * n * du..(b + 1) * n * du];
        assert_bits_equal(&format!("dn_last_scan grad b={b}"), got_gu, &want_gu);
    }

    // None carry ≡ Some(zeros), bitwise, values and grads
    let zeros = Tensor::zeros(&[batch, du * d]);
    let mut ga = Graph::new();
    let ua = ga.input(u.clone());
    let ya = ga.dn_last_scan(ua, op.clone(), batch, None);
    let la = ga.sum_all(ya);
    ga.backward(la);
    let mut gb = Graph::new();
    let ub = gb.input(u.clone());
    let yb = gb.dn_last_scan(ub, op.clone(), batch, Some(&zeros));
    let lb = gb.sum_all(yb);
    gb.backward(lb);
    assert_bits_equal("last_scan None≡zeros fwd", ga.value(ya).data(), gb.value(yb).data());
    assert_bits_equal(
        "last_scan None≡zeros grad",
        ga.grad(ua).unwrap().data(),
        gb.grad(ub).unwrap().data(),
    );
}

// ------------------------------------------------ knob + cross-strategy

#[test]
fn knob_routes_the_operator_and_restores() {
    let _guard = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let was = scan::mode();
    let dn = DelayNetwork::new(4, 16.0);
    scan::set_mode(scan::parse_mode("scan:8").unwrap());
    assert_eq!(scan::mode(), ScanMode::Scan { block: 8 });
    let op = DnOperator::for_mode(&dn, 16);
    assert!(op.as_scan().is_some(), "scan knob must build the scan operator");
    assert_eq!(op.as_scan().unwrap().block, 8);
    scan::set_mode(scan::parse_mode("fft").unwrap());
    assert!(DnOperator::for_mode(&dn, 16).as_scan().is_none());
    scan::set_mode(was);
}

#[test]
fn scan_and_fft_agree_to_strategy_tolerance_not_bits() {
    // the honest cross-strategy pin: same ~2e-4 budget as the paper's
    // other strategy cross-checks (different f32 association, so bits
    // are NOT compared — see the scan module doc)
    let mut rng = Rng::new(210);
    for &(n, d, du, l) in &[(64usize, 8usize, 2usize, 16usize), (128, 16, 1, 32), (33, 5, 2, 8)] {
        let dn = DelayNetwork::new(d, theta_for(n));
        let u = Tensor::randn(&[n, du], 1.0, &mut rng);
        let fft = DnFftOperator::new(&dn, n).apply(&u);
        let scan_m = DnScanOperator::new(&dn, n, l).apply(&u);
        let err = fft.max_abs_diff(&scan_m);
        assert!(err < 2e-4, "n={n} d={d} du={du} L={l}: fft-vs-scan err={err}");
    }
}

// ----------------------------------------------------- streaming train

/// A separable toy task (sign of the sequence mean), mirroring the
/// trainer's unit-test dataset.
fn toy_ds(n_examples: usize, seq_len: usize, seed: u64) -> plmu::data::SeqDataset {
    let mut rng = Rng::new(seed);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..n_examples {
        let sign = if i % 2 == 0 { 1.0f32 } else { -1.0 };
        let mut x = Tensor::randn(&[seq_len, 1], 0.5, &mut rng);
        for v in x.data_mut().iter_mut() {
            *v += sign * 0.4;
        }
        xs.push(x);
        ys.push(usize::from(sign > 0.0));
    }
    plmu::data::SeqDataset::classification(xs, ys)
}

fn run_fingerprint(streaming: Option<usize>, seq_len: usize, window: usize) -> u64 {
    let ds = toy_ds(32, seq_len, 42);
    let mut store = ParamStore::new();
    let mut rng = Rng::new(7);
    let model = SeqClassifier::new(ModelKind::LmuParallel, seq_len, 1, 6, 12, 2, &mut store, &mut rng);
    let mut opt = Adam::new(1e-3);
    let opts = FitOptions { epochs: 2, batch_size: 8, grad_clip: Some(5.0), ..Default::default() };
    let res = match streaming {
        Some(_) => fit_streaming(&model, &mut store, &mut opt, &ds, None, &opts, window),
        None => fit(&model, &mut store, &mut opt, &ds, None, &opts),
    };
    assert!(res.step_losses.iter().all(|l| l.is_finite()), "non-finite loss");
    bit_fingerprint(res.step_losses.iter().copied().chain(store.pack()))
}

#[test]
fn fit_streaming_with_whole_sequence_window_is_bit_identical_to_fit() {
    // window ≥ n ⇒ every step is one whole-sequence window from a zero
    // carry, so the streamed trainer and the batch trainer must produce
    // the same losses and the same final parameters, bit for bit
    let _guard = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let was = scan::mode();
    scan::set_mode(ScanMode::Scan { block: 4 });
    let seq_len = 12usize;
    let whole = run_fingerprint(None, seq_len, 0);
    let streamed = run_fingerprint(Some(seq_len), seq_len, seq_len);
    scan::set_mode(was);
    assert_eq!(
        whole, streamed,
        "fit vs fit_streaming(window=n) fingerprints differ: {whole:016x} vs {streamed:016x}"
    );
}

#[test]
fn fit_streaming_truncated_windows_train_and_stay_finite() {
    // window < n: the TBPTT path proper — non-final windows advance the
    // carry values-only.  Different gradients than full BPTT by design,
    // so no bit claim; the run must complete, stay finite, and be
    // deterministic against itself.
    let _guard = KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let was = scan::mode();
    scan::set_mode(ScanMode::Scan { block: 4 });
    let a = run_fingerprint(Some(4), 12, 4);
    let b = run_fingerprint(Some(4), 12, 4);
    // window is rounded up to a block multiple: 5 -> 8
    let c = run_fingerprint(Some(5), 12, 5);
    let d = run_fingerprint(Some(5), 12, 8);
    scan::set_mode(was);
    assert_eq!(a, b, "streaming run not deterministic");
    assert_eq!(c, d, "window round-up to the block multiple changed the result");
}
