//! Integration tests across the three layers: the Rust PJRT runtime
//! executes the AOT artifacts (L2 jax model + L1 Pallas kernel lowered to
//! HLO) and the results are pinned against the native Rust Delay Network —
//! cross-language numerical consistency, the strongest end-to-end signal
//! in the repo.
//!
//! These tests require `make artifacts` to have run; they skip (pass
//! trivially with a notice) when artifacts are absent so `cargo test`
//! works in a fresh checkout.

use plmu::dn::DelayNetwork;
use plmu::runtime::{ArtifactInput, Runtime};
use plmu::tensor::Tensor;
use plmu::util::Rng;
use std::path::Path;

fn open_runtime() -> Option<Runtime> {
    let dir = Path::new("artifacts");
    match Runtime::open(dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping integration test (run `make artifacts`): {e}");
            None
        }
    }
}

fn rand_u(n: usize, du: usize, seed: u64) -> Tensor {
    let mut rng = Rng::new(seed);
    Tensor::randn(&[n, du], 1.0, &mut rng)
}

#[test]
fn jax_fft_artifact_matches_native_dn() {
    let Some(mut rt) = open_runtime() else { return };
    let n = rt.manifest.config_usize("n").unwrap();
    let d = rt.manifest.config_usize("d").unwrap();
    let theta = rt.manifest.config_f64("theta").unwrap();
    let u = rand_u(n, 1, 42);

    let art = rt.artifact("dn_fwd_fft").unwrap();
    let outs = art.run(&[ArtifactInput::F32(u.clone())]).unwrap();
    let m_jax = &outs[0]; // (n, d, 1)

    let dn = DelayNetwork::new(d, theta);
    let m_native = dn.scan_sequential(&u);
    let err = m_jax.max_abs_diff(&m_native);
    assert!(err < 5e-3, "jax FFT artifact vs native Rust DN: err={err}");
}

#[test]
fn pallas_kernel_artifact_matches_native_dn() {
    // The L1 Pallas chunked-scan kernel, lowered through interpret=True
    // into the same HLO pipeline, executed by the Rust PJRT client.
    let Some(mut rt) = open_runtime() else { return };
    let n = rt.manifest.config_usize("n").unwrap();
    let d = rt.manifest.config_usize("d").unwrap();
    let theta = rt.manifest.config_f64("theta").unwrap();
    let u = rand_u(n, 1, 43);

    let art = rt.artifact("dn_fwd_pallas").unwrap();
    let outs = art.run(&[ArtifactInput::F32(u.clone())]).unwrap();
    let m_pallas = &outs[0];

    let dn = DelayNetwork::new(d, theta);
    let m_native = dn.scan_sequential(&u);
    let err = m_pallas.max_abs_diff(&m_native);
    assert!(err < 5e-3, "pallas artifact vs native Rust DN: err={err}");
}

#[test]
fn pallas_and_fft_artifacts_agree() {
    let Some(mut rt) = open_runtime() else { return };
    let n = rt.manifest.config_usize("n").unwrap();
    let u = rand_u(n, 1, 44);
    let m_fft = rt
        .artifact("dn_fwd_fft")
        .unwrap()
        .run(&[ArtifactInput::F32(u.clone())])
        .unwrap();
    let m_pal = rt
        .artifact("dn_fwd_pallas")
        .unwrap()
        .run(&[ArtifactInput::F32(u)])
        .unwrap();
    let err = m_fft[0].max_abs_diff(&m_pal[0]);
    assert!(err < 2e-3, "fft vs pallas artifacts: err={err}");
}

#[test]
fn recurrent_step_artifact_matches_batched_forward() {
    // The paper's parallel-train / recurrent-infer equivalence, across the
    // AOT boundary: running recurrent_step n times must produce the same
    // logits as the batched parallel `fwd` artifact.
    let Some(mut rt) = open_runtime() else { return };
    let n = rt.manifest.config_usize("n").unwrap();
    let d = rt.manifest.config_usize("d").unwrap();
    let du = rt.manifest.config_usize("du").unwrap();
    let dx = rt.manifest.config_usize("dx").unwrap();
    let batch = rt.manifest.config_usize("batch").unwrap();
    let classes = rt.manifest.config_usize("classes").unwrap();
    let params = rt.init_params().unwrap();

    // one real sample replicated across the batch
    let x1 = rand_u(n, dx, 45);
    let mut xb = Tensor::zeros(&[batch, n, dx]);
    for b in 0..batch {
        xb.data_mut()[b * n * dx..(b + 1) * n * dx].copy_from_slice(x1.data());
    }
    let fwd = rt.artifact("fwd").unwrap();
    let logits_par = fwd
        .run(&[ArtifactInput::F32(params.clone()), ArtifactInput::F32(xb)])
        .unwrap();
    let logits_par = &logits_par[0]; // (batch, classes)

    // streaming path
    let step = rt.artifact("recurrent_step").unwrap();
    let mut m = Tensor::zeros(&[d, du]);
    let mut logits_seq = Tensor::zeros(&[classes]);
    for t in 0..n {
        let x_t = Tensor::new(&[dx], x1.data()[t * dx..(t + 1) * dx].to_vec());
        let outs = step
            .run(&[
                ArtifactInput::F32(params.clone()),
                ArtifactInput::F32(m),
                ArtifactInput::F32(x_t),
            ])
            .unwrap();
        m = outs[0].clone();
        logits_seq = outs[1].clone();
    }
    let mut max_err = 0.0f32;
    for c in 0..classes {
        max_err = max_err.max((logits_par.data()[c] - logits_seq.data()[c]).abs());
    }
    assert!(max_err < 5e-3, "recurrent vs parallel artifact: err={max_err}");
}

#[test]
fn train_step_artifact_reduces_loss() {
    // Drive the fused fwd+bwd+Adam artifact from Rust for a few steps on a
    // fixed batch: the loss must fall (the E2E training path works).
    let Some(mut rt) = open_runtime() else { return };
    let n = rt.manifest.config_usize("n").unwrap();
    let dx = rt.manifest.config_usize("dx").unwrap();
    let batch = rt.manifest.config_usize("batch").unwrap();
    let classes = rt.manifest.config_usize("classes").unwrap();
    let mut params = rt.init_params().unwrap();
    let p_len = params.len();
    let mut adam_m = Tensor::zeros(&[p_len]);
    let mut adam_v = Tensor::zeros(&[p_len]);

    let mut rng = Rng::new(46);
    let xb = Tensor::randn(&[batch, n, dx], 1.0, &mut rng);
    let yb: Vec<i32> = (0..batch).map(|i| (i % classes) as i32).collect();

    let art = rt.artifact("train_step").unwrap();
    let mut losses = Vec::new();
    for step in 0..12 {
        let outs = art
            .run(&[
                ArtifactInput::F32(params),
                ArtifactInput::F32(adam_m),
                ArtifactInput::F32(adam_v),
                ArtifactInput::F32(Tensor::scalar(step as f32)),
                ArtifactInput::F32(xb.clone()),
                ArtifactInput::I32(yb.clone()),
            ])
            .unwrap();
        params = outs[0].clone().reshape(&[p_len]);
        adam_m = outs[1].clone().reshape(&[p_len]);
        adam_v = outs[2].clone().reshape(&[p_len]);
        losses.push(outs[3].item());
    }
    assert!(
        losses.last().unwrap() < &losses[0],
        "train_step loss did not fall: {losses:?}"
    );
    assert!(losses.iter().all(|l| l.is_finite()));
}
