//! Differential harness for the elementwise fusion pass (`PLMU_FUSION`):
//! every fused graph builder — `affine_act` (matmul epilogue),
//! `add2_row_act`, `add3_act` — must produce **bit-identical** values
//! AND parameter gradients to the unfused node chain it replaces, over
//! odd / lane-remainder shapes, NaN/Inf inputs, and with the buffer
//! arena recycling allocations underneath.
//!
//! The fusion knob is process-global, so every test that flips it
//! serializes on one mutex and restores the prior setting (same
//! discipline as the `PLMU_SIMD` knob in `simd_equivalence.rs`).

use plmu::autograd::{Act, Graph, NodeId, ParamStore};
use plmu::coordinator::data_parallel::pack_grads;
use plmu::data::batcher::BatchIter;
use plmu::data::SeqDataset;
use plmu::exec::arena::{self, Arena};
use plmu::fusion;
use plmu::train::{ModelKind, SeqClassifier, TrainableModel};
use plmu::util::Rng;
use plmu::Tensor;
use std::sync::Mutex;

static FUSION_KNOB: Mutex<()> = Mutex::new(());

/// Run `f` with fusion on and off (serialized, prior setting restored)
/// and return both results.
fn with_fusion_both<T>(f: impl Fn() -> T) -> (T, T) {
    let _guard = FUSION_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let was = fusion::enabled();
    fusion::set_enabled(true);
    let on = f();
    fusion::set_enabled(false);
    let off = f();
    fusion::set_enabled(was);
    (on, off)
}

fn assert_bits_equal(label: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{label}: element {i} differs: {g} ({:#010x}) vs {w} ({:#010x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// Output data + per-param gradient data of one recorded graph, driven
/// to a scalar loss so the backward sweep runs end to end.
type ChainResult = (Vec<f32>, Vec<Vec<f32>>);

fn run_graph(store: &ParamStore, build: &dyn Fn(&mut Graph, &ParamStore) -> NodeId) -> ChainResult {
    let mut g = Graph::new();
    let out = build(&mut g, store);
    let sq = g.mul(out, out);
    let loss = g.mean_all(sq);
    g.backward(loss);
    let val = g.value(out).data().to_vec();
    let grads = g.param_grads().into_iter().map(|(_, t)| t.data().to_vec()).collect();
    (val, grads)
}

fn compare_chain(label: &str, store: &ParamStore, build: &dyn Fn(&mut Graph, &ParamStore) -> NodeId) {
    let (on, off) = with_fusion_both(|| run_graph(store, build));
    assert_bits_equal(&format!("{label}: value"), &on.0, &off.0);
    assert_eq!(on.1.len(), off.1.len(), "{label}: grad count");
    for (i, (g_on, g_off)) in on.1.iter().zip(&off.1).enumerate() {
        assert_bits_equal(&format!("{label}: grad {i}"), g_on, g_off);
    }
}

const ACTS: [Option<Act>; 3] = [None, Some(Act::Tanh), Some(Act::Relu)];

#[test]
fn affine_act_fused_chain_bit_equal_including_grads() {
    // lane-remainder shapes: width 1, 8k-1 / 8k / 8k+1, and a k large
    // enough to span multiple k-panels of the matmul
    for &(m, k, n) in &[(1usize, 1usize, 1usize), (3, 5, 7), (17, 9, 4), (5, 16, 8), (33, 300, 31)] {
        for &act in &ACTS {
            let mut rng = Rng::new((m * 1000 + k * 10 + n) as u64);
            let mut store = ParamStore::new();
            let x = store.add("x", Tensor::randn(&[m, k], 1.0, &mut rng));
            let w = store.add("w", Tensor::randn(&[k, n], 0.5, &mut rng));
            let b = store.add("b", Tensor::randn(&[n], 0.1, &mut rng));
            let build = move |g: &mut Graph, s: &ParamStore| {
                let (xn, wn, bn) = (g.param(s, x), g.param(s, w), g.param(s, b));
                g.affine_act(xn, wn, bn, act)
            };
            compare_chain(&format!("affine_act ({m},{k},{n}) {act:?}"), &store, &build);
        }
    }
}

#[test]
fn add2_row_and_add3_fused_chains_bit_equal_including_grads() {
    for &(m, n) in &[(1usize, 1usize), (3, 7), (9, 8), (17, 33)] {
        for &act in &ACTS {
            let mut rng = Rng::new((m * 100 + n) as u64);
            let mut store = ParamStore::new();
            let a = store.add("a", Tensor::randn(&[m, n], 1.0, &mut rng));
            let b = store.add("b", Tensor::randn(&[m, n], 1.0, &mut rng));
            let bias = store.add("bias", Tensor::randn(&[n], 0.2, &mut rng));
            let c = store.add("c", Tensor::randn(&[m, n], 1.0, &mut rng));

            let build2 = move |g: &mut Graph, s: &ParamStore| {
                let (an, bn, biasn) = (g.param(s, a), g.param(s, b), g.param(s, bias));
                g.add2_row_act(an, bn, biasn, act)
            };
            compare_chain(&format!("add2_row_act ({m},{n}) {act:?}"), &store, &build2);

            let build3 = move |g: &mut Graph, s: &ParamStore| {
                let (an, bn, cn) = (g.param(s, a), g.param(s, b), g.param(s, c));
                g.add3_act(an, bn, cn, act)
            };
            compare_chain(&format!("add3_act ({m},{n}) {act:?}"), &store, &build3);
        }
    }
}

#[test]
fn non_finite_inputs_propagate_identically_across_fusion() {
    // NaN in x (hits the matmul zero-skip gate), Inf in the bias (sweeps
    // a whole output column through the epilogue), -0.0 under relu
    let (m, k, n) = (5usize, 9usize, 7usize);
    for &act in &ACTS {
        let mut rng = Rng::new(77);
        let mut xt = Tensor::randn(&[m, k], 1.0, &mut rng);
        xt.data_mut()[m * k - 1] = f32::NAN;
        xt.data_mut()[0] = -0.0;
        let mut bt = Tensor::randn(&[n], 0.1, &mut rng);
        bt.data_mut()[n - 1] = f32::INFINITY;
        let mut store = ParamStore::new();
        let x = store.add("x", xt);
        let w = store.add("w", Tensor::randn(&[k, n], 0.5, &mut rng));
        let b = store.add("b", bt);
        let build = move |g: &mut Graph, s: &ParamStore| {
            let (xn, wn, bn) = (g.param(s, x), g.param(s, w), g.param(s, b));
            g.affine_act(xn, wn, bn, act)
        };
        compare_chain(&format!("affine_act non-finite {act:?}"), &store, &build);
    }
}

// ------------------------------------------------------ full-model sweep

fn toy_classification(n_examples: usize, seq_len: usize, seed: u64) -> SeqDataset {
    let mut rng = Rng::new(seed);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..n_examples {
        let sign = if i % 2 == 0 { 1.0f32 } else { -1.0 };
        let mut x = Tensor::randn(&[seq_len, 1], 0.5, &mut rng);
        x.map_inplace(|v| v + sign * 0.4);
        xs.push(x);
        ys.push(usize::from(sign > 0.0));
    }
    SeqDataset::classification(xs, ys)
}

/// Loss value + packed parameter gradients of one batch through a full
/// model — the end-to-end composition of every fused chain.
fn model_loss_and_grads(kind: ModelKind) -> (f32, Vec<f32>) {
    let ds = toy_classification(8, 12, 21);
    let mut store = ParamStore::new();
    let mut rng = Rng::new(11);
    let model = SeqClassifier::new(kind, 12, 1, 6, 12, 2, &mut store, &mut rng);
    let batch = BatchIter::sequential(&ds, 8).next().unwrap();
    let mut g = Graph::new();
    let loss = model.loss(&mut g, &store, &batch);
    g.backward(loss);
    let lv = g.value(loss).item();
    let packed = pack_grads(&store, &g.param_grads());
    (lv, packed)
}

#[test]
fn full_models_bit_equal_across_fusion() {
    // parallel LMU (affine_act + add2_row_act), sequential LMU (same
    // chains around the recurrent scan), original cell (add3_act × 2),
    // LSTM (add2_row_act gate pre-activation + Dense head)
    for kind in [
        ModelKind::LmuParallel,
        ModelKind::LmuSequential,
        ModelKind::LmuOriginal,
        ModelKind::Lstm,
    ] {
        let (on, off) = with_fusion_both(|| model_loss_and_grads(kind));
        assert_eq!(
            on.0.to_bits(),
            off.0.to_bits(),
            "{kind:?}: loss differs across fusion: {} vs {}",
            on.0,
            off.0
        );
        assert_bits_equal(&format!("{kind:?}: packed grads"), &on.1, &off.1);
    }
}

#[test]
fn full_model_bit_equal_across_gemm_paths() {
    // the PLMU_GEMM packed path under the fused graph: loss and packed
    // gradients of a whole training batch must be bit-identical to the
    // axpy default, with fusion at its ambient setting (serialized on
    // the same knob mutex so no other test flips fusion mid-run)
    use plmu::tensor::packed::{gemm_path, set_gemm_path, GemmPath};
    let _guard = FUSION_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let was = gemm_path();
    for kind in [ModelKind::LmuParallel, ModelKind::Lstm] {
        set_gemm_path(GemmPath::Axpy);
        let axpy = model_loss_and_grads(kind);
        set_gemm_path(GemmPath::Packed);
        let packed = model_loss_and_grads(kind);
        assert_eq!(
            packed.0.to_bits(),
            axpy.0.to_bits(),
            "{kind:?}: loss differs across PLMU_GEMM: {} vs {}",
            packed.0,
            axpy.0
        );
        assert_bits_equal(&format!("{kind:?}: packed grads across PLMU_GEMM"), &packed.1, &axpy.1);
    }
    set_gemm_path(was);
}

#[test]
fn arena_recycling_does_not_change_results() {
    // plain allocation vs a fresh arena vs a *warm* arena (second round
    // reuses recycled buffers): all three bit-identical, and the warm
    // round must actually hit the free lists
    let run = || model_loss_and_grads(ModelKind::LmuParallel);
    let plain = run();
    let mut a = Arena::new();
    let cold = arena::scope(&mut a, run);
    let warm = arena::scope(&mut a, run);
    assert_eq!(plain.0.to_bits(), cold.0.to_bits(), "cold-arena loss differs");
    assert_eq!(plain.0.to_bits(), warm.0.to_bits(), "warm-arena loss differs");
    assert_bits_equal("cold-arena grads", &cold.1, &plain.1);
    assert_bits_equal("warm-arena grads", &warm.1, &plain.1);
    let s = a.stats();
    assert!(s.hits > 0, "second round never reused a buffer: {s:?}");
}
