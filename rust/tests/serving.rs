//! Serving-stack pins: continuous batching must be **bit-identical** to
//! per-session serial stepping, and the whole load simulation — served
//! counts, evictions, latency quantiles, output checksum — must be a
//! pure function of (seed, config), independent of `PLMU_THREADS`.
//!
//! Everything lives in one test fn because `exec::set_threads` is
//! process-global and the assertions sweep it.

use plmu::autograd::ParamStore;
use plmu::coordinator::sessions::{
    execute_packed, run_load_sim, LoadSimConfig, PackedRun, ShedPolicy,
};
use plmu::coordinator::{NativeStreamingEngine, StreamingEngine};
use plmu::exec;
use plmu::layers::lmu::{LmuParallelLayer, LmuSpec};
use plmu::util::Rng;

fn engine() -> NativeStreamingEngine {
    let mut rng = Rng::new(7);
    let mut store = ParamStore::new();
    let spec = LmuSpec::new(1, 1, 8, 64.0, 16);
    let layer = LmuParallelLayer::new(spec.clone(), 64, &mut store, &mut rng, "t");
    NativeStreamingEngine::from_store(&spec, &layer.params, &store)
}

/// Deterministic pseudo-input for (session, token, lane).
fn x_for(s: usize, t: usize) -> Vec<f32> {
    vec![((s * 31 + t * 7 + 1) as f32 * 0.137).sin()]
}

#[test]
fn continuous_batching_is_bit_exact_and_thread_invariant() {
    let eng = engine();
    let state_size = eng.state_size();

    // --- packed batch vs serial reference, at 1 and 8 threads ---------
    // 37 sessions with ragged step counts (1..=5) in one packed batch
    let sessions = 37usize;
    let serial: Vec<Vec<Vec<f32>>> = (0..sessions)
        .map(|s| {
            let mut state = vec![0.0f32; state_size];
            (0..(s % 5 + 1)).map(|t| eng.step(&mut state, &x_for(s, t))).collect()
        })
        .collect();
    for threads in [1usize, 8] {
        exec::set_threads(threads);
        let mut runs: Vec<PackedRun> = (0..sessions)
            .map(|s| PackedRun {
                session: s as u64,
                state: vec![0.0f32; state_size],
                xs: (0..(s % 5 + 1)).map(|t| x_for(s, t)).collect(),
                outs: Vec::new(),
            })
            .collect();
        execute_packed(&eng, &mut runs);
        for (s, run) in runs.iter().enumerate() {
            assert_eq!(run.outs.len(), serial[s].len());
            for (t, (got, want)) in run.outs.iter().zip(&serial[s]).enumerate() {
                assert_eq!(got.len(), want.len());
                for (i, (a, b)) in got.iter().zip(want.iter()).enumerate() {
                    assert!(
                        a.to_bits() == b.to_bits(),
                        "packed output differs from serial at session {s} step {t} \
                         lane {i} ({threads} threads): {a} vs {b}"
                    );
                }
            }
        }
    }

    // --- the full load sim is a pure function of (seed, config) -------
    // small but non-trivial: arrivals, think-time, LRU + idle eviction,
    // and queue shedding all fire
    let cfg = LoadSimConfig {
        seed: 3,
        windows: 200,
        window_us: 500,
        arrivals_per_window: 6.0,
        session_tokens_mean: 4.0,
        token_gap_windows: 8,
        dx: 1,
        queue_cap: 24,
        batch_cap: 12,
        session_mem_bytes: 40 * plmu::coordinator::sessions::session_bytes(state_size),
        idle_deadline_windows: Some(40),
        shed: ShedPolicy::RejectNew,
        retry_windows: 3,
        slo_us: 1500,
    };
    let mut reports = Vec::new();
    for threads in [1usize, 8] {
        exec::set_threads(threads);
        reports.push((threads, run_load_sim(&eng, &cfg)));
    }
    let (_, ref base) = reports[0];
    assert!(base.served > 0, "sim served nothing");
    assert!(base.shed > 0, "sim config did not exercise shedding");
    assert!(
        base.evicted_lru + base.evicted_idle > 0,
        "sim config did not exercise eviction"
    );
    assert!(!base.budget_exceeded, "store byte budget violated");
    for (threads, rep) in &reports {
        assert_eq!(
            rep.checksum, base.checksum,
            "output checksum differs at {threads} threads"
        );
        assert_eq!(rep.served, base.served, "served count differs at {threads} threads");
        assert_eq!(rep.shed, base.shed, "shed count differs at {threads} threads");
        assert_eq!(
            (rep.evicted_lru, rep.evicted_idle),
            (base.evicted_lru, base.evicted_idle),
            "eviction counts differ at {threads} threads"
        );
        assert_eq!(
            (rep.p50_us, rep.p95_us, rep.p99_us, rep.max_us),
            (base.p50_us, base.p95_us, base.p99_us, base.max_us),
            "latency quantiles differ at {threads} threads"
        );
    }
    // same seed, same thread count, run again: byte-identical
    let again = run_load_sim(&eng, &cfg);
    assert_eq!(again.checksum, base.checksum, "same-seed rerun differs");
    exec::set_threads(1);
}
