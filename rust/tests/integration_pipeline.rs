//! Integration tests over the native stack: dataset -> trainer ->
//! evaluation -> streaming deployment, plus train-parallel /
//! serve-recurrent weight handoff (no artifacts required).

use plmu::autograd::ParamStore;
use plmu::coordinator::{NativeStreamingEngine, ServerConfig, StreamingEngine, StreamingServer};
use plmu::data::{MackeyGlass, PsMnist, SeqDataset};
use plmu::data::nlp::SynthLang;
use plmu::layers::lmu::{LmuParallelLayer, LmuSpec};
use plmu::optim::{Adam, LrSchedule};
use plmu::train::{evaluate, fit, FitOptions, ModelKind, SeqClassifier, SeqRegressor, RegressorKind};
use plmu::util::Rng;

#[test]
fn psmnist_small_pipeline_beats_chance() {
    // tiny psMNIST (8x8, 4 classes): full pipeline should reach well
    // above the 25% chance level within a few epochs
    let task = PsMnist::new(8, 4, 0);
    let (xs, ys) = task.dataset(160, 1);
    let (train, test) = SeqDataset::classification(xs, ys).split(0.25);
    let mut store = ParamStore::new();
    let mut rng = Rng::new(2);
    let model = SeqClassifier::new(
        ModelKind::LmuParallel,
        task.seq_len(),
        1,
        16,
        32,
        4,
        &mut store,
        &mut rng,
    );
    let mut opt = Adam::new(5e-3);
    let opts = FitOptions { epochs: 10, batch_size: 16, ..Default::default() };
    let res = fit(&model, &mut store, &mut opt, &train, Some(&test), &opts);
    let acc = res.epochs.last().unwrap().eval_metric.unwrap();
    assert!(acc > 50.0, "psMNIST-small accuracy too low: {acc}");
}

#[test]
fn mackey_glass_regressor_learns() {
    let mg = MackeyGlass::generate(1200, 0);
    let (mean, std) = mg.stats();
    let mut mgz = mg;
    for v in mgz.series.iter_mut() {
        *v = (*v - mean) / std;
    }
    let (xs, ys) = mgz.windows(32, 15, 4);
    let ds = SeqDataset::regression(xs, ys);
    let (train, test) = ds.split(0.25);
    let mut store = ParamStore::new();
    let mut rng = Rng::new(3);
    let model = SeqRegressor::new(RegressorKind::LmuParallel, 32, 12, 32.0, 24, &mut store, &mut rng);
    let mut opt = Adam::new(3e-3);
    let opts = FitOptions { epochs: 8, batch_size: 16, ..Default::default() };
    let before = evaluate(&model, &store, &test, 16);
    fit(&model, &mut store, &mut opt, &train, None, &opts);
    let after = evaluate(&model, &store, &test, 16);
    assert!(
        after < before * 0.7 && after < 0.6,
        "MG NRMSE did not improve: {before} -> {after}"
    );
}

#[test]
fn sentiment_dn_only_learnable() {
    // sanity for the Table 4 setup: planted sentiment structure is
    // linearly recoverable through a frozen-embedding average
    let lang = SynthLang::new(300, 8, 0);
    let (xs, ys) = lang.sentiment_dataset(200, 40, 1);
    // featurize: mean frozen embedding (dim 16)
    let mut rng = Rng::new(4);
    let emb: Vec<Vec<f32>> = (0..300)
        .map(|_| (0..16).map(|_| rng.normal_f32(0.0, 1.0)).collect())
        .collect();
    let feats: Vec<plmu::Tensor> = xs
        .iter()
        .map(|sent| {
            let mut f = vec![0.0f32; 16];
            for &w in sent {
                for (a, b) in f.iter_mut().zip(&emb[w]) {
                    *a += b / sent.len() as f32;
                }
            }
            plmu::Tensor::new(&[1, 16], f)
        })
        .collect();
    // logistic regression via the autograd stack
    let mut store = ParamStore::new();
    let w = store.add("w", plmu::Tensor::glorot(16, 2, &mut rng));
    let b = store.add("b", plmu::Tensor::zeros(&[2]));
    let mut opt = Adam::new(5e-2);
    for _ in 0..150 {
        let mut g = plmu::autograd::Graph::new();
        let x = g.input(plmu::Tensor::concat_rows(&feats.iter().collect::<Vec<_>>()));
        let wi = g.param(&store, w);
        let bi = g.param(&store, b);
        let logits = g.affine(x, wi, bi);
        let loss = g.softmax_xent(logits, &ys);
        g.backward(loss);
        let grads = g.param_grads();
        plmu::optim::Optimizer::step(&mut opt, &mut store, &grads);
    }
    let mut g = plmu::autograd::Graph::new();
    let x = g.input(plmu::Tensor::concat_rows(&feats.iter().collect::<Vec<_>>()));
    let wi = g.param(&store, w);
    let bi = g.param(&store, b);
    let logits = g.affine(x, wi, bi);
    let pred = g.value(logits).argmax_rows();
    let acc = plmu::metrics::accuracy(&pred, &ys);
    assert!(acc > 70.0, "sentiment structure unlearnable: {acc}");
}

#[test]
fn train_parallel_then_serve_recurrent() {
    // the deployment story end-to-end: train with the parallel form,
    // hand the SAME weights to the streaming server, and verify the
    // server's final-step outputs match the parallel forward
    let (n, d, hidden) = (24usize, 8usize, 6usize);
    let mut store = ParamStore::new();
    let mut rng = Rng::new(5);
    let spec = LmuSpec::new(1, 1, d, n as f64, hidden);
    let layer = LmuParallelLayer::new(spec.clone(), n, &mut store, &mut rng, "e2e");

    // brief training on a toy regression target
    let x = plmu::Tensor::randn(&[2 * n, 1], 1.0, &mut rng);
    let x_last = plmu::layers::last_steps(&x, 2, n);
    let target = plmu::Tensor::randn(&[2, hidden], 0.5, &mut rng);
    let mut opt = Adam::new(1e-2);
    for _ in 0..20 {
        let mut g = plmu::autograd::Graph::new();
        let xi = g.input(x.clone());
        let xl = g.input(x_last.clone());
        let o = layer.forward_last(&mut g, &store, xi, xl, 2);
        let loss = g.mse(o, &target);
        g.backward(loss);
        let grads = g.param_grads();
        plmu::optim::Optimizer::step(&mut opt, &mut store, &grads);
    }

    // parallel forward of sample 0 with the trained weights
    let mut g = plmu::autograd::Graph::new();
    let xi = g.input(x.slice_rows(0, n));
    let xl = g.input(x_last.slice_rows(0, 1));
    let o_par = layer.forward_last(&mut g, &store, xi, xl, 1);
    let par = g.value(o_par).clone();

    // streaming server with the same weights
    let server = StreamingServer::new(1, ServerConfig::default(), || {
        Box::new(NativeStreamingEngine::from_store(&spec, &layer.params, &store))
    });
    let mut last = Vec::new();
    for t in 0..n {
        let r = server.router.step_blocking(1, vec![x.data()[t]]);
        last = r.output;
    }
    for (a, b) in par.data().iter().zip(&last) {
        assert!((a - b).abs() < 2e-4, "served output != trained parallel output");
    }
}

#[test]
fn lr_schedule_text8_style_decay_in_fit() {
    // schedule integration: decay at epoch 1 visible in optimizer lr
    let task = PsMnist::new(6, 2, 7);
    let (xs, ys) = task.dataset(24, 8);
    let ds = SeqDataset::classification(xs, ys);
    let mut store = ParamStore::new();
    let mut rng = Rng::new(9);
    let model = SeqClassifier::new(ModelKind::LmuParallel, 36, 1, 4, 8, 2, &mut store, &mut rng);
    let mut opt = Adam::new(1.0);
    let opts = FitOptions {
        epochs: 2,
        batch_size: 8,
        schedule: LrSchedule::step_decay(1e-3, 1, 0.1),
        ..Default::default()
    };
    fit(&model, &mut store, &mut opt, &ds, None, &opts);
    assert!((plmu::optim::Optimizer::lr(&opt) - 1e-4).abs() < 1e-9);
}

#[test]
fn streaming_engine_throughput_sane() {
    // not a benchmark, just a liveness guard: 1k tokens stream quickly
    let mut rng = Rng::new(11);
    let mut store = ParamStore::new();
    let spec = LmuSpec::new(1, 1, 16, 64.0, 8);
    let layer = LmuParallelLayer::new(spec.clone(), 64, &mut store, &mut rng, "tp");
    let engine = NativeStreamingEngine::from_store(&spec, &layer.params, &store);
    let mut state = vec![0.0f32; engine.state_size()];
    let t0 = std::time::Instant::now();
    for t in 0..1000 {
        engine.step(&mut state, &[(t as f32).sin()]);
    }
    assert!(t0.elapsed().as_secs_f64() < 5.0, "streaming engine unreasonably slow");
}
