//! Serial/parallel equivalence for the exec substrate: every kernel that
//! dispatches through `plmu::exec` must produce BIT-IDENTICAL results at
//! every thread count, because work is partitioned over independent
//! output rows/items and each element keeps the serial op order.  This is
//! the substrate's contract (and the CPU mirror of the paper's claim that
//! the parallel and recurrent LMU forms compute the same function).
//!
//! The global thread knob is process-wide, so these tests serialize on a
//! mutex; other test binaries run in separate processes and are
//! unaffected.

use plmu::autograd::{Graph, ParamStore};
use plmu::coordinator::data_parallel::{
    shard_dataset, DataParallelConfig, DataParallelCoordinator,
};
use plmu::dn::{DelayNetwork, DnFftOperator};
use plmu::exec;
use plmu::fft::{next_pow2, RfftCache};
use plmu::layers::lmu::{LmuParallelLayer, LmuSpec};
use plmu::layers::{to_sample_major, to_time_major};
use plmu::optim::Adam;
use plmu::train::{ModelKind, SeqClassifier};
use plmu::util::Rng;
use plmu::Tensor;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

static THREAD_KNOB: Mutex<()> = Mutex::new(());

/// Hold the global thread-knob lock for a whole test body.  The knob,
/// the worker pool, and its peak-concurrency counter are process-global,
/// so *every* test in this binary — including its setup work, which may
/// itself dispatch on the pool (e.g. `DnFftOperator::new`) — must be
/// serialized, or the budget assertions below turn flaky.
fn knob_guard() -> std::sync::MutexGuard<'static, ()> {
    THREAD_KNOB.lock().unwrap_or_else(|e| e.into_inner())
}

/// Run `f` at each thread count and assert the outputs are bit-identical
/// to the 1-thread reference.  Callers hold [`knob_guard`] around their
/// whole test body.
fn assert_equal_across_threads(label: &str, f: impl Fn() -> Vec<f32>) {
    exec::set_threads(1);
    let reference = f();
    for &t in &[2usize, 3, 4] {
        exec::set_threads(t);
        let got = f();
        assert_eq!(got.len(), reference.len(), "{label}: length changed at {t} threads");
        for (i, (a, b)) in got.iter().zip(&reference).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{label}: element {i} differs at {t} threads: {a} vs {b}"
            );
        }
    }
    exec::set_threads(1);
}

// Shapes: the first entry in each list crosses exec::MIN_PARALLEL_WORK so
// the parallel path genuinely runs; the rest are odd/degenerate shapes
// (non-divisible row counts, single rows) that exercise the partition
// edge cases (they may fall back to serial — equivalence must hold
// regardless).

#[test]
fn matvec_bit_equal() {
    let _k = knob_guard();
    let mut rng = Rng::new(12);
    // first shape crosses MIN_PARALLEL_WORK so the (newly routed) exec
    // dispatch genuinely engages; the rest are degenerate fallbacks
    for &(r, c) in &[(600usize, 300usize), (7, 11), (1, 5)] {
        let m = Tensor::randn(&[r, c], 1.0, &mut rng);
        let x: Vec<f32> = (0..c).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        assert_equal_across_threads(&format!("matvec {r}x{c}"), || {
            plmu::tensor::matmul::matvec(&m, &x)
        });
    }
}

#[test]
fn matmul_family_bit_equal() {
    let _k = knob_guard();
    // both PLMU_GEMM paths must be thread-count invariant: the packed
    // path packs per exec chunk, so the partition must not change bytes
    use plmu::tensor::packed::{gemm_path, set_gemm_path, GemmPath};
    let was = gemm_path();
    let mut rng = Rng::new(1);
    let shapes: &[(usize, usize, usize)] =
        &[(129, 67, 65), (517, 33, 31), (7, 300, 5), (1, 1, 1), (3, 2, 1)];
    for &(m, k, n) in shapes {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let at = Tensor::randn(&[k, m], 1.0, &mut rng);
        let bt = Tensor::randn(&[n, k], 1.0, &mut rng);
        for path in [GemmPath::Axpy, GemmPath::Packed] {
            set_gemm_path(path);
            assert_equal_across_threads(&format!("matmul {m}x{k}x{n} {path:?}"), || {
                a.matmul(&b).data().to_vec()
            });
            assert_equal_across_threads(&format!("matmul_tn {m}x{k}x{n} {path:?}"), || {
                at.matmul_tn(&b).data().to_vec()
            });
            assert_equal_across_threads(&format!("matmul_nt {m}x{k}x{n} {path:?}"), || {
                a.matmul_nt(&bt).data().to_vec()
            });
        }
    }
    set_gemm_path(was);
}

#[test]
fn elementwise_and_softmax_bit_equal() {
    let _k = knob_guard();
    let mut rng = Rng::new(2);
    // big enough to cross the parallel threshold, odd row count
    let x = Tensor::randn(&[301, 1031], 1.0, &mut rng);
    let y = Tensor::randn(&[301, 1031], 1.0, &mut rng);
    assert_equal_across_threads("tanh map", || x.tanh().data().to_vec());
    assert_equal_across_threads("zip mul", || x.mul(&y).data().to_vec());
    assert_equal_across_threads("softmax_rows", || x.softmax_rows().data().to_vec());
    assert_equal_across_threads("transpose2", || x.transpose2().data().to_vec());
    assert_equal_across_threads("add_row", || {
        let bias = y.slice_rows(0, 1).reshape(&[1031]);
        x.add_row(&bias).data().to_vec()
    });
}

#[test]
fn fft_conv_batch_bit_equal() {
    let _k = knob_guard();
    let mut rng = Rng::new(3);
    let n = 700usize;
    let kernel: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
    let cache = RfftCache::new(&kernel, next_pow2(2 * n));
    let rows: Vec<Vec<f32>> =
        (0..13).map(|_| (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
    assert_equal_across_threads("conv_batch", || {
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        cache.conv_batch(&refs, n).concat()
    });
}

#[test]
fn dn_fft_operator_bit_equal() {
    let _k = knob_guard();
    let mut rng = Rng::new(4);
    for &(n, d, du) in &[(257usize, 12usize, 5usize), (64, 8, 1), (1, 4, 2)] {
        let dn = DelayNetwork::new(d, n.max(4) as f64);
        let op = DnFftOperator::new(&dn, n);
        let u = Tensor::randn(&[n, du], 1.0, &mut rng);
        let dm = Tensor::randn(&[n, d, du], 1.0, &mut rng);
        assert_equal_across_threads(&format!("dn_fft apply n={n} d={d} du={du}"), || {
            op.apply(&u).data().to_vec()
        });
        assert_equal_across_threads(&format!("dn_fft adjoint n={n} d={d} du={du}"), || {
            op.apply_adjoint(&dm).data().to_vec()
        });
        assert_equal_across_threads(&format!("dn parallel_last n={n} d={d} du={du}"), || {
            dn.parallel_last(&u).data().to_vec()
        });
    }
}

#[test]
fn dn_parallel_last_bit_equal_large() {
    let _k = knob_guard();
    // big enough that the row partition over the d state dimensions
    // actually engages (n*d*du crosses MIN_PARALLEL_WORK)
    let mut rng = Rng::new(9);
    let (n, d, du) = (2100usize, 16usize, 8usize);
    let dn = DelayNetwork::new(d, 256.0);
    let u = Tensor::randn(&[n, du], 1.0, &mut rng);
    assert_equal_across_threads("dn parallel_last large", || {
        dn.parallel_last(&u).data().to_vec()
    });
}

#[test]
fn dn_operator_rebuild_bit_equal_across_threads() {
    let _k = knob_guard();
    // operator CONSTRUCTION also fans out (per-kernel FFTs) — rebuilding
    // under different thread counts must give identical spectra, observed
    // through apply()
    let mut rng = Rng::new(5);
    let (n, d, du) = (200usize, 16usize, 3usize);
    let u = Tensor::randn(&[n, du], 1.0, &mut rng);
    assert_equal_across_threads("dn_fft rebuild+apply", || {
        let dn = DelayNetwork::new(d, n as f64);
        let op = DnFftOperator::new(&dn, n);
        op.apply(&u).data().to_vec()
    });
}

#[test]
fn lmu_parallel_layer_forward_bit_equal() {
    let _k = knob_guard();
    // full layer forward through the autograd graph: encoder matmul ->
    // batched DN conv (nested parallelism) -> output matmul; odd batch
    // and sequence sizes, plus the B=1 and n=1 degenerate cases
    // first shape crosses the dn_conv batch-parallel threshold
    for &(batch, n, dx, d, hidden) in
        &[(3usize, 300usize, 5usize, 9usize, 11usize), (1, 64, 3, 8, 6), (2, 1, 2, 4, 3)]
    {
        let mut rng = Rng::new(6);
        let mut store = ParamStore::new();
        let spec = LmuSpec::new(dx, 2, d, n.max(4) as f64, hidden);
        let layer = LmuParallelLayer::new(spec, n, &mut store, &mut rng, "eq");
        let x = Tensor::randn(&[batch * n, dx], 1.0, &mut rng);
        assert_equal_across_threads(&format!("lmu fwd B={batch} n={n}"), || {
            let mut g = Graph::new();
            let xi = g.input(x.clone());
            let o = layer.forward_all(&mut g, &store, xi, batch);
            g.value(o).data().to_vec()
        });
    }
}

#[test]
fn lmu_backward_grads_bit_equal() {
    let _k = knob_guard();
    // gradients flow through the adjoint convolution and matmul_tn —
    // the full training step must also be thread-count invariant
    let (batch, n, dx, d, hidden) = (2usize, 257usize, 4usize, 7usize, 9usize);
    let mut rng = Rng::new(7);
    let mut store = ParamStore::new();
    let spec = LmuSpec::new(dx, 2, d, n as f64, hidden);
    let layer = LmuParallelLayer::new(spec, n, &mut store, &mut rng, "eqb");
    let x = Tensor::randn(&[batch * n, dx], 1.0, &mut rng);
    let target = Tensor::randn(&[batch * n, hidden], 0.5, &mut rng);
    assert_equal_across_threads("lmu backward grads", || {
        let mut g = Graph::new();
        let xi = g.input(x.clone());
        let o = layer.forward_all(&mut g, &store, xi, batch);
        let loss = g.mse(o, &target);
        g.backward(loss);
        let mut flat = Vec::new();
        for (_, grad) in g.param_grads() {
            flat.extend_from_slice(grad.data());
        }
        flat
    });
}

#[test]
fn layout_transposes_bit_equal() {
    let _k = knob_guard();
    let mut rng = Rng::new(8);
    for &(batch, n, f) in &[(7usize, 53usize, 19usize), (1, 5, 3), (4, 1, 2)] {
        let x = Tensor::randn(&[batch * n, f], 1.0, &mut rng);
        assert_equal_across_threads(&format!("to_time_major B={batch} n={n}"), || {
            to_time_major(&x, batch, n).data().to_vec()
        });
        assert_equal_across_threads(&format!("to_sample_major B={batch} n={n}"), || {
            to_sample_major(&x, batch, n).data().to_vec()
        });
        // roundtrip stays exact too
        let tm = to_time_major(&x, batch, n);
        assert_eq!(to_sample_major(&tm, batch, n).data(), x.data());
    }
}

fn dp_toy_data(n: usize, seq: usize, seed: u64) -> (Vec<Tensor>, Vec<usize>) {
    let mut rng = Rng::new(seed);
    let mut xs = Vec::new();
    let mut ys = Vec::new();
    for i in 0..n {
        xs.push(Tensor::randn(&[seq, 1], 1.0, &mut rng));
        ys.push(i % 2);
    }
    (xs, ys)
}

fn dp_factory(seq: usize) -> impl Fn() -> (ParamStore, SeqClassifier) + Sync {
    move || {
        let mut store = ParamStore::new();
        let mut rng = Rng::new(7);
        let model =
            SeqClassifier::new(ModelKind::LmuParallel, seq, 1, 6, 12, 2, &mut store, &mut rng);
        (store, model)
    }
}

#[test]
fn data_parallel_step_respects_thread_budget() {
    // 4 replicas on a 2-thread budget: the replica fan-out runs as chunks
    // of one pool job and every nested kernel is serialized, so the
    // process must never have more than `threads` compute threads busy.
    let _k = knob_guard();
    exec::set_threads(2);
    exec::reset_pool_peak();
    let (xs, ys) = dp_toy_data(32, 16, 11);
    let shards = shard_dataset(xs, ys, 4);
    let mut opt = Adam::new(1e-3);
    let cfg = DataParallelConfig {
        workers: 4,
        epochs: 1,
        batch_size: 4,
        grad_clip: None,
        seed: 0,
        pipeline: false,
    };
    let res = DataParallelCoordinator::run(dp_factory(16), shards, &mut opt, &cfg);
    assert!(res.steps >= 1, "no steps ran");
    let peak = exec::pool_peak_concurrency();
    assert!(peak >= 1, "the pool never engaged during a data-parallel run");
    assert!(peak <= 2, "thread budget exceeded: peak {peak} busy > 2 configured");
    exec::set_threads(1);
}

#[test]
fn data_parallel_training_bit_equal_across_threads() {
    let _k = knob_guard();
    // whole data-parallel runs — replica fan-out, kernels, deterministic
    // all-reduce, Adam — must produce bit-identical final parameters at
    // every thread count
    assert_equal_across_threads("data-parallel final params", || {
        let (xs, ys) = dp_toy_data(16, 12, 3);
        let shards = shard_dataset(xs, ys, 2);
        let mut opt = Adam::new(1e-2);
        let cfg = DataParallelConfig {
            workers: 2,
            epochs: 1,
            batch_size: 4,
            grad_clip: Some(5.0),
            seed: 0,
            pipeline: false,
        };
        DataParallelCoordinator::run(dp_factory(12), shards, &mut opt, &cfg).final_params
    });
}

// --------------------------------------------------------- scheduler tests
// Hierarchical budgets + work stealing: deterministic sub-budget split,
// full-budget saturation under nested fan-out, nested panic propagation,
// and the 2-replica/8-thread data-parallel scenario the scheduler
// overhaul unblocks (previously every nested kernel serialized).

#[test]
fn hierarchical_budget_split_is_deterministic() {
    let _k = knob_guard();
    exec::set_threads(8);
    assert_eq!(exec::budget(), 8, "top-level budget is the global knob");
    let budgets = Mutex::new(vec![0usize; 2]);
    exec::parallel_ranges(2, exec::plan_for(2, usize::MAX), |lo, _| {
        budgets.lock().unwrap()[lo] = exec::budget();
        // nested plans are capped by the chunk's sub-budget, not the knob
        assert_eq!(exec::plan_for(100, usize::MAX).workers, exec::budget());
    });
    assert_eq!(
        *budgets.lock().unwrap(),
        vec![4, 4],
        "2 chunk slots on 8 threads get 4 threads' worth each"
    );
    // uneven split: the remainder goes to the lowest chunk indices
    exec::set_threads(7);
    let budgets = Mutex::new(vec![0usize; 2]);
    exec::parallel_ranges(2, exec::plan_for(2, usize::MAX), |lo, _| {
        budgets.lock().unwrap()[lo] = exec::budget();
    });
    assert_eq!(*budgets.lock().unwrap(), vec![4, 3]);
    // more chunks than budget: everything below runs serial, like before
    exec::set_threads(2);
    exec::parallel_ranges(4, exec::plan_for(4, usize::MAX), |_, _| {
        assert_eq!(exec::budget(), 1);
        assert!(exec::plan_for(100, usize::MAX).is_serial());
    });
    // run_serialized still pins the budget to 1
    exec::set_threads(8);
    exec::run_serialized(|| {
        assert_eq!(exec::budget(), 1);
        assert!(exec::plan_for(100, usize::MAX).is_serial());
    });
    exec::set_threads(1);
}

/// Spin (yielding) until `counter` reaches `target`; gives up after 10s
/// so a scheduler bug fails the calling assertion instead of hanging CI.
fn spin_until(counter: &AtomicUsize, target: usize) {
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    while counter.load(Ordering::Relaxed) < target {
        if std::time::Instant::now() > deadline {
            return;
        }
        std::thread::yield_now();
    }
}

#[test]
fn nested_fanout_saturates_thread_budget_exactly() {
    // 2 outer chunks on an 8-thread budget, each dispatching a nested
    // 4-chunk job: all 8 chunk slots must be occupied by 8 distinct
    // threads SIMULTANEOUSLY (the old scheduler pinned this at 2), and
    // never more than 8 — the hierarchical budget invariant, made
    // deterministic with barriers instead of timing luck.
    let _k = knob_guard();
    exec::set_threads(8);
    exec::reset_pool_peak();
    let top = AtomicUsize::new(0);
    let inner = AtomicUsize::new(0);
    exec::parallel_ranges(2, exec::plan_for(2, usize::MAX), |_, _| {
        top.fetch_add(1, Ordering::SeqCst);
        spin_until(&top, 2); // both replica slots running concurrently
        exec::parallel_ranges(4, exec::plan_for(4, usize::MAX), |_, _| {
            inner.fetch_add(1, Ordering::SeqCst);
            spin_until(&inner, 8); // all 8 nested chunks in flight at once
        });
    });
    let peak = exec::pool_peak_concurrency();
    assert_eq!(
        peak, 8,
        "nested fan-out should saturate exactly the 8-thread budget (got {peak})"
    );
    exec::set_threads(1);
}

#[test]
fn panic_in_nested_job_propagates_to_root_dispatcher() {
    let _k = knob_guard();
    exec::set_threads(4);
    let r = std::panic::catch_unwind(|| {
        exec::parallel_ranges(2, exec::plan_for(2, usize::MAX), |lo, _| {
            // each outer chunk has sub-budget 2, so this genuinely
            // dispatches a nested pool job whose chunk may be stolen
            exec::parallel_ranges(2, exec::plan_for(2, usize::MAX), |ilo, _| {
                if lo == 1 && ilo == 1 {
                    panic!("nested boom");
                }
            });
        });
    });
    assert!(r.is_err(), "nested panic was swallowed");
    // the pool must stay fully usable afterwards
    let v = exec::parallel_map(6, exec::plan_for(6, usize::MAX), |i| i * 2);
    assert_eq!(v, vec![0, 2, 4, 6, 8, 10]);
    exec::set_threads(1);
}

fn dp_wide_factory(seq: usize) -> impl Fn() -> (ParamStore, SeqClassifier) + Sync {
    // wide enough that per-replica kernels cross MIN_PARALLEL_WORK, so a
    // replica chunk with a sub-budget > 1 really fans its kernels out
    move || {
        let mut store = ParamStore::new();
        let mut rng = Rng::new(13);
        let model =
            SeqClassifier::new(ModelKind::LmuParallel, seq, 1, 8, 16, 2, &mut store, &mut rng);
        (store, model)
    }
}

#[test]
fn dp_two_replicas_on_eight_threads_bit_exact_and_budgeted() {
    // The acceptance scenario: a 2-replica data-parallel run on an
    // 8-thread budget.  Each replica chunk gets a sub-budget of 4 and its
    // nested kernels dispatch as first-class pool jobs (previously they
    // serialized), the busy-thread peak must stay within the configured
    // budget, and the final parameters must be bit-identical to the fully
    // serial run.
    let _k = knob_guard();
    let run = || {
        let (xs, ys) = dp_toy_data(16, 128, 21);
        let shards = shard_dataset(xs, ys, 2);
        let mut opt = Adam::new(1e-2);
        let cfg = DataParallelConfig {
            workers: 2,
            epochs: 4,
            batch_size: 8,
            grad_clip: Some(5.0),
            seed: 0,
            pipeline: false,
        };
        DataParallelCoordinator::run(dp_wide_factory(128), shards, &mut opt, &cfg)
    };
    exec::set_threads(1);
    let reference = run();
    exec::set_threads(8);
    exec::reset_pool_peak();
    let got = run();
    let peak = exec::pool_peak_concurrency();
    exec::set_threads(1);
    assert_eq!(reference.steps, got.steps, "step count changed with threads");
    assert!(reference.steps >= 4, "too few steps to exercise nesting");
    assert_eq!(reference.final_params.len(), got.final_params.len());
    for (i, (a, b)) in got.final_params.iter().zip(&reference.final_params).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "final param {i} differs under nested fan-out: {a} vs {b}"
        );
    }
    assert!(peak >= 2, "replica fan-out never engaged (peak {peak})");
    assert!(peak <= 8, "thread budget exceeded: peak {peak} busy > 8 configured");
}

// ------------------------------------------------- pipelined coordinator
// The async double-buffered pipeline: with `pipeline` off the coordinator
// is the PR 3 bulk-synchronous path (pinned above by
// `dp_two_replicas_on_eight_threads_bit_exact_and_budgeted`); with it on,
// the optimizer stage of step k overlaps batch k+1's replica job under
// one thread budget, and the staleness-1 schedule is deterministic.

#[test]
fn dp_pipelined_two_stages_in_flight_deterministic_and_budgeted() {
    // Acceptance scenario: 2 replicas, 8-thread budget, pipeline on.
    //  * the replica job is dispatched async with a 7-thread budget and
    //    the coordinator's optimizer stage keeps the reserved thread, so
    //    peak busy threads stay ≤ 8 with BOTH stages in flight;
    //  * two consecutive runs are bit-identical;
    //  * the schedule does not depend on the thread count: pipelined
    //    runs on 1, 2, and 8 threads match bit-for-bit (on one thread
    //    the same staleness-1 schedule runs its stages back-to-back).
    let _k = knob_guard();
    assert!(!DataParallelConfig::default().pipeline, "pipeline must default off");
    let run = || {
        let (xs, ys) = dp_toy_data(16, 128, 21);
        let shards = shard_dataset(xs, ys, 2);
        let mut opt = Adam::new(1e-2);
        let cfg = DataParallelConfig {
            workers: 2,
            epochs: 4,
            batch_size: 8,
            grad_clip: Some(5.0),
            seed: 0,
            pipeline: true,
        };
        DataParallelCoordinator::run(dp_wide_factory(128), shards, &mut opt, &cfg)
    };
    exec::set_threads(8);
    exec::reset_pool_peak();
    let a = run();
    let peak = exec::pool_peak_concurrency();
    let b = run();
    exec::set_threads(2);
    let c = run();
    exec::set_threads(1);
    let d = run();
    assert!(a.steps >= 4, "too few steps to exercise the pipeline ({})", a.steps);
    assert_eq!(a.steps, b.steps);
    assert_eq!(a.steps, c.steps, "step schedule changed with the thread count");
    assert_eq!(a.steps, d.steps, "step schedule changed on one thread");
    for (i, (x, y)) in a.final_params.iter().zip(&b.final_params).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "pipelined run not reproducible at param {i}: {x} vs {y}"
        );
    }
    for (i, (x, y)) in a.final_params.iter().zip(&c.final_params).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "pipelined run differs across thread counts at param {i}: {x} vs {y}"
        );
    }
    for (i, (x, y)) in a.final_params.iter().zip(&d.final_params).enumerate() {
        assert!(
            x.to_bits() == y.to_bits(),
            "pipelined run differs on one thread at param {i}: {x} vs {y}"
        );
    }
    for (x, y) in a.step_losses.iter().zip(&b.step_losses) {
        assert!(x.to_bits() == y.to_bits(), "pipelined losses not reproducible");
    }
    assert!(peak >= 2, "replica fan-out never engaged (peak {peak})");
    assert!(peak <= 8, "thread budget exceeded with two stages in flight: peak {peak} > 8");
}

#[test]
fn dp_pipelined_more_replicas_than_budget_stays_bounded() {
    // 4 replicas on a 2-thread budget, pipeline on: the async job gets a
    // 1-thread budget (each replica chunk serial inside) and the
    // coordinator keeps the other thread — the peak must stay ≤ 2 even
    // though two stages are in flight, and the run must still drain
    // deterministically.
    let _k = knob_guard();
    let run = || {
        let (xs, ys) = dp_toy_data(32, 16, 11);
        let shards = shard_dataset(xs, ys, 4);
        let mut opt = Adam::new(1e-3);
        let cfg = DataParallelConfig {
            workers: 4,
            epochs: 1,
            batch_size: 4,
            grad_clip: None,
            seed: 0,
            pipeline: true,
        };
        DataParallelCoordinator::run(dp_factory(16), shards, &mut opt, &cfg)
    };
    exec::set_threads(2);
    exec::reset_pool_peak();
    let a = run();
    let peak = exec::pool_peak_concurrency();
    let b = run();
    exec::set_threads(1);
    assert!(a.steps >= 1, "no steps ran");
    assert!(peak >= 1, "the pool never engaged");
    assert!(peak <= 2, "thread budget exceeded: peak {peak} busy > 2 configured");
    for (x, y) in a.final_params.iter().zip(&b.final_params) {
        assert!(x.to_bits() == y.to_bits(), "pipelined run not reproducible");
    }
}

#[test]
fn thread_knob_roundtrip() {
    let _k = knob_guard();
    exec::set_threads(5);
    assert_eq!(exec::threads(), 5);
    exec::set_threads(0); // clamped to 1
    assert_eq!(exec::threads(), 1);
    exec::set_threads(1);
}
