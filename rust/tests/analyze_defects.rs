//! Seeded-defect suite for the static analysis passes (`plmu analyze`):
//! each test constructs the exact defect a pass exists to catch — a
//! forward-referencing tape node, a wrong-arity fused op, a
//! double-release in the arena event stream, overlapping chunk ranges,
//! an over-budget pool event log — and asserts the checker flags it
//! with the right provenance.  The final test is the clean half of the
//! differential: the full `analyze_models` sweep (all four model
//! families x both DN paths, instrumentation forced to `PLMU_VERIFY=2`)
//! must come back with zero findings.
//!
//! The defect tests feed the checkers hand-built inputs only — no
//! global knobs — so they can run concurrently with the clean sweep.

use plmu::analyze::arena_check::{check_arena_log, ArenaEvent};
use plmu::analyze::exec_check::{check_pool_events, check_ranges, PoolEvent};
use plmu::analyze::tape::{verify, TapeNode, TapeOp, TapeView};

fn node(op: TapeOp, parents: Vec<usize>, shape: Vec<usize>) -> TapeNode {
    TapeNode { op, parents, shape, aux_shape: None }
}

// --------------------------------------------------------------- pass 1

/// A `NodeId` held across `Graph::reset()` shows up as a parent id >=
/// the node's own id on the next tape.
#[test]
fn forward_referencing_tape_node_is_caught() {
    let view = TapeView {
        nodes: vec![
            node(TapeOp::Leaf, vec![], vec![2, 3]),
            // parent 7 does not exist yet: a stale NodeId from the
            // previous recording
            node(TapeOp::Neg, vec![7], vec![2, 3]),
        ],
    };
    let findings = verify(&view);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].detail.contains("node 1 (Neg)"), "{}", findings[0]);
    assert!(findings[0].detail.contains("reset"), "{}", findings[0]);
}

/// A fused `Affine` rewrites `matmul -> add_row -> act`, so it must have
/// exactly three parents [x, w, bias]; two parents means the fusion
/// rewrite dropped an operand.
#[test]
fn wrong_arity_fused_op_is_caught() {
    let view = TapeView {
        nodes: vec![
            node(TapeOp::Leaf, vec![], vec![4, 3]),
            node(TapeOp::Leaf, vec![], vec![3, 5]),
            // missing the bias parent
            node(TapeOp::Affine { act: None }, vec![0, 1], vec![4, 5]),
        ],
    };
    let findings = verify(&view);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].detail.contains("node 2 (Affine)"), "{}", findings[0]);
    assert!(findings[0].detail.contains("arity 2"), "{}", findings[0]);
}

// --------------------------------------------------------------- pass 2

/// The same buffer identity reclaimed twice without an intervening
/// re-issue is a double-release — exactly the bug the recycler's
/// free-list scan assert exists for, caught here offline.
#[test]
fn double_release_event_log_is_caught() {
    const ARENA: u64 = 3;
    let events = [
        ArenaEvent::Issue { buf: 0xbeef0, bytes: 256, fresh: true },
        ArenaEvent::Reclaim { buf: 0xbeef0, bytes: 256, issued_by: Some(ARENA) },
        ArenaEvent::Reclaim { buf: 0xbeef0, bytes: 256, issued_by: Some(ARENA) },
    ];
    let report = check_arena_log(ARENA, &events, None);
    assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
    assert!(report.findings[0].detail.contains("double-release"), "{}", report.findings[0]);
}

/// A reclaim whose issuing arena differs from the replaying arena is the
/// `--pipeline` two-arenas-in-flight hazard.
#[test]
fn cross_arena_release_event_log_is_caught() {
    let events = [ArenaEvent::Reclaim { buf: 0xf00d0, bytes: 64, issued_by: Some(9) }];
    let report = check_arena_log(1, &events, None);
    assert_eq!(report.findings.len(), 1, "{:?}", report.findings);
    assert!(report.findings[0].detail.contains("cross-arena"), "{}", report.findings[0]);
}

// --------------------------------------------------------------- pass 3

/// Overlapping chunk ranges would alias two `&mut` sub-slices across
/// pool threads — the one memory-safety contract the `SendPtr` fan-out
/// rests on.
#[test]
fn overlapping_chunk_ranges_are_caught() {
    // [0,128) and [96,224) overlap by 32 elements
    let findings = check_ranges(224, &[(0, 128), (96, 224)]);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].detail.contains("overlap"), "{}", findings[0]);

    // the clean partition of the same buffer passes
    assert!(check_ranges(224, &[(0, 128), (128, 224)]).is_empty());
}

/// Concurrent chunk sub-budgets summing past the job's thread budget
/// means nested dispatches could oversubscribe the machine.
#[test]
fn over_budget_event_log_is_caught() {
    const JOB: u64 = 11;
    let events: Vec<(u64, PoolEvent)> = vec![
        (1, PoolEvent::JobBegin { job: JOB, chunks: 2, workers_cap: 2, budget: 2, root: 8 }),
        // both chunks claim a sub-budget of 2 concurrently: 4 > max(2, 2)
        (2, PoolEvent::ChunkStart { job: JOB, idx: 0, sub_budget: 2 }),
        (3, PoolEvent::ChunkStart { job: JOB, idx: 1, sub_budget: 2 }),
        (4, PoolEvent::ChunkEnd { job: JOB, idx: 0 }),
        (5, PoolEvent::ChunkEnd { job: JOB, idx: 1 }),
        (6, PoolEvent::JobEnd { job: JOB, panicked: false }),
    ];
    let findings = check_pool_events(&events);
    assert_eq!(findings.len(), 1, "{findings:?}");
    assert!(findings[0].detail.contains("budget"), "{}", findings[0]);
}

/// The same serialized log with legal sub-budgets (1 + 1 = budget) is
/// clean — the differential pair for the over-budget test.
#[test]
fn within_budget_event_log_is_clean() {
    const JOB: u64 = 12;
    let events: Vec<(u64, PoolEvent)> = vec![
        (1, PoolEvent::JobBegin { job: JOB, chunks: 2, workers_cap: 2, budget: 2, root: 8 }),
        (2, PoolEvent::ChunkStart { job: JOB, idx: 0, sub_budget: 1 }),
        (3, PoolEvent::ChunkStart { job: JOB, idx: 1, sub_budget: 1 }),
        (4, PoolEvent::ChunkEnd { job: JOB, idx: 0 }),
        (5, PoolEvent::ChunkEnd { job: JOB, idx: 1 }),
        (6, PoolEvent::JobEnd { job: JOB, panicked: false }),
    ];
    let findings = check_pool_events(&events);
    assert!(findings.is_empty(), "{findings:?}");
}

// ----------------------------------------------------------- clean half

/// The full sweep — every model family x both DN paths, three real
/// optimizer steps each under forced `PLMU_VERIFY=2`, tape + arena +
/// pool replay — must produce zero findings and non-vacuous evidence
/// (a single test so the process-global verify/scan knobs are not
/// flipped concurrently).
#[test]
fn clean_models_sweep_has_zero_findings() {
    let report = plmu::analyze::analyze_models();
    assert_eq!(report.cases.len(), 8, "4 families x 2 DN paths");
    assert_eq!(report.total_findings(), 0, "\n{}", report.render());
    for case in &report.cases {
        assert!(case.tape_nodes > 0, "{}: empty tape", case.case);
        assert!(case.arena_events > 0, "{}: no arena events recorded", case.case);
        assert!(case.partitions > 0, "{}: no chunk partitions validated", case.case);
        assert!(case.peak_live_bytes > 0, "{}: empty memory plan", case.case);
    }
}
