//! Differential-testing harness for the `plmu::simd` 8-lane kernel
//! layer: every vectorized kernel is A/B'd against a **naive scalar
//! reference written independently in this file**, over a deterministic
//! shape sweep that spans the lane-remainder cases (`8k-1`, `8k`,
//! `8k+1`), width 1, empty inputs, and the odd shapes
//! `exec_equivalence.rs` uses — asserting **bit-equality, not
//! tolerance**.
//!
//! The references implement the repo's canonical blocked accumulation
//! order (eight accumulators, element `i` into lane `i % 8`, zero-fill
//! tail identity, one fixed reduction tree — see `rust/src/simd/mod.rs`
//! and DESIGN.md) as the most obvious possible loops.  If either the
//! vector or the scalar production path ever drifts from that order —
//! a reassociated reduction, a sneaky FMA contraction, a changed tail —
//! the order-sensitive inputs here (±1e8 cancellation patterns, NaN/Inf
//! at lane boundaries) flip bits and the diff fails.
//!
//! The `PLMU_SIMD` knob is process-global, so the few tests that flip
//! it serialize on a mutex and restore the prior setting; everything
//! else calls the `_vec`/`_scalar` entry points directly and can run
//! concurrently.

use plmu::fft::{irfft_half, next_pow2, rfft_half, Cpx, Plan, RfftCache};
use plmu::simd;
use plmu::tensor::matmul::{affine_act, dot, matvec};
use plmu::tensor::packed::{gemm_path, set_gemm_path, GemmPath};
use plmu::tensor::Act;
use plmu::util::Rng;
use plmu::Tensor;
use std::sync::Mutex;

static SIMD_KNOB: Mutex<()> = Mutex::new(());

/// Run `f` under simd on and off (serialized on the knob mutex, prior
/// setting restored) and return both results for comparison.
fn with_knob_both<T>(f: impl Fn() -> T) -> (T, T) {
    let _guard = SIMD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let was = simd::enabled();
    simd::set_enabled(true);
    let on = f();
    simd::set_enabled(false);
    let off = f();
    simd::set_enabled(was);
    (on, off)
}

/// Run `f` under `PLMU_GEMM` packed and axpy (serialized on the same
/// process-global knob mutex, prior setting restored) and return
/// (packed, axpy) for comparison.
fn with_gemm_both<T>(f: impl Fn() -> T) -> (T, T) {
    let _guard = SIMD_KNOB.lock().unwrap_or_else(|e| e.into_inner());
    let was = gemm_path();
    set_gemm_path(GemmPath::Packed);
    let packed = f();
    set_gemm_path(GemmPath::Axpy);
    let axpy = f();
    set_gemm_path(was);
    (packed, axpy)
}

fn assert_bits_equal(label: &str, got: &[f32], want: &[f32]) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{label}: element {i} differs: {g} ({:#010x}) vs {w} ({:#010x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// Lengths spanning every lane-remainder class: 8k-1 / 8k / 8k+1 at
/// several scales, plus width 1 and empty.
const LENGTHS: &[usize] = &[0, 1, 2, 7, 8, 9, 15, 16, 17, 31, 32, 33, 63, 64, 65, 127, 128, 129, 1000];

/// Order-sensitive fill: large ±1e8 terms that cancel only if the
/// accumulation order is exactly the canonical one, mixed with
/// small-magnitude noise (1e8 + small rounds the small term away, so
/// any reassociation shows up in the bits).
fn order_sensitive(n: usize, rng: &mut Rng) -> Vec<f32> {
    (0..n)
        .map(|i| match i % 4 {
            0 => 1e8,
            2 => -1e8,
            _ => rng.normal_f32(0.0, 1.0),
        })
        .collect()
}

// ------------------------------------------------- canonical references

/// The canonical blocked dot, as naive loops: lane accumulators, tail
/// into the low lanes, fixed adjacent-pairs tree.
fn ref_dot(a: &[f32], b: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    for i in 0..a.len() {
        acc[i % 8] += a[i] * b[i];
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

fn ref_sum(xs: &[f32]) -> f32 {
    let mut acc = [0.0f32; 8];
    for (i, &x) in xs.iter().enumerate() {
        acc[i % 8] += x;
    }
    ((acc[0] + acc[1]) + (acc[2] + acc[3])) + ((acc[4] + acc[5]) + (acc[6] + acc[7]))
}

/// Canonical max: strict-greater rule per lane, fixed tree, -inf
/// identity.  NaN never wins; ties keep the earlier value.
fn ref_max(xs: &[f32]) -> f32 {
    fn gt(m: f32, v: f32) -> f32 {
        if v > m {
            v
        } else {
            m
        }
    }
    let mut acc = [f32::NEG_INFINITY; 8];
    for (i, &x) in xs.iter().enumerate() {
        acc[i % 8] = gt(acc[i % 8], x);
    }
    gt(gt(gt(acc[0], acc[1]), gt(acc[2], acc[3])), gt(gt(acc[4], acc[5]), gt(acc[6], acc[7])))
}

/// Naive triple-loop matmul with a plain sequential f32 accumulator —
/// the bit-reference for `matmul`/`matmul_tn`, whose per-element op
/// order is the p-ascending axpy sweep (elementwise adds, no blocked
/// reduction).
fn ref_matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = b.shape()[1];
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut s = 0.0f32;
            for p in 0..k {
                s += a.at2(i, p) * b.at2(p, j);
            }
            c.set2(i, j, s);
        }
    }
    c
}

/// Reference for `matmul_nt`/`matvec`: every output element is a
/// canonical blocked dot of two contiguous rows.
fn ref_matmul_nt(a: &Tensor, bt: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = bt.shape()[0];
    let mut c = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let s = ref_dot(&a.data()[i * k..(i + 1) * k], &bt.data()[j * k..(j + 1) * k]);
            c.set2(i, j, s);
        }
    }
    c
}

/// Canonical softmax row reference: blocked max, exp, blocked sum,
/// scale — the exact pass structure of `Tensor::softmax_rows`.
fn ref_softmax_row(row: &[f32]) -> Vec<f32> {
    let mx = ref_max(row);
    let mut out: Vec<f32> = row.iter().map(|v| (v - mx).exp()).collect();
    let inv = 1.0 / ref_sum(&out);
    for v in out.iter_mut() {
        *v *= inv;
    }
    out
}

// ------------------------------------------------------- reduction sweep

#[test]
fn dot_sum_max_match_reference_bit_for_bit() {
    let mut rng = Rng::new(100);
    for &n in LENGTHS {
        let a = order_sensitive(n, &mut rng);
        let b: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let label = format!("n={n}");

        let want = ref_dot(&a, &b);
        assert_eq!(simd::dot_vec(&a, &b).to_bits(), want.to_bits(), "dot_vec {label}");
        assert_eq!(simd::dot_scalar(&a, &b).to_bits(), want.to_bits(), "dot_scalar {label}");

        let want = ref_sum(&a);
        assert_eq!(simd::sum_vec(&a).to_bits(), want.to_bits(), "sum_vec {label}");
        assert_eq!(simd::sum_scalar(&a).to_bits(), want.to_bits(), "sum_scalar {label}");

        let want = ref_max(&a);
        assert_eq!(simd::max_vec(&a).to_bits(), want.to_bits(), "max_vec {label}");
        assert_eq!(simd::max_scalar(&a).to_bits(), want.to_bits(), "max_scalar {label}");
    }
    // the public dot entry (tensor::matmul::dot) routes through the
    // same canonical kernel under both knob settings
    let a = order_sensitive(129, &mut rng);
    let b = order_sensitive(129, &mut rng);
    let (on, off) = with_knob_both(|| dot(&a, &b));
    assert_eq!(on.to_bits(), off.to_bits(), "dot dispatch differs across the knob");
    assert_eq!(on.to_bits(), ref_dot(&a, &b).to_bits());
}

#[test]
fn max_edge_cases_are_deterministic() {
    // duplicates, signed zeros, empty: the strict-greater rule keeps
    // the earliest occurrence and both paths agree with the reference
    for xs in [
        vec![],
        vec![-0.0f32, 0.0],
        vec![0.0f32, -0.0],
        vec![7.5f32; 20],
        vec![f32::NEG_INFINITY; 9],
        vec![-1.0f32, f32::NEG_INFINITY, -2.0],
    ] {
        let want = ref_max(&xs);
        assert_eq!(simd::max_vec(&xs).to_bits(), want.to_bits(), "{xs:?}");
        assert_eq!(simd::max_scalar(&xs).to_bits(), want.to_bits(), "{xs:?}");
    }
}

// ----------------------------------------------------- elementwise sweep

#[test]
fn elementwise_kernels_match_plain_loops_bit_for_bit() {
    let mut rng = Rng::new(101);
    for &n in LENGTHS {
        let a: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 10.0)).collect();
        let mut b: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 0.1)).collect();
        // salt with special values at lane-boundary positions
        for (pos, v) in [(0usize, -0.0f32), (7, f32::MIN_POSITIVE / 2.0), (8, 1e38)] {
            if pos < n {
                b[pos] = v;
            }
        }
        let label = format!("n={n}");

        type Slice3 = fn(&[f32], &[f32], &mut [f32]);
        type Binary = (&'static str, Slice3, Slice3, fn(f32, f32) -> f32);
        // both paths explicitly (never through the global knob, so
        // coverage is deterministic under any PLMU_SIMD setting)
        let cases: [Binary; 4] = [
            ("add", simd::add_vec, simd::add_scalar, |x, y| x + y),
            ("sub", simd::sub_vec, simd::sub_scalar, |x, y| x - y),
            ("mul", simd::mul_vec, simd::mul_scalar, |x, y| x * y),
            ("div", simd::div_vec, simd::div_scalar, |x, y| x / y),
        ];
        for (name, kvec, kscalar, op) in cases {
            let want: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| op(x, y)).collect();
            let mut got = vec![0.0f32; n];
            kvec(&a, &b, &mut got);
            assert_bits_equal(&format!("{name}_vec {label}"), &got, &want);
            let mut got = vec![0.0f32; n];
            kscalar(&a, &b, &mut got);
            assert_bits_equal(&format!("{name}_scalar {label}"), &got, &want);
        }

        // axpy and add_assign mutate in place
        let alpha = 1.7f32;
        let mut got = a.clone();
        simd::axpy_vec(alpha, &b, &mut got);
        let mut want = a.clone();
        for (w, &x) in want.iter_mut().zip(&b) {
            *w += alpha * x;
        }
        assert_bits_equal(&format!("axpy_vec {label}"), &got, &want);
        let mut got = a.clone();
        simd::axpy_scalar(alpha, &b, &mut got);
        assert_bits_equal(&format!("axpy_scalar {label}"), &got, &want);

        let want: Vec<f32> = a.iter().zip(&b).map(|(&x, &y)| x + y).collect();
        let mut got = a.clone();
        simd::add_assign_vec(&mut got, &b);
        assert_bits_equal(&format!("add_assign_vec {label}"), &got, &want);
        let mut got = a.clone();
        simd::add_assign_scalar(&mut got, &b);
        assert_bits_equal(&format!("add_assign_scalar {label}"), &got, &want);

        let want: Vec<f32> = a.iter().map(|&x| x * 0.3).collect();
        let mut got = a.clone();
        simd::scale_assign_vec(&mut got, 0.3);
        assert_bits_equal(&format!("scale_assign_vec {label}"), &got, &want);
        let mut got = a.clone();
        simd::scale_assign_scalar(&mut got, 0.3);
        assert_bits_equal(&format!("scale_assign_scalar {label}"), &got, &want);
        let mut got2 = vec![0.0f32; n];
        simd::scale_vec(&a, 0.3, &mut got2);
        assert_bits_equal(&format!("scale_vec {label}"), &got2, &want);
        let mut got2 = vec![0.0f32; n];
        simd::scale_scalar(&a, 0.3, &mut got2);
        assert_bits_equal(&format!("scale_scalar {label}"), &got2, &want);
    }
}

#[test]
fn activation_kernels_match_plain_loops_bit_for_bit() {
    // the tanh/relu forward, backward, and in-place kernels the fused
    // epilogues dispatch to: both explicit paths against naive loops
    // written here, including NaN/Inf/-0.0 salted at lane seams
    let mut rng = Rng::new(109);
    for &n in LENGTHS {
        let mut x: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 2.0)).collect();
        let g: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for (pos, v) in
            [(0usize, f32::NAN), (7, -0.0f32), (8, f32::INFINITY), (15, f32::NEG_INFINITY)]
        {
            if pos < n {
                x[pos] = v;
            }
        }
        let label = format!("n={n}");

        let want: Vec<f32> = x.iter().map(|v| v.tanh()).collect();
        let mut got = vec![0.0f32; n];
        simd::tanh_fwd_vec(&x, &mut got);
        assert_bits_equal(&format!("tanh_fwd_vec {label}"), &got, &want);
        let mut got = vec![0.0f32; n];
        simd::tanh_fwd_scalar(&x, &mut got);
        assert_bits_equal(&format!("tanh_fwd_scalar {label}"), &got, &want);
        let mut got = x.clone();
        simd::tanh_assign_vec(&mut got);
        assert_bits_equal(&format!("tanh_assign_vec {label}"), &got, &want);
        let mut got = x.clone();
        simd::tanh_assign_scalar(&mut got);
        assert_bits_equal(&format!("tanh_assign_scalar {label}"), &got, &want);

        // canonical relu: strict-greater against zero, NaN/-0.0 -> +0.0
        let want: Vec<f32> = x.iter().map(|&v| if v > 0.0 { v } else { 0.0 }).collect();
        let mut got = vec![0.0f32; n];
        simd::relu_fwd_vec(&x, &mut got);
        assert_bits_equal(&format!("relu_fwd_vec {label}"), &got, &want);
        let mut got = vec![0.0f32; n];
        simd::relu_fwd_scalar(&x, &mut got);
        assert_bits_equal(&format!("relu_fwd_scalar {label}"), &got, &want);
        let mut got = x.clone();
        simd::relu_assign_vec(&mut got);
        assert_bits_equal(&format!("relu_assign_vec {label}"), &got, &want);
        let mut got = x.clone();
        simd::relu_assign_scalar(&mut got);
        assert_bits_equal(&format!("relu_assign_scalar {label}"), &got, &want);

        // backward: dtanh = g * (1 - y^2) on post-activation y,
        // drelu = g * [x > 0] (0 · NaN g still propagates NaN)
        let y: Vec<f32> = x.iter().map(|v| v.tanh()).collect();
        let want: Vec<f32> = g.iter().zip(&y).map(|(&gv, &yv)| gv * (1.0 - yv * yv)).collect();
        let mut got = vec![0.0f32; n];
        simd::tanh_bwd_vec(&g, &y, &mut got);
        assert_bits_equal(&format!("tanh_bwd_vec {label}"), &got, &want);
        let mut got = vec![0.0f32; n];
        simd::tanh_bwd_scalar(&g, &y, &mut got);
        assert_bits_equal(&format!("tanh_bwd_scalar {label}"), &got, &want);

        let want: Vec<f32> =
            g.iter().zip(&x).map(|(&gv, &xv)| gv * if xv > 0.0 { 1.0 } else { 0.0 }).collect();
        let mut got = vec![0.0f32; n];
        simd::relu_bwd_vec(&g, &x, &mut got);
        assert_bits_equal(&format!("relu_bwd_vec {label}"), &got, &want);
        let mut got = vec![0.0f32; n];
        simd::relu_bwd_scalar(&g, &x, &mut got);
        assert_bits_equal(&format!("relu_bwd_scalar {label}"), &got, &want);
    }
}

#[test]
fn tensor_elementwise_ops_stable_across_the_knob() {
    // the Tensor-level entries (exec partition + simd block kernels):
    // big enough to cross MIN_PARALLEL_WORK, odd element count
    let mut rng = Rng::new(102);
    let x = Tensor::randn(&[129, 131], 1.0, &mut rng);
    let y = Tensor::randn(&[129, 131], 1.0, &mut rng);
    let cases: Vec<(&str, Box<dyn Fn() -> Tensor + '_>)> = vec![
        ("add", Box::new(|| x.add(&y))),
        ("sub", Box::new(|| x.sub(&y))),
        ("mul", Box::new(|| x.mul(&y))),
        ("div", Box::new(|| x.div(&y))),
        ("scale", Box::new(|| x.scale(0.125))),
        ("add_row", Box::new(|| x.add_row(&y.row(0)))),
        ("tanh", Box::new(|| x.tanh())),
        ("relu", Box::new(|| x.relu())),
        ("softmax", Box::new(|| x.softmax_rows())),
    ];
    for (name, f) in &cases {
        let (on, off) = with_knob_both(f);
        assert_bits_equal(&format!("Tensor::{name} knob"), on.data(), off.data());
    }
}

// --------------------------------------------------------- matmul family

#[test]
fn matmul_family_matches_references_bit_for_bit() {
    let mut rng = Rng::new(103);
    // the exec_equivalence odd shapes plus lane-remainder widths
    // (n = 8k-1 / 8k / 8k+1 / 1) and empty dimensions
    let shapes: &[(usize, usize, usize)] = &[
        (129, 67, 65),
        (7, 300, 5),
        (1, 1, 1),
        (3, 2, 1),
        (5, 16, 7),
        (5, 16, 8),
        (5, 16, 9),
        (4, 23, 1),
        (2, 0, 3),
        (0, 3, 4),
        (3, 4, 0),
    ];
    for &(m, k, n) in shapes {
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let at = a.transpose2();
        let bt = b.transpose2();
        let label = format!("({m},{k},{n})");

        let want = ref_matmul(&a, &b);
        let (on, off) = with_knob_both(|| a.matmul(&b));
        assert_bits_equal(&format!("matmul {label} knob"), on.data(), off.data());
        assert_bits_equal(&format!("matmul {label} vs naive"), on.data(), want.data());

        let (on, off) = with_knob_both(|| at.matmul_tn(&b));
        assert_bits_equal(&format!("matmul_tn {label} knob"), on.data(), off.data());
        assert_bits_equal(&format!("matmul_tn {label} vs naive"), on.data(), want.data());

        let want_nt = ref_matmul_nt(&a, &bt);
        let (on, off) = with_knob_both(|| a.matmul_nt(&bt));
        assert_bits_equal(&format!("matmul_nt {label} knob"), on.data(), off.data());
        assert_bits_equal(&format!("matmul_nt {label} vs blocked-dot ref"), on.data(), want_nt.data());

        if k > 0 && n > 0 {
            let x: Vec<f32> = (0..k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let (on, off) = with_knob_both(|| matvec(&a, &x));
            assert_bits_equal(&format!("matvec {label} knob"), &on, &off);
            let want: Vec<f32> = (0..m)
                .map(|i| ref_dot(&a.data()[i * k..(i + 1) * k], &x))
                .collect();
            assert_bits_equal(&format!("matvec {label} vs blocked-dot ref"), &on, &want);
        }
    }
}

// ----------------------------------------------- NaN/Inf lane-tail suite
//
// Extends the PR 3 `0·NaN` regression suite to the blocked accumulation
// order: non-finite values sitting in the last partial lane and at lane
// boundaries must propagate exactly as in the canonical scalar
// reference.

/// Positions that straddle the lane structure of a length-`n` buffer:
/// first/last lane of the first block, the 8k-1/8k boundary, and the
/// lane tail (last element, which lives in a partial block whenever
/// `n % 8 != 0`).
fn lane_boundary_positions(n: usize) -> Vec<usize> {
    let mut ps = vec![0, 7, 8, 15, 16];
    if n > 0 {
        ps.push(n - 1);
        ps.push((n / 8) * 8); // first lane of the tail block
    }
    ps.retain(|&p| p < n);
    ps.sort_unstable();
    ps.dedup();
    ps
}

#[test]
fn nan_inf_in_lane_tails_propagate_like_the_reference() {
    let mut rng = Rng::new(104);
    for &n in &[7usize, 8, 9, 17, 23, 24, 25, 65] {
        let base: Vec<f32> = (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        for bad in [f32::NAN, f32::INFINITY, f32::NEG_INFINITY] {
            for pos in lane_boundary_positions(n) {
                let mut a = base.clone();
                a[pos] = bad;
                let b: Vec<f32> = (0..n).map(|i| (i as f32) * 0.5 - 1.0).collect();
                let label = format!("n={n} pos={pos} bad={bad}");

                let want = ref_dot(&a, &b);
                let (von, voff) = (simd::dot_vec(&a, &b), simd::dot_scalar(&a, &b));
                assert_eq!(von.to_bits(), want.to_bits(), "dot_vec {label}");
                assert_eq!(voff.to_bits(), want.to_bits(), "dot_scalar {label}");

                let want = ref_sum(&a);
                assert_eq!(simd::sum_vec(&a).to_bits(), want.to_bits(), "sum_vec {label}");
                assert_eq!(simd::sum_scalar(&a).to_bits(), want.to_bits(), "sum_scalar {label}");

                let want = ref_max(&a);
                assert_eq!(simd::max_vec(&a).to_bits(), want.to_bits(), "max_vec {label}");
                assert_eq!(simd::max_scalar(&a).to_bits(), want.to_bits(), "max_scalar {label}");

                // NaN/Inf alpha sweeps through the whole axpy row
                let mut got = base.clone();
                simd::axpy_vec(bad, &b, &mut got);
                let mut want_row = base.clone();
                for (w, &x) in want_row.iter_mut().zip(&b) {
                    *w += bad * x;
                }
                assert_bits_equal(&format!("axpy alpha {label}"), &got, &want_row);
            }
        }
    }
}

#[test]
fn matmul_zero_skip_gate_survives_lane_tail_nan() {
    // NaN placed in B's final element (the lane tail of the last row):
    // the all_finite gate must disable the zero skip so 0 · NaN = NaN
    // exactly like the naive reference, at every knob setting
    let mut rng = Rng::new(105);
    let (m, k, n) = (5usize, 9usize, 7usize); // odd everything
    let mut a = Tensor::randn(&[m, k], 1.0, &mut rng);
    // zeros exactly where the unconditional skip would drop NaN columns
    for (i, v) in a.data_mut().iter_mut().enumerate() {
        if i % 3 == 0 {
            *v = 0.0;
        }
    }
    for bad_pos in [k * n - 1, (k - 1) * n, n - 1, 8, 7] {
        let mut b = Tensor::randn(&[k, n], 1.0, &mut rng);
        b.data_mut()[bad_pos] = f32::NAN;
        let want = ref_matmul(&a, &b);
        let (on, off) = with_knob_both(|| a.matmul(&b));
        for (x, y) in on.data().iter().zip(off.data()) {
            assert!(
                x.to_bits() == y.to_bits(),
                "matmul knob mismatch with NaN at {bad_pos}: {x} vs {y}"
            );
        }
        for (i, (x, y)) in on.data().iter().zip(want.data()).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "matmul elem {i} with NaN at {bad_pos}: {x} vs naive {y}"
            );
        }
        // and the gate itself agrees across paths
        assert!(!simd::all_finite_vec(b.data()));
        assert!(!simd::all_finite_scalar(b.data()));
    }
}

#[test]
fn argmax_rows_total_at_lane_boundaries() {
    // argmax stays scalar, but its NaN totality must hold wherever the
    // blocked kernels put lane seams: NaN at positions 7/8/tail never
    // wins, ties keep the lowest index, an all-NaN row yields 0
    let c = 17usize;
    let mut data = vec![0.5f32; c * 4];
    // row 0: NaN at lane boundary 7, max at the tail position
    data[7] = f32::NAN;
    data[16] = 9.0;
    // row 1: NaN in the lane tail (last element), max at 8
    data[c + 8] = 3.0;
    data[c + 16] = f32::NAN;
    // row 2: all NaN
    for v in data[2 * c..3 * c].iter_mut() {
        *v = f32::NAN;
    }
    // row 3: tie straddling the 8-boundary keeps the lower index
    data[3 * c + 7] = 4.0;
    data[3 * c + 8] = 4.0;
    let t = Tensor::new(&[4, c], data);
    assert_eq!(t.argmax_rows(), vec![16, 8, 0, 7]);
}

#[test]
fn softmax_rows_match_canonical_reference_including_nan_inf_tails() {
    let mut rng = Rng::new(106);
    for &c in &[1usize, 7, 8, 9, 17, 33] {
        let rows = 5usize;
        let mut t = Tensor::randn(&[rows, c], 2.0, &mut rng);
        // row 1 gets a NaN in its lane tail, row 2 an Inf at a boundary
        if c > 1 {
            t.set2(1, c - 1, f32::NAN);
            let boundary = ((c / 8) * 8).min(c - 1);
            t.set2(2, boundary, f32::INFINITY);
        }
        let (on, off) = with_knob_both(|| t.softmax_rows());
        assert_bits_equal(&format!("softmax c={c} knob"), on.data(), off.data());
        for r in 0..rows {
            let want = ref_softmax_row(&t.data()[r * c..(r + 1) * c]);
            let got = &on.data()[r * c..(r + 1) * c];
            for (i, (g, w)) in got.iter().zip(&want).enumerate() {
                assert!(
                    g.to_bits() == w.to_bits(),
                    "softmax c={c} row {r} elem {i}: {g} vs {w}"
                );
            }
        }
    }
}

// ------------------------------------------------------ fft complex MAC

#[test]
fn spectrum_product_stable_across_the_knob_and_matches_cpx_mul() {
    let mut rng = Rng::new(107);
    // kernel/signal lengths spanning complex-pair remainders of the
    // 4-pair blocks
    for &len in &[3usize, 4, 5, 31, 32, 33, 100] {
        let kernel: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let sig: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let nfft = next_pow2(2 * len);
        let cache = RfftCache::new(&kernel, nfft);
        let (on, off) = with_knob_both(|| cache.conv(&sig, len));
        assert_bits_equal(&format!("conv len={len} knob"), &on, &off);
    }
    // the raw kernel against the Cpx::mul formula, bitwise
    let n = 9usize;
    let a: Vec<Cpx> = (0..n).map(|_| Cpx::new(rng.normal(), rng.normal())).collect();
    let b: Vec<Cpx> = (0..n).map(|_| Cpx::new(rng.normal(), rng.normal())).collect();
    let af: Vec<f64> = a.iter().flat_map(|c| [c.re, c.im]).collect();
    let bf: Vec<f64> = b.iter().flat_map(|c| [c.re, c.im]).collect();
    let mut got = vec![0.0f64; 2 * n];
    simd::cmul_vec(&af, &bf, &mut got);
    let mut got_s = vec![0.0f64; 2 * n];
    simd::cmul_scalar(&af, &bf, &mut got_s);
    for k in 0..n {
        let want = a[k].mul(b[k]);
        assert_eq!(got[2 * k].to_bits(), want.re.to_bits(), "re {k}");
        assert_eq!(got[2 * k + 1].to_bits(), want.im.to_bits(), "im {k}");
        assert_eq!(got[2 * k].to_bits(), got_s[2 * k].to_bits());
        assert_eq!(got[2 * k + 1].to_bits(), got_s[2 * k + 1].to_bits());
    }
}

// ------------------------------------------------------ f64 kernel sweep
//
// The F64x4 kernel triples behind the FFT butterflies and spectrum
// products, A/B'd against naive Cpx-formula references written here,
// over pair counts spanning every 2-pair-block remainder (4 f64 lanes =
// 2 complex pairs per block) and the 8k-1 / 8k / 8k+1 lane classes.

fn assert_bits_equal_f64(label: &str, got: &[f64], want: &[f64]) {
    assert_eq!(got.len(), want.len(), "{label}: length mismatch");
    for (i, (g, w)) in got.iter().zip(want).enumerate() {
        assert!(
            g.to_bits() == w.to_bits(),
            "{label}: element {i} differs: {g} ({:#018x}) vs {w} ({:#018x})",
            g.to_bits(),
            w.to_bits()
        );
    }
}

/// Complex pair counts: every remainder class of the 2-pair vector
/// blocks, plus 8k-1 / 8k / 8k+1 in f64-lane terms (pairs 3/4/5 give
/// lane counts 6/8/10 etc.), empty, and a long tail.
const PAIR_COUNTS: &[usize] = &[0, 1, 2, 3, 4, 5, 7, 8, 9, 15, 16, 17, 31, 32, 33, 500];

/// Interleaved (re, im) buffer with NaN/Inf salted at block seams.
fn cpx_buf(pairs: usize, rng: &mut Rng, salt: bool) -> Vec<f64> {
    let mut v: Vec<f64> = (0..2 * pairs).map(|_| rng.normal()).collect();
    if salt {
        for (pos, bad) in [(0usize, f64::NAN), (3, f64::INFINITY), (4, f64::NEG_INFINITY), (2 * pairs - 1, f64::NAN)] {
            if pos < v.len() {
                v[pos] = bad;
            }
        }
    }
    v
}

#[test]
fn f64_cmul_and_conj_cmul_match_cpx_formulas_bit_for_bit() {
    let mut rng = Rng::new(110);
    for &pairs in PAIR_COUNTS {
        for salt in [false, true] {
            let a = cpx_buf(pairs, &mut rng, salt);
            let b = cpx_buf(pairs, &mut rng, salt);
            let label = format!("pairs={pairs} salt={salt}");

            // cmul: (ar + i·ai)(br + i·bi)
            let mut want = vec![0.0f64; 2 * pairs];
            for p in 0..pairs {
                let (ar, ai, br, bi) = (a[2 * p], a[2 * p + 1], b[2 * p], b[2 * p + 1]);
                want[2 * p] = ar * br - ai * bi;
                want[2 * p + 1] = ar * bi + ai * br;
            }
            let mut got = vec![0.0f64; 2 * pairs];
            simd::cmul_vec(&a, &b, &mut got);
            assert_bits_equal_f64(&format!("cmul_vec {label}"), &got, &want);
            let mut got = vec![0.0f64; 2 * pairs];
            simd::cmul_scalar(&a, &b, &mut got);
            assert_bits_equal_f64(&format!("cmul_scalar {label}"), &got, &want);

            // conj_cmul: conj(a) · b
            for p in 0..pairs {
                let (ar, ai, br, bi) = (a[2 * p], a[2 * p + 1], b[2 * p], b[2 * p + 1]);
                want[2 * p] = ar * br + ai * bi;
                want[2 * p + 1] = ar * bi - ai * br;
            }
            let mut got = vec![0.0f64; 2 * pairs];
            simd::conj_cmul_vec(&a, &b, &mut got);
            assert_bits_equal_f64(&format!("conj_cmul_vec {label}"), &got, &want);
            let mut got = vec![0.0f64; 2 * pairs];
            simd::conj_cmul_scalar(&a, &b, &mut got);
            assert_bits_equal_f64(&format!("conj_cmul_scalar {label}"), &got, &want);

            // cmul_add: out += a · b, accumulator on the add's left
            let base = cpx_buf(pairs, &mut rng, false);
            let mut want_acc = base.clone();
            for p in 0..pairs {
                let (ar, ai, br, bi) = (a[2 * p], a[2 * p + 1], b[2 * p], b[2 * p + 1]);
                want_acc[2 * p] += ar * br - ai * bi;
                want_acc[2 * p + 1] += ar * bi + ai * br;
            }
            let mut got = base.clone();
            simd::cmul_add_vec(&a, &b, &mut got);
            assert_bits_equal_f64(&format!("cmul_add_vec {label}"), &got, &want_acc);
            let mut got = base.clone();
            simd::cmul_add_scalar(&a, &b, &mut got);
            assert_bits_equal_f64(&format!("cmul_add_scalar {label}"), &got, &want_acc);
        }
    }
}

#[test]
fn f64_butterfly_matches_cpx_formula_bit_for_bit() {
    let mut rng = Rng::new(111);
    for &pairs in PAIR_COUNTS {
        for salt in [false, true] {
            let tw = cpx_buf(pairs, &mut rng, salt);
            let lo0 = cpx_buf(pairs, &mut rng, salt);
            let hi0 = cpx_buf(pairs, &mut rng, false);
            let label = format!("pairs={pairs} salt={salt}");

            // b = hi · tw; lo = a + b; hi = a - b (a = old lo)
            let mut want_lo = lo0.clone();
            let mut want_hi = hi0.clone();
            for p in 0..pairs {
                let (hr, hi_) = (hi0[2 * p], hi0[2 * p + 1]);
                let (tr, ti) = (tw[2 * p], tw[2 * p + 1]);
                let br = hr * tr - hi_ * ti;
                let bi = hr * ti + hi_ * tr;
                let (ar, ai) = (lo0[2 * p], lo0[2 * p + 1]);
                want_lo[2 * p] = ar + br;
                want_lo[2 * p + 1] = ai + bi;
                want_hi[2 * p] = ar - br;
                want_hi[2 * p + 1] = ai - bi;
            }
            let (mut lo, mut hi) = (lo0.clone(), hi0.clone());
            simd::butterfly_vec(&tw, &mut lo, &mut hi);
            assert_bits_equal_f64(&format!("butterfly_vec lo {label}"), &lo, &want_lo);
            assert_bits_equal_f64(&format!("butterfly_vec hi {label}"), &hi, &want_hi);
            let (mut lo, mut hi) = (lo0.clone(), hi0.clone());
            simd::butterfly_scalar(&tw, &mut lo, &mut hi);
            assert_bits_equal_f64(&format!("butterfly_scalar lo {label}"), &lo, &want_lo);
            assert_bits_equal_f64(&format!("butterfly_scalar hi {label}"), &hi, &want_hi);
        }
    }
}

#[test]
fn fft_plan_and_real_transforms_stable_across_the_knob() {
    // whole transforms through the public entry points: the vectorized
    // butterflies and the rfft_half/irfft_half pack/unpack kernels must
    // change no bits when PLMU_SIMD flips
    let mut rng = Rng::new(112);
    for &n in &[2usize, 8, 64, 256] {
        let sig: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let (on, off) = with_knob_both(|| {
            let p = Plan::new(n);
            let mut buf: Vec<Cpx> = sig.iter().map(|&v| Cpx::new(v, 0.0)).collect();
            p.forward(&mut buf);
            let mut rt = buf.clone();
            p.inverse(&mut rt);
            (buf, rt)
        });
        for (a, b) in on.0.iter().zip(&off.0).chain(on.1.iter().zip(&off.1)) {
            assert!(a.re.to_bits() == b.re.to_bits() && a.im.to_bits() == b.im.to_bits(), "plan n={n} knob");
        }
    }
    for &len in &[1usize, 5, 17, 100] {
        let sig: Vec<f32> = (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let nfft = next_pow2(2 * len);
        let (on, off) = with_knob_both(|| {
            let spec = rfft_half(&sig, nfft);
            let back = irfft_half(&spec, nfft, len);
            (spec, back)
        });
        for (a, b) in on.0.iter().zip(&off.0) {
            assert!(a.re.to_bits() == b.re.to_bits() && a.im.to_bits() == b.im.to_bits(), "rfft_half len={len} knob");
        }
        assert_bits_equal(&format!("irfft_half len={len} knob"), &on.1, &off.1);
    }
}

// ----------------------------------------------------- PLMU_GEMM matrix
//
// The packed GEMM path must be bit-identical to the axpy path at every
// entry point, over degenerate and lane-remainder shapes, with NaN/Inf
// in B (the packed path has no zero-skip — it must match both outcomes
// of the axpy gate), and through gradients (backprop routes through
// matmul_tn / matmul_nt, so a full autograd chain pins all of them).

#[test]
fn matmul_family_bit_equal_across_gemm_paths() {
    let mut rng = Rng::new(113);
    let shapes: &[(usize, usize, usize)] = &[
        (129, 67, 65),
        (7, 300, 5),
        (1, 1, 1),
        (5, 16, 7),
        (5, 16, 8),
        (5, 16, 9),
        (8, 257, 16),
        (9, 300, 33),
        (2, 0, 3),
        (0, 3, 4),
        (3, 4, 0),
    ];
    for &(m, k, n) in shapes {
        for salt in [false, true] {
            let mut a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let mut b = Tensor::randn(&[k, n], 1.0, &mut rng);
            // zeros in A tempt the axpy zero-skip; non-finite B disables
            // its gate — the packed path must match either way
            for (i, v) in a.data_mut().iter_mut().enumerate() {
                if i % 3 == 0 {
                    *v = 0.0;
                }
            }
            if salt && !b.data().is_empty() {
                let bl = b.len();
                b.data_mut()[0] = f32::NAN;
                b.data_mut()[bl - 1] = f32::INFINITY;
            }
            let at = a.transpose2();
            let bt = b.transpose2();
            let bias = Tensor::randn(&[n], 0.1, &mut rng);
            let label = format!("({m},{k},{n}) salt={salt}");

            let (p, x) = with_gemm_both(|| a.matmul(&b));
            assert_bits_equal(&format!("matmul {label} gemm"), p.data(), x.data());
            let (p, x) = with_gemm_both(|| at.matmul_tn(&b));
            assert_bits_equal(&format!("matmul_tn {label} gemm"), p.data(), x.data());
            let (p, x) = with_gemm_both(|| a.matmul_nt(&bt));
            assert_bits_equal(&format!("matmul_nt {label} gemm"), p.data(), x.data());
            let (p, x) = with_gemm_both(|| affine_act(&a, &b, &bias, Some(Act::Tanh)));
            assert_bits_equal(&format!("affine_act {label} gemm"), p.data(), x.data());
            if k > 0 {
                let xv: Vec<f32> = (0..k).map(|_| rng.normal_f32(0.0, 1.0)).collect();
                let (p, x) = with_gemm_both(|| matvec(&a, &xv));
                assert_bits_equal(&format!("matvec {label} gemm"), &p, &x);
            }
        }
    }
}

#[test]
fn gradients_bit_equal_across_gemm_paths() {
    use plmu::autograd::{Graph, ParamStore};
    // forward affine_act routes matmul; backward routes matmul_tn (dW)
    // and matmul_nt (dX) — one chain pins values AND gradients across
    // the knob, at a k spanning multiple KC panels and ragged n
    for &(m, k, n) in &[(3usize, 5usize, 7usize), (17, 300, 9), (8, 64, 33)] {
        let mut rng = Rng::new((m + 10 * k + 1000 * n) as u64);
        let mut store = ParamStore::new();
        let x = store.add("x", Tensor::randn(&[m, k], 1.0, &mut rng));
        let w = store.add("w", Tensor::randn(&[k, n], 0.5, &mut rng));
        let b = store.add("b", Tensor::randn(&[n], 0.1, &mut rng));
        let (p, ax) = with_gemm_both(|| {
            let mut g = Graph::new();
            let (xn, wn, bn) = (g.param(&store, x), g.param(&store, w), g.param(&store, b));
            let o = g.affine_act(xn, wn, bn, Some(plmu::autograd::Act::Tanh));
            let sq = g.mul(o, o);
            let loss = g.mean_all(sq);
            g.backward(loss);
            let mut flat = g.value(o).data().to_vec();
            for (_, grad) in g.param_grads() {
                flat.extend_from_slice(grad.data());
            }
            flat
        });
        assert_bits_equal(&format!("affine grads ({m},{k},{n}) gemm"), &p, &ax);
    }
}

// ------------------------------------------------------- composite sweep

#[test]
fn dn_fft_operator_apply_stable_across_the_knob() {
    // end-to-end composite (matmul + elementwise + FFT conv): the DN
    // operator's output must be bit-identical with the vector paths on
    // and off — the kernel-level guarantee composed through the system
    use plmu::dn::{DelayNetwork, DnFftOperator};
    let mut rng = Rng::new(108);
    for &(n, d, du) in &[(65usize, 9usize, 3usize), (64, 8, 1), (33, 4, 2)] {
        let dn = DelayNetwork::new(d, n as f64);
        let op = DnFftOperator::new(&dn, n);
        let u = Tensor::randn(&[n, du], 1.0, &mut rng);
        let (on, off) = with_knob_both(|| op.apply(&u));
        assert_bits_equal(&format!("dn apply ({n},{d},{du}) knob"), on.data(), off.data());
    }
}
