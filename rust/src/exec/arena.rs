//! Size-classed buffer arena: the zero-allocation memory plan for the
//! training hot path.
//!
//! Every training step records the same graph over the same batch
//! shapes, so the sequence of buffer sizes a step allocates is
//! deterministic and identical to the sequence the previous step
//! released.  This module exploits that: an [`Arena`] keeps freed
//! `Vec<f32>` buffers in power-of-two **size classes**, and
//! `Tensor`'s allocation paths (`zeros`, `full`, `Clone`) draw from the
//! arena installed on the current thread while `Tensor`'s `Drop`
//! returns buffers to it.  After one warmup step has populated the
//! classes, steady-state training performs **zero heap allocation** —
//! asserted by `train::tests::steady_state_training_allocates_nothing`
//! and observable via `PLMU_ALLOC_STATS` (`crate::metrics::alloc_stats`).
//!
//! # Scoping and threading
//!
//! Arenas are installed per thread with [`scope`]: the arena is moved
//! into a thread-local slot for the duration of a closure and handed
//! back after, so the owner (a train loop, a data-parallel replica, the
//! pipelined optimizer stage) keeps the arena across steps while the
//! allocation hooks stay free of locks.  Outside any scope the hooks
//! fall through to the plain allocator and counters stay untouched —
//! code that never opts in is unaffected.
//!
//! Under `--pipeline`, the replica's arena (worker thread) and the
//! optimizer's arena (coordinator thread) are **two arenas in flight**
//! on different threads — the thread-local slot is what keeps their
//! free lists isolated, mirroring PR 4's double-buffered parameter
//! arenas.  `arena_unit` tests pin the isolation.
//!
//! # Why recycling cannot change bits
//!
//! The arena hands out *whole buffers*, never aliased views: a buffer
//! is pushed to a free list only by `release` (called from `Tensor::drop`
//! or `Graph::reset`, i.e. after its last use) and popped by exactly one
//! later allocation.  `alloc_zeroed`/`alloc_filled`/`alloc_copy`
//! overwrite every element before the buffer is visible, so recycled
//! and fresh buffers are indistinguishable to the kernels — determinism
//! is untouched, which is why the fingerprint matrix in `./ci.sh
//! determinism` needs no arena dimension.

use std::cell::RefCell;
use std::collections::HashMap; // lint-src: allow(hashmap) — identity registry below is insert/remove/lookup only, never iterated
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};

/// Per-class cap on retained free buffers.  Untracked buffers can enter
/// through `release` (e.g. batch tensors built outside the scope but
/// dropped inside it), so without a cap a long run could grow the free
/// lists without bound; 32 comfortably covers the deepest per-step
/// live-buffer population at one size.
pub const MAX_FREE_PER_CLASS: usize = 32;

/// Snapshot of allocation counters (per arena, or process-wide via
/// [`global_stats`]).  `hits / (hits + misses)` is the arena hit rate;
/// `misses` and `fresh_bytes` are the heap traffic — both must stay
/// flat across steady-state steps.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ArenaStats {
    /// Allocations served from a free list (no heap traffic).
    pub hits: u64,
    /// Allocations that had to touch the heap.
    pub misses: u64,
    /// Bytes of fresh heap capacity allocated by misses.
    pub fresh_bytes: u64,
    /// Buffers returned to a free list by `release`.
    pub recycled: u64,
    /// Buffers dropped by `release` because their class was full.
    pub dropped: u64,
}

impl ArenaStats {
    /// Counter-wise difference since an earlier snapshot.
    pub fn since(&self, earlier: &ArenaStats) -> ArenaStats {
        ArenaStats {
            hits: self.hits - earlier.hits,
            misses: self.misses - earlier.misses,
            fresh_bytes: self.fresh_bytes - earlier.fresh_bytes,
            recycled: self.recycled - earlier.recycled,
            dropped: self.dropped - earlier.dropped,
        }
    }
}

// Process-wide mirrors of the per-arena counters (relaxed: they are
// observability, not synchronization) — the backing for
// `metrics::alloc_stats` / `PLMU_ALLOC_STATS`.
static G_HITS: AtomicU64 = AtomicU64::new(0);
static G_MISSES: AtomicU64 = AtomicU64::new(0);
static G_FRESH_BYTES: AtomicU64 = AtomicU64::new(0);
static G_RECYCLED: AtomicU64 = AtomicU64::new(0);
static G_DROPPED: AtomicU64 = AtomicU64::new(0);

/// Process-wide allocation counters, summed over every arena that has
/// ever been active on any thread.
pub fn global_stats() -> ArenaStats {
    ArenaStats {
        hits: G_HITS.load(Ordering::Relaxed),
        misses: G_MISSES.load(Ordering::Relaxed),
        fresh_bytes: G_FRESH_BYTES.load(Ordering::Relaxed),
        recycled: G_RECYCLED.load(Ordering::Relaxed),
        dropped: G_DROPPED.load(Ordering::Relaxed),
    }
}

// ---------------------------------------------------------------------------
// Buffer-identity tracking (debug builds and PLMU_VERIFY=2)
// ---------------------------------------------------------------------------

/// Arena ids for release-provenance checks; starts at 1 so 0 never
/// names a real arena.
static NEXT_ARENA_ID: AtomicU64 = AtomicU64::new(1);

/// `ptr -> issuing arena id` for buffers currently issued by some
/// arena.  Insert on `take`, remove on `release`/[`untrack`] — so an
/// entry exists exactly while an arena-issued buffer is live, and a
/// `release` can verify the buffer comes home to the arena that issued
/// it.  Never iterated (lookup-only), so it cannot affect determinism.
static ISSUED_BY: OnceLock<Mutex<HashMap<usize, u64>>> = OnceLock::new(); // lint-src: allow(hashmap)

/// Whether identity tracking is on: always in debug builds (the
/// [`Arena::put`] identity check), and in release builds at
/// `PLMU_VERIFY=2` (the audit event stream needs provenance).  In a
/// level-0 release build this is one relaxed load.
#[inline]
fn tracking() -> bool {
    cfg!(debug_assertions) || crate::analyze::audit_enabled()
}

fn registry() -> &'static Mutex<HashMap<usize, u64>> { // lint-src: allow(hashmap)
    ISSUED_BY.get_or_init(|| Mutex::new(HashMap::new())) // lint-src: allow(hashmap)
}

/// Forget a buffer's arena provenance.  Called by every path that moves
/// an arena-issued buffer out of arena management without a `release`
/// (`Tensor::into_data`), so the registry never holds a stale entry for
/// an address the allocator may reuse.
pub(crate) fn untrack(ptr: *const f32) {
    if tracking() {
        registry().lock().unwrap().remove(&(ptr as usize));
    }
}

/// Size class that can serve a request for `len` elements: the
/// exponent of `len.next_power_of_two()`, so class `c` serves every
/// `len in (2^(c-1), 2^c]`.
#[inline]
fn class_for_len(len: usize) -> usize {
    debug_assert!(len >= 1);
    len.next_power_of_two().trailing_zeros() as usize
}

/// Size class a buffer of capacity `cap` belongs to: `floor(log2(cap))`,
/// rounding *down* so every buffer in class `c` has capacity `>= 2^c`
/// and can serve any request routed to that class.
#[inline]
fn class_for_cap(cap: usize) -> usize {
    debug_assert!(cap >= 1);
    (usize::BITS - 1 - cap.leading_zeros()) as usize
}

/// A size-classed free-list pool of `Vec<f32>` buffers.  Plain data
/// (`Send`), owned by one train loop / replica / optimizer stage and
/// installed per thread with [`scope`].
pub struct Arena {
    /// `classes[c]` holds freed buffers with `capacity in [2^c, 2^(c+1))`.
    classes: Vec<Vec<Vec<f32>>>,
    stats: ArenaStats,
    /// process-unique identity, for release-provenance checks
    id: u64,
    /// buffer-identity event log, populated at `PLMU_VERIFY=2` and
    /// drained by [`Arena::take_audit_events`] (the `plmu analyze`
    /// arena pass replays it)
    audit_log: Vec<crate::analyze::arena_check::ArenaEvent>,
}

impl Default for Arena {
    fn default() -> Self {
        Arena {
            classes: Vec::new(),
            stats: ArenaStats::default(),
            id: NEXT_ARENA_ID.fetch_add(1, Ordering::Relaxed),
            audit_log: Vec::new(),
        }
    }
}

impl Arena {
    pub fn new() -> Self {
        Arena::default()
    }

    /// This arena's process-unique identity.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Drain the buffer-identity event log recorded at `PLMU_VERIFY=2`
    /// (empty below level 2).
    pub fn take_audit_events(&mut self) -> Vec<crate::analyze::arena_check::ArenaEvent> {
        std::mem::take(&mut self.audit_log)
    }

    /// Snapshot of this arena's counters (read between [`scope`] calls;
    /// per-arena counters keep concurrently-running tests and replicas
    /// from polluting each other's assertions).
    pub fn stats(&self) -> ArenaStats {
        self.stats
    }

    /// Total buffers currently parked on free lists.
    pub fn free_buffers(&self) -> usize {
        self.classes.iter().map(|c| c.len()).sum()
    }

    fn take(&mut self, len: usize) -> Vec<f32> {
        let c = class_for_len(len);
        let (buf, fresh) = if let Some(buf) = self.classes.get_mut(c).and_then(|l| l.pop()) {
            self.stats.hits += 1;
            G_HITS.fetch_add(1, Ordering::Relaxed);
            debug_assert!(buf.capacity() >= len);
            (buf, false)
        } else {
            let cap = 1usize << c;
            self.stats.misses += 1;
            self.stats.fresh_bytes += (cap * std::mem::size_of::<f32>()) as u64;
            G_MISSES.fetch_add(1, Ordering::Relaxed);
            G_FRESH_BYTES.fetch_add((cap * std::mem::size_of::<f32>()) as u64, Ordering::Relaxed);
            (Vec::with_capacity(cap), true)
        };
        if tracking() {
            // overwrite is deliberate: the allocator may reuse an address
            // whose previous tenant left arena management untracked
            registry().lock().unwrap().insert(buf.as_ptr() as usize, self.id);
        }
        if crate::analyze::audit_enabled() {
            self.audit_log.push(crate::analyze::arena_check::ArenaEvent::Issue {
                buf: buf.as_ptr() as usize,
                bytes: buf.capacity() * std::mem::size_of::<f32>(),
                fresh,
            });
        }
        buf
    }

    fn put(&mut self, buf: Vec<f32>) {
        let ptr = buf.as_ptr() as usize;
        let issued_by = if tracking() { registry().lock().unwrap().remove(&ptr) } else { None };
        // The identity check `release` promises: a buffer coming home
        // must have been issued by THIS arena (cross-arena release is
        // the --pipeline free-list-migration hazard) and must not
        // already be parked on a free list (double release).  Buffers
        // with no provenance are foreign Vecs adopted by design (e.g.
        // batch tensors built outside the scope, dropped inside it).
        #[cfg(debug_assertions)]
        {
            if let Some(owner) = issued_by {
                assert_eq!(
                    owner, self.id,
                    "arena {}: released buffer {ptr:#x} was issued by arena {owner} — cross-arena release",
                    self.id
                );
            }
            assert!(
                !self.classes.iter().flatten().any(|b| b.as_ptr() as usize == ptr),
                "arena {}: buffer {ptr:#x} is already on a free list — double release",
                self.id
            );
        }
        if crate::analyze::audit_enabled() {
            self.audit_log.push(crate::analyze::arena_check::ArenaEvent::Reclaim {
                buf: ptr,
                bytes: buf.capacity() * std::mem::size_of::<f32>(),
                issued_by,
            });
        }
        let c = class_for_cap(buf.capacity());
        if self.classes.len() <= c {
            self.classes.resize_with(c + 1, Vec::new);
        }
        let list = &mut self.classes[c];
        if list.len() < MAX_FREE_PER_CLASS {
            self.stats.recycled += 1;
            G_RECYCLED.fetch_add(1, Ordering::Relaxed);
            list.push(buf);
        } else {
            self.stats.dropped += 1;
            G_DROPPED.fetch_add(1, Ordering::Relaxed);
        }
    }
}

thread_local! {
    /// The arena installed on this thread by [`scope`], if any.
    static CURRENT: RefCell<Option<Arena>> = const { RefCell::new(None) };
}

/// Run `f` with `arena` installed as this thread's allocation arena.
///
/// The arena is *moved* into the thread-local slot (so the hooks need
/// no locking) and moved back out when `f` returns — including on
/// unwind, so a panicking test does not lose its arena.  Nested scopes
/// stack: the inner arena shadows the outer for the inner closure.
pub fn scope<R>(arena: &mut Arena, f: impl FnOnce() -> R) -> R {
    let prev = CURRENT.with(|c| c.borrow_mut().replace(std::mem::take(arena)));
    struct Restore<'a> {
        arena: &'a mut Arena,
        prev: Option<Arena>,
    }
    impl Drop for Restore<'_> {
        fn drop(&mut self) {
            let cur = CURRENT.with(|c| std::mem::replace(&mut *c.borrow_mut(), self.prev.take()));
            *self.arena = cur.unwrap_or_default();
        }
    }
    let _restore = Restore { arena, prev };
    f()
}

/// Whether an arena is installed on this thread.
pub fn active() -> bool {
    CURRENT.with(|c| c.borrow().is_some())
}

/// Allocate a zero-filled buffer of `len` elements — `Tensor::zeros`'
/// backing.  Served from the installed arena's free lists when
/// possible; a plain (uncounted) allocation outside any scope.
pub fn alloc_zeroed(len: usize) -> Vec<f32> {
    if len == 0 {
        return Vec::new();
    }
    CURRENT.with(|c| match c.borrow_mut().as_mut() {
        Some(a) => {
            let mut buf = a.take(len);
            buf.clear();
            buf.resize(len, 0.0);
            buf
        }
        None => vec![0.0; len],
    })
}

/// Allocate a buffer of `len` copies of `v` — `Tensor::full`'s backing.
pub fn alloc_filled(len: usize, v: f32) -> Vec<f32> {
    if len == 0 {
        return Vec::new();
    }
    CURRENT.with(|c| match c.borrow_mut().as_mut() {
        Some(a) => {
            let mut buf = a.take(len);
            buf.clear();
            buf.resize(len, v);
            buf
        }
        None => vec![v; len],
    })
}

/// Allocate a copy of `src` — `Tensor::clone` and the slicing ops'
/// backing.
pub fn alloc_copy(src: &[f32]) -> Vec<f32> {
    if src.is_empty() {
        return Vec::new();
    }
    CURRENT.with(|c| match c.borrow_mut().as_mut() {
        Some(a) => {
            let mut buf = a.take(src.len());
            buf.clear();
            buf.extend_from_slice(src);
            buf
        }
        None => src.to_vec(),
    })
}

/// Return a buffer to the installed arena's free lists (`Tensor::drop`,
/// `Graph::reset`).  Outside any scope — or for a zero-capacity buffer
/// — this is a plain drop.
pub fn release(buf: Vec<f32>) {
    if buf.capacity() == 0 {
        return;
    }
    CURRENT.with(|c| {
        if let Some(a) = c.borrow_mut().as_mut() {
            a.put(buf);
        } else {
            // `buf` drops here, a plain deallocation — forget its
            // provenance so the registry never maps a freed address
            untrack(buf.as_ptr());
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_class_reuse_round_trips_buffers() {
        let mut a = Arena::new();
        scope(&mut a, || {
            let b = alloc_zeroed(100); // class 7 (128)
            release(b);
            let b2 = alloc_zeroed(90); // same class -> must be a hit
            assert!(b2.capacity() >= 128);
            release(b2);
        });
        let s = a.stats();
        assert_eq!(s.misses, 1, "{s:?}");
        assert_eq!(s.hits, 1, "{s:?}");
        assert_eq!(s.recycled, 2, "{s:?}");
        assert_eq!(a.free_buffers(), 1);
    }

    #[test]
    fn reused_buffers_are_fully_overwritten() {
        let mut a = Arena::new();
        scope(&mut a, || {
            let mut b = alloc_zeroed(64);
            for v in b.iter_mut() {
                *v = f32::NAN;
            }
            release(b);
            let z = alloc_zeroed(64);
            assert!(z.iter().all(|v| v.to_bits() == 0), "stale bytes leaked");
            let f = alloc_filled(64, 2.5);
            assert!(f.iter().all(|&v| v == 2.5));
            let src: Vec<f32> = (0..64).map(|i| i as f32).collect();
            let c = alloc_copy(&src);
            assert_eq!(c, src);
            release(z);
            release(f);
            release(c);
        });
    }

    #[test]
    fn live_buffers_never_alias() {
        let mut a = Arena::new();
        scope(&mut a, || {
            let b1 = alloc_zeroed(32);
            let b2 = alloc_zeroed(32);
            assert_ne!(b1.as_ptr(), b2.as_ptr(), "arena handed out an aliased live buffer");
            release(b1);
            let b3 = alloc_zeroed(32); // may reuse b1's storage — b1 is dead
            assert_ne!(b3.as_ptr(), b2.as_ptr());
            release(b2);
            release(b3);
        });
        assert_eq!(a.stats().hits, 1);
    }

    #[test]
    fn per_class_cap_bounds_growth() {
        let mut a = Arena::new();
        scope(&mut a, || {
            let bufs: Vec<_> = (0..MAX_FREE_PER_CLASS + 5).map(|_| alloc_zeroed(16)).collect();
            for b in bufs {
                release(b);
            }
        });
        let s = a.stats();
        assert_eq!(s.recycled, MAX_FREE_PER_CLASS as u64);
        assert_eq!(s.dropped, 5);
        assert_eq!(a.free_buffers(), MAX_FREE_PER_CLASS);
    }

    #[test]
    fn outside_scope_is_plain_allocation() {
        assert!(!active());
        let b = alloc_zeroed(128);
        assert_eq!(b.len(), 128);
        release(b); // no arena: plain drop, no panic
    }

    #[test]
    fn scopes_nest_and_restore() {
        let mut outer = Arena::new();
        let mut inner = Arena::new();
        scope(&mut outer, || {
            release(alloc_zeroed(8));
            scope(&mut inner, || {
                release(alloc_zeroed(8));
            });
            assert!(active(), "outer arena restored after inner scope");
            release(alloc_zeroed(8)); // hit against outer's free list
        });
        assert_eq!(outer.stats().misses, 1);
        assert_eq!(outer.stats().hits, 1);
        assert_eq!(inner.stats().misses, 1);
    }

    #[test]
    fn two_arenas_on_two_threads_stay_isolated() {
        // the pipelined coordinator's shape: a replica arena on a worker
        // thread and an optimizer arena on the coordinator thread, both
        // in flight at once — free lists must never cross.
        let t1 = std::thread::spawn(|| {
            let mut a = Arena::new();
            for _ in 0..4 {
                scope(&mut a, || {
                    let b = alloc_zeroed(1000);
                    release(b);
                });
            }
            a.stats()
        });
        let t2 = std::thread::spawn(|| {
            let mut a = Arena::new();
            for _ in 0..4 {
                scope(&mut a, || {
                    let b = alloc_zeroed(1000);
                    release(b);
                });
            }
            a.stats()
        });
        let (s1, s2) = (t1.join().unwrap(), t2.join().unwrap());
        for s in [s1, s2] {
            assert_eq!(s.misses, 1, "each thread warms its own arena exactly once: {s:?}");
            assert_eq!(s.hits, 3, "{s:?}");
            assert_eq!(s.recycled, 4, "{s:?}");
        }
    }

    #[test]
    fn steady_state_is_hit_only() {
        let mut a = Arena::new();
        // warmup: a "step" allocating a fixed size profile
        let step = || {
            let bufs: Vec<_> = [100usize, 200, 300, 100].iter().map(|&n| alloc_zeroed(n)).collect();
            for b in bufs {
                release(b);
            }
        };
        scope(&mut a, step);
        let warm = a.stats();
        for _ in 0..10 {
            scope(&mut a, step);
        }
        let delta = a.stats().since(&warm);
        assert_eq!(delta.misses, 0, "steady state must not touch the heap: {delta:?}");
        assert_eq!(delta.fresh_bytes, 0);
        assert_eq!(delta.hits, 40);
    }
}
