//! The persistent work-stealing worker pool behind the `exec` dispatch
//! helpers.
//!
//! Two generations ago the substrate spawned scoped threads per call
//! (~10µs per dispatch); the first pool generation parked persistent
//! workers on a condvar (~1µs hand-off) but ran **one job at a time**
//! with a *static* partition (`rows.div_ceil(workers)` chunks, one per
//! worker), which left threads idle in exactly the scenarios the paper's
//! speedup claim needs saturated: ragged per-row costs stalled on the
//! largest static chunk, and a parallel region entered with fewer items
//! than threads (e.g. 2 data-parallel replicas on 8 threads) serialized
//! every nested kernel.
//!
//! This generation fixes both:
//!
//!  * **Work stealing.**  A job is published as `chunks` fine-grained
//!    chunk indices (`chunks >= workers`, sized by `exec::Plan` so one
//!    chunk is ~[`super::CHUNK_WORK_TARGET`] scalar ops) and every thread
//!    working the job claims indices off a single **atomic counter**
//!    ([`JobCore::next`], one `fetch_add` per chunk, no lock on the claim
//!    path).  A thread that finishes early steals the next index instead
//!    of idling, so ragged tails and uneven per-row costs smooth out.
//!    Which thread runs which chunk never affects results (chunks are
//!    independent and internally serial), so bit-exactness is preserved.
//!  * **Multiple in-flight jobs + hierarchical budgets.**  The pool keeps
//!    a registry of active jobs.  A chunk that dispatches a kernel is no
//!    longer forced serial: its dispatch registers a first-class *nested*
//!    job whose concurrency is capped by the **sub-budget** the chunk was
//!    handed (the dispatcher's budget split evenly over the job's
//!    `workers_cap` concurrent chunk slots — see [`JobCore::sub_budget`]).
//!    Any set of `workers_cap` concurrently running chunks is handed at
//!    most the dispatcher's whole budget, so the busy-thread high-water
//!    mark of a job tree never exceeds the root budget
//!    ([`super::threads`] for a top-level dispatch), pinned by
//!    `rust/tests/exec_equivalence.rs`.
//!  * **Per-job worker caps.**  `workers_cap` bounds how many threads may
//!    attach to one job at once, so fine-grained chunking adds steal
//!    slots without adding threads.  Helpers are spawned lazily: each
//!    registration tops the pool up until the *unmet attach demand* of
//!    every live job is covered by unattached helpers (demand is bounded
//!    by the budget invariant, so the pool converges to ~`threads`
//!    helpers and then only reuses them).
//!  * **Top-level admission.**  Unrelated OS threads that dispatch
//!    concurrently (e.g. two serving batchers) still time-share: one owns
//!    the `dispatch` mutex, the rest degrade to serial with a unit
//!    budget, so independent dispatchers can never multiply thread
//!    counts.  Nested dispatch (from inside a pool chunk) skips this gate
//!    — its concurrency is already paid for by its chunk's sub-budget.
//!  * **Panic safe.**  A panic inside a chunk is caught on the worker,
//!    recorded on the job, and re-raised on the dispatching thread after
//!    the job drains; chunks nobody has claimed yet are abandoned.  A
//!    panic in a *nested* job unwinds its dispatcher — which is itself a
//!    chunk of the parent job — and therefore propagates level by level
//!    to the root dispatcher.  Helpers survive and the pool stays usable.
//!
//! "Pinned" here means the workers are long-lived named threads; OS-level
//! CPU affinity would need a syscall crate that is not in the offline
//! vendor set (see DESIGN.md §Substitutions).

use std::any::Any;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, TryLockError};

/// Hard backstop on helper-thread growth (demand-driven spawning keeps
/// the real count near the thread budget; this only guards against a
/// pathological registration storm).
const MAX_HELPERS: usize = 256;

/// Lifetime-erased fat pointer to a job's per-chunk closure.
///
/// Soundness: the pointer is dereferenced only inside [`run_chunk`], and
/// every such call finishes (and bumps [`JobCore::done`]) before [`run`]
/// — which keeps the borrowed closure alive — observes `done == chunks`
/// and returns.
#[derive(Clone, Copy)]
struct JobFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared-callable from any thread) and the
// `done`-counter handshake in `run` bounds its lifetime; the pointer
// itself is plain data.
unsafe impl Send for JobFn {}
unsafe impl Sync for JobFn {}

/// Shared state of one in-flight job.  Lives in an `Arc` so helpers can
/// outlast the dispatcher's registry entry; the closure behind `f` is
/// only guaranteed alive until `done == chunks` (see [`JobFn`]).
struct JobCore {
    /// the job's per-chunk closure
    f: JobFn,
    /// total chunk indices to hand out
    chunks: usize,
    /// steal counter: next chunk index to claim (may overshoot `chunks`;
    /// claims at or past it are no-ops)
    next: AtomicUsize,
    /// chunks executed or abandoned; the job is complete at `== chunks`
    done: AtomicUsize,
    /// max threads attached to this job at once (its concurrency share)
    workers_cap: usize,
    /// sub-budget floor handed to every chunk (`dispatcher budget / cap`)
    budget_base: usize,
    /// the first `budget_extra` chunk indices get `budget_base + 1`
    budget_extra: usize,
    /// threads currently attached (only mutated under the pool state
    /// lock; atomic so [`run`] can read it lock-free in debug asserts)
    attached: AtomicUsize,
    /// first panic payload observed in a chunk of this job
    panic: Mutex<Option<Box<dyn Any + Send>>>,
    /// nonzero = the `PLMU_VERIFY=2` audit id events for this job carry
    /// (zero = auditing off at dispatch time; chunks record nothing)
    audit_id: u64,
}

impl JobCore {
    fn has_work(&self) -> bool {
        self.next.load(Ordering::Relaxed) < self.chunks
    }

    fn is_done(&self) -> bool {
        // Acquire pairs with the AcqRel `fetch_add` in `finish`: once the
        // dispatcher sees `done == chunks`, every chunk's writes (to the
        // output buffer and the panic slot) are visible to it.
        self.done.load(Ordering::Acquire) >= self.chunks
    }

    /// Nested-dispatch budget for chunk `idx`: the dispatcher's budget is
    /// split `base + 1` for the first `extra` indices, `base` for the
    /// rest, so ANY `workers_cap` concurrently running chunks sum to at
    /// most the dispatcher's budget (`cap * base + extra`).
    fn sub_budget(&self, idx: usize) -> usize {
        (self.budget_base + usize::from(idx < self.budget_extra)).max(1)
    }
}

struct State {
    /// active jobs in registration order (stealers scan newest-first so
    /// leaf jobs of a nested tree drain first and unblock their parents)
    jobs: Vec<Arc<JobCore>>,
    /// helper threads spawned so far (grows with demand, never shrinks)
    helpers: usize,
    /// helpers currently attached to a job (under the state lock this is
    /// exact, so `helpers - busy_helpers` is the spawn-deficit baseline)
    busy_helpers: usize,
}

struct Pool {
    state: Mutex<State>,
    /// helpers park here waiting for claimable work
    cv_work: Condvar,
    /// dispatchers park here waiting for their job's stragglers
    cv_done: Condvar,
    /// held by the top-level dispatching thread for its whole job tree
    dispatch: Mutex<()>,
    /// distinct threads currently executing exec-dispatched work
    busy: AtomicUsize,
    /// high-water mark of `busy` since the last [`reset_peak`]
    peak: AtomicUsize,
}

fn lock(m: &Mutex<State>) -> MutexGuard<'_, State> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait<'a>(cv: &Condvar, g: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        state: Mutex::new(State { jobs: Vec::new(), helpers: 0, busy_helpers: 0 }),
        cv_work: Condvar::new(),
        cv_done: Condvar::new(),
        dispatch: Mutex::new(()),
        busy: AtomicUsize::new(0),
        peak: AtomicUsize::new(0),
    })
}

/// RAII busy-thread accounting.  Counts each OS thread once: nested
/// chunks on a thread already inside a chunk (depth > 0) don't re-count,
/// so `busy` is the number of distinct threads doing exec work and `peak`
/// is directly comparable to the `threads` budget.
struct BusyGuard<'a> {
    pool: &'a Pool,
    counted: bool,
}

impl<'a> BusyGuard<'a> {
    fn new(pool: &'a Pool) -> Self {
        let counted = super::chunk_depth() == 0;
        if counted {
            let b = pool.busy.fetch_add(1, Ordering::Relaxed) + 1;
            pool.peak.fetch_max(b, Ordering::Relaxed);
        }
        BusyGuard { pool, counted }
    }
}

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        if self.counted {
            self.pool.busy.fetch_sub(1, Ordering::Relaxed);
        }
    }
}

fn spawn_helper(pool: &'static Pool) {
    std::thread::Builder::new()
        .name("plmu-exec".to_string())
        .spawn(move || helper_loop(pool))
        .expect("exec: failed to spawn pool worker");
}

/// Pick a job worth attaching to: claimable work left and a free worker
/// slot.  Newest-first so nested (leaf) jobs complete before their
/// parents' remaining chunks are stolen.
fn claimable(st: &State) -> Option<Arc<JobCore>> {
    st.jobs
        .iter()
        .rev()
        .find(|c| c.has_work() && c.attached.load(Ordering::Relaxed) < c.workers_cap)
        .cloned()
}

fn helper_loop(pool: &'static Pool) {
    let mut st = lock(&pool.state);
    loop {
        if let Some(core) = claimable(&st) {
            core.attached.fetch_add(1, Ordering::Relaxed);
            st.busy_helpers += 1;
            drop(st);
            drain(pool, &core);
            st = lock(&pool.state);
            core.attached.fetch_sub(1, Ordering::Relaxed);
            st.busy_helpers -= 1;
            continue;
        }
        st = wait(&pool.cv_work, st);
    }
}

/// Steal chunks off `core`'s claim counter until none remain.  Called by
/// helpers and by the dispatcher itself (which participates in its own
/// job).  One atomic `fetch_add` per chunk — the entire hand-off cost.
fn drain(pool: &Pool, core: &JobCore) {
    loop {
        let idx = core.next.fetch_add(1, Ordering::Relaxed);
        if idx >= core.chunks {
            return;
        }
        match run_chunk(pool, core, idx) {
            None => finish(pool, core, 1),
            Some(p) => {
                {
                    let mut slot = core.panic.lock().unwrap_or_else(|e| e.into_inner());
                    if slot.is_none() {
                        *slot = Some(p);
                    }
                }
                // failed job: abandon every chunk nobody has claimed yet
                // (the swap also stops further claims; `min` discounts
                // counter overshoot from racing claimers)
                let prev = core.next.swap(core.chunks, Ordering::Relaxed).min(core.chunks);
                finish(pool, core, 1 + (core.chunks - prev));
            }
        }
    }
}

/// Execute one chunk: busy accounting, sub-budget install, panic capture.
fn run_chunk(pool: &Pool, core: &JobCore, idx: usize) -> Option<Box<dyn Any + Send>> {
    let _busy = BusyGuard::new(pool);
    let sub = core.sub_budget(idx);
    let _env = super::enter_chunk(sub);
    if core.audit_id != 0 {
        crate::analyze::audit::record(crate::analyze::exec_check::PoolEvent::ChunkStart {
            job: core.audit_id,
            idx,
            sub_budget: sub,
        });
    }
    let result = catch_unwind(AssertUnwindSafe(|| {
        // SAFETY: see `JobFn` — the dispatcher keeps the closure alive
        // until `done == chunks`, and this call's `finish` contribution
        // happens only after `f` returns.
        let f = unsafe { &*core.f.0 };
        f(idx)
    }))
    .err();
    // recorded on the panic path too: the chunk *stopped running*, which
    // is what the offline active-set/budget replay needs to know
    if core.audit_id != 0 {
        crate::analyze::audit::record(crate::analyze::exec_check::PoolEvent::ChunkEnd {
            job: core.audit_id,
            idx,
        });
    }
    result
}

/// Record `n` chunks as executed/abandoned; on completion, wake the
/// dispatcher (the state-lock round trip closes the race against a
/// dispatcher that just checked `is_done` and is about to park).
fn finish(pool: &Pool, core: &JobCore, n: usize) {
    if core.done.fetch_add(n, Ordering::AcqRel) + n >= core.chunks {
        drop(lock(&pool.state));
        pool.cv_done.notify_all();
    }
}

/// Run `f(chunk)` for every chunk index in `0..chunks` on the persistent
/// pool, with the calling thread participating and at most `workers`
/// threads attached at once.  Blocks until every chunk has completed; a
/// panic in any chunk is re-raised here.
///
/// The dispatcher's current budget (see [`super::budget`]) is split over
/// the job's `min(workers, chunks)` concurrent slots, and each chunk runs
/// with its share installed as the thread budget — so kernels inside a
/// chunk fan out as first-class nested pool jobs instead of serializing,
/// while the whole tree stays within the root budget.
///
/// Top-level calls (not from inside a pool chunk) take the `dispatch`
/// gate; if another top-level thread owns it, the job degrades to serial
/// on the caller with a unit budget, so concurrent dispatchers never
/// oversubscribe.
pub(super) fn run(chunks: usize, workers: usize, f: &(dyn Fn(usize) + Sync)) {
    if chunks == 0 {
        return;
    }
    let pool = pool();
    let owner = if super::chunk_depth() > 0 {
        // nested dispatch: already accounted for by this chunk's
        // sub-budget, no admission gate
        None
    } else {
        match pool.dispatch.try_lock() {
            Ok(g) => Some(g),
            // a previous dispatcher panicked while holding the lock (only
            // possible on the inline single-chunk path); the pool state
            // is consistent, so just take ownership
            Err(TryLockError::Poisoned(p)) => Some(p.into_inner()),
            Err(TryLockError::WouldBlock) => {
                // pool owned by another top-level dispatcher: degrade to
                // serial on this thread with a unit budget so kernels
                // below do not fan out either
                let _busy = BusyGuard::new(pool);
                let _env = super::enter_chunk(1);
                for i in 0..chunks {
                    f(i);
                }
                return;
            }
        }
    };
    if chunks == 1 {
        // degenerate single-chunk job: run inline, keeping the full
        // current budget (a lone chunk may still fan out beneath itself)
        let _busy = BusyGuard::new(pool);
        let _env = super::enter_chunk(super::budget());
        f(0);
        return;
    }
    let budget = super::budget();
    let cap = workers.max(1).min(chunks);
    // SAFETY: erases the closure's lifetime so it can sit in the shared
    // job core; `run` does not return until `done == chunks`, after the
    // last dereference.
    let job_fn = {
        let f_erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        JobFn(f_erased)
    };
    let audit_id =
        if crate::analyze::audit_enabled() { crate::analyze::audit::next_job_id() } else { 0 };
    let core = Arc::new(JobCore {
        f: job_fn,
        chunks,
        next: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        workers_cap: cap,
        budget_base: budget / cap,
        budget_extra: budget % cap,
        attached: AtomicUsize::new(1), // the dispatcher occupies one slot
        panic: Mutex::new(None),
        audit_id,
    });
    if audit_id != 0 {
        // stamped before the job is visible in the registry, so every
        // chunk event of this job sequences after its JobBegin
        crate::analyze::audit::record(crate::analyze::exec_check::PoolEvent::JobBegin {
            job: audit_id,
            chunks,
            workers_cap: cap,
            budget,
            root: super::threads(),
        });
    }
    let to_spawn = {
        let mut st = lock(&pool.state);
        st.jobs.push(core.clone());
        // top the pool up so every live job's unmet attach demand is
        // covered by helpers that are not currently attached anywhere —
        // demand is bounded by the budget invariant, so growth converges
        // to ~`threads` helpers which are then reused forever
        let want: usize = st
            .jobs
            .iter()
            .filter(|c| c.has_work())
            .map(|c| c.workers_cap.saturating_sub(c.attached.load(Ordering::Relaxed)))
            .sum();
        let available = st.helpers - st.busy_helpers;
        let deficit =
            want.saturating_sub(available).min(MAX_HELPERS.saturating_sub(st.helpers));
        // reserve the slots under the lock, but do the (~10µs each)
        // thread spawns after dropping it so concurrent finish()/rescan
        // paths are not stalled behind a spawn burst; a reserved helper
        // counts as available, which is exactly right — it scans the
        // registry as its first action
        st.helpers += deficit;
        // wake only as many parked helpers as this job can seat; helpers
        // that finish other work rescan the registry on their own
        for _ in 0..cap - 1 {
            pool.cv_work.notify_one();
        }
        deficit
    };
    for _ in 0..to_spawn {
        spawn_helper(pool);
    }
    // claim chunks alongside the helpers...
    drain(pool, &core);
    // ...then wait out stragglers still running stolen chunks
    if !core.is_done() {
        let mut st = lock(&pool.state);
        while !core.is_done() {
            st = wait(&pool.cv_done, st);
        }
    }
    {
        let mut st = lock(&pool.state);
        st.jobs.retain(|c| !Arc::ptr_eq(c, &core));
        core.attached.fetch_sub(1, Ordering::Relaxed);
    }
    drop(owner);
    let panic = core.panic.lock().unwrap_or_else(|e| e.into_inner()).take();
    if audit_id != 0 {
        // every chunk's End event is already stamped (done == chunks
        // was observed), so JobEnd sequences after all of them
        crate::analyze::audit::record(crate::analyze::exec_check::PoolEvent::JobEnd {
            job: audit_id,
            panicked: panic.is_some(),
        });
    }
    if let Some(p) = panic {
        std::panic::resume_unwind(p);
    }
}

// ------------------------------------------------------------ async jobs

/// One asynchronously dispatched job: its registry entry plus the
/// top-level dispatch-gate ownership, which is held until the job
/// completes so concurrent top-level dispatchers keep degrading to
/// serial instead of oversubscribing alongside the in-flight job.
///
/// Callers get this wrapped in `exec::JobHandle`, whose `wait`/`Drop`
/// funnels into [`wait_async`]; the handle keeps the chunk closure alive
/// until then (see [`run_async`]'s safety contract).
pub(super) struct AsyncJob {
    core: Arc<JobCore>,
    owner: Option<MutexGuard<'static, ()>>,
}

/// Register `chunks` chunk indices of `f` as a pool job and return
/// WITHOUT waiting: helpers execute the chunks while the caller overlaps
/// other work, up to `workers` threads at once, each chunk handed a share
/// of the explicit `budget` (the async analogue of the dispatcher-budget
/// split in [`run`] — the caller passes the budget because its own thread
/// keeps working and typically reserves itself a share of the global
/// knob).
///
/// Returns `None` when the job already ran inline — an empty job, a
/// nested dispatch (from inside a pool chunk, already paid for by that
/// chunk's sub-budget), or a pool owned by another top-level dispatcher
/// (degrades to serial with a unit budget, exactly like [`run`]).  Inline
/// execution means a panic surfaces here instead of at `wait`.
///
/// SAFETY contract (enforced by `exec::JobHandle`): the closure behind
/// `f` must stay alive and at a stable address until [`wait_async`] has
/// returned for the job this call registers.
pub(super) fn run_async(
    chunks: usize,
    workers: usize,
    budget: usize,
    f: &(dyn Fn(usize) + Sync),
) -> Option<AsyncJob> {
    if chunks == 0 {
        return None;
    }
    let pool = pool();
    if super::chunk_depth() > 0 {
        // nested dispatch cannot overlap with its caller (the chunk IS
        // the caller's work); run inline under the chunk's budget
        for i in 0..chunks {
            f(i);
        }
        return None;
    }
    let owner = match pool.dispatch.try_lock() {
        Ok(g) => g,
        Err(TryLockError::Poisoned(p)) => p.into_inner(),
        Err(TryLockError::WouldBlock) => {
            // pool owned elsewhere (including an earlier async job of
            // THIS thread — one overlapped job per thread): degrade to
            // serial with a unit budget, like `run`
            let _busy = BusyGuard::new(pool);
            let _env = super::enter_chunk(1);
            for i in 0..chunks {
                f(i);
            }
            return None;
        }
    };
    let cap = workers.max(1).min(chunks);
    let budget = budget.max(1);
    // SAFETY: see the function-level contract — `exec::JobHandle` owns
    // the boxed closure and blocks in wait/Drop until `done == chunks`.
    let job_fn = {
        let f_erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        JobFn(f_erased)
    };
    let audit_id =
        if crate::analyze::audit_enabled() { crate::analyze::audit::next_job_id() } else { 0 };
    let core = Arc::new(JobCore {
        f: job_fn,
        chunks,
        next: AtomicUsize::new(0),
        done: AtomicUsize::new(0),
        workers_cap: cap,
        budget_base: budget / cap,
        budget_extra: budget % cap,
        // unlike `run`, the dispatcher does NOT occupy a slot: it walks
        // away to overlap other work, so all `cap` slots go to helpers
        attached: AtomicUsize::new(0),
        panic: Mutex::new(None),
        audit_id,
    });
    if audit_id != 0 {
        crate::analyze::audit::record(crate::analyze::exec_check::PoolEvent::JobBegin {
            job: audit_id,
            chunks,
            workers_cap: cap,
            budget,
            root: super::threads(),
        });
    }
    let to_spawn = {
        let mut st = lock(&pool.state);
        st.jobs.push(core.clone());
        let want: usize = st
            .jobs
            .iter()
            .filter(|c| c.has_work())
            .map(|c| c.workers_cap.saturating_sub(c.attached.load(Ordering::Relaxed)))
            .sum();
        let available = st.helpers - st.busy_helpers;
        let deficit =
            want.saturating_sub(available).min(MAX_HELPERS.saturating_sub(st.helpers));
        st.helpers += deficit;
        for _ in 0..cap {
            pool.cv_work.notify_one();
        }
        deficit
    };
    for _ in 0..to_spawn {
        spawn_helper(pool);
    }
    Some(AsyncJob { core, owner })
}

/// Block until every chunk of an async job has completed, remove it from
/// the registry, and release the dispatch gate.  The waiter steals
/// remaining chunks itself when a worker slot is free (it respects
/// `workers_cap` like any helper, so the job's concurrency cap — and the
/// budget invariant derived from it — holds even while waiting).
///
/// A chunk panic is re-raised here when `propagate` is true, else
/// swallowed (the drop-while-unwinding path).
pub(super) fn wait_async(mut job: AsyncJob, propagate: bool) {
    let pool = pool();
    let core = &job.core;
    let attach = {
        let st = lock(&pool.state);
        let free = core.has_work() && core.attached.load(Ordering::Relaxed) < core.workers_cap;
        if free {
            core.attached.fetch_add(1, Ordering::Relaxed);
        }
        drop(st);
        free
    };
    if attach {
        drain(pool, core);
        let st = lock(&pool.state);
        core.attached.fetch_sub(1, Ordering::Relaxed);
        drop(st);
    }
    if !core.is_done() {
        let mut st = lock(&pool.state);
        while !core.is_done() {
            st = wait(&pool.cv_done, st);
        }
    }
    {
        let mut st = lock(&pool.state);
        st.jobs.retain(|c| !Arc::ptr_eq(c, core));
    }
    job.owner.take();
    let panic = job.core.panic.lock().unwrap_or_else(|e| e.into_inner()).take();
    if job.core.audit_id != 0 {
        // done == chunks was observed above, so every ChunkEnd is already
        // sequence-stamped before this JobEnd
        crate::analyze::audit::record(crate::analyze::exec_check::PoolEvent::JobEnd {
            job: job.core.audit_id,
            panicked: panic.is_some(),
        });
    }
    if let Some(p) = panic {
        if propagate {
            std::panic::resume_unwind(p);
        }
    }
}

/// High-water mark of concurrently busy exec threads since the last
/// [`reset_peak`] (each OS thread counted once, however deeply nested).
pub(super) fn peak_concurrency() -> usize {
    pool().peak.load(Ordering::Relaxed)
}

/// Reset the [`peak_concurrency`] high-water mark to zero.
pub(super) fn reset_peak() {
    pool().peak.store(0, Ordering::Relaxed)
}

/// Number of helper threads the pool has spawned so far (excludes the
/// dispatching caller; grows with demand, never shrinks).
pub(super) fn helper_count() -> usize {
    lock(&pool().state).helpers
}
