//! The persistent worker pool behind the `exec` dispatch helpers.
//!
//! The previous substrate spawned scoped threads per call
//! (`std::thread::scope`), which costs ~10µs per dispatch and forced a
//! high serial/parallel crossover (`MIN_PARALLEL_WORK` was 2^18 scalar
//! ops).  This pool keeps workers alive across calls, parked on a
//! `Condvar` when idle, so a dispatch is a mutex hand-off (~1µs) and the
//! crossover drops by an order of magnitude — exactly what the
//! many-small-batch serving workload needs.
//!
//! Design:
//!
//!  * **Lazy, process-global.**  The pool is created on first parallel
//!    dispatch; helper threads are spawned on demand up to
//!    `chunks - 1` for the largest job seen and then reused forever
//!    (they are parked, not spinning, so idle helpers cost nothing).
//!  * **One job at a time.**  A dispatching thread takes the `dispatch`
//!    mutex for the whole job.  A second thread that wants to dispatch
//!    while the pool is busy runs its job serially on itself instead —
//!    so two concurrent dispatchers can never multiply thread counts,
//!    and the process-wide compute concurrency the pool *creates* stays
//!    bounded by the `threads` budget.
//!  * **Work queue, caller participates.**  A job is `chunks` disjoint
//!    chunk indices; the dispatcher and the helpers claim indices from a
//!    shared counter until none remain.  Which thread runs which chunk
//!    never affects results (chunks are independent and internally
//!    serial), so bit-exactness is preserved.
//!  * **Panic safe.**  A panic inside a chunk is caught on the worker,
//!    recorded, and re-raised on the dispatching thread after the job
//!    drains; unstarted chunks of the failed job are abandoned.  Helpers
//!    survive and the pool stays usable.
//!
//! "Pinned" here means the workers are long-lived named threads; OS-level
//! CPU affinity would need a syscall crate that is not in the offline
//! vendor set (see DESIGN.md §Substitutions).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Condvar, Mutex, MutexGuard, OnceLock, TryLockError};

/// Lifetime-erased fat pointer to the active job's per-chunk closure.
///
/// Soundness: the pointer is dereferenced only between job publication
/// and the `unfinished == 0` handshake in [`run`], and `run` does not
/// return (so the borrowed closure cannot be dropped) until that
/// handshake completes.
#[derive(Clone, Copy)]
struct JobFn(*const (dyn Fn(usize) + Sync));

// SAFETY: the pointee is `Sync` (shared-callable from any thread) and the
// completion handshake in `run` bounds its lifetime.
unsafe impl Send for JobFn {}

struct State {
    /// the active job's chunk closure (`None` = pool idle)
    job: Option<JobFn>,
    /// next chunk index to hand out
    next_chunk: usize,
    /// one past the last chunk index of the active job
    total_chunks: usize,
    /// chunks of the active job not yet completed
    unfinished: usize,
    /// helper threads spawned so far (grows lazily, never shrinks)
    helpers: usize,
    /// first panic payload observed in a chunk of the active job
    panic: Option<Box<dyn std::any::Any + Send>>,
}

struct Pool {
    state: Mutex<State>,
    /// helpers and the dispatcher both wait here; every state change that
    /// could unblock a waiter does `notify_all`
    cv: Condvar,
    /// held by the dispatching thread for the whole job
    dispatch: Mutex<()>,
    /// threads currently executing exec-dispatched work
    busy: AtomicUsize,
    /// high-water mark of `busy` since the last [`reset_peak`]
    peak: AtomicUsize,
}

fn lock(m: &Mutex<State>) -> MutexGuard<'_, State> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait<'a>(cv: &Condvar, g: MutexGuard<'a, State>) -> MutexGuard<'a, State> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

fn pool() -> &'static Pool {
    static POOL: OnceLock<Pool> = OnceLock::new();
    POOL.get_or_init(|| Pool {
        state: Mutex::new(State {
            job: None,
            next_chunk: 0,
            total_chunks: 0,
            unfinished: 0,
            helpers: 0,
            panic: None,
        }),
        cv: Condvar::new(),
        dispatch: Mutex::new(()),
        busy: AtomicUsize::new(0),
        peak: AtomicUsize::new(0),
    })
}

/// RAII busy-thread accounting (peak tracking survives panics).
struct BusyGuard<'a>(&'a Pool);

impl<'a> BusyGuard<'a> {
    fn new(pool: &'a Pool) -> Self {
        let b = pool.busy.fetch_add(1, Ordering::Relaxed) + 1;
        pool.peak.fetch_max(b, Ordering::Relaxed);
        BusyGuard(pool)
    }
}

impl Drop for BusyGuard<'_> {
    fn drop(&mut self) {
        self.0.busy.fetch_sub(1, Ordering::Relaxed);
    }
}

fn spawn_helper(pool: &'static Pool) {
    std::thread::Builder::new()
        .name("plmu-exec".to_string())
        .spawn(move || helper_loop(pool))
        .expect("exec: failed to spawn pool worker");
}

fn helper_loop(pool: &'static Pool) {
    let mut st = lock(&pool.state);
    loop {
        if let Some(job) = st.job {
            if st.next_chunk < st.total_chunks {
                let idx = st.next_chunk;
                st.next_chunk += 1;
                drop(st);
                let panicked = run_chunk(pool, job, idx);
                st = lock(&pool.state);
                finish_chunk(pool, &mut st, panicked);
                continue;
            }
        }
        st = wait(&pool.cv, st);
    }
}

/// Execute one chunk inside a parallel region, catching panics.
fn run_chunk(pool: &Pool, job: JobFn, idx: usize) -> Option<Box<dyn std::any::Any + Send>> {
    let _busy = BusyGuard::new(pool);
    let _region = super::enter_region();
    catch_unwind(AssertUnwindSafe(|| {
        // SAFETY: see `JobFn` — the dispatcher keeps the closure alive
        // until every chunk has reported completion.
        let f = unsafe { &*job.0 };
        f(idx)
    }))
    .err()
}

fn finish_chunk(pool: &Pool, st: &mut State, panicked: Option<Box<dyn std::any::Any + Send>>) {
    st.unfinished -= 1;
    if let Some(p) = panicked {
        if st.panic.is_none() {
            st.panic = Some(p);
        }
        // failed job: abandon every chunk nobody has started yet
        st.unfinished -= st.total_chunks - st.next_chunk;
        st.next_chunk = st.total_chunks;
    }
    // the only waiter that consumes this transition is the dispatcher
    // blocked on job completion; helpers only wait for new jobs, so
    // skipping the wakeup while chunks remain avoids O(chunks × helpers)
    // spurious wakeups on the hot dispatch path
    if st.unfinished == 0 {
        pool.cv.notify_all();
    }
}

/// Run `f(chunk)` for every chunk index in `0..chunks` on the persistent
/// pool, with the calling thread participating.  Blocks until every chunk
/// has completed; a panic in any chunk is re-raised here.
///
/// `chunks` must already respect the thread budget — dispatch sites derive
/// it from [`super::workers_for`], which caps at [`super::threads`].  If
/// another thread currently owns the pool (or this is a re-entrant call),
/// the whole job runs serially on the caller instead, so concurrent
/// dispatchers never oversubscribe.
pub(super) fn run(chunks: usize, f: &(dyn Fn(usize) + Sync)) {
    if chunks == 0 {
        return;
    }
    let pool = pool();
    let owner = match pool.dispatch.try_lock() {
        Ok(g) => g,
        // a previous dispatcher panicked while holding the lock (only
        // possible on the degenerate single-chunk path); the pool state
        // is consistent, so just take ownership
        Err(TryLockError::Poisoned(p)) => p.into_inner(),
        Err(TryLockError::WouldBlock) => {
            // pool busy: degrade to serial on this thread (still flagged
            // as a region so kernels below do not try to fan out)
            let _busy = BusyGuard::new(pool);
            let _region = super::enter_region();
            for i in 0..chunks {
                f(i);
            }
            return;
        }
    };
    if chunks == 1 {
        let _busy = BusyGuard::new(pool);
        let _region = super::enter_region();
        f(0);
        return;
    }
    // SAFETY: erases the closure's lifetime so it can sit in the shared
    // state; `run` does not return until `unfinished == 0`, after the
    // last dereference.
    let job = {
        let f_erased: &'static (dyn Fn(usize) + Sync) = unsafe { std::mem::transmute(f) };
        JobFn(f_erased)
    };
    {
        let mut st = lock(&pool.state);
        let want = chunks - 1;
        while st.helpers < want {
            spawn_helper(pool);
            st.helpers += 1;
        }
        debug_assert!(st.job.is_none(), "exec pool: overlapping jobs");
        st.job = Some(job);
        st.next_chunk = 0;
        st.total_chunks = chunks;
        st.unfinished = chunks;
        st.panic = None;
        // wake only as many helpers as this job can occupy — notify_all
        // would stampede every helper ever spawned through the state
        // mutex on each dispatch.  Under-waking is harmless: the
        // dispatcher claims leftover chunks itself, and a not-yet-parked
        // helper re-checks the claim condition before waiting.
        for _ in 0..want {
            pool.cv.notify_one();
        }
    }
    // claim chunks alongside the helpers, then wait out the stragglers
    let mut st = lock(&pool.state);
    loop {
        if st.next_chunk < st.total_chunks {
            let idx = st.next_chunk;
            st.next_chunk += 1;
            drop(st);
            let panicked = run_chunk(pool, job, idx);
            st = lock(&pool.state);
            finish_chunk(pool, &mut st, panicked);
            continue;
        }
        if st.unfinished == 0 {
            break;
        }
        st = wait(&pool.cv, st);
    }
    st.job = None;
    let panic = st.panic.take();
    drop(st);
    drop(owner);
    if let Some(p) = panic {
        std::panic::resume_unwind(p);
    }
}

/// High-water mark of concurrently busy exec threads since the last
/// [`reset_peak`] (dispatcher and serial-fallback callers included).
pub(super) fn peak_concurrency() -> usize {
    pool().peak.load(Ordering::Relaxed)
}

/// Reset the [`peak_concurrency`] high-water mark to zero.
pub(super) fn reset_peak() {
    pool().peak.store(0, Ordering::Relaxed)
}

/// Number of helper threads the pool has spawned so far (excludes the
/// dispatching caller; grows lazily, never shrinks).
pub(super) fn helper_count() -> usize {
    lock(&pool().state).helpers
}
