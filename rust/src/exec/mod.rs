//! Thread-parallel execution substrate for the native compute hot paths.
//!
//! The paper's entire point is that the LMU's frozen LTI memory removes
//! the sequential dependency from training, leaving big, embarrassingly
//! parallel batched kernels (matmul, FFT causal convolution, elementwise
//! maps).  This module is the single place that turns that latent
//! parallelism into wall-clock speedup on CPU: a **work-stealing,
//! budget-aware scheduler** over a persistent parked worker pool (see
//! `pool.rs` — plain `Mutex`/`Condvar`/atomics, no crate dependencies,
//! builds offline) with a global thread-count knob plumbed through the
//! CLI (`--threads`), config (`[train] threads`), and environment
//! (`PLMU_THREADS`).
//!
//! Design rules every dispatch site follows:
//!
//!  * **Bit-exact equivalence.**  Work is partitioned over *output* rows
//!    (or independent items); each element is computed by exactly the same
//!    sequence of floating-point operations as the serial reference, so
//!    results are identical for every thread count AND every chunk
//!    granularity — which thread steals which chunk never matters.
//!    `threads = 1` (or any job below [`MIN_PARALLEL_WORK`]) takes the
//!    serial path outright.  `rust/tests/exec_equivalence.rs` pins this.
//!  * **Work stealing.**  A [`Plan`] splits a job into more chunks than
//!    workers (targeting ~[`CHUNK_WORK_TARGET`] scalar ops per chunk, so
//!    the one-atomic-op claim stays below ~5% of chunk runtime); threads
//!    claim chunks off an atomic counter, smoothing ragged tails and
//!    uneven per-row costs that a static `rows.div_ceil(workers)`
//!    partition would stall on.
//!  * **Hierarchical budgets.**  Every thread carries a parallelism
//!    budget ([`budget`]): the global knob at top level, a *sub-budget*
//!    inside a pool chunk.  A parallel region entered with `R` chunk
//!    slots hands each chunk `budget / R`, so a data-parallel run with 2
//!    replicas on 8 threads drives 4 threads' worth of nested kernel
//!    fan-out per replica — nested dispatch is a first-class pool job,
//!    not a degenerate serial path — while the busy-thread high-water
//!    mark of the whole tree never exceeds the root budget.  A chunk
//!    whose sub-budget is 1 (the common case when chunks >= threads)
//!    runs nested kernels serially, exactly like the old region flag.
//!  * **Threshold-gated.**  Jobs smaller than [`MIN_PARALLEL_WORK`] scalar
//!    ops stay serial.  With the persistent pool a dispatch is a parked
//!    hand-off (~1µs) instead of a thread spawn (~10µs) — the crossover
//!    measured by `cargo bench --bench pool_crossover`.

pub mod arena;
mod pool;

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Global worker-count knob.  0 = unresolved (first read resolves the
/// default from `PLMU_THREADS` or the machine's parallelism).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Default cap: beyond this, memory bandwidth dominates for the shapes
/// these models use.
const DEFAULT_MAX_THREADS: usize = 8;

/// Minimum total scalar ops before a kernel fans out.  A parked-pool
/// hand-off costs ~1µs (versus ~10µs for the scoped-spawn substrate this
/// replaced, whose threshold was `1 << 18`); `cargo bench --bench
/// pool_crossover` measures the crossover and writes `BENCH_pool.json`.
pub const MIN_PARALLEL_WORK: usize = 1 << 14;

/// Target scalar ops per work-stealing chunk (~a few µs of kernel time),
/// sized so the per-chunk claim — one atomic `fetch_add`, ~0.1µs with
/// cache-line traffic — stays below ~5% of chunk runtime.
pub const CHUNK_WORK_TARGET: usize = 1 << 12;

/// Steal-granularity cap: a [`Plan`] never carries more than this many
/// chunks per worker, bounding total claim traffic per job.
pub const MAX_CHUNKS_PER_WORKER: usize = 8;

fn resolve_default() -> usize {
    if let Some(n) = crate::util::env_knob::usize_knob("PLMU_THREADS", 1) {
        return n;
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, DEFAULT_MAX_THREADS)
}

/// The configured worker count (resolving the default on first use).
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let d = resolve_default();
    // racy double-resolve is benign: resolve_default is deterministic
    THREADS.store(d, Ordering::Relaxed);
    d
}

/// Set the worker count (clamped to >= 1).  1 selects the serial
/// reference path everywhere.  Raising the knob grows the pool lazily on
/// the next dispatch; lowering it caps future dispatches (already-spawned
/// helpers park and stay idle).
pub fn set_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Sentinel for "no budget installed": fall back to the global knob.
const BUDGET_UNSET: usize = usize::MAX;

thread_local! {
    /// Parallelism budget of the current thread (see [`budget`]).
    static BUDGET: Cell<usize> = const { Cell::new(BUDGET_UNSET) };
    /// Pool-chunk nesting depth of the current thread (0 = not inside a
    /// pool chunk; used for busy-thread accounting and to route nested
    /// dispatch past the top-level admission gate).
    static DEPTH: Cell<usize> = const { Cell::new(0) };
}

/// The parallelism budget of the current thread: how many threads a
/// kernel dispatched *from this thread* may occupy, itself included.
///
/// Top-level threads get the global [`threads`] knob.  Inside a pool
/// chunk this is the chunk's sub-budget (the dispatcher's budget divided
/// over the job's concurrent chunk slots); inside [`run_serialized`] it
/// is 1.  [`plan_for`] caps every plan at this value, which is what makes
/// the budget hierarchical: sub-budgets of concurrently running chunks
/// never sum past the root budget.
pub fn budget() -> usize {
    let b = BUDGET.with(|c| c.get());
    if b == BUDGET_UNSET {
        threads()
    } else {
        b
    }
}

/// Pool-chunk nesting depth of the current thread.
fn chunk_depth() -> usize {
    DEPTH.with(|c| c.get())
}

/// True while the current thread is executing inside a parallel region
/// (a pool chunk or a [`run_serialized`] scope) — i.e. whenever a budget
/// other than the global knob is installed.
pub fn in_parallel_region() -> bool {
    BUDGET.with(|c| c.get()) != BUDGET_UNSET
}

/// RAII scope installing a chunk's sub-budget (and, for real pool
/// chunks, the nesting depth used by busy accounting).
struct ChunkGuard {
    prev_budget: usize,
    raised_depth: bool,
}

impl Drop for ChunkGuard {
    fn drop(&mut self) {
        if self.raised_depth {
            DEPTH.with(|c| c.set(c.get() - 1));
        }
        BUDGET.with(|c| c.set(self.prev_budget));
    }
}

/// Enter a pool-chunk scope with the given sub-budget (pool.rs calls this
/// around every chunk execution and serial-degraded job).
fn enter_chunk(sub_budget: usize) -> ChunkGuard {
    let prev_budget = BUDGET.with(|c| c.replace(sub_budget.max(1)));
    DEPTH.with(|c| c.set(c.get() + 1));
    ChunkGuard { prev_budget, raised_depth: true }
}

/// Run `f` with kernel-level parallel dispatch disabled on the current
/// thread: every [`plan_for`] inside reports serial.  For
/// code that manages its own thread-level parallelism (e.g. engines
/// constructed on thread-bound batcher threads) so external thread counts
/// and kernel threads don't multiply.
pub fn run_serialized<R>(f: impl FnOnce() -> R) -> R {
    let prev_budget = BUDGET.with(|c| c.replace(1));
    let _g = ChunkGuard { prev_budget, raised_depth: false };
    f()
}

/// A dispatch plan: how many threads may work a job at once, and how many
/// steal-granularity chunks the job is split into.
///
/// `workers` is the concurrency share (capped at the dispatching thread's
/// [`budget`] by [`plan_for`]); `chunks >= workers` adds steal slots
/// without adding threads, so uneven per-chunk costs smooth out.  The
/// partition a plan induces depends only on `(rows, chunks)` — never on
/// which thread steals which chunk — so results stay bit-exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Plan {
    /// max threads working the job concurrently
    pub workers: usize,
    /// total claimable chunks (`1` = the serial reference path)
    pub chunks: usize,
}

impl Plan {
    /// The serial reference path: one worker, one chunk, no pool dispatch.
    pub const SERIAL: Plan = Plan { workers: 1, chunks: 1 };

    /// True when this plan takes the serial path outright.
    pub fn is_serial(&self) -> bool {
        self.workers <= 1 || self.chunks <= 1
    }

    /// Plan for an explicit worker count (benches and tests; production
    /// call sites should use [`plan_for`], which reads the budget):
    /// chunks target [`CHUNK_WORK_TARGET`] scalar ops each, clamped to
    /// `[workers, workers * MAX_CHUNKS_PER_WORKER]` and the item count.
    pub fn sized(workers: usize, items: usize, work: usize) -> Plan {
        if workers <= 1 || items <= 1 {
            return Plan::SERIAL;
        }
        let workers = workers.min(items);
        let by_work = work / CHUNK_WORK_TARGET;
        let chunks =
            by_work.clamp(workers, workers.saturating_mul(MAX_CHUNKS_PER_WORKER)).min(items);
        Plan { workers, chunks }
    }

    /// A static one-chunk-per-worker partition (the pre-work-stealing
    /// scheduler's granularity; kept for A/B benchmarking).
    pub fn static_partition(workers: usize) -> Plan {
        Plan { workers: workers.max(1), chunks: workers.max(1) }
    }
}

/// Dispatch plan for a job of `items` independent units totalling `work`
/// scalar ops: workers = the current thread's [`budget`] capped by the
/// item count, serial when the job is too small or the budget is 1.
pub fn plan_for(items: usize, work: usize) -> Plan {
    let b = budget();
    if b <= 1 || items <= 1 || work < MIN_PARALLEL_WORK {
        return Plan::SERIAL;
    }
    Plan::sized(b, items, work)
}

/// Raw-pointer wrapper that lets disjoint sub-slices of one buffer be
/// handed to pool workers.  Soundness relies on the chunk ranges being
/// disjoint (they partition the buffer) and on `T: Send`.
struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Partition `out` into chunk blocks of whole rows (`row_len` elements
/// each) per `plan` and run `f(first_row_index, block)` on each block on
/// the work-stealing pool, with the calling thread participating.
///
/// A serial plan (or a single row) short-circuits to `f(0, out)` with no
/// pool dispatch and no budget change — the serial reference path.  The
/// block partition depends only on `(rows, plan.chunks)`, never on which
/// pool thread steals which block, so results are bit-exact at every
/// thread count and granularity.
pub fn parallel_rows_mut<T, F>(out: &mut [T], row_len: usize, plan: Plan, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    if plan.is_serial() || rows <= 1 {
        DISPATCH_SERIAL.fetch_add(1, Ordering::Relaxed);
        f(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(plan.chunks.min(rows));
    let chunks = rows.div_ceil(chunk_rows);
    if chunks <= 1 {
        DISPATCH_SERIAL.fetch_add(1, Ordering::Relaxed);
        f(0, out);
        return;
    }
    DISPATCH_POOLED.fetch_add(1, Ordering::Relaxed);
    let total_len = out.len();
    // PLMU_VERIFY>=1: prove the SAFETY claim below — the chunk ranges
    // must partition [0, total_len) — before any `&mut` fans out
    if crate::analyze::level() >= 1 {
        let ranges: Vec<(usize, usize)> = (0..chunks)
            .map(|ci| {
                let start = ci * chunk_rows * row_len;
                let end =
                    if ci + 1 == chunks { total_len } else { start + chunk_rows * row_len };
                (start, end)
            })
            .collect();
        let findings = crate::analyze::exec_check::check_ranges(total_len, &ranges);
        assert!(findings.is_empty(), "parallel_rows_mut chunk plan is unsound: {findings:?}");
    }
    let base = SendPtr(out.as_mut_ptr());
    pool::run(chunks, plan.workers, &|ci| {
        let start = ci * chunk_rows * row_len;
        // the last chunk absorbs any ragged tail beyond rows * row_len
        let end = if ci + 1 == chunks { total_len } else { start + chunk_rows * row_len };
        // SAFETY: chunk ranges [start, end) are in-bounds, pairwise
        // disjoint, and cover the buffer exactly once; `T: Send` lets the
        // sub-slice cross to a pool thread.
        let block = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(ci * chunk_rows, block);
    });
}

/// Run `f(lo, hi)` over a partition of `0..n` into `plan.chunks`
/// contiguous ranges on the work-stealing pool (calling thread
/// participating).  For jobs whose output is not one contiguous mutable
/// slice.
pub fn parallel_ranges<F>(n: usize, plan: Plan, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if plan.is_serial() || n <= 1 {
        DISPATCH_SERIAL.fetch_add(1, Ordering::Relaxed);
        if n > 0 {
            f(0, n);
        }
        return;
    }
    let chunk = n.div_ceil(plan.chunks.min(n));
    let chunks = n.div_ceil(chunk);
    if chunks <= 1 {
        DISPATCH_SERIAL.fetch_add(1, Ordering::Relaxed);
        f(0, n);
        return;
    }
    DISPATCH_POOLED.fetch_add(1, Ordering::Relaxed);
    pool::run(chunks, plan.workers, &|ci| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(n);
        f(lo, hi);
    });
}

/// Order-preserving parallel map: `out[i] = f(i)` for `i in 0..n`.
pub fn parallel_map<T, F>(n: usize, plan: Plan, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if plan.is_serial() || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    parallel_rows_mut(&mut out, 1, plan, |i0, block| {
        for (k, slot) in block.iter_mut().enumerate() {
            *slot = Some(f(i0 + k));
        }
    });
    out.into_iter().map(|v| v.expect("parallel_map: slot unfilled")).collect()
}

// ------------------------------------------------------------- async jobs

/// Handle to an asynchronously dispatched pool job (see
/// [`dispatch_async`]).  The dispatching thread keeps running while the
/// pool's helpers execute the job's chunks; [`JobHandle::wait`] blocks
/// until every chunk has completed and re-raises the first chunk panic.
///
/// Dropping the handle without waiting also blocks until completion (the
/// chunk closure lives in the handle, so the pool must be done with it
/// before the handle can go away); a panic is then re-raised only if the
/// current thread is not already unwinding.
///
/// Crate-internal on purpose: the join relies on this handle's
/// `wait`/`Drop` running, so leaking it (`std::mem::forget`) while the
/// borrowed buffer is freed would be unsound — the public, can't-leak
/// surface is the scoped [`parallel_rows_overlap`], which joins before
/// returning.
pub(crate) struct JobHandle<'env> {
    job: Option<pool::AsyncJob>,
    /// keeps the chunk closure alive — and at a stable address — until
    /// the pool has executed every chunk
    _f: Box<dyn Fn(usize) + Sync + 'env>,
}

impl JobHandle<'_> {
    /// Block until every chunk has completed.  A panic from any chunk is
    /// re-raised here.
    pub(crate) fn wait(mut self) {
        if let Some(job) = self.job.take() {
            pool::wait_async(job, true);
        }
    }

    /// True when the job ran inline at dispatch (empty, nested, or the
    /// pool was owned by another top-level dispatcher) — there is nothing
    /// left in flight and [`JobHandle::wait`] returns immediately.
    #[cfg(test)]
    pub(crate) fn is_inline(&self) -> bool {
        self.job.is_none()
    }
}

impl Drop for JobHandle<'_> {
    fn drop(&mut self) {
        if let Some(job) = self.job.take() {
            pool::wait_async(job, !std::thread::panicking());
        }
    }
}

/// Dispatch `f(chunk)` for every chunk index in `0..chunks` on the pool
/// WITHOUT blocking: up to `workers` threads execute the chunks while the
/// caller overlaps its own work, and the returned [`JobHandle`] joins the
/// job (wait or drop).  This is the primitive behind the pipelined
/// data-parallel coordinator: two stages — one async pool job plus the
/// dispatcher's own overlapped work — in flight under one thread budget.
///
/// `budget` is split over the job's chunk slots exactly like a
/// synchronous dispatch splits the dispatcher's budget (base/base+1 over
/// `min(workers, chunks)` slots), so nested kernels inside chunks fan out
/// hierarchically.  It is explicit because the dispatching thread keeps
/// working: a caller that overlaps compute of its own passes
/// `threads() - 1`, reserving itself one thread, so both in-flight stages
/// sum to at most the root budget.
///
/// Degenerate dispatches (empty job, called from inside a pool chunk, or
/// the pool is owned by another top-level dispatcher) run inline before
/// this returns — overlap is an optimization, never a semantic change.
///
/// Crate-internal (see [`JobHandle`]); external callers use the scoped
/// [`parallel_rows_overlap`].
pub(crate) fn dispatch_async<'env>(
    chunks: usize,
    workers: usize,
    budget: usize,
    f: Box<dyn Fn(usize) + Sync + 'env>,
) -> JobHandle<'env> {
    // The pool's safety contract: the closure must outlive the job.  The
    // box pins the closure at a stable address and `JobHandle` keeps it
    // alive until wait/Drop has seen the job complete.
    let job = pool::run_async(chunks, workers, budget, &*f);
    JobHandle { job, _f: f }
}

/// Asynchronous analogue of [`parallel_rows_mut`] at one-row granularity:
/// partition `out` into whole-row blocks and dispatch `f(row_index,
/// block)` over them as a non-blocking pool job (chunk = one row, so a
/// caller with R items gets R steal slots).  The mutable borrow of `out`
/// lives in the returned handle, so the caller cannot touch the buffer
/// until the job is joined — the double-buffer discipline the pipelined
/// coordinator relies on is enforced by the borrow checker.
///
/// Crate-internal (see [`JobHandle`]); external callers use the scoped
/// [`parallel_rows_overlap`].
pub(crate) fn parallel_rows_async<'env, T, F>(
    out: &'env mut [T],
    row_len: usize,
    workers: usize,
    budget: usize,
    f: F,
) -> JobHandle<'env>
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync + 'env,
{
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    let total_len = out.len();
    let base = SendPtr(out.as_mut_ptr());
    // an undersized buffer (fewer elements than one row) is still handed
    // to `f` whole, as one chunk — mirroring `parallel_rows_mut`
    let chunks = if total_len == 0 { 0 } else { rows.max(1) };
    // PLMU_VERIFY>=1: same pre-dispatch disjointness proof as
    // `parallel_rows_mut`, for the one-row-per-chunk partition
    if chunks > 0 && crate::analyze::level() >= 1 {
        let ranges: Vec<(usize, usize)> = (0..chunks)
            .map(|ci| {
                let start = ci * row_len;
                let end = if ci + 1 >= rows { total_len } else { start + row_len };
                (start, end)
            })
            .collect();
        let findings = crate::analyze::exec_check::check_ranges(total_len, &ranges);
        assert!(findings.is_empty(), "parallel_rows_async chunk plan is unsound: {findings:?}");
    }
    let body = move |ci: usize| {
        let start = ci * row_len;
        // the last row absorbs any ragged tail beyond rows * row_len
        let end = if ci + 1 >= rows { total_len } else { start + row_len };
        // SAFETY: chunk ranges [start, end) are in-bounds, pairwise
        // disjoint, and cover the buffer exactly once; `T: Send` lets
        // the sub-slice cross to a pool thread (same argument as
        // `parallel_rows_mut`).
        let block = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(ci, block);
    };
    dispatch_async(chunks, workers, budget, Box::new(body))
}

/// Overlap two stages under one thread budget: dispatch `f(row_index,
/// block)` over `out`'s rows as an **async pool job** (one steal-chunk
/// per row, up to `workers` threads, the job's chunks sharing `budget`
/// hierarchically), run `overlapped()` on the calling thread while the
/// job computes, then join the job and return `overlapped`'s result.
/// This is the primitive behind the pipelined data-parallel coordinator
/// and the pipelined serving batcher: one in-flight pool job plus the
/// dispatcher's own stage, with `budget` typically set to
/// [`threads`]` - 1` so both stages sum to at most the root budget.
///
/// The join is unconditional — it happens before this function returns,
/// even if `overlapped` panics (the internal handle joins on unwind) —
/// so the borrowed buffer and closure can never outlive the pool's use
/// of them.  A panic from a job chunk is re-raised here after
/// `overlapped` has run.  Degenerate dispatches (empty job, nested
/// call, pool owned by another top-level dispatcher) execute `f` inline
/// before `overlapped` runs — overlap is an optimization, never a
/// semantic change.
pub fn parallel_rows_overlap<'env, T, F, G, R>(
    out: &'env mut [T],
    row_len: usize,
    workers: usize,
    budget: usize,
    f: F,
    overlapped: G,
) -> R
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync + 'env,
    G: FnOnce() -> R,
{
    let handle = parallel_rows_async(out, row_len, workers, budget, f);
    let result = overlapped();
    handle.wait();
    result
}

// ------------------------------------------------------- pool observability

/// Row/range dispatches that fanned out on the pool since the last
/// [`reset_dispatch_counts`].
static DISPATCH_POOLED: AtomicUsize = AtomicUsize::new(0);
/// Row/range dispatches that short-circuited to the serial path
/// (serial plan, single row, or a degenerate chunk count).
static DISPATCH_SERIAL: AtomicUsize = AtomicUsize::new(0);

/// Queue observability for the serving stack: how many
/// `parallel_rows_mut` / `parallel_ranges` dispatches went to the pool
/// vs. ran serially since the last [`reset_dispatch_counts`].  Returns
/// `(pooled, serial)`.  The serving bench reports these so a
/// continuous-batching configuration that silently degenerates to
/// serial dispatch (batches below `MIN_PARALLEL_WORK`) is visible in
/// `BENCH_serving.json` instead of masquerading as pool throughput.
pub fn dispatch_counts() -> (usize, usize) {
    (DISPATCH_POOLED.load(Ordering::Relaxed), DISPATCH_SERIAL.load(Ordering::Relaxed))
}

/// Zero the [`dispatch_counts`] counters.
pub fn reset_dispatch_counts() {
    DISPATCH_POOLED.store(0, Ordering::Relaxed);
    DISPATCH_SERIAL.store(0, Ordering::Relaxed);
}

/// High-water mark of concurrently busy exec threads (each OS thread
/// counted once, however deeply nested) since the last
/// [`reset_pool_peak`].  The budget invariant — pinned by
/// `rust/tests/exec_equivalence.rs` — is that a single dispatching
/// pipeline never drives this above [`threads`], even with nested
/// fan-out under hierarchical sub-budgets.
pub fn pool_peak_concurrency() -> usize {
    pool::peak_concurrency()
}

/// Reset the [`pool_peak_concurrency`] high-water mark to zero.
pub fn reset_pool_peak() {
    pool::reset_peak()
}

/// Number of persistent helper threads the pool has spawned so far
/// (excludes the dispatching caller).  Grows with unmet attach demand,
/// never shrinks; idle helpers are parked on a condvar and cost nothing.
pub fn pool_helpers() -> usize {
    pool::helper_count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::panic::AssertUnwindSafe;
    use std::sync::atomic::AtomicU64;

    /// Explicit plan shorthand for the partition tests.
    fn plan(workers: usize, chunks: usize) -> Plan {
        Plan { workers, chunks }
    }

    #[test]
    fn rows_partition_covers_exactly_once() {
        // (rows, row_len, workers, chunks) — including chunks > workers
        // (steal granularity), chunks not dividing rows (ragged tails),
        // and chunks > rows (clamped)
        for &(rows, row_len, workers, chunks) in &[
            (7usize, 3usize, 4usize, 4usize),
            (1, 5, 4, 4),
            (16, 1, 3, 3),
            (5, 2, 8, 8),
            (4, 4, 4, 4),
            (13, 3, 2, 7),
            (29, 2, 3, 12),
            (6, 5, 2, 16),
            (10, 1, 3, 10),
        ] {
            let mut out = vec![0u32; rows * row_len];
            parallel_rows_mut(&mut out, row_len, plan(workers, chunks), |r0, block| {
                for (k, row) in block.chunks_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (r0 + k + 1) as u32;
                    }
                }
            });
            // every row touched exactly once with its own index
            for r in 0..rows {
                for c in 0..row_len {
                    assert_eq!(
                        out[r * row_len + c],
                        (r + 1) as u32,
                        "rows={rows} w={workers} ch={chunks}"
                    );
                }
            }
        }
    }

    #[test]
    fn ragged_tail_is_covered() {
        // out.len() not a multiple of row_len: the tail elements beyond
        // the last whole row must still be handed to exactly one block
        let mut out = vec![0u32; 11]; // 5 rows of 2 + 1 ragged element
        parallel_rows_mut(&mut out, 2, plan(2, 4), |_, block| {
            for v in block.iter_mut() {
                *v += 1;
            }
        });
        assert!(out.iter().all(|&v| v == 1), "{out:?}");
    }

    #[test]
    fn ranges_partition_covers_exactly_once() {
        for &(n, workers, chunks) in &[
            (10usize, 3usize, 3usize),
            (1, 4, 4),
            (0, 2, 2),
            (8, 8, 8),
            (9, 2, 2),
            (17, 2, 9),
            (23, 3, 24),
        ] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_ranges(n, plan(workers, chunks), |lo, hi| {
                for i in lo..hi {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n} w={workers} ch={chunks}"
            );
        }
    }

    #[test]
    fn map_preserves_order() {
        for &(workers, chunks) in &[(1usize, 1usize), (2, 2), (3, 6), (5, 11)] {
            let v = parallel_map(11, plan(workers, chunks), |i| i * i);
            assert_eq!(v, (0..11).map(|i| i * i).collect::<Vec<_>>());
        }
        assert!(parallel_map(0, plan(4, 4), |i| i).is_empty());
    }

    #[test]
    fn plan_sizing_rules() {
        // below two workers or two items: serial
        assert!(Plan::sized(1, 100, usize::MAX).is_serial());
        assert!(Plan::sized(4, 1, usize::MAX).is_serial());
        // workers capped by items; chunks within [workers, workers*MAX]
        let p = Plan::sized(4, 3, usize::MAX);
        assert_eq!(p.workers, 3);
        assert_eq!(p.chunks, 3);
        let p = Plan::sized(4, 1 << 20, usize::MAX);
        assert_eq!(p.workers, 4);
        assert_eq!(p.chunks, 4 * MAX_CHUNKS_PER_WORKER);
        // small work: chunk count shrinks toward the worker count so the
        // claim traffic stays amortized
        let p = Plan::sized(4, 1 << 20, MIN_PARALLEL_WORK);
        assert_eq!(p.workers, 4);
        assert_eq!(p.chunks, (MIN_PARALLEL_WORK / CHUNK_WORK_TARGET).max(4));
        // chunks never exceed items
        let p = Plan::sized(2, 3, usize::MAX);
        assert!(p.chunks <= 3);
    }

    #[test]
    fn small_work_stays_serial() {
        assert!(plan_for(8, 10).is_serial());
        assert!(plan_for(1, usize::MAX).is_serial());
    }

    #[test]
    fn run_serialized_installs_unit_budget() {
        assert!(!in_parallel_region());
        run_serialized(|| {
            assert!(in_parallel_region());
            assert_eq!(budget(), 1);
            assert!(plan_for(100, usize::MAX).is_serial());
        });
        assert!(!in_parallel_region(), "budget scope leaked");
    }

    #[test]
    fn chunks_inherit_sub_budgets() {
        // a 4-chunk job splits the dispatcher's budget across its chunk
        // slots; with explicit workers == chunks == 4 every sub-budget is
        // deterministic per chunk index regardless of the global knob
        let budgets: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        parallel_ranges(4, plan(4, 4), |lo, _| {
            assert!(in_parallel_region());
            budgets[lo].store(budget() as u64, Ordering::Relaxed);
        });
        assert!(!in_parallel_region(), "budget scope leaked");
        // sub-budgets sum to at most the dispatcher's budget and are
        // spread base/base+1 by chunk index
        let total: u64 = budgets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        assert!(total >= 4, "every chunk gets at least budget 1: {total}");
        let read: Vec<u64> = budgets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        assert!(read.windows(2).all(|w| w[0] >= w[1]), "extras go to low indices: {read:?}");
    }

    #[test]
    fn pool_is_reused_across_many_dispatches() {
        // hammer the pool: helpers must be reused, results exact each time
        for round in 0..200usize {
            let n = 16 + round % 7;
            let v = parallel_map(n, Plan::sized(4, n, usize::MAX), |i| i * 3 + round);
            assert_eq!(v, (0..n).map(|i| i * 3 + round).collect::<Vec<_>>());
        }
        // demand-driven spawning keeps the pool near the worker cap even
        // though each job carries more steal chunks than workers
        assert!(pool_helpers() <= 16, "helpers {}", pool_helpers());
    }

    #[test]
    fn concurrent_dispatchers_stay_correct() {
        // several OS threads dispatching at once: one owns the pool, the
        // rest degrade to serial — every result must still be exact
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    for round in 0..50usize {
                        let v = parallel_map(13, Plan::sized(3, 13, usize::MAX), |i| {
                            i * 7 + t * 1000 + round
                        });
                        let want: Vec<usize> =
                            (0..13).map(|i| i * 7 + t * 1000 + round).collect();
                        assert_eq!(v, want);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn panic_in_chunk_propagates_and_pool_survives() {
        let r = std::panic::catch_unwind(|| {
            parallel_ranges(8, plan(4, 8), |lo, _| {
                if lo >= 4 {
                    panic!("chunk boom");
                }
            });
        });
        assert!(r.is_err(), "panic was swallowed");
        // the pool must remain fully usable after a failed job
        let v = parallel_map(9, plan(3, 9), |i| i + 1);
        assert_eq!(v, (1..=9).collect::<Vec<_>>());
    }

    #[test]
    fn panic_in_stolen_fine_grained_chunk_propagates() {
        // many more chunks than workers, the failure deep in the steal
        // stream: whichever thread steals it, the panic must surface on
        // the dispatcher and the remaining chunks must be abandoned
        // without wedging the pool
        for _ in 0..20 {
            let r = std::panic::catch_unwind(|| {
                parallel_ranges(64, plan(2, 16), |lo, _| {
                    if lo >= 32 {
                        panic!("stolen chunk boom");
                    }
                });
            });
            assert!(r.is_err(), "panic was swallowed");
        }
        let v = parallel_map(9, plan(3, 9), |i| i + 1);
        assert_eq!(v, (1..=9).collect::<Vec<_>>());
    }

    #[test]
    fn async_rows_complete_and_exact() {
        for &(rows, row_len, workers) in
            &[(8usize, 3usize, 3usize), (1, 4, 2), (5, 2, 8), (16, 1, 2)]
        {
            let mut out = vec![0u32; rows * row_len];
            let handle = parallel_rows_async(&mut out, row_len, workers, workers, |r0, block| {
                for (k, row) in block.chunks_mut(row_len.max(1)).enumerate() {
                    for v in row.iter_mut() {
                        *v += (r0 + k + 1) as u32;
                    }
                }
            });
            handle.wait();
            for r in 0..rows {
                for c in 0..row_len {
                    assert_eq!(out[r * row_len + c], (r + 1) as u32, "rows={rows} w={workers}");
                }
            }
        }
        // empty buffer: nothing dispatched, nothing to wait for
        let mut empty: Vec<u32> = Vec::new();
        let h = parallel_rows_async(&mut empty, 1, 2, 2, |_, _| panic!("empty job ran"));
        assert!(h.is_inline());
        h.wait();
    }

    #[test]
    fn async_job_overlaps_with_dispatcher_work() {
        use std::sync::atomic::AtomicBool;
        // the job's chunks park until the DISPATCHER flips a flag after
        // dispatch returns — completing at all proves the dispatcher got
        // control back while the job was in flight.  A sibling test may
        // own the pool (the dispatch then degrades to inline and cannot
        // prove overlap), so retry until a genuinely async round runs.
        let mut proven = false;
        for _ in 0..5 {
            let released = AtomicBool::new(false);
            let mut out = vec![0u32; 4];
            let deadline = std::time::Instant::now() + std::time::Duration::from_secs(2);
            let handle = parallel_rows_async(&mut out, 1, 2, 2, |_, block| {
                while !released.load(Ordering::Relaxed) {
                    if std::time::Instant::now() > deadline {
                        return; // watchdog: fail the assertion below, not CI
                    }
                    std::thread::yield_now();
                }
                for v in block.iter_mut() {
                    *v = 1;
                }
            });
            if handle.is_inline() {
                continue; // pool contended — this round proved nothing
            }
            // dispatcher-side overlapped "optimizer stage"
            released.store(true, Ordering::Relaxed);
            handle.wait();
            assert_eq!(out, vec![1, 1, 1, 1], "chunks never saw the dispatcher's release");
            proven = true;
            break;
        }
        assert!(proven, "pool stayed contended across every retry; overlap never observed");
    }

    #[test]
    fn async_panic_propagates_on_wait_and_pool_survives() {
        let mut out = vec![0u32; 8];
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let handle = parallel_rows_async(&mut out, 1, 2, 2, |r0, _| {
                if r0 >= 4 {
                    panic!("async chunk boom");
                }
            });
            handle.wait();
        }));
        assert!(r.is_err(), "async panic was swallowed");
        let v = parallel_map(9, plan(3, 9), |i| i + 1);
        assert_eq!(v, (1..=9).collect::<Vec<_>>());
    }

    #[test]
    fn async_drop_without_wait_joins_the_job() {
        let mut out = vec![0u32; 12];
        {
            let _handle = parallel_rows_async(&mut out, 1, 3, 3, |r0, block| {
                for v in block.iter_mut() {
                    *v = r0 as u32 + 7;
                }
            });
            // handle dropped here without wait(): Drop must block until
            // every chunk has run (the closure dies with this scope)
        }
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i as u32 + 7);
        }
    }

    #[test]
    fn async_from_inside_chunk_runs_inline() {
        // nested async cannot overlap (the chunk is the caller's work):
        // it must run inline and be complete by the time dispatch returns
        parallel_ranges(2, plan(2, 2), |_, _| {
            let mut out = vec![0u32; 4];
            let h = parallel_rows_async(&mut out, 1, 2, 2, |_, block| {
                for v in block.iter_mut() {
                    *v = 9;
                }
            });
            assert!(h.is_inline());
            drop(h);
            assert_eq!(out, vec![9, 9, 9, 9]);
        });
    }

    #[test]
    fn rows_overlap_runs_both_stages_and_returns_result() {
        let mut out = vec![0u32; 6];
        let mut side = 0u32;
        let got = parallel_rows_overlap(
            &mut out,
            1,
            2,
            2,
            |r0, block| {
                for v in block.iter_mut() {
                    *v = r0 as u32 + 1;
                }
            },
            || {
                side = 7; // the dispatcher-side stage
                41 + 1
            },
        );
        assert_eq!(got, 42);
        assert_eq!(side, 7);
        assert_eq!(out, vec![1, 2, 3, 4, 5, 6]);
        // a chunk panic surfaces from the combinator (on the async path
        // it is re-raised at the internal join, after the overlapped
        // stage; on a contended pool the inline dispatch raises it
        // directly — either way it must not be swallowed)
        let mut out = vec![0u32; 4];
        let r = std::panic::catch_unwind(AssertUnwindSafe(|| {
            parallel_rows_overlap(
                &mut out,
                1,
                2,
                2,
                |r0, _| {
                    if r0 >= 2 {
                        panic!("overlap chunk boom");
                    }
                },
                || {},
            );
        }));
        assert!(r.is_err(), "chunk panic was swallowed by the combinator");
    }

    #[test]
    fn pool_reuse_after_idle_does_not_deadlock() {
        // regression: dispatch a job, let every helper park on the
        // condvar, then dispatch again from a DIFFERENT thread — helper
        // reuse after an idle period must hand off cleanly rather than
        // waiting on a wakeup that never comes
        let v = parallel_map(16, plan(4, 8), |i| i * 2);
        assert_eq!(v, (0..16).map(|i| i * 2).collect::<Vec<_>>());
        std::thread::sleep(std::time::Duration::from_millis(60)); // helpers park
        let other = std::thread::spawn(|| {
            let v = parallel_map(16, plan(4, 8), |i| i * 3);
            assert_eq!(v, (0..16).map(|i| i * 3).collect::<Vec<_>>());
        });
        other.join().expect("dispatch from a second thread failed after idle");
        // and again from this thread, against helpers that just worked
        // for someone else
        std::thread::sleep(std::time::Duration::from_millis(60));
        let v = parallel_map(11, plan(3, 6), |i| i + 5);
        assert_eq!(v, (5..16).collect::<Vec<_>>());
    }

    #[test]
    fn peak_concurrency_is_tracked() {
        // at least the dispatching thread is counted while a job runs
        reset_pool_peak();
        parallel_ranges(64, plan(4, 8), |lo, hi| {
            std::hint::black_box((lo..hi).sum::<usize>());
        });
        assert!(pool_peak_concurrency() >= 1);
        // (the exact upper bound is pinned by exec_equivalence.rs, which
        // owns the global thread knob; unit tests here may run
        // concurrently with each other so only the lower bound is safe)
    }
}
