//! Thread-parallel execution substrate for the native compute hot paths.
//!
//! The paper's entire point is that the LMU's frozen LTI memory removes
//! the sequential dependency from training, leaving big, embarrassingly
//! parallel batched kernels (matmul, FFT causal convolution, elementwise
//! maps).  This module is the single place that turns that latent
//! parallelism into wall-clock speedup on CPU: a **work-stealing,
//! budget-aware scheduler** over a persistent parked worker pool (see
//! `pool.rs` — plain `Mutex`/`Condvar`/atomics, no crate dependencies,
//! builds offline) with a global thread-count knob plumbed through the
//! CLI (`--threads`), config (`[train] threads`), and environment
//! (`PLMU_THREADS`).
//!
//! Design rules every dispatch site follows:
//!
//!  * **Bit-exact equivalence.**  Work is partitioned over *output* rows
//!    (or independent items); each element is computed by exactly the same
//!    sequence of floating-point operations as the serial reference, so
//!    results are identical for every thread count AND every chunk
//!    granularity — which thread steals which chunk never matters.
//!    `threads = 1` (or any job below [`MIN_PARALLEL_WORK`]) takes the
//!    serial path outright.  `rust/tests/exec_equivalence.rs` pins this.
//!  * **Work stealing.**  A [`Plan`] splits a job into more chunks than
//!    workers (targeting ~[`CHUNK_WORK_TARGET`] scalar ops per chunk, so
//!    the one-atomic-op claim stays below ~5% of chunk runtime); threads
//!    claim chunks off an atomic counter, smoothing ragged tails and
//!    uneven per-row costs that a static `rows.div_ceil(workers)`
//!    partition would stall on.
//!  * **Hierarchical budgets.**  Every thread carries a parallelism
//!    budget ([`budget`]): the global knob at top level, a *sub-budget*
//!    inside a pool chunk.  A parallel region entered with `R` chunk
//!    slots hands each chunk `budget / R`, so a data-parallel run with 2
//!    replicas on 8 threads drives 4 threads' worth of nested kernel
//!    fan-out per replica — nested dispatch is a first-class pool job,
//!    not a degenerate serial path — while the busy-thread high-water
//!    mark of the whole tree never exceeds the root budget.  A chunk
//!    whose sub-budget is 1 (the common case when chunks >= threads)
//!    runs nested kernels serially, exactly like the old region flag.
//!  * **Threshold-gated.**  Jobs smaller than [`MIN_PARALLEL_WORK`] scalar
//!    ops stay serial.  With the persistent pool a dispatch is a parked
//!    hand-off (~1µs) instead of a thread spawn (~10µs) — the crossover
//!    measured by `cargo bench --bench pool_crossover`.

mod pool;

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Global worker-count knob.  0 = unresolved (first read resolves the
/// default from `PLMU_THREADS` or the machine's parallelism).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Default cap: beyond this, memory bandwidth dominates for the shapes
/// these models use.
const DEFAULT_MAX_THREADS: usize = 8;

/// Minimum total scalar ops before a kernel fans out.  A parked-pool
/// hand-off costs ~1µs (versus ~10µs for the scoped-spawn substrate this
/// replaced, whose threshold was `1 << 18`); `cargo bench --bench
/// pool_crossover` measures the crossover and writes `BENCH_pool.json`.
pub const MIN_PARALLEL_WORK: usize = 1 << 14;

/// Target scalar ops per work-stealing chunk (~a few µs of kernel time),
/// sized so the per-chunk claim — one atomic `fetch_add`, ~0.1µs with
/// cache-line traffic — stays below ~5% of chunk runtime.
pub const CHUNK_WORK_TARGET: usize = 1 << 12;

/// Steal-granularity cap: a [`Plan`] never carries more than this many
/// chunks per worker, bounding total claim traffic per job.
pub const MAX_CHUNKS_PER_WORKER: usize = 8;

fn resolve_default() -> usize {
    if let Ok(v) = std::env::var("PLMU_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, DEFAULT_MAX_THREADS)
}

/// The configured worker count (resolving the default on first use).
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let d = resolve_default();
    // racy double-resolve is benign: resolve_default is deterministic
    THREADS.store(d, Ordering::Relaxed);
    d
}

/// Set the worker count (clamped to >= 1).  1 selects the serial
/// reference path everywhere.  Raising the knob grows the pool lazily on
/// the next dispatch; lowering it caps future dispatches (already-spawned
/// helpers park and stay idle).
pub fn set_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

/// Sentinel for "no budget installed": fall back to the global knob.
const BUDGET_UNSET: usize = usize::MAX;

thread_local! {
    /// Parallelism budget of the current thread (see [`budget`]).
    static BUDGET: Cell<usize> = const { Cell::new(BUDGET_UNSET) };
    /// Pool-chunk nesting depth of the current thread (0 = not inside a
    /// pool chunk; used for busy-thread accounting and to route nested
    /// dispatch past the top-level admission gate).
    static DEPTH: Cell<usize> = const { Cell::new(0) };
}

/// The parallelism budget of the current thread: how many threads a
/// kernel dispatched *from this thread* may occupy, itself included.
///
/// Top-level threads get the global [`threads`] knob.  Inside a pool
/// chunk this is the chunk's sub-budget (the dispatcher's budget divided
/// over the job's concurrent chunk slots); inside [`run_serialized`] it
/// is 1.  [`plan_for`] caps every plan at this value, which is what makes
/// the budget hierarchical: sub-budgets of concurrently running chunks
/// never sum past the root budget.
pub fn budget() -> usize {
    let b = BUDGET.with(|c| c.get());
    if b == BUDGET_UNSET {
        threads()
    } else {
        b
    }
}

/// Pool-chunk nesting depth of the current thread.
fn chunk_depth() -> usize {
    DEPTH.with(|c| c.get())
}

/// True while the current thread is executing inside a parallel region
/// (a pool chunk or a [`run_serialized`] scope) — i.e. whenever a budget
/// other than the global knob is installed.
pub fn in_parallel_region() -> bool {
    BUDGET.with(|c| c.get()) != BUDGET_UNSET
}

/// RAII scope installing a chunk's sub-budget (and, for real pool
/// chunks, the nesting depth used by busy accounting).
struct ChunkGuard {
    prev_budget: usize,
    raised_depth: bool,
}

impl Drop for ChunkGuard {
    fn drop(&mut self) {
        if self.raised_depth {
            DEPTH.with(|c| c.set(c.get() - 1));
        }
        BUDGET.with(|c| c.set(self.prev_budget));
    }
}

/// Enter a pool-chunk scope with the given sub-budget (pool.rs calls this
/// around every chunk execution and serial-degraded job).
fn enter_chunk(sub_budget: usize) -> ChunkGuard {
    let prev_budget = BUDGET.with(|c| c.replace(sub_budget.max(1)));
    DEPTH.with(|c| c.set(c.get() + 1));
    ChunkGuard { prev_budget, raised_depth: true }
}

/// Run `f` with kernel-level parallel dispatch disabled on the current
/// thread: every [`plan_for`] inside reports serial.  For
/// code that manages its own thread-level parallelism (e.g. engines
/// constructed on thread-bound batcher threads) so external thread counts
/// and kernel threads don't multiply.
pub fn run_serialized<R>(f: impl FnOnce() -> R) -> R {
    let prev_budget = BUDGET.with(|c| c.replace(1));
    let _g = ChunkGuard { prev_budget, raised_depth: false };
    f()
}

/// A dispatch plan: how many threads may work a job at once, and how many
/// steal-granularity chunks the job is split into.
///
/// `workers` is the concurrency share (capped at the dispatching thread's
/// [`budget`] by [`plan_for`]); `chunks >= workers` adds steal slots
/// without adding threads, so uneven per-chunk costs smooth out.  The
/// partition a plan induces depends only on `(rows, chunks)` — never on
/// which thread steals which chunk — so results stay bit-exact.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Plan {
    /// max threads working the job concurrently
    pub workers: usize,
    /// total claimable chunks (`1` = the serial reference path)
    pub chunks: usize,
}

impl Plan {
    /// The serial reference path: one worker, one chunk, no pool dispatch.
    pub const SERIAL: Plan = Plan { workers: 1, chunks: 1 };

    /// True when this plan takes the serial path outright.
    pub fn is_serial(&self) -> bool {
        self.workers <= 1 || self.chunks <= 1
    }

    /// Plan for an explicit worker count (benches and tests; production
    /// call sites should use [`plan_for`], which reads the budget):
    /// chunks target [`CHUNK_WORK_TARGET`] scalar ops each, clamped to
    /// `[workers, workers * MAX_CHUNKS_PER_WORKER]` and the item count.
    pub fn sized(workers: usize, items: usize, work: usize) -> Plan {
        if workers <= 1 || items <= 1 {
            return Plan::SERIAL;
        }
        let workers = workers.min(items);
        let by_work = work / CHUNK_WORK_TARGET;
        let chunks =
            by_work.clamp(workers, workers.saturating_mul(MAX_CHUNKS_PER_WORKER)).min(items);
        Plan { workers, chunks }
    }

    /// A static one-chunk-per-worker partition (the pre-work-stealing
    /// scheduler's granularity; kept for A/B benchmarking).
    pub fn static_partition(workers: usize) -> Plan {
        Plan { workers: workers.max(1), chunks: workers.max(1) }
    }
}

/// Dispatch plan for a job of `items` independent units totalling `work`
/// scalar ops: workers = the current thread's [`budget`] capped by the
/// item count, serial when the job is too small or the budget is 1.
pub fn plan_for(items: usize, work: usize) -> Plan {
    let b = budget();
    if b <= 1 || items <= 1 || work < MIN_PARALLEL_WORK {
        return Plan::SERIAL;
    }
    Plan::sized(b, items, work)
}

/// Raw-pointer wrapper that lets disjoint sub-slices of one buffer be
/// handed to pool workers.  Soundness relies on the chunk ranges being
/// disjoint (they partition the buffer) and on `T: Send`.
struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Partition `out` into chunk blocks of whole rows (`row_len` elements
/// each) per `plan` and run `f(first_row_index, block)` on each block on
/// the work-stealing pool, with the calling thread participating.
///
/// A serial plan (or a single row) short-circuits to `f(0, out)` with no
/// pool dispatch and no budget change — the serial reference path.  The
/// block partition depends only on `(rows, plan.chunks)`, never on which
/// pool thread steals which block, so results are bit-exact at every
/// thread count and granularity.
pub fn parallel_rows_mut<T, F>(out: &mut [T], row_len: usize, plan: Plan, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    if plan.is_serial() || rows <= 1 {
        f(0, out);
        return;
    }
    let chunk_rows = rows.div_ceil(plan.chunks.min(rows));
    let chunks = rows.div_ceil(chunk_rows);
    if chunks <= 1 {
        f(0, out);
        return;
    }
    let total_len = out.len();
    let base = SendPtr(out.as_mut_ptr());
    pool::run(chunks, plan.workers, &|ci| {
        let start = ci * chunk_rows * row_len;
        // the last chunk absorbs any ragged tail beyond rows * row_len
        let end = if ci + 1 == chunks { total_len } else { start + chunk_rows * row_len };
        // SAFETY: chunk ranges [start, end) are in-bounds, pairwise
        // disjoint, and cover the buffer exactly once; `T: Send` lets the
        // sub-slice cross to a pool thread.
        let block = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(ci * chunk_rows, block);
    });
}

/// Run `f(lo, hi)` over a partition of `0..n` into `plan.chunks`
/// contiguous ranges on the work-stealing pool (calling thread
/// participating).  For jobs whose output is not one contiguous mutable
/// slice.
pub fn parallel_ranges<F>(n: usize, plan: Plan, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if plan.is_serial() || n <= 1 {
        if n > 0 {
            f(0, n);
        }
        return;
    }
    let chunk = n.div_ceil(plan.chunks.min(n));
    let chunks = n.div_ceil(chunk);
    if chunks <= 1 {
        f(0, n);
        return;
    }
    pool::run(chunks, plan.workers, &|ci| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(n);
        f(lo, hi);
    });
}

/// Order-preserving parallel map: `out[i] = f(i)` for `i in 0..n`.
pub fn parallel_map<T, F>(n: usize, plan: Plan, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if plan.is_serial() || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    parallel_rows_mut(&mut out, 1, plan, |i0, block| {
        for (k, slot) in block.iter_mut().enumerate() {
            *slot = Some(f(i0 + k));
        }
    });
    out.into_iter().map(|v| v.expect("parallel_map: slot unfilled")).collect()
}

// ------------------------------------------------------- pool observability

/// High-water mark of concurrently busy exec threads (each OS thread
/// counted once, however deeply nested) since the last
/// [`reset_pool_peak`].  The budget invariant — pinned by
/// `rust/tests/exec_equivalence.rs` — is that a single dispatching
/// pipeline never drives this above [`threads`], even with nested
/// fan-out under hierarchical sub-budgets.
pub fn pool_peak_concurrency() -> usize {
    pool::peak_concurrency()
}

/// Reset the [`pool_peak_concurrency`] high-water mark to zero.
pub fn reset_pool_peak() {
    pool::reset_peak()
}

/// Number of persistent helper threads the pool has spawned so far
/// (excludes the dispatching caller).  Grows with unmet attach demand,
/// never shrinks; idle helpers are parked on a condvar and cost nothing.
pub fn pool_helpers() -> usize {
    pool::helper_count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    /// Explicit plan shorthand for the partition tests.
    fn plan(workers: usize, chunks: usize) -> Plan {
        Plan { workers, chunks }
    }

    #[test]
    fn rows_partition_covers_exactly_once() {
        // (rows, row_len, workers, chunks) — including chunks > workers
        // (steal granularity), chunks not dividing rows (ragged tails),
        // and chunks > rows (clamped)
        for &(rows, row_len, workers, chunks) in &[
            (7usize, 3usize, 4usize, 4usize),
            (1, 5, 4, 4),
            (16, 1, 3, 3),
            (5, 2, 8, 8),
            (4, 4, 4, 4),
            (13, 3, 2, 7),
            (29, 2, 3, 12),
            (6, 5, 2, 16),
            (10, 1, 3, 10),
        ] {
            let mut out = vec![0u32; rows * row_len];
            parallel_rows_mut(&mut out, row_len, plan(workers, chunks), |r0, block| {
                for (k, row) in block.chunks_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (r0 + k + 1) as u32;
                    }
                }
            });
            // every row touched exactly once with its own index
            for r in 0..rows {
                for c in 0..row_len {
                    assert_eq!(
                        out[r * row_len + c],
                        (r + 1) as u32,
                        "rows={rows} w={workers} ch={chunks}"
                    );
                }
            }
        }
    }

    #[test]
    fn ragged_tail_is_covered() {
        // out.len() not a multiple of row_len: the tail elements beyond
        // the last whole row must still be handed to exactly one block
        let mut out = vec![0u32; 11]; // 5 rows of 2 + 1 ragged element
        parallel_rows_mut(&mut out, 2, plan(2, 4), |_, block| {
            for v in block.iter_mut() {
                *v += 1;
            }
        });
        assert!(out.iter().all(|&v| v == 1), "{out:?}");
    }

    #[test]
    fn ranges_partition_covers_exactly_once() {
        for &(n, workers, chunks) in &[
            (10usize, 3usize, 3usize),
            (1, 4, 4),
            (0, 2, 2),
            (8, 8, 8),
            (9, 2, 2),
            (17, 2, 9),
            (23, 3, 24),
        ] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_ranges(n, plan(workers, chunks), |lo, hi| {
                for i in lo..hi {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "n={n} w={workers} ch={chunks}"
            );
        }
    }

    #[test]
    fn map_preserves_order() {
        for &(workers, chunks) in &[(1usize, 1usize), (2, 2), (3, 6), (5, 11)] {
            let v = parallel_map(11, plan(workers, chunks), |i| i * i);
            assert_eq!(v, (0..11).map(|i| i * i).collect::<Vec<_>>());
        }
        assert!(parallel_map(0, plan(4, 4), |i| i).is_empty());
    }

    #[test]
    fn plan_sizing_rules() {
        // below two workers or two items: serial
        assert!(Plan::sized(1, 100, usize::MAX).is_serial());
        assert!(Plan::sized(4, 1, usize::MAX).is_serial());
        // workers capped by items; chunks within [workers, workers*MAX]
        let p = Plan::sized(4, 3, usize::MAX);
        assert_eq!(p.workers, 3);
        assert_eq!(p.chunks, 3);
        let p = Plan::sized(4, 1 << 20, usize::MAX);
        assert_eq!(p.workers, 4);
        assert_eq!(p.chunks, 4 * MAX_CHUNKS_PER_WORKER);
        // small work: chunk count shrinks toward the worker count so the
        // claim traffic stays amortized
        let p = Plan::sized(4, 1 << 20, MIN_PARALLEL_WORK);
        assert_eq!(p.workers, 4);
        assert_eq!(p.chunks, (MIN_PARALLEL_WORK / CHUNK_WORK_TARGET).max(4));
        // chunks never exceed items
        let p = Plan::sized(2, 3, usize::MAX);
        assert!(p.chunks <= 3);
    }

    #[test]
    fn small_work_stays_serial() {
        assert!(plan_for(8, 10).is_serial());
        assert!(plan_for(1, usize::MAX).is_serial());
    }

    #[test]
    fn run_serialized_installs_unit_budget() {
        assert!(!in_parallel_region());
        run_serialized(|| {
            assert!(in_parallel_region());
            assert_eq!(budget(), 1);
            assert!(plan_for(100, usize::MAX).is_serial());
        });
        assert!(!in_parallel_region(), "budget scope leaked");
    }

    #[test]
    fn chunks_inherit_sub_budgets() {
        // a 4-chunk job splits the dispatcher's budget across its chunk
        // slots; with explicit workers == chunks == 4 every sub-budget is
        // deterministic per chunk index regardless of the global knob
        let budgets: Vec<AtomicU64> = (0..4).map(|_| AtomicU64::new(0)).collect();
        parallel_ranges(4, plan(4, 4), |lo, _| {
            assert!(in_parallel_region());
            budgets[lo].store(budget() as u64, Ordering::Relaxed);
        });
        assert!(!in_parallel_region(), "budget scope leaked");
        // sub-budgets sum to at most the dispatcher's budget and are
        // spread base/base+1 by chunk index
        let total: u64 = budgets.iter().map(|b| b.load(Ordering::Relaxed)).sum();
        assert!(total >= 4, "every chunk gets at least budget 1: {total}");
        let read: Vec<u64> = budgets.iter().map(|b| b.load(Ordering::Relaxed)).collect();
        assert!(read.windows(2).all(|w| w[0] >= w[1]), "extras go to low indices: {read:?}");
    }

    #[test]
    fn pool_is_reused_across_many_dispatches() {
        // hammer the pool: helpers must be reused, results exact each time
        for round in 0..200usize {
            let n = 16 + round % 7;
            let v = parallel_map(n, Plan::sized(4, n, usize::MAX), |i| i * 3 + round);
            assert_eq!(v, (0..n).map(|i| i * 3 + round).collect::<Vec<_>>());
        }
        // demand-driven spawning keeps the pool near the worker cap even
        // though each job carries more steal chunks than workers
        assert!(pool_helpers() <= 16, "helpers {}", pool_helpers());
    }

    #[test]
    fn concurrent_dispatchers_stay_correct() {
        // several OS threads dispatching at once: one owns the pool, the
        // rest degrade to serial — every result must still be exact
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    for round in 0..50usize {
                        let v = parallel_map(13, Plan::sized(3, 13, usize::MAX), |i| {
                            i * 7 + t * 1000 + round
                        });
                        let want: Vec<usize> =
                            (0..13).map(|i| i * 7 + t * 1000 + round).collect();
                        assert_eq!(v, want);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn panic_in_chunk_propagates_and_pool_survives() {
        let r = std::panic::catch_unwind(|| {
            parallel_ranges(8, plan(4, 8), |lo, _| {
                if lo >= 4 {
                    panic!("chunk boom");
                }
            });
        });
        assert!(r.is_err(), "panic was swallowed");
        // the pool must remain fully usable after a failed job
        let v = parallel_map(9, plan(3, 9), |i| i + 1);
        assert_eq!(v, (1..=9).collect::<Vec<_>>());
    }

    #[test]
    fn panic_in_stolen_fine_grained_chunk_propagates() {
        // many more chunks than workers, the failure deep in the steal
        // stream: whichever thread steals it, the panic must surface on
        // the dispatcher and the remaining chunks must be abandoned
        // without wedging the pool
        for _ in 0..20 {
            let r = std::panic::catch_unwind(|| {
                parallel_ranges(64, plan(2, 16), |lo, _| {
                    if lo >= 32 {
                        panic!("stolen chunk boom");
                    }
                });
            });
            assert!(r.is_err(), "panic was swallowed");
        }
        let v = parallel_map(9, plan(3, 9), |i| i + 1);
        assert_eq!(v, (1..=9).collect::<Vec<_>>());
    }

    #[test]
    fn peak_concurrency_is_tracked() {
        // at least the dispatching thread is counted while a job runs
        reset_pool_peak();
        parallel_ranges(64, plan(4, 8), |lo, hi| {
            std::hint::black_box((lo..hi).sum::<usize>());
        });
        assert!(pool_peak_concurrency() >= 1);
        // (the exact upper bound is pinned by exec_equivalence.rs, which
        // owns the global thread knob; unit tests here may run
        // concurrently with each other so only the lower bound is safe)
    }
}
