//! Thread-parallel execution substrate for the native compute hot paths.
//!
//! The paper's entire point is that the LMU's frozen LTI memory removes
//! the sequential dependency from training, leaving big, embarrassingly
//! parallel batched kernels (matmul, FFT causal convolution, elementwise
//! maps).  This module is the single place that turns that latent
//! parallelism into wall-clock speedup on CPU: a row-partition executor
//! backed by a **persistent parked worker pool** (see `pool.rs` — plain
//! `Mutex`/`Condvar`, no crate dependencies, builds are offline) with a
//! global thread-count knob plumbed through the CLI (`--threads`), config
//! (`[train] threads`), and environment (`PLMU_THREADS`).
//!
//! Design rules every dispatch site follows:
//!
//!  * **Bit-exact equivalence.**  Work is partitioned over *output* rows
//!    (or independent items); each element is computed by exactly the same
//!    sequence of floating-point operations as the serial reference, so
//!    results are identical for every thread count.  `threads = 1` (or any
//!    job below [`MIN_PARALLEL_WORK`]) takes the serial path outright.
//!    The `rust/tests/exec_equivalence.rs` suite pins this.
//!  * **No nested fan-out.**  A worker that calls back into a parallel
//!    kernel (e.g. per-sample DN conv → per-channel FFT) runs it serially:
//!    [`workers_for`] returns 1 inside a parallel region, bounding live
//!    compute threads at the configured count.  The data-parallel
//!    coordinator and the serving batcher dispatch *their* fan-out through
//!    this same pool, so replica-level and kernel-level parallelism share
//!    one budget instead of multiplying.
//!  * **Threshold-gated.**  Jobs smaller than [`MIN_PARALLEL_WORK`] scalar
//!    ops stay serial.  With the persistent pool a dispatch is a parked
//!    hand-off (~1µs) instead of a thread spawn (~10µs), so the threshold
//!    sits an order of magnitude lower than the scoped-spawn substrate's —
//!    the crossover measured by `cargo bench --bench pool_crossover`.

mod pool;

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Global worker-count knob.  0 = unresolved (first read resolves the
/// default from `PLMU_THREADS` or the machine's parallelism).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Default cap: beyond this, memory bandwidth dominates for the shapes
/// these models use.
const DEFAULT_MAX_THREADS: usize = 8;

/// Minimum total scalar ops before a kernel fans out.  A parked-pool
/// hand-off costs ~1µs (versus ~10µs for the scoped-spawn substrate this
/// replaced, whose threshold was `1 << 18`); `cargo bench --bench
/// pool_crossover` measures the crossover and writes `BENCH_pool.json`.
pub const MIN_PARALLEL_WORK: usize = 1 << 14;

fn resolve_default() -> usize {
    if let Ok(v) = std::env::var("PLMU_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, DEFAULT_MAX_THREADS)
}

/// The configured worker count (resolving the default on first use).
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let d = resolve_default();
    // racy double-resolve is benign: resolve_default is deterministic
    THREADS.store(d, Ordering::Relaxed);
    d
}

/// Set the worker count (clamped to >= 1).  1 selects the serial
/// reference path everywhere.  Raising the knob grows the pool lazily on
/// the next dispatch; lowering it caps future dispatches (already-spawned
/// helpers park and stay idle).
pub fn set_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

thread_local! {
    static IN_PARALLEL: Cell<bool> = const { Cell::new(false) };
}

/// True while the current thread is executing inside a parallel region
/// (used to serialize nested kernels).
pub fn in_parallel_region() -> bool {
    IN_PARALLEL.with(|c| c.get())
}

struct RegionGuard(bool);

impl Drop for RegionGuard {
    fn drop(&mut self) {
        IN_PARALLEL.with(|c| c.set(self.0));
    }
}

fn enter_region() -> RegionGuard {
    RegionGuard(IN_PARALLEL.with(|c| c.replace(true)))
}

/// Run `f` with kernel-level parallel dispatch disabled on the current
/// thread: every `workers_for` inside reports 1.  For code that manages
/// its own thread-level parallelism (e.g. engines constructed on
/// thread-bound batcher threads) so external thread counts and kernel
/// threads don't multiply.
pub fn run_serialized<R>(f: impl FnOnce() -> R) -> R {
    let _g = enter_region();
    f()
}

/// Worker count for a job of `items` independent units totalling `work`
/// scalar ops: the global knob, capped by the item count, 1 when the job
/// is too small or we are already inside a parallel region.
pub fn workers_for(items: usize, work: usize) -> usize {
    if in_parallel_region() {
        return 1;
    }
    let t = threads();
    if t <= 1 || items <= 1 || work < MIN_PARALLEL_WORK {
        return 1;
    }
    t.min(items)
}

/// Raw-pointer wrapper that lets disjoint sub-slices of one buffer be
/// handed to pool workers.  Soundness relies on the chunk ranges being
/// disjoint (they partition the buffer) and on `T: Send`.
struct SendPtr<T>(*mut T);

unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}

/// Partition `out` into per-worker blocks of whole rows (`row_len`
/// elements each) and run `f(first_row_index, block)` on each block, on
/// the persistent worker pool with the calling thread participating.
///
/// `workers <= 1` (or a single row) short-circuits to `f(0, out)` with no
/// pool dispatch and no region flag — the serial reference path.  The
/// block partition depends only on `(rows, workers)`, never on which pool
/// thread runs which block, so results are bit-exact at every thread
/// count.
pub fn parallel_rows_mut<T, F>(out: &mut [T], row_len: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    if workers <= 1 || rows <= 1 {
        f(0, out);
        return;
    }
    let workers = workers.min(rows);
    let chunk_rows = rows.div_ceil(workers);
    let chunks = rows.div_ceil(chunk_rows);
    if chunks <= 1 {
        f(0, out);
        return;
    }
    let total_len = out.len();
    let base = SendPtr(out.as_mut_ptr());
    pool::run(chunks, &|ci| {
        let start = ci * chunk_rows * row_len;
        // the last chunk absorbs any ragged tail beyond rows * row_len
        let end = if ci + 1 == chunks { total_len } else { start + chunk_rows * row_len };
        // SAFETY: chunk ranges [start, end) are in-bounds, pairwise
        // disjoint, and cover the buffer exactly once; `T: Send` lets the
        // sub-slice cross to a pool thread.
        let block = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), end - start) };
        f(ci * chunk_rows, block);
    });
}

/// Run `f(lo, hi)` over a partition of `0..n` into `workers` contiguous
/// ranges on the persistent worker pool (calling thread participating).
/// For jobs whose output is not one contiguous mutable slice.
pub fn parallel_ranges<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if workers <= 1 || n <= 1 {
        if n > 0 {
            f(0, n);
        }
        return;
    }
    let workers = workers.min(n);
    let chunk = n.div_ceil(workers);
    let chunks = n.div_ceil(chunk);
    if chunks <= 1 {
        f(0, n);
        return;
    }
    pool::run(chunks, &|ci| {
        let lo = ci * chunk;
        let hi = ((ci + 1) * chunk).min(n);
        f(lo, hi);
    });
}

/// Order-preserving parallel map: `out[i] = f(i)` for `i in 0..n`.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    parallel_rows_mut(&mut out, 1, workers, |i0, block| {
        for (k, slot) in block.iter_mut().enumerate() {
            *slot = Some(f(i0 + k));
        }
    });
    out.into_iter().map(|v| v.expect("parallel_map: slot unfilled")).collect()
}

// ------------------------------------------------------- pool observability

/// High-water mark of concurrently busy exec threads (pool workers, the
/// dispatching caller, and serial-fallback callers) since the last
/// [`reset_pool_peak`].  The budget invariant — pinned by
/// `rust/tests/exec_equivalence.rs` — is that a single dispatching
/// pipeline never drives this above [`threads`].
pub fn pool_peak_concurrency() -> usize {
    pool::peak_concurrency()
}

/// Reset the [`pool_peak_concurrency`] high-water mark to zero.
pub fn reset_pool_peak() {
    pool::reset_peak()
}

/// Number of persistent helper threads the pool has spawned so far
/// (excludes the dispatching caller).  Grows lazily with demand, never
/// shrinks; idle helpers are parked on a condvar and cost nothing.
pub fn pool_helpers() -> usize {
    pool::helper_count()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn rows_partition_covers_exactly_once() {
        for &(rows, row_len, workers) in
            &[(7usize, 3usize, 4usize), (1, 5, 4), (16, 1, 3), (5, 2, 8), (4, 4, 4)]
        {
            let mut out = vec![0u32; rows * row_len];
            parallel_rows_mut(&mut out, row_len, workers, |r0, block| {
                for (k, row) in block.chunks_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (r0 + k + 1) as u32;
                    }
                }
            });
            // every row touched exactly once with its own index
            for r in 0..rows {
                for c in 0..row_len {
                    assert_eq!(out[r * row_len + c], (r + 1) as u32, "rows={rows} w={workers}");
                }
            }
        }
    }

    #[test]
    fn ragged_tail_is_covered() {
        // out.len() not a multiple of row_len: the tail elements beyond
        // the last whole row must still be handed to exactly one block
        let mut out = vec![0u32; 11]; // 5 rows of 2 + 1 ragged element
        parallel_rows_mut(&mut out, 2, 2, |_, block| {
            for v in block.iter_mut() {
                *v += 1;
            }
        });
        assert!(out.iter().all(|&v| v == 1), "{out:?}");
    }

    #[test]
    fn ranges_partition_covers_exactly_once() {
        for &(n, workers) in &[(10usize, 3usize), (1, 4), (0, 2), (8, 8), (9, 2)] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_ranges(n, workers, |lo, hi| {
                for i in lo..hi {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "n={n} w={workers}");
        }
    }

    #[test]
    fn map_preserves_order() {
        for &workers in &[1usize, 2, 3, 5] {
            let v = parallel_map(11, workers, |i| i * i);
            assert_eq!(v, (0..11).map(|i| i * i).collect::<Vec<_>>());
        }
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn nested_region_serializes() {
        // inside a parallel region, workers_for must report 1
        let saw_nested: AtomicU64 = AtomicU64::new(0);
        parallel_ranges(4, 2, |_, _| {
            assert!(in_parallel_region());
            if workers_for(100, usize::MAX) == 1 {
                saw_nested.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(saw_nested.load(Ordering::Relaxed), 2);
        assert!(!in_parallel_region(), "region flag leaked");
    }

    #[test]
    fn small_work_stays_serial() {
        assert_eq!(workers_for(8, 10), 1);
        assert_eq!(workers_for(1, usize::MAX), 1);
    }

    #[test]
    fn pool_is_reused_across_many_dispatches() {
        // hammer the pool: helpers must be reused, results exact each time
        for round in 0..200usize {
            let n = 16 + round % 7;
            let v = parallel_map(n, 4, |i| i * 3 + round);
            assert_eq!(v, (0..n).map(|i| i * 3 + round).collect::<Vec<_>>());
        }
        // the pool never spawns more helpers than the largest job needed
        assert!(pool_helpers() <= 16, "helpers {}", pool_helpers());
    }

    #[test]
    fn concurrent_dispatchers_stay_correct() {
        // several OS threads dispatching at once: one owns the pool, the
        // rest degrade to serial — every result must still be exact
        let handles: Vec<_> = (0..4)
            .map(|t| {
                std::thread::spawn(move || {
                    for round in 0..50usize {
                        let v = parallel_map(13, 3, |i| i * 7 + t * 1000 + round);
                        let want: Vec<usize> =
                            (0..13).map(|i| i * 7 + t * 1000 + round).collect();
                        assert_eq!(v, want);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn panic_in_chunk_propagates_and_pool_survives() {
        let r = std::panic::catch_unwind(|| {
            parallel_ranges(8, 4, |lo, _| {
                if lo >= 4 {
                    panic!("chunk boom");
                }
            });
        });
        assert!(r.is_err(), "panic was swallowed");
        // the pool must remain fully usable after a failed job
        let v = parallel_map(9, 3, |i| i + 1);
        assert_eq!(v, (1..=9).collect::<Vec<_>>());
    }

    #[test]
    fn peak_concurrency_is_tracked() {
        // at least the dispatching thread is counted while a job runs
        reset_pool_peak();
        parallel_ranges(64, 4, |lo, hi| {
            std::hint::black_box((lo..hi).sum::<usize>());
        });
        assert!(pool_peak_concurrency() >= 1);
        // (the exact upper bound is pinned by exec_equivalence.rs, which
        // owns the global thread knob; unit tests here may run
        // concurrently with each other so only the lower bound is safe)
    }
}
