//! Thread-parallel execution substrate for the native compute hot paths.
//!
//! The paper's entire point is that the LMU's frozen LTI memory removes
//! the sequential dependency from training, leaving big, embarrassingly
//! parallel batched kernels (matmul, FFT causal convolution, elementwise
//! maps).  This module is the single place that turns that latent
//! parallelism into wall-clock speedup on CPU: a scoped-thread
//! row-partition executor (`std::thread::scope` — no crate dependencies,
//! builds are offline) with a global thread-count knob plumbed through the
//! CLI (`--threads`) and config (`[train] threads`).
//!
//! Design rules every dispatch site follows:
//!
//!  * **Bit-exact equivalence.**  Work is partitioned over *output* rows
//!    (or independent items); each element is computed by exactly the same
//!    sequence of floating-point operations as the serial reference, so
//!    results are identical for every thread count.  `threads = 1` (or any
//!    job below [`MIN_PARALLEL_WORK`]) takes the serial path outright.
//!    The `rust/tests/exec_equivalence.rs` suite pins this.
//!  * **No nested fan-out.**  A worker that calls back into a parallel
//!    kernel (e.g. per-sample DN conv → per-channel FFT) runs it serially:
//!    [`workers_for`] returns 1 inside a parallel region, bounding live
//!    threads at the configured count.
//!  * **Threshold-gated.**  Scoped threads are spawned per call; jobs
//!    smaller than [`MIN_PARALLEL_WORK`] scalar ops stay serial so the
//!    many tiny per-timestep matmuls of the sequential baselines don't pay
//!    spawn overhead.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};

/// Global worker-count knob.  0 = unresolved (first read resolves the
/// default from `PLMU_THREADS` or the machine's parallelism).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Default cap: beyond this, per-call spawn overhead and memory bandwidth
/// dominate for the shapes these models use.
const DEFAULT_MAX_THREADS: usize = 8;

/// Minimum total scalar ops before a kernel fans out.  A scoped-thread
/// spawn costs ~10µs; this keeps the crossover comfortably profitable.
pub const MIN_PARALLEL_WORK: usize = 1 << 18;

fn resolve_default() -> usize {
    if let Ok(v) = std::env::var("PLMU_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .clamp(1, DEFAULT_MAX_THREADS)
}

/// The configured worker count (resolving the default on first use).
pub fn threads() -> usize {
    let t = THREADS.load(Ordering::Relaxed);
    if t != 0 {
        return t;
    }
    let d = resolve_default();
    // racy double-resolve is benign: resolve_default is deterministic
    THREADS.store(d, Ordering::Relaxed);
    d
}

/// Set the worker count (clamped to >= 1).  1 selects the serial
/// reference path everywhere.
pub fn set_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

thread_local! {
    static IN_PARALLEL: Cell<bool> = const { Cell::new(false) };
}

/// True while the current thread is executing inside a parallel region
/// (used to serialize nested kernels).
pub fn in_parallel_region() -> bool {
    IN_PARALLEL.with(|c| c.get())
}

struct RegionGuard(bool);

impl Drop for RegionGuard {
    fn drop(&mut self) {
        IN_PARALLEL.with(|c| c.set(self.0));
    }
}

fn enter_region() -> RegionGuard {
    RegionGuard(IN_PARALLEL.with(|c| c.replace(true)))
}

/// Run `f` with kernel-level parallel dispatch disabled on the current
/// thread: every `workers_for` inside reports 1.  For coordinators that
/// manage their own thread-level parallelism (e.g. data-parallel replica
/// workers) so replica count × kernel threads don't multiply.
pub fn run_serialized<R>(f: impl FnOnce() -> R) -> R {
    let _g = enter_region();
    f()
}

/// Worker count for a job of `items` independent units totalling `work`
/// scalar ops: the global knob, capped by the item count, 1 when the job
/// is too small or we are already inside a parallel region.
pub fn workers_for(items: usize, work: usize) -> usize {
    if in_parallel_region() {
        return 1;
    }
    let t = threads();
    if t <= 1 || items <= 1 || work < MIN_PARALLEL_WORK {
        return 1;
    }
    t.min(items)
}

/// Partition `out` into per-worker blocks of whole rows (`row_len`
/// elements each) and run `f(first_row_index, block)` on each block, the
/// first block on the calling thread and the rest on scoped threads.
///
/// `workers <= 1` (or a single row) short-circuits to `f(0, out)` with no
/// scope and no region flag — the serial reference path.
pub fn parallel_rows_mut<T, F>(out: &mut [T], row_len: usize, workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let rows = if row_len == 0 { 0 } else { out.len() / row_len };
    if workers <= 1 || rows <= 1 {
        f(0, out);
        return;
    }
    let workers = workers.min(rows);
    let chunk_rows = rows.div_ceil(workers);
    std::thread::scope(|scope| {
        let f = &f;
        let mut rest = out;
        let mut row0 = 0usize;
        let mut first: Option<(usize, &mut [T])> = None;
        while !rest.is_empty() {
            let take = (chunk_rows * row_len).min(rest.len());
            let (head, tail) = {
                let tmp = rest;
                tmp.split_at_mut(take)
            };
            if first.is_none() {
                first = Some((row0, head));
            } else {
                scope.spawn(move || {
                    let _g = enter_region();
                    f(row0, head);
                });
            }
            row0 += take / row_len;
            rest = tail;
        }
        if let Some((r0, block)) = first {
            let _g = enter_region();
            f(r0, block);
        }
    });
}

/// Run `f(lo, hi)` over a partition of `0..n` into `workers` contiguous
/// ranges (first range on the calling thread).  For jobs whose output is
/// not one contiguous mutable slice.
pub fn parallel_ranges<F>(n: usize, workers: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if workers <= 1 || n <= 1 {
        if n > 0 {
            f(0, n);
        }
        return;
    }
    let workers = workers.min(n);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        let f = &f;
        for w in 1..workers {
            let lo = w * chunk;
            if lo >= n {
                break;
            }
            let hi = ((w + 1) * chunk).min(n);
            scope.spawn(move || {
                let _g = enter_region();
                f(lo, hi);
            });
        }
        let _g = enter_region();
        f(0, chunk.min(n));
    });
}

/// Order-preserving parallel map: `out[i] = f(i)` for `i in 0..n`.
pub fn parallel_map<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    if workers <= 1 || n <= 1 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    parallel_rows_mut(&mut out, 1, workers, |i0, block| {
        for (k, slot) in block.iter_mut().enumerate() {
            *slot = Some(f(i0 + k));
        }
    });
    out.into_iter().map(|v| v.expect("parallel_map: slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn rows_partition_covers_exactly_once() {
        for &(rows, row_len, workers) in
            &[(7usize, 3usize, 4usize), (1, 5, 4), (16, 1, 3), (5, 2, 8), (4, 4, 4)]
        {
            let mut out = vec![0u32; rows * row_len];
            parallel_rows_mut(&mut out, row_len, workers, |r0, block| {
                for (k, row) in block.chunks_mut(row_len).enumerate() {
                    for v in row.iter_mut() {
                        *v += (r0 + k + 1) as u32;
                    }
                }
            });
            // every row touched exactly once with its own index
            for r in 0..rows {
                for c in 0..row_len {
                    assert_eq!(out[r * row_len + c], (r + 1) as u32, "rows={rows} w={workers}");
                }
            }
        }
    }

    #[test]
    fn ranges_partition_covers_exactly_once() {
        for &(n, workers) in &[(10usize, 3usize), (1, 4), (0, 2), (8, 8), (9, 2)] {
            let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
            parallel_ranges(n, workers, |lo, hi| {
                for i in lo..hi {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1), "n={n} w={workers}");
        }
    }

    #[test]
    fn map_preserves_order() {
        for &workers in &[1usize, 2, 3, 5] {
            let v = parallel_map(11, workers, |i| i * i);
            assert_eq!(v, (0..11).map(|i| i * i).collect::<Vec<_>>());
        }
        assert!(parallel_map(0, 4, |i| i).is_empty());
    }

    #[test]
    fn nested_region_serializes() {
        // inside a parallel region, workers_for must report 1
        let saw_nested: AtomicU64 = AtomicU64::new(0);
        parallel_ranges(4, 2, |_, _| {
            assert!(in_parallel_region());
            if workers_for(100, usize::MAX) == 1 {
                saw_nested.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert_eq!(saw_nested.load(Ordering::Relaxed), 2);
        assert!(!in_parallel_region(), "region flag leaked");
    }

    #[test]
    fn small_work_stays_serial() {
        assert_eq!(workers_for(8, 10), 1);
        assert_eq!(workers_for(1, usize::MAX), 1);
    }
}
