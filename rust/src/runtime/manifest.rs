//! Parser for `artifacts/manifest.txt` (written by python/compile/aot.py).
//!
//! Line format (whitespace separated):
//!
//! ```text
//! # comment
//! config k=v k=v ...
//! param <name> offset=<int> shape=<d0>x<d1>...
//! artifact <name> <file>
//!   in <idx> <dtype> <d0,d1,...|scalar>
//!   out <idx> <dtype> <dims|scalar>
//! blob <name> <file> len=<int>
//! ```

use crate::error::Result;
use crate::{anyhow, bail};
use std::collections::HashMap;

/// dtype + dims of one artifact input/output.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl IoSpec {
    pub fn num_elements(&self) -> usize {
        self.dims.iter().product()
    }
}

#[derive(Clone, Debug)]
pub struct ArtifactSpec {
    pub name: String,
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Clone, Debug)]
pub struct ParamSpec {
    pub name: String,
    pub offset: usize,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug)]
pub struct BlobSpec {
    pub name: String,
    pub file: String,
    pub len: usize,
}

#[derive(Clone, Debug, Default)]
pub struct Manifest {
    pub config: HashMap<String, String>,
    pub params: Vec<ParamSpec>,
    pub artifacts: Vec<ArtifactSpec>,
    pub blobs: Vec<BlobSpec>,
}

fn parse_dims(s: &str) -> Result<Vec<usize>> {
    if s == "scalar" {
        return Ok(vec![]);
    }
    s.split(',')
        .map(|d| d.parse::<usize>().map_err(|e| anyhow!("bad dim {d}: {e}")))
        .collect()
}

fn kv(s: &str) -> Option<(&str, &str)> {
    s.split_once('=')
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let mut m = Manifest::default();
        let mut current: Option<ArtifactSpec> = None;
        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let toks: Vec<&str> = line.split_whitespace().collect();
            match toks[0] {
                "config" => {
                    for t in &toks[1..] {
                        if let Some((k, v)) = kv(t) {
                            m.config.insert(k.to_string(), v.to_string());
                        }
                    }
                }
                "param" => {
                    if toks.len() < 4 {
                        bail!("line {}: malformed param", lineno + 1);
                    }
                    let offset = kv(toks[2])
                        .filter(|(k, _)| *k == "offset")
                        .ok_or_else(|| anyhow!("line {}: missing offset", lineno + 1))?
                        .1
                        .parse()?;
                    let shape_str = kv(toks[3])
                        .filter(|(k, _)| *k == "shape")
                        .ok_or_else(|| anyhow!("line {}: missing shape", lineno + 1))?
                        .1;
                    let shape: Result<Vec<usize>, _> =
                        shape_str.split('x').map(|d| d.parse::<usize>()).collect();
                    m.params.push(ParamSpec {
                        name: toks[1].to_string(),
                        offset,
                        shape: shape?,
                    });
                }
                "artifact" => {
                    if let Some(a) = current.take() {
                        m.artifacts.push(a);
                    }
                    if toks.len() < 3 {
                        bail!("line {}: malformed artifact", lineno + 1);
                    }
                    current = Some(ArtifactSpec {
                        name: toks[1].to_string(),
                        file: toks[2].to_string(),
                        inputs: vec![],
                        outputs: vec![],
                    });
                }
                "in" | "out" => {
                    let a = current
                        .as_mut()
                        .ok_or_else(|| anyhow!("line {}: io outside artifact", lineno + 1))?;
                    if toks.len() < 4 {
                        bail!("line {}: malformed io line", lineno + 1);
                    }
                    let spec = IoSpec { dtype: toks[2].to_string(), dims: parse_dims(toks[3])? };
                    if toks[0] == "in" {
                        a.inputs.push(spec);
                    } else {
                        a.outputs.push(spec);
                    }
                }
                "blob" => {
                    if toks.len() < 4 {
                        bail!("line {}: malformed blob", lineno + 1);
                    }
                    let len = kv(toks[3])
                        .filter(|(k, _)| *k == "len")
                        .ok_or_else(|| anyhow!("line {}: missing len", lineno + 1))?
                        .1
                        .parse()?;
                    m.blobs.push(BlobSpec {
                        name: toks[1].to_string(),
                        file: toks[2].to_string(),
                        len,
                    });
                }
                other => bail!("line {}: unknown directive {other}", lineno + 1),
            }
        }
        if let Some(a) = current.take() {
            m.artifacts.push(a);
        }
        Ok(m)
    }

    pub fn config_usize(&self, key: &str) -> Option<usize> {
        self.config.get(key).and_then(|v| v.parse().ok())
    }

    pub fn config_f64(&self, key: &str) -> Option<f64> {
        self.config.get(key).and_then(|v| v.parse().ok())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"# plmu artifact manifest v1
config n=256 d=64 lr=0.001 n_params=9740
param Ux offset=0 shape=1x1
param Wm offset=2 shape=64x128
artifact fwd fwd.hlo.txt
  in 0 f32 9740
  in 1 f32 32,256,1
  out 0 f32 32,10
artifact train_step train_step.hlo.txt
  in 0 f32 9740
  in 1 i32 32
  out 0 f32 scalar
blob init_params init_params.txt len=9740
"#;

    #[test]
    fn parses_all_sections() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.config_usize("n"), Some(256));
        assert_eq!(m.config_f64("lr"), Some(0.001));
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[1].name, "Wm");
        assert_eq!(m.params[1].offset, 2);
        assert_eq!(m.params[1].shape, vec![64, 128]);
        assert_eq!(m.artifacts.len(), 2);
        let fwd = &m.artifacts[0];
        assert_eq!(fwd.inputs.len(), 2);
        assert_eq!(fwd.inputs[1].dims, vec![32, 256, 1]);
        assert_eq!(fwd.outputs[0].dims, vec![32, 10]);
        assert_eq!(m.artifacts[1].inputs[1].dtype, "i32");
        assert_eq!(m.artifacts[1].outputs[0].dims, Vec::<usize>::new());
        assert_eq!(m.blobs[0].len, 9740);
    }

    #[test]
    fn scalar_dims_are_empty() {
        assert_eq!(parse_dims("scalar").unwrap(), Vec::<usize>::new());
        assert_eq!(parse_dims("3,4").unwrap(), vec![3, 4]);
        assert!(parse_dims("3,x").is_err());
    }

    #[test]
    fn io_outside_artifact_rejected() {
        assert!(Manifest::parse("in 0 f32 3").is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // integration: parse the actual artifact manifest when it exists
        let p = std::path::Path::new("artifacts/manifest.txt");
        if let Ok(text) = std::fs::read_to_string(p) {
            let m = Manifest::parse(&text).unwrap();
            assert!(m.artifacts.iter().any(|a| a.name == "train_step"));
            assert!(m.config_usize("n_params").unwrap() > 0);
        }
    }

    #[test]
    fn num_elements() {
        let io = IoSpec { dtype: "f32".into(), dims: vec![2, 3, 4] };
        assert_eq!(io.num_elements(), 24);
        let s = IoSpec { dtype: "f32".into(), dims: vec![] };
        assert_eq!(s.num_elements(), 1);
    }
}
