//! The PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` (`make artifacts`) and executes them from Rust.
//! Python is never on this path — artifacts are self-contained.
//!
//! Flow (see /opt/xla-example/load_hlo for the reference wiring):
//!   HLO text --HloModuleProto::from_text_file--> XlaComputation
//!            --PjRtClient::compile-->            PjRtLoadedExecutable
//!            --execute(Literal inputs)-->        tuple of output Literals
//!
//! The manifest (`manifest.txt`) describes every artifact's I/O shapes and
//! the flat-parameter layout; [`Manifest::parse`] is a tiny hand-rolled
//! parser (no serde offline).

pub mod manifest;

pub use manifest::{ArtifactSpec, IoSpec, Manifest};

use crate::anyhow;
use crate::error::{Context, Result};
use crate::tensor::Tensor;
use crate::xla;
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A compiled artifact ready to execute.
pub struct Artifact {
    pub spec: ArtifactSpec,
    exe: xla::PjRtLoadedExecutable,
}

impl Artifact {
    /// Execute with f32 tensor inputs (and optional i32 inputs marked in
    /// the spec).  Returns the flattened output tensors.
    pub fn run(&self, inputs: &[ArtifactInput]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.spec.inputs.len() {
            return Err(anyhow!(
                "artifact {} expects {} inputs, got {}",
                self.spec.name,
                self.spec.inputs.len(),
                inputs.len()
            ));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (inp, spec) in inputs.iter().zip(&self.spec.inputs) {
            literals.push(inp.to_literal(spec)?);
        }
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.spec.name))?;
        // jax lowering uses return_tuple=True: one tuple literal
        let tuple = result[0][0]
            .to_literal_sync()
            .context("device->host transfer")?;
        let parts = tuple.to_tuple().context("untupling outputs")?;
        let mut out = Vec::with_capacity(parts.len());
        for (lit, ospec) in parts.iter().zip(&self.spec.outputs) {
            let data: Vec<f32> = match ospec.dtype.as_str() {
                "f32" => lit.to_vec::<f32>()?,
                "i32" => lit.to_vec::<i32>()?.into_iter().map(|v| v as f32).collect(),
                other => return Err(anyhow!("unsupported output dtype {other}")),
            };
            out.push(Tensor::new(&ospec.dims, data));
        }
        Ok(out)
    }
}

/// One input value for `Artifact::run`.
pub enum ArtifactInput {
    F32(Tensor),
    I32(Vec<i32>),
}

impl ArtifactInput {
    fn to_literal(&self, spec: &IoSpec) -> Result<xla::Literal> {
        let dims_i64: Vec<i64> = spec.dims.iter().map(|&d| d as i64).collect();
        match (self, spec.dtype.as_str()) {
            (ArtifactInput::F32(t), "f32") => {
                let expect: usize = spec.dims.iter().product();
                if t.len() != expect {
                    return Err(anyhow!(
                        "input size mismatch: tensor {} vs spec {:?}",
                        t.len(),
                        spec.dims
                    ));
                }
                let lit = xla::Literal::vec1(t.data());
                Ok(if spec.dims.is_empty() {
                    lit.reshape(&[])?
                } else {
                    lit.reshape(&dims_i64)?
                })
            }
            (ArtifactInput::I32(v), "i32") => {
                let lit = xla::Literal::vec1(v.as_slice());
                Ok(if spec.dims.is_empty() {
                    lit.reshape(&[])?
                } else {
                    lit.reshape(&dims_i64)?
                })
            }
            (_, dt) => Err(anyhow!("input/spec dtype mismatch (spec {dt})")),
        }
    }
}

/// The runtime: a PJRT client plus the compiled artifact registry.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    artifacts: HashMap<String, Artifact>,
}

impl Runtime {
    /// Load the manifest and lazily compile nothing yet.
    pub fn open(dir: &Path) -> Result<Self> {
        let manifest = Manifest::parse(
            &std::fs::read_to_string(dir.join("manifest.txt"))
                .with_context(|| format!("reading {}/manifest.txt — run `make artifacts`", dir.display()))?,
        )?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client, manifest, dir: dir.to_path_buf(), artifacts: HashMap::new() })
    }

    /// Compile (memoized) and return an artifact by name.
    pub fn artifact(&mut self, name: &str) -> Result<&Artifact> {
        if !self.artifacts.contains_key(name) {
            let spec = self
                .manifest
                .artifacts
                .iter()
                .find(|a| a.name == name)
                .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
                .clone();
            let path = self.dir.join(&spec.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("bad path"))?,
            )
            .with_context(|| format!("parsing {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .with_context(|| format!("compiling {name}"))?;
            self.artifacts.insert(name.to_string(), Artifact { spec, exe });
        }
        Ok(&self.artifacts[name])
    }

    /// Load the exported initial parameter vector.
    pub fn init_params(&self) -> Result<Tensor> {
        let blob = self
            .manifest
            .blobs
            .iter()
            .find(|b| b.name == "init_params")
            .ok_or_else(|| anyhow!("no init_params blob in manifest"))?;
        let text = std::fs::read_to_string(self.dir.join(&blob.file))?;
        let vals: Result<Vec<f32>, _> = text.lines().map(|l| l.trim().parse::<f32>()).collect();
        let vals = vals.context("parsing init_params")?;
        if vals.len() != blob.len {
            return Err(anyhow!("init_params length {} != manifest {}", vals.len(), blob.len));
        }
        Ok(Tensor::new(&[vals.len()], vals))
    }
}
