//! Minimal CLI argument parser (clap substitute — not in the offline
//! vendor set).  Supports `--key value`, `--key=value`, boolean `--flag`,
//! positional arguments, and generated help.

use std::collections::HashMap;

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Declarative argument parser.
#[derive(Debug, Default)]
pub struct Args {
    program: String,
    about: String,
    specs: Vec<Spec>,
    values: HashMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Args {
            program: program.to_string(),
            about: about.to_string(),
            ..Default::default()
        }
    }

    /// Declare an option with a default value.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required option.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean flag.
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: true,
        });
        self
    }

    /// Parse from an iterator of argument strings (no program name).
    pub fn parse_from<I: IntoIterator<Item = String>>(mut self, argv: I) -> Result<Self, String> {
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if arg == "--help" || arg == "-h" {
                return Err(self.help_text());
            }
            if let Some(stripped) = arg.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == key)
                    .ok_or_else(|| format!("unknown option --{key}\n{}", self.help_text()))?
                    .clone();
                let value = if spec.is_flag {
                    if inline_val.is_some() {
                        return Err(format!("flag --{key} takes no value"));
                    }
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    it.next().ok_or_else(|| format!("option --{key} needs a value"))?
                };
                self.values.insert(key, value);
            } else {
                self.positionals.push(arg);
            }
        }
        // check required
        for s in &self.specs {
            if !s.is_flag && s.default.is_none() && !self.values.contains_key(&s.name) {
                return Err(format!("missing required option --{}\n{}", s.name, self.help_text()));
            }
        }
        Ok(self)
    }

    /// Parse from the process arguments, printing help/errors and exiting
    /// on failure.
    pub fn parse(self) -> Self {
        match self.parse_from(std::env::args().skip(1)) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    pub fn help_text(&self) -> String {
        let mut s = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for spec in &self.specs {
            let kind = if spec.is_flag {
                "".to_string()
            } else if let Some(d) = &spec.default {
                format!(" <value> (default: {d})")
            } else {
                " <value> (required)".to_string()
            };
            s.push_str(&format!("  --{}{}\n      {}\n", spec.name, kind, spec.help));
        }
        s
    }

    // ------------------------------------------------------------- getters

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.specs
            .iter()
            .find(|s| s.name == name)
            .and_then(|s| s.default.clone())
            .unwrap_or_else(|| panic!("option --{name} was not declared"))
    }

    pub fn get_usize(&self, name: &str) -> usize {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_u64(&self, name: &str) -> u64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> f64 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn get_f32(&self, name: &str) -> f32 {
        self.get(name).parse().unwrap_or_else(|_| panic!("--{name} must be a number"))
    }

    pub fn get_flag(&self, name: &str) -> bool {
        self.values.get(name).map(|v| v == "true").unwrap_or(false)
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::new("t", "test")
            .opt("epochs", "10", "number of epochs")
            .opt("lr", "0.001", "learning rate")
            .parse_from(argv(&["--epochs", "5"]))
            .unwrap();
        assert_eq!(a.get_usize("epochs"), 5);
        assert_eq!(a.get_f64("lr"), 0.001);
    }

    #[test]
    fn equals_syntax() {
        let a = Args::new("t", "test")
            .opt("mode", "train", "run mode")
            .parse_from(argv(&["--mode=serve"]))
            .unwrap();
        assert_eq!(a.get("mode"), "serve");
    }

    #[test]
    fn flags_default_false() {
        let a = Args::new("t", "test")
            .flag("verbose", "noisy output")
            .parse_from(argv(&[]))
            .unwrap();
        assert!(!a.get_flag("verbose"));
        let b = Args::new("t", "test")
            .flag("verbose", "noisy output")
            .parse_from(argv(&["--verbose"]))
            .unwrap();
        assert!(b.get_flag("verbose"));
    }

    #[test]
    fn required_option_enforced() {
        let r = Args::new("t", "test").req("data", "dataset path").parse_from(argv(&[]));
        assert!(r.is_err());
        assert!(r.unwrap_err().contains("missing required option --data"));
    }

    #[test]
    fn unknown_option_rejected() {
        let r = Args::new("t", "test").parse_from(argv(&["--bogus", "1"]));
        assert!(r.is_err());
    }

    #[test]
    fn positionals_collected() {
        let a = Args::new("t", "test")
            .opt("k", "1", "k")
            .parse_from(argv(&["train", "--k", "2", "extra"]))
            .unwrap();
        assert_eq!(a.positionals(), &["train".to_string(), "extra".to_string()]);
        assert_eq!(a.get_usize("k"), 2);
    }

    #[test]
    fn help_lists_options() {
        let h = Args::new("prog", "about text").opt("alpha", "1", "the alpha").help_text();
        assert!(h.contains("prog"));
        assert!(h.contains("--alpha"));
        assert!(h.contains("default: 1"));
    }
}
