//! # plmu — Parallelized Legendre Memory Unit training & serving
//!
//! A Rust + JAX + Pallas reproduction of *“Parallelizing Legendre Memory
//! Unit Training”* (Chilkuri & Eliasmith, ICML 2021).
//!
//! The paper's observation: the LMU's memory is a **frozen linear
//! time-invariant system** (the Delay Network), so its recurrence
//! `m_t = Ā m_{t-1} + B̄ u_t` can be *solved* — evaluated as a causal
//! convolution with the impulse response — making training parallel over
//! the sequence dimension while an exactly-equivalent recurrent form
//! serves streaming inference.
//!
//! Architecture (three layers, Python never on the request path):
//!  * L1: Pallas chunked-scan kernel (`python/compile/kernels/`);
//!  * L2: JAX model fwd/bwd (`python/compile/model.py`), AOT-lowered once
//!    to HLO text artifacts;
//!  * L3: this crate — the training coordinator, the streaming inference
//!    server, a PJRT runtime that executes the artifacts, and a complete
//!    native substrate (tensor/FFT/autograd/data/optim) used for the
//!    paper's benchmark reproductions.
//!
//! The native substrate's hot kernels (matmul, FFT causal convolution,
//! elementwise maps, DN application) dispatch through the [`exec`]
//! thread-parallel execution substrate — a work-stealing persistent
//! worker pool with hierarchical parallelism budgets, which the
//! data-parallel coordinator and the serving batcher also fan out on, so
//! every parallel code path in the process shares one thread budget
//! (nested kernels get a sub-budget share instead of serializing).
//! Serial (`threads = 1`) and parallel execution are bit-exact,
//! mirroring the paper's claim that the parallel and recurrent forms
//! compute the same function.  Below the thread level, the hot inner
//! loops (dot/axpy, elementwise chains, the FFT spectrum product) run
//! through the [`simd`] 8-lane kernel layer, whose vector and scalar
//! paths share one canonical blocked accumulation order — so
//! `simd on/off` is as bit-exact as `threads ∈ {1, 2, 8}`
//! (`rust/tests/simd_equivalence.rs`).  The pool also runs **async jobs**
//! (scoped via [`exec::parallel_rows_overlap`]): the data-parallel
//! coordinator's `pipeline` mode overlaps the optimizer stage with the
//! next batch's replica compute (staleness-1, double-buffered parameter
//! broadcast), and the serving batcher overlaps reply delivery with the
//! next batch's session fan-out — still within the one budget.
//!
//! See DESIGN.md for the experiment index and architecture notes, and
//! EXPERIMENTS.md for results and perf records.

pub mod analyze;
pub mod autograd;
pub mod benchlib;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod dn;
pub mod error;
pub mod exec;
pub mod fft;
pub mod fusion;
pub mod layers;
pub mod linalg;
pub mod metrics;
pub mod optim;
pub mod runtime;
pub mod simd;
pub mod tensor;
pub mod train;
pub mod util;
pub mod xla;

pub use tensor::Tensor;
