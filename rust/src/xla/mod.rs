//! Offline stub of the `xla` (PJRT) bindings the runtime layer codes
//! against.  The real xla-rs crate is not in the offline vendor set, so
//! this module mirrors the exact API surface `runtime/` uses —
//! [`PjRtClient`], [`HloModuleProto`], [`XlaComputation`],
//! [`PjRtLoadedExecutable`], [`Literal`] — with honest behavior:
//!
//!  * client construction, manifest-driven shape plumbing, and literal
//!    packing all work (so `plmu info` and artifact inventory run);
//!  * `compile`/`execute` return a clear error, since no PJRT backend is
//!    present — the integration tests and examples already skip cleanly
//!    when artifact execution is unavailable.
//!
//! When a vendored PJRT runtime lands, this module is deleted and the
//! `use crate::xla;` aliases in `runtime/` and `main.rs` point back at the
//! real crate with no other source changes.

use crate::error::{Context, Result};

const UNAVAILABLE: &str =
    "XLA/PJRT backend is unavailable in this offline build (native substrate only)";

/// Scalar types a [`Literal`] can carry.
pub trait NativeType: Copy {
    fn dtype_name() -> &'static str;
}

impl NativeType for f32 {
    fn dtype_name() -> &'static str {
        "f32"
    }
}

impl NativeType for i32 {
    fn dtype_name() -> &'static str {
        "i32"
    }
}

/// A host-side literal: element count + dtype tag (values are not retained
/// — nothing can execute on them in the stub).
pub struct Literal {
    len: usize,
    dtype: &'static str,
}

impl Literal {
    /// Pack a 1-D slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal { len: data.len(), dtype: T::dtype_name() }
    }

    /// Reshape; validates the element count like the real binding.
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let expect: i64 = dims.iter().product();
        let expect = if dims.is_empty() { 1 } else { expect };
        if expect as usize != self.len {
            crate::bail!("reshape {:?} does not match literal length {}", dims, self.len);
        }
        Ok(Literal { len: self.len, dtype: self.dtype })
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }
}

/// Parsed HLO module (text retained for inventory/debugging only).
pub struct HloModuleProto {
    pub text_len: usize,
}

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        Ok(HloModuleProto { text_len: text.len() })
    }
}

/// An XLA computation handle.
pub struct XlaComputation {
    _proto_len: usize,
}

impl XlaComputation {
    pub fn from_proto(p: &HloModuleProto) -> Self {
        XlaComputation { _proto_len: p.text_len }
    }
}

/// A compiled executable.  Never constructed by the stub ([`PjRtClient::
/// compile`] errors), but the methods typecheck the runtime layer.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[Literal]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }
}

/// A device buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }
}

/// The PJRT client.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient { _private: () })
    }

    pub fn platform_name(&self) -> &'static str {
        "cpu (offline stub — native substrate only)"
    }

    pub fn device_count(&self) -> usize {
        1
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(crate::anyhow!("{UNAVAILABLE}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_opens_but_cannot_compile() {
        let c = match PjRtClient::cpu() {
            Ok(c) => c,
            Err(e) => panic!("stub client failed: {e}"),
        };
        assert_eq!(c.device_count(), 1);
        let comp = XlaComputation::from_proto(&HloModuleProto { text_len: 0 });
        let err = match c.compile(&comp) {
            Err(e) => e.to_string(),
            Ok(_) => panic!("offline stub unexpectedly compiled"),
        };
        assert!(err.contains("unavailable"), "{err}");
    }

    #[test]
    fn literal_reshape_validates_counts() {
        let l = Literal::vec1(&[1.0f32; 6]);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
        let s = Literal::vec1(&[7i32]);
        assert!(s.reshape(&[]).is_ok()); // scalar
    }
}
