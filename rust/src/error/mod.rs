//! Minimal error type with context chaining (anyhow substitute — anyhow is
//! not in the offline vendor set).  Provides the small surface the runtime
//! and CLI layers need: an opaque [`Error`], a [`Result`] alias defaulting
//! to it, the [`crate::anyhow!`] / [`crate::bail!`] macros, and a
//! [`Context`] extension trait for `Result`.
//!
//! Like anyhow's, [`Error`] deliberately does NOT implement
//! `std::error::Error`: that keeps the blanket `From<E: std::error::Error>`
//! conversion coherent, so `?` works on `io::Error`, parse errors,
//! [`crate::config::ConfigError`], and friends without per-type glue.

use std::fmt;

/// An opaque error: a message with optional context prefixes accumulated
/// by [`Context::context`] (outermost context first, like anyhow's chain
/// rendered on one line).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build an error from anything displayable.
    pub fn msg(m: impl fmt::Display) -> Self {
        Error { msg: m.to_string() }
    }

    fn wrap(self, ctx: impl fmt::Display) -> Self {
        Error { msg: format!("{ctx}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl fmt::Debug for Error {
    // main() exits print the Debug form; keep it human-readable.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl<E: std::error::Error> From<E> for Error {
    fn from(e: E) -> Self {
        Error::msg(e)
    }
}

/// Result alias defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to the error branch of a `Result`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, ctx: C) -> Result<T> {
        self.map_err(|e| e.into().wrap(ctx))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().wrap(f()))
    }
}

/// Construct an [`Error`](crate::error::Error) from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::error::Error::msg(format!($($arg)*))
    };
}

/// Early-return an [`Err`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn from_std_error_and_display() {
        let e: Error = io_err().into();
        assert!(e.to_string().contains("gone"));
        assert_eq!(format!("{e}"), format!("{e:?}"));
    }

    #[test]
    fn context_prefixes_outermost_first() {
        let r: Result<()> = Err(io_err()).context("reading manifest");
        let msg = r.unwrap_err().to_string();
        assert!(msg.starts_with("reading manifest: "), "{msg}");
        let r2: Result<()> = Err(Error::msg("inner"))
            .context("mid")
            .with_context(|| format!("outer {}", 1));
        assert_eq!(r2.unwrap_err().to_string(), "outer 1: mid: inner");
    }

    #[test]
    fn macros_build_and_bail() {
        fn fails(n: usize) -> Result<usize> {
            if n == 0 {
                bail!("n was {n}");
            }
            Err(anyhow!("always {}", n))
        }
        assert_eq!(fails(0).unwrap_err().to_string(), "n was 0");
        assert_eq!(fails(3).unwrap_err().to_string(), "always 3");
    }

    #[test]
    fn question_mark_converts() {
        fn parse(s: &str) -> Result<i64> {
            Ok(s.parse::<i64>()?)
        }
        assert_eq!(parse("42").unwrap(), 42);
        assert!(parse("nope").is_err());
    }
}
