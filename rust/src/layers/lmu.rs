//! The three LMU variants the paper compares (§4.6, Fig. 1):
//!
//!  * [`LmuOriginalCell`] — Voelker et al. (2019), eqs. 15–17: nonlinear
//!    hidden state coupled to the DN, fully sequential (the baseline);
//!  * [`LmuSequentialLayer`] — *our model* (eqs. 18–20) run in its
//!    recurrent "LTI version" (eq. 19 step by step);
//!  * [`LmuParallelLayer`] — *our model* with the DN evaluated in parallel
//!    (FFT eq. 26 when all states are needed, matmul eq. 25 when only the
//!    final state is).
//!
//! Sequential and parallel versions compute identical functions — the
//! tests pin this — which is the paper's train-parallel / infer-recurrent
//! equivalence.
//!
//! [`LmuParallelLayer`]'s compute runs on the thread-parallel substrate
//! end to end: the encoder/output matmuls, the batched DN convolution
//! (`Graph::dn_conv` → [`DnOperator`], FFT or chunked scan per the
//! `PLMU_SCAN` knob), and the last-state path (eq. 25 matmul or the
//! scan carry chain) all dispatch through `crate::exec`, while the
//! sequential/original cells remain the serial references.  Serial and
//! parallel execution are bit-exact, so `threads` never changes a
//! result.

use crate::autograd::{Act, Graph, NodeId, ParamId, ParamStore};
use crate::dn::{DelayNetwork, DnOperator, DnScanOperator};
use crate::exec;
use crate::tensor::Tensor;
use crate::util::Rng;
use std::sync::Arc;

/// Shared hyperparameters of our-model layers.
#[derive(Clone, Debug)]
pub struct LmuSpec {
    pub dx: usize,
    pub du: usize,
    pub d: usize,
    pub theta: f64,
    pub hidden: usize,
    /// apply tanh in eq. 18 (f1). DN-only models (Table 4) use identity+no-encoder.
    pub nonlin_u: bool,
    /// apply tanh in eq. 20 (f2).
    pub nonlin_o: bool,
}

impl LmuSpec {
    pub fn new(dx: usize, du: usize, d: usize, theta: f64, hidden: usize) -> Self {
        LmuSpec { dx, du, d, theta, hidden, nonlin_u: true, nonlin_o: true }
    }
}

/// Parameters of our-model (eqs. 18 & 20): shared by the sequential and
/// parallel evaluation strategies so equivalence is exact.
pub struct LmuParams {
    pub ux: ParamId,
    pub bu: ParamId,
    pub wm: ParamId,
    pub wx: ParamId,
    pub bo: ParamId,
}

impl LmuParams {
    pub fn init(spec: &LmuSpec, store: &mut ParamStore, rng: &mut Rng, prefix: &str) -> Self {
        LmuParams {
            ux: store.add(&format!("{prefix}.Ux"), Tensor::glorot(spec.dx, spec.du, rng)),
            bu: store.add(&format!("{prefix}.bu"), Tensor::zeros(&[spec.du])),
            wm: store.add(&format!("{prefix}.Wm"), Tensor::glorot(spec.du * spec.d, spec.hidden, rng)),
            wx: store.add(&format!("{prefix}.Wx"), Tensor::glorot(spec.dx, spec.hidden, rng)),
            bo: store.add(&format!("{prefix}.bo"), Tensor::zeros(&[spec.hidden])),
        }
    }
}

// ---------------------------------------------------------------------------
// Parallel form
// ---------------------------------------------------------------------------

/// Our model with the DN evaluated in parallel over the sequence.
/// The DN operator is whichever path the `PLMU_SCAN` knob selects at
/// construction time — FFT (eq. 26) or the chunked scan — and both the
/// all-states and last-state forwards route through it.
pub struct LmuParallelLayer {
    pub spec: LmuSpec,
    pub params: LmuParams,
    dn_op: Arc<DnOperator>,
    /// time-reversed impulse response for the eq. 25 last-state path
    hrev: Tensor,
    pub n: usize,
}

impl LmuParallelLayer {
    pub fn new(spec: LmuSpec, n: usize, store: &mut ParamStore, rng: &mut Rng, prefix: &str) -> Self {
        let dn = DelayNetwork::new(spec.d, spec.theta);
        let dn_op = Arc::new(DnOperator::for_mode(&dn, n));
        let h = dn.impulse_response(n);
        let d = spec.d;
        // time-reversal is a pure row permutation — partition output rows
        let mut hrev = Tensor::zeros(&[n, d]);
        let hd = h.data();
        let plan = exec::plan_for(n, n * d);
        exec::parallel_rows_mut(hrev.data_mut(), d, plan, |t0, block| {
            for (r, row) in block.chunks_mut(d).enumerate() {
                let t = t0 + r;
                row.copy_from_slice(&hd[(n - 1 - t) * d..(n - t) * d]);
            }
        });
        let params = LmuParams::init(&spec, store, rng, prefix);
        LmuParallelLayer { spec, params, dn_op, hrev, n }
    }

    /// Encoder (eq. 18): u = f1(x Ux + bu).  x sample-major (B·n, dx).
    fn encode(&self, g: &mut Graph, store: &ParamStore, x: NodeId) -> NodeId {
        let ux = g.param(store, self.params.ux);
        let bu = g.param(store, self.params.bu);
        let act = if self.spec.nonlin_u { Some(Act::Tanh) } else { None };
        g.affine_act(x, ux, bu, act)
    }

    /// Output map (eq. 20): o = f2(m Wm + x Wx + bo).
    fn output(&self, g: &mut Graph, store: &ParamStore, m: NodeId, x: NodeId) -> NodeId {
        let wm = g.param(store, self.params.wm);
        let wx = g.param(store, self.params.wx);
        let bo = g.param(store, self.params.bo);
        let mm = g.matmul(m, wm);
        let xx = g.matmul(x, wx);
        let act = if self.spec.nonlin_o { Some(Act::Tanh) } else { None };
        g.add2_row_act(mm, xx, bo, act)
    }

    /// All-states forward (eq. 26 path): x (B·n, dx) -> o (B·n, hidden).
    pub fn forward_all(&self, g: &mut Graph, store: &ParamStore, x: NodeId, batch: usize) -> NodeId {
        let u = self.encode(g, store, x);
        let m = g.dn_conv(u, self.dn_op.clone(), batch); // (B·n, du·d)
        self.output(g, store, m, x)
    }

    /// Last-state forward (return_sequences=False): x (B·n, dx),
    /// x_last (B, dx) -> o (B, hidden).  Routes by the operator the
    /// knob built: the eq. 25 hrev-matmul under FFT mode, the carry
    /// chain of [`DnScanOperator::apply_last`] under scan mode.
    pub fn forward_last(
        &self,
        g: &mut Graph,
        store: &ParamStore,
        x: NodeId,
        x_last: NodeId,
        batch: usize,
    ) -> NodeId {
        let u = self.encode(g, store, x);
        let m = match self.dn_op.as_scan() {
            Some(scan) => g.dn_last_scan(u, scan.clone(), batch, None),
            None => g.dn_last(u, &self.hrev, batch), // (B, du·d)
        };
        self.output(g, store, m, x_last)
    }

    /// Last-state forward resuming from an explicit DN carry (B, du·d)
    /// — the streaming trainer's final-window pass.  Scan mode only:
    /// the FFT operator has no incremental state to resume from.
    pub fn forward_last_from(
        &self,
        g: &mut Graph,
        store: &ParamStore,
        x: NodeId,
        x_last: NodeId,
        batch: usize,
        carry: &Tensor,
    ) -> NodeId {
        let scan = self
            .dn_op
            .as_scan()
            .expect("forward_last_from requires PLMU_SCAN=scan (the FFT path cannot stream)")
            .clone();
        let u = self.encode(g, store, x);
        let m = g.dn_last_scan(u, scan, batch, Some(carry));
        self.output(g, store, m, x_last)
    }

    /// DN-only final state (Table 4 sentence encoders): no encoder, no
    /// output map — m_n of the raw input, (B, du·d) with du = dx.
    pub fn dn_only_last(&self, g: &mut Graph, x: NodeId, batch: usize) -> NodeId {
        g.dn_last(x, &self.hrev, batch)
    }

    /// The DN operator this layer routes through (knob-selected at
    /// construction).
    pub fn dn_operator(&self) -> &Arc<DnOperator> {
        &self.dn_op
    }

    /// The scan operator, when `PLMU_SCAN=scan` built one.
    pub fn scan_operator(&self) -> Option<&Arc<DnScanOperator>> {
        self.dn_op.as_scan()
    }

    /// Value-only encoder (eq. 18), no tape: the exact kernel the graph
    /// encode records (`Tensor::affine_act`), so streamed non-final
    /// windows see bit-identical u values.
    pub fn encode_values(&self, store: &ParamStore, x: &Tensor) -> Tensor {
        let act = if self.spec.nonlin_u { Some(Act::Tanh) } else { None };
        x.affine_act(store.get(self.params.ux), store.get(self.params.bu), act)
    }
}

// ---------------------------------------------------------------------------
// Sequential (LTI version) form
// ---------------------------------------------------------------------------

/// Our model with eq. 19 evaluated step by step (the "LTI version" of
/// §4.6 and the streaming-inference path).
pub struct LmuSequentialLayer {
    pub spec: LmuSpec,
    pub params: LmuParams,
    abar_t: Tensor,
    /// B̄ as a (1, d) row for rank-1 updates
    bbar_row: Tensor,
}

impl LmuSequentialLayer {
    pub fn new(spec: LmuSpec, store: &mut ParamStore, rng: &mut Rng, prefix: &str) -> Self {
        let dn = DelayNetwork::new(spec.d, spec.theta);
        let abar_t = dn.abar_f32.transpose2();
        let bbar_row = Tensor::new(&[1, spec.d], dn.bbar_f32.clone());
        let params = LmuParams::init(&spec, store, rng, prefix);
        LmuSequentialLayer { spec, params, abar_t, bbar_row }
    }

    /// Share parameters with a parallel layer (for equivalence tests and
    /// train-parallel / serve-recurrent deployments).
    pub fn with_params(spec: LmuSpec, params: LmuParams) -> Self {
        let dn = DelayNetwork::new(spec.d, spec.theta);
        let abar_t = dn.abar_f32.transpose2();
        let bbar_row = Tensor::new(&[1, spec.d], dn.bbar_f32.clone());
        LmuSequentialLayer { spec, params, abar_t, bbar_row }
    }

    /// Full sequential forward.  x time-major (n·B, dx).
    /// Returns time-major (n·B, hidden).
    pub fn forward_all(
        &self,
        g: &mut Graph,
        store: &ParamStore,
        x: NodeId,
        batch: usize,
        n: usize,
    ) -> NodeId {
        let (du, d) = (self.spec.du, self.spec.d);
        let ux = g.param(store, self.params.ux);
        let bu = g.param(store, self.params.bu);
        let act_u = if self.spec.nonlin_u { Some(Act::Tanh) } else { None };
        let u_full = g.affine_act(x, ux, bu, act_u); // (n·B, du)

        let abar_t = g.input(self.abar_t.clone());
        let bbar_row = g.input(self.bbar_row.clone());
        // memory in (B·du, d) layout so the step is one matmul
        let mut m = g.input(Tensor::zeros(&[batch * du, d]));
        let mut per_step: Vec<NodeId> = Vec::with_capacity(n);
        for t in 0..n {
            let u_t = g.slice_rows(u_full, t * batch, (t + 1) * batch); // (B, du)
            let u_col = g.reshape(u_t, &[batch * du, 1]);
            let drive = g.matmul(u_col, bbar_row); // (B·du, d)
            let decay = g.matmul(m, abar_t);
            m = g.add(decay, drive);
            per_step.push(g.reshape(m, &[batch, du * d]));
        }
        let m_all = g.concat_rows(&per_step); // (n·B, du·d) time-major

        let wm = g.param(store, self.params.wm);
        let wx = g.param(store, self.params.wx);
        let bo = g.param(store, self.params.bo);
        let mm = g.matmul(m_all, wm);
        let xx = g.matmul(x, wx);
        let act_o = if self.spec.nonlin_o { Some(Act::Tanh) } else { None };
        g.add2_row_act(mm, xx, bo, act_o)
    }

    /// Sequential forward returning only the final step's output (B, hidden).
    pub fn forward_last(
        &self,
        g: &mut Graph,
        store: &ParamStore,
        x: NodeId,
        batch: usize,
        n: usize,
    ) -> NodeId {
        let (du, d) = (self.spec.du, self.spec.d);
        let ux = g.param(store, self.params.ux);
        let bu = g.param(store, self.params.bu);
        let act_u = if self.spec.nonlin_u { Some(Act::Tanh) } else { None };
        let u_full = g.affine_act(x, ux, bu, act_u);

        let abar_t = g.input(self.abar_t.clone());
        let bbar_row = g.input(self.bbar_row.clone());
        let mut m = g.input(Tensor::zeros(&[batch * du, d]));
        for t in 0..n {
            let u_t = g.slice_rows(u_full, t * batch, (t + 1) * batch);
            let u_col = g.reshape(u_t, &[batch * du, 1]);
            let drive = g.matmul(u_col, bbar_row);
            let decay = g.matmul(m, abar_t);
            m = g.add(decay, drive);
        }
        let m_last = g.reshape(m, &[batch, du * d]);
        let x_last = g.slice_rows(x, (n - 1) * batch, n * batch);

        let wm = g.param(store, self.params.wm);
        let wx = g.param(store, self.params.wx);
        let bo = g.param(store, self.params.bo);
        let mm = g.matmul(m_last, wm);
        let xx = g.matmul(x_last, wx);
        let act_o = if self.spec.nonlin_o { Some(Act::Tanh) } else { None };
        g.add2_row_act(mm, xx, bo, act_o)
    }
}

// ---------------------------------------------------------------------------
// Original LMU (eqs. 15-17)
// ---------------------------------------------------------------------------

/// The original LMU cell: scalar DN input computed from x, h, and m
/// (eq. 15), DN update (eq. 16), nonlinear hidden state (eq. 17).
/// Three recurrent dependencies — cannot be parallelized.
pub struct LmuOriginalCell {
    pub dx: usize,
    pub dh: usize,
    pub d: usize,
    pub ex: ParamId,
    pub eh: ParamId,
    pub em: ParamId,
    pub wx: ParamId,
    pub wh: ParamId,
    pub wm: ParamId,
    abar_t: Tensor,
    bbar_row: Tensor,
}

impl LmuOriginalCell {
    pub fn new(
        dx: usize,
        dh: usize,
        d: usize,
        theta: f64,
        store: &mut ParamStore,
        rng: &mut Rng,
        prefix: &str,
    ) -> Self {
        let dn = DelayNetwork::new(d, theta);
        LmuOriginalCell {
            dx,
            dh,
            d,
            ex: store.add(&format!("{prefix}.ex"), Tensor::glorot(dx, 1, rng)),
            eh: store.add(&format!("{prefix}.eh"), Tensor::glorot(dh, 1, rng)),
            em: store.add(&format!("{prefix}.em"), Tensor::glorot(d, 1, rng)),
            wx: store.add(&format!("{prefix}.Wx"), Tensor::glorot(dx, dh, rng)),
            wh: store.add(&format!("{prefix}.Wh"), Tensor::recurrent_init(dh, rng)),
            wm: store.add(&format!("{prefix}.Wm"), Tensor::glorot(d, dh, rng)),
            abar_t: dn.abar_f32.transpose2(),
            bbar_row: Tensor::new(&[1, d], dn.bbar_f32.clone()),
        }
    }

    /// x time-major (n·B, dx) -> final hidden state (B, dh).
    pub fn forward_last(
        &self,
        g: &mut Graph,
        store: &ParamStore,
        x: NodeId,
        batch: usize,
        n: usize,
    ) -> NodeId {
        let ex = g.param(store, self.ex);
        let eh = g.param(store, self.eh);
        let em = g.param(store, self.em);
        let wx = g.param(store, self.wx);
        let wh = g.param(store, self.wh);
        let wm = g.param(store, self.wm);
        let abar_t = g.input(self.abar_t.clone());
        let bbar_row = g.input(self.bbar_row.clone());

        let mut h = g.input(Tensor::zeros(&[batch, self.dh]));
        let mut m = g.input(Tensor::zeros(&[batch, self.d]));
        for t in 0..n {
            let x_t = g.slice_rows(x, t * batch, (t + 1) * batch);
            // eq. 15: u_t = e_xᵀ x + e_hᵀ h_{t-1} + e_mᵀ m_{t-1}
            let uxp = g.matmul(x_t, ex);
            let uhp = g.matmul(h, eh);
            let ump = g.matmul(m, em);
            let u_t = g.add3_act(uxp, uhp, ump, None); // (B, 1)
            // eq. 16
            let drive = g.matmul(u_t, bbar_row);
            let decay = g.matmul(m, abar_t);
            m = g.add(decay, drive);
            // eq. 17: h = f(Wx x + Wh h + Wm m)
            let hx = g.matmul(x_t, wx);
            let hh = g.matmul(h, wh);
            let hm = g.matmul(m, wm);
            h = g.add3_act(hx, hh, hm, Some(Act::Tanh));
        }
        h
    }

    /// x time-major (n·B, dx) -> all hidden states, time-major (n·B, dh).
    pub fn forward_all(
        &self,
        g: &mut Graph,
        store: &ParamStore,
        x: NodeId,
        batch: usize,
        n: usize,
    ) -> NodeId {
        let ex = g.param(store, self.ex);
        let eh = g.param(store, self.eh);
        let em = g.param(store, self.em);
        let wx = g.param(store, self.wx);
        let wh = g.param(store, self.wh);
        let wm = g.param(store, self.wm);
        let abar_t = g.input(self.abar_t.clone());
        let bbar_row = g.input(self.bbar_row.clone());

        let mut h = g.input(Tensor::zeros(&[batch, self.dh]));
        let mut m = g.input(Tensor::zeros(&[batch, self.d]));
        let mut steps = Vec::with_capacity(n);
        for t in 0..n {
            let x_t = g.slice_rows(x, t * batch, (t + 1) * batch);
            let uxp = g.matmul(x_t, ex);
            let uhp = g.matmul(h, eh);
            let ump = g.matmul(m, em);
            let u_t = g.add3_act(uxp, uhp, ump, None);
            let drive = g.matmul(u_t, bbar_row);
            let decay = g.matmul(m, abar_t);
            m = g.add(decay, drive);
            let hx = g.matmul(x_t, wx);
            let hh = g.matmul(h, wh);
            let hm = g.matmul(m, wm);
            h = g.add3_act(hx, hh, hm, Some(Act::Tanh));
            steps.push(h);
        }
        g.concat_rows(&steps)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::layers::to_time_major;

    fn spec_small() -> LmuSpec {
        LmuSpec::new(3, 2, 8, 24.0, 5)
    }

    #[test]
    fn parallel_and_sequential_agree_all_states() {
        // identical parameters => identical outputs (train-parallel /
        // infer-recurrent equivalence, the paper's central claim)
        let mut rng = Rng::new(0);
        let mut store = ParamStore::new();
        let (batch, n) = (3usize, 24usize);
        let par = LmuParallelLayer::new(spec_small(), n, &mut store, &mut rng, "lmu");
        let seq = LmuSequentialLayer::with_params(
            spec_small(),
            LmuParams {
                ux: par.params.ux,
                bu: par.params.bu,
                wm: par.params.wm,
                wx: par.params.wx,
                bo: par.params.bo,
            },
        );

        let x_sm = Tensor::randn(&[batch * n, 3], 1.0, &mut rng);
        let x_tm = to_time_major(&x_sm, batch, n);

        let mut g1 = Graph::new();
        let xi = g1.input(x_sm.clone());
        let o_par = par.forward_all(&mut g1, &store, xi, batch);

        let mut g2 = Graph::new();
        let xi2 = g2.input(x_tm);
        let o_seq = seq.forward_all(&mut g2, &store, xi2, batch, n);

        // compare time-major vs sample-major
        let par_v = g1.value(o_par);
        let seq_v = g2.value(o_seq);
        let h = 5;
        let mut max_err = 0.0f32;
        for b in 0..batch {
            for t in 0..n {
                for j in 0..h {
                    let pv = par_v.data()[(b * n + t) * h + j];
                    let sv = seq_v.data()[(t * batch + b) * h + j];
                    max_err = max_err.max((pv - sv).abs());
                }
            }
        }
        assert!(max_err < 2e-4, "parallel/sequential diverge: {max_err}");
    }

    #[test]
    fn parallel_and_sequential_agree_last_state() {
        let mut rng = Rng::new(1);
        let mut store = ParamStore::new();
        let (batch, n) = (2usize, 16usize);
        let par = LmuParallelLayer::new(spec_small(), n, &mut store, &mut rng, "lmu");
        let seq = LmuSequentialLayer::with_params(
            spec_small(),
            LmuParams {
                ux: par.params.ux,
                bu: par.params.bu,
                wm: par.params.wm,
                wx: par.params.wx,
                bo: par.params.bo,
            },
        );
        let x_sm = Tensor::randn(&[batch * n, 3], 1.0, &mut rng);
        let x_tm = to_time_major(&x_sm, batch, n);
        let x_last = crate::layers::last_steps(&x_sm, batch, n);

        let mut g1 = Graph::new();
        let xi = g1.input(x_sm);
        let xl = g1.input(x_last);
        let o_par = par.forward_last(&mut g1, &store, xi, xl, batch);

        let mut g2 = Graph::new();
        let xi2 = g2.input(x_tm);
        let o_seq = seq.forward_last(&mut g2, &store, xi2, batch, n);

        let err = g1.value(o_par).max_abs_diff(g2.value(o_seq));
        assert!(err < 2e-4, "last-state diverge: {err}");
    }

    #[test]
    fn parallel_layer_trains() {
        // a few Adam-free GD steps reduce a regression loss
        let mut rng = Rng::new(2);
        let mut store = ParamStore::new();
        let (batch, n) = (4usize, 12usize);
        let layer = LmuParallelLayer::new(spec_small(), n, &mut store, &mut rng, "lmu");
        let x = Tensor::randn(&[batch * n, 3], 1.0, &mut rng);
        let x_last = crate::layers::last_steps(&x, batch, n);
        let target = Tensor::randn(&[batch, 5], 0.5, &mut rng);
        let mut opt = crate::optim::Adam::new(0.02);
        let mut losses = Vec::new();
        for _ in 0..60 {
            let mut g = Graph::new();
            let xi = g.input(x.clone());
            let xl = g.input(x_last.clone());
            let o = layer.forward_last(&mut g, &store, xi, xl, batch);
            let loss = g.mse(o, &target);
            g.backward(loss);
            losses.push(g.value(loss).item());
            let grads = g.param_grads();
            crate::optim::Optimizer::step(&mut opt, &mut store, &grads);
        }
        assert!(
            losses.last().unwrap() < &(losses[0] * 0.5),
            "loss did not halve: {:?} -> {:?}",
            losses[0],
            losses.last().unwrap()
        );
    }

    #[test]
    fn original_cell_shapes_and_grads() {
        let mut rng = Rng::new(3);
        let mut store = ParamStore::new();
        let (batch, n, dx, dh, d) = (2usize, 10usize, 3usize, 6usize, 4usize);
        let cell = LmuOriginalCell::new(dx, dh, d, n as f64, &mut store, &mut rng, "orig");
        let x = Tensor::randn(&[n * batch, dx], 1.0, &mut rng);
        let mut g = Graph::new();
        let xi = g.input(x);
        let h = cell.forward_last(&mut g, &store, xi, batch, n);
        assert_eq!(g.value(h).shape(), &[batch, dh]);
        let sq = g.mul(h, h);
        let loss = g.mean_all(sq);
        g.backward(loss);
        let grads = g.param_grads();
        assert_eq!(grads.len(), 6, "all six param groups get gradients");
        for (pid, gr) in grads {
            assert!(
                gr.data().iter().all(|v| v.is_finite()),
                "non-finite grad for {}",
                store.name(pid)
            );
            assert!(gr.abs_max() > 0.0, "zero grad for {}", store.name(pid));
        }
    }

    #[test]
    fn dn_only_matches_delay_network_last() {
        let mut rng = Rng::new(4);
        let mut store = ParamStore::new();
        let (batch, n, d) = (2usize, 20usize, 6usize);
        let spec = LmuSpec { dx: 3, du: 3, d, theta: n as f64, hidden: 1, nonlin_u: false, nonlin_o: false };
        let layer = LmuParallelLayer::new(spec, n, &mut store, &mut rng, "dn");
        let x = Tensor::randn(&[batch * n, 3], 1.0, &mut rng);
        let mut g = Graph::new();
        let xi = g.input(x.clone());
        let m = layer.dn_only_last(&mut g, xi, batch);
        assert_eq!(g.value(m).shape(), &[batch, 3 * d]);
        // cross-check against DelayNetwork::parallel_last per sample
        let dn = DelayNetwork::new(d, n as f64);
        for b in 0..batch {
            let xb = x.slice_rows(b * n, (b + 1) * n);
            let last = dn.parallel_last(&xb); // (d, du)
            for c in 0..3 {
                for s in 0..d {
                    let got = g.value(m).data()[b * 3 * d + c * d + s];
                    let expect = last.data()[s * 3 + c];
                    assert!((got - expect).abs() < 2e-4);
                }
            }
        }
    }
}
