//! LSTM baseline (Hochreiter & Schmidhuber 1997) — the model the paper
//! compares against on every task.  Standard formulation with a fused
//! gate matmul and forget-gate bias init of 1.

use crate::autograd::{Graph, NodeId, ParamId, ParamStore};
use crate::tensor::Tensor;
use crate::util::Rng;

/// A single LSTM layer with fused gates: [i, f, g, o] = x Wx + h Wh + b.
pub struct LstmLayer {
    pub dx: usize,
    pub dh: usize,
    pub wx: ParamId,
    pub wh: ParamId,
    pub b: ParamId,
}

impl LstmLayer {
    pub fn new(dx: usize, dh: usize, store: &mut ParamStore, rng: &mut Rng, prefix: &str) -> Self {
        let wx = store.add(&format!("{prefix}.Wx"), Tensor::glorot(dx, 4 * dh, rng));
        let wh = store.add(&format!("{prefix}.Wh"), {
            let mut t = Tensor::recurrent_init(dh, rng);
            // widen to (dh, 4dh)
            let mut full = Tensor::glorot(dh, 4 * dh, rng);
            // keep the recurrent block scaling for the candidate gate region
            for i in 0..dh {
                for j in 0..dh {
                    full.data_mut()[i * 4 * dh + 2 * dh + j] = t.data()[i * dh + j];
                }
            }
            t = full;
            t
        });
        // forget gate bias = 1 (standard trick for gradient flow)
        let mut bias = Tensor::zeros(&[4 * dh]);
        for j in dh..2 * dh {
            bias.data_mut()[j] = 1.0;
        }
        let b = store.add(&format!("{prefix}.b"), bias);
        LstmLayer { dx, dh, wx, wh, b }
    }

    fn step(
        &self,
        g: &mut Graph,
        x_t: NodeId,
        h: NodeId,
        c: NodeId,
        wx: NodeId,
        wh: NodeId,
        b: NodeId,
    ) -> (NodeId, NodeId) {
        let dh = self.dh;
        let gx = g.matmul(x_t, wx);
        let gh = g.matmul(h, wh);
        let gates = g.add2_row_act(gx, gh, b, None); // (B, 4dh)
        let i_g = {
            let sl = g.slice_cols(gates, 0, dh);
            g.sigmoid(sl)
        };
        let f_g = {
            let sl = g.slice_cols(gates, dh, 2 * dh);
            g.sigmoid(sl)
        };
        let g_g = {
            let sl = g.slice_cols(gates, 2 * dh, 3 * dh);
            g.tanh(sl)
        };
        let o_g = {
            let sl = g.slice_cols(gates, 3 * dh, 4 * dh);
            g.sigmoid(sl)
        };
        let fc = g.mul(f_g, c);
        let ig = g.mul(i_g, g_g);
        let c_new = g.add(fc, ig);
        let tc = g.tanh(c_new);
        let h_new = g.mul(o_g, tc);
        (h_new, c_new)
    }

    /// x time-major (n·B, dx) -> final hidden state (B, dh).
    pub fn forward_last(
        &self,
        g: &mut Graph,
        store: &ParamStore,
        x: NodeId,
        batch: usize,
        n: usize,
    ) -> NodeId {
        let wx = g.param(store, self.wx);
        let wh = g.param(store, self.wh);
        let b = g.param(store, self.b);
        let mut h = g.input(Tensor::zeros(&[batch, self.dh]));
        let mut c = g.input(Tensor::zeros(&[batch, self.dh]));
        for t in 0..n {
            let x_t = g.slice_rows(x, t * batch, (t + 1) * batch);
            let (h2, c2) = self.step(g, x_t, h, c, wx, wh, b);
            h = h2;
            c = c2;
        }
        h
    }

    /// x time-major (n·B, dx) -> all hidden states, time-major (n·B, dh).
    pub fn forward_all(
        &self,
        g: &mut Graph,
        store: &ParamStore,
        x: NodeId,
        batch: usize,
        n: usize,
    ) -> NodeId {
        let wx = g.param(store, self.wx);
        let wh = g.param(store, self.wh);
        let b = g.param(store, self.b);
        let mut h = g.input(Tensor::zeros(&[batch, self.dh]));
        let mut c = g.input(Tensor::zeros(&[batch, self.dh]));
        let mut steps = Vec::with_capacity(n);
        for t in 0..n {
            let x_t = g.slice_rows(x, t * batch, (t + 1) * batch);
            let (h2, c2) = self.step(g, x_t, h, c, wx, wh, b);
            h = h2;
            c = c2;
            steps.push(h);
        }
        g.concat_rows(&steps)
    }

    /// Parameter count: 4·dh·(dx + dh + 1).
    pub fn num_params(&self) -> usize {
        4 * self.dh * (self.dx + self.dh + 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_param_count() {
        let mut rng = Rng::new(0);
        let mut store = ParamStore::new();
        let lstm = LstmLayer::new(3, 8, &mut store, &mut rng, "lstm");
        assert_eq!(lstm.num_params(), 4 * 8 * (3 + 8 + 1));
        assert_eq!(store.num_scalars(), lstm.num_params());
        let (batch, n) = (2, 5);
        let x = Tensor::randn(&[n * batch, 3], 1.0, &mut rng);
        let mut g = Graph::new();
        let xi = g.input(x);
        let h = lstm.forward_last(&mut g, &store, xi, batch, n);
        assert_eq!(g.value(h).shape(), &[batch, 8]);
        let all = lstm.forward_all(&mut g, &store, xi, batch, n);
        assert_eq!(g.value(all).shape(), &[n * batch, 8]);
    }

    #[test]
    fn hidden_state_bounded() {
        // |h| <= 1 by construction (o · tanh(c))
        let mut rng = Rng::new(1);
        let mut store = ParamStore::new();
        let lstm = LstmLayer::new(2, 4, &mut store, &mut rng, "lstm");
        let (batch, n) = (3, 50);
        let x = Tensor::randn(&[n * batch, 2], 3.0, &mut rng);
        let mut g = Graph::new();
        let xi = g.input(x);
        let h = lstm.forward_last(&mut g, &store, xi, batch, n);
        assert!(g.value(h).abs_max() <= 1.0 + 1e-6);
    }

    #[test]
    fn gradients_flow_to_all_params() {
        let mut rng = Rng::new(2);
        let mut store = ParamStore::new();
        let lstm = LstmLayer::new(3, 4, &mut store, &mut rng, "lstm");
        let (batch, n) = (2, 8);
        let x = Tensor::randn(&[n * batch, 3], 1.0, &mut rng);
        let mut g = Graph::new();
        let xi = g.input(x);
        let h = lstm.forward_last(&mut g, &store, xi, batch, n);
        let sq = g.mul(h, h);
        let loss = g.mean_all(sq);
        g.backward(loss);
        let grads = g.param_grads();
        assert_eq!(grads.len(), 3);
        for (pid, gr) in grads {
            assert!(gr.abs_max() > 0.0, "zero grad for {}", store.name(pid));
            assert!(gr.data().iter().all(|v| v.is_finite()));
        }
    }

    #[test]
    fn lstm_learns_to_remember_first_token() {
        // task: output sign of the first input — requires memory
        let mut rng = Rng::new(3);
        let mut store = ParamStore::new();
        let lstm = LstmLayer::new(1, 8, &mut store, &mut rng, "lstm");
        let wout = store.add("out.w", Tensor::glorot(8, 2, &mut rng));
        let bout = store.add("out.b", Tensor::zeros(&[2]));
        let (batch, n) = (8, 12);
        let mut opt = crate::optim::Adam::new(0.01);
        let mut losses = Vec::new();
        for it in 0..250 {
            let mut data = Tensor::randn(&[n * batch, 1], 1.0, &mut rng);
            let mut labels = vec![0usize; batch];
            for b in 0..batch {
                let first = if (it + b) % 2 == 0 { 1.0 } else { -1.0 };
                data.data_mut()[b] = first; // time-major row t=0
                labels[b] = if first > 0.0 { 1 } else { 0 };
            }
            let mut g = Graph::new();
            let xi = g.input(data);
            let h = lstm.forward_last(&mut g, &store, xi, batch, n);
            let wo = g.param(&store, wout);
            let bo = g.param(&store, bout);
            let logits = g.affine(h, wo, bo);
            let loss = g.softmax_xent(logits, &labels);
            g.backward(loss);
            losses.push(g.value(loss).item());
            let grads = g.param_grads();
            crate::optim::Optimizer::step(&mut opt, &mut store, &grads);
        }
        let early: f32 = losses[..10].iter().sum::<f32>() / 10.0;
        let late: f32 = losses[losses.len() - 10..].iter().sum::<f32>() / 10.0;
        assert!(late < early * 0.5, "LSTM failed to learn: {early} -> {late}");
    }
}
