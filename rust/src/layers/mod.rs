//! Model layers: the three LMU variants under comparison (original
//! eq. 15–17, our-model sequential LTI eq. 18–20, our-model parallel
//! eq. 24/25/26), the LSTM baseline, and the feed-forward building blocks
//! (dense / highway / embedding) the paper's NLP architectures use.
//!
//! Sequence layout conventions:
//!  * parallel layers take **sample-major** rows `(B·n, dx)` (row `b·n+t`);
//!  * sequential cells take **time-major** rows `(n·B, dx)` (row `t·B+b`)
//!    so each step is a contiguous row slice.
//! `to_time_major` / `to_sample_major` convert; both are pure row
//! permutations, so they row-partition the output across `crate::exec`
//! workers above the size threshold (each output row is written exactly
//! once — bit-exact at any thread count).

pub mod attention;
pub mod dense;
pub mod lmu;
pub mod lstm;

pub use attention::SelfAttention;
pub use dense::{Activation, Dense, Embedding, Highway};
pub use lmu::{LmuOriginalCell, LmuParallelLayer, LmuSequentialLayer};
pub use lstm::LstmLayer;

use crate::exec;
use crate::tensor::Tensor;

/// (B, n, f) sample-major rows -> (n, B, f) time-major rows.
pub fn to_time_major(x: &Tensor, batch: usize, n: usize) -> Tensor {
    let f = x.cols();
    assert_eq!(x.rows(), batch * n);
    let mut out = Tensor::zeros(&[n * batch, f]);
    if f == 0 || batch * n == 0 {
        return out;
    }
    let xd = x.data();
    let plan = exec::plan_for(batch * n, batch * n * f);
    exec::parallel_rows_mut(out.data_mut(), f, plan, |r0, block| {
        for (k, row) in block.chunks_mut(f).enumerate() {
            let r = r0 + k; // time-major row index = t*batch + b
            let (t, b) = (r / batch, r % batch);
            row.copy_from_slice(&xd[(b * n + t) * f..(b * n + t + 1) * f]);
        }
    });
    out
}

/// (n, B, f) time-major rows -> (B, n, f) sample-major rows.
pub fn to_sample_major(x: &Tensor, batch: usize, n: usize) -> Tensor {
    let f = x.cols();
    assert_eq!(x.rows(), batch * n);
    let mut out = Tensor::zeros(&[batch * n, f]);
    if f == 0 || batch * n == 0 {
        return out;
    }
    let xd = x.data();
    let plan = exec::plan_for(batch * n, batch * n * f);
    exec::parallel_rows_mut(out.data_mut(), f, plan, |r0, block| {
        for (k, row) in block.chunks_mut(f).enumerate() {
            let r = r0 + k; // sample-major row index = b*n + t
            let (b, t) = (r / n, r % n);
            row.copy_from_slice(&xd[(t * batch + b) * f..(t * batch + b + 1) * f]);
        }
    });
    out
}

/// Extract the last timestep rows from a sample-major (B·n, f) tensor.
pub fn last_steps(x: &Tensor, batch: usize, n: usize) -> Tensor {
    let f = x.cols();
    let mut out = Tensor::zeros(&[batch, f]);
    for b in 0..batch {
        let src = &x.data()[(b * n + n - 1) * f..(b * n + n) * f];
        out.data_mut()[b * f..(b + 1) * f].copy_from_slice(src);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn layout_roundtrip() {
        let mut rng = Rng::new(0);
        let (b, n, f) = (3, 5, 2);
        let x = Tensor::randn(&[b * n, f], 1.0, &mut rng);
        let tm = to_time_major(&x, b, n);
        let back = to_sample_major(&tm, b, n);
        assert!(x.allclose(&back, 0.0));
    }

    #[test]
    fn time_major_places_rows() {
        // sample-major row (b=1, t=0) must land at time-major row (t=0, b=1)
        let (b, n, f) = (2, 3, 1);
        let x = Tensor::new(&[b * n, f], vec![0., 1., 2., 10., 11., 12.]);
        let tm = to_time_major(&x, b, n);
        assert_eq!(tm.data(), &[0., 10., 1., 11., 2., 12.]);
    }

    #[test]
    fn last_steps_extracts_tail() {
        let (b, n, f) = (2, 3, 2);
        let x = Tensor::new(
            &[b * n, f],
            (0..12).map(|i| i as f32).collect::<Vec<_>>(),
        );
        let last = last_steps(&x, b, n);
        assert_eq!(last.data(), &[4., 5., 10., 11.]);
    }
}
