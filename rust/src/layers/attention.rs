//! Single-head scaled dot-product self-attention (Vaswani et al. 2017).
//!
//! Two uses in this repo:
//!  * the Table 1 complexity row (`O(n² d_x)`) — forward-only timing;
//!  * the decoder attention of the translation experiment (Table 6) and
//!    the text8 note (§4.4) — trained through autograd using the
//!    primitive ops (matmul/softmax are expressed with existing nodes is
//!    not possible for row-softmax, so training uses [`attention_forward`]
//!    outputs as features via the fixed-context trick; the benches only
//!    need the forward cost).

use crate::tensor::Tensor;
use crate::util::Rng;

/// Forward-only self-attention over one sequence: x (n, dx) -> (n, dx).
pub struct SelfAttention {
    pub dx: usize,
    pub wq: Tensor,
    pub wk: Tensor,
    pub wv: Tensor,
    /// causal masking (decoder-style) if true
    pub causal: bool,
}

impl SelfAttention {
    pub fn new(dx: usize, causal: bool, rng: &mut Rng) -> Self {
        SelfAttention {
            dx,
            wq: Tensor::glorot(dx, dx, rng),
            wk: Tensor::glorot(dx, dx, rng),
            wv: Tensor::glorot(dx, dx, rng),
            causal,
        }
    }

    /// softmax(Q Kᵀ / √dx) V
    pub fn forward(&self, x: &Tensor) -> Tensor {
        let n = x.rows();
        let q = x.matmul(&self.wq);
        let k = x.matmul(&self.wk);
        let v = x.matmul(&self.wv);
        let mut scores = q.matmul_nt(&k); // (n, n)
        let scale = 1.0 / (self.dx as f32).sqrt();
        scores.map_inplace(|s| s * scale);
        if self.causal {
            for i in 0..n {
                for j in i + 1..n {
                    scores.data_mut()[i * n + j] = f32::NEG_INFINITY;
                }
            }
        }
        let attn = scores.softmax_rows();
        attn.matmul(&v)
    }

    /// Cross-attention: queries from `x` (n, dx), keys/values from
    /// `context` (m, dx) — the translation decoder's attention.
    pub fn forward_cross(&self, x: &Tensor, context: &Tensor) -> Tensor {
        let q = x.matmul(&self.wq);
        let k = context.matmul(&self.wk);
        let v = context.matmul(&self.wv);
        let mut scores = q.matmul_nt(&k);
        let scale = 1.0 / (self.dx as f32).sqrt();
        scores.map_inplace(|s| s * scale);
        let attn = scores.softmax_rows();
        attn.matmul(&v)
    }

    pub fn num_params(&self) -> usize {
        3 * self.dx * self.dx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn output_shape_matches_input() {
        let mut rng = Rng::new(0);
        let att = SelfAttention::new(8, false, &mut rng);
        let x = Tensor::randn(&[12, 8], 1.0, &mut rng);
        let y = att.forward(&x);
        assert_eq!(y.shape(), &[12, 8]);
        assert!(y.data().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn causal_mask_respects_order() {
        // with a causal mask, changing future inputs must not change
        // earlier outputs
        let mut rng = Rng::new(1);
        let att = SelfAttention::new(4, true, &mut rng);
        let mut x = Tensor::randn(&[6, 4], 1.0, &mut rng);
        let y1 = att.forward(&x);
        // perturb the last timestep
        for j in 0..4 {
            x.data_mut()[5 * 4 + j] += 10.0;
        }
        let y2 = att.forward(&x);
        for t in 0..5 {
            for j in 0..4 {
                assert!(
                    (y1.data()[t * 4 + j] - y2.data()[t * 4 + j]).abs() < 1e-5,
                    "future leaked into t={t}"
                );
            }
        }
        // ...but the last step does change
        let mut changed = false;
        for j in 0..4 {
            if (y1.data()[5 * 4 + j] - y2.data()[5 * 4 + j]).abs() > 1e-4 {
                changed = true;
            }
        }
        assert!(changed);
    }

    #[test]
    fn non_causal_attends_globally() {
        let mut rng = Rng::new(2);
        let att = SelfAttention::new(4, false, &mut rng);
        let mut x = Tensor::randn(&[6, 4], 1.0, &mut rng);
        let y1 = att.forward(&x);
        for j in 0..4 {
            x.data_mut()[5 * 4 + j] += 10.0;
        }
        let y2 = att.forward(&x);
        // earlier outputs DO change without the mask
        let diff = y1.max_abs_diff(&y2);
        assert!(diff > 1e-3);
    }

    #[test]
    fn cross_attention_shapes() {
        let mut rng = Rng::new(3);
        let att = SelfAttention::new(8, false, &mut rng);
        let x = Tensor::randn(&[5, 8], 1.0, &mut rng);
        let ctx = Tensor::randn(&[9, 8], 1.0, &mut rng);
        let y = att.forward_cross(&x, &ctx);
        assert_eq!(y.shape(), &[5, 8]);
    }
}
