//! Feed-forward building blocks: dense, highway (Srivastava et al. 2015 —
//! the paper's language-model blocks are DN + dense + highway), and token
//! embedding.

use crate::autograd::{Act, Graph, NodeId, ParamId, ParamStore};
use crate::tensor::Tensor;
use crate::util::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Activation {
    Linear,
    Tanh,
    Relu,
    Sigmoid,
}

impl Activation {
    pub fn apply(&self, g: &mut Graph, x: NodeId) -> NodeId {
        match self {
            Activation::Linear => x,
            Activation::Tanh => g.tanh(x),
            Activation::Relu => g.relu(x),
            Activation::Sigmoid => g.sigmoid(x),
        }
    }
}

/// y = act(x W + b)
pub struct Dense {
    pub w: ParamId,
    pub b: ParamId,
    pub act: Activation,
    pub din: usize,
    pub dout: usize,
}

impl Dense {
    pub fn new(
        din: usize,
        dout: usize,
        act: Activation,
        store: &mut ParamStore,
        rng: &mut Rng,
        prefix: &str,
    ) -> Self {
        Dense {
            w: store.add(&format!("{prefix}.w"), Tensor::glorot(din, dout, rng)),
            b: store.add(&format!("{prefix}.b"), Tensor::zeros(&[dout])),
            act,
            din,
            dout,
        }
    }

    pub fn forward(&self, g: &mut Graph, store: &ParamStore, x: NodeId) -> NodeId {
        let w = g.param(store, self.w);
        let b = g.param(store, self.b);
        match self.act {
            // tanh/relu ride the fused affine epilogue; sigmoid has no
            // fused kernel and stays a separate node
            Activation::Tanh => g.affine_act(x, w, b, Some(Act::Tanh)),
            Activation::Relu => g.affine_act(x, w, b, Some(Act::Relu)),
            _ => {
                let a = g.affine(x, w, b);
                self.act.apply(g, a)
            }
        }
    }

    pub fn num_params(&self) -> usize {
        self.din * self.dout + self.dout
    }
}

/// Highway layer: y = t ⊙ h(x) + (1 − t) ⊙ x with t = σ(x Wt + bt).
/// Gate bias initialized negative (paper: −1) so early training passes
/// the input through.
pub struct Highway {
    pub wt: ParamId,
    pub bt: ParamId,
    pub wh: ParamId,
    pub bh: ParamId,
    pub dim: usize,
}

impl Highway {
    pub fn new(dim: usize, store: &mut ParamStore, rng: &mut Rng, prefix: &str) -> Self {
        Highway {
            wt: store.add(&format!("{prefix}.wt"), Tensor::glorot(dim, dim, rng)),
            bt: store.add(&format!("{prefix}.bt"), Tensor::full(&[dim], -1.0)),
            wh: store.add(&format!("{prefix}.wh"), Tensor::glorot(dim, dim, rng)),
            bh: store.add(&format!("{prefix}.bh"), Tensor::zeros(&[dim])),
            dim,
        }
    }

    pub fn forward(&self, g: &mut Graph, store: &ParamStore, x: NodeId) -> NodeId {
        let wt = g.param(store, self.wt);
        let bt = g.param(store, self.bt);
        let wh = g.param(store, self.wh);
        let bh = g.param(store, self.bh);
        let ta = g.affine(x, wt, bt);
        let t = g.sigmoid(ta);
        let h = g.affine_act(x, wh, bh, Some(Act::Tanh));
        let th = g.mul(t, h);
        let one_minus_t = g.one_minus(t);
        let carry = g.mul(one_minus_t, x);
        g.add(th, carry)
    }

    pub fn num_params(&self) -> usize {
        2 * (self.dim * self.dim + self.dim)
    }
}

/// Token embedding table, optionally frozen (the paper's GloVe stand-in is
/// frozen random embeddings — see DESIGN.md §Substitutions).
pub struct Embedding {
    pub table: ParamId,
    pub vocab: usize,
    pub dim: usize,
    pub frozen: bool,
}

impl Embedding {
    pub fn new(vocab: usize, dim: usize, store: &mut ParamStore, rng: &mut Rng, prefix: &str) -> Self {
        let t = Tensor::randn(&[vocab, dim], 1.0 / (dim as f32).sqrt(), rng);
        Embedding { table: store.add(&format!("{prefix}.emb"), t), vocab, dim, frozen: false }
    }

    pub fn frozen(mut self) -> Self {
        self.frozen = true;
        self
    }

    /// ids -> (len, dim).  Frozen tables enter the graph as constants so
    /// no gradient is computed or applied.
    pub fn forward(&self, g: &mut Graph, store: &ParamStore, ids: &[usize]) -> NodeId {
        let table = if self.frozen {
            g.input(store.get(self.table).clone())
        } else {
            g.param(store, self.table)
        };
        g.embedding(table, ids)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_shapes_and_activation() {
        let mut rng = Rng::new(0);
        let mut store = ParamStore::new();
        let layer = Dense::new(4, 3, Activation::Relu, &mut store, &mut rng, "d");
        assert_eq!(layer.num_params(), 15);
        let mut g = Graph::new();
        let x = g.input(Tensor::randn(&[5, 4], 1.0, &mut rng));
        let y = layer.forward(&mut g, &store, x);
        assert_eq!(g.value(y).shape(), &[5, 3]);
        assert!(g.value(y).data().iter().all(|&v| v >= 0.0)); // relu
    }

    #[test]
    fn highway_initially_passes_input_through() {
        // bt = -1 => gate ≈ 0.27, output closer to x than to h; with
        // bt very negative it converges to identity
        let mut rng = Rng::new(1);
        let mut store = ParamStore::new();
        let hw = Highway::new(6, &mut store, &mut rng, "hw");
        // force the gate closed
        store.get_mut(hw.bt).map_inplace(|_| -20.0);
        let mut g = Graph::new();
        let x_val = Tensor::randn(&[3, 6], 1.0, &mut rng);
        let x = g.input(x_val.clone());
        let y = hw.forward(&mut g, &store, x);
        assert!(g.value(y).allclose(&x_val, 1e-4));
    }

    #[test]
    fn highway_gradients_flow() {
        let mut rng = Rng::new(2);
        let mut store = ParamStore::new();
        let hw = Highway::new(4, &mut store, &mut rng, "hw");
        let mut g = Graph::new();
        let x = g.input(Tensor::randn(&[2, 4], 1.0, &mut rng));
        let y = hw.forward(&mut g, &store, x);
        let sq = g.mul(y, y);
        let loss = g.mean_all(sq);
        g.backward(loss);
        assert_eq!(g.param_grads().len(), 4);
    }

    #[test]
    fn embedding_gathers_and_freezes() {
        let mut rng = Rng::new(3);
        let mut store = ParamStore::new();
        let emb = Embedding::new(10, 4, &mut store, &mut rng, "e");
        let ids = vec![2usize, 7, 2];
        let mut g = Graph::new();
        let e = emb.forward(&mut g, &store, &ids);
        assert_eq!(g.value(e).shape(), &[3, 4]);
        // rows 0 and 2 identical (same token)
        let v = g.value(e);
        for j in 0..4 {
            assert_eq!(v.data()[j], v.data()[2 * 4 + j]);
        }
        // frozen variant: no grads
        let emb_f = Embedding::new(10, 4, &mut store, &mut rng, "ef").frozen();
        let mut g2 = Graph::new();
        let e2 = emb_f.forward(&mut g2, &store, &ids);
        let loss = g2.mean_all(e2);
        g2.backward(loss);
        assert!(g2.param_grads().is_empty());
    }
}
