//! Autograd correctness: every op's analytic gradient is checked against
//! central finite differences on random inputs.

use super::*;
use crate::dn::DelayNetwork;
use crate::tensor::Tensor;
use crate::util::Rng;

/// Central finite-difference gradient of `f` w.r.t. the parameter at `id`.
fn numeric_grad(
    store: &mut ParamStore,
    id: ParamId,
    mut f: impl FnMut(&ParamStore) -> f32,
    eps: f32,
) -> Tensor {
    let n = store.get(id).len();
    let shape = store.get(id).shape().to_vec();
    let mut g = Tensor::zeros(&shape);
    for i in 0..n {
        let orig = store.get(id).data()[i];
        store.get_mut(id).data_mut()[i] = orig + eps;
        let fp = f(store);
        store.get_mut(id).data_mut()[i] = orig - eps;
        let fm = f(store);
        store.get_mut(id).data_mut()[i] = orig;
        g.data_mut()[i] = (fp - fm) / (2.0 * eps);
    }
    g
}

fn check_grads(
    store: &mut ParamStore,
    build: impl Fn(&mut Graph, &ParamStore) -> NodeId,
    tol: f32,
) {
    // analytic
    let mut g = Graph::new();
    let loss = build(&mut g, store);
    g.backward(loss);
    let analytic = g.param_grads();
    assert!(!analytic.is_empty(), "no parameter gradients produced");
    // numeric per param
    for (pid, ag) in &analytic {
        let ng = numeric_grad(
            store,
            *pid,
            |s| {
                let mut g2 = Graph::new();
                let l = build(&mut g2, s);
                g2.value(l).item()
            },
            1e-3,
        );
        let err = ag.max_abs_diff(&ng);
        let scale = ng.abs_max().max(1.0);
        assert!(
            err / scale < tol,
            "param {pid:?} grad mismatch: err={err} scale={scale}\nanalytic={ag:?}\nnumeric={ng:?}"
        );
    }
}

#[test]
fn grad_affine_tanh_mse() {
    let mut rng = Rng::new(0);
    let mut store = ParamStore::new();
    let w = store.add("w", Tensor::randn(&[3, 2], 0.5, &mut rng));
    let b = store.add("b", Tensor::randn(&[2], 0.5, &mut rng));
    let x = Tensor::randn(&[4, 3], 1.0, &mut rng);
    let target = Tensor::randn(&[4, 2], 1.0, &mut rng);
    check_grads(
        &mut store,
        |g, s| {
            let xw = {
                let xi = g.input(x.clone());
                let wi = g.param(s, w);
                let bi = g.param(s, b);
                g.affine(xi, wi, bi)
            };
            let y = g.tanh(xw);
            g.mse(y, &target)
        },
        2e-2,
    );
}

#[test]
fn grad_elementwise_chain() {
    let mut rng = Rng::new(1);
    let mut store = ParamStore::new();
    let a = store.add("a", Tensor::randn(&[5], 0.8, &mut rng));
    let b = store.add("b", Tensor::randn(&[5], 0.8, &mut rng));
    check_grads(
        &mut store,
        |g, s| {
            let ai = g.param(s, a);
            let bi = g.param(s, b);
            let prod = g.mul(ai, bi);
            let sg = g.sigmoid(prod);
            let om = g.one_minus(sg);
            let sq = g.mul(om, om);
            g.mean_all(sq)
        },
        2e-2,
    );
}

#[test]
fn grad_relu_abs_sub() {
    let mut rng = Rng::new(2);
    let mut store = ParamStore::new();
    // offset away from 0 to dodge the kink in finite differences
    let mut t = Tensor::randn(&[6], 1.0, &mut rng);
    t.map_inplace(|v| if v.abs() < 0.2 { v + 0.5 } else { v });
    let a = store.add("a", t);
    check_grads(
        &mut store,
        |g, s| {
            let ai = g.param(s, a);
            let r = g.relu(ai);
            let half = g.scale(ai, 0.5);
            let d = g.sub(r, half);
            let ab = g.abs(d);
            g.sum_all(ab)
        },
        2e-2,
    );
}

#[test]
fn grad_softmax_xent() {
    let mut rng = Rng::new(3);
    let mut store = ParamStore::new();
    let w = store.add("w", Tensor::randn(&[4, 3], 0.5, &mut rng));
    let x = Tensor::randn(&[5, 4], 1.0, &mut rng);
    let labels = vec![0usize, 2, 1, 2, 0];
    check_grads(
        &mut store,
        |g, s| {
            let xi = g.input(x.clone());
            let wi = g.param(s, w);
            let logits = g.matmul(xi, wi);
            g.softmax_xent(logits, &labels)
        },
        2e-2,
    );
}

#[test]
fn grad_slice_concat_reshape() {
    let mut rng = Rng::new(4);
    let mut store = ParamStore::new();
    let a = store.add("a", Tensor::randn(&[4, 6], 0.7, &mut rng));
    check_grads(
        &mut store,
        |g, s| {
            let ai = g.param(s, a);
            let left = g.slice_cols(ai, 0, 3);
            let right = g.slice_cols(ai, 3, 6);
            let prod = g.mul(left, right);
            let top = g.slice_rows(prod, 0, 2);
            let bottom = g.slice_rows(prod, 2, 4);
            let cat = g.concat_cols(&[top, bottom]);
            let rs = g.reshape(cat, &[12, 1]);
            let t = g.tanh(rs);
            g.mean_all(t)
        },
        2e-2,
    );
}

#[test]
fn grad_concat_rows() {
    let mut rng = Rng::new(5);
    let mut store = ParamStore::new();
    let a = store.add("a", Tensor::randn(&[2, 3], 0.7, &mut rng));
    let b = store.add("b", Tensor::randn(&[3, 3], 0.7, &mut rng));
    check_grads(
        &mut store,
        |g, s| {
            let ai = g.param(s, a);
            let bi = g.param(s, b);
            let cat = g.concat_rows(&[ai, bi]);
            let sq = g.mul(cat, cat);
            g.sum_all(sq)
        },
        2e-2,
    );
}

#[test]
fn grad_embedding() {
    let mut rng = Rng::new(6);
    let mut store = ParamStore::new();
    let table = store.add("emb", Tensor::randn(&[7, 4], 0.5, &mut rng));
    let ids = vec![1usize, 3, 1, 6]; // repeated id accumulates
    check_grads(
        &mut store,
        |g, s| {
            let ti = g.param(s, table);
            let e = g.embedding(ti, &ids);
            let t = g.tanh(e);
            g.mean_all(t)
        },
        2e-2,
    );
}

#[test]
fn grad_dn_conv_matches_fd() {
    let mut rng = Rng::new(7);
    let (n, d, du, batch) = (12usize, 4usize, 2usize, 2usize);
    let dn = DelayNetwork::new(d, n as f64);
    let op =
        std::sync::Arc::new(crate::dn::DnOperator::Fft(crate::dn::DnFftOperator::new(&dn, n)));
    let mut store = ParamStore::new();
    let u = store.add("u", Tensor::randn(&[batch * n, du], 0.5, &mut rng));
    let w = Tensor::randn(&[batch * n, du * d], 0.5, &mut rng);
    check_grads(
        &mut store,
        |g, s| {
            let ui = g.param(s, u);
            let m = g.dn_conv(ui, op.clone(), batch);
            let wi = g.input(w.clone());
            let prod = g.mul(m, wi);
            g.sum_all(prod)
        },
        2e-2,
    );
}

#[test]
fn grad_dn_conv_scan_matches_fd() {
    // same harness as grad_dn_conv_matches_fd, routed through the
    // chunked-scan operator with a block that does not divide n
    let mut rng = Rng::new(7);
    let (n, d, du, batch) = (12usize, 4usize, 2usize, 2usize);
    let dn = DelayNetwork::new(d, n as f64);
    let op = std::sync::Arc::new(crate::dn::DnOperator::Scan(std::sync::Arc::new(
        crate::dn::DnScanOperator::new(&dn, n, 5),
    )));
    let mut store = ParamStore::new();
    let u = store.add("u", Tensor::randn(&[batch * n, du], 0.5, &mut rng));
    let w = Tensor::randn(&[batch * n, du * d], 0.5, &mut rng);
    check_grads(
        &mut store,
        |g, s| {
            let ui = g.param(s, u);
            let m = g.dn_conv(ui, op.clone(), batch);
            let wi = g.input(w.clone());
            let prod = g.mul(m, wi);
            g.sum_all(prod)
        },
        2e-2,
    );
}

#[test]
fn grad_dn_last_scan_matches_fd() {
    let mut rng = Rng::new(8);
    let (n, d, du, batch) = (10usize, 3usize, 2usize, 2usize);
    let dn = DelayNetwork::new(d, n as f64);
    let op = std::sync::Arc::new(crate::dn::DnScanOperator::new(&dn, n, 4));
    let mut store = ParamStore::new();
    let u = store.add("u", Tensor::randn(&[batch * n, du], 0.5, &mut rng));
    let w = Tensor::randn(&[batch, du * d], 0.5, &mut rng);
    // a nonzero entering carry: its contribution is constant in u, so the
    // u-gradient check still holds while exercising the carry path
    let mut c0 = Tensor::randn(&[batch, du * d], 0.5, &mut rng);
    c0.data_mut()[0] = 1.0;
    check_grads(
        &mut store,
        |g, s| {
            let ui = g.param(s, u);
            let m = g.dn_last_scan(ui, op.clone(), batch, Some(&c0));
            let wi = g.input(w.clone());
            let prod = g.mul(m, wi);
            g.sum_all(prod)
        },
        2e-2,
    );
}

#[test]
fn grad_dn_last_matches_fd() {
    let mut rng = Rng::new(8);
    let (n, d, du, batch) = (10usize, 3usize, 2usize, 2usize);
    let dn = DelayNetwork::new(d, n as f64);
    let h = dn.impulse_response(n);
    // time-reversed impulse response
    let mut hrev = Tensor::zeros(&[n, d]);
    for t in 0..n {
        for s in 0..d {
            hrev.data_mut()[t * d + s] = h.data()[(n - 1 - t) * d + s];
        }
    }
    let mut store = ParamStore::new();
    let u = store.add("u", Tensor::randn(&[batch * n, du], 0.5, &mut rng));
    let w = Tensor::randn(&[batch, du * d], 0.5, &mut rng);
    check_grads(
        &mut store,
        |g, s| {
            let ui = g.param(s, u);
            let m = g.dn_last(ui, &hrev, batch);
            let wi = g.input(w.clone());
            let prod = g.mul(m, wi);
            g.sum_all(prod)
        },
        2e-2,
    );
}

#[test]
fn grad_matmul_nt() {
    let mut rng = Rng::new(20);
    let mut store = ParamStore::new();
    let a = store.add("a", Tensor::randn(&[3, 4], 0.5, &mut rng));
    let b = store.add("b", Tensor::randn(&[5, 4], 0.5, &mut rng));
    check_grads(
        &mut store,
        |g, s| {
            let ai = g.param(s, a);
            let bi = g.param(s, b);
            let c = g.matmul_nt(ai, bi); // (3, 5)
            let sq = g.mul(c, c);
            g.mean_all(sq)
        },
        2e-2,
    );
}

#[test]
fn grad_softmax_rows() {
    let mut rng = Rng::new(21);
    let mut store = ParamStore::new();
    let a = store.add("a", Tensor::randn(&[3, 5], 1.0, &mut rng));
    let w = Tensor::randn(&[3, 5], 1.0, &mut rng);
    check_grads(
        &mut store,
        |g, s| {
            let ai = g.param(s, a);
            let sm = g.softmax_rows(ai);
            let wi = g.input(w.clone());
            let prod = g.mul(sm, wi);
            g.sum_all(prod)
        },
        2e-2,
    );
}

#[test]
fn grad_attention_block() {
    // full scaled-dot-product attention through the tape
    let mut rng = Rng::new(22);
    let mut store = ParamStore::new();
    let wq = store.add("wq", Tensor::randn(&[4, 4], 0.4, &mut rng));
    let wk = store.add("wk", Tensor::randn(&[4, 4], 0.4, &mut rng));
    let wv = store.add("wv", Tensor::randn(&[4, 4], 0.4, &mut rng));
    let x = Tensor::randn(&[6, 4], 1.0, &mut rng);
    let target = Tensor::randn(&[6, 4], 1.0, &mut rng);
    check_grads(
        &mut store,
        |g, s| {
            let xi = g.input(x.clone());
            let q = {
                let w = g.param(s, wq);
                g.matmul(xi, w)
            };
            let k = {
                let w = g.param(s, wk);
                g.matmul(xi, w)
            };
            let v = {
                let w = g.param(s, wv);
                g.matmul(xi, w)
            };
            let scores = g.matmul_nt(q, k);
            let scaled = g.scale(scores, 0.5);
            let attn = g.softmax_rows(scaled);
            let out = g.matmul(attn, v);
            g.mse(out, &target)
        },
        3e-2,
    );
}

#[test]
fn grad_param_reused_twice_accumulates() {
    let mut rng = Rng::new(9);
    let mut store = ParamStore::new();
    let a = store.add("a", Tensor::randn(&[3], 0.5, &mut rng));
    check_grads(
        &mut store,
        |g, s| {
            let a1 = g.param(s, a);
            let a2 = g.param(s, a); // same parameter, second snapshot
            let sum = g.add(a1, a2);
            let sq = g.mul(sum, a1);
            g.sum_all(sq)
        },
        2e-2,
    );
}

#[test]
fn grad_add_row_bias() {
    let mut rng = Rng::new(10);
    let mut store = ParamStore::new();
    let b = store.add("b", Tensor::randn(&[4], 0.5, &mut rng));
    let x = Tensor::randn(&[3, 4], 1.0, &mut rng);
    check_grads(
        &mut store,
        |g, s| {
            let xi = g.input(x.clone());
            let bi = g.param(s, b);
            let y = g.add_row(xi, bi);
            let t = g.tanh(y);
            g.mean_all(t)
        },
        2e-2,
    );
}

#[test]
fn dropout_scales_and_masks() {
    let mut rng = Rng::new(11);
    let mut g = Graph::new();
    let x = g.input(Tensor::ones(&[1000]));
    let y = g.dropout(x, 0.8, &mut rng);
    let vals = g.value(y).data();
    let kept = vals.iter().filter(|&&v| v > 0.0).count();
    // kept values are scaled by 1/keep
    for &v in vals {
        assert!(v == 0.0 || (v - 1.25).abs() < 1e-6);
    }
    assert!((kept as f64 / 1000.0 - 0.8).abs() < 0.05);
}

#[test]
fn backward_through_deep_chain() {
    // 50 stacked tanh-affine layers: gradient stays finite, no panic
    let mut rng = Rng::new(12);
    let mut store = ParamStore::new();
    let w = store.add("w", Tensor::randn(&[4, 4], 0.5, &mut rng));
    let b = store.add("b", Tensor::zeros(&[4]));
    let mut g = Graph::new();
    let mut h = g.input(Tensor::randn(&[2, 4], 1.0, &mut rng));
    let wi = g.param(&store, w);
    let bi = g.param(&store, b);
    for _ in 0..50 {
        let a = g.affine(h, wi, bi);
        h = g.tanh(a);
    }
    let loss = g.mean_all(h);
    g.backward(loss);
    let grads = g.param_grads();
    assert_eq!(grads.len(), 2);
    for (_, gr) in grads {
        assert!(gr.data().iter().all(|v| v.is_finite()));
    }
}

#[test]
fn no_grad_for_unused_params() {
    let mut store = ParamStore::new();
    let used = store.add("used", Tensor::ones(&[2]));
    let _unused = store.add("unused", Tensor::ones(&[2]));
    let mut g = Graph::new();
    let u = g.param(&store, used);
    let loss = g.sum_all(u);
    g.backward(loss);
    let grads = g.param_grads();
    assert_eq!(grads.len(), 1);
    assert_eq!(grads[0].0, used);
}
