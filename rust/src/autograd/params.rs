//! External storage for trainable parameters.  The tape ([`Graph`]) is
//! rebuilt every batch; parameters persist here and are snapshotted in via
//! `Graph::param`, with gradients routed back through `param_grads`.

use crate::tensor::Tensor;

/// Handle to a parameter in a [`ParamStore`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct ParamId(pub usize);

/// Named parameter arena shared by model layers and the optimizer.
#[derive(Default)]
pub struct ParamStore {
    tensors: Vec<Tensor>,
    names: Vec<String>,
}

impl ParamStore {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, name: &str, t: Tensor) -> ParamId {
        self.tensors.push(t);
        self.names.push(name.to_string());
        ParamId(self.tensors.len() - 1)
    }

    pub fn get(&self, id: ParamId) -> &Tensor {
        &self.tensors[id.0]
    }

    pub fn get_mut(&mut self, id: ParamId) -> &mut Tensor {
        &mut self.tensors[id.0]
    }

    pub fn name(&self, id: ParamId) -> &str {
        &self.names[id.0]
    }

    pub fn len(&self) -> usize {
        self.tensors.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tensors.is_empty()
    }

    pub fn ids(&self) -> impl Iterator<Item = ParamId> {
        (0..self.tensors.len()).map(ParamId)
    }

    /// Total scalar parameter count (the paper reports these per model).
    pub fn num_scalars(&self) -> usize {
        self.tensors.iter().map(|t| t.len()).sum()
    }

    /// Flatten all parameters into one vector (checkpointing).
    pub fn pack(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.num_scalars());
        self.pack_into(&mut out);
        out
    }

    /// Flatten all parameters into an existing arena, reusing its
    /// allocation — the pipelined coordinator's double-buffered broadcast
    /// repacks every step, so the buffers must not churn the allocator.
    pub fn pack_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.reserve(self.num_scalars());
        for t in &self.tensors {
            out.extend_from_slice(t.data());
        }
    }

    /// Restore from a packed vector (must match the current layout).
    pub fn unpack(&mut self, flat: &[f32]) {
        assert_eq!(flat.len(), self.num_scalars(), "checkpoint size mismatch");
        let mut ofs = 0;
        for t in &mut self.tensors {
            let n = t.len();
            t.data_mut().copy_from_slice(&flat[ofs..ofs + n]);
            ofs += n;
        }
    }

    /// Save to a plain text file (one float per line after a header) —
    /// no serde offline, and text keeps checkpoints debuggable.
    pub fn save(&self, path: &std::path::Path) -> std::io::Result<()> {
        use std::io::Write;
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "plmu-checkpoint v1 params={} scalars={}", self.len(), self.num_scalars())?;
        for (t, name) in self.tensors.iter().zip(&self.names) {
            let shape: Vec<String> = t.shape().iter().map(|s| s.to_string()).collect();
            writeln!(f, "tensor {name} {}", shape.join("x"))?;
            for v in t.data() {
                writeln!(f, "{v:?}")?;
            }
        }
        Ok(())
    }

    /// Load values from `save` output into the existing (same-layout) store.
    pub fn load(&mut self, path: &std::path::Path) -> std::io::Result<()> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.lines();
        let header = lines.next().unwrap_or("");
        if !header.starts_with("plmu-checkpoint v1") {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!("bad checkpoint header: {header}"),
            ));
        }
        let mut flat = Vec::with_capacity(self.num_scalars());
        for line in lines {
            if line.starts_with("tensor ") {
                continue;
            }
            let v: f32 = line.trim().parse().map_err(|e| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, format!("bad float: {e}"))
            })?;
            flat.push(v);
        }
        self.unpack(&flat);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn add_get_roundtrip() {
        let mut s = ParamStore::new();
        let id = s.add("w", Tensor::ones(&[2, 3]));
        assert_eq!(s.get(id).shape(), &[2, 3]);
        assert_eq!(s.name(id), "w");
        assert_eq!(s.num_scalars(), 6);
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let mut rng = Rng::new(0);
        let mut s = ParamStore::new();
        s.add("a", Tensor::randn(&[3, 4], 1.0, &mut rng));
        s.add("b", Tensor::randn(&[5], 1.0, &mut rng));
        let packed = s.pack();
        let orig_a = s.get(ParamId(0)).clone();
        s.get_mut(ParamId(0)).map_inplace(|_| 0.0);
        s.unpack(&packed);
        assert!(s.get(ParamId(0)).allclose(&orig_a, 0.0));
    }

    #[test]
    fn save_load_roundtrip() {
        let mut rng = Rng::new(1);
        let dir = std::env::temp_dir().join("plmu_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.txt");
        let mut s = ParamStore::new();
        s.add("w1", Tensor::randn(&[4, 4], 0.5, &mut rng));
        s.add("b1", Tensor::randn(&[4], 0.5, &mut rng));
        let orig = s.pack();
        s.save(&path).unwrap();
        s.get_mut(ParamId(0)).map_inplace(|_| 9.0);
        s.load(&path).unwrap();
        assert_eq!(s.pack(), orig);
    }

    #[test]
    fn load_rejects_garbage() {
        let dir = std::env::temp_dir().join("plmu_ckpt_test2");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.txt");
        std::fs::write(&path, "not a checkpoint\n1.0\n").unwrap();
        let mut s = ParamStore::new();
        s.add("w", Tensor::ones(&[1]));
        assert!(s.load(&path).is_err());
    }
}
