//! Tape-based reverse-mode automatic differentiation over [`Tensor`]s.
//!
//! The native trainer uses this to train every architecture in the paper's
//! comparisons (original LMU, our-model LTI, our-model parallel, LSTM)
//! without hand-written BPTT.  Design:
//!
//!  * a [`Graph`] is a flat arena of nodes built per batch (define-by-run);
//!  * ops are an enum, not closures — backward is one `match`, borrow-
//!    checker friendly and cheap;
//!  * trainable parameters live outside the graph in a [`ParamStore`];
//!    `Graph::param` snapshots a value in and records the linkage so
//!    gradients can be routed back to the optimizer;
//!  * the DN enters the graph through [`Graph::dn_conv`] /
//!    [`Graph::dn_last`], whose backward passes are the *adjoint
//!    convolutions* — parallel over the sequence exactly like the forward
//!    (the custom-VJP trick mirrored from python/compile/model.py).

pub mod params;

pub use params::{ParamId, ParamStore};

use crate::dn::{DnOperator, DnScanOperator};
use crate::fusion;
use crate::tensor::Tensor;
pub use crate::tensor::Act;
use std::sync::Arc;

pub type NodeId = usize;

enum Op {
    /// constant or input — no gradient propagation
    Leaf,
    /// trainable parameter snapshot (store index recorded separately)
    Param,
    Add,
    Sub,
    Mul,
    Neg,
    Scale(f32),
    /// one_minus: 1 - x
    OneMinus,
    Abs,
    AddRow,
    MatMul,
    /// C = A · Bᵀ (attention scores)
    MatMulNT,
    /// row-wise softmax; aux = the softmax output itself
    SoftmaxRows,
    Tanh,
    Sigmoid,
    Relu,
    /// fused `act(x·W + bias_row)` — parents [x, w, bias]; the epilogue
    /// runs inside the matmul kernel (`matmul::affine_act`)
    Affine { act: Option<Act> },
    /// fused `act((a + b) + bias_row)` — parents [a, b, bias]; one pass,
    /// no intermediates (`Tensor::add2_row_act`)
    Add2RowAct { act: Option<Act> },
    /// fused `act((a + b) + c)` elementwise — parents [a, b, c]
    /// (`Tensor::add3_act`)
    Add3Act { act: Option<Act> },
    MeanAll,
    SumAll,
    SliceRows { lo: usize },
    SliceCols { lo: usize, hi: usize },
    ConcatCols { widths: Vec<usize> },
    ConcatRows { heights: Vec<usize> },
    Reshape { from: Vec<usize> },
    /// fused mean softmax cross-entropy; aux = softmax probabilities
    SoftmaxXent { labels: Vec<usize> },
    /// mean squared error against a constant target; aux = target
    Mse,
    /// rows of a table gathered by token id
    Embedding { ids: Vec<usize> },
    Dropout { mask: Vec<f32> },
    /// batched DN causal convolution (all states): (B·n, du) -> (B·n, du·d)
    DnConv { op: Arc<DnOperator>, batch: usize },
    /// batched DN final state (eq. 25): (B·n, du) -> (B, du·d); aux = H reversed (n, d)
    DnLast { batch: usize },
    /// batched DN final state on the scan path: (B·n, du) -> (B, du·d);
    /// aux = the entering carries (B, du·d), zeros unless streaming
    DnLastScan { op: Arc<DnScanOperator>, batch: usize },
}

struct Node {
    value: Tensor,
    grad: Option<Tensor>,
    op: Op,
    parents: Vec<NodeId>,
    /// op-specific cached tensor (softmax probs, MSE target, H_rev, ...)
    aux: Option<Tensor>,
}

/// A single-use computation tape.
pub struct Graph {
    nodes: Vec<Node>,
    /// (store index, node) pairs for parameter leaves
    param_nodes: Vec<(ParamId, NodeId)>,
}

impl Default for Graph {
    fn default() -> Self {
        Self::new()
    }
}

impl Graph {
    pub fn new() -> Self {
        Graph { nodes: Vec::with_capacity(256), param_nodes: Vec::new() }
    }

    /// Clear the tape for re-recording into retained storage: the node
    /// and param vectors keep their capacity (no `with_capacity(256)`
    /// plus regrowth every step), and dropping the nodes returns every
    /// value/grad/aux buffer to the current thread's arena — so the
    /// next step's recording re-draws the exact buffers this step
    /// released.  The train loops call this instead of building a fresh
    /// `Graph` per batch.
    pub fn reset(&mut self) {
        self.nodes.clear();
        self.param_nodes.clear();
    }

    fn push(&mut self, value: Tensor, op: Op, parents: Vec<NodeId>, aux: Option<Tensor>) -> NodeId {
        self.nodes.push(Node { value, grad: None, op, parents, aux });
        self.nodes.len() - 1
    }

    // ------------------------------------------------------------- inputs

    /// Non-trainable input / constant.
    pub fn input(&mut self, t: Tensor) -> NodeId {
        self.push(t, Op::Leaf, vec![], None)
    }

    /// Trainable parameter: snapshots the current value from the store.
    pub fn param(&mut self, store: &ParamStore, id: ParamId) -> NodeId {
        let n = self.push(store.get(id).clone(), Op::Param, vec![], None);
        self.param_nodes.push((id, n));
        n
    }

    pub fn value(&self, id: NodeId) -> &Tensor {
        &self.nodes[id].value
    }

    pub fn grad(&self, id: NodeId) -> Option<&Tensor> {
        self.nodes[id].grad.as_ref()
    }

    pub fn num_nodes(&self) -> usize {
        self.nodes.len()
    }

    // ---------------------------------------------------------- arithmetic

    pub fn add(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let v = self.nodes[a].value.add(&self.nodes[b].value);
        self.push(v, Op::Add, vec![a, b], None)
    }

    pub fn sub(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let v = self.nodes[a].value.sub(&self.nodes[b].value);
        self.push(v, Op::Sub, vec![a, b], None)
    }

    pub fn mul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let v = self.nodes[a].value.mul(&self.nodes[b].value);
        self.push(v, Op::Mul, vec![a, b], None)
    }

    pub fn neg(&mut self, a: NodeId) -> NodeId {
        let v = self.nodes[a].value.neg();
        self.push(v, Op::Neg, vec![a], None)
    }

    pub fn scale(&mut self, a: NodeId, s: f32) -> NodeId {
        let v = self.nodes[a].value.scale(s);
        self.push(v, Op::Scale(s), vec![a], None)
    }

    pub fn one_minus(&mut self, a: NodeId) -> NodeId {
        let v = self.nodes[a].value.map(|x| 1.0 - x);
        self.push(v, Op::OneMinus, vec![a], None)
    }

    pub fn abs(&mut self, a: NodeId) -> NodeId {
        let v = self.nodes[a].value.map(f32::abs);
        self.push(v, Op::Abs, vec![a], None)
    }

    /// Broadcast-add a bias row vector to each row of `a`.
    pub fn add_row(&mut self, a: NodeId, bias: NodeId) -> NodeId {
        let v = self.nodes[a].value.add_row(&self.nodes[bias].value);
        self.push(v, Op::AddRow, vec![a, bias], None)
    }

    pub fn matmul(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let v = self.nodes[a].value.matmul(&self.nodes[b].value);
        self.push(v, Op::MatMul, vec![a, b], None)
    }

    /// C = A · Bᵀ — used for attention score matrices.
    pub fn matmul_nt(&mut self, a: NodeId, b: NodeId) -> NodeId {
        let v = self.nodes[a].value.matmul_nt(&self.nodes[b].value);
        self.push(v, Op::MatMulNT, vec![a, b], None)
    }

    /// Row-wise softmax (differentiable — attention weights).
    pub fn softmax_rows(&mut self, a: NodeId) -> NodeId {
        let v = self.nodes[a].value.softmax_rows();
        self.push(v.clone(), Op::SoftmaxRows, vec![a], Some(v))
    }

    /// x @ W + b — the affine building block.
    pub fn affine(&mut self, x: NodeId, w: NodeId, b: NodeId) -> NodeId {
        self.affine_act(x, w, b, None)
    }

    /// `act(x @ W + b)` — the affine building block with its elementwise
    /// tail.  With fusion on (the default) this records ONE node whose
    /// forward applies bias + activation per output row inside the
    /// matmul kernel and whose backward feeds the activation gradient
    /// straight into the matmul/bias gradients; with fusion off it
    /// records the original unfused chain (`matmul → add_row → act`).
    /// Both record paths are bit-identical (see `crate::fusion`).
    pub fn affine_act(&mut self, x: NodeId, w: NodeId, b: NodeId, act: Option<Act>) -> NodeId {
        if fusion::enabled() {
            let v = self.nodes[x]
                .value
                .affine_act(&self.nodes[w].value, &self.nodes[b].value, act);
            self.push(v, Op::Affine { act }, vec![x, w, b], None)
        } else {
            let xw = self.matmul(x, w);
            let s = self.add_row(xw, b);
            self.apply_act(s, act)
        }
    }

    /// `act((a + b) + bias_row)` — the fused elementwise tail of the
    /// LMU output stage.  One node and one output pass with fusion on;
    /// the original `add → add_row → act` chain with fusion off.
    pub fn add2_row_act(&mut self, a: NodeId, b: NodeId, bias: NodeId, act: Option<Act>) -> NodeId {
        if fusion::enabled() {
            let v = self.nodes[a]
                .value
                .add2_row_act(&self.nodes[b].value, &self.nodes[bias].value, act);
            self.push(v, Op::Add2RowAct { act }, vec![a, b, bias], None)
        } else {
            let s = self.add(a, b);
            let s = self.add_row(s, bias);
            self.apply_act(s, act)
        }
    }

    /// `act((a + b) + c)` elementwise over three same-shape tensors —
    /// the original LMU cell's recurrent sum.  One node with fusion on;
    /// `add → add → act` with fusion off.
    pub fn add3_act(&mut self, a: NodeId, b: NodeId, c: NodeId, act: Option<Act>) -> NodeId {
        if fusion::enabled() {
            let v = self.nodes[a]
                .value
                .add3_act(&self.nodes[b].value, &self.nodes[c].value, act);
            self.push(v, Op::Add3Act { act }, vec![a, b, c], None)
        } else {
            let s = self.add(a, b);
            let s = self.add(s, c);
            self.apply_act(s, act)
        }
    }

    fn apply_act(&mut self, s: NodeId, act: Option<Act>) -> NodeId {
        match act {
            Some(Act::Tanh) => self.tanh(s),
            Some(Act::Relu) => self.relu(s),
            None => s,
        }
    }

    // ---------------------------------------------------------- nonlinear

    pub fn tanh(&mut self, a: NodeId) -> NodeId {
        let v = self.nodes[a].value.tanh();
        self.push(v, Op::Tanh, vec![a], None)
    }

    pub fn sigmoid(&mut self, a: NodeId) -> NodeId {
        let v = self.nodes[a].value.sigmoid();
        self.push(v, Op::Sigmoid, vec![a], None)
    }

    pub fn relu(&mut self, a: NodeId) -> NodeId {
        let v = self.nodes[a].value.relu();
        self.push(v, Op::Relu, vec![a], None)
    }

    // ---------------------------------------------------------- reductions

    pub fn mean_all(&mut self, a: NodeId) -> NodeId {
        let v = Tensor::scalar(self.nodes[a].value.mean());
        self.push(v, Op::MeanAll, vec![a], None)
    }

    pub fn sum_all(&mut self, a: NodeId) -> NodeId {
        let v = Tensor::scalar(self.nodes[a].value.sum());
        self.push(v, Op::SumAll, vec![a], None)
    }

    // ------------------------------------------------------------- shaping

    pub fn slice_rows(&mut self, a: NodeId, lo: usize, hi: usize) -> NodeId {
        let v = self.nodes[a].value.slice_rows(lo, hi);
        self.push(v, Op::SliceRows { lo }, vec![a], None)
    }

    pub fn slice_cols(&mut self, a: NodeId, lo: usize, hi: usize) -> NodeId {
        let src = &self.nodes[a].value;
        let (r, c) = (src.rows(), src.cols());
        assert!(lo <= hi && hi <= c);
        let mut v = Tensor::zeros(&[r, hi - lo]);
        for i in 0..r {
            v.data_mut()[i * (hi - lo)..(i + 1) * (hi - lo)]
                .copy_from_slice(&src.data()[i * c + lo..i * c + hi]);
        }
        self.push(v, Op::SliceCols { lo, hi }, vec![a], None)
    }

    pub fn concat_cols(&mut self, parts: &[NodeId]) -> NodeId {
        let tensors: Vec<&Tensor> = parts.iter().map(|&p| &self.nodes[p].value).collect();
        let widths: Vec<usize> = tensors.iter().map(|t| t.cols()).collect();
        let v = Tensor::concat_cols(&tensors);
        self.push(v, Op::ConcatCols { widths }, parts.to_vec(), None)
    }

    pub fn concat_rows(&mut self, parts: &[NodeId]) -> NodeId {
        let tensors: Vec<&Tensor> = parts.iter().map(|&p| &self.nodes[p].value).collect();
        let heights: Vec<usize> = tensors.iter().map(|t| t.rows()).collect();
        let v = Tensor::concat_rows(&tensors);
        self.push(v, Op::ConcatRows { heights }, parts.to_vec(), None)
    }

    pub fn reshape(&mut self, a: NodeId, shape: &[usize]) -> NodeId {
        let from = self.nodes[a].value.shape().to_vec();
        let v = self.nodes[a].value.reshaped(shape);
        self.push(v, Op::Reshape { from }, vec![a], None)
    }

    // --------------------------------------------------------------- loss

    /// Mean softmax cross-entropy of logits (B, C) against integer labels.
    pub fn softmax_xent(&mut self, logits: NodeId, labels: &[usize]) -> NodeId {
        let probs = self.nodes[logits].value.softmax_rows();
        let c = probs.cols();
        assert_eq!(labels.len(), probs.rows(), "labels/batch mismatch");
        let mut nll = 0.0f64;
        for (i, &y) in labels.iter().enumerate() {
            assert!(y < c, "label {y} out of range {c}");
            nll -= (probs.data()[i * c + y].max(1e-12) as f64).ln();
        }
        let v = Tensor::scalar((nll / labels.len() as f64) as f32);
        self.push(v, Op::SoftmaxXent { labels: labels.to_vec() }, vec![logits], Some(probs))
    }

    /// Mean squared error against a constant target.
    pub fn mse(&mut self, pred: NodeId, target: &Tensor) -> NodeId {
        let diff = self.nodes[pred].value.sub(target);
        let v = Tensor::scalar(diff.sq_norm() / diff.len() as f32);
        self.push(v, Op::Mse, vec![pred], Some(target.clone()))
    }

    // ----------------------------------------------------------- embedding

    /// Gather rows of an embedding table (V, E) by token ids -> (len, E).
    pub fn embedding(&mut self, table: NodeId, ids: &[usize]) -> NodeId {
        let t = &self.nodes[table].value;
        let (v_sz, e) = (t.shape()[0], t.shape()[1]);
        let mut out = Tensor::zeros(&[ids.len(), e]);
        for (i, &id) in ids.iter().enumerate() {
            assert!(id < v_sz, "token id {id} out of vocab {v_sz}");
            out.data_mut()[i * e..(i + 1) * e].copy_from_slice(&t.data()[id * e..(id + 1) * e]);
        }
        self.push(out, Op::Embedding { ids: ids.to_vec() }, vec![table], None)
    }

    /// Inverted dropout with the given keep probability (training mode).
    pub fn dropout(&mut self, a: NodeId, keep: f32, rng: &mut crate::util::Rng) -> NodeId {
        assert!(keep > 0.0 && keep <= 1.0);
        let src = &self.nodes[a].value;
        let mask: Vec<f32> = (0..src.len())
            .map(|_| if (rng.uniform() as f32) < keep { 1.0 / keep } else { 0.0 })
            .collect();
        let mut v = src.clone();
        for (x, m) in v.data_mut().iter_mut().zip(&mask) {
            *x *= m;
        }
        self.push(v, Op::Dropout { mask }, vec![a], None)
    }

    // ------------------------------------------------------------------ DN

    /// Batched DN causal convolution, all states (the parallel training
    /// path: eq. 26 FFT or the chunked scan, per the [`DnOperator`] the
    /// `PLMU_SCAN` knob built).  u: (B·n, du) channel-major output:
    /// (B·n, du·d).
    ///
    /// The B samples are independent and each owns a contiguous block of
    /// output rows, so the batch fans out across `crate::exec` workers.
    /// The inner parallelism of either operator's `apply` runs under the
    /// chunk's sub-budget: serial when the batch already fills the
    /// thread budget, a nested pool job when spare threads remain
    /// (e.g. under a 2-replica data-parallel run on 8 threads) — either
    /// way the tree never over-subscribes and values are bit-identical.
    pub fn dn_conv(&mut self, u: NodeId, op: Arc<DnOperator>, batch: usize) -> NodeId {
        let uv = &self.nodes[u].value;
        let n = op.n();
        let du = uv.cols();
        assert_eq!(uv.rows(), batch * n, "dn_conv: rows {} != B*n {}", uv.rows(), batch * n);
        let d = op.d();
        let mut out = Tensor::zeros(&[batch * n, du * d]);
        let op_ref: &DnOperator = &op;
        let sample_len = n * du * d;
        let plan = crate::exec::plan_for(batch, batch * du * (d + 1) * n * 32);
        crate::exec::parallel_rows_mut(out.data_mut(), sample_len, plan, |b0, block| {
            for (bi, sample) in block.chunks_mut(sample_len).enumerate() {
                let b = b0 + bi;
                let u_b = uv.slice_rows(b * n, (b + 1) * n);
                let m = op_ref.apply(&u_b); // (n, d, du)
                // repack (n, d, du) -> rows (n, du*d) channel-major
                for t in 0..n {
                    for c in 0..du {
                        for s in 0..d {
                            sample[t * du * d + c * d + s] = m.data()[(t * d + s) * du + c];
                        }
                    }
                }
            }
        });
        self.push(out, Op::DnConv { op, batch }, vec![u], None)
    }

    /// Batched DN final state (eq. 25).  u: (B·n, du) -> (B, du·d).
    /// `hrev` is the time-reversed impulse response (n, d), computed once.
    pub fn dn_last(&mut self, u: NodeId, hrev: &Tensor, batch: usize) -> NodeId {
        let uv = &self.nodes[u].value;
        let (n, d) = (hrev.shape()[0], hrev.shape()[1]);
        let du = uv.cols();
        assert_eq!(uv.rows(), batch * n, "dn_last: rows {} != B*n {}", uv.rows(), batch * n);
        let mut out = Tensor::zeros(&[batch, du * d]);
        for b in 0..batch {
            let u_b = uv.slice_rows(b * n, (b + 1) * n); // (n, du)
            let m = hrev.matmul_tn(&u_b); // (d, du) = Hrevᵀ·u
            for c in 0..du {
                for s in 0..d {
                    out.data_mut()[b * du * d + c * d + s] = m.data()[s * du + c];
                }
            }
        }
        self.push(out, Op::DnLast { batch }, vec![u], Some(hrev.clone()))
    }

    /// Batched DN final state on the chunked-scan path (the eq. 25
    /// analogue of [`Graph::dn_conv`] under `PLMU_SCAN=scan`):
    /// u: (B·n, du) -> (B, du·d) channel-major, via the sequential carry
    /// chain of [`DnScanOperator::apply_last`] per sample, batch
    /// fanned out over the exec pool.
    ///
    /// `carry0` is the (B, du·d) carry entering the window (the
    /// streaming trainer's state); `None` means zeros and is
    /// bit-identical to passing explicit zeros — the carry dot is always
    /// evaluated.  Gradients flow to `u` only: the carry is truncation
    /// state from outside the tape (TBPTT), held constant by design.
    pub fn dn_last_scan(
        &mut self,
        u: NodeId,
        op: Arc<DnScanOperator>,
        batch: usize,
        carry0: Option<&Tensor>,
    ) -> NodeId {
        let uv = &self.nodes[u].value;
        let d = op.d;
        let du = uv.cols();
        // the scan tables are length-independent, so n is whatever the
        // input carries — the streaming trainer's windows vary in length
        assert!(batch >= 1 && uv.rows() % batch == 0, "dn_last_scan: rows not divisible by B");
        let n = uv.rows() / batch;
        assert!(n >= 1, "dn_last_scan: empty window");
        let carries = match carry0 {
            Some(c) => {
                assert_eq!(c.shape(), &[batch, du * d], "carry must be (B, du*d)");
                c.clone()
            }
            None => Tensor::zeros(&[batch, du * d]),
        };
        let mut out = Tensor::zeros(&[batch, du * d]);
        let op_ref: &DnScanOperator = &op;
        let uv_ref = &*uv;
        let carries_ref = &carries;
        let plan = crate::exec::plan_for(batch, batch * du * d * n * 8);
        crate::exec::parallel_rows_mut(out.data_mut(), du * d, plan, |b0, block| {
            for (bi, row) in block.chunks_mut(du * d).enumerate() {
                let b = b0 + bi;
                let u_b = uv_ref.slice_rows(b * n, (b + 1) * n);
                let c0 = &carries_ref.data()[b * du * d..(b + 1) * du * d];
                // apply_last returns carryᵀ (du, d) — already channel-major
                row.copy_from_slice(&op_ref.apply_last(&u_b, Some(c0)));
            }
        });
        self.push(out, Op::DnLastScan { op, batch }, vec![u], Some(carries))
    }

    // ------------------------------------------------------------ analysis

    /// Export the recorded tape as a value-free
    /// [`TapeView`](crate::analyze::tape::TapeView) for the static tape
    /// verifier: per node, the op (with the metadata its backward rule
    /// consumes), parent ids, and the value/aux shapes — never tensor
    /// data.  `Op` itself stays private; this mirror is the only window
    /// `analyze` gets into the tape.
    pub fn tape_view(&self) -> crate::analyze::tape::TapeView {
        use crate::analyze::tape::{TapeNode, TapeOp};
        let nodes = self
            .nodes
            .iter()
            .map(|node| {
                let op = match &node.op {
                    Op::Leaf => TapeOp::Leaf,
                    Op::Param => TapeOp::Param,
                    Op::Add => TapeOp::Add,
                    Op::Sub => TapeOp::Sub,
                    Op::Mul => TapeOp::Mul,
                    Op::Neg => TapeOp::Neg,
                    Op::Scale(_) => TapeOp::Scale,
                    Op::OneMinus => TapeOp::OneMinus,
                    Op::Abs => TapeOp::Abs,
                    Op::AddRow => TapeOp::AddRow,
                    Op::MatMul => TapeOp::MatMul,
                    Op::MatMulNT => TapeOp::MatMulNT,
                    Op::SoftmaxRows => TapeOp::SoftmaxRows,
                    Op::Tanh => TapeOp::Tanh,
                    Op::Sigmoid => TapeOp::Sigmoid,
                    Op::Relu => TapeOp::Relu,
                    Op::Affine { act } => TapeOp::Affine { act: *act },
                    Op::Add2RowAct { act } => TapeOp::Add2RowAct { act: *act },
                    Op::Add3Act { act } => TapeOp::Add3Act { act: *act },
                    Op::MeanAll => TapeOp::MeanAll,
                    Op::SumAll => TapeOp::SumAll,
                    Op::SliceRows { lo } => TapeOp::SliceRows { lo: *lo },
                    Op::SliceCols { lo, hi } => TapeOp::SliceCols { lo: *lo, hi: *hi },
                    Op::ConcatCols { widths } => {
                        TapeOp::ConcatCols { widths: widths.clone() }
                    }
                    Op::ConcatRows { heights } => {
                        TapeOp::ConcatRows { heights: heights.clone() }
                    }
                    Op::Reshape { from } => TapeOp::Reshape { from: from.clone() },
                    Op::SoftmaxXent { labels } => TapeOp::SoftmaxXent {
                        batch: labels.len(),
                        max_label: labels.iter().copied().max(),
                    },
                    Op::Mse => {
                        TapeOp::Mse { target_len: node.aux.as_ref().map_or(0, |t| t.len()) }
                    }
                    Op::Embedding { ids } => TapeOp::Embedding {
                        count: ids.len(),
                        max_id: ids.iter().copied().max(),
                    },
                    Op::Dropout { mask } => TapeOp::Dropout { mask_len: mask.len() },
                    Op::DnConv { op, batch } => {
                        TapeOp::DnConv { n: op.n(), d: op.d(), batch: *batch }
                    }
                    Op::DnLast { batch } => {
                        // aux is H_rev with shape (n, d)
                        let hs = node.aux.as_ref().map_or(&[][..], |t| t.shape());
                        TapeOp::DnLast {
                            n: hs.first().copied().unwrap_or(0),
                            d: hs.get(1).copied().unwrap_or(0),
                            batch: *batch,
                        }
                    }
                    Op::DnLastScan { op, batch } => {
                        TapeOp::DnLastScan { d: op.d, batch: *batch }
                    }
                };
                TapeNode {
                    op,
                    parents: node.parents.clone(),
                    shape: node.value.shape().to_vec(),
                    aux_shape: node.aux.as_ref().map(|t| t.shape().to_vec()),
                }
            })
            .collect();
        crate::analyze::tape::TapeView { nodes }
    }

    // ------------------------------------------------------------ backward

    /// Reverse-mode sweep from a scalar loss node.
    pub fn backward(&mut self, loss: NodeId) {
        // PLMU_VERIFY>=1: verify the recorded tape before the sweep
        // consumes it, so a stale NodeId or illegal shape surfaces with
        // op provenance instead of as a slice panic mid-backward
        if crate::analyze::level() >= 1 {
            let findings = crate::analyze::tape::verify(&self.tape_view());
            assert!(
                findings.is_empty(),
                "tape verification failed:\n{}",
                findings.iter().map(|f| f.to_string()).collect::<Vec<_>>().join("\n")
            );
        }
        assert_eq!(self.nodes[loss].value.len(), 1, "backward from non-scalar");
        self.nodes[loss].grad = Some(Tensor::scalar(1.0));
        for id in (0..=loss).rev() {
            if self.nodes[id].grad.is_none() {
                continue;
            }
            self.propagate(id);
        }
    }

    fn accum(&mut self, node: NodeId, g: Tensor) {
        match &mut self.nodes[node].grad {
            Some(existing) => existing.add_assign(&g),
            slot @ None => *slot = Some(g),
        }
    }

    fn propagate(&mut self, id: NodeId) {
        let g = self.nodes[id].grad.clone().unwrap();
        let parents = self.nodes[id].parents.clone();
        match &self.nodes[id].op {
            Op::Leaf | Op::Param => {}
            Op::Add => {
                self.accum(parents[0], g.clone());
                self.accum(parents[1], g);
            }
            Op::Sub => {
                self.accum(parents[0], g.clone());
                self.accum(parents[1], g.neg());
            }
            Op::Mul => {
                let ga = g.mul(&self.nodes[parents[1]].value);
                let gb = g.mul(&self.nodes[parents[0]].value);
                self.accum(parents[0], ga);
                self.accum(parents[1], gb);
            }
            Op::Neg => self.accum(parents[0], g.neg()),
            Op::Scale(s) => {
                let s = *s;
                self.accum(parents[0], g.scale(s));
            }
            Op::OneMinus => self.accum(parents[0], g.neg()),
            Op::Abs => {
                let sign = self.nodes[parents[0]].value.map(|v| {
                    if v > 0.0 {
                        1.0
                    } else if v < 0.0 {
                        -1.0
                    } else {
                        0.0
                    }
                });
                self.accum(parents[0], g.mul(&sign));
            }
            Op::AddRow => {
                self.accum(parents[0], g.clone());
                self.accum(parents[1], g.sum_rows());
            }
            Op::MatMul => {
                // C = A·B: dA = dC·Bᵀ, dB = Aᵀ·dC
                let da = g.matmul_nt(&self.nodes[parents[1]].value);
                let db = self.nodes[parents[0]].value.matmul_tn(&g);
                self.accum(parents[0], da);
                self.accum(parents[1], db);
            }
            Op::MatMulNT => {
                // C = A·Bᵀ: dA = dC·B, dB = dCᵀ·A
                let da = g.matmul(&self.nodes[parents[1]].value);
                let db = g.matmul_tn(&self.nodes[parents[0]].value);
                self.accum(parents[0], da);
                self.accum(parents[1], db);
            }
            Op::SoftmaxRows => {
                // dx_ij = s_ij (g_ij - sum_k g_ik s_ik)
                let s = self.nodes[id].aux.as_ref().unwrap();
                let c = s.cols();
                let mut gx = g.mul(s);
                for (grow, srow) in gx
                    .data_mut()
                    .chunks_mut(c)
                    .zip(s.data().chunks(c))
                {
                    let dot: f32 = grow.iter().sum();
                    for (gv, sv) in grow.iter_mut().zip(srow) {
                        *gv -= dot * sv;
                    }
                }
                self.accum(parents[0], gx);
            }
            Op::Tanh => {
                // g ⊙ (1 - y²) via the shared simd kernel — the same
                // per-element expression the old `map` + `mul` pair
                // computed, and the same kernel the fused ops use
                let y = &self.nodes[id].value;
                let gy = Tensor::tanh_bwd(&g, y);
                self.accum(parents[0], gy);
            }
            Op::Sigmoid => {
                let y = &self.nodes[id].value;
                let gy = g.mul(&y.map(|v| v * (1.0 - v)));
                self.accum(parents[0], gy);
            }
            Op::Relu => {
                // g ⊙ (x > 0 ? 1 : 0) via the shared simd kernel — a
                // mask *multiply*, so 0 · NaN propagates like before
                let x = &self.nodes[parents[0]].value;
                let gy = Tensor::relu_bwd(&g, x);
                self.accum(parents[0], gy);
            }
            Op::Affine { act } => {
                // y = act(x·W + bias).  The activation gradient dz is
                // exactly what the unfused chain's act node produced
                // (tanh reads y; relu's mask reads y, and `y > 0` ⟺
                // `z > 0` for every z including NaN/±Inf — relu zeroes
                // exactly the non-positive and NaN entries), and then
                // dx = dz·Wᵀ, dW = xᵀ·dz, dbias = dz row-sum are the
                // identical matmul/add_row backward expressions.
                let act = *act;
                let y = &self.nodes[id].value;
                let dz = match act {
                    None => g,
                    Some(Act::Tanh) => Tensor::tanh_bwd(&g, y),
                    Some(Act::Relu) => Tensor::relu_bwd(&g, y),
                };
                let x = &self.nodes[parents[0]].value;
                let w = &self.nodes[parents[1]].value;
                let dx = dz.matmul_nt(w);
                let dw = x.matmul_tn(&dz);
                let dbias = dz.sum_rows();
                self.accum(parents[0], dx);
                self.accum(parents[1], dw);
                self.accum(parents[2], dbias);
            }
            Op::Add2RowAct { act } => {
                // y = act((a + b) + bias_row): dz flows unchanged to a
                // and b, row-summed to the bias
                let act = *act;
                let y = &self.nodes[id].value;
                let dz = match act {
                    None => g,
                    Some(Act::Tanh) => Tensor::tanh_bwd(&g, y),
                    Some(Act::Relu) => Tensor::relu_bwd(&g, y),
                };
                let dbias = dz.sum_rows();
                self.accum(parents[0], dz.clone());
                self.accum(parents[1], dz);
                self.accum(parents[2], dbias);
            }
            Op::Add3Act { act } => {
                // y = act((a + b) + c): dz flows unchanged to all three
                let act = *act;
                let y = &self.nodes[id].value;
                let dz = match act {
                    None => g,
                    Some(Act::Tanh) => Tensor::tanh_bwd(&g, y),
                    Some(Act::Relu) => Tensor::relu_bwd(&g, y),
                };
                self.accum(parents[0], dz.clone());
                self.accum(parents[1], dz.clone());
                self.accum(parents[2], dz);
            }
            Op::MeanAll => {
                let p = &self.nodes[parents[0]].value;
                let scale = g.item() / p.len() as f32;
                let gp = Tensor::full(p.shape(), scale);
                self.accum(parents[0], gp);
            }
            Op::SumAll => {
                let p = &self.nodes[parents[0]].value;
                let gp = Tensor::full(p.shape(), g.item());
                self.accum(parents[0], gp);
            }
            Op::SliceRows { lo } => {
                let lo = *lo;
                let p = &self.nodes[parents[0]].value;
                let c = p.cols();
                let mut gp = Tensor::zeros(&[p.rows(), c]);
                gp.data_mut()[lo * c..lo * c + g.len()].copy_from_slice(g.data());
                self.accum(parents[0], gp.reshape(p.shape()));
            }
            Op::SliceCols { lo, hi } => {
                let (lo, hi) = (*lo, *hi);
                let p = &self.nodes[parents[0]].value;
                let (r, c) = (p.rows(), p.cols());
                let w = hi - lo;
                let mut gp = Tensor::zeros(&[r, c]);
                for i in 0..r {
                    gp.data_mut()[i * c + lo..i * c + hi].copy_from_slice(&g.data()[i * w..(i + 1) * w]);
                }
                self.accum(parents[0], gp);
            }
            Op::ConcatCols { widths } => {
                let widths = widths.clone();
                let r = g.rows();
                let total: usize = widths.iter().sum();
                let mut ofs = 0;
                for (p, w) in parents.iter().zip(&widths) {
                    let mut gp = Tensor::zeros(&[r, *w]);
                    for i in 0..r {
                        gp.data_mut()[i * w..(i + 1) * w]
                            .copy_from_slice(&g.data()[i * total + ofs..i * total + ofs + w]);
                    }
                    // match original parent shape
                    let pshape = self.nodes[*p].value.shape().to_vec();
                    self.accum(*p, gp.reshape(&pshape));
                    ofs += w;
                }
            }
            Op::ConcatRows { heights } => {
                let heights = heights.clone();
                let c = g.cols();
                let mut ofs = 0;
                for (p, h) in parents.iter().zip(&heights) {
                    let gp = Tensor::new(&[*h, c], g.data()[ofs * c..(ofs + h) * c].to_vec());
                    let pshape = self.nodes[*p].value.shape().to_vec();
                    self.accum(*p, gp.reshape(&pshape));
                    ofs += h;
                }
            }
            Op::Reshape { from } => {
                let from = from.clone();
                self.accum(parents[0], g.reshaped(&from));
            }
            Op::SoftmaxXent { labels } => {
                let labels = labels.clone();
                let probs = self.nodes[id].aux.as_ref().unwrap();
                let c = probs.cols();
                let b = labels.len() as f32;
                let mut gp = probs.clone();
                for (i, &y) in labels.iter().enumerate() {
                    gp.data_mut()[i * c + y] -= 1.0;
                }
                self.accum(parents[0], gp.scale(g.item() / b));
            }
            Op::Mse => {
                let target = self.nodes[id].aux.as_ref().unwrap();
                let p = &self.nodes[parents[0]].value;
                let gp = p.sub(target).scale(2.0 * g.item() / p.len() as f32);
                self.accum(parents[0], gp);
            }
            Op::Embedding { ids } => {
                let ids = ids.clone();
                let table = &self.nodes[parents[0]].value;
                let (v_sz, e) = (table.shape()[0], table.shape()[1]);
                let mut gt = Tensor::zeros(&[v_sz, e]);
                for (i, &idx) in ids.iter().enumerate() {
                    for j in 0..e {
                        gt.data_mut()[idx * e + j] += g.data()[i * e + j];
                    }
                }
                self.accum(parents[0], gt);
            }
            Op::Dropout { mask } => {
                let mask = mask.clone();
                let mut gp = g.clone();
                for (x, m) in gp.data_mut().iter_mut().zip(&mask) {
                    *x *= m;
                }
                self.accum(parents[0], gp);
            }
            Op::DnConv { op, batch } => {
                let (op, batch) = (op.clone(), *batch);
                let n = op.n();
                let d = op.d();
                let du = self.nodes[parents[0]].value.cols();
                // unpack channel-major (B·n, du·d) grad -> (n, d, du) per b,
                // run the adjoint convolution, pack back into (B·n, du);
                // samples are independent, so the batch fans out like the
                // forward pass does.
                let mut gu = Tensor::zeros(&[batch * n, du]);
                let op_ref: &DnOperator = &op;
                let g_ref = &g;
                let sample_len = n * du;
                let plan = crate::exec::plan_for(batch, batch * du * (d + 1) * n * 32);
                crate::exec::parallel_rows_mut(gu.data_mut(), sample_len, plan, |b0, block| {
                    for (bi, sample) in block.chunks_mut(sample_len).enumerate() {
                        let b = b0 + bi;
                        let mut dm = Tensor::zeros(&[n, d, du]);
                        for t in 0..n {
                            for c in 0..du {
                                for s in 0..d {
                                    dm.data_mut()[(t * d + s) * du + c] =
                                        g_ref.data()[(b * n + t) * du * d + c * d + s];
                                }
                            }
                        }
                        let gb = op_ref.apply_adjoint(&dm); // (n, du)
                        sample.copy_from_slice(gb.data());
                    }
                });
                self.accum(parents[0], gu);
            }
            Op::DnLast { batch } => {
                let batch = *batch;
                let hrev = self.nodes[id].aux.as_ref().unwrap().clone(); // (n, d)
                let (n, d) = (hrev.shape()[0], hrev.shape()[1]);
                let du = self.nodes[parents[0]].value.cols();
                // dm (du·d per sample) -> du = Hrev · dmᵀ arranged (n, du)
                let mut gu = Tensor::zeros(&[batch * n, du]);
                for b in 0..batch {
                    // dm as (d, du) from channel-major row b
                    let mut dm = Tensor::zeros(&[d, du]);
                    for c in 0..du {
                        for s in 0..d {
                            dm.data_mut()[s * du + c] = g.data()[b * du * d + c * d + s];
                        }
                    }
                    let gb = hrev.matmul(&dm); // (n, du)
                    gu.data_mut()[b * n * du..(b + 1) * n * du].copy_from_slice(gb.data());
                }
                self.accum(parents[0], gu);
            }
            Op::DnLastScan { op, batch } => {
                let (op, batch) = (op.clone(), *batch);
                let d = op.d;
                let du = self.nodes[parents[0]].value.cols();
                let n = self.nodes[parents[0]].value.rows() / batch;
                // each sample's grad row is already the (du, d) carryᵀ
                // layout apply_last_adjoint expects; samples fan out like
                // the forward.  No gradient to the entering carry (aux):
                // it is TBPTT truncation state, constant by design.
                let mut gu = Tensor::zeros(&[batch * n, du]);
                let op_ref: &DnScanOperator = &op;
                let g_ref = &g;
                let plan = crate::exec::plan_for(batch, batch * du * d * n * 8);
                crate::exec::parallel_rows_mut(gu.data_mut(), n * du, plan, |b0, block| {
                    for (bi, sample) in block.chunks_mut(n * du).enumerate() {
                        let b = b0 + bi;
                        let dlast = &g_ref.data()[b * du * d..(b + 1) * du * d];
                        let gb = op_ref.apply_last_adjoint(n, du, dlast); // (n, du)
                        sample.copy_from_slice(gb.data());
                    }
                });
                self.accum(parents[0], gu);
            }
        }
    }

    /// Collect (param, gradient) pairs after `backward`.  Parameters used
    /// more than once get their gradients summed.
    pub fn param_grads(&self) -> Vec<(ParamId, Tensor)> {
        let mut out: Vec<(ParamId, Tensor)> = Vec::new();
        for &(pid, nid) in &self.param_nodes {
            if let Some(g) = &self.nodes[nid].grad {
                if let Some(slot) = out.iter_mut().find(|(p, _)| *p == pid) {
                    slot.1.add_assign(g);
                } else {
                    out.push((pid, g.clone()));
                }
            }
        }
        out
    }
}

#[cfg(test)]
mod tests;
