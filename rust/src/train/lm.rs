//! Language models and the encoder-decoder translator, built from the
//! paper's repeating block (DN/LMU + dense + highway, §4.3-4.5 and the
//! supplementary figure).
//!
//!  * [`LmModel`] — token LM: embedding -> N blocks -> vocab head, with
//!    next-token cross-entropy over every position (the Amazon-reviews
//!    pretraining and text8 experiments);
//!  * finetuning reuses the pretrained blocks via [`LmModel::encode`]
//!    plus a fresh classification head — with a learned weighted sum of
//!    per-block representations ("deep representations", Peters et al.);
//!  * [`Translator`] — LMU encoder + cross-attention decoder predicting
//!    the target sequence position-wise (IWSLT experiment).

use crate::autograd::{Graph, NodeId, ParamId, ParamStore};
use crate::layers::lmu::{LmuParallelLayer, LmuSpec};
use crate::layers::{Activation, Dense, Embedding, Highway};
use crate::tensor::Tensor;
use crate::util::Rng;

/// One repeating block: our-model LMU layer + highway + residual-friendly
/// dimensionality (all widths = `dim`).
pub struct LmBlock {
    pub lmu: LmuParallelLayer,
    pub highway: Highway,
}

impl LmBlock {
    pub fn new(
        dim: usize,
        d: usize,
        theta: f64,
        n: usize,
        store: &mut ParamStore,
        rng: &mut Rng,
        prefix: &str,
    ) -> Self {
        // du = 1 keeps the memory d-dimensional per block (the paper works
        // with small theta/d per block and stacks blocks for long context)
        let spec = LmuSpec::new(dim, 1, d, theta, dim);
        LmBlock {
            lmu: LmuParallelLayer::new(spec, n, store, rng, &format!("{prefix}.lmu")),
            highway: Highway::new(dim, store, rng, &format!("{prefix}.hw")),
        }
    }

    /// (B·n, dim) -> (B·n, dim), with a skip connection around the LMU.
    pub fn forward(&self, g: &mut Graph, store: &ParamStore, x: NodeId, batch: usize) -> NodeId {
        let o = self.lmu.forward_all(g, store, x, batch);
        let res = g.add(o, x); // skip connection (supplementary figure)
        self.highway.forward(g, store, res)
    }
}

/// Token language model with stacked blocks.
pub struct LmModel {
    pub emb: Embedding,
    pub blocks: Vec<LmBlock>,
    pub head: Dense,
    pub dim: usize,
    pub n: usize,
    pub vocab: usize,
    /// learned per-block mixing weights for deep representations
    pub mix: ParamId,
}

impl LmModel {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        vocab: usize,
        dim: usize,
        n_blocks: usize,
        d: usize,
        theta: f64,
        n: usize,
        store: &mut ParamStore,
        rng: &mut Rng,
    ) -> Self {
        let emb = Embedding::new(vocab, dim, store, rng, "lm");
        let blocks = (0..n_blocks)
            .map(|i| LmBlock::new(dim, d, theta, n, store, rng, &format!("lm.b{i}")))
            .collect();
        let head = Dense::new(dim, vocab, Activation::Linear, store, rng, "lm.head");
        let mix = store.add("lm.mix", Tensor::full(&[n_blocks], 1.0 / n_blocks as f32));
        LmModel { emb, blocks, head, dim, n, vocab, mix }
    }

    /// Hidden states of every block: input ids (B·n,) -> per-block
    /// (B·n, dim) nodes.
    fn block_states(
        &self,
        g: &mut Graph,
        store: &ParamStore,
        ids: &[usize],
        batch: usize,
    ) -> Vec<NodeId> {
        let mut h = self.emb.forward(g, store, ids);
        let mut states = Vec::with_capacity(self.blocks.len());
        for b in &self.blocks {
            h = b.forward(g, store, h, batch);
            states.push(h);
        }
        states
    }

    /// Top-block representation (text8-style: "we simply work with the
    /// output from the top block").
    pub fn encode_top(&self, g: &mut Graph, store: &ParamStore, ids: &[usize], batch: usize) -> NodeId {
        *self.block_states(g, store, ids, batch).last().unwrap()
    }

    /// Deep representation: learned weighted sum over block outputs
    /// (Amazon-reviews finetuning).
    pub fn encode_deep(&self, g: &mut Graph, store: &ParamStore, ids: &[usize], batch: usize) -> NodeId {
        let states = self.block_states(g, store, ids, batch);
        let mix0 = g.param(store, self.mix);
        let mix = g.reshape(mix0, &[1, self.blocks.len()]);
        let mut acc: Option<NodeId> = None;
        for (i, s) in states.iter().enumerate() {
            let wi = g.slice_cols(mix, i, i + 1); // (1, 1) scalar
            let w_mat = g.reshape(wi, &[1, 1]);
            // (B·n, dim) x scalar: use matmul with (1,1) after reshaping rows
            let flat = g.reshape(*s, &[g.value(*s).len(), 1]);
            let scaled = g.matmul(flat, w_mat);
            let back = {
                let dim = self.dim;
                let rows = g.value(*s).rows();
                g.reshape(scaled, &[rows, dim])
            };
            acc = Some(match acc {
                None => back,
                Some(a) => g.add(a, back),
            });
        }
        acc.unwrap()
    }

    /// Next-token LM loss on a (B, n+1) id batch: predict ids[t+1] from
    /// prefix ending at t, causal by the DN's construction.
    pub fn lm_loss(&self, g: &mut Graph, store: &ParamStore, batch_ids: &[Vec<usize>]) -> NodeId {
        let b = batch_ids.len();
        let n = self.n;
        let mut inputs = Vec::with_capacity(b * n);
        let mut labels = Vec::with_capacity(b * n);
        for ids in batch_ids {
            assert!(ids.len() >= n + 1, "need n+1 tokens per LM sample");
            inputs.extend_from_slice(&ids[..n]);
            labels.extend(ids[1..n + 1].iter().copied());
        }
        let h = self.encode_top(g, store, &inputs, b);
        let logits = self.head.forward(g, store, h); // (B·n, V)
        g.softmax_xent(logits, &labels)
    }

    /// Mean next-token NLL (nats) on held-out windows, for bpc reporting.
    pub fn eval_nll(&self, store: &ParamStore, batch_ids: &[Vec<usize>]) -> f64 {
        let mut g = Graph::new();
        let loss = self.lm_loss(&mut g, store, batch_ids);
        g.value(loss).item() as f64
    }
}

/// Cross-attention (trainable) for the translation decoder.
pub struct CrossAttention {
    pub wq: ParamId,
    pub wk: ParamId,
    pub wv: ParamId,
    pub dim: usize,
}

impl CrossAttention {
    pub fn new(dim: usize, store: &mut ParamStore, rng: &mut Rng, prefix: &str) -> Self {
        CrossAttention {
            wq: store.add(&format!("{prefix}.wq"), Tensor::glorot(dim, dim, rng)),
            wk: store.add(&format!("{prefix}.wk"), Tensor::glorot(dim, dim, rng)),
            wv: store.add(&format!("{prefix}.wv"), Tensor::glorot(dim, dim, rng)),
            dim,
        }
    }

    /// queries (R_q, dim), context (R_k, dim) -> (R_q, dim).
    /// NOTE: rows attend across the WHOLE context block, so callers batch
    /// one sample at a time (translation batches are per-sample graphs).
    pub fn forward(&self, g: &mut Graph, store: &ParamStore, x_q: NodeId, x_kv: NodeId) -> NodeId {
        let wq = g.param(store, self.wq);
        let wk = g.param(store, self.wk);
        let wv = g.param(store, self.wv);
        let q = g.matmul(x_q, wq);
        let k = g.matmul(x_kv, wk);
        let v = g.matmul(x_kv, wv);
        let scores = g.matmul_nt(q, k);
        let scaled = g.scale(scores, 1.0 / (self.dim as f32).sqrt());
        let attn = g.softmax_rows(scaled);
        g.matmul(attn, v)
    }
}

/// Encoder-decoder translator: LMU encoder over source embeddings, then a
/// per-position decoder that cross-attends into the encoder states
/// (§4.5's "standard encoder-decoder architecture ... with an attention
/// layer to help with translation").
pub struct Translator {
    pub src_emb: Embedding,
    pub encoder: LmuParallelLayer,
    pub attn: CrossAttention,
    pub out: Dense,
    pub n: usize,
    pub dim: usize,
}

impl Translator {
    pub fn new(
        src_vocab: usize,
        tgt_vocab: usize,
        dim: usize,
        d: usize,
        n: usize,
        store: &mut ParamStore,
        rng: &mut Rng,
    ) -> Self {
        let src_emb = Embedding::new(src_vocab, dim, store, rng, "tr.src");
        let spec = LmuSpec::new(dim, 1, d, n as f64, dim);
        let encoder = LmuParallelLayer::new(spec, n, store, rng, "tr.enc");
        let attn = CrossAttention::new(dim, store, rng, "tr.attn");
        let out = Dense::new(2 * dim, tgt_vocab, Activation::Linear, store, rng, "tr.out");
        Translator { src_emb, encoder, attn, out, n, dim }
    }

    /// Per-sample logits over target positions: src ids (n,) -> (n, V_tgt).
    pub fn logits(&self, g: &mut Graph, store: &ParamStore, src: &[usize]) -> NodeId {
        assert_eq!(src.len(), self.n);
        let e = self.src_emb.forward(g, store, src); // (n, dim)
        let enc = self.encoder.forward_all(g, store, e, 1); // (n, dim)
        let ctx = self.attn.forward(g, store, enc, enc); // (n, dim)
        let cat = g.concat_cols(&[enc, ctx]); // (n, 2dim)
        self.out.forward(g, store, cat)
    }

    pub fn loss(&self, g: &mut Graph, store: &ParamStore, src: &[usize], tgt: &[usize]) -> NodeId {
        let logits = self.logits(g, store, src);
        g.softmax_xent(logits, tgt)
    }

    pub fn translate(&self, store: &ParamStore, src: &[usize]) -> Vec<usize> {
        let mut g = Graph::new();
        let logits = self.logits(&mut g, store, src);
        g.value(logits).argmax_rows()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::{Adam, Optimizer};

    #[test]
    fn lm_shapes_and_loss_finite() {
        let mut store = ParamStore::new();
        let mut rng = Rng::new(0);
        let lm = LmModel::new(30, 16, 2, 4, 8.0, 12, &mut store, &mut rng);
        let batch: Vec<Vec<usize>> = (0..3).map(|i| (0..13).map(|t| (t * 3 + i) % 30).collect()).collect();
        let mut g = Graph::new();
        let loss = lm.lm_loss(&mut g, &store, &batch);
        let lv = g.value(loss).item();
        assert!(lv.is_finite());
        // near-uniform init => loss ~ ln(vocab)
        assert!((lv - (30.0f32).ln()).abs() < 1.0, "init loss {lv}");
        g.backward(loss);
        assert!(g.param_grads().len() > 5);
    }

    #[test]
    fn lm_memorizes_tiny_corpus() {
        let mut store = ParamStore::new();
        let mut rng = Rng::new(1);
        let lm = LmModel::new(10, 12, 1, 4, 8.0, 8, &mut store, &mut rng);
        // deterministic cyclic sequence: fully predictable
        let seq: Vec<usize> = (0..9).map(|t| t % 10).collect();
        let batch = vec![seq; 4];
        let mut opt = Adam::new(1e-2);
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..80 {
            let mut g = Graph::new();
            let loss = lm.lm_loss(&mut g, &store, &batch);
            let lv = g.value(loss).item();
            if it == 0 {
                first = lv;
            }
            last = lv;
            g.backward(loss);
            let grads = g.param_grads();
            opt.step(&mut store, &grads);
        }
        assert!(last < first * 0.3, "LM failed to memorize: {first} -> {last}");
    }

    #[test]
    fn deep_representation_mixes_blocks() {
        let mut store = ParamStore::new();
        let mut rng = Rng::new(2);
        let lm = LmModel::new(20, 8, 3, 4, 6.0, 6, &mut store, &mut rng);
        let ids: Vec<usize> = (0..12).map(|t| t % 20).collect();
        let mut g = Graph::new();
        let deep = lm.encode_deep(&mut g, &store, &ids, 2);
        assert_eq!(g.value(deep).shape(), &[12, 8]);
        // gradient reaches the mixing weights
        let sq = g.mul(deep, deep);
        let loss = g.mean_all(sq);
        g.backward(loss);
        let grads = g.param_grads();
        assert!(
            grads.iter().any(|(pid, g2)| store.name(*pid) == "lm.mix" && g2.abs_max() > 0.0),
            "mix weights got no gradient"
        );
    }

    #[test]
    fn translator_learns_identity_mapping() {
        // trivial translation task (identity) to validate the pipeline
        let mut store = ParamStore::new();
        let mut rng = Rng::new(3);
        let tr = Translator::new(12, 12, 16, 6, 6, &mut store, &mut rng);
        let mut opt = Adam::new(5e-3);
        let mut first = 0.0;
        let mut last = 0.0;
        for it in 0..60 {
            let src: Vec<usize> = (0..6).map(|t| (t * 5 + it) % 12).collect();
            let tgt = src.clone();
            let mut g = Graph::new();
            let loss = tr.loss(&mut g, &store, &src, &tgt);
            let lv = g.value(loss).item();
            if it == 0 {
                first = lv;
            }
            last = lv;
            g.backward(loss);
            let grads = g.param_grads();
            opt.step(&mut store, &grads);
        }
        assert!(last < first * 0.6, "translator not learning: {first} -> {last}");
    }
}
