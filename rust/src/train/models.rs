//! Ready-made models for the paper's experiments: sequence classifiers
//! (psMNIST, sentiment) and regressors (Mackey-Glass) over any of the
//! compared architectures.

use crate::autograd::{Graph, NodeId, ParamStore};
use crate::data::batcher::{Batch, Targets};
use crate::layers::{
    last_steps, lmu::LmuSpec, to_time_major, Activation, Dense, LmuOriginalCell,
    LmuParallelLayer, LmuSequentialLayer, LstmLayer,
};
use crate::tensor::Tensor;
use crate::train::{Prediction, TrainableModel};
use crate::util::Rng;

/// Which architecture backs the model (the paper's comparison set).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// our model, parallel training path (eq. 25/26)
    LmuParallel,
    /// our model, sequential "LTI version" (eq. 19 step by step)
    LmuSequential,
    /// the original LMU (eqs. 15-17)
    LmuOriginal,
    /// LSTM baseline
    Lstm,
}

enum Backbone {
    Parallel(LmuParallelLayer),
    Sequential(LmuSequentialLayer),
    Original(LmuOriginalCell),
    Lstm(LstmLayer),
}

/// Classifier: backbone -> dense softmax head on the final-step features.
pub struct SeqClassifier {
    pub kind: ModelKind,
    backbone: Backbone,
    head: Dense,
    pub seq_len: usize,
    pub dx: usize,
}

impl SeqClassifier {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        kind: ModelKind,
        seq_len: usize,
        dx: usize,
        d: usize,
        hidden: usize,
        classes: usize,
        store: &mut ParamStore,
        rng: &mut Rng,
    ) -> Self {
        let theta = seq_len as f64;
        let backbone = match kind {
            ModelKind::LmuParallel => Backbone::Parallel(LmuParallelLayer::new(
                LmuSpec::new(dx, 1, d, theta, hidden),
                seq_len,
                store,
                rng,
                "clf.lmu",
            )),
            ModelKind::LmuSequential => Backbone::Sequential(LmuSequentialLayer::new(
                LmuSpec::new(dx, 1, d, theta, hidden),
                store,
                rng,
                "clf.lmu",
            )),
            ModelKind::LmuOriginal => Backbone::Original(LmuOriginalCell::new(
                dx, hidden, d, theta, store, rng, "clf.orig",
            )),
            ModelKind::Lstm => Backbone::Lstm(LstmLayer::new(dx, hidden, store, rng, "clf.lstm")),
        };
        let head = Dense::new(hidden, classes, Activation::Linear, store, rng, "clf.head");
        SeqClassifier { kind, backbone, head, seq_len, dx }
    }

    fn features(&self, g: &mut Graph, store: &ParamStore, batch: &Batch) -> NodeId {
        let b = batch.batch_size;
        let n = self.seq_len;
        match &self.backbone {
            Backbone::Parallel(layer) => {
                let x = g.input(batch.x.clone());
                let xl = g.input(last_steps(&batch.x, b, n));
                layer.forward_last(g, store, x, xl, b)
            }
            Backbone::Sequential(layer) => {
                let x = g.input(to_time_major(&batch.x, b, n));
                layer.forward_last(g, store, x, b, n)
            }
            Backbone::Original(cell) => {
                let x = g.input(to_time_major(&batch.x, b, n));
                cell.forward_last(g, store, x, b, n)
            }
            Backbone::Lstm(layer) => {
                let x = g.input(to_time_major(&batch.x, b, n));
                layer.forward_last(g, store, x, b, n)
            }
        }
    }

    pub fn logits(&self, g: &mut Graph, store: &ParamStore, batch: &Batch) -> NodeId {
        let f = self.features(g, store, batch);
        self.head.forward(g, store, f)
    }

    // ------------------------------------------------------- streaming

    /// The scan chunk length L when this model can stream (parallel
    /// backbone under `PLMU_SCAN=scan`), else None.
    pub fn scan_block(&self) -> Option<usize> {
        match &self.backbone {
            Backbone::Parallel(layer) => layer.scan_operator().map(|op| op.block),
            _ => None,
        }
    }

    fn parallel_layer(&self) -> &LmuParallelLayer {
        match &self.backbone {
            Backbone::Parallel(layer) => layer,
            _ => panic!("streaming training requires the parallel (scan) backbone"),
        }
    }

    /// A zero DN carry (B, du·d) to start a stream from.
    pub fn carry_zeros(&self, batch: usize) -> Tensor {
        let spec = &self.parallel_layer().spec;
        Tensor::zeros(&[batch, spec.du * spec.d])
    }

    /// Advance the DN carry (B, du·d) through a non-final window, values
    /// only — the TBPTT truncation: no tape, no gradients, just the
    /// d-dim state per channel.  `x_window` is sample-major (B·win, dx);
    /// `win` must be a multiple of the scan block so the streamed chunk
    /// seams land exactly where the whole-sequence evaluation puts them.
    pub fn advance_carry(
        &self,
        store: &ParamStore,
        x_window: &Tensor,
        batch: usize,
        carry: &mut Tensor,
    ) {
        let layer = self.parallel_layer();
        let scan =
            layer.scan_operator().expect("streaming training requires PLMU_SCAN=scan").clone();
        assert_eq!(x_window.rows() % batch, 0);
        let win = x_window.rows() / batch;
        assert_eq!(
            win % scan.block,
            0,
            "non-final stream windows must be a multiple of the scan block {}",
            scan.block
        );
        // the exact encoder kernel the graph path records
        let u = layer.encode_values(store, x_window); // (B·win, du)
        let dud = carry.cols();
        for b in 0..batch {
            let u_b = u.slice_rows(b * win, (b + 1) * win);
            let c0 = carry.data()[b * dud..(b + 1) * dud].to_vec();
            let next = scan.apply_last(&u_b, Some(&c0));
            carry.data_mut()[b * dud..(b + 1) * dud].copy_from_slice(&next);
        }
    }

    /// Loss over the final stream window, resuming the DN from `carry`
    /// (B, du·d): the only window that gets a tape and gradients.
    pub fn window_loss(
        &self,
        g: &mut Graph,
        store: &ParamStore,
        x_window: &Tensor,
        labels: &[usize],
        batch: usize,
        carry: &Tensor,
    ) -> NodeId {
        let layer = self.parallel_layer();
        assert_eq!(x_window.rows() % batch, 0);
        let win = x_window.rows() / batch;
        let x = g.input(x_window.clone());
        let xl = g.input(last_steps(x_window, batch, win));
        let o = layer.forward_last_from(g, store, x, xl, batch, carry);
        let logits = self.head.forward(g, store, o);
        g.softmax_xent(logits, labels)
    }
}

impl TrainableModel for SeqClassifier {
    fn loss(&self, g: &mut Graph, store: &ParamStore, batch: &Batch) -> NodeId {
        let logits = self.logits(g, store, batch);
        match &batch.targets {
            Targets::Labels(y) => g.softmax_xent(logits, y),
            _ => panic!("classifier needs labels"),
        }
    }

    fn predict(&self, store: &ParamStore, batch: &Batch) -> Prediction {
        let mut g = Graph::new();
        let logits = self.logits(&mut g, store, batch);
        Prediction::Classes(g.value(logits).argmax_rows())
    }
}

/// Regressor for Mackey-Glass (Table 3): backbone -> dense(80, tanh) ->
/// dense(1), matching the paper's "our model + an additional dense layer".
pub struct SeqRegressor {
    pub kind: RegressorKind,
    backbone: RegressorBackbone,
    mid: Dense,
    out: Dense,
    pub seq_len: usize,
}

/// The four rows of Table 3.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RegressorKind {
    /// stacked LSTMs (paper baseline row 1)
    Lstm,
    /// original LMU cells (row 2)
    LmuOriginal,
    /// LMU -> LSTM hybrid (row 3)
    Hybrid,
    /// our model, parallel (row 4)
    LmuParallel,
}

enum RegressorBackbone {
    Lstm(LstmLayer, LstmLayer),
    Original(LmuOriginalCell),
    Hybrid(LmuOriginalCell, LstmLayer),
    Parallel(LmuParallelLayer),
}

impl SeqRegressor {
    pub fn new(
        kind: RegressorKind,
        seq_len: usize,
        d: usize,
        theta: f64,
        hidden: usize,
        store: &mut ParamStore,
        rng: &mut Rng,
    ) -> Self {
        let backbone = match kind {
            RegressorKind::Lstm => RegressorBackbone::Lstm(
                LstmLayer::new(1, hidden, store, rng, "reg.lstm1"),
                LstmLayer::new(hidden, hidden, store, rng, "reg.lstm2"),
            ),
            RegressorKind::LmuOriginal => RegressorBackbone::Original(LmuOriginalCell::new(
                1, hidden, d, theta, store, rng, "reg.orig",
            )),
            RegressorKind::Hybrid => RegressorBackbone::Hybrid(
                LmuOriginalCell::new(1, hidden, d, theta, store, rng, "reg.hlmu"),
                LstmLayer::new(hidden, hidden, store, rng, "reg.hlstm"),
            ),
            RegressorKind::LmuParallel => RegressorBackbone::Parallel(LmuParallelLayer::new(
                LmuSpec::new(1, 1, d, theta, hidden),
                seq_len,
                store,
                rng,
                "reg.lmu",
            )),
        };
        let mid = Dense::new(hidden, 80.min(hidden * 4), Activation::Tanh, store, rng, "reg.mid");
        let out = Dense::new(mid.dout, 1, Activation::Linear, store, rng, "reg.out");
        SeqRegressor { kind, backbone, mid, out, seq_len }
    }

    fn features(&self, g: &mut Graph, store: &ParamStore, batch: &Batch) -> NodeId {
        let b = batch.batch_size;
        let n = self.seq_len;
        match &self.backbone {
            RegressorBackbone::Parallel(layer) => {
                let x = g.input(batch.x.clone());
                let xl = g.input(last_steps(&batch.x, b, n));
                layer.forward_last(g, store, x, xl, b)
            }
            RegressorBackbone::Lstm(l1, l2) => {
                let x = g.input(to_time_major(&batch.x, b, n));
                let h1 = l1.forward_all(g, store, x, b, n);
                l2.forward_last(g, store, h1, b, n)
            }
            RegressorBackbone::Original(cell) => {
                let x = g.input(to_time_major(&batch.x, b, n));
                cell.forward_last(g, store, x, b, n)
            }
            RegressorBackbone::Hybrid(cell, lstm) => {
                let x = g.input(to_time_major(&batch.x, b, n));
                let h1 = cell.forward_all(g, store, x, b, n);
                lstm.forward_last(g, store, h1, b, n)
            }
        }
    }

    pub fn outputs(&self, g: &mut Graph, store: &ParamStore, batch: &Batch) -> NodeId {
        let f = self.features(g, store, batch);
        let m = self.mid.forward(g, store, f);
        self.out.forward(g, store, m)
    }
}

impl TrainableModel for SeqRegressor {
    fn loss(&self, g: &mut Graph, store: &ParamStore, batch: &Batch) -> NodeId {
        let pred = self.outputs(g, store, batch);
        match &batch.targets {
            Targets::Values(v) => {
                let target = Tensor::new(&[v.len(), 1], v.clone());
                g.mse(pred, &target)
            }
            _ => panic!("regressor needs values"),
        }
    }

    fn predict(&self, store: &ParamStore, batch: &Batch) -> Prediction {
        let mut g = Graph::new();
        let out = self.outputs(&mut g, store, batch);
        Prediction::Values(g.value(out).data().to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::batcher::{BatchIter, SeqDataset};
    use crate::optim::{Adam, Optimizer};

    fn toy_batch(b: usize, n: usize, seed: u64) -> (SeqDataset, Batch) {
        let mut rng = Rng::new(seed);
        let xs: Vec<Tensor> = (0..b * 2).map(|_| Tensor::randn(&[n, 1], 1.0, &mut rng)).collect();
        let ys: Vec<usize> = (0..b * 2).map(|i| i % 2).collect();
        let ds = SeqDataset::classification(xs, ys);
        let batch = BatchIter::sequential(&ds, b).next().unwrap();
        (ds, batch)
    }

    #[test]
    fn all_classifier_kinds_run_forward_and_backward() {
        for kind in [
            ModelKind::LmuParallel,
            ModelKind::LmuSequential,
            ModelKind::LmuOriginal,
            ModelKind::Lstm,
        ] {
            let mut store = ParamStore::new();
            let mut rng = Rng::new(0);
            let model = SeqClassifier::new(kind, 12, 1, 6, 10, 3, &mut store, &mut rng);
            let (_ds, batch) = toy_batch(4, 12, 1);
            let mut g = Graph::new();
            let loss = model.loss(&mut g, &store, &batch);
            assert!(g.value(loss).item().is_finite(), "{kind:?}");
            g.backward(loss);
            assert!(!g.param_grads().is_empty(), "{kind:?}");
            match model.predict(&store, &batch) {
                Prediction::Classes(c) => assert_eq!(c.len(), 4),
                _ => panic!(),
            }
        }
    }

    #[test]
    fn parallel_and_sequential_classifiers_same_function() {
        // build parallel, copy params into a sequential twin, compare
        let mut store = ParamStore::new();
        let mut rng = Rng::new(5);
        let par = SeqClassifier::new(ModelKind::LmuParallel, 10, 1, 5, 8, 3, &mut store, &mut rng);
        let mut store2 = ParamStore::new();
        let mut rng2 = Rng::new(5); // same seed => same init draws
        let seq =
            SeqClassifier::new(ModelKind::LmuSequential, 10, 1, 5, 8, 3, &mut store2, &mut rng2);
        let (_ds, batch) = toy_batch(3, 10, 2);
        let mut g1 = Graph::new();
        let l1 = par.logits(&mut g1, &store, &batch);
        let mut g2 = Graph::new();
        let l2 = seq.logits(&mut g2, &store2, &batch);
        let err = g1.value(l1).max_abs_diff(g2.value(l2));
        assert!(err < 2e-4, "parallel vs sequential classifier: {err}");
    }

    #[test]
    fn all_regressor_kinds_train_a_step() {
        let mut rng0 = Rng::new(7);
        let xs: Vec<Tensor> = (0..8).map(|_| Tensor::randn(&[10, 1], 1.0, &mut rng0)).collect();
        let ys: Vec<f32> = (0..8).map(|i| (i % 3) as f32 * 0.1).collect();
        let ds = SeqDataset::regression(xs, ys);
        for kind in [
            RegressorKind::Lstm,
            RegressorKind::LmuOriginal,
            RegressorKind::Hybrid,
            RegressorKind::LmuParallel,
        ] {
            let mut store = ParamStore::new();
            let mut rng = Rng::new(8);
            let model = SeqRegressor::new(kind, 10, 4, 10.0, 8, &mut store, &mut rng);
            let batch = BatchIter::sequential(&ds, 4).next().unwrap();
            let mut g = Graph::new();
            let loss = model.loss(&mut g, &store, &batch);
            let l0 = g.value(loss).item();
            assert!(l0.is_finite(), "{kind:?}");
            g.backward(loss);
            let grads = g.param_grads();
            let mut opt = Adam::new(1e-2);
            opt.step(&mut store, &grads);
            // second pass must see a changed (typically lower) loss
            let mut g2 = Graph::new();
            let loss2 = model.loss(&mut g2, &store, &batch);
            assert_ne!(l0, g2.value(loss2).item(), "{kind:?} params did not move");
        }
    }
}
