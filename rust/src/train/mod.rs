//! The trainer: epoch loops, loss-curve logging, checkpointing, and the
//! ready-made models for the paper's experiments (classifier/regressor
//! heads over the LMU/LSTM layers).

pub mod lm;
pub mod models;

pub use lm::{LmModel, Translator};
pub use models::{ModelKind, RegressorKind, SeqClassifier, SeqRegressor};

use crate::autograd::{Graph, NodeId, ParamStore};
use crate::data::batcher::{Batch, BatchIter, SeqDataset, Targets};
use crate::exec::arena::{self, Arena};
use crate::optim::{clip_global_norm, LrSchedule, Optimizer};
use crate::util::{Rng, Timer};

/// A trainable model: build the loss node for one batch, and predict.
pub trait TrainableModel {
    fn loss(&self, g: &mut Graph, store: &ParamStore, batch: &Batch) -> NodeId;
    /// Class predictions (classification) or scalar outputs (regression).
    fn predict(&self, store: &ParamStore, batch: &Batch) -> Prediction;
}

pub enum Prediction {
    Classes(Vec<usize>),
    Values(Vec<f32>),
}

/// Per-epoch record for EXPERIMENTS.md loss curves.
#[derive(Clone, Debug)]
pub struct EpochLog {
    pub epoch: usize,
    pub mean_loss: f64,
    pub wall_secs: f64,
    pub eval_metric: Option<f64>,
}

/// Result of a full training run.
pub struct TrainResult {
    pub epochs: Vec<EpochLog>,
    pub step_losses: Vec<f32>,
}

/// Options for `fit`.
pub struct FitOptions {
    pub epochs: usize,
    pub batch_size: usize,
    pub schedule: LrSchedule,
    pub grad_clip: Option<f32>,
    pub seed: u64,
    pub log_every: usize,
    pub verbose: bool,
}

impl Default for FitOptions {
    fn default() -> Self {
        FitOptions {
            epochs: 5,
            batch_size: 32,
            schedule: LrSchedule::constant(1e-3),
            grad_clip: None,
            seed: 0,
            log_every: 0,
            verbose: false,
        }
    }
}

/// One optimizer step: reset the retained graph, re-record the model
/// over `batch` with the thread's arena installed, backprop, clip, and
/// apply.  Factored out of [`fit`] so tests and coordinators can drive
/// single steps against the same retained graph + arena pair.
pub fn train_step(
    model: &dyn TrainableModel,
    store: &mut ParamStore,
    opt: &mut dyn Optimizer,
    g: &mut Graph,
    arena: &mut Arena,
    batch: &Batch,
    grad_clip: Option<f32>,
) -> f32 {
    arena::scope(arena, || {
        // Dropping last step's nodes inside the scope returns their
        // buffers to the arena; this step's recording draws them back.
        g.reset();
        let loss = model.loss(g, store, batch);
        g.backward(loss);
        let lv = g.value(loss).item();
        let mut grads = g.param_grads();
        if let Some(c) = grad_clip {
            clip_global_norm(&mut grads, c);
        }
        opt.step(store, &grads);
        lv
    })
}

/// Sample-major window gather: rows [lo, hi) of every sample in a
/// (B·n, dx) tensor, re-packed (B·(hi−lo), dx).
fn gather_window(x: &crate::tensor::Tensor, batch: usize, n: usize, lo: usize, hi: usize) -> crate::tensor::Tensor {
    let dx = x.cols();
    let w = hi - lo;
    let mut out = crate::tensor::Tensor::zeros(&[batch * w, dx]);
    for b in 0..batch {
        out.data_mut()[b * w * dx..(b + 1) * w * dx]
            .copy_from_slice(&x.data()[(b * n + lo) * dx..(b * n + hi) * dx]);
    }
    out
}

/// One truncated-BPTT optimizer step over an arbitrarily long batch:
/// non-final windows advance the DN carry values-only (bounded memory —
/// only (B, du·d) state survives a window), the final window gets the
/// tape and the gradients.  Requires `PLMU_SCAN=scan`; `window` is
/// rounded up to a multiple of the scan block so streamed chunk seams
/// coincide with the whole-sequence evaluation's, which makes a window
/// covering the full sequence bit-identical to [`train_step`].
#[allow(clippy::too_many_arguments)]
pub fn train_step_streaming(
    model: &SeqClassifier,
    store: &mut ParamStore,
    opt: &mut dyn Optimizer,
    g: &mut Graph,
    arena: &mut Arena,
    batch: &Batch,
    window: usize,
    grad_clip: Option<f32>,
) -> f32 {
    let b = batch.batch_size;
    let n = model.seq_len;
    let l = model
        .scan_block()
        .expect("streaming training requires PLMU_SCAN=scan (env, [train] scan, or --scan)");
    let w = window.max(1).div_ceil(l) * l;
    let labels = match &batch.targets {
        Targets::Labels(y) => y.clone(),
        _ => panic!("streaming trainer needs labels"),
    };
    let mut carry = model.carry_zeros(b);
    let mut lo = 0usize;
    while n - lo > w {
        let xw = gather_window(&batch.x, b, n, lo, lo + w);
        model.advance_carry(store, &xw, b, &mut carry);
        lo += w;
    }
    let xw = gather_window(&batch.x, b, n, lo, n);
    arena::scope(arena, || {
        g.reset();
        let loss = model.window_loss(g, store, &xw, &labels, b, &carry);
        g.backward(loss);
        let lv = g.value(loss).item();
        let mut grads = g.param_grads();
        if let Some(c) = grad_clip {
            clip_global_norm(&mut grads, c);
        }
        opt.step(store, &grads);
        lv
    })
}

/// Train a classifier with truncated-BPTT streaming windows (the
/// overlap-save mode of the chunked scan): same epoch loop, logging,
/// and eval as [`fit`], but each step runs [`train_step_streaming`]
/// with the given window length.  With `window >= seq_len` every step
/// degenerates to one whole-sequence window from a zero carry, and the
/// run is bit-identical to [`fit`] under the same knobs.
pub fn fit_streaming(
    model: &SeqClassifier,
    store: &mut ParamStore,
    opt: &mut dyn Optimizer,
    train: &SeqDataset,
    eval: Option<&SeqDataset>,
    opts: &FitOptions,
    window: usize,
) -> TrainResult {
    let mut rng = Rng::new(opts.seed);
    let mut epochs = Vec::new();
    let mut step_losses = Vec::new();
    let mut g = Graph::new();
    let mut arena = Arena::new();
    for epoch in 0..opts.epochs {
        opt.set_lr(opts.schedule.lr_at(epoch));
        let timer = Timer::start();
        let mut running = crate::metrics::Running::new();
        let mut step = 0usize;
        for batch in BatchIter::new(train, opts.batch_size, &mut rng) {
            let lv = train_step_streaming(
                model,
                store,
                opt,
                &mut g,
                &mut arena,
                &batch,
                window,
                opts.grad_clip,
            );
            running.push(lv as f64);
            step_losses.push(lv);
            step += 1;
            if opts.verbose && opts.log_every > 0 && step % opts.log_every == 0 {
                println!("    epoch {epoch} step {step}: loss {lv:.4}");
            }
        }
        let eval_metric = eval.map(|ds| evaluate(model, store, ds, opts.batch_size));
        let log = EpochLog {
            epoch,
            mean_loss: running.mean(),
            wall_secs: timer.elapsed(),
            eval_metric,
        };
        if opts.verbose {
            match log.eval_metric {
                Some(m) => println!(
                    "  epoch {epoch}: loss {:.4}, eval {m:.4}, {:.1}s",
                    log.mean_loss, log.wall_secs
                ),
                None => println!("  epoch {epoch}: loss {:.4}, {:.1}s", log.mean_loss, log.wall_secs),
            }
        }
        epochs.push(log);
    }
    TrainResult { epochs, step_losses }
}

/// Train `model` on `train`, optionally evaluating on `eval` each epoch.
pub fn fit(
    model: &dyn TrainableModel,
    store: &mut ParamStore,
    opt: &mut dyn Optimizer,
    train: &SeqDataset,
    eval: Option<&SeqDataset>,
    opts: &FitOptions,
) -> TrainResult {
    let mut rng = Rng::new(opts.seed);
    let mut epochs = Vec::new();
    let mut step_losses = Vec::new();
    // Retained across every step of the run: the graph keeps its node
    // vector's capacity, the arena keeps the recycled tensor buffers.
    let mut g = Graph::new();
    let mut arena = Arena::new();
    let mut alloc_mark = arena.stats();
    for epoch in 0..opts.epochs {
        opt.set_lr(opts.schedule.lr_at(epoch));
        let timer = Timer::start();
        let mut running = crate::metrics::Running::new();
        let mut step = 0usize;
        for batch in BatchIter::new(train, opts.batch_size, &mut rng) {
            let lv = train_step(model, store, opt, &mut g, &mut arena, &batch, opts.grad_clip);
            running.push(lv as f64);
            step_losses.push(lv);
            step += 1;
            if opts.verbose && opts.log_every > 0 && step % opts.log_every == 0 {
                println!("    epoch {epoch} step {step}: loss {lv:.4}");
            }
        }
        if crate::metrics::alloc_stats_enabled() {
            let now = arena.stats();
            println!("  epoch {epoch} {}", crate::metrics::alloc_report(&now.since(&alloc_mark)));
            alloc_mark = now;
        }
        let eval_metric = eval.map(|ds| evaluate(model, store, ds, opts.batch_size));
        let log = EpochLog {
            epoch,
            mean_loss: running.mean(),
            wall_secs: timer.elapsed(),
            eval_metric,
        };
        if opts.verbose {
            match log.eval_metric {
                Some(m) => println!(
                    "  epoch {epoch}: loss {:.4}, eval {m:.4}, {:.1}s",
                    log.mean_loss, log.wall_secs
                ),
                None => println!("  epoch {epoch}: loss {:.4}, {:.1}s", log.mean_loss, log.wall_secs),
            }
        }
        epochs.push(log);
    }
    TrainResult { epochs, step_losses }
}

/// Evaluate accuracy (classification) or NRMSE (regression).
pub fn evaluate(
    model: &dyn TrainableModel,
    store: &ParamStore,
    ds: &SeqDataset,
    batch_size: usize,
) -> f64 {
    let mut all_pred_c = Vec::new();
    let mut all_true_c = Vec::new();
    let mut all_pred_v = Vec::new();
    let mut all_true_v = Vec::new();
    for batch in BatchIter::sequential(ds, batch_size.min(ds.len())) {
        match (model.predict(store, &batch), &batch.targets) {
            (Prediction::Classes(p), Targets::Labels(t)) => {
                all_pred_c.extend(p);
                all_true_c.extend_from_slice(t);
            }
            (Prediction::Values(p), Targets::Values(t)) => {
                all_pred_v.extend(p);
                all_true_v.extend_from_slice(t);
            }
            _ => panic!("prediction/target kind mismatch"),
        }
    }
    if !all_pred_c.is_empty() {
        crate::metrics::accuracy(&all_pred_c, &all_true_c)
    } else {
        crate::metrics::nrmse(&all_pred_v, &all_true_v)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SeqDataset;
    use crate::optim::Adam;
    use crate::tensor::Tensor;

    /// A separable toy task: class = sign of the mean of the sequence.
    fn toy_classification(n_examples: usize, seq_len: usize, seed: u64) -> SeqDataset {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..n_examples {
            let sign = if i % 2 == 0 { 1.0f32 } else { -1.0 };
            let mut x = Tensor::randn(&[seq_len, 1], 0.5, &mut rng);
            x.map_inplace(|v| v + sign * 0.4);
            xs.push(x);
            ys.push(usize::from(sign > 0.0));
        }
        SeqDataset::classification(xs, ys)
    }

    #[test]
    fn fit_reduces_loss_and_evaluates() {
        let ds = toy_classification(64, 16, 0);
        let (train, test) = ds.split(0.25);
        let mut store = ParamStore::new();
        let mut rng = Rng::new(1);
        let model = SeqClassifier::new(
            ModelKind::LmuParallel,
            16, // seq len
            1,  // dx
            8,  // d
            16, // hidden
            2,  // classes
            &mut store,
            &mut rng,
        );
        let mut opt = Adam::new(1e-2);
        let opts = FitOptions { epochs: 12, batch_size: 8, ..Default::default() };
        let res = fit(&model, &mut store, &mut opt, &train, Some(&test), &opts);
        assert_eq!(res.epochs.len(), 12);
        let first = res.epochs[0].mean_loss;
        let last = res.epochs.last().unwrap().mean_loss;
        assert!(last < first * 0.7, "loss {first} -> {last}");
        let acc = res.epochs.last().unwrap().eval_metric.unwrap();
        assert!(acc > 80.0, "eval accuracy {acc}");
    }

    #[test]
    fn steady_state_training_allocates_nothing() {
        // After warmup has populated the arena's size classes (and Adam's
        // moment buffers), further steps over same-shaped batches must be
        // served entirely from the arena: zero misses, zero fresh bytes.
        let ds = toy_classification(32, 12, 5);
        let mut store = ParamStore::new();
        let mut rng = Rng::new(7);
        let model = SeqClassifier::new(
            ModelKind::LmuParallel,
            12, // seq len
            1,  // dx
            6,  // d
            12, // hidden
            2,  // classes
            &mut store,
            &mut rng,
        );
        let mut opt = Adam::new(1e-3);
        let mut g = Graph::new();
        let mut arena = Arena::new();
        let batches: Vec<_> = crate::data::batcher::BatchIter::sequential(&ds, 8).collect();
        assert!(batches.len() >= 2);
        // warmup: two passes (first allocates activations + optimizer
        // state; second settles the free-list population)
        for _ in 0..2 {
            for b in &batches {
                train_step(&model, &mut store, &mut opt, &mut g, &mut arena, b, None);
            }
        }
        let warm = arena.stats();
        for _ in 0..3 {
            for b in &batches {
                train_step(&model, &mut store, &mut opt, &mut g, &mut arena, b, None);
            }
        }
        let delta = arena.stats().since(&warm);
        assert_eq!(delta.misses, 0, "steady-state step touched the heap: {delta:?}");
        assert_eq!(delta.fresh_bytes, 0, "{delta:?}");
        assert!(delta.hits > 0, "arena was never exercised: {delta:?}");
    }

    #[test]
    fn schedule_applies_decay() {
        let ds = toy_classification(16, 8, 2);
        let mut store = ParamStore::new();
        let mut rng = Rng::new(3);
        let model = SeqClassifier::new(
            ModelKind::LmuParallel,
            8,
            1,
            4,
            8,
            2,
            &mut store,
            &mut rng,
        );
        let mut opt = Adam::new(1.0); // overwritten by schedule
        let opts = FitOptions {
            epochs: 2,
            batch_size: 8,
            schedule: LrSchedule::step_decay(1e-2, 1, 0.1),
            ..Default::default()
        };
        fit(&model, &mut store, &mut opt, &ds, None, &opts);
        assert!((opt.lr() - 1e-3).abs() < 1e-9, "decay not applied: {}", opt.lr());
    }
}
