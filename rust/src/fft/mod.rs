//! Radix-2 FFT and FFT-based causal convolution — the engine behind the
//! paper's eq. (26): `m_{1:n} = F^{-1}{ F{H} · F{U} }`.
//!
//! A `Plan` precomputes twiddle factors and the bit-reversal permutation
//! for a given power-of-two size; convolutions pad to `next_pow2(2n)` so a
//! circular convolution realizes the causal (linear) one exactly.
//! The impulse-response spectrum `F{H}` is frozen (A, B are not trained),
//! so `RfftCache` lets callers reuse it across every batch — this is the
//! single biggest win on the training hot path (see EXPERIMENTS.md §Perf).
//!
//! Plans and post-twiddle tables live in a process-global `Arc` cache
//! (RwLock'd HashMap) rather than the former `thread_local!` `Rc` cache:
//! the batched convolutions fan out over `crate::exec` pool worker
//! threads, and per-thread caches would rebuild every plan on every
//! spawned worker.  Batch-level parallelism partitions the *independent
//! signal rows* (B·dx of them); each row's transform is the identical
//! serial op sequence, so results are bit-exact at any thread count.

use crate::exec;
use crate::simd;
use std::collections::HashMap; // lint-src: allow(hashmap) — caches below, lookup-only
use std::f64::consts::PI;
use std::sync::{Arc, OnceLock, RwLock};

/// Complex number (f64 — convolution error compounds across long sequences,
/// and the FFT is a small fraction of total time).
///
/// `repr(C)` is load-bearing: a `&[Cpx]` is reinterpreted as interleaved
/// `(re, im)` `f64`s (`cpx_floats`) so the spectrum product can run on
/// the `crate::simd` complex-multiply kernel.
#[repr(C)]
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Cpx {
    pub re: f64,
    pub im: f64,
}

impl Cpx {
    pub const ZERO: Cpx = Cpx { re: 0.0, im: 0.0 };

    #[inline]
    pub fn new(re: f64, im: f64) -> Self {
        Cpx { re, im }
    }

    #[inline]
    pub fn mul(self, o: Cpx) -> Cpx {
        Cpx::new(self.re * o.re - self.im * o.im, self.re * o.im + self.im * o.re)
    }

    #[inline]
    pub fn add(self, o: Cpx) -> Cpx {
        Cpx::new(self.re + o.re, self.im + o.im)
    }

    #[inline]
    pub fn sub(self, o: Cpx) -> Cpx {
        Cpx::new(self.re - o.re, self.im - o.im)
    }

    #[inline]
    pub fn conj(self) -> Cpx {
        Cpx::new(self.re, -self.im)
    }

    #[inline]
    pub fn scale(self, s: f64) -> Cpx {
        Cpx::new(self.re * s, self.im * s)
    }
}

/// View a complex slice as interleaved `(re, im)` `f64`s for the simd
/// complex-multiply kernel.
#[inline]
fn cpx_floats(xs: &[Cpx]) -> &[f64] {
    // SAFETY: Cpx is #[repr(C)] { re: f64, im: f64 } — size 16, align 8,
    // no padding — so n Cpx values are exactly 2n contiguous f64s.
    unsafe { std::slice::from_raw_parts(xs.as_ptr() as *const f64, xs.len() * 2) }
}

/// Mutable variant of [`cpx_floats`].
#[inline]
fn cpx_floats_mut(xs: &mut [Cpx]) -> &mut [f64] {
    // SAFETY: as in cpx_floats; the borrow is exclusive.
    unsafe { std::slice::from_raw_parts_mut(xs.as_mut_ptr() as *mut f64, xs.len() * 2) }
}

/// Elementwise spectrum product `out[k] = a[k] · b[k]` on the simd
/// complex-MAC kernel — the one inner loop of every FFT convolution
/// here (eq. 26's `F{H} · F{U}`); `a` and `b` may be longer than `out`
/// (extra bins are ignored).
fn spectrum_product(a: &[Cpx], b: &[Cpx], out: &mut [Cpx]) {
    let n = out.len();
    simd::cmul(cpx_floats(&a[..n]), cpx_floats(&b[..n]), cpx_floats_mut(out));
}

/// Next power of two >= n (n >= 1).
pub fn next_pow2(n: usize) -> usize {
    n.next_power_of_two()
}

/// Precomputed FFT plan for a fixed power-of-two length.
pub struct Plan {
    n: usize,
    /// `twiddles[s]` holds the n/2 factors for stage with half-size m/2
    twiddles: Vec<Vec<Cpx>>,
    /// conjugates of `twiddles`, stage by stage — precomputed so the
    /// inverse transform runs the identical butterfly kernel with a
    /// different table instead of conjugating per butterfly
    /// (conjugation is an exact sign flip, so the values are the same
    /// bits the old per-butterfly `conj()` produced)
    twiddles_inv: Vec<Vec<Cpx>>,
    bitrev: Vec<usize>,
}

impl Plan {
    pub fn new(n: usize) -> Self {
        assert!(n.is_power_of_two(), "Plan requires power-of-two n, got {n}");
        let levels = n.trailing_zeros() as usize;
        // bit-reversal permutation
        let mut bitrev = vec![0usize; n];
        for i in 0..n {
            bitrev[i] = (i.reverse_bits()) >> (usize::BITS as usize - levels);
        }
        // per-stage twiddles: stage with block size m uses w = exp(-2πi k/m)
        let mut twiddles = Vec::with_capacity(levels);
        let mut m = 2;
        while m <= n {
            let half = m / 2;
            let mut tw = Vec::with_capacity(half);
            for k in 0..half {
                let ang = -2.0 * PI * k as f64 / m as f64;
                tw.push(Cpx::new(ang.cos(), ang.sin()));
            }
            twiddles.push(tw);
            m <<= 1;
        }
        let twiddles_inv =
            twiddles.iter().map(|tw| tw.iter().map(|w| w.conj()).collect()).collect();
        Plan { n, twiddles, twiddles_inv, bitrev }
    }

    pub fn len(&self) -> usize {
        self.n
    }

    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// In-place forward FFT.
    pub fn forward(&self, buf: &mut [Cpx]) {
        self.dispatch(buf, false);
    }

    /// In-place inverse FFT (includes 1/n normalization).
    pub fn inverse(&self, buf: &mut [Cpx]) {
        self.dispatch(buf, true);
        let inv = 1.0 / self.n as f64;
        for v in buf.iter_mut() {
            *v = v.scale(inv);
        }
    }

    fn dispatch(&self, buf: &mut [Cpx], invert: bool) {
        let n = self.n;
        assert_eq!(buf.len(), n, "buffer length {} != plan size {n}", buf.len());
        if n == 1 {
            return;
        }
        // bit-reversal reorder
        for i in 0..n {
            let j = self.bitrev[i];
            if i < j {
                buf.swap(i, j);
            }
        }
        // butterflies: per stage, each block splits into a lo and a hi
        // half and runs the simd butterfly kernel over the interleaved
        // pair views — per complex element the expression is exactly
        // the scalar `b = hi·w; lo' = lo + b; hi' = lo − b`, so the
        // vectorization changes no bits (`rust/tests/simd_equivalence.rs`)
        let tables = if invert { &self.twiddles_inv } else { &self.twiddles };
        let bf = simd::butterfly_kernel(); // resolve the knob once per transform
        let mut m = 2;
        let mut stage = 0;
        while m <= n {
            let half = m / 2;
            let tw = cpx_floats(&tables[stage]);
            for start in (0..n).step_by(m) {
                let (lo, hi) = buf[start..start + m].split_at_mut(half);
                bf(tw, cpx_floats_mut(lo), cpx_floats_mut(hi));
            }
            m <<= 1;
            stage += 1;
        }
    }
}

// lint-src: allow(hashmap) — plan/twiddle caches are get-or-build by key, never iterated
static PLAN_CACHE: OnceLock<RwLock<HashMap<usize, Arc<Plan>>>> = OnceLock::new();
/// post-twiddles w^k = exp(-2pi i k / nfft), k in [0, nfft/2] — shared
/// by rfft_half / irfft_half (recomputing trig per call dominated the
/// half-spectrum savings; see EXPERIMENTS.md §Perf).
// lint-src: allow(hashmap)
static RTWIDDLE_CACHE: OnceLock<RwLock<HashMap<usize, Arc<Vec<Cpx>>>>> = OnceLock::new();

/// Read-mostly lookup in a global keyed cache, building on miss.
fn cached<V: Clone>(
    cache: &OnceLock<RwLock<HashMap<usize, V>>>, // lint-src: allow(hashmap)
    key: usize,
    build: impl FnOnce() -> V,
) -> V {
    let lock = cache.get_or_init(|| RwLock::new(HashMap::new())); // lint-src: allow(hashmap)
    if let Some(v) = lock.read().expect("fft cache poisoned").get(&key) {
        return v.clone();
    }
    let mut map = lock.write().expect("fft cache poisoned");
    map.entry(key).or_insert_with(build).clone()
}

fn rtwiddles(nfft: usize) -> Arc<Vec<Cpx>> {
    cached(&RTWIDDLE_CACHE, nfft, || {
        Arc::new(
            (0..=nfft / 2)
                .map(|k| {
                    let ang = -2.0 * PI * k as f64 / nfft as f64;
                    Cpx::new(ang.cos(), ang.sin())
                })
                .collect(),
        )
    })
}

/// Fetch (or build) the cached plan for a power-of-two length.
pub fn plan(n: usize) -> Arc<Plan> {
    cached(&PLAN_CACHE, n, || Arc::new(Plan::new(n)))
}

/// FFT of a real signal zero-padded to `nfft` (power of two).  The
/// signal must fit: an over-length signal would silently truncate and
/// yield a wrong (aliased) convolution, so it is rejected loudly.
pub fn rfft(signal: &[f32], nfft: usize) -> Vec<Cpx> {
    assert!(
        signal.len() <= nfft,
        "rfft: signal length {} exceeds nfft {nfft} — the tail would be silently dropped",
        signal.len()
    );
    let p = plan(nfft);
    let mut buf = vec![Cpx::ZERO; nfft];
    for (b, &s) in buf.iter_mut().zip(signal.iter()) {
        b.re = s as f64;
    }
    p.forward(&mut buf);
    buf
}

/// Half-spectrum FFT of a real signal via the packed half-size complex
/// transform: pack x[2k] + i·x[2k+1], FFT at nfft/2, then unpack with the
/// split-radix post-twiddle.  ~2× faster than `rfft` (which wastes a full
/// complex transform on a real input).  Returns nfft/2 + 1 bins.
pub fn rfft_half(signal: &[f32], nfft: usize) -> Vec<Cpx> {
    assert!(nfft.is_power_of_two() && nfft >= 2);
    assert!(
        signal.len() <= nfft,
        "rfft_half: signal length {} exceeds nfft {nfft} — the tail would be silently dropped",
        signal.len()
    );
    let half = nfft / 2;
    if half == 1 {
        // nfft == 2: trivial DFT
        let a = *signal.first().unwrap_or(&0.0) as f64;
        let b = *signal.get(1).unwrap_or(&0.0) as f64;
        return vec![Cpx::new(a + b, 0.0), Cpx::new(a - b, 0.0)];
    }
    let p = plan(half);
    let mut buf = vec![Cpx::ZERO; half];
    for k in 0..half {
        let re = signal.get(2 * k).copied().unwrap_or(0.0) as f64;
        let im = signal.get(2 * k + 1).copied().unwrap_or(0.0) as f64;
        buf[k] = Cpx::new(re, im);
    }
    p.forward(&mut buf);
    // unpack: X[k] = E[k] + w^k O[k] with
    //   E[k] = (Z[k] + conj(Z[half-k]))/2, O[k] = -i (Z[k] - conj(Z[half-k]))/2
    // The cross-indexed E/O extraction stays scalar; the post-twiddle
    // multiply-accumulate `E[k] + w^k·O[k]` runs on the simd
    // complex-MAC kernel over the whole half-spectrum at once.
    let tw = rtwiddles(nfft);
    let mut out = vec![Cpx::ZERO; half + 1];
    let mut odd = vec![Cpx::ZERO; half + 1];
    for k in 0..=half {
        let zk = if k == half { buf[0] } else { buf[k] };
        let zc = buf[(half - k) % half].conj();
        out[k] = zk.add(zc).scale(0.5); // E[k]
        let o_times_i = zk.sub(zc).scale(0.5); // = i·O[k]
        odd[k] = Cpx::new(o_times_i.im, -o_times_i.re); // divide by i
    }
    simd::cmul_add(cpx_floats(&tw[..=half]), cpx_floats(&odd), cpx_floats_mut(&mut out));
    out
}

/// Inverse of `rfft_half`: half-spectrum (nfft/2 + 1 bins) -> real signal
/// truncated to `out_len`, via the packed half-size complex inverse.
pub fn irfft_half(spectrum: &[Cpx], nfft: usize, out_len: usize) -> Vec<f32> {
    assert!(nfft.is_power_of_two() && nfft >= 2);
    let half = nfft / 2;
    assert_eq!(spectrum.len(), half + 1, "half-spectrum length");
    if half == 1 {
        let x0 = (spectrum[0].re + spectrum[1].re) * 0.5;
        let x1 = (spectrum[0].re - spectrum[1].re) * 0.5;
        return [x0, x1].iter().take(out_len).map(|&v| v as f32).collect();
    }
    // repack: Z[k] = E[k] + i·O[k] where
    //   E[k] = (X[k] + conj(X[half-k]))/2, O[k] = w^{-k} (X[k] - conj(X[half-k]))/2
    // As in rfft_half the cross-indexed E/diff extraction stays scalar;
    // the `w^{-k}·diff` twiddle runs on the simd conjugated-multiply
    // kernel (conj(w^k)·diff — same expression, no conjugated table).
    let p = plan(half);
    let tw = rtwiddles(nfft);
    let mut evens = vec![Cpx::ZERO; half];
    let mut diffs = vec![Cpx::ZERO; half];
    for k in 0..half {
        let xk = spectrum[k];
        let xc = spectrum[half - k].conj();
        evens[k] = xk.add(xc).scale(0.5);
        diffs[k] = xk.sub(xc).scale(0.5);
    }
    let mut odds = vec![Cpx::ZERO; half];
    simd::conj_cmul(cpx_floats(&tw[..half]), cpx_floats(&diffs), cpx_floats_mut(&mut odds));
    let mut buf = vec![Cpx::ZERO; half];
    for (k, b) in buf.iter_mut().enumerate() {
        let (e, o) = (evens[k], odds[k]);
        // Z[k] = E[k] + i·O[k]
        *b = Cpx::new(e.re - o.im, e.im + o.re);
    }
    p.inverse(&mut buf);
    let mut out = Vec::with_capacity(out_len);
    for k in 0..half {
        if out.len() < out_len {
            out.push(buf[k].re as f32);
        }
        if out.len() < out_len {
            out.push(buf[k].im as f32);
        }
    }
    while out.len() < out_len {
        out.push(0.0);
    }
    out
}

/// Inverse FFT, returning the real part truncated to `out_len`.
pub fn irfft_real(mut spectrum: Vec<Cpx>, out_len: usize) -> Vec<f32> {
    let nfft = spectrum.len();
    let p = plan(nfft);
    p.inverse(&mut spectrum);
    spectrum.iter().take(out_len).map(|c| c.re as f32).collect()
}

/// Causal (linear) convolution of two real sequences, truncated to `out_len`:
/// `out[t] = sum_{j<=t} a[j] b[t-j]`.
pub fn conv_causal(a: &[f32], b: &[f32], out_len: usize) -> Vec<f32> {
    if a.is_empty() || b.is_empty() {
        // an empty operand makes every output sum empty — all zeros
        // (and `a.len() + b.len() - 1` below would underflow)
        return vec![0.0; out_len];
    }
    let need = a.len() + b.len() - 1;
    let nfft = next_pow2(need.max(out_len));
    let fa = rfft(a, nfft);
    let fb = rfft(b, nfft);
    let mut prod = vec![Cpx::ZERO; nfft];
    spectrum_product(&fa, &fb, &mut prod);
    irfft_real(prod, out_len)
}

/// A cached half-spectrum of a fixed real kernel at a fixed FFT size —
/// reused across every convolution with that kernel (the DN's frozen
/// F{H}).  Real-to-real convolutions run entirely in half-spectrum space
/// (§Perf: ~2× over the full complex transform).
pub struct RfftCache {
    pub nfft: usize,
    /// half spectrum: nfft/2 + 1 bins
    pub spectrum: Vec<Cpx>,
}

impl RfftCache {
    pub fn new(kernel: &[f32], nfft: usize) -> Self {
        RfftCache { nfft, spectrum: rfft_half(kernel, nfft) }
    }

    /// Convolve a real signal with the cached kernel, truncated to out_len.
    pub fn conv(&self, signal: &[f32], out_len: usize) -> Vec<f32> {
        assert!(
            signal.len() <= self.nfft,
            "RfftCache::conv: signal length {} exceeds the cache's nfft {} — rebuild the \
             cache at next_pow2(signal_len + kernel_len - 1)",
            signal.len(),
            self.nfft
        );
        let fs = rfft_half(signal, self.nfft);
        self.conv_spectrum(&fs, out_len)
    }

    /// Convolve a precomputed signal half-spectrum with the cached
    /// kernel.  The bin product runs on the simd complex-MAC kernel —
    /// elementwise, so `simd on/off` and every thread count produce the
    /// identical bits.  The signal spectrum must cover all of the
    /// cache's `nfft/2 + 1` bins — a short spectrum means it was built
    /// at a smaller FFT size and the bin-wise product would alias.
    pub fn conv_spectrum(&self, signal_spectrum: &[Cpx], out_len: usize) -> Vec<f32> {
        let bins = self.nfft / 2 + 1;
        assert!(
            signal_spectrum.len() >= bins,
            "RfftCache::conv_spectrum: signal half-spectrum has {} bins but the cache was \
             built at nfft {} ({} bins, kernel spectrum {}) — both spectra must come from \
             the same FFT size",
            signal_spectrum.len(),
            self.nfft,
            bins,
            self.spectrum.len()
        );
        let bins = self.spectrum.len().min(signal_spectrum.len());
        let mut prod = vec![Cpx::ZERO; bins];
        spectrum_product(&self.spectrum, signal_spectrum, &mut prod);
        irfft_half(&prod, self.nfft, out_len)
    }

    /// Convolve many independent signals with the cached kernel, fanning
    /// the rows out across `crate::exec` worker threads (the batched
    /// training path: B·dx independent sequences share one frozen F{H}).
    /// Row order is preserved and each row is the identical serial
    /// computation, so the result is bit-exact at any thread count.
    pub fn conv_batch(&self, signals: &[&[f32]], out_len: usize) -> Vec<Vec<f32>> {
        let plan = exec::plan_for(signals.len(), signals.len() * self.nfft * 16);
        exec::parallel_map(signals.len(), plan, |i| self.conv(signals[i], out_len))
    }
}

/// Naive O(n^2) causal convolution — test oracle.
pub fn conv_causal_naive(a: &[f32], b: &[f32], out_len: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; out_len];
    for (t, o) in out.iter_mut().enumerate() {
        let mut s = 0.0f64;
        for j in 0..=t.min(a.len().saturating_sub(1)) {
            if t - j < b.len() {
                s += a[j] as f64 * b[t - j] as f64;
            }
        }
        *o = s as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn next_pow2_values() {
        assert_eq!(next_pow2(1), 1);
        assert_eq!(next_pow2(2), 2);
        assert_eq!(next_pow2(3), 4);
        assert_eq!(next_pow2(1000), 1024);
    }

    #[test]
    fn fft_of_impulse_is_flat() {
        let p = Plan::new(8);
        let mut buf = vec![Cpx::ZERO; 8];
        buf[0].re = 1.0;
        p.forward(&mut buf);
        for c in &buf {
            assert!((c.re - 1.0).abs() < 1e-12 && c.im.abs() < 1e-12);
        }
    }

    #[test]
    fn fft_roundtrip() {
        let mut rng = Rng::new(0);
        for &n in &[2usize, 8, 64, 256] {
            let p = Plan::new(n);
            let orig: Vec<Cpx> =
                (0..n).map(|_| Cpx::new(rng.normal(), rng.normal())).collect();
            let mut buf = orig.clone();
            p.forward(&mut buf);
            p.inverse(&mut buf);
            for (a, b) in buf.iter().zip(&orig) {
                assert!((a.re - b.re).abs() < 1e-9 && (a.im - b.im).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn fft_matches_dft() {
        let mut rng = Rng::new(1);
        let n = 16;
        let sig: Vec<Cpx> = (0..n).map(|_| Cpx::new(rng.normal(), 0.0)).collect();
        let mut buf = sig.clone();
        Plan::new(n).forward(&mut buf);
        for k in 0..n {
            let mut expect = Cpx::ZERO;
            for (t, s) in sig.iter().enumerate() {
                let ang = -2.0 * PI * (k * t) as f64 / n as f64;
                expect = expect.add(s.mul(Cpx::new(ang.cos(), ang.sin())));
            }
            assert!((buf[k].re - expect.re).abs() < 1e-9);
            assert!((buf[k].im - expect.im).abs() < 1e-9);
        }
    }

    #[test]
    fn parseval_energy_conserved() {
        let mut rng = Rng::new(2);
        let n = 64;
        let sig: Vec<Cpx> = (0..n).map(|_| Cpx::new(rng.normal(), 0.0)).collect();
        let time_energy: f64 = sig.iter().map(|c| c.re * c.re + c.im * c.im).sum();
        let mut buf = sig;
        Plan::new(n).forward(&mut buf);
        let freq_energy: f64 =
            buf.iter().map(|c| c.re * c.re + c.im * c.im).sum::<f64>() / n as f64;
        assert!((time_energy - freq_energy).abs() < 1e-8);
    }

    #[test]
    fn conv_matches_naive() {
        let mut rng = Rng::new(3);
        for &(na, nb) in &[(4usize, 4usize), (16, 7), (100, 100), (33, 129)] {
            let a: Vec<f32> = (0..na).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..nb).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let out_len = na.max(nb);
            let fast = conv_causal(&a, &b, out_len);
            let slow = conv_causal_naive(&a, &b, out_len);
            for (x, y) in fast.iter().zip(&slow) {
                assert!((x - y).abs() < 1e-3, "na={na} nb={nb}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn conv_identity_kernel() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let delta = [1.0f32];
        let out = conv_causal(&a, &delta, 4);
        for (x, y) in out.iter().zip(&a) {
            assert!((x - y).abs() < 1e-6);
        }
    }

    #[test]
    fn conv_shift_kernel_delays() {
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let shift = [0.0f32, 1.0]; // delay by one step
        let out = conv_causal(&a, &shift, 4);
        assert!((out[0]).abs() < 1e-6);
        for t in 1..4 {
            assert!((out[t] - a[t - 1]).abs() < 1e-6);
        }
    }

    #[test]
    fn rfft_cache_reuse_matches_direct() {
        let mut rng = Rng::new(4);
        let kernel: Vec<f32> = (0..32).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let cache = RfftCache::new(&kernel, next_pow2(64));
        for seed in 0..3 {
            let mut r2 = Rng::new(seed);
            let sig: Vec<f32> = (0..32).map(|_| r2.normal_f32(0.0, 1.0)).collect();
            let fast = cache.conv(&sig, 32);
            let slow = conv_causal_naive(&sig, &kernel, 32);
            for (x, y) in fast.iter().zip(&slow) {
                assert!((x - y).abs() < 1e-3);
            }
        }
    }

    #[test]
    #[should_panic(expected = "power-of-two")]
    fn plan_rejects_non_pow2() {
        Plan::new(12);
    }

    #[test]
    fn rfft_half_matches_full() {
        let mut rng = Rng::new(8);
        for &nfft in &[2usize, 4, 16, 128, 512] {
            let sig: Vec<f32> = (0..nfft / 2 + 1).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let full = rfft(&sig, nfft);
            let half = rfft_half(&sig, nfft);
            assert_eq!(half.len(), nfft / 2 + 1);
            for k in 0..=nfft / 2 {
                assert!(
                    (full[k].re - half[k].re).abs() < 1e-9
                        && (full[k].im - half[k].im).abs() < 1e-9,
                    "nfft={nfft} k={k}: {:?} vs {:?}",
                    full[k],
                    half[k]
                );
            }
        }
    }

    #[test]
    fn conv_batch_matches_per_row_conv() {
        let mut rng = Rng::new(12);
        let kernel: Vec<f32> = (0..40).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let cache = RfftCache::new(&kernel, next_pow2(128));
        let rows: Vec<Vec<f32>> =
            (0..9).map(|_| (0..64).map(|_| rng.normal_f32(0.0, 1.0)).collect()).collect();
        let refs: Vec<&[f32]> = rows.iter().map(|r| r.as_slice()).collect();
        let batch = cache.conv_batch(&refs, 64);
        assert_eq!(batch.len(), rows.len());
        for (b, r) in batch.iter().zip(&rows) {
            assert_eq!(b, &cache.conv(r, 64), "batched row differs from serial conv");
        }
    }

    #[test]
    fn plan_cache_shared_across_threads() {
        // the global Arc cache must hand identical plans to worker threads
        let p_main = plan(64);
        // lint-src: allow(thread-spawn) — test needs a raw OS thread, not pool work
        let p_thread = std::thread::spawn(|| plan(64)).join().unwrap();
        assert!(Arc::ptr_eq(&p_main, &p_thread), "plan cache not shared across threads");
    }

    #[test]
    fn irfft_half_roundtrip() {
        let mut rng = Rng::new(9);
        for &nfft in &[4usize, 32, 256] {
            let sig: Vec<f32> = (0..nfft).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let spec = rfft_half(&sig, nfft);
            let back = irfft_half(&spec, nfft, nfft);
            for (a, b) in sig.iter().zip(&back) {
                assert!((a - b).abs() < 1e-5, "nfft={nfft}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn conv_causal_empty_operands_yield_zeros() {
        // empty a, empty b, both empty: no terms in any output sum, so
        // all zeros — and no `a.len() + b.len() - 1` underflow panic
        let sig = [1.0f32, 2.0, 3.0];
        for (a, b) in [(&sig[..], &[][..]), (&[][..], &sig[..]), (&[][..], &[][..])] {
            let out = conv_causal(a, b, 4);
            assert_eq!(out, vec![0.0f32; 4], "a.len()={} b.len()={}", a.len(), b.len());
            assert_eq!(out, conv_causal_naive(a, b, 4));
        }
        // out_len 0 stays fine too
        assert!(conv_causal(&[], &sig, 0).is_empty());
    }

    #[test]
    #[should_panic(expected = "exceeds nfft")]
    fn rfft_rejects_over_length_signal() {
        let sig = vec![1.0f32; 9];
        rfft(&sig, 8);
    }

    #[test]
    #[should_panic(expected = "exceeds nfft")]
    fn rfft_half_rejects_over_length_signal() {
        let sig = vec![1.0f32; 9];
        rfft_half(&sig, 8);
    }

    #[test]
    #[should_panic(expected = "exceeds nfft")]
    fn rfft_half_rejects_over_length_signal_at_nfft_2() {
        // the nfft == 2 trivial-DFT branch must reject too, not
        // silently drop signal[2..]
        rfft_half(&[1.0, 2.0, 3.0], 2);
    }

    #[test]
    #[should_panic(expected = "RfftCache::conv: signal length")]
    fn cache_conv_rejects_over_length_signal() {
        let kernel = [1.0f32, 0.5];
        let cache = RfftCache::new(&kernel, 8);
        let sig = vec![1.0f32; 9];
        cache.conv(&sig, 4);
    }

    #[test]
    #[should_panic(expected = "RfftCache::conv_spectrum")]
    fn conv_spectrum_rejects_short_spectrum() {
        // a spectrum from a smaller FFT size must fail loudly at entry,
        // naming the cache size — not deep inside irfft_half
        let kernel = [1.0f32, 0.5, 0.25];
        let cache = RfftCache::new(&kernel, 16); // 9 bins
        let short = rfft_half(&kernel, 8); // 5 bins
        cache.conv_spectrum(&short, 4);
    }

    #[test]
    fn fit_signals_still_pass_the_length_guards() {
        // the guards must not reject the sizes in-tree callers use:
        // signal length == nfft (exact fit) and shorter
        let sig: Vec<f32> = (0..8).map(|i| i as f32 * 0.5 - 2.0).collect();
        assert_eq!(rfft(&sig, 8).len(), 8);
        assert_eq!(rfft_half(&sig, 8).len(), 5);
        let cache = RfftCache::new(&sig[..4], 8);
        assert_eq!(cache.conv(&sig, 8).len(), 8);
    }

    #[test]
    fn half_spectrum_conv_matches_naive() {
        let mut rng = Rng::new(10);
        for &(na, nb) in &[(16usize, 7usize), (100, 100), (33, 129)] {
            let a: Vec<f32> = (0..na).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let b: Vec<f32> = (0..nb).map(|_| rng.normal_f32(0.0, 1.0)).collect();
            let out_len = na.max(nb);
            let nfft = next_pow2(na + nb - 1);
            let cache = RfftCache::new(&b, nfft);
            let fast = cache.conv(&a, out_len);
            let slow = conv_causal_naive(&a, &b, out_len);
            for (x, y) in fast.iter().zip(&slow) {
                assert!((x - y).abs() < 1e-3, "na={na} nb={nb}: {x} vs {y}");
            }
        }
    }
}
