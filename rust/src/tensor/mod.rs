//! Dense row-major f32 tensors and the numeric kernels the framework is
//! built on.  No BLAS is available offline, so `matmul` carries its own
//! blocked/packed implementation (see `matmul.rs`); everything else is
//! straightforward contiguous-slice arithmetic.
//!
//! Elementwise maps, row-wise softmax, and the 2-D transpose dispatch
//! through `crate::exec` above a size threshold: the output is
//! row-partitioned across the exec pool workers, each element is computed
//! by the identical op sequence as the serial loop, so results are
//! bit-exact at every thread count.
//!
//! Inside each partition block, the arithmetic kernels (add/sub/mul/div,
//! scaling, the bias broadcast, and softmax's max + sum passes) run on
//! the `crate::simd` 8-lane layer.  Elementwise kernels are bit-stable
//! under vectorization by construction; the softmax reductions use the
//! canonical blocked accumulation order shared by the vector and scalar
//! paths, so `simd on/off` changes no bits either
//! (`rust/tests/simd_equivalence.rs`).  Closure-generic [`Tensor::map`]
//! stays scalar; the named nonlinearities (`tanh`, `relu`) route
//! through dedicated `crate::simd` kernels so the fused affine epilogue
//! (`matmul::affine_act`) shares their exact per-element expressions.
//!
//! Tensor **data buffers** come from the size-classed arena installed
//! on the current thread (`crate::exec::arena`), when one is: `zeros`,
//! `full`, `Clone`, and the slicing ops draw buffers from its free
//! lists, and `Drop` returns them — so a steady-state training step
//! allocates no fresh data buffers at all.  Outside an arena scope
//! every path falls through to the plain allocator unchanged.

pub mod matmul;
pub mod packed;

use crate::exec;
use crate::exec::arena;
use crate::simd;
use crate::util::Rng;
use std::fmt;

/// An elementwise activation a fused kernel may apply as its epilogue.
/// The fused and standalone forms share one `crate::simd` kernel per
/// variant, so fusing can never change bits.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Act {
    Tanh,
    Relu,
}

impl Act {
    /// In-place epilogue kernel (resolved once per fused kernel entry).
    #[inline]
    pub fn assign_kernel(self) -> fn(&mut [f32]) {
        match self {
            Act::Tanh => simd::tanh_assign_kernel(),
            Act::Relu => simd::relu_assign_kernel(),
        }
    }
}

/// A dense row-major f32 tensor with a dynamic shape.
#[derive(PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Clone for Tensor {
    fn clone(&self) -> Self {
        Tensor { shape: self.shape.clone(), data: arena::alloc_copy(&self.data) }
    }
}

impl Drop for Tensor {
    fn drop(&mut self) {
        // return the data buffer to this thread's arena (no-op outside
        // an arena scope or for an empty buffer)
        arena::release(std::mem::take(&mut self.data));
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}", self.shape)?;
        if self.data.len() <= 8 {
            write!(f, " {:?}", self.data)
        } else {
            write!(f, " [{:.4}, {:.4}, …, {:.4}]", self.data[0], self.data[1], self.data[self.data.len() - 1])
        }
    }
}

impl Tensor {
    // ----------------------------------------------------------------- ctor

    pub fn new(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {:?} does not match data length {}",
            shape,
            data.len()
        );
        Tensor { shape: shape.to_vec(), data }
    }

    pub fn zeros(shape: &[usize]) -> Self {
        Tensor { shape: shape.to_vec(), data: arena::alloc_zeroed(shape.iter().product()) }
    }

    pub fn ones(shape: &[usize]) -> Self {
        Tensor::full(shape, 1.0)
    }

    pub fn full(shape: &[usize], v: f32) -> Self {
        Tensor { shape: shape.to_vec(), data: arena::alloc_filled(shape.iter().product(), v) }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: arena::alloc_filled(1, v) }
    }

    /// N(0, std) initialization.
    pub fn randn(shape: &[usize], std: f32, rng: &mut Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_normal(&mut t.data, std);
        t
    }

    /// U[lo, hi) initialization.
    pub fn rand_uniform(shape: &[usize], lo: f32, hi: f32, rng: &mut Rng) -> Self {
        let mut t = Tensor::zeros(shape);
        rng.fill_uniform(&mut t.data, lo, hi);
        t
    }

    /// Glorot/Xavier-uniform for a (fan_in, fan_out) weight matrix.
    pub fn glorot(fan_in: usize, fan_out: usize, rng: &mut Rng) -> Self {
        let limit = (6.0 / (fan_in + fan_out) as f32).sqrt();
        Tensor::rand_uniform(&[fan_in, fan_out], -limit, limit, rng)
    }

    /// Orthogonal-ish init for recurrent matrices: scaled Gaussian.
    pub fn recurrent_init(n: usize, rng: &mut Rng) -> Self {
        Tensor::randn(&[n, n], 1.0 / (n as f32).sqrt(), rng)
    }

    pub fn eye(n: usize) -> Self {
        let mut t = Tensor::zeros(&[n, n]);
        for i in 0..n {
            t.data[i * n + i] = 1.0;
        }
        t
    }

    // ------------------------------------------------------------ accessors

    #[inline]
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    #[inline]
    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.data.len()
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    #[inline]
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    #[inline]
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(mut self) -> Vec<f32> {
        // `Drop` forbids moving the field out; take it so the drop sees
        // an empty buffer and the caller owns the Vec.  The buffer
        // leaves arena management here without a `release`, so forget
        // its issue provenance — the identity registry must never map
        // an address the caller will free on their own.
        let data = std::mem::take(&mut self.data);
        arena::untrack(data.as_ptr());
        data
    }

    /// Number of rows / row length, treating the tensor as 2-D
    /// (all-but-last dims collapsed).
    pub fn rows(&self) -> usize {
        if self.shape.is_empty() {
            1
        } else {
            self.shape[..self.shape.len() - 1].iter().product()
        }
    }

    pub fn cols(&self) -> usize {
        *self.shape.last().unwrap_or(&1)
    }

    #[inline]
    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    #[inline]
    pub fn set2(&mut self, i: usize, j: usize, v: f32) {
        debug_assert_eq!(self.ndim(), 2);
        let c = self.shape[1];
        self.data[i * c + j] = v;
    }

    pub fn item(&self) -> f32 {
        assert_eq!(self.data.len(), 1, "item() on non-scalar tensor {:?}", self.shape);
        self.data[0]
    }

    // -------------------------------------------------------------- reshape

    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(
            shape.iter().product::<usize>(),
            self.data.len(),
            "cannot reshape {:?} to {:?}",
            self.shape,
            shape
        );
        self.shape = shape.to_vec();
        self
    }

    pub fn reshaped(&self, shape: &[usize]) -> Self {
        self.clone().reshape(shape)
    }

    /// 2-D transpose (copies).  Parallel over output rows (each output row
    /// gathers one input column), bit-exact at any thread count.
    pub fn transpose2(&self) -> Self {
        assert_eq!(self.ndim(), 2, "transpose2 on {:?}", self.shape);
        let (r, c) = (self.shape[0], self.shape[1]);
        let mut out = Tensor::zeros(&[c, r]);
        if r == 0 || c == 0 {
            return out;
        }
        let plan = exec::plan_for(c, r * c);
        let src = &self.data;
        exec::parallel_rows_mut(&mut out.data, r, plan, |j0, block| {
            for (k, orow) in block.chunks_mut(r).enumerate() {
                let j = j0 + k;
                for (i, o) in orow.iter_mut().enumerate() {
                    *o = src[i * c + j];
                }
            }
        });
        out
    }

    // ---------------------------------------------------------- elementwise

    pub fn map(&self, f: impl Fn(f32) -> f32 + Sync) -> Self {
        let mut out = Tensor::zeros(&self.shape);
        let plan = exec::plan_for(self.data.len(), self.data.len());
        let src = &self.data;
        exec::parallel_rows_mut(&mut out.data, 1, plan, |i0, block| {
            for (dst, &v) in block.iter_mut().zip(&src[i0..i0 + block.len()]) {
                *dst = f(v);
            }
        });
        out
    }

    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32 + Sync) {
        let plan = exec::plan_for(self.data.len(), self.data.len());
        exec::parallel_rows_mut(&mut self.data, 1, plan, |_, block| {
            for v in block.iter_mut() {
                *v = f(*v);
            }
        });
    }

    /// Unary elementwise combinator over a slice kernel: the exec pool
    /// partitions the output, `kernel(src_block, out_block)` runs on
    /// each block.  Block boundaries cannot change bits — every element
    /// is one fixed expression.
    fn map_kernel(&self, kernel: impl Fn(&[f32], &mut [f32]) + Sync) -> Self {
        let mut out = Tensor::zeros(&self.shape);
        let plan = exec::plan_for(self.data.len(), self.data.len());
        let src = &self.data;
        exec::parallel_rows_mut(&mut out.data, 1, plan, |i0, block| {
            kernel(&src[i0..i0 + block.len()], block);
        });
        out
    }

    /// Binary elementwise combinator over a slice kernel: the exec pool
    /// partitions the output, `kernel(a_block, b_block, out_block)` runs
    /// on each block (the simd layer's elementwise entries slot in
    /// directly).  Block boundaries cannot change bits — every element
    /// is one fixed expression.
    fn zip_kernel(&self, other: &Tensor, kernel: impl Fn(&[f32], &[f32], &mut [f32]) + Sync) -> Self {
        assert_eq!(self.shape, other.shape, "elementwise shape mismatch");
        let mut out = Tensor::zeros(&self.shape);
        let plan = exec::plan_for(self.data.len(), self.data.len());
        let (a, b) = (&self.data, &other.data);
        exec::parallel_rows_mut(&mut out.data, 1, plan, |i0, block| {
            kernel(&a[i0..i0 + block.len()], &b[i0..i0 + block.len()], block);
        });
        out
    }

    pub fn add(&self, other: &Tensor) -> Self {
        self.zip_kernel(other, simd::add)
    }

    pub fn sub(&self, other: &Tensor) -> Self {
        self.zip_kernel(other, simd::sub)
    }

    pub fn mul(&self, other: &Tensor) -> Self {
        self.zip_kernel(other, simd::mul)
    }

    pub fn div(&self, other: &Tensor) -> Self {
        self.zip_kernel(other, simd::div)
    }

    pub fn add_assign(&mut self, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        simd::add_assign(&mut self.data, &other.data);
    }

    /// self += alpha * other (axpy).
    pub fn axpy(&mut self, alpha: f32, other: &Tensor) {
        assert_eq!(self.shape, other.shape);
        simd::axpy(alpha, &other.data, &mut self.data);
    }

    pub fn scale(&self, s: f32) -> Self {
        self.map_kernel(|src, out| simd::scale(src, s, out))
    }

    pub fn neg(&self) -> Self {
        self.map(|v| -v)
    }

    /// Broadcast-add a length-`cols` bias vector to every row.
    pub fn add_row(&self, bias: &Tensor) -> Self {
        let c = self.cols();
        assert_eq!(bias.len(), c, "bias length {} != cols {}", bias.len(), c);
        let mut out = self.clone();
        let plan = exec::plan_for(self.rows(), self.data.len());
        let bd = &bias.data;
        exec::parallel_rows_mut(&mut out.data, c, plan, |_, block| {
            for row in block.chunks_mut(c) {
                simd::add_assign(row, bd);
            }
        });
        out
    }

    /// Fused `act((self + other) + bias_row)` in one pass over the
    /// output — the elementwise tail of the LMU output stage
    /// (`add → add_row → tanh`) without materializing the two
    /// intermediates.  Per element this computes exactly the unfused
    /// chain's expression — `simd::add`, then the bias via
    /// `simd::add_assign` (bias on the add's right), then the shared
    /// activation kernel — so fused and unfused are bit-identical.
    pub fn add2_row_act(&self, other: &Tensor, bias: &Tensor, act: Option<Act>) -> Tensor {
        assert_eq!(self.shape, other.shape, "add2_row_act shape mismatch");
        let c = self.cols();
        assert_eq!(bias.len(), c, "bias length {} != cols {}", bias.len(), c);
        let mut out = Tensor::zeros(&self.shape);
        let plan = exec::plan_for(self.rows(), self.data.len() * 3);
        let (a, b, bd) = (&self.data, &other.data, &bias.data);
        let act_assign = act.map(Act::assign_kernel);
        exec::parallel_rows_mut(&mut out.data, c, plan, |r0, block| {
            for (k, orow) in block.chunks_mut(c).enumerate() {
                let o = (r0 + k) * c;
                simd::add(&a[o..o + c], &b[o..o + c], orow);
                simd::add_assign(orow, bd);
                if let Some(f) = act_assign {
                    f(orow);
                }
            }
        });
        out
    }

    /// Fused `act((self + other) + third)` elementwise over three
    /// same-shape tensors — the original LMU cell's recurrent sum
    /// without the two intermediates.  Per element, exactly the unfused
    /// `add → add → act` chain's expressions.
    pub fn add3_act(&self, other: &Tensor, third: &Tensor, act: Option<Act>) -> Tensor {
        assert_eq!(self.shape, other.shape, "add3_act shape mismatch");
        assert_eq!(self.shape, third.shape, "add3_act shape mismatch");
        let mut out = Tensor::zeros(&self.shape);
        let plan = exec::plan_for(self.data.len(), self.data.len() * 3);
        let (a, b, c) = (&self.data, &other.data, &third.data);
        let act_assign = act.map(Act::assign_kernel);
        exec::parallel_rows_mut(&mut out.data, 1, plan, |i0, block| {
            let hi = i0 + block.len();
            simd::add(&a[i0..hi], &b[i0..hi], block);
            simd::add_assign(block, &c[i0..hi]);
            if let Some(f) = act_assign {
                f(block);
            }
        });
        out
    }

    /// `g ⊙ (1 - self²)` with `self = tanh(x)` from the forward pass —
    /// the tanh backward, shared by the standalone `Op::Tanh` and the
    /// fused affine/add epilogues (`simd::tanh_bwd`).
    pub fn tanh_bwd(g: &Tensor, y: &Tensor) -> Tensor {
        g.zip_kernel(y, simd::tanh_bwd)
    }

    /// `g ⊙ (x > 0 ? 1 : 0)` — the relu backward as a mask multiply
    /// (`0 · NaN = NaN` propagates), shared by `Op::Relu` and the fused
    /// epilogues (`simd::relu_bwd`).
    pub fn relu_bwd(g: &Tensor, x: &Tensor) -> Tensor {
        g.zip_kernel(x, simd::relu_bwd)
    }

    // ------------------------------------------------------------ nonlinear

    pub fn tanh(&self) -> Self {
        self.map_kernel(simd::tanh_fwd)
    }

    pub fn sigmoid(&self) -> Self {
        self.map(|v| 1.0 / (1.0 + (-v).exp()))
    }

    /// Relu under the canonical strict-greater rule (`simd::relu_fwd`):
    /// NaN and `-0.0` map to `+0.0`, identical to the fused epilogue.
    pub fn relu(&self) -> Self {
        self.map_kernel(simd::relu_fwd)
    }

    // ----------------------------------------------------------- reductions

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            0.0
        } else {
            self.sum() / self.data.len() as f32
        }
    }

    pub fn abs_max(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
    }

    pub fn sq_norm(&self) -> f32 {
        self.data.iter().map(|&v| v * v).sum()
    }

    /// Row-wise sum: (r, c) -> (c,) summing over rows.
    pub fn sum_rows(&self) -> Tensor {
        let c = self.cols();
        let mut out = Tensor::zeros(&[c]);
        for row in self.data.chunks(c) {
            for (o, v) in out.data.iter_mut().zip(row) {
                *o += v;
            }
        }
        out
    }

    /// Argmax of each row: (r, c) -> Vec of r indices.
    ///
    /// Total over NaN with a deterministic rule (a diverged model must
    /// yield a stable prediction, not a `partial_cmp(..).unwrap()` panic):
    /// NaN never beats a non-NaN value, ties keep the lowest index, and
    /// an all-NaN row yields index 0.
    pub fn argmax_rows(&self) -> Vec<usize> {
        let c = self.cols();
        self.data
            .chunks(c)
            .map(|row| {
                let mut best = 0usize;
                let mut best_v = row[0];
                for (i, &v) in row.iter().enumerate().skip(1) {
                    if v > best_v || (best_v.is_nan() && !v.is_nan()) {
                        best = i;
                        best_v = v;
                    }
                }
                best
            })
            .collect()
    }

    /// Row-wise softmax, numerically stabilized.  Rows are independent, so
    /// the row partition is bit-exact at any thread count.
    ///
    /// The stabilizer max and the normalizer sum run in the canonical
    /// blocked order (`crate::simd`): NaN logits never win the max (a
    /// diverged model still normalizes against a real stabilizer and
    /// the NaN poisons the row through `exp`/`z`, exactly as the old
    /// sequential fold behaved), and `simd on/off` changes no bits.
    pub fn softmax_rows(&self) -> Tensor {
        let c = self.cols();
        let mut out = self.clone();
        let plan = exec::plan_for(self.rows(), self.data.len() * 4);
        exec::parallel_rows_mut(&mut out.data, c, plan, |_, block| {
            for row in block.chunks_mut(c) {
                let mx = simd::max(row);
                for v in row.iter_mut() {
                    *v = (*v - mx).exp();
                }
                let z = simd::sum(row);
                simd::scale_assign(row, 1.0 / z);
            }
        });
        out
    }

    // -------------------------------------------------------------- slicing

    /// Rows [lo, hi) of a 2-D-viewed tensor.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        let c = self.cols();
        assert!(lo <= hi && hi <= self.rows(), "slice [{lo},{hi}) of {} rows", self.rows());
        Tensor::new(&[hi - lo, c], arena::alloc_copy(&self.data[lo * c..hi * c]))
    }

    /// Single row as a (c,) vector.
    pub fn row(&self, i: usize) -> Tensor {
        let c = self.cols();
        Tensor::new(&[c], arena::alloc_copy(&self.data[i * c..(i + 1) * c]))
    }

    /// Concatenate along axis 0 (first dims may differ, rest must match).
    pub fn concat_rows(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let c = parts[0].cols();
        let mut rows = 0;
        for p in parts {
            assert_eq!(p.cols(), c, "concat col mismatch");
            rows += p.rows();
        }
        let mut out = Tensor::zeros(&[rows, c]);
        let mut ofs = 0;
        for p in parts {
            out.data[ofs..ofs + p.data.len()].copy_from_slice(&p.data);
            ofs += p.data.len();
        }
        out
    }

    /// Concatenate along the last axis: all parts (r, c_i) -> (r, sum c_i).
    pub fn concat_cols(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let r = parts[0].rows();
        let total_c: usize = parts.iter().map(|p| p.cols()).sum();
        let mut out = Tensor::zeros(&[r, total_c]);
        let mut ofs = 0;
        for p in parts {
            assert_eq!(p.rows(), r, "concat row mismatch");
            let c = p.cols();
            for i in 0..r {
                out.data[i * total_c + ofs..i * total_c + ofs + c]
                    .copy_from_slice(&p.data[i * c..(i + 1) * c]);
            }
            ofs += c;
        }
        out
    }

    // --------------------------------------------------------------- matmul

    /// 2-D matrix product: (m, k) x (k, n) -> (m, n).
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        matmul::matmul(self, other)
    }

    /// self^T * other: (k, m) x (k, n) -> (m, n) without materializing
    /// the transpose.
    pub fn matmul_tn(&self, other: &Tensor) -> Tensor {
        matmul::matmul_tn(self, other)
    }

    /// self * other^T: (m, k) x (n, k) -> (m, n).
    pub fn matmul_nt(&self, other: &Tensor) -> Tensor {
        matmul::matmul_nt(self, other)
    }

    /// Fused affine: `act(self · other + bias_row)` with the bias add
    /// and activation applied per output row while the matmul tile is
    /// still cache-hot.  Bit-identical to `matmul → add_row → act`.
    pub fn affine_act(&self, other: &Tensor, bias: &Tensor, act: Option<Act>) -> Tensor {
        matmul::affine_act(self, other, bias, act)
    }

    // ----------------------------------------------------------- comparison

    pub fn allclose(&self, other: &Tensor, atol: f32) -> bool {
        self.shape == other.shape
            && self
                .data
                .iter()
                .zip(&other.data)
                .all(|(a, b)| (a - b).abs() <= atol + 1e-5 * b.abs())
    }

    pub fn max_abs_diff(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape);
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_shape() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.shape(), &[2, 3]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert_eq!(t.at2(1, 2), 6.0);
    }

    #[test]
    #[should_panic(expected = "shape")]
    fn bad_shape_panics() {
        Tensor::new(&[2, 2], vec![1.0; 3]);
    }

    #[test]
    fn zeros_ones_eye() {
        assert_eq!(Tensor::zeros(&[3]).sum(), 0.0);
        assert_eq!(Tensor::ones(&[4]).sum(), 4.0);
        let i = Tensor::eye(3);
        assert_eq!(i.sum(), 3.0);
        assert_eq!(i.at2(1, 1), 1.0);
        assert_eq!(i.at2(0, 1), 0.0);
    }

    #[test]
    fn elementwise_ops() {
        let a = Tensor::new(&[2], vec![1., 2.]);
        let b = Tensor::new(&[2], vec![3., 5.]);
        assert_eq!(a.add(&b).data(), &[4., 7.]);
        assert_eq!(b.sub(&a).data(), &[2., 3.]);
        assert_eq!(a.mul(&b).data(), &[3., 10.]);
        assert_eq!(b.div(&a).data(), &[3., 2.5]);
        assert_eq!(a.scale(2.0).data(), &[2., 4.]);
        assert_eq!(a.neg().data(), &[-1., -2.]);
    }

    #[test]
    fn axpy_and_add_assign() {
        let mut a = Tensor::new(&[2], vec![1., 2.]);
        let b = Tensor::new(&[2], vec![10., 20.]);
        a.axpy(0.5, &b);
        assert_eq!(a.data(), &[6., 12.]);
        a.add_assign(&b);
        assert_eq!(a.data(), &[16., 32.]);
    }

    #[test]
    fn add_row_broadcast() {
        let x = Tensor::new(&[2, 3], vec![0.; 6]);
        let b = Tensor::new(&[3], vec![1., 2., 3.]);
        let y = x.add_row(&b);
        assert_eq!(y.data(), &[1., 2., 3., 1., 2., 3.]);
    }

    #[test]
    fn transpose_roundtrip() {
        let mut rng = Rng::new(0);
        let t = Tensor::randn(&[3, 5], 1.0, &mut rng);
        let tt = t.transpose2().transpose2();
        assert!(t.allclose(&tt, 0.0));
    }

    #[test]
    fn reductions() {
        let t = Tensor::new(&[2, 2], vec![1., -2., 3., -4.]);
        assert_eq!(t.sum(), -2.0);
        assert_eq!(t.mean(), -0.5);
        assert_eq!(t.abs_max(), 4.0);
        assert_eq!(t.sq_norm(), 30.0);
        assert_eq!(t.sum_rows().data(), &[4., -6.]);
    }

    #[test]
    fn softmax_rows_normalized() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 1000., 1000., 1000.]);
        let s = t.softmax_rows();
        for row in s.data().chunks(3) {
            let total: f32 = row.iter().sum();
            assert!((total - 1.0).abs() < 1e-5);
        }
        // large-logit row must not produce NaN
        assert!(s.data().iter().all(|v| v.is_finite()));
        assert!((s.at2(1, 0) - 1.0 / 3.0).abs() < 1e-5);
    }

    #[test]
    fn argmax_rows_works() {
        let t = Tensor::new(&[2, 3], vec![1., 5., 3., 9., 0., 2.]);
        assert_eq!(t.argmax_rows(), vec![1, 0]);
    }

    #[test]
    fn argmax_rows_is_total_over_nan() {
        // NaN logits (a diverged model) must not panic and must lose to
        // every non-NaN value; an all-NaN row deterministically yields 0
        let t = Tensor::new(
            &[4, 3],
            vec![
                f32::NAN,
                1.0,
                0.5, // NaN first, real max later
                2.0,
                f32::NAN,
                3.0, // NaN in the middle
                f32::NAN,
                f32::NAN,
                f32::NAN, // all NaN
                -1.0,
                f32::NEG_INFINITY,
                f32::NAN, // -inf beats NaN
            ],
        );
        assert_eq!(t.argmax_rows(), vec![1, 2, 0, 0]);
    }

    #[test]
    fn argmax_rows_ties_take_lowest_index() {
        let t = Tensor::new(&[2, 3], vec![7., 7., 7., 1., 4., 4.]);
        assert_eq!(t.argmax_rows(), vec![0, 1]);
    }

    #[test]
    fn slicing_and_concat() {
        let t = Tensor::new(&[3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let s = t.slice_rows(1, 3);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.data(), &[3., 4., 5., 6.]);
        let r = t.row(0);
        assert_eq!(r.data(), &[1., 2.]);
        let c = Tensor::concat_rows(&[&s, &t.slice_rows(0, 1)]);
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.data(), &[3., 4., 5., 6., 1., 2.]);
    }

    #[test]
    fn concat_cols_interleaves() {
        let a = Tensor::new(&[2, 1], vec![1., 2.]);
        let b = Tensor::new(&[2, 2], vec![3., 4., 5., 6.]);
        let c = Tensor::concat_cols(&[&a, &b]);
        assert_eq!(c.shape(), &[2, 3]);
        assert_eq!(c.data(), &[1., 3., 4., 2., 5., 6.]);
    }

    #[test]
    fn glorot_within_limit() {
        let mut rng = Rng::new(1);
        let w = Tensor::glorot(100, 50, &mut rng);
        let limit = (6.0f32 / 150.0).sqrt();
        assert!(w.abs_max() <= limit);
        assert!(w.abs_max() > limit * 0.8);
    }

    #[test]
    fn nonlinearities() {
        let t = Tensor::new(&[3], vec![-1., 0., 1.]);
        assert_eq!(t.relu().data(), &[0., 0., 1.]);
        let s = t.sigmoid();
        assert!((s.data()[1] - 0.5).abs() < 1e-6);
        let th = t.tanh();
        assert!((th.data()[2] - 0.76159).abs() < 1e-4);
    }
}
