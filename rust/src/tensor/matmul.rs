//! Blocked matrix multiplication.  No BLAS offline, so this is the hot
//! kernel of the native trainer; the layout choices matter:
//!
//!  * `matmul`   — C = A·B with an i-k-j loop order so the inner loop is a
//!    contiguous axpy over B's rows (auto-vectorizes well);
//!  * `matmul_tn`— C = Aᵀ·B without materializing Aᵀ (used by backprop for
//!    weight gradients: dW = Xᵀ·dY);
//!  * `matmul_nt`— C = A·Bᵀ (used by backprop for input gradients:
//!    dX = dY·Wᵀ); inner loop is a dot product of two contiguous rows.
//!
//! Cache blocking over k keeps the working set of B in L1/L2 for large
//! shapes; for the small-to-medium shapes the models use, the simple loop
//! order dominates.
//!
//! All three kernels dispatch through `crate::exec`: the output C is
//! row-partitioned across the exec pool workers, so every thread owns a
//! disjoint contiguous shard of C and no accumulation races exist —
//! including `matmul_tn`, whose rank-1 updates stay race-free because each
//! worker applies the full p-sweep to its own rows only.  Per output
//! element the floating-point operation order is identical to the serial
//! loop, so results are bit-exact at every thread count (pinned by
//! `rust/tests/exec_equivalence.rs`).

use super::Tensor;
use crate::exec;

const KC: usize = 256; // k-panel height (keeps a B panel ~KC*cols*4B in cache)

/// C = A (m,k) · B (k,n)
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = dims2(a, "matmul lhs");
    let (kb, n) = dims2(b, "matmul rhs");
    assert_eq!(k, kb, "matmul inner dims: {:?} x {:?}", a.shape(), b.shape());
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let workers = exec::workers_for(m, m * k * n);
    exec::parallel_rows_mut(c.data_mut(), n, workers, |i0, cblock| {
        matmul_rows(ad, bd, cblock, i0, k, n);
    });
    c
}

/// The serial kernel over one contiguous block of C's rows
/// (`cblock` = rows `i0 ..` of C).
fn matmul_rows(ad: &[f32], bd: &[f32], cblock: &mut [f32], i0: usize, k: usize, n: usize) {
    let rows = if n == 0 { 0 } else { cblock.len() / n };
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        for r in 0..rows {
            let i = i0 + r;
            let crow = &mut cblock[r * n..(r + 1) * n];
            for p in k0..k1 {
                let aip = ad[i * k + p];
                if aip == 0.0 {
                    continue;
                }
                let brow = &bd[p * n..(p + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += aip * bv;
                }
            }
        }
    }
}

/// C = Aᵀ (k,m)ᵀ · B (k,n) -> (m, n)
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = dims2(a, "matmul_tn lhs");
    let (kb, n) = dims2(b, "matmul_tn rhs");
    assert_eq!(k, kb, "matmul_tn inner dims: {:?} x {:?}", a.shape(), b.shape());
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let workers = exec::workers_for(m, m * k * n);
    // Each worker owns rows [i0, i0+rows) of C and scans all k rank-1
    // updates itself: contiguous in B's row, p-ascending per element
    // exactly like the serial p-outer loop.
    exec::parallel_rows_mut(c.data_mut(), n, workers, |i0, cblock| {
        let rows = if n == 0 { 0 } else { cblock.len() / n };
        for p in 0..k {
            let brow = &bd[p * n..(p + 1) * n];
            let arow = &ad[p * m..(p + 1) * m];
            for r in 0..rows {
                let av = arow[i0 + r];
                if av == 0.0 {
                    continue;
                }
                let crow = &mut cblock[r * n..(r + 1) * n];
                for (cv, bv) in crow.iter_mut().zip(brow) {
                    *cv += av * bv;
                }
            }
        }
    });
    c
}

/// C = A (m,k) · Bᵀ (n,k)ᵀ -> (m, n)
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = dims2(a, "matmul_nt lhs");
    let (n, kb) = dims2(b, "matmul_nt rhs");
    assert_eq!(k, kb, "matmul_nt inner dims: {:?} x {:?}", a.shape(), b.shape());
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let workers = exec::workers_for(m, m * k * n);
    exec::parallel_rows_mut(c.data_mut(), n, workers, |i0, cblock| {
        let rows = if n == 0 { 0 } else { cblock.len() / n };
        for r in 0..rows {
            let i = i0 + r;
            let arow = &ad[i * k..(i + 1) * k];
            let crow = &mut cblock[r * n..(r + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &bd[j * k..(j + 1) * k];
                *cv = dot(arow, brow);
            }
        }
    });
    c
}

/// Contiguous dot product, 4-way unrolled for ILP.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 4;
    let (mut s0, mut s1, mut s2, mut s3) = (0.0f32, 0.0f32, 0.0f32, 0.0f32);
    for c in 0..chunks {
        let i = c * 4;
        s0 += a[i] * b[i];
        s1 += a[i + 1] * b[i + 1];
        s2 += a[i + 2] * b[i + 2];
        s3 += a[i + 3] * b[i + 3];
    }
    let mut s = s0 + s1 + s2 + s3;
    for i in chunks * 4..n {
        s += a[i] * b[i];
    }
    s
}

fn dims2(t: &Tensor, what: &str) -> (usize, usize) {
    assert_eq!(t.ndim(), 2, "{what} must be 2-D, got {:?}", t.shape());
    (t.shape()[0], t.shape()[1])
}

/// y = M (m,n) · x (n,)  — matrix-vector product.
pub fn matvec(m: &Tensor, x: &[f32]) -> Vec<f32> {
    let (rows, cols) = dims2(m, "matvec lhs");
    assert_eq!(cols, x.len(), "matvec dims");
    let md = m.data();
    (0..rows).map(|i| dot(&md[i * cols..(i + 1) * cols], x)).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.at2(i, p) * b.at2(p, j);
                }
                c.set2(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_small_known() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 9, 4), (32, 300, 20), (64, 64, 64)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.allclose(&naive(&a, &b), 1e-4), "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_tn_matches_transpose() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(4, 6, 3), (13, 31, 7), (64, 128, 32)] {
            let at = Tensor::randn(&[k, m], 1.0, &mut rng); // A stored transposed
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul_tn(&at, &b);
            let c_ref = matmul(&at.transpose2(), &b);
            assert!(c.allclose(&c_ref, 1e-4), "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_nt_matches_transpose() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(4, 6, 3), (13, 31, 7), (32, 64, 16)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let bt = Tensor::randn(&[n, k], 1.0, &mut rng); // B stored transposed
            let c = matmul_nt(&a, &bt);
            let c_ref = matmul(&a, &bt.transpose2());
            assert!(c.allclose(&c_ref, 1e-4), "({m},{k},{n})");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[5, 5], 1.0, &mut rng);
        assert!(matmul(&a, &Tensor::eye(5)).allclose(&a, 1e-6));
        assert!(matmul(&Tensor::eye(5), &a).allclose(&a, 1e-6));
    }

    #[test]
    fn dot_matches_sum() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let expect: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b), expect);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(4);
        let m = Tensor::randn(&[7, 11], 1.0, &mut rng);
        let x = Tensor::randn(&[11, 1], 1.0, &mut rng);
        let y = matvec(&m, x.data());
        let y_ref = matmul(&m, &x);
        for (a, b) in y.iter().zip(y_ref.data()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn mismatched_dims_panic() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        matmul(&a, &b);
    }

    #[test]
    fn large_shapes_match_naive_above_parallel_threshold() {
        // (129, 67, 65) crosses MIN_PARALLEL_WORK with odd, non-divisible
        // dimensions; the default thread count exercises the parallel path.
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[129, 67], 1.0, &mut rng);
        let b = Tensor::randn(&[67, 65], 1.0, &mut rng);
        assert!(matmul(&a, &b).allclose(&naive(&a, &b), 1e-3));
        let at = Tensor::randn(&[67, 129], 1.0, &mut rng);
        assert!(matmul_tn(&at, &b).allclose(&matmul(&at.transpose2(), &b), 1e-3));
        let bt = Tensor::randn(&[65, 67], 1.0, &mut rng);
        assert!(matmul_nt(&a, &bt).allclose(&matmul(&a, &bt.transpose2()), 1e-3));
    }
}
