//! Blocked matrix multiplication.  No BLAS offline, so this is the hot
//! kernel of the native trainer; the layout choices matter:
//!
//!  * `matmul`   — C = A·B with an i-k-j loop order so the inner loop is a
//!    contiguous axpy over B's rows (explicit 8-lane kernel, see below);
//!  * `matmul_tn`— C = Aᵀ·B without materializing Aᵀ (used by backprop for
//!    weight gradients: dW = Xᵀ·dY);
//!  * `matmul_nt`— C = A·Bᵀ (used by backprop for input gradients:
//!    dX = dY·Wᵀ); inner loop is a dot product of two contiguous rows.
//!
//! Cache blocking over k keeps the working set of B in L1/L2 for large
//! shapes; for the small-to-medium shapes the models use, the simple loop
//! order dominates.
//!
//! All three kernels dispatch through `crate::exec`: the output C is
//! row-partitioned into work-stealing chunks, so every chunk owns a
//! disjoint contiguous shard of C and no accumulation races exist —
//! including `matmul_tn`, whose rank-1 updates stay race-free because each
//! chunk applies the full p-sweep to its own rows only.  Per output
//! element the floating-point operation order is identical to the serial
//! loop, so results are bit-exact at every thread count (pinned by
//! `rust/tests/exec_equivalence.rs`).
//!
//! Non-finite propagation: `matmul` and `matmul_tn` skip zero entries of
//! A (a cheap sparsity win for one-hot-ish operands), but `0 · NaN` and
//! `0 · ±Inf` must still produce `NaN` like the naive triple loop.  The
//! skip is therefore gated on a one-pass "B is entirely finite" scan —
//! when B is finite the skip is bit-exact (the accumulator starts at
//! `+0.0` and can never become `-0.0`, so adding `±0.0` is the identity),
//! and when B carries any NaN/Inf the skip is disabled so propagation
//! matches the naive reference exactly.  Both the scan
//! (`simd::all_finite`) and the gated inner axpy (`GatedAxpy`) live
//! in exactly one place, shared by `matmul` and `matmul_tn`, so the
//! SIMD and scalar paths cannot drift apart.
//!
//! The inner loops run on the `crate::simd` 8-lane kernel layer:
//! the gated axpy is elementwise (bit-identical however it vectorizes)
//! and [`dot`] uses the canonical blocked accumulation order, so
//! `simd on/off` changes no bits anywhere in this file
//! (`rust/tests/simd_equivalence.rs`).
//!
//! `PLMU_GEMM=packed` swaps the chunk bodies of `matmul`, `matmul_tn`,
//! `matmul_nt`, and `affine_act` for the BLIS-style packed micro-kernel
//! in `tensor::packed` — same exec row partition, same per-element
//! operation chains, bit-identical output (the module docs over there
//! carry the argument).  `matvec` stays on the dot kernel: its rows are
//! single dot products with nothing to pack.

use super::packed::{self, GemmPath};
use super::{Act, Tensor};
use crate::exec;
use crate::simd;

// k-panel height (keeps a B panel ~KC*cols*4B in cache); shared with the
// packed path so both walk identical k-panels
pub(crate) const KC: usize = 256;

/// C = A (m,k) · B (k,n)
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = dims2(a, "matmul lhs");
    let (kb, n) = dims2(b, "matmul rhs");
    assert_eq!(k, kb, "matmul inner dims: {:?} x {:?}", a.shape(), b.shape());
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let plan = exec::plan_for(m, m * k * n);
    if packed::gemm_path() == GemmPath::Packed {
        exec::parallel_rows_mut(c.data_mut(), n, plan, |i0, cblock| {
            packed::gemm_rows(ad, bd, cblock, i0, k, n, m, false);
        });
    } else {
        let gate = GatedAxpy::new(bd);
        exec::parallel_rows_mut(c.data_mut(), n, plan, |i0, cblock| {
            matmul_rows(ad, bd, cblock, i0, k, n, gate);
        });
    }
    c
}

/// The serial kernel over one contiguous block of C's rows
/// (`cblock` = rows `i0 ..` of C).
fn matmul_rows(ad: &[f32], bd: &[f32], cblock: &mut [f32], i0: usize, k: usize, n: usize, gate: GatedAxpy) {
    let rows = if n == 0 { 0 } else { cblock.len() / n };
    for k0 in (0..k).step_by(KC) {
        let k1 = (k0 + KC).min(k);
        for r in 0..rows {
            let i = i0 + r;
            let crow = &mut cblock[r * n..(r + 1) * n];
            for p in k0..k1 {
                gate.apply(ad[i * k + p], &bd[p * n..(p + 1) * n], crow);
            }
        }
    }
}

/// The one shared inner kernel of `matmul` and `matmul_tn`:
/// `crow += a * brow`, with the finiteness-gated zero skip hoisted here
/// so the skip logic exists exactly once — the SIMD and scalar axpy
/// paths sit behind it and cannot drift from each other.  Both the
/// finiteness scan and the `PLMU_SIMD` dispatch resolve ONCE, at kernel
/// entry, so the inner rank-1 loop pays neither.
///
/// The skip is bit-exact for finite B (adding `a · brow = ±0.0` to an
/// accumulator that can never be `-0.0` is the identity); construction
/// disables the skip whenever B carries NaN/Inf so `0 · NaN` propagates
/// exactly like the naive reference.
#[derive(Clone, Copy)]
struct GatedAxpy {
    /// zero-skip soundness: true iff B is entirely finite
    skip_zeros: bool,
    /// the resolved simd axpy path (vector or scalar reference)
    axpy: fn(f32, &[f32], &mut [f32]),
}

impl GatedAxpy {
    fn new(b: &[f32]) -> Self {
        GatedAxpy { skip_zeros: simd::all_finite(b), axpy: simd::axpy_kernel() }
    }

    #[inline]
    fn apply(&self, a: f32, brow: &[f32], crow: &mut [f32]) {
        if a == 0.0 && self.skip_zeros {
            return;
        }
        (self.axpy)(a, brow, crow);
    }
}

/// C = act(A (m,k) · B (k,n) + bias (n,)) — the fused affine kernel.
///
/// Identical to [`matmul`] through the k sweep; once a row of C has
/// seen its last k panel (the row loop sits inside the same chunk
/// closure, so the block is still cache-hot), the epilogue adds the
/// bias broadcast (`simd::add_assign`, bias on the add's right — the
/// exact `Tensor::add_row` expression) and applies the optional
/// activation through the same shared `crate::simd` kernel the
/// standalone op uses.  Per element nothing differs from
/// `matmul → add_row → act`, so fused and unfused are bit-identical;
/// what changes is memory traffic — the two intermediate (m, n)
/// tensors are never materialized.
pub fn affine_act(a: &Tensor, b: &Tensor, bias: &Tensor, act: Option<Act>) -> Tensor {
    let (m, k) = dims2(a, "affine lhs");
    let (kb, n) = dims2(b, "affine rhs");
    assert_eq!(k, kb, "affine inner dims: {:?} x {:?}", a.shape(), b.shape());
    assert_eq!(bias.len(), n, "affine bias length {} != cols {n}", bias.len());
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd, biasd) = (a.data(), b.data(), bias.data());
    // resolve both knobs once; the gate's finiteness scan only runs when
    // the axpy path (the only consumer of the skip) is selected
    let gate = match packed::gemm_path() {
        GemmPath::Axpy => Some(GatedAxpy::new(bd)),
        GemmPath::Packed => None,
    };
    let act_assign = act.map(Act::assign_kernel); // resolve the knob once
    let plan = exec::plan_for(m, m * k * n);
    exec::parallel_rows_mut(c.data_mut(), n, plan, |i0, cblock| {
        match gate {
            Some(g) => matmul_rows(ad, bd, cblock, i0, k, n, g),
            None => packed::gemm_rows(ad, bd, cblock, i0, k, n, m, false),
        }
        if n > 0 {
            for crow in cblock.chunks_mut(n) {
                simd::add_assign(crow, biasd);
                if let Some(f) = act_assign {
                    f(crow);
                }
            }
        }
    });
    c
}

/// C = Aᵀ (k,m)ᵀ · B (k,n) -> (m, n)
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (k, m) = dims2(a, "matmul_tn lhs");
    let (kb, n) = dims2(b, "matmul_tn rhs");
    assert_eq!(k, kb, "matmul_tn inner dims: {:?} x {:?}", a.shape(), b.shape());
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let plan = exec::plan_for(m, m * k * n);
    if packed::gemm_path() == GemmPath::Packed {
        // the packed A panel reads A column-major ((k, m) layout), which
        // is exactly matmul_tn's storage — tn = true selects that gather
        exec::parallel_rows_mut(c.data_mut(), n, plan, |i0, cblock| {
            packed::gemm_rows(ad, bd, cblock, i0, k, n, m, true);
        });
        return c;
    }
    let gate = GatedAxpy::new(bd);
    // Each chunk owns rows [i0, i0+rows) of C and scans all k rank-1
    // updates itself: contiguous in B's row, p-ascending per element
    // exactly like the serial p-outer loop.
    exec::parallel_rows_mut(c.data_mut(), n, plan, |i0, cblock| {
        let rows = if n == 0 { 0 } else { cblock.len() / n };
        for p in 0..k {
            let brow = &bd[p * n..(p + 1) * n];
            let arow = &ad[p * m..(p + 1) * m];
            for r in 0..rows {
                gate.apply(arow[i0 + r], brow, &mut cblock[r * n..(r + 1) * n]);
            }
        }
    });
    c
}

/// C = A (m,k) · Bᵀ (n,k)ᵀ -> (m, n)
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = dims2(a, "matmul_nt lhs");
    let (n, kb) = dims2(b, "matmul_nt rhs");
    assert_eq!(k, kb, "matmul_nt inner dims: {:?} x {:?}", a.shape(), b.shape());
    let mut c = Tensor::zeros(&[m, n]);
    let (ad, bd) = (a.data(), b.data());
    let plan = exec::plan_for(m, m * k * n);
    if packed::gemm_path() == GemmPath::Packed {
        // register-blocks 8 columns of dots; each column's chain is the
        // canonical blocked dot, so per element nothing differs
        exec::parallel_rows_mut(c.data_mut(), n, plan, |i0, cblock| {
            packed::gemm_nt_rows(ad, bd, cblock, i0, k, n);
        });
        return c;
    }
    let dot_k = simd::dot_kernel(); // resolve the knob once, not per element
    exec::parallel_rows_mut(c.data_mut(), n, plan, |i0, cblock| {
        let rows = if n == 0 { 0 } else { cblock.len() / n };
        for r in 0..rows {
            let i = i0 + r;
            let arow = &ad[i * k..(i + 1) * k];
            let crow = &mut cblock[r * n..(r + 1) * n];
            for (j, cv) in crow.iter_mut().enumerate() {
                let brow = &bd[j * k..(j + 1) * k];
                *cv = dot_k(arow, brow);
            }
        }
    });
    c
}

/// Contiguous dot product in the canonical 8-lane blocked accumulation
/// order (see `crate::simd`): eight accumulators, element `i` folds into
/// lane `i % 8`, one fixed horizontal reduction tree.  Identical bits
/// whether the vector or scalar path runs.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    simd::dot(a, b)
}

fn dims2(t: &Tensor, what: &str) -> (usize, usize) {
    assert_eq!(t.ndim(), 2, "{what} must be 2-D, got {:?}", t.shape());
    (t.shape()[0], t.shape()[1])
}

/// y = M (m,n) · x (n,)  — matrix-vector product, the RNN-mode streaming
/// inference hot path.  Output rows are independent dot products, so the
/// row range dispatches through the exec pool like every other kernel;
/// per element the op order is the untouched serial [`dot`], so results
/// are bit-exact at every thread count.
pub fn matvec(m: &Tensor, x: &[f32]) -> Vec<f32> {
    let (rows, cols) = dims2(m, "matvec lhs");
    assert_eq!(cols, x.len(), "matvec dims");
    let md = m.data();
    let mut y = vec![0.0f32; rows];
    let dot_k = simd::dot_kernel(); // resolve the knob once, not per row
    let plan = exec::plan_for(rows, 2 * rows * cols);
    exec::parallel_rows_mut(&mut y, 1, plan, |i0, block| {
        for (r, o) in block.iter_mut().enumerate() {
            let i = i0 + r;
            *o = dot_k(&md[i * cols..(i + 1) * cols], x);
        }
    });
    y
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn naive(a: &Tensor, b: &Tensor) -> Tensor {
        let (m, k) = (a.shape()[0], a.shape()[1]);
        let n = b.shape()[1];
        let mut c = Tensor::zeros(&[m, n]);
        for i in 0..m {
            for j in 0..n {
                let mut s = 0.0;
                for p in 0..k {
                    s += a.at2(i, p) * b.at2(p, j);
                }
                c.set2(i, j, s);
            }
        }
        c
    }

    #[test]
    fn matmul_small_known() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]);
        let b = Tensor::new(&[2, 2], vec![1., 1., 1., 1.]);
        let c = matmul(&a, &b);
        assert_eq!(c.data(), &[3., 3., 7., 7.]);
    }

    #[test]
    fn matmul_matches_naive() {
        let mut rng = Rng::new(0);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 9, 4), (32, 300, 20), (64, 64, 64)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul(&a, &b);
            assert!(c.allclose(&naive(&a, &b), 1e-4), "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_tn_matches_transpose() {
        let mut rng = Rng::new(1);
        for &(m, k, n) in &[(4, 6, 3), (13, 31, 7), (64, 128, 32)] {
            let at = Tensor::randn(&[k, m], 1.0, &mut rng); // A stored transposed
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let c = matmul_tn(&at, &b);
            let c_ref = matmul(&at.transpose2(), &b);
            assert!(c.allclose(&c_ref, 1e-4), "({m},{k},{n})");
        }
    }

    #[test]
    fn matmul_nt_matches_transpose() {
        let mut rng = Rng::new(2);
        for &(m, k, n) in &[(4, 6, 3), (13, 31, 7), (32, 64, 16)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let bt = Tensor::randn(&[n, k], 1.0, &mut rng); // B stored transposed
            let c = matmul_nt(&a, &bt);
            let c_ref = matmul(&a, &bt.transpose2());
            assert!(c.allclose(&c_ref, 1e-4), "({m},{k},{n})");
        }
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Rng::new(3);
        let a = Tensor::randn(&[5, 5], 1.0, &mut rng);
        assert!(matmul(&a, &Tensor::eye(5)).allclose(&a, 1e-6));
        assert!(matmul(&Tensor::eye(5), &a).allclose(&a, 1e-6));
    }

    #[test]
    fn dot_matches_sum() {
        let a: Vec<f32> = (0..13).map(|i| i as f32).collect();
        let b: Vec<f32> = (0..13).map(|i| (i * 2) as f32).collect();
        let expect: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert_eq!(dot(&a, &b), expect);
    }

    #[test]
    fn matvec_matches_matmul() {
        let mut rng = Rng::new(4);
        let m = Tensor::randn(&[7, 11], 1.0, &mut rng);
        let x = Tensor::randn(&[11, 1], 1.0, &mut rng);
        let y = matvec(&m, x.data());
        let y_ref = matmul(&m, &x);
        for (a, b) in y.iter().zip(y_ref.data()) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn matvec_large_matches_serial_dots() {
        // large enough to cross the exec threshold: the parallel path must
        // be bit-identical to per-row serial dot products
        let mut rng = Rng::new(6);
        let (r, c) = (300usize, 101usize);
        let m = Tensor::randn(&[r, c], 1.0, &mut rng);
        let xv: Vec<f32> = (0..c).map(|_| rng.normal_f32(0.0, 1.0)).collect();
        let y = matvec(&m, &xv);
        for i in 0..r {
            let want = dot(&m.data()[i * c..(i + 1) * c], &xv);
            assert!(y[i].to_bits() == want.to_bits(), "row {i}");
        }
    }

    /// Naive reference on data that may contain NaN/Inf: the kernels must
    /// propagate non-finite values exactly like the plain triple loop.
    #[test]
    fn non_finite_in_b_propagates_through_zero_entries_of_a() {
        // A holds explicit zeros exactly where the old unconditional
        // zero-skip would have dropped B's NaN/Inf contribution
        let a = Tensor::new(&[2, 3], vec![0.0, 1.0, 0.0, 0.0, 0.0, 2.0]);
        let mut bdata = vec![1.0f32; 3 * 2];
        bdata[0] = f32::NAN; // B[0,0]
        bdata[5] = f32::INFINITY; // B[2,1]
        let b = Tensor::new(&[3, 2], bdata);
        let c = matmul(&a, &b);
        let r = naive(&a, &b);
        for (i, (x, y)) in c.data().iter().zip(r.data()).enumerate() {
            assert!(
                x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()),
                "matmul elem {i}: {x} vs naive {y}"
            );
        }
        // C[0,0] = 0*NaN + 1*1 + 0*1 -> NaN; C[1,1] = 0 + 0 + 2*Inf -> Inf
        assert!(c.at2(0, 0).is_nan(), "0 * NaN was silently dropped");
        assert!(c.at2(1, 1).is_infinite());

        // same for the transposed kernel (A stored as (k, m))
        let at = a.transpose2();
        let c_tn = matmul_tn(&at, &b);
        for (i, (x, y)) in c_tn.data().iter().zip(r.data()).enumerate() {
            assert!(
                x.to_bits() == y.to_bits() || (x.is_nan() && y.is_nan()),
                "matmul_tn elem {i}: {x} vs naive {y}"
            );
        }
    }

    #[test]
    fn finite_b_keeps_zero_skip_bit_exact() {
        // with finite B, the zero-skip path must be bit-identical to the
        // naive reference even for A dense in zeros (incl. -0.0)
        let mut rng = Rng::new(7);
        let mut a = Tensor::randn(&[9, 13], 1.0, &mut rng);
        for (i, v) in a.data_mut().iter_mut().enumerate() {
            if i % 3 == 0 {
                *v = 0.0;
            }
            if i % 7 == 0 {
                *v = -0.0;
            }
        }
        let b = Tensor::randn(&[13, 5], 1.0, &mut rng);
        let c = matmul(&a, &b);
        let r = naive(&a, &b);
        for (x, y) in c.data().iter().zip(r.data()) {
            assert!(x.to_bits() == y.to_bits(), "{x} vs {y}");
        }
    }

    #[test]
    fn affine_act_bit_equal_to_unfused_chain() {
        let mut rng = Rng::new(8);
        for &(m, k, n) in &[(1, 1, 1), (3, 5, 7), (17, 9, 4), (33, 300, 31), (64, 64, 64)] {
            let a = Tensor::randn(&[m, k], 1.0, &mut rng);
            let b = Tensor::randn(&[k, n], 1.0, &mut rng);
            let bias = Tensor::randn(&[n], 1.0, &mut rng);
            for act in [None, Some(Act::Tanh), Some(Act::Relu)] {
                let fused = affine_act(&a, &b, &bias, act);
                let mut unfused = matmul(&a, &b).add_row(&bias);
                unfused = match act {
                    Some(Act::Tanh) => unfused.tanh(),
                    Some(Act::Relu) => unfused.relu(),
                    None => unfused,
                };
                for (i, (x, y)) in fused.data().iter().zip(unfused.data()).enumerate() {
                    assert!(
                        x.to_bits() == y.to_bits(),
                        "({m},{k},{n}) act {act:?} elem {i}: {x} vs {y}"
                    );
                }
            }
        }
    }

    #[test]
    fn affine_act_propagates_non_finite_like_unfused() {
        // NaN/Inf entering through A, B, or the bias must flow through
        // the fused epilogue exactly as through the unfused chain
        let a = Tensor::new(&[2, 3], vec![0.0, 1.0, 0.0, 0.5, f32::NAN, 2.0]);
        let mut bdata = vec![1.0f32; 3 * 2];
        bdata[0] = f32::NAN;
        let b = Tensor::new(&[3, 2], bdata);
        let bias = Tensor::new(&[2], vec![f32::INFINITY, -1.0]);
        for act in [None, Some(Act::Tanh), Some(Act::Relu)] {
            let fused = affine_act(&a, &b, &bias, act);
            let mut unfused = matmul(&a, &b).add_row(&bias);
            unfused = match act {
                Some(Act::Tanh) => unfused.tanh(),
                Some(Act::Relu) => unfused.relu(),
                None => unfused,
            };
            for (i, (x, y)) in fused.data().iter().zip(unfused.data()).enumerate() {
                assert!(
                    x.to_bits() == y.to_bits(),
                    "act {act:?} elem {i}: {x} vs {y}"
                );
            }
        }
    }

    /// Degenerate GEMM shapes (m == 0, n == 0, k == 0) across every
    /// entry point: the output must exist with the right shape and,
    /// where it has elements (k == 0), be exactly +0.0 / the bias.
    /// Direct calls into the packed kernels cover the same degenerate
    /// cases without flipping the global knob.
    #[test]
    fn degenerate_shapes_yield_empty_or_zero_outputs() {
        for &(m, k, n) in &[(0usize, 3usize, 4usize), (2, 0, 3), (3, 4, 0), (0, 0, 0)] {
            let a = Tensor::zeros(&[m, k]);
            let b = Tensor::zeros(&[k, n]);
            let c = matmul(&a, &b);
            assert_eq!(c.shape(), &[m, n], "matmul ({m},{k},{n})");
            assert!(c.data().iter().all(|v| v.to_bits() == 0), "matmul ({m},{k},{n})");

            let at = Tensor::zeros(&[k, m]);
            let c_tn = matmul_tn(&at, &b);
            assert_eq!(c_tn.shape(), &[m, n], "matmul_tn ({m},{k},{n})");
            assert!(c_tn.data().iter().all(|v| v.to_bits() == 0));

            let bt = Tensor::zeros(&[n, k]);
            let c_nt = matmul_nt(&a, &bt);
            assert_eq!(c_nt.shape(), &[m, n], "matmul_nt ({m},{k},{n})");
            assert!(c_nt.data().iter().all(|v| v.to_bits() == 0));

            let bias = Tensor::new(&[n], (0..n).map(|j| j as f32 + 1.0).collect());
            let c_aa = affine_act(&a, &b, &bias, Some(Act::Relu));
            assert_eq!(c_aa.shape(), &[m, n], "affine_act ({m},{k},{n})");
            for row in c_aa.data().chunks(n.max(1)) {
                for (j, v) in row.iter().enumerate() {
                    assert_eq!(*v, j as f32 + 1.0, "affine_act bias row ({m},{k},{n})");
                }
            }

            // packed kernels, called directly on the degenerate blocks
            let mut cp = vec![0.0f32; m * n];
            packed::gemm_rows(a.data(), b.data(), &mut cp, 0, k, n, m, false);
            assert!(cp.iter().all(|v| v.to_bits() == 0));
            packed::gemm_rows(at.data(), b.data(), &mut cp, 0, k, n, m, true);
            assert!(cp.iter().all(|v| v.to_bits() == 0));
            packed::gemm_nt_rows(a.data(), bt.data(), &mut cp, 0, k, n);
            assert!(cp.iter().all(|v| v.to_bits() == 0));
        }
        // matvec degenerate: zero rows and zero cols
        let y = matvec(&Tensor::zeros(&[0, 5]), &[1.0; 5]);
        assert!(y.is_empty());
        let y = matvec(&Tensor::zeros(&[4, 0]), &[]);
        assert_eq!(y, vec![0.0; 4]);
    }

    /// The packed kernels, called directly (no knob flip — the lib test
    /// binary runs tests concurrently), must be bit-identical to the
    /// axpy entry points on ragged shapes that exercise every tile
    /// remainder, including zero-dense A and non-finite B (the packed
    /// path has no zero-skip, so it must match both gate outcomes).
    #[test]
    fn packed_kernels_bit_equal_to_axpy_entry_points() {
        let mut rng = Rng::new(9);
        for &(m, k, n) in &[(1, 1, 1), (7, 9, 8), (8, 256, 16), (9, 257, 17), (16, 300, 33)] {
            for salt in [false, true] {
                let mut a = Tensor::randn(&[m, k], 1.0, &mut rng);
                let mut b = Tensor::randn(&[k, n], 1.0, &mut rng);
                for (i, v) in a.data_mut().iter_mut().enumerate() {
                    if i % 3 == 0 {
                        *v = 0.0;
                    }
                }
                if salt {
                    let bl = b.len();
                    b.data_mut()[0] = f32::NAN;
                    b.data_mut()[bl - 1] = f32::INFINITY;
                }

                let c_ref = matmul(&a, &b);
                let mut cp = vec![0.0f32; m * n];
                packed::gemm_rows(a.data(), b.data(), &mut cp, 0, k, n, m, false);
                for (i, (x, y)) in cp.iter().zip(c_ref.data()).enumerate() {
                    assert!(x.to_bits() == y.to_bits(), "matmul ({m},{k},{n}) salt {salt} elem {i}: {x} vs {y}");
                }

                let at = a.transpose2();
                let c_tn_ref = matmul_tn(&at, &b);
                cp.iter_mut().for_each(|v| *v = 0.0);
                packed::gemm_rows(at.data(), b.data(), &mut cp, 0, k, n, m, true);
                for (i, (x, y)) in cp.iter().zip(c_tn_ref.data()).enumerate() {
                    assert!(x.to_bits() == y.to_bits(), "matmul_tn ({m},{k},{n}) salt {salt} elem {i}: {x} vs {y}");
                }

                let bt = b.transpose2();
                let c_nt_ref = matmul_nt(&a, &bt);
                cp.iter_mut().for_each(|v| *v = 0.0);
                packed::gemm_nt_rows(a.data(), bt.data(), &mut cp, 0, k, n);
                for (i, (x, y)) in cp.iter().zip(c_nt_ref.data()).enumerate() {
                    assert!(x.to_bits() == y.to_bits(), "matmul_nt ({m},{k},{n}) salt {salt} elem {i}: {x} vs {y}");
                }
            }
        }
    }

    /// Chunked packed calls (the exec sharding pattern: disjoint row
    /// blocks with their own pack buffers) must agree bit-for-bit with
    /// one whole-matrix call — the thread count cannot change bytes.
    #[test]
    fn packed_chunks_match_whole_matrix_call() {
        let mut rng = Rng::new(10);
        let (m, k, n) = (13usize, 37usize, 21usize);
        let a = Tensor::randn(&[m, k], 1.0, &mut rng);
        let b = Tensor::randn(&[k, n], 1.0, &mut rng);
        let mut whole = vec![0.0f32; m * n];
        packed::gemm_rows(a.data(), b.data(), &mut whole, 0, k, n, m, false);
        for split in [1usize, 5, 8, 12] {
            let mut chunked = vec![0.0f32; m * n];
            let (lo, hi) = chunked.split_at_mut(split * n);
            packed::gemm_rows(a.data(), b.data(), lo, 0, k, n, m, false);
            packed::gemm_rows(a.data(), b.data(), hi, split, k, n, m, false);
            for (i, (x, y)) in chunked.iter().zip(&whole).enumerate() {
                assert!(x.to_bits() == y.to_bits(), "split {split} elem {i}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "inner dims")]
    fn mismatched_dims_panic() {
        let a = Tensor::zeros(&[2, 3]);
        let b = Tensor::zeros(&[4, 2]);
        matmul(&a, &b);
    }

    #[test]
    fn large_shapes_match_naive_above_parallel_threshold() {
        // (129, 67, 65) crosses MIN_PARALLEL_WORK with odd, non-divisible
        // dimensions; the default thread count exercises the parallel path.
        let mut rng = Rng::new(5);
        let a = Tensor::randn(&[129, 67], 1.0, &mut rng);
        let b = Tensor::randn(&[67, 65], 1.0, &mut rng);
        assert!(matmul(&a, &b).allclose(&naive(&a, &b), 1e-3));
        let at = Tensor::randn(&[67, 129], 1.0, &mut rng);
        assert!(matmul_tn(&at, &b).allclose(&matmul(&at.transpose2(), &b), 1e-3));
        let bt = Tensor::randn(&[65, 67], 1.0, &mut rng);
        assert!(matmul_nt(&a, &bt).allclose(&matmul(&a, &bt.transpose2()), 1e-3));
    }
}
