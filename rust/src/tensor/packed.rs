//! BLIS-style packed GEMM path behind the `PLMU_GEMM` knob.
//!
//! The axpy kernels in `tensor/matmul.rs` are row-sharded but untiled:
//! every rank-1 update streams a full row of B through cache and each
//! output element is touched `k` times from memory.  This module packs
//! operand panels once per job chunk and runs an `MR × NR` register
//! micro-kernel over them, BLIS-style:
//!
//!  * B's k-panel (`KC` rows) is repacked into width-[`NR`] column
//!    tiles, so the micro-kernel's B loads are contiguous and the tile
//!    stays in L1 across all of the chunk's row panels;
//!  * A's `MR`-row micro-panel is repacked p-major (`ap[p·MR + r]`),
//!    so the per-p broadcast reads are contiguous;
//!  * the micro-kernel holds an `MR`-row × `NR`-column tile of C in
//!    [`F32x8`] registers ([`MR`] accumulators) and folds the whole
//!    k-panel into it with one splat·load multiply-add per (p, row).
//!
//! # Why bit-exactness survives the tiling
//!
//! Lane `j` of accumulator `r` holds `C[i0+r0+r, j0+j]` and the p loop
//! performs `acc += splat(A[i,p]) · B[p, j0..]` — multiply then add,
//! accumulator on the add's left, p ascending.  That is *per element*
//! the identical sequential chain the axpy path writes as
//! `crow[j] += a[i,p] * b[p,j]`: same k-panel order (both use [`KC`]),
//! same expression, no horizontal reduction anywhere, so no
//! reassociation exists to change bits.  The tile's round-trips through
//! memory between k-panels are exact, and the axpy path's
//! finiteness-gated zero-skip is bit-invisible by the same argument
//! that makes it sound there (adding `a·b = ±0.0` to an accumulator
//! that can never be `-0.0` is the identity; with non-finite B the
//! axpy path disables the skip and performs every add, exactly like
//! this path always does).  `matmul_nt`'s packed kernel instead blocks
//! eight *columns* of dot products whose per-column chains are exactly
//! `simd::dot_vec`'s canonical blocked order.  Pinned bit-for-bit
//! against the axpy path in `rust/tests/simd_equivalence.rs` and
//! across the `PLMU_THREADS × PLMU_SIMD × PLMU_GEMM` matrix by
//! `./ci.sh determinism`.
//!
//! Padded B-tile lanes are zero-filled and their accumulator lanes are
//! never stored (partial stores), so ragged `n` is handled without
//! branches in the inner loop; ragged `m` runs the micro-kernel with
//! fewer live accumulators.

use crate::simd::{F32x8, LANES};
use crate::util::env_knob;
use std::sync::atomic::{AtomicUsize, Ordering};

use super::matmul::KC;

/// Micro-tile rows: one [`F32x8`] accumulator per row.
pub const MR: usize = 8;
/// Micro-tile columns: the [`F32x8`] lane count.
pub const NR: usize = LANES;

/// Which GEMM inner path the matmul entry points run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GemmPath {
    /// The untiled rank-1/axpy kernels (default; `tensor/matmul.rs`).
    Axpy,
    /// The packed-panel register micro-kernel in this module.
    Packed,
}

/// Runtime GEMM-path knob: 0 = unresolved, 1 = axpy, 2 = packed.
static GEMM_PATH: AtomicUsize = AtomicUsize::new(0);

fn parse_path(s: &str) -> Result<GemmPath, String> {
    if s.eq_ignore_ascii_case("axpy") {
        Ok(GemmPath::Axpy)
    } else if s.eq_ignore_ascii_case("packed") {
        Ok(GemmPath::Packed)
    } else {
        Err(format!("bad PLMU_GEMM value {s:?} (want axpy | packed)"))
    }
}

fn resolve_default() -> GemmPath {
    match env_knob::str_knob("PLMU_GEMM") {
        // like PLMU_SCAN: a garbled env value warns once and falls back
        // to the default rather than panicking inside library calls
        Some(v) => parse_path(&v).unwrap_or_else(|e| {
            env_knob::warn_once("PLMU_GEMM", &format!("ignoring PLMU_GEMM ({e}); using the axpy default"));
            GemmPath::Axpy
        }),
        None => GemmPath::Axpy,
    }
}

/// The active GEMM path (default: axpy, unless `PLMU_GEMM=packed`).
/// Both paths are bit-identical on every input; the knob exists so the
/// determinism gate can prove it end-to-end and the benches can A/B it.
pub fn gemm_path() -> GemmPath {
    match GEMM_PATH.load(Ordering::Relaxed) {
        1 => GemmPath::Axpy,
        2 => GemmPath::Packed,
        _ => {
            let p = resolve_default();
            // racy double-resolve is benign: resolve_default is deterministic
            set_gemm_path(p);
            p
        }
    }
}

/// Set the GEMM-path knob (tests and benches; production reads
/// `PLMU_GEMM` once).  Resolved once per matmul entry call, so flipping
/// it mid-run is safe.
pub fn set_gemm_path(p: GemmPath) {
    GEMM_PATH.store(
        match p {
            GemmPath::Axpy => 1,
            GemmPath::Packed => 2,
        },
        Ordering::Relaxed,
    );
}

/// Pack rows `k0 .. k0+kc` of B (k, n) into width-[`NR`] column tiles:
/// `bp[t·KC·NR + p·NR + c] = B[k0+p, t·NR + c]`, zero-padding the last
/// tile's missing columns (those lanes are never stored back to C).
fn pack_b(bd: &[f32], n: usize, k0: usize, kc: usize, n_tiles: usize, bp: &mut [f32]) {
    for t in 0..n_tiles {
        let j0 = t * NR;
        let nr = (j0 + NR).min(n) - j0;
        let tile = &mut bp[t * KC * NR..t * KC * NR + kc * NR];
        for p in 0..kc {
            let src = &bd[(k0 + p) * n + j0..(k0 + p) * n + j0 + nr];
            let dst = &mut tile[p * NR..(p + 1) * NR];
            dst[..nr].copy_from_slice(src);
            for pad in &mut dst[nr..] {
                *pad = 0.0;
            }
        }
    }
}

/// Pack an `mr`-row micro-panel of A p-major: `ap[p·MR + r]` holds the
/// element multiplying into output row `r` at reduction index `k0+p`.
/// `tn` selects A's layout: `false` reads `A[(i_first+r)·k + k0+p]`
/// (matmul: A is (m, k)); `true` reads `A[(k0+p)·m + i_first+r]`
/// (matmul_tn: A is (k, m), C-row index = A-column index).  Slots for
/// rows `>= mr` go stale but are never read.
#[allow(clippy::too_many_arguments)]
fn pack_a(
    ad: &[f32],
    tn: bool,
    i_first: usize,
    k0: usize,
    kc: usize,
    mr: usize,
    k: usize,
    m: usize,
    ap: &mut [f32],
) {
    if tn {
        for p in 0..kc {
            let arow = &ad[(k0 + p) * m + i_first..(k0 + p) * m + i_first + mr];
            let dst = &mut ap[p * MR..p * MR + mr];
            dst.copy_from_slice(arow);
        }
    } else {
        for r in 0..mr {
            let arow = &ad[(i_first + r) * k + k0..(i_first + r) * k + k0 + kc];
            for (p, &v) in arow.iter().enumerate() {
                ap[p * MR + r] = v;
            }
        }
    }
}

/// The register micro-kernel: fold one packed k-panel into the
/// `mr × nr` C tile at (`r0`, `j0`) of the chunk.  Accumulator `r`
/// starts from C's current tile row (accumulation continues across
/// k-panels) and the p loop is the per-element sequential chain the
/// module docs pin against the axpy path.
#[allow(clippy::too_many_arguments)]
fn micro_kernel(
    ap: &[f32],
    btile: &[f32],
    kc: usize,
    mr: usize,
    nr: usize,
    cblock: &mut [f32],
    r0: usize,
    j0: usize,
    n: usize,
) {
    let mut acc = [F32x8::zero(); MR];
    for (r, a) in acc.iter_mut().enumerate().take(mr) {
        let crow = &cblock[(r0 + r) * n + j0..];
        *a = if nr == NR { F32x8::load(crow) } else { F32x8::load_or(&crow[..nr], 0.0) };
    }
    for p in 0..kc {
        let bv = F32x8::load(&btile[p * NR..]);
        for (r, a) in acc.iter_mut().enumerate().take(mr) {
            *a = a.mul_acc(F32x8::splat(ap[p * MR + r]), bv);
        }
    }
    for (r, a) in acc.iter().enumerate().take(mr) {
        let crow = &mut cblock[(r0 + r) * n + j0..];
        if nr == NR {
            a.store(crow);
        } else {
            a.store_partial(crow, nr);
        }
    }
}

/// Packed serial kernel over one contiguous block of C's rows (rows
/// `i0 ..` of C, `cblock`) — the packed twin of `matmul_rows` /
/// `matmul_tn`'s chunk body.  Pack buffers are allocated per chunk:
/// each exec chunk packs its own panels, so chunks share nothing and
/// the thread count cannot change bytes.
#[allow(clippy::too_many_arguments)]
pub fn gemm_rows(
    ad: &[f32],
    bd: &[f32],
    cblock: &mut [f32],
    i0: usize,
    k: usize,
    n: usize,
    m: usize,
    tn: bool,
) {
    let rows = if n == 0 { 0 } else { cblock.len() / n };
    if rows == 0 || n == 0 || k == 0 {
        return; // degenerate shapes: C is already all zeros
    }
    let n_tiles = n.div_ceil(NR);
    let mut bp = vec![0.0f32; KC * n_tiles * NR];
    let mut ap = vec![0.0f32; KC * MR];
    for k0 in (0..k).step_by(KC) {
        let kc = (k0 + KC).min(k) - k0;
        pack_b(bd, n, k0, kc, n_tiles, &mut bp);
        for r0 in (0..rows).step_by(MR) {
            let mr = (r0 + MR).min(rows) - r0;
            pack_a(ad, tn, i0 + r0, k0, kc, mr, k, m, &mut ap);
            for t in 0..n_tiles {
                let j0 = t * NR;
                let nr = (j0 + NR).min(n) - j0;
                micro_kernel(&ap, &bp[t * KC * NR..], kc, mr, nr, cblock, r0, j0, n);
            }
        }
    }
}

/// Packed serial kernel for `matmul_nt` (C = A·Bᵀ) over one chunk of
/// C's rows.  B's rows are already contiguous in `k`, so nothing needs
/// repacking; instead the kernel register-blocks [`NR`] *columns* of
/// dot products, sharing each loaded A block across all eight.  Every
/// per-column accumulation chain is exactly `simd::dot_vec`'s
/// canonical blocked order (eight lanes, element `i` into lane
/// `i % 8`, the one fixed reduction tree), so each output element is
/// bit-identical to the axpy path's per-element `dot`.
pub fn gemm_nt_rows(ad: &[f32], bd: &[f32], cblock: &mut [f32], i0: usize, k: usize, n: usize) {
    let rows = if n == 0 { 0 } else { cblock.len() / n };
    for r in 0..rows {
        let i = i0 + r;
        let arow = &ad[i * k..(i + 1) * k];
        let crow = &mut cblock[r * n..(r + 1) * n];
        let blocks = k / LANES;
        let tail = blocks * LANES;
        let mut j0 = 0;
        while j0 + NR <= n {
            let mut acc = [F32x8::zero(); NR];
            for bi in 0..blocks {
                let o = bi * LANES;
                let av = F32x8::load(&arow[o..]);
                for (c, a) in acc.iter_mut().enumerate() {
                    *a = a.mul_acc(av, F32x8::load(&bd[(j0 + c) * k + o..]));
                }
            }
            if tail < k {
                let av = F32x8::load_or(&arow[tail..], 0.0);
                for (c, a) in acc.iter_mut().enumerate() {
                    let brow = &bd[(j0 + c) * k + tail..(j0 + c + 1) * k];
                    *a = a.mul_acc(av, F32x8::load_or(brow, 0.0));
                }
            }
            for (c, a) in acc.iter().enumerate() {
                crow[j0 + c] = a.hsum();
            }
            j0 += NR;
        }
        // column tail: plain canonical dots, same chain as the blocks
        for j in j0..n {
            crow[j] = crate::simd::dot_vec(arow, &bd[j * k..(j + 1) * k]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_roundtrip() {
        let was = gemm_path();
        set_gemm_path(GemmPath::Packed);
        assert_eq!(gemm_path(), GemmPath::Packed);
        set_gemm_path(GemmPath::Axpy);
        assert_eq!(gemm_path(), GemmPath::Axpy);
        set_gemm_path(was);
    }

    #[test]
    fn parse_accepts_both_paths_case_insensitively() {
        assert_eq!(parse_path("axpy"), Ok(GemmPath::Axpy));
        assert_eq!(parse_path("Packed"), Ok(GemmPath::Packed));
        assert_eq!(parse_path("PACKED"), Ok(GemmPath::Packed));
        assert!(parse_path("blis").is_err());
    }

    #[test]
    fn pack_b_tiles_and_pads() {
        // B is (2, 10): two tiles, the second ragged by 2 columns
        let n = 10usize;
        let bd: Vec<f32> = (0..2 * n).map(|i| i as f32).collect();
        let n_tiles = n.div_ceil(NR);
        let mut bp = vec![-1.0f32; KC * n_tiles * NR];
        pack_b(&bd, n, 0, 2, n_tiles, &mut bp);
        // tile 0, p = 1, c = 3 -> B[1, 3] = 13
        assert_eq!(bp[NR + 3], 13.0);
        // tile 1, p = 0, c = 1 -> B[0, 9] = 9
        assert_eq!(bp[KC * NR + 1], 9.0);
        // tile 1 padded lanes are +0.0
        assert_eq!(bp[KC * NR + 2], 0.0);
        assert_eq!(bp[KC * NR + NR + 7], 0.0);
    }

    #[test]
    fn pack_a_layouts_agree() {
        // a 3×4 A packed from the (m, k) and (k, m) layouts must yield
        // the identical p-major micro-panel
        let (m, k) = (3usize, 4usize);
        let a_mk: Vec<f32> = (0..m * k).map(|i| i as f32).collect();
        let mut a_km = vec![0.0f32; m * k];
        for i in 0..m {
            for p in 0..k {
                a_km[p * m + i] = a_mk[i * k + p];
            }
        }
        let mut ap1 = vec![0.0f32; KC * MR];
        let mut ap2 = vec![0.0f32; KC * MR];
        pack_a(&a_mk, false, 0, 0, k, m, k, m, &mut ap1);
        pack_a(&a_km, true, 0, 0, k, m, k, m, &mut ap2);
        for p in 0..k {
            for r in 0..m {
                assert_eq!(ap1[p * MR + r], ap2[p * MR + r], "p={p} r={r}");
                assert_eq!(ap1[p * MR + r], a_mk[r * k + p]);
            }
        }
    }
}
