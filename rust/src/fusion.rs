//! Runtime knob for the elementwise fusion pass.
//!
//! When fusion is on (the default), `Graph::affine_act` and
//! `Graph::add2_row_act` record a single fused node whose forward pass
//! applies the bias add and optional activation per output row while
//! the matmul tile is still cache-hot, and whose backward pass feeds
//! the activation gradient straight into the matmul/bias gradients —
//! no intermediate tensors are materialized.  When it is off, the same
//! entry points record the original unfused node chain
//! (`matmul → add_row → tanh`).
//!
//! Both paths are **bit-identical**: the fused kernels apply the
//! identical canonical per-element expressions through the shared
//! `crate::simd` entries (see `tensor/matmul.rs::affine_act` and the
//! `Op::Affine`/`Op::Add2RowAct` arms in `autograd`), so the SIMD
//! layer's bit-exactness argument carries over unchanged.  The knob
//! exists so the CI determinism matrix can prove that end-to-end:
//! `./ci.sh determinism` byte-diffs the train fingerprint across
//! `PLMU_FUSION ∈ {1, 0}` on top of the threads × simd matrix.
//!
//! The knob mirrors `PLMU_SIMD` exactly: resolved once from the
//! `PLMU_FUSION` environment variable via the unified
//! [`crate::util::env_knob`] parser (`0`/`off`/`false`/`no` disable
//! it), overridable by [`set_enabled`] from tests, benches, config,
//! and the `--no-fusion` CLI flag.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Runtime fusion knob: 0 = unresolved, 1 = on, 2 = off.
static FUSION_ENABLED: AtomicUsize = AtomicUsize::new(0);

fn resolve_default() -> bool {
    crate::util::env_knob::bool_knob("PLMU_FUSION", true)
}

/// Whether the graph builders record fused nodes (default: on, unless
/// `PLMU_FUSION=0`/`off`/`false`/`no`).  Both settings are
/// bit-identical by construction; the knob exists so the determinism
/// gate can prove it end-to-end.
pub fn enabled() -> bool {
    match FUSION_ENABLED.load(Ordering::Relaxed) {
        1 => true,
        2 => false,
        _ => {
            let on = resolve_default();
            // racy double-resolve is benign: resolve_default is deterministic
            FUSION_ENABLED.store(if on { 1 } else { 2 }, Ordering::Relaxed);
            on
        }
    }
}

/// Set the fusion knob (tests, benches, config, CLI; production reads
/// `PLMU_FUSION` once).  Flipping it mid-run is safe — already-recorded
/// nodes keep their op, and both op forms are bit-identical — but A/B
/// timers should serialize on their own lock.
pub fn set_enabled(on: bool) {
    FUSION_ENABLED.store(if on { 1 } else { 2 }, Ordering::Relaxed);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knob_roundtrip() {
        let was = enabled();
        set_enabled(true);
        assert!(enabled());
        set_enabled(false);
        assert!(!enabled());
        set_enabled(was);
    }
}
