//! f64 dense linear algebra used by the Delay Network construction:
//! matrix exponential (ZOH discretization), LU solves, matrix powers.
//!
//! These run once at model-build time (A and B are frozen during training,
//! paper §3.3), so clarity wins over speed; f64 because `expm` of the DN's
//! stiff A matrix at large d/θ loses digits in f32.

/// Dense row-major f64 matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f64>,
}

impl Mat {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m.data[i * n + i] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows[0].len();
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c);
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    #[inline]
    pub fn at(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    pub fn matmul(&self, other: &Mat) -> Mat {
        assert_eq!(self.cols, other.rows, "matmul dims");
        let mut out = Mat::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for p in 0..self.cols {
                let a = self.at(i, p);
                if a == 0.0 {
                    continue;
                }
                for j in 0..other.cols {
                    out.data[i * out.cols + j] += a * other.at(p, j);
                }
            }
        }
        out
    }

    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, x.len());
        (0..self.rows)
            .map(|i| {
                let row = &self.data[i * self.cols..(i + 1) * self.cols];
                row.iter().zip(x).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    pub fn add(&self, other: &Mat) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect(),
        }
    }

    pub fn scale(&self, s: f64) -> Mat {
        Mat { rows: self.rows, cols: self.cols, data: self.data.iter().map(|v| v * s).collect() }
    }

    /// 1-norm (max absolute column sum) — used by expm scaling.
    pub fn norm_1(&self) -> f64 {
        (0..self.cols)
            .map(|j| (0..self.rows).map(|i| self.at(i, j).abs()).sum::<f64>())
            .fold(0.0, f64::max)
    }

    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0f64, |m, v| m.max(v.abs()))
    }

    pub fn transpose(&self) -> Mat {
        let mut out = Mat::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out.set(j, i, self.at(i, j));
            }
        }
        out
    }

    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&v| v as f32).collect()
    }

    /// A^k by repeated squaring.
    pub fn pow(&self, mut k: usize) -> Mat {
        assert_eq!(self.rows, self.cols);
        let mut result = Mat::eye(self.rows);
        let mut base = self.clone();
        while k > 0 {
            if k & 1 == 1 {
                result = result.matmul(&base);
            }
            base = base.matmul(&base);
            k >>= 1;
        }
        result
    }
}

/// LU decomposition with partial pivoting.  Returns (LU, perm, sign).
pub fn lu_decompose(a: &Mat) -> Option<(Mat, Vec<usize>, f64)> {
    assert_eq!(a.rows, a.cols, "LU requires square");
    let n = a.rows;
    let mut lu = a.clone();
    let mut perm: Vec<usize> = (0..n).collect();
    let mut sign = 1.0;
    for k in 0..n {
        // pivot
        let mut p = k;
        let mut best = lu.at(k, k).abs();
        for i in k + 1..n {
            let v = lu.at(i, k).abs();
            if v > best {
                best = v;
                p = i;
            }
        }
        if best < 1e-300 {
            return None; // singular
        }
        if p != k {
            for j in 0..n {
                let tmp = lu.at(k, j);
                lu.set(k, j, lu.at(p, j));
                lu.set(p, j, tmp);
            }
            perm.swap(k, p);
            sign = -sign;
        }
        let pivot = lu.at(k, k);
        for i in k + 1..n {
            let f = lu.at(i, k) / pivot;
            lu.set(i, k, f);
            for j in k + 1..n {
                let v = lu.at(i, j) - f * lu.at(k, j);
                lu.set(i, j, v);
            }
        }
    }
    Some((lu, perm, sign))
}

/// Solve A x = b via a precomputed LU.
pub fn lu_solve(lu: &Mat, perm: &[usize], b: &[f64]) -> Vec<f64> {
    let n = lu.rows;
    assert_eq!(b.len(), n);
    let mut x: Vec<f64> = perm.iter().map(|&p| b[p]).collect();
    // forward substitution (unit lower)
    for i in 1..n {
        let mut s = x[i];
        for j in 0..i {
            s -= lu.at(i, j) * x[j];
        }
        x[i] = s;
    }
    // back substitution
    for i in (0..n).rev() {
        let mut s = x[i];
        for j in i + 1..n {
            s -= lu.at(i, j) * x[j];
        }
        x[i] = s / lu.at(i, i);
    }
    x
}

/// Solve A X = B for matrix B.
pub fn solve_mat(a: &Mat, b: &Mat) -> Option<Mat> {
    let (lu, perm, _) = lu_decompose(a)?;
    let n = a.rows;
    let mut out = Mat::zeros(n, b.cols);
    for j in 0..b.cols {
        let col: Vec<f64> = (0..n).map(|i| b.at(i, j)).collect();
        let x = lu_solve(&lu, &perm, &col);
        for i in 0..n {
            out.set(i, j, x[i]);
        }
    }
    Some(out)
}

/// Matrix inverse.
pub fn inverse(a: &Mat) -> Option<Mat> {
    solve_mat(a, &Mat::eye(a.rows))
}

/// Matrix exponential by Padé-13 with scaling and squaring (Higham 2005,
/// the algorithm scipy's `expm` uses, without the order-switching).
pub fn expm(a: &Mat) -> Mat {
    assert_eq!(a.rows, a.cols);
    let n = a.rows;
    if n == 0 {
        return Mat::zeros(0, 0);
    }
    // scale so that ||A/2^s|| <= theta_13 ~= 5.37
    const THETA_13: f64 = 5.371920351148152;
    let norm = a.norm_1();
    let s = if norm > THETA_13 { ((norm / THETA_13).log2().ceil()) as u32 } else { 0 };
    let a_scaled = a.scale(1.0 / (1u64 << s) as f64);

    // Pade-13 coefficients
    const B: [f64; 14] = [
        64764752532480000.0,
        32382376266240000.0,
        7771770303897600.0,
        1187353796428800.0,
        129060195264000.0,
        10559470521600.0,
        670442572800.0,
        33522128640.0,
        1323241920.0,
        40840800.0,
        960960.0,
        16380.0,
        182.0,
        1.0,
    ];

    let i_mat = Mat::eye(n);
    let a2 = a_scaled.matmul(&a_scaled);
    let a4 = a2.matmul(&a2);
    let a6 = a4.matmul(&a2);

    // U = A (A6 (b13 A6 + b11 A4 + b9 A2) + b7 A6 + b5 A4 + b3 A2 + b1 I)
    let w1 = a6.scale(B[13]).add(&a4.scale(B[11])).add(&a2.scale(B[9]));
    let w2 = a6.scale(B[7]).add(&a4.scale(B[5])).add(&a2.scale(B[3])).add(&i_mat.scale(B[1]));
    let u = a_scaled.matmul(&a6.matmul(&w1).add(&w2));
    // V = A6 (b12 A6 + b10 A4 + b8 A2) + b6 A6 + b4 A4 + b2 A2 + b0 I
    let z1 = a6.scale(B[12]).add(&a4.scale(B[10])).add(&a2.scale(B[8]));
    let v = a6.matmul(&z1).add(&a6.scale(B[6])).add(&a4.scale(B[4])).add(&a2.scale(B[2])).add(&i_mat.scale(B[0]));

    // solve (V - U) R = (V + U)
    let lhs = v.add(&u.scale(-1.0));
    let rhs = v.add(&u);
    let mut r = solve_mat(&lhs, &rhs).expect("expm: singular (V - U)");
    for _ in 0..s {
        r = r.matmul(&r);
    }
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: &Mat, b: &Mat, tol: f64) {
        assert_eq!((a.rows, a.cols), (b.rows, b.cols));
        for (x, y) in a.data.iter().zip(&b.data) {
            assert!((x - y).abs() < tol, "{x} vs {y}");
        }
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_rows(&[&[1., 2.], &[3., 4.]]);
        assert_close(&a.matmul(&Mat::eye(2)), &a, 1e-12);
    }

    #[test]
    fn lu_solve_known_system() {
        // 2x + y = 5 ; x + 3y = 10  -> x = 1, y = 3
        let a = Mat::from_rows(&[&[2., 1.], &[1., 3.]]);
        let (lu, p, _) = lu_decompose(&a).unwrap();
        let x = lu_solve(&lu, &p, &[5., 10.]);
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn lu_detects_singular() {
        let a = Mat::from_rows(&[&[1., 2.], &[2., 4.]]);
        assert!(lu_decompose(&a).is_none());
    }

    #[test]
    fn inverse_roundtrip() {
        let a = Mat::from_rows(&[&[4., 7., 1.], &[2., 6., 0.], &[1., 0., 3.]]);
        let ai = inverse(&a).unwrap();
        assert_close(&a.matmul(&ai), &Mat::eye(3), 1e-10);
    }

    #[test]
    fn expm_zero_is_identity() {
        let z = Mat::zeros(4, 4);
        assert_close(&expm(&z), &Mat::eye(4), 1e-12);
    }

    #[test]
    fn expm_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a.set(0, 0, 1.0);
        a.set(1, 1, -2.0);
        a.set(2, 2, 0.5);
        let e = expm(&a);
        assert!((e.at(0, 0) - 1.0f64.exp()).abs() < 1e-10);
        assert!((e.at(1, 1) - (-2.0f64).exp()).abs() < 1e-10);
        assert!((e.at(2, 2) - 0.5f64.exp()).abs() < 1e-10);
        assert!(e.at(0, 1).abs() < 1e-12);
    }

    #[test]
    fn expm_rotation() {
        // exp([[0, -t], [t, 0]]) = [[cos t, -sin t], [sin t, cos t]]
        let t = 0.7f64;
        let a = Mat::from_rows(&[&[0., -t], &[t, 0.]]);
        let e = expm(&a);
        assert!((e.at(0, 0) - t.cos()).abs() < 1e-12);
        assert!((e.at(1, 0) - t.sin()).abs() < 1e-12);
    }

    #[test]
    fn expm_additivity_for_commuting() {
        // exp(A) exp(A) = exp(2A)
        let a = Mat::from_rows(&[&[0.1, 0.3], &[-0.2, 0.05]]);
        let e1 = expm(&a);
        let e2 = expm(&a.scale(2.0));
        assert_close(&e1.matmul(&e1), &e2, 1e-10);
    }

    #[test]
    fn expm_large_norm_uses_squaring() {
        // matrix with norm >> theta13 must still be accurate:
        // exp(diag(10, -10))
        let mut a = Mat::zeros(2, 2);
        a.set(0, 0, 10.0);
        a.set(1, 1, -10.0);
        let e = expm(&a);
        assert!((e.at(0, 0) - 10.0f64.exp()).abs() / 10.0f64.exp() < 1e-10);
        assert!((e.at(1, 1) - (-10.0f64).exp()).abs() < 1e-12);
    }

    #[test]
    fn pow_matches_repeated_matmul() {
        let a = Mat::from_rows(&[&[0.9, 0.1], &[-0.2, 0.8]]);
        let mut expect = Mat::eye(2);
        for _ in 0..7 {
            expect = expect.matmul(&a);
        }
        assert_close(&a.pow(7), &expect, 1e-12);
        assert_close(&a.pow(0), &Mat::eye(2), 1e-15);
    }

    #[test]
    fn norm1_is_max_col_sum() {
        let a = Mat::from_rows(&[&[1., -4.], &[2., 1.]]);
        assert_eq!(a.norm_1(), 5.0);
    }
}
