//! Micro-benchmark harness (criterion substitute — criterion is not in the
//! offline vendor set).  Provides warmup, adaptive iteration counts, and
//! robust statistics, a table printer the `rust/benches/*.rs` binaries use
//! to emit the paper's tables/figures as aligned text, and a minimal JSON
//! perf-record writer ([`PerfJson`], no serde offline) for machine-readable
//! trajectory files like `BENCH_threads.json`.

use crate::util::{human_duration, Timer};

/// Statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// seconds of warmup before measurement
    pub warmup_secs: f64,
    /// target measurement time
    pub measure_secs: f64,
    /// hard cap on measured iterations
    pub max_iters: usize,
    /// minimum measured iterations
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_secs: 0.2, measure_secs: 1.0, max_iters: 1000, min_iters: 3 }
    }
}

impl BenchConfig {
    /// Fast settings for expensive end-to-end cases.
    pub fn quick() -> Self {
        BenchConfig { warmup_secs: 0.05, measure_secs: 0.3, max_iters: 50, min_iters: 2 }
    }
}

/// Walk up from the current directory looking for the repo root (the
/// ROADMAP.md marker).  Bench binaries run with cwd = the crate dir
/// (`rust/`), but the `BENCH_*.json` perf trajectory files they emit
/// belong at the repo root; falls back to the cwd when no marker is
/// found within a few levels.
pub fn repo_root() -> std::path::PathBuf {
    let mut dir = std::env::current_dir().unwrap_or_else(|_| ".".into());
    for _ in 0..5 {
        if dir.join("ROADMAP.md").exists() {
            return dir;
        }
        match dir.parent() {
            Some(p) => dir = p.to_path_buf(),
            None => break,
        }
    }
    std::env::current_dir().unwrap_or_else(|_| ".".into())
}

/// Order-sensitive FNV-style fingerprint over `f32` bit patterns: equal
/// iff the sequence is bit-identical.  Benches hash kernel results with
/// it to assert a parallel/vector path matches its serial/scalar
/// reference before timing it (one shared definition so the scheme
/// cannot diverge between benches).
pub fn checksum_f32(xs: &[f32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in xs {
        h ^= v.to_bits() as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// `f64` variant of [`checksum_f32`].
pub fn checksum_f64(xs: &[f64]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for v in xs {
        h ^= v.to_bits();
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Time a closure under the given config and return robust statistics.
pub fn bench<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> Stats {
    // warmup + calibration
    let t = Timer::start();
    let mut warm_iters = 0usize;
    while t.elapsed() < cfg.warmup_secs || warm_iters == 0 {
        f();
        warm_iters += 1;
        if warm_iters >= cfg.max_iters {
            break;
        }
    }
    let per_iter = (t.elapsed() / warm_iters as f64).max(1e-9);
    let iters = ((cfg.measure_secs / per_iter) as usize)
        .clamp(cfg.min_iters, cfg.max_iters);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let it = Timer::start();
        f();
        samples.push(it.elapsed());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |q: f64| samples[(((samples.len() - 1) as f64) * q) as usize];
    Stats {
        name: name.to_string(),
        iters,
        mean,
        p50: pct(0.5),
        p95: pct(0.95),
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// Convenience: run and immediately print one line.
pub fn bench_report<F: FnMut()>(name: &str, cfg: BenchConfig, f: F) -> Stats {
    let s = bench(name, cfg, f);
    println!(
        "  {:<44} mean {:>12}  p50 {:>12}  p95 {:>12}  (n={})",
        s.name,
        human_duration(s.mean),
        human_duration(s.p50),
        human_duration(s.p95),
        s.iters
    );
    s
}

/// Aligned-text table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("\n{title}");
        println!("{}", "=".repeat(total.min(120)));
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(total.min(120)));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

// --------------------------------------------------------------- perf JSON

/// A JSON value for perf records (numbers, strings, bools).
#[derive(Clone, Debug)]
pub enum JsonValue {
    Num(f64),
    Int(i64),
    Str(String),
    Bool(bool),
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl JsonValue {
    fn render(&self) -> String {
        match self {
            // f64 Display never emits exponents or inf/nan-safe text, so
            // guard non-finite values explicitly
            JsonValue::Num(v) if v.is_finite() => format!("{v}"),
            JsonValue::Num(_) => "null".to_string(),
            JsonValue::Int(v) => format!("{v}"),
            JsonValue::Str(s) => format!("\"{}\"", json_escape(s)),
            JsonValue::Bool(b) => format!("{b}"),
        }
    }
}

/// Flat-record JSON writer for perf trajectory files:
/// `{"bench": ..., "records": [{...}, ...]}`.
pub struct PerfJson {
    bench: String,
    records: Vec<Vec<(String, JsonValue)>>,
}

impl PerfJson {
    pub fn new(bench: &str) -> Self {
        PerfJson { bench: bench.to_string(), records: Vec::new() }
    }

    /// Append one flat record of (field, value) pairs.
    pub fn push(&mut self, fields: &[(&str, JsonValue)]) {
        self.records
            .push(fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect());
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Serialize the whole document.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.bench)));
        out.push_str("  \"records\": [\n");
        for (i, rec) in self.records.iter().enumerate() {
            let fields: Vec<String> = rec
                .iter()
                .map(|(k, v)| format!("\"{}\": {}", json_escape(k), v.render()))
                .collect();
            let comma = if i + 1 < self.records.len() { "," } else { "" };
            out.push_str(&format!("    {{{}}}{comma}\n", fields.join(", ")));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the document to `path`.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

// ------------------------------------------------------ perf JSON reading

/// A parsed JSON value — the reading half of the perf-record story (the
/// writer is [`PerfJson`]; both exist because serde is not in the
/// offline vendor set).  Only what perf records need: objects keep
/// insertion order, numbers are f64.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Look a key up in an object (first occurrence).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
}

struct JsonParser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl JsonParser<'_> {
    fn err(&self, msg: &str) -> String {
        format!("json parse error at byte {}: {msg}", self.pos)
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len() && self.bytes[self.pos].is_ascii_whitespace() {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> Result<(), String> {
        match self.peek() {
            Some(c) if c == b => {
                self.pos += 1;
                Ok(())
            }
            other => Err(self.err(&format!(
                "expected {:?}, found {:?}",
                b as char,
                other.map(|c| c as char)
            ))),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&b) = self.bytes.get(self.pos) else {
                return Err(self.err("unterminated string"));
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&esc) = self.bytes.get(self.pos) else {
                        return Err(self.err("dangling escape"));
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("truncated \\u escape"))?;
                            let hex = std::str::from_utf8(hex)
                                .map_err(|_| self.err("non-ascii \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // surrogate pairs don't appear in our own
                            // writer's output; map lone surrogates to
                            // the replacement character
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => {
                            return Err(self.err(&format!("bad escape \\{}", other as char)))
                        }
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence starting at b
                    let start = self.pos - 1;
                    let mut end = self.pos;
                    while end < self.bytes.len() && self.bytes[end] & 0xC0 == 0x80 {
                        end += 1;
                    }
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| self.err("invalid utf-8 in string"))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_digit() || matches!(b, b'-' | b'+' | b'.' | b'e' | b'E'))
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err(&format!("bad number {text:?}")))
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => {
                self.pos += 1;
                let mut fields = Vec::new();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.expect(b':')?;
                    fields.push((key, self.value()?));
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => return Err(self.err("expected ',' or '}' in object")),
                    }
                }
            }
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(self.err("expected ',' or ']' in array")),
                    }
                }
            }
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(_) => self.number(),
            None => Err(self.err("unexpected end of input")),
        }
    }
}

/// Parse a JSON document (sufficient for perf records; no streaming, no
/// surrogate-pair pedantry).
pub fn parse_json(text: &str) -> Result<Json, String> {
    let mut p = JsonParser { bytes: text.as_bytes(), pos: 0 };
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing garbage after document"));
    }
    Ok(v)
}

/// What [`validate_perf_json`] reports for a valid perf record.
#[derive(Debug)]
pub struct PerfSummary {
    /// the record's `bench` name
    pub bench: String,
    /// number of records in the file
    pub records: usize,
}

fn timing_field(key: &str) -> bool {
    key.ends_with("_s") || key.ends_with("_ns") || key.ends_with("_us") || key.ends_with("_ms")
}

/// Byte-count fields (`bytes_moved_fused`, `fresh_bytes`, ...) carry
/// traffic estimates; like timings they must be finite and non-negative.
fn bytes_field(key: &str) -> bool {
    key.contains("bytes")
}

/// Ratio fields (`speedup`, `scan_over_fft`, `hit_ratio`, ...) compare
/// two measurements; a 0, NaN, or ∞ here means one side of the division
/// was missing or zero — a broken bench, not a slow one.
fn ratio_field(key: &str) -> bool {
    key.contains("speedup") || key.contains("_over_") || key.contains("ratio")
}

/// Validate a `BENCH_*.json` perf record, the CI bench stage's gate: a
/// refactored bench that silently emits an empty or malformed perf
/// record fails here instead of landing.
///
/// Rules:
///  * top level is an object with a string `bench` and a non-empty
///    `records` array of flat objects;
///  * every record carries `case` (string), `threads` (integer >= 1),
///    and `wall_ns` (number >= 0) — the minimal schema every perf
///    trajectory consumer can rely on;
///  * every timing field (`*_s` / `*_ms` / `*_us` / `*_ns`, including
///    `wall_ns`) is finite and non-negative;
///  * every byte-count field (key containing `bytes`, e.g.
///    `bytes_moved_fused`) is a finite non-negative number;
///  * every ratio field (key containing `speedup`, `_over_`, or
///    `ratio`) is finite and strictly positive — a 0/NaN/∞ comparison
///    means a division against a missing or zero measurement;
///  * where a record carries percentile timings of one unit
///    (`min_*`/`p50_*`/`p95_*`/`max_*`), they are monotone
///    non-decreasing;
///  * a `simd_kernels` record must cover the f64 FFT kernels and the
///    packed GEMM path: at least one `f64_*` case and one `gemm_*`
///    case, each carrying a `speedup_vs_scalar` ratio (which the ratio
///    rule above already forces finite and strictly positive) — a bench
///    refactor that silently drops either A/B family fails here.
pub fn validate_perf_json(text: &str) -> Result<PerfSummary, String> {
    let doc = parse_json(text)?;
    let bench = doc
        .get("bench")
        .and_then(Json::as_str)
        .ok_or("missing or non-string \"bench\" key")?
        .to_string();
    let Some(Json::Arr(records)) = doc.get("records") else {
        return Err("missing \"records\" array".into());
    };
    if records.is_empty() {
        return Err("\"records\" is empty — the bench produced no perf data".into());
    }
    let mut f64_speedups = 0usize;
    let mut gemm_speedups = 0usize;
    for (i, rec) in records.iter().enumerate() {
        let Json::Obj(fields) = rec else {
            return Err(format!("record {i} is not an object"));
        };
        let case = rec
            .get("case")
            .and_then(Json::as_str)
            .ok_or_else(|| format!("record {i}: missing or non-string \"case\""))?;
        let has_speedup = rec.get("speedup_vs_scalar").and_then(Json::as_f64).is_some();
        if case.starts_with("f64_") && has_speedup {
            f64_speedups += 1;
        }
        if case.starts_with("gemm_") && has_speedup {
            gemm_speedups += 1;
        }
        let threads = rec
            .get("threads")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("record {i}: missing or non-numeric \"threads\""))?;
        if threads < 1.0 || threads.fract() != 0.0 {
            return Err(format!("record {i}: \"threads\" = {threads} is not a positive integer"));
        }
        rec.get("wall_ns")
            .and_then(Json::as_f64)
            .ok_or_else(|| format!("record {i}: missing or non-numeric \"wall_ns\""))?;
        for (key, value) in fields {
            if timing_field(key) {
                let v = value
                    .as_f64()
                    .ok_or_else(|| format!("record {i}: timing field {key:?} is not a number"))?;
                if !v.is_finite() || v < 0.0 {
                    return Err(format!(
                        "record {i}: timing field {key:?} = {v} is not finite and non-negative"
                    ));
                }
            }
            if bytes_field(key) {
                let v = value
                    .as_f64()
                    .ok_or_else(|| format!("record {i}: bytes field {key:?} is not a number"))?;
                if !v.is_finite() || v < 0.0 {
                    return Err(format!(
                        "record {i}: bytes field {key:?} = {v} is not finite and non-negative"
                    ));
                }
            }
            if ratio_field(key) {
                let v = value
                    .as_f64()
                    .ok_or_else(|| format!("record {i}: ratio field {key:?} is not a number"))?;
                if !v.is_finite() || v <= 0.0 {
                    return Err(format!(
                        "record {i}: ratio field {key:?} = {v} is not finite and positive — \
                         one side of the comparison was missing or zero"
                    ));
                }
            }
        }
        // percentile monotonicity per unit suffix
        for suffix in ["_s", "_ms", "_us", "_ns"] {
            let stat = |name: &str| {
                rec.get(&format!("{name}{suffix}")).and_then(Json::as_f64)
            };
            let present: Vec<f64> = ["min", "p50", "p95", "max"]
                .iter()
                .filter_map(|n| stat(n))
                .collect();
            if present.windows(2).any(|w| w[0] > w[1]) {
                return Err(format!(
                    "record {i}: min/p50/p95/max{suffix} timings are not monotone: {present:?}"
                ));
            }
        }
    }
    if bench == "simd_kernels" {
        if f64_speedups == 0 {
            return Err("simd_kernels record has no f64_* case with a \
                        speedup_vs_scalar ratio — the f64 FFT kernel A/B is missing"
                .into());
        }
        if gemm_speedups == 0 {
            return Err("simd_kernels record has no gemm_* case with a \
                        speedup_vs_scalar ratio — the packed GEMM A/B is missing"
                .into());
        }
    }
    Ok(PerfSummary { bench, records: records.len() })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let cfg = BenchConfig { warmup_secs: 0.01, measure_secs: 0.05, max_iters: 100, min_iters: 3 };
        let s = bench("spin", cfg, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(s.iters >= 3);
        assert!(s.mean > 0.0);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
    }

    #[test]
    fn bench_ordering_detects_slower_work() {
        let cfg = BenchConfig { warmup_secs: 0.01, measure_secs: 0.05, max_iters: 200, min_iters: 3 };
        let fast = bench("fast", cfg, || {
            std::hint::black_box((0..std::hint::black_box(100usize)).sum::<usize>());
        });
        let slow = bench("slow", cfg, || {
            std::hint::black_box(
                (0..std::hint::black_box(1_000_000usize)).map(|i| i ^ 3).sum::<usize>(),
            );
        });
        assert!(slow.p50 > fast.p50, "slow {} <= fast {}", slow.p50, fast.p50);
    }

    #[test]
    fn throughput_inverts_mean() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            mean: 0.5,
            p50: 0.5,
            p95: 0.5,
            min: 0.5,
            max: 0.5,
        };
        assert!((s.throughput(100.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["model", "acc"]);
        t.row(&["LSTM".into(), "89.86".into()]);
        t.row(&["ours".into(), "98.49".into()]);
        t.print("Table 2 (smoke)");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn perf_json_renders_valid_structure() {
        let mut p = PerfJson::new("fig1_threads");
        p.push(&[
            ("case", JsonValue::Str("matmul \"odd\"".into())),
            ("threads", JsonValue::Int(4)),
            ("mean_s", JsonValue::Num(0.0125)),
            ("ok", JsonValue::Bool(true)),
            ("bad", JsonValue::Num(f64::NAN)),
        ]);
        p.push(&[("threads", JsonValue::Int(1))]);
        let s = p.render();
        assert!(s.contains("\"bench\": \"fig1_threads\""));
        assert!(s.contains("\"threads\": 4"));
        assert!(s.contains("\"mean_s\": 0.0125"));
        assert!(s.contains("\"case\": \"matmul \\\"odd\\\"\""));
        assert!(s.contains("\"bad\": null"));
        assert_eq!(p.len(), 2);
        // balanced braces/brackets as a cheap well-formedness check
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    fn valid_doc() -> PerfJson {
        let mut p = PerfJson::new("demo");
        p.push(&[
            ("case", JsonValue::Str("matmul".into())),
            ("threads", JsonValue::Int(4)),
            ("wall_ns", JsonValue::Int(12_500)),
            ("mean_s", JsonValue::Num(1.25e-5)),
            ("p50_s", JsonValue::Num(1.2e-5)),
            ("p95_s", JsonValue::Num(1.4e-5)),
            ("smoke", JsonValue::Bool(true)),
        ]);
        p
    }

    #[test]
    fn parse_json_roundtrips_writer_output() {
        let doc = parse_json(&valid_doc().render()).expect("writer output must parse");
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("demo"));
        let Some(Json::Arr(recs)) = doc.get("records") else {
            panic!("records array missing");
        };
        assert_eq!(recs.len(), 1);
        assert_eq!(recs[0].get("threads").and_then(Json::as_f64), Some(4.0));
        assert_eq!(recs[0].get("case").and_then(Json::as_str), Some("matmul"));
    }

    #[test]
    fn parse_json_handles_escapes_and_nesting() {
        let doc = parse_json(
            r#"{"a": "x\"y\nA", "b": [1, -2.5, true, null], "c": {"d": []}}"#,
        )
        .unwrap();
        assert_eq!(doc.get("a").and_then(Json::as_str), Some("x\"y\nA"));
        let Some(Json::Arr(b)) = doc.get("b") else { panic!() };
        assert_eq!(b[0].as_f64(), Some(1.0));
        assert_eq!(b[1].as_f64(), Some(-2.5));
        assert_eq!(b[2], Json::Bool(true));
        assert_eq!(b[3], Json::Null);
        assert!(parse_json("{\"a\": 1} trailing").is_err());
        assert!(parse_json("{\"a\": }").is_err());
    }

    #[test]
    fn validate_accepts_well_formed_record() {
        let s = valid_doc().render();
        let summary = validate_perf_json(&s).expect("valid record rejected");
        assert_eq!(summary.bench, "demo");
        assert_eq!(summary.records, 1);
    }

    #[test]
    fn validate_rejects_missing_required_keys() {
        for missing in ["case", "threads", "wall_ns"] {
            let mut p = PerfJson::new("demo");
            let fields: Vec<(&str, JsonValue)> = [
                ("case", JsonValue::Str("x".into())),
                ("threads", JsonValue::Int(1)),
                ("wall_ns", JsonValue::Int(5)),
            ]
            .into_iter()
            .filter(|(k, _)| *k != missing)
            .collect();
            p.push(&fields);
            let err = validate_perf_json(&p.render()).unwrap_err();
            assert!(err.contains(missing), "error {err:?} should name {missing}");
        }
    }

    #[test]
    fn validate_rejects_empty_and_malformed_records() {
        let empty = PerfJson::new("demo").render();
        assert!(validate_perf_json(&empty).unwrap_err().contains("empty"));
        assert!(validate_perf_json("not json at all").is_err());
        assert!(validate_perf_json("{\"records\": []}").is_err(), "bench key required");
    }

    #[test]
    fn validate_rejects_bad_timings() {
        // negative timing
        let mut p = PerfJson::new("demo");
        p.push(&[
            ("case", JsonValue::Str("x".into())),
            ("threads", JsonValue::Int(2)),
            ("wall_ns", JsonValue::Int(-1)),
        ]);
        assert!(validate_perf_json(&p.render()).is_err());
        // non-integer thread count
        let mut p = PerfJson::new("demo");
        p.push(&[
            ("case", JsonValue::Str("x".into())),
            ("threads", JsonValue::Num(1.5)),
            ("wall_ns", JsonValue::Int(1)),
        ]);
        assert!(validate_perf_json(&p.render()).is_err());
        // non-monotone percentiles
        let mut p = PerfJson::new("demo");
        p.push(&[
            ("case", JsonValue::Str("x".into())),
            ("threads", JsonValue::Int(2)),
            ("wall_ns", JsonValue::Int(1)),
            ("p50_s", JsonValue::Num(2.0)),
            ("p95_s", JsonValue::Num(1.0)),
        ]);
        let err = validate_perf_json(&p.render()).unwrap_err();
        assert!(err.contains("monotone"), "{err}");
    }

    #[test]
    fn validate_requires_f64_and_gemm_speedups_for_simd_kernels() {
        let rec = |case: &str| {
            vec![
                ("case", JsonValue::Str(case.into())),
                ("threads", JsonValue::Int(1)),
                ("wall_ns", JsonValue::Int(5)),
                ("speedup_vs_scalar", JsonValue::Num(1.1)),
            ]
        };
        // both families present: valid
        let mut p = PerfJson::new("simd_kernels");
        p.push(&rec("f64_cmul_128"));
        p.push(&rec("gemm_256x256x256"));
        validate_perf_json(&p.render()).expect("complete simd_kernels record rejected");
        // missing gemm family
        let mut p = PerfJson::new("simd_kernels");
        p.push(&rec("f64_cmul_128"));
        let err = validate_perf_json(&p.render()).unwrap_err();
        assert!(err.contains("gemm"), "{err}");
        // missing f64 family
        let mut p = PerfJson::new("simd_kernels");
        p.push(&rec("gemm_256x256x256"));
        let err = validate_perf_json(&p.render()).unwrap_err();
        assert!(err.contains("f64"), "{err}");
        // a gemm case WITHOUT the speedup ratio does not count as coverage
        let mut p = PerfJson::new("simd_kernels");
        p.push(&rec("f64_cmul_128"));
        p.push(&[
            ("case", JsonValue::Str("gemm_64x64x64".into())),
            ("threads", JsonValue::Int(1)),
            ("wall_ns", JsonValue::Int(5)),
        ]);
        let err = validate_perf_json(&p.render()).unwrap_err();
        assert!(err.contains("gemm"), "{err}");
        // other benches are exempt from the rule
        let mut p = PerfJson::new("fig1_threads");
        p.push(&rec("matmul"));
        validate_perf_json(&p.render()).expect("non-simd_kernels bench wrongly gated");
    }

    #[test]
    fn validate_rejects_bad_byte_counts() {
        let rec = |v: JsonValue| {
            let mut p = PerfJson::new("demo");
            p.push(&[
                ("case", JsonValue::Str("x".into())),
                ("threads", JsonValue::Int(2)),
                ("wall_ns", JsonValue::Int(1)),
                ("bytes_moved_fused", v),
            ]);
            p.render()
        };
        let err = validate_perf_json(&rec(JsonValue::Num(-1.0))).unwrap_err();
        assert!(err.contains("bytes"), "negative byte count not rejected: {err}");
        let err = validate_perf_json(&rec(JsonValue::Str("lots".into()))).unwrap_err();
        assert!(err.contains("bytes"), "non-numeric byte count not rejected: {err}");
        validate_perf_json(&rec(JsonValue::Int(4096))).expect("valid byte count rejected");
        validate_perf_json(&rec(JsonValue::Num(0.0))).expect("zero byte count rejected");
    }

    #[test]
    fn validate_rejects_bad_ratios() {
        let rec = |key: &str, v: JsonValue| {
            let mut p = PerfJson::new("demo");
            p.push(&[
                ("case", JsonValue::Str("x".into())),
                ("threads", JsonValue::Int(2)),
                ("wall_ns", JsonValue::Int(1)),
                (key, v),
            ]);
            p.render()
        };
        // zero means one side of the comparison was missing
        let err = validate_perf_json(&rec("speedup", JsonValue::Num(0.0))).unwrap_err();
        assert!(err.contains("ratio"), "zero speedup not rejected: {err}");
        let err = validate_perf_json(&rec("scan_over_fft", JsonValue::Num(-3.0))).unwrap_err();
        assert!(err.contains("ratio"), "negative ratio not rejected: {err}");
        let err = validate_perf_json(&rec("hit_ratio", JsonValue::Num(f64::NAN))).unwrap_err();
        assert!(err.contains("ratio"), "NaN ratio not rejected: {err}");
        let err =
            validate_perf_json(&rec("speedup", JsonValue::Num(f64::INFINITY))).unwrap_err();
        assert!(err.contains("ratio"), "infinite speedup not rejected: {err}");
        let err = validate_perf_json(&rec("speedup", JsonValue::Str("2x".into()))).unwrap_err();
        assert!(err.contains("ratio"), "non-numeric speedup not rejected: {err}");
        // sane values pass, sub-1.0 included (slowdowns are valid data)
        validate_perf_json(&rec("speedup", JsonValue::Num(3.7))).expect("valid speedup rejected");
        validate_perf_json(&rec("scan_over_fft", JsonValue::Num(0.8)))
            .expect("sub-1.0 ratio rejected");
    }

    #[test]
    fn perf_json_roundtrips_to_disk() {
        let dir = std::env::temp_dir().join("plmu_perfjson_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        let mut p = PerfJson::new("t");
        p.push(&[("v", JsonValue::Num(1.5))]);
        p.write(&path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, p.render());
    }
}
