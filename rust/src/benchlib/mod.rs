//! Micro-benchmark harness (criterion substitute — criterion is not in the
//! offline vendor set).  Provides warmup, adaptive iteration counts, and
//! robust statistics, a table printer the `rust/benches/*.rs` binaries use
//! to emit the paper's tables/figures as aligned text, and a minimal JSON
//! perf-record writer ([`PerfJson`], no serde offline) for machine-readable
//! trajectory files like `BENCH_threads.json`.

use crate::util::{human_duration, Timer};

/// Statistics of one benchmark case.
#[derive(Clone, Debug)]
pub struct Stats {
    pub name: String,
    pub iters: usize,
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub min: f64,
    pub max: f64,
}

impl Stats {
    pub fn throughput(&self, items: f64) -> f64 {
        items / self.mean
    }
}

/// Benchmark runner configuration.
#[derive(Clone, Copy, Debug)]
pub struct BenchConfig {
    /// seconds of warmup before measurement
    pub warmup_secs: f64,
    /// target measurement time
    pub measure_secs: f64,
    /// hard cap on measured iterations
    pub max_iters: usize,
    /// minimum measured iterations
    pub min_iters: usize,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { warmup_secs: 0.2, measure_secs: 1.0, max_iters: 1000, min_iters: 3 }
    }
}

impl BenchConfig {
    /// Fast settings for expensive end-to-end cases.
    pub fn quick() -> Self {
        BenchConfig { warmup_secs: 0.05, measure_secs: 0.3, max_iters: 50, min_iters: 2 }
    }
}

/// Time a closure under the given config and return robust statistics.
pub fn bench<F: FnMut()>(name: &str, cfg: BenchConfig, mut f: F) -> Stats {
    // warmup + calibration
    let t = Timer::start();
    let mut warm_iters = 0usize;
    while t.elapsed() < cfg.warmup_secs || warm_iters == 0 {
        f();
        warm_iters += 1;
        if warm_iters >= cfg.max_iters {
            break;
        }
    }
    let per_iter = (t.elapsed() / warm_iters as f64).max(1e-9);
    let iters = ((cfg.measure_secs / per_iter) as usize)
        .clamp(cfg.min_iters, cfg.max_iters);

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let it = Timer::start();
        f();
        samples.push(it.elapsed());
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let pct = |q: f64| samples[(((samples.len() - 1) as f64) * q) as usize];
    Stats {
        name: name.to_string(),
        iters,
        mean,
        p50: pct(0.5),
        p95: pct(0.95),
        min: samples[0],
        max: *samples.last().unwrap(),
    }
}

/// Convenience: run and immediately print one line.
pub fn bench_report<F: FnMut()>(name: &str, cfg: BenchConfig, f: F) -> Stats {
    let s = bench(name, cfg, f);
    println!(
        "  {:<44} mean {:>12}  p50 {:>12}  p95 {:>12}  (n={})",
        s.name,
        human_duration(s.mean),
        human_duration(s.p50),
        human_duration(s.p95),
        s.iters
    );
    s
}

/// Aligned-text table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "table row arity");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self, title: &str) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        println!("\n{title}");
        println!("{}", "=".repeat(total.min(120)));
        let fmt_row = |cells: &[String]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                line.push_str(&format!(" {:<w$} |", c, w = w));
            }
            line
        };
        println!("{}", fmt_row(&self.headers));
        println!("{}", "-".repeat(total.min(120)));
        for row in &self.rows {
            println!("{}", fmt_row(row));
        }
    }
}

// --------------------------------------------------------------- perf JSON

/// A JSON value for perf records (numbers, strings, bools).
#[derive(Clone, Debug)]
pub enum JsonValue {
    Num(f64),
    Int(i64),
    Str(String),
    Bool(bool),
}

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

impl JsonValue {
    fn render(&self) -> String {
        match self {
            // f64 Display never emits exponents or inf/nan-safe text, so
            // guard non-finite values explicitly
            JsonValue::Num(v) if v.is_finite() => format!("{v}"),
            JsonValue::Num(_) => "null".to_string(),
            JsonValue::Int(v) => format!("{v}"),
            JsonValue::Str(s) => format!("\"{}\"", json_escape(s)),
            JsonValue::Bool(b) => format!("{b}"),
        }
    }
}

/// Flat-record JSON writer for perf trajectory files:
/// `{"bench": ..., "records": [{...}, ...]}`.
pub struct PerfJson {
    bench: String,
    records: Vec<Vec<(String, JsonValue)>>,
}

impl PerfJson {
    pub fn new(bench: &str) -> Self {
        PerfJson { bench: bench.to_string(), records: Vec::new() }
    }

    /// Append one flat record of (field, value) pairs.
    pub fn push(&mut self, fields: &[(&str, JsonValue)]) {
        self.records
            .push(fields.iter().map(|(k, v)| (k.to_string(), v.clone())).collect());
    }

    pub fn len(&self) -> usize {
        self.records.len()
    }

    pub fn is_empty(&self) -> bool {
        self.records.is_empty()
    }

    /// Serialize the whole document.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", json_escape(&self.bench)));
        out.push_str("  \"records\": [\n");
        for (i, rec) in self.records.iter().enumerate() {
            let fields: Vec<String> = rec
                .iter()
                .map(|(k, v)| format!("\"{}\": {}", json_escape(k), v.render()))
                .collect();
            let comma = if i + 1 < self.records.len() { "," } else { "" };
            out.push_str(&format!("    {{{}}}{comma}\n", fields.join(", ")));
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Write the document to `path`.
    pub fn write(&self, path: &std::path::Path) -> std::io::Result<()> {
        std::fs::write(path, self.render())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_returns_sane_stats() {
        let cfg = BenchConfig { warmup_secs: 0.01, measure_secs: 0.05, max_iters: 100, min_iters: 3 };
        let s = bench("spin", cfg, || {
            std::hint::black_box((0..1000).sum::<usize>());
        });
        assert!(s.iters >= 3);
        assert!(s.mean > 0.0);
        assert!(s.min <= s.p50 && s.p50 <= s.p95 && s.p95 <= s.max);
    }

    #[test]
    fn bench_ordering_detects_slower_work() {
        let cfg = BenchConfig { warmup_secs: 0.01, measure_secs: 0.05, max_iters: 200, min_iters: 3 };
        let fast = bench("fast", cfg, || {
            std::hint::black_box((0..std::hint::black_box(100usize)).sum::<usize>());
        });
        let slow = bench("slow", cfg, || {
            std::hint::black_box(
                (0..std::hint::black_box(1_000_000usize)).map(|i| i ^ 3).sum::<usize>(),
            );
        });
        assert!(slow.p50 > fast.p50, "slow {} <= fast {}", slow.p50, fast.p50);
    }

    #[test]
    fn throughput_inverts_mean() {
        let s = Stats {
            name: "x".into(),
            iters: 1,
            mean: 0.5,
            p50: 0.5,
            p95: 0.5,
            min: 0.5,
            max: 0.5,
        };
        assert!((s.throughput(100.0) - 200.0).abs() < 1e-9);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new(&["model", "acc"]);
        t.row(&["LSTM".into(), "89.86".into()]);
        t.row(&["ours".into(), "98.49".into()]);
        t.print("Table 2 (smoke)");
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn table_rejects_bad_row() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn perf_json_renders_valid_structure() {
        let mut p = PerfJson::new("fig1_threads");
        p.push(&[
            ("case", JsonValue::Str("matmul \"odd\"".into())),
            ("threads", JsonValue::Int(4)),
            ("mean_s", JsonValue::Num(0.0125)),
            ("ok", JsonValue::Bool(true)),
            ("bad", JsonValue::Num(f64::NAN)),
        ]);
        p.push(&[("threads", JsonValue::Int(1))]);
        let s = p.render();
        assert!(s.contains("\"bench\": \"fig1_threads\""));
        assert!(s.contains("\"threads\": 4"));
        assert!(s.contains("\"mean_s\": 0.0125"));
        assert!(s.contains("\"case\": \"matmul \\\"odd\\\"\""));
        assert!(s.contains("\"bad\": null"));
        assert_eq!(p.len(), 2);
        // balanced braces/brackets as a cheap well-formedness check
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert_eq!(s.matches('[').count(), s.matches(']').count());
    }

    #[test]
    fn perf_json_roundtrips_to_disk() {
        let dir = std::env::temp_dir().join("plmu_perfjson_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("out.json");
        let mut p = PerfJson::new("t");
        p.push(&[("v", JsonValue::Num(1.5))]);
        p.write(&path).unwrap();
        let back = std::fs::read_to_string(&path).unwrap();
        assert_eq!(back, p.render());
    }
}
