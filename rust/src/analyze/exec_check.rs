//! Pass 3: exec disjointness + budget audit.
//!
//! The exec layer's one `unsafe` contract is the `SendPtr` fan-out in
//! `parallel_rows_mut`/`parallel_rows_async`: chunk closures get
//! `&mut [f32]` slices manufactured from a shared base pointer, which
//! is sound **iff** the chunk ranges are pairwise disjoint and in
//! bounds.  The dynamic suites (`exec_equivalence`) catch a violation
//! only if two racing chunks happen to collide during a sampled run;
//! [`check_ranges`] proves the property statically from the partition
//! itself, and at `PLMU_VERIFY>=1` the dispatch sites call it on every
//! fan-out *before* the first `from_raw_parts_mut`.
//!
//! At `PLMU_VERIFY=2` the pool additionally records a [`PoolEvent`] log
//! (via [`super::audit`]) and [`check_pool_events`] replays it offline —
//! the static companion to `exec_equivalence`'s peak-concurrency
//! assertions:
//!
//!  * every chunk index of a completed job claimed **exactly once**
//!    (at-most-once for panicked jobs, whose drain intentionally
//!    abandons unclaimed chunks);
//!  * no chunk event after its job's completion event (a straggler
//!    helper touching a job the caller already returned from would be a
//!    use-after-free of the transmuted closure);
//!  * at every instant the set of in-flight chunks is within the job's
//!    `workers_cap`, and the sum of their sub-budgets within the job's
//!    budget — which itself must not exceed the root thread budget
//!    (`PLMU_THREADS`), proving budget splits never over-subscribe.

use super::{audit, Finding, Pass};
use std::sync::atomic::{AtomicU64, Ordering};

/// Count of chunk partitions validated by [`check_ranges`] since
/// process start — lets `plmu analyze` report how many fan-outs each
/// case actually exercised.
static PARTITIONS_VALIDATED: AtomicU64 = AtomicU64::new(0);

pub fn partitions_validated() -> u64 {
    PARTITIONS_VALIDATED.load(Ordering::Relaxed)
}

/// Validate one chunk partition of `[0, total_len)`: every range in
/// bounds and well-formed, ranges pairwise disjoint, and the union
/// covering the whole buffer (the dispatchers never skip elements).
/// Returns findings; empty = the fan-out is sound.
pub fn check_ranges(total_len: usize, ranges: &[(usize, usize)]) -> Vec<Finding> {
    PARTITIONS_VALIDATED.fetch_add(1, Ordering::Relaxed);
    let mut findings = Vec::new();
    for (i, &(start, end)) in ranges.iter().enumerate() {
        if start > end {
            findings.push(Finding::new(
                Pass::Exec,
                format!("chunk {i}: inverted range [{start}, {end})"),
            ));
        }
        if end > total_len {
            findings.push(Finding::new(
                Pass::Exec,
                format!("chunk {i}: range [{start}, {end}) exceeds buffer length {total_len}"),
            ));
        }
    }
    if !findings.is_empty() {
        return findings;
    }
    let mut sorted: Vec<(usize, usize, usize)> =
        ranges.iter().enumerate().map(|(i, &(s, e))| (s, e, i)).collect();
    sorted.sort_unstable();
    let mut covered = 0usize;
    for w in sorted.windows(2) {
        let (s0, e0, i0) = w[0];
        let (s1, e1, i1) = w[1];
        if e0 > s1 {
            findings.push(Finding::new(
                Pass::Exec,
                format!(
                    "chunks {i0} and {i1} overlap: [{s0}, {e0}) ∩ [{s1}, {e1}) — aliased &mut slices"
                ),
            ));
        }
    }
    if findings.is_empty() {
        // disjoint: coverage is just endpoint stitching
        for &(s, e, i) in &sorted {
            if s != covered {
                findings.push(Finding::new(
                    Pass::Exec,
                    format!("gap before chunk {i}: [{covered}, {s}) is never written"),
                ));
            }
            covered = e;
        }
        if covered != total_len && !sorted.is_empty() {
            findings.push(Finding::new(
                Pass::Exec,
                format!("tail [{covered}, {total_len}) is never written"),
            ));
        }
    }
    findings
}

/// One pool event at `PLMU_VERIFY=2` (recorded via [`audit::record`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PoolEvent {
    /// a multi-chunk job entered the pool (inline/serial paths record
    /// nothing — there is no concurrency to audit)
    JobBegin { job: u64, chunks: usize, workers_cap: usize, budget: usize, root: usize },
    /// a worker claimed chunk `idx` and entered it with `sub_budget`
    ChunkStart { job: u64, idx: usize, sub_budget: usize },
    ChunkEnd { job: u64, idx: usize },
    /// the submitting thread observed completion and returned
    JobEnd { job: u64, panicked: bool },
}

impl PoolEvent {
    pub fn job(&self) -> u64 {
        match *self {
            PoolEvent::JobBegin { job, .. }
            | PoolEvent::ChunkStart { job, .. }
            | PoolEvent::ChunkEnd { job, .. }
            | PoolEvent::JobEnd { job, .. } => job,
        }
    }
}

/// Replay a drained, seq-ordered pool event stream (the output of
/// [`audit::drain_pool_events`]) and check the claiming/budget
/// discipline per job.  Jobs with no `JobEnd` in the stream were still
/// in flight at drain time and are skipped (their events complete in
/// the next drain).
pub fn check_pool_events(events: &[(u64, PoolEvent)]) -> Vec<Finding> {
    use std::collections::{HashMap, HashSet};
    let mut findings = Vec::new();

    let mut jobs: HashMap<u64, Vec<(u64, PoolEvent)>> = HashMap::new();
    let mut order: Vec<u64> = Vec::new();
    for &(seq, ev) in events {
        let id = ev.job();
        let per = jobs.entry(id).or_default();
        if per.is_empty() {
            order.push(id);
        }
        per.push((seq, ev));
    }

    for id in order {
        let evs = &jobs[&id];
        let Some(&(end_seq, PoolEvent::JobEnd { panicked, .. })) =
            evs.iter().find(|(_, e)| matches!(e, PoolEvent::JobEnd { .. }))
        else {
            continue; // in flight at drain time
        };
        let Some(&(begin_seq, PoolEvent::JobBegin { chunks, workers_cap, budget, root, .. })) =
            evs.iter().find(|(_, e)| matches!(e, PoolEvent::JobBegin { .. }))
        else {
            findings.push(Finding::new(Pass::Exec, format!("job {id}: completed without a JobBegin event")));
            continue;
        };

        if budget > root {
            findings.push(Finding::new(
                Pass::Exec,
                format!("job {id}: budget {budget} exceeds the root thread budget {root}"),
            ));
        }

        let mut claims: HashMap<usize, usize> = HashMap::new();
        let mut active: HashSet<usize> = HashSet::new();
        let mut active_budget = 0usize;
        for &(seq, ev) in evs {
            match ev {
                PoolEvent::JobBegin { .. } | PoolEvent::JobEnd { .. } => {}
                PoolEvent::ChunkStart { idx, sub_budget, .. } => {
                    if seq < begin_seq || seq > end_seq {
                        findings.push(Finding::new(
                            Pass::Exec,
                            format!("job {id}: chunk {idx} started outside the job's lifetime — \
                                     a straggler worker raced job completion"),
                        ));
                    }
                    *claims.entry(idx).or_insert(0) += 1;
                    if idx >= chunks {
                        findings.push(Finding::new(
                            Pass::Exec,
                            format!("job {id}: claimed chunk {idx} out of range {chunks}"),
                        ));
                    }
                    if !active.insert(idx) {
                        findings.push(Finding::new(
                            Pass::Exec,
                            format!("job {id}: chunk {idx} started while already running"),
                        ));
                    }
                    active_budget += sub_budget;
                    if active.len() > workers_cap {
                        findings.push(Finding::new(
                            Pass::Exec,
                            format!(
                                "job {id}: {} chunks in flight exceeds workers_cap {workers_cap}",
                                active.len()
                            ),
                        ));
                    }
                    // `sub_budget` floors at 1 per chunk, so a job whose
                    // budget is below its workers_cap legitimately sums
                    // to workers_cap — the invariant is the max of both
                    if active_budget > budget.max(workers_cap) {
                        findings.push(Finding::new(
                            Pass::Exec,
                            format!(
                                "job {id}: concurrent sub-budgets sum to {active_budget}, \
                                 over the job budget {budget}"
                            ),
                        ));
                    }
                }
                PoolEvent::ChunkEnd { idx, .. } => {
                    if seq > end_seq {
                        findings.push(Finding::new(
                            Pass::Exec,
                            format!("job {id}: chunk {idx} finished after JobEnd — \
                                     use-after-return of the job closure"),
                        ));
                    }
                    match evs.iter().find(|(s2, e2)| {
                        *s2 < seq && matches!(e2, PoolEvent::ChunkStart { idx: i2, .. } if *i2 == idx)
                    }) {
                        Some(_) => {
                            if active.remove(&idx) {
                                // find this chunk's sub_budget to retire it
                                if let Some((_, PoolEvent::ChunkStart { sub_budget, .. })) =
                                    evs.iter().rev().find(|(s2, e2)| {
                                        *s2 < seq
                                            && matches!(e2, PoolEvent::ChunkStart { idx: i2, .. } if *i2 == idx)
                                    })
                                {
                                    active_budget -= sub_budget;
                                }
                            }
                        }
                        None => {
                            findings.push(Finding::new(
                                Pass::Exec,
                                format!("job {id}: chunk {idx} ended without a start"),
                            ));
                        }
                    }
                }
            }
        }
        for idx in 0..chunks {
            match claims.get(&idx).copied().unwrap_or(0) {
                0 if !panicked => findings.push(Finding::new(
                    Pass::Exec,
                    format!("job {id}: chunk {idx} was never claimed"),
                )),
                n if n > 1 => findings.push(Finding::new(
                    Pass::Exec,
                    format!("job {id}: chunk {idx} claimed {n} times — the claim counter raced"),
                )),
                _ => {}
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    // ---- check_ranges

    #[test]
    fn exact_partition_is_clean() {
        assert!(check_ranges(10, &[(0, 4), (4, 8), (8, 10)]).is_empty());
        assert!(check_ranges(0, &[]).is_empty());
    }

    #[test]
    fn overlap_is_caught() {
        let f = check_ranges(10, &[(0, 5), (4, 10)]);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("overlap"), "{}", f[0]);
    }

    #[test]
    fn out_of_bounds_is_caught() {
        let f = check_ranges(8, &[(0, 4), (4, 9)]);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("exceeds buffer length"), "{}", f[0]);
    }

    #[test]
    fn gap_and_tail_are_caught() {
        let f = check_ranges(10, &[(0, 3), (5, 8)]);
        assert_eq!(f.len(), 2, "{f:?}");
        assert!(f[0].detail.contains("gap"), "{}", f[0]);
        assert!(f[1].detail.contains("tail"), "{}", f[1]);
    }

    #[test]
    fn inverted_range_is_caught() {
        let f = check_ranges(10, &[(6, 2)]);
        assert!(f.iter().any(|x| x.detail.contains("inverted")), "{f:?}");
    }

    #[test]
    fn validation_counter_advances() {
        let before = partitions_validated();
        check_ranges(4, &[(0, 4)]);
        assert!(partitions_validated() > before);
    }

    // ---- check_pool_events

    fn job(id: u64, seq0: u64, chunks: usize, cap: usize, budget: usize) -> Vec<(u64, PoolEvent)> {
        // serial claim order: start/end each chunk in sequence
        let mut evs = vec![(seq0, PoolEvent::JobBegin { job: id, chunks, workers_cap: cap, budget, root: budget })];
        let mut seq = seq0 + 1;
        for idx in 0..chunks {
            evs.push((seq, PoolEvent::ChunkStart { job: id, idx, sub_budget: budget / cap.max(1).min(chunks).max(1) }));
            evs.push((seq + 1, PoolEvent::ChunkEnd { job: id, idx }));
            seq += 2;
        }
        evs.push((seq, PoolEvent::JobEnd { job: id, panicked: false }));
        evs
    }

    #[test]
    fn serial_claims_are_clean() {
        let evs = job(1, 0, 4, 2, 2);
        assert!(check_pool_events(&evs).is_empty(), "{:?}", check_pool_events(&evs));
    }

    #[test]
    fn double_claim_is_caught() {
        let evs = vec![
            (0, PoolEvent::JobBegin { job: 2, chunks: 2, workers_cap: 2, budget: 2, root: 2 }),
            (1, PoolEvent::ChunkStart { job: 2, idx: 0, sub_budget: 1 }),
            (2, PoolEvent::ChunkEnd { job: 2, idx: 0 }),
            (3, PoolEvent::ChunkStart { job: 2, idx: 1, sub_budget: 1 }),
            (4, PoolEvent::ChunkEnd { job: 2, idx: 1 }),
            (5, PoolEvent::ChunkStart { job: 2, idx: 0, sub_budget: 1 }), // raced claim counter
            (6, PoolEvent::ChunkEnd { job: 2, idx: 0 }),
            (7, PoolEvent::JobEnd { job: 2, panicked: false }),
        ];
        let f = check_pool_events(&evs);
        assert!(f.iter().any(|x| x.detail.contains("claimed 2 times")), "{f:?}");
    }

    #[test]
    fn unclaimed_chunk_is_caught() {
        let evs = vec![
            (0, PoolEvent::JobBegin { job: 3, chunks: 2, workers_cap: 2, budget: 2, root: 2 }),
            (1, PoolEvent::ChunkStart { job: 3, idx: 0, sub_budget: 1 }),
            (2, PoolEvent::ChunkEnd { job: 3, idx: 0 }),
            (3, PoolEvent::JobEnd { job: 3, panicked: false }),
        ];
        let f = check_pool_events(&evs);
        assert!(f.iter().any(|x| x.detail.contains("never claimed")), "{f:?}");
    }

    #[test]
    fn panicked_job_may_abandon_chunks() {
        let evs = vec![
            (0, PoolEvent::JobBegin { job: 4, chunks: 3, workers_cap: 2, budget: 2, root: 2 }),
            (1, PoolEvent::ChunkStart { job: 4, idx: 0, sub_budget: 1 }),
            (2, PoolEvent::ChunkEnd { job: 4, idx: 0 }),
            (3, PoolEvent::JobEnd { job: 4, panicked: true }),
        ];
        assert!(check_pool_events(&evs).is_empty(), "{:?}", check_pool_events(&evs));
    }

    #[test]
    fn chunk_after_job_end_is_caught() {
        let evs = vec![
            (0, PoolEvent::JobBegin { job: 5, chunks: 1, workers_cap: 1, budget: 1, root: 1 }),
            (1, PoolEvent::ChunkStart { job: 5, idx: 0, sub_budget: 1 }),
            (2, PoolEvent::JobEnd { job: 5, panicked: false }),
            (3, PoolEvent::ChunkEnd { job: 5, idx: 0 }),
        ];
        let f = check_pool_events(&evs);
        assert!(f.iter().any(|x| x.detail.contains("after JobEnd")), "{f:?}");
    }

    #[test]
    fn over_budget_event_log_is_caught() {
        // two chunks live at once, each with sub-budget 2, job budget 2
        let evs = vec![
            (0, PoolEvent::JobBegin { job: 6, chunks: 2, workers_cap: 2, budget: 2, root: 4 }),
            (1, PoolEvent::ChunkStart { job: 6, idx: 0, sub_budget: 2 }),
            (2, PoolEvent::ChunkStart { job: 6, idx: 1, sub_budget: 2 }),
            (3, PoolEvent::ChunkEnd { job: 6, idx: 0 }),
            (4, PoolEvent::ChunkEnd { job: 6, idx: 1 }),
            (5, PoolEvent::JobEnd { job: 6, panicked: false }),
        ];
        let f = check_pool_events(&evs);
        assert!(f.iter().any(|x| x.detail.contains("over the job budget")), "{f:?}");
    }

    #[test]
    fn budget_over_root_is_caught() {
        let evs = vec![
            (0, PoolEvent::JobBegin { job: 7, chunks: 1, workers_cap: 1, budget: 8, root: 4 }),
            (1, PoolEvent::ChunkStart { job: 7, idx: 0, sub_budget: 8 }),
            (2, PoolEvent::ChunkEnd { job: 7, idx: 0 }),
            (3, PoolEvent::JobEnd { job: 7, panicked: false }),
        ];
        let f = check_pool_events(&evs);
        assert!(f.iter().any(|x| x.detail.contains("root thread budget")), "{f:?}");
    }

    #[test]
    fn workers_cap_violation_is_caught() {
        let evs = vec![
            (0, PoolEvent::JobBegin { job: 8, chunks: 3, workers_cap: 1, budget: 3, root: 3 }),
            (1, PoolEvent::ChunkStart { job: 8, idx: 0, sub_budget: 1 }),
            (2, PoolEvent::ChunkStart { job: 8, idx: 1, sub_budget: 1 }),
            (3, PoolEvent::ChunkEnd { job: 8, idx: 0 }),
            (4, PoolEvent::ChunkEnd { job: 8, idx: 1 }),
            (5, PoolEvent::ChunkStart { job: 8, idx: 2, sub_budget: 1 }),
            (6, PoolEvent::ChunkEnd { job: 8, idx: 2 }),
            (7, PoolEvent::JobEnd { job: 8, panicked: false }),
        ];
        let f = check_pool_events(&evs);
        assert!(f.iter().any(|x| x.detail.contains("workers_cap")), "{f:?}");
    }

    #[test]
    fn in_flight_jobs_are_skipped() {
        let evs = vec![
            (0, PoolEvent::JobBegin { job: 9, chunks: 2, workers_cap: 2, budget: 2, root: 2 }),
            (1, PoolEvent::ChunkStart { job: 9, idx: 0, sub_budget: 1 }),
        ];
        assert!(check_pool_events(&evs).is_empty());
    }
}
