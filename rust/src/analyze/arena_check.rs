//! Pass 2: arena alias/liveness analysis.
//!
//! At `PLMU_VERIFY=2` every [`crate::exec::arena::Arena`] records a
//! buffer-identity event per `take` (issue) and per `put`/`release`
//! (reclaim) — the buffer's pointer value as an opaque identity, its
//! capacity in bytes, and for reclaims which arena (if any) originally
//! issued the buffer.  [`check_arena_log`] replays that stream and
//! proves the liveness discipline the recycler's safety rests on:
//!
//!  * **no aliased issue** — a buffer identity is never issued while a
//!    previous issue of the same identity is still live (two `Tensor`s
//!    believing they own the same allocation);
//!  * **no double-release / use-after-release** — a reclaim of an
//!    identity that is not currently live means either the same buffer
//!    was released twice or a buffer kept being used after its identity
//!    was re-issued to someone else;
//!  * **no cross-arena release** — a reclaim whose issuing arena is a
//!    *different* arena: the `--pipeline` hazard where two arenas are in
//!    flight and a tensor recorded under one is dropped under the
//!    other, silently migrating buffers between free lists.  (Reclaims
//!    with no issuing arena are legitimate: foreign `Vec`s — e.g. a
//!    tensor built outside any scope — are adopted by design.)
//!
//! The replay also computes a **peak-liveness memory plan** — the high-
//! water mark of concurrently-live issued bytes — and cross-checks the
//! event stream against the arena's own [`ArenaStats`] counters:
//! issues = hits + misses, fresh issues = misses, and peak-live bytes
//! bounded by the fresh bytes the arena ever allocated (recycling can
//! only reduce the footprint, never grow it).

use super::{Finding, Pass};
use crate::exec::arena::ArenaStats;
use std::collections::HashMap;

/// One buffer-identity event, recorded by the instrumented arena at
/// `PLMU_VERIFY=2`.  `buf` is the buffer's pointer value — an opaque
/// identity, never dereferenced.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ArenaEvent {
    /// `take` handed out a buffer: `fresh` = newly allocated (miss),
    /// otherwise recycled off a free list (hit).  `bytes` = capacity.
    Issue { buf: usize, bytes: usize, fresh: bool },
    /// `put`/`release` got a buffer back.  `issued_by` = the arena that
    /// the identity registry says issued it (`None` = foreign buffer,
    /// adopted silently by design).
    Reclaim { buf: usize, bytes: usize, issued_by: Option<u64> },
}

/// Replay result: findings plus the memory plan.
#[derive(Debug, Default)]
pub struct ArenaReport {
    pub findings: Vec<Finding>,
    /// high-water mark of concurrently-live issued bytes
    pub peak_live_bytes: usize,
    /// issued-and-never-reclaimed identities at end of log (not a
    /// finding by itself: tensors legitimately outlive a scope)
    pub leaked: usize,
}

/// Replay `events` (one arena's log, in order) and check the liveness
/// discipline; `stats` (when given) is cross-checked against the event
/// stream.  `arena_id` is only used for provenance in messages.
pub fn check_arena_log(arena_id: u64, events: &[ArenaEvent], stats: Option<&ArenaStats>) -> ArenaReport {
    let mut report = ArenaReport::default();
    // identity -> bytes for currently-live issues
    let mut live: HashMap<usize, usize> = HashMap::new();
    let mut live_bytes = 0usize;
    let (mut issues, mut fresh_issues, mut reclaims) = (0u64, 0u64, 0u64);

    for (i, ev) in events.iter().enumerate() {
        match *ev {
            ArenaEvent::Issue { buf, bytes, fresh } => {
                issues += 1;
                fresh_issues += fresh as u64;
                if let Some(prev) = live.insert(buf, bytes) {
                    report.findings.push(Finding::new(
                        Pass::Arena,
                        format!(
                            "arena {arena_id} event {i}: buffer {buf:#x} ({bytes} B) issued while a \
                             previous issue ({prev} B) is still live — aliased ownership"
                        ),
                    ));
                    live_bytes -= prev;
                }
                live_bytes += bytes;
                report.peak_live_bytes = report.peak_live_bytes.max(live_bytes);
            }
            ArenaEvent::Reclaim { buf, bytes, issued_by } => {
                reclaims += 1;
                match issued_by {
                    Some(owner) if owner != arena_id => {
                        report.findings.push(Finding::new(
                            Pass::Arena,
                            format!(
                                "arena {arena_id} event {i}: buffer {buf:#x} ({bytes} B) released here \
                                 but issued by arena {owner} — cross-arena release (two arenas in \
                                 flight under --pipeline?)"
                            ),
                        ));
                    }
                    Some(_) => match live.remove(&buf) {
                        Some(b) => live_bytes -= b,
                        None => {
                            report.findings.push(Finding::new(
                                Pass::Arena,
                                format!(
                                    "arena {arena_id} event {i}: buffer {buf:#x} ({bytes} B) reclaimed \
                                     while not live — double-release, or use after its identity was \
                                     re-issued"
                                ),
                            ));
                        }
                    },
                    // foreign buffer adopted — by-design flow, nothing to check
                    None => {
                        if let Some(b) = live.remove(&buf) {
                            live_bytes -= b;
                        }
                    }
                }
            }
        }
    }
    report.leaked = live.len();

    if let Some(s) = stats {
        if issues != s.hits + s.misses {
            report.findings.push(Finding::new(
                Pass::Arena,
                format!(
                    "arena {arena_id}: {issues} issue events but stats say hits {} + misses {} = {}",
                    s.hits,
                    s.misses,
                    s.hits + s.misses
                ),
            ));
        }
        if fresh_issues != s.misses {
            report.findings.push(Finding::new(
                Pass::Arena,
                format!("arena {arena_id}: {fresh_issues} fresh issues but stats count {} misses", s.misses),
            ));
        }
        if reclaims != s.recycled + s.dropped {
            report.findings.push(Finding::new(
                Pass::Arena,
                format!(
                    "arena {arena_id}: {reclaims} reclaim events but stats say recycled {} + dropped {} = {}",
                    s.recycled,
                    s.dropped,
                    s.recycled + s.dropped
                ),
            ));
        }
        if report.peak_live_bytes as u64 > s.fresh_bytes {
            report.findings.push(Finding::new(
                Pass::Arena,
                format!(
                    "arena {arena_id}: peak-live plan {} B exceeds fresh allocation {} B — \
                     liveness replay and allocator disagree",
                    report.peak_live_bytes, s.fresh_bytes
                ),
            ));
        }
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    const A: u64 = 1;

    fn issue(buf: usize, bytes: usize, fresh: bool) -> ArenaEvent {
        ArenaEvent::Issue { buf, bytes, fresh }
    }

    fn reclaim(buf: usize, bytes: usize, issued_by: Option<u64>) -> ArenaEvent {
        ArenaEvent::Reclaim { buf, bytes, issued_by }
    }

    #[test]
    fn clean_cycle_no_findings_and_peak_plan() {
        let events = [
            issue(0x100, 64, true),
            issue(0x200, 128, true),
            reclaim(0x100, 64, Some(A)),
            issue(0x100, 64, false), // recycled
            reclaim(0x100, 64, Some(A)),
            reclaim(0x200, 128, Some(A)),
        ];
        let r = check_arena_log(A, &events, None);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
        assert_eq!(r.peak_live_bytes, 192);
        assert_eq!(r.leaked, 0);
    }

    #[test]
    fn double_release_is_caught() {
        let events = [
            issue(0x100, 64, true),
            reclaim(0x100, 64, Some(A)),
            reclaim(0x100, 64, Some(A)),
        ];
        let r = check_arena_log(A, &events, None);
        assert_eq!(r.findings.len(), 1);
        assert!(r.findings[0].detail.contains("double-release"), "{}", r.findings[0]);
    }

    #[test]
    fn aliased_issue_is_caught() {
        let events = [issue(0x100, 64, true), issue(0x100, 64, false)];
        let r = check_arena_log(A, &events, None);
        assert_eq!(r.findings.len(), 1);
        assert!(r.findings[0].detail.contains("aliased"), "{}", r.findings[0]);
    }

    #[test]
    fn cross_arena_release_is_caught() {
        let events = [reclaim(0x300, 32, Some(7))];
        let r = check_arena_log(A, &events, None);
        assert_eq!(r.findings.len(), 1);
        assert!(r.findings[0].detail.contains("cross-arena"), "{}", r.findings[0]);
    }

    #[test]
    fn foreign_adoption_is_silent() {
        let r = check_arena_log(A, &[reclaim(0x400, 16, None)], None);
        assert!(r.findings.is_empty(), "{:?}", r.findings);
    }

    #[test]
    fn stats_cross_check() {
        let events = [issue(0x100, 64, true), reclaim(0x100, 64, Some(A)), issue(0x100, 64, false)];
        let good = ArenaStats { hits: 1, misses: 1, fresh_bytes: 64, recycled: 1, dropped: 0 };
        assert!(check_arena_log(A, &events, Some(&good)).findings.is_empty());
        let bad = ArenaStats { hits: 5, misses: 1, fresh_bytes: 64, recycled: 1, dropped: 0 };
        let r = check_arena_log(A, &events, Some(&bad));
        assert!(!r.findings.is_empty());
        assert!(r.findings[0].detail.contains("stats"), "{}", r.findings[0]);
    }

    #[test]
    fn peak_exceeding_fresh_bytes_is_flagged() {
        let events = [issue(0x100, 4096, true)];
        let s = ArenaStats { hits: 0, misses: 1, fresh_bytes: 64, recycled: 0, dropped: 0 };
        let r = check_arena_log(A, &events, Some(&s));
        assert!(r.findings.iter().any(|f| f.detail.contains("peak-live")), "{:?}", r.findings);
    }
}
