//! Static analysis over the crate's three unsafe-adjacent substrates:
//! the autograd tape, the buffer arena, and the exec dispatch plan.
//!
//! The repo's bit-exactness story is enforced *dynamically* by the
//! differential suites (`exec_equivalence`, `simd_equivalence`,
//! `fusion_equivalence`, `scan_equivalence`) — tests that must happen
//! to hit a violation.  This module adds the *static* companion: four
//! passes that check the invariants those suites rely on **before**
//! execution, over recorded structures rather than sampled runs.
//!
//!  1. **Tape verifier** ([`tape`]) — walks a [`tape::TapeView`] of the
//!     recorded autograd graph and checks topology (parents strictly
//!     earlier, so a `NodeId` held across `Graph::reset` is caught as a
//!     forward reference), per-op operand shape/arity legality, and
//!     fused-op rewrite legality (an `Affine`/`Add2RowAct`/`Add3Act`
//!     node must match the documented exact-rewrite pattern from
//!     `fusion.rs`/DESIGN.md), with op-provenance error messages.
//!  2. **Arena alias/liveness analysis** ([`arena_check`]) — replays the
//!     buffer-identity event stream `exec/arena.rs` records at level 2
//!     and proves no double-release, no re-issue of a live buffer, no
//!     cross-arena release (the `--pipeline` two-arenas hazard), and a
//!     peak-liveness memory plan consistent with `ArenaStats`.
//!  3. **Exec disjointness + budget audit** ([`exec_check`]) — validates
//!     every `parallel_rows_*` chunk partition pairwise-disjoint,
//!     in-bounds, and covering before the `SendPtr` fan-out (level >= 1,
//!     at the dispatch site), and replays the level-2 pool event log to
//!     prove every chunk claimed exactly once, no chunk executed after
//!     its job completed, and concurrent sub-budget sums within each
//!     job's budget.
//!  4. **Source conformance lint** ([`lint`]) — a scanner over
//!     `rust/src` enforcing repo rules clippy cannot express (thread
//!     spawns outside `exec/`, `HashMap` on fingerprinted paths, env
//!     knobs read outside `util::env_knob`, simd kernel triples).
//!
//! # The `PLMU_VERIFY` knob
//!
//! * `0` (default) — off.  The hooks compile to one relaxed atomic load
//!   and a predictable branch per *dispatch/backward* (never per
//!   element); no events are recorded, no allocation happens.
//! * `1` — cheap checks: tape verification before every `backward`,
//!   chunk-partition validation before every `SendPtr` fan-out.
//! * `2` — full audit: level 1 plus arena buffer-identity events and
//!   the pool event log for offline replay.
//!
//! Resolved once via [`crate::util::env_knob`], overridable with
//! [`set_level`] (the `plmu analyze` driver forces level 2 for its
//! runs).  None of the instrumentation touches f32 math or scheduling
//! decisions, so fingerprints are byte-identical across levels — CI
//! proves that by running the train-dp fingerprint under
//! `PLMU_VERIFY=2` against the level-0 reference.

pub mod arena_check;
pub mod audit;
pub mod exec_check;
pub mod lint;
pub mod tape;

use std::fmt;
use std::sync::atomic::{AtomicUsize, Ordering};

// ----------------------------------------------------------------- knob

/// Verify-level knob: 0 = unresolved, else `level + 1` (the resolved
/// level is 0, 1, or 2).  Same lazy idiom as `PLMU_SIMD`/`PLMU_FUSION`.
static VERIFY_LEVEL: AtomicUsize = AtomicUsize::new(0);

/// The active `PLMU_VERIFY` level (0 = off, 1 = cheap checks, 2 = full
/// audit), resolving the env default on first read.
pub fn level() -> usize {
    match VERIFY_LEVEL.load(Ordering::Relaxed) {
        0 => {
            let l = crate::util::env_knob::level_knob("PLMU_VERIFY", 2, 0);
            // racy double-resolve is benign: level_knob is deterministic
            VERIFY_LEVEL.store(l + 1, Ordering::Relaxed);
            l
        }
        v => v - 1,
    }
}

/// Force the verify level (tests, the `plmu analyze` driver; production
/// reads `PLMU_VERIFY` once).  Values above 2 clamp to 2.
pub fn set_level(l: usize) {
    VERIFY_LEVEL.store(l.min(2) + 1, Ordering::Relaxed);
}

/// Whether level-2 event recording (arena identities, pool events) is
/// active.  One relaxed load on the instrumented paths.
pub fn audit_enabled() -> bool {
    level() >= 2
}

// ------------------------------------------------------------- findings

/// Which analysis pass produced a finding.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pass {
    Tape,
    Arena,
    Exec,
    Lint,
}

impl Pass {
    pub fn name(&self) -> &'static str {
        match self {
            Pass::Tape => "tape",
            Pass::Arena => "arena",
            Pass::Exec => "exec",
            Pass::Lint => "lint-src",
        }
    }
}

/// One analyzer finding: the pass that produced it and a provenance
/// message (node id + op name for tape findings, buffer/arena ids for
/// arena findings, job/chunk ids for exec findings, file:line for lint
/// findings).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Finding {
    pub pass: Pass,
    pub detail: String,
}

impl Finding {
    pub fn new(pass: Pass, detail: impl Into<String>) -> Self {
        Finding { pass, detail: detail.into() }
    }
}

impl fmt::Display for Finding {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "[{}] {}", self.pass.name(), self.detail)
    }
}

// ------------------------------------------------------------- driver

/// Result of one pass over one model-family case.
#[derive(Debug)]
pub struct CaseReport {
    /// e.g. `"LmuParallel/fft"`
    pub case: String,
    /// tape nodes verified
    pub tape_nodes: usize,
    /// arena events replayed
    pub arena_events: usize,
    /// pool events replayed
    pub pool_events: usize,
    /// chunk partitions validated at the dispatch sites during the case
    pub partitions: u64,
    /// peak concurrently-live arena bytes (the memory plan)
    pub peak_live_bytes: usize,
    pub findings: Vec<Finding>,
}

/// Aggregate of [`analyze_models`]: one [`CaseReport`] per model family
/// x DN path.
#[derive(Debug, Default)]
pub struct AnalyzeReport {
    pub cases: Vec<CaseReport>,
}

impl AnalyzeReport {
    pub fn total_findings(&self) -> usize {
        self.cases.iter().map(|c| c.findings.len()).sum()
    }

    /// Per-pass report table plus every finding, the format `plmu
    /// analyze` prints.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "{:<22} {:>10} {:>12} {:>11} {:>11} {:>14} {:>9}\n",
            "case", "tape nodes", "arena events", "pool events", "partitions", "peak live", "findings"
        ));
        for c in &self.cases {
            out.push_str(&format!(
                "{:<22} {:>10} {:>12} {:>11} {:>11} {:>14} {:>9}\n",
                c.case,
                c.tape_nodes,
                c.arena_events,
                c.pool_events,
                c.partitions,
                crate::util::human_bytes(c.peak_live_bytes),
                c.findings.len(),
            ));
        }
        for c in &self.cases {
            for f in &c.findings {
                out.push_str(&format!("{}: {f}\n", c.case));
            }
        }
        out
    }
}

/// Run passes 1-3 over every in-tree model family (LmuParallel,
/// LmuSequential, LmuOriginal, Lstm) under both DN evaluation paths
/// (`fft` and `scan`): record a training tape and verify it, replay the
/// arena's buffer-identity events from three real optimizer steps, and
/// replay the pool's event log from those steps plus one synthetic
/// multi-chunk dispatch (the toy models are small enough that their own
/// kernels may legitimately stay serial).
///
/// Forces `PLMU_VERIFY=2` for the duration (restoring the previous
/// level) so the event streams exist to be checked.
pub fn analyze_models() -> AnalyzeReport {
    use crate::data::batcher::{BatchIter, SeqDataset};
    use crate::dn::scan::{self, ScanMode, DEFAULT_BLOCK};
    use crate::exec::arena::Arena;
    use crate::optim::Adam;
    use crate::tensor::Tensor;
    use crate::train::models::{ModelKind, SeqClassifier};
    use crate::train::train_step;
    use crate::util::Rng;

    let prev_level = level();
    set_level(2);
    let prev_mode = scan::mode();

    let kinds = [
        (ModelKind::LmuParallel, "LmuParallel"),
        (ModelKind::LmuSequential, "LmuSequential"),
        (ModelKind::LmuOriginal, "LmuOriginal"),
        (ModelKind::Lstm, "Lstm"),
    ];
    let modes = [(ScanMode::Fft, "fft"), (ScanMode::Scan { block: DEFAULT_BLOCK }, "scan")];

    let mut report = AnalyzeReport::default();
    for (kind, kname) in kinds {
        for (mode, mname) in modes {
            scan::set_mode(mode);
            let case = format!("{kname}/{mname}");
            let mut findings = Vec::new();

            // toy classification problem, same shape the train tests use
            let (b, n, dx, d, hidden, classes) = (4usize, 16usize, 1usize, 6usize, 8usize, 2usize);
            let mut rng = Rng::new(7);
            let mut store = crate::autograd::ParamStore::new();
            let model = SeqClassifier::new(kind, n, dx, d, hidden, classes, &mut store, &mut rng);
            let xs: Vec<Tensor> = (0..b).map(|_| Tensor::randn(&[n, dx], 1.0, &mut rng)).collect();
            let ys: Vec<usize> = (0..b).map(|i| i % classes).collect();
            let ds = SeqDataset::classification(xs, ys);
            let batch = BatchIter::sequential(&ds, b).next().expect("toy batch");

            // ---- passes 2+3 setup: drain stale pool events, count partitions
            audit::drain_pool_events();
            let partitions_before = exec_check::partitions_validated();

            // ---- pass 1: tape verification over a recorded loss graph
            let mut g = crate::autograd::Graph::new();
            let mut arena = Arena::new();
            let mut opt = Adam::new(1e-3);
            // three real steps: warmup (all fresh allocations), then two
            // steady-state steps that exercise recycling
            for _ in 0..3 {
                train_step(&model, &mut store, &mut opt, &mut g, &mut arena, &batch, None);
            }
            let view = g.tape_view();
            let tape_nodes = view.nodes.len();
            findings.extend(tape::verify(&view));

            // one synthetic fan-out so the pool log is never vacuously
            // empty (also covered: partition validation on a ragged tail)
            let mut buf = vec![0.0f32; 4096 + 3];
            let plan = crate::exec::Plan::sized(crate::exec::threads().max(2), 512, 1 << 20);
            crate::exec::parallel_rows_mut(&mut buf, 8, plan, |r0, block| {
                for (i, v) in block.iter_mut().enumerate() {
                    *v = (r0 + i) as f32;
                }
            });

            // ---- pass 2: replay the arena's buffer-identity events
            let events = arena.take_audit_events();
            let arena_events = events.len();
            let arena_report = arena_check::check_arena_log(arena.id(), &events, Some(&arena.stats()));
            let peak_live_bytes = arena_report.peak_live_bytes;
            findings.extend(arena_report.findings);

            // ---- pass 3: replay the pool event log
            let pool_log = audit::drain_pool_events();
            let pool_events = pool_log.len();
            findings.extend(exec_check::check_pool_events(&pool_log));
            let partitions = exec_check::partitions_validated() - partitions_before;

            report.cases.push(CaseReport {
                case,
                tape_nodes,
                arena_events,
                pool_events,
                partitions,
                peak_live_bytes,
                findings,
            });
        }
    }

    scan::set_mode(prev_mode);
    set_level(prev_level);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_knob_roundtrip() {
        let was = level();
        set_level(2);
        assert_eq!(level(), 2);
        assert!(audit_enabled());
        set_level(0);
        assert_eq!(level(), 0);
        assert!(!audit_enabled());
        set_level(9);
        assert_eq!(level(), 2, "levels clamp to 2");
        set_level(was);
    }

    #[test]
    fn finding_display_carries_pass_name() {
        let f = Finding::new(Pass::Tape, "node 3 (MatMul): inner dims 4 != 5");
        assert_eq!(f.to_string(), "[tape] node 3 (MatMul): inner dims 4 != 5");
    }
}
