//! Pass 1: the tape verifier.
//!
//! [`crate::autograd::Graph`] keeps its node and op representation
//! private (backward is one `match` over a sealed enum).  For analysis
//! it exports a [`TapeView`] — a public, value-free mirror of the
//! recorded tape: per node, the op (with the metadata its backward rule
//! consumes), parent ids, and the value/aux shapes.  [`verify`] walks
//! that view and checks, per node:
//!
//!  * **topology** — every parent id is strictly smaller than the node's
//!    own id.  `Graph::push` guarantees this by construction for ids
//!    minted by the same recording, so a violation means a `NodeId` was
//!    held across `Graph::reset()` and re-used against the next tape
//!    (the classic dangling-reference bug this pass exists to catch);
//!  * **arity** — the op's parent count matches its backward rule;
//!  * **shape legality** — the operand shapes satisfy the op's contract
//!    (elementwise ops exact-match, matmul inner dims agree, bias rows
//!    broadcast, slices stay in bounds, concat widths sum, DN ops agree
//!    with their operator's `(n, d)` and the batch layout);
//!  * **fusion-rule legality** — a fused node must be shape-for-shape
//!    replaceable by the unfused chain it rewrites (`Affine` ⇔
//!    `matmul → add_row → act`, `Add2RowAct` ⇔ `add → add_row → act`,
//!    `Add3Act` ⇔ `add → add → act`; see `fusion.rs` / DESIGN.md
//!    §Fusion).  Since the rewrites are exact, the legality conditions
//!    are precisely the shape contracts of the unfused chain, checked
//!    here against the single fused node.
//!
//! Every finding carries op provenance: `node {id} ({OpName}): ...`.

use super::{Finding, Pass};
use crate::tensor::Act;

/// Public mirror of one recorded tape node (no values, just structure).
#[derive(Clone, Debug)]
pub struct TapeNode {
    pub op: TapeOp,
    pub parents: Vec<usize>,
    /// shape of the node's value tensor
    pub shape: Vec<usize>,
    /// shape of the op-specific cached tensor, if any (softmax probs,
    /// MSE target, H_rev, entering carries)
    pub aux_shape: Option<Vec<usize>>,
}

/// Public mirror of `autograd::Op`, carrying exactly the metadata the
/// shape rules need (never the tensor data).
#[derive(Clone, Debug, PartialEq)]
pub enum TapeOp {
    Leaf,
    Param,
    Add,
    Sub,
    Mul,
    Neg,
    Scale,
    OneMinus,
    Abs,
    AddRow,
    MatMul,
    MatMulNT,
    SoftmaxRows,
    Tanh,
    Sigmoid,
    Relu,
    /// fused `act(x·W + bias_row)` — parents [x, w, bias]
    Affine { act: Option<Act> },
    /// fused `act((a + b) + bias_row)` — parents [a, b, bias]
    Add2RowAct { act: Option<Act> },
    /// fused `act((a + b) + c)` — parents [a, b, c]
    Add3Act { act: Option<Act> },
    MeanAll,
    SumAll,
    SliceRows { lo: usize },
    SliceCols { lo: usize, hi: usize },
    ConcatCols { widths: Vec<usize> },
    ConcatRows { heights: Vec<usize> },
    Reshape { from: Vec<usize> },
    /// `batch` = labels.len(); `max_label` = max recorded label
    SoftmaxXent { batch: usize, max_label: Option<usize> },
    /// `target_len` = element count of the cached target
    Mse { target_len: usize },
    /// `count` = ids.len(); `max_id` = max recorded token id
    Embedding { count: usize, max_id: Option<usize> },
    Dropout { mask_len: usize },
    /// operator dims captured from the recorded `Arc<DnOperator>`
    DnConv { n: usize, d: usize, batch: usize },
    DnLast { n: usize, d: usize, batch: usize },
    DnLastScan { d: usize, batch: usize },
}

impl TapeOp {
    pub fn name(&self) -> &'static str {
        match self {
            TapeOp::Leaf => "Leaf",
            TapeOp::Param => "Param",
            TapeOp::Add => "Add",
            TapeOp::Sub => "Sub",
            TapeOp::Mul => "Mul",
            TapeOp::Neg => "Neg",
            TapeOp::Scale => "Scale",
            TapeOp::OneMinus => "OneMinus",
            TapeOp::Abs => "Abs",
            TapeOp::AddRow => "AddRow",
            TapeOp::MatMul => "MatMul",
            TapeOp::MatMulNT => "MatMulNT",
            TapeOp::SoftmaxRows => "SoftmaxRows",
            TapeOp::Tanh => "Tanh",
            TapeOp::Sigmoid => "Sigmoid",
            TapeOp::Relu => "Relu",
            TapeOp::Affine { .. } => "Affine",
            TapeOp::Add2RowAct { .. } => "Add2RowAct",
            TapeOp::Add3Act { .. } => "Add3Act",
            TapeOp::MeanAll => "MeanAll",
            TapeOp::SumAll => "SumAll",
            TapeOp::SliceRows { .. } => "SliceRows",
            TapeOp::SliceCols { .. } => "SliceCols",
            TapeOp::ConcatCols { .. } => "ConcatCols",
            TapeOp::ConcatRows { .. } => "ConcatRows",
            TapeOp::Reshape { .. } => "Reshape",
            TapeOp::SoftmaxXent { .. } => "SoftmaxXent",
            TapeOp::Mse { .. } => "Mse",
            TapeOp::Embedding { .. } => "Embedding",
            TapeOp::Dropout { .. } => "Dropout",
            TapeOp::DnConv { .. } => "DnConv",
            TapeOp::DnLast { .. } => "DnLast",
            TapeOp::DnLastScan { .. } => "DnLastScan",
        }
    }

    /// Expected parent count; `None` = variadic (the concats: >= 1,
    /// length pinned by the widths/heights metadata instead).
    fn arity(&self) -> Option<usize> {
        match self {
            TapeOp::Leaf | TapeOp::Param => Some(0),
            TapeOp::Neg
            | TapeOp::Scale
            | TapeOp::OneMinus
            | TapeOp::Abs
            | TapeOp::SoftmaxRows
            | TapeOp::Tanh
            | TapeOp::Sigmoid
            | TapeOp::Relu
            | TapeOp::MeanAll
            | TapeOp::SumAll
            | TapeOp::SliceRows { .. }
            | TapeOp::SliceCols { .. }
            | TapeOp::Reshape { .. }
            | TapeOp::SoftmaxXent { .. }
            | TapeOp::Mse { .. }
            | TapeOp::Embedding { .. }
            | TapeOp::Dropout { .. }
            | TapeOp::DnConv { .. }
            | TapeOp::DnLast { .. }
            | TapeOp::DnLastScan { .. } => Some(1),
            TapeOp::Add | TapeOp::Sub | TapeOp::Mul | TapeOp::AddRow | TapeOp::MatMul | TapeOp::MatMulNT => Some(2),
            TapeOp::Affine { .. } | TapeOp::Add2RowAct { .. } | TapeOp::Add3Act { .. } => Some(3),
            TapeOp::ConcatCols { .. } | TapeOp::ConcatRows { .. } => None,
        }
    }
}

/// The exported tape: `nodes[i]` mirrors `Graph`'s node `i`.
#[derive(Clone, Debug, Default)]
pub struct TapeView {
    pub nodes: Vec<TapeNode>,
}

// Same row/col semantics as `Tensor`: rows = product of all-but-last
// dims (1 if the shape is empty — scalars), cols = last dim (1 if
// empty).
fn rows(shape: &[usize]) -> usize {
    match shape.split_last() {
        Some((_, rest)) => rest.iter().product(),
        None => 1,
    }
}

fn cols(shape: &[usize]) -> usize {
    shape.last().copied().unwrap_or(1)
}

fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Verify a tape view; returns one [`Finding`] per violation (empty =
/// clean).  Checks are per-node and keep going after a finding, so one
/// report covers the whole tape.
pub fn verify(view: &TapeView) -> Vec<Finding> {
    let mut findings = Vec::new();
    let mut fail = |id: usize, op: &TapeOp, msg: String| {
        findings.push(Finding::new(Pass::Tape, format!("node {id} ({}): {msg}", op.name())));
    };

    for (id, node) in view.nodes.iter().enumerate() {
        let op = &node.op;

        // -- topology: parents strictly earlier on the tape
        let mut topology_ok = true;
        for &p in &node.parents {
            if p >= id {
                topology_ok = false;
                fail(
                    id,
                    op,
                    format!(
                        "parent {p} is not earlier on the tape — a NodeId held across Graph::reset()?"
                    ),
                );
            }
        }

        // -- arity
        let arity_ok = match op.arity() {
            Some(n) if node.parents.len() != n => {
                fail(id, op, format!("arity {} (expected {n})", node.parents.len()));
                false
            }
            None if node.parents.is_empty() => {
                fail(id, op, "concat with no parents".to_string());
                false
            }
            _ => true,
        };
        if !(topology_ok && arity_ok) {
            // parent shapes unusable; shape rules would index garbage
            continue;
        }

        // -- shape legality (fusion legality for the fused ops: these
        //    are exactly the unfused chain's contracts)
        let p = |i: usize| -> &[usize] { &view.nodes[node.parents[i]].shape };
        let out = &node.shape[..];
        match op {
            TapeOp::Leaf | TapeOp::Param => {}
            TapeOp::Add | TapeOp::Sub | TapeOp::Mul => {
                if p(0) != p(1) {
                    fail(id, op, format!("operand shapes differ: {:?} vs {:?}", p(0), p(1)));
                } else if out != p(0) {
                    fail(id, op, format!("output shape {:?} != operand {:?}", out, p(0)));
                }
            }
            TapeOp::Neg | TapeOp::Scale | TapeOp::OneMinus | TapeOp::Abs | TapeOp::Tanh | TapeOp::Sigmoid | TapeOp::Relu => {
                if out != p(0) {
                    fail(id, op, format!("output shape {:?} != operand {:?}", out, p(0)));
                }
            }
            TapeOp::SoftmaxRows => {
                if out != p(0) {
                    fail(id, op, format!("output shape {:?} != operand {:?}", out, p(0)));
                }
                if node.aux_shape.as_deref() != Some(out) {
                    fail(id, op, format!("cached probs shape {:?} != output {:?}", node.aux_shape, out));
                }
            }
            TapeOp::AddRow => {
                if rows(p(1)) != 1 || cols(p(1)) != cols(p(0)) {
                    fail(id, op, format!("bias {:?} is not a ({},)-row for operand {:?}", p(1), cols(p(0)), p(0)));
                } else if out != p(0) {
                    fail(id, op, format!("output shape {:?} != operand {:?}", out, p(0)));
                }
            }
            TapeOp::MatMul => {
                if cols(p(0)) != rows(p(1)) {
                    fail(id, op, format!("inner dims disagree: {:?} · {:?}", p(0), p(1)));
                } else if rows(out) != rows(p(0)) || cols(out) != cols(p(1)) {
                    fail(id, op, format!("output {:?} != ({}, {})", out, rows(p(0)), cols(p(1))));
                }
            }
            TapeOp::MatMulNT => {
                if cols(p(0)) != cols(p(1)) {
                    fail(id, op, format!("inner dims disagree: {:?} · {:?}ᵀ", p(0), p(1)));
                } else if rows(out) != rows(p(0)) || cols(out) != rows(p(1)) {
                    fail(id, op, format!("output {:?} != ({}, {})", out, rows(p(0)), rows(p(1))));
                }
            }
            TapeOp::Affine { .. } => {
                // fused matmul → add_row → act: x (r, k) · w (k, m) + bias (m)
                let (k, m) = (cols(p(0)), cols(p(1)));
                if rows(p(1)) != k {
                    fail(id, op, format!("x {:?} · w {:?}: inner dims disagree", p(0), p(1)));
                } else if rows(p(2)) != 1 || cols(p(2)) != m {
                    fail(id, op, format!("bias {:?} is not a ({m},)-row", p(2)));
                } else if rows(out) != rows(p(0)) || cols(out) != m {
                    fail(id, op, format!("output {:?} != ({}, {m})", out, rows(p(0))));
                }
            }
            TapeOp::Add2RowAct { .. } => {
                // fused add → add_row → act
                if p(0) != p(1) {
                    fail(id, op, format!("addend shapes differ: {:?} vs {:?}", p(0), p(1)));
                } else if rows(p(2)) != 1 || cols(p(2)) != cols(p(0)) {
                    fail(id, op, format!("bias {:?} is not a ({},)-row", p(2), cols(p(0))));
                } else if out != p(0) {
                    fail(id, op, format!("output shape {:?} != operand {:?}", out, p(0)));
                }
            }
            TapeOp::Add3Act { .. } => {
                // fused add → add → act, all elementwise
                if p(0) != p(1) || p(1) != p(2) {
                    fail(id, op, format!("operand shapes differ: {:?}, {:?}, {:?}", p(0), p(1), p(2)));
                } else if out != p(0) {
                    fail(id, op, format!("output shape {:?} != operand {:?}", out, p(0)));
                }
            }
            TapeOp::MeanAll | TapeOp::SumAll => {
                if numel(out) != 1 {
                    fail(id, op, format!("output {:?} is not scalar", out));
                }
            }
            TapeOp::SliceRows { lo } => {
                if cols(out) != cols(p(0)) {
                    fail(id, op, format!("output cols {} != operand cols {}", cols(out), cols(p(0))));
                } else if lo + rows(out) > rows(p(0)) {
                    fail(id, op, format!("rows [{lo}, {}) out of bounds for {:?}", lo + rows(out), p(0)));
                }
            }
            TapeOp::SliceCols { lo, hi } => {
                if *lo > *hi || *hi > cols(p(0)) {
                    fail(id, op, format!("cols [{lo}, {hi}) out of bounds for {:?}", p(0)));
                } else if rows(out) != rows(p(0)) || cols(out) != hi - lo {
                    fail(id, op, format!("output {:?} != ({}, {})", out, rows(p(0)), hi - lo));
                }
            }
            TapeOp::ConcatCols { widths } => {
                if widths.len() != node.parents.len() {
                    fail(id, op, format!("{} widths for {} parents", widths.len(), node.parents.len()));
                } else {
                    let r = rows(p(0));
                    for (i, w) in widths.iter().enumerate() {
                        if cols(p(i)) != *w {
                            fail(id, op, format!("part {i} cols {} != recorded width {w}", cols(p(i))));
                        }
                        if rows(p(i)) != r {
                            fail(id, op, format!("part {i} rows {} != part 0 rows {r}", rows(p(i))));
                        }
                    }
                    let total: usize = widths.iter().sum();
                    if rows(out) != r || cols(out) != total {
                        fail(id, op, format!("output {:?} != ({r}, {total})", out));
                    }
                }
            }
            TapeOp::ConcatRows { heights } => {
                if heights.len() != node.parents.len() {
                    fail(id, op, format!("{} heights for {} parents", heights.len(), node.parents.len()));
                } else {
                    let c = cols(p(0));
                    for (i, h) in heights.iter().enumerate() {
                        if rows(p(i)) != *h {
                            fail(id, op, format!("part {i} rows {} != recorded height {h}", rows(p(i))));
                        }
                        if cols(p(i)) != c {
                            fail(id, op, format!("part {i} cols {} != part 0 cols {c}", cols(p(i))));
                        }
                    }
                    let total: usize = heights.iter().sum();
                    if rows(out) != total || cols(out) != c {
                        fail(id, op, format!("output {:?} != ({total}, {c})", out));
                    }
                }
            }
            TapeOp::Reshape { from } => {
                if from != p(0) {
                    fail(id, op, format!("recorded source shape {:?} != operand {:?}", from, p(0)));
                } else if numel(out) != numel(from) {
                    fail(id, op, format!("element count changes: {:?} -> {:?}", from, out));
                }
            }
            TapeOp::SoftmaxXent { batch, max_label } => {
                if *batch != rows(p(0)) {
                    fail(id, op, format!("{batch} labels for {} logit rows", rows(p(0))));
                }
                if let Some(ml) = max_label {
                    if *ml >= cols(p(0)) {
                        fail(id, op, format!("label {ml} out of range {}", cols(p(0))));
                    }
                }
                if numel(out) != 1 {
                    fail(id, op, format!("output {:?} is not scalar", out));
                }
                if node.aux_shape.as_deref() != Some(p(0)) {
                    fail(id, op, format!("cached probs shape {:?} != logits {:?}", node.aux_shape, p(0)));
                }
            }
            TapeOp::Mse { target_len } => {
                if *target_len != numel(p(0)) {
                    fail(id, op, format!("target has {target_len} elements, prediction {:?}", p(0)));
                }
                if numel(out) != 1 {
                    fail(id, op, format!("output {:?} is not scalar", out));
                }
            }
            TapeOp::Embedding { count, max_id } => {
                if let Some(mi) = max_id {
                    if *mi >= rows(p(0)) {
                        fail(id, op, format!("token id {mi} out of vocab {}", rows(p(0))));
                    }
                }
                if rows(out) != *count || cols(out) != cols(p(0)) {
                    fail(id, op, format!("output {:?} != ({count}, {})", out, cols(p(0))));
                }
            }
            TapeOp::Dropout { mask_len } => {
                if *mask_len != numel(p(0)) {
                    fail(id, op, format!("mask has {mask_len} elements, operand {:?}", p(0)));
                }
                if out != p(0) {
                    fail(id, op, format!("output shape {:?} != operand {:?}", out, p(0)));
                }
            }
            TapeOp::DnConv { n, d, batch } => {
                let du = cols(p(0));
                if rows(p(0)) != batch * n {
                    fail(id, op, format!("input rows {} != B·n = {}·{}", rows(p(0)), batch, n));
                } else if rows(out) != batch * n || cols(out) != du * d {
                    fail(id, op, format!("output {:?} != ({}, {})", out, batch * n, du * d));
                }
            }
            TapeOp::DnLast { n, d, batch } => {
                let du = cols(p(0));
                if rows(p(0)) != batch * n {
                    fail(id, op, format!("input rows {} != B·n = {}·{}", rows(p(0)), batch, n));
                } else if rows(out) != *batch || cols(out) != du * d {
                    fail(id, op, format!("output {:?} != ({}, {})", out, batch, du * d));
                }
                if node.aux_shape.as_deref() != Some(&[*n, *d][..]) {
                    fail(id, op, format!("cached H_rev shape {:?} != ({n}, {d})", node.aux_shape));
                }
            }
            TapeOp::DnLastScan { d, batch } => {
                let du = cols(p(0));
                if *batch == 0 || rows(p(0)) % batch != 0 || rows(p(0)) / batch == 0 {
                    fail(id, op, format!("input rows {} not divisible into batch {batch}", rows(p(0))));
                } else if rows(out) != *batch || cols(out) != du * d {
                    fail(id, op, format!("output {:?} != ({}, {})", out, batch, du * d));
                }
                if node.aux_shape.as_deref() != Some(&[*batch, du * d][..]) {
                    fail(id, op, format!("entering carries shape {:?} != ({batch}, {})", node.aux_shape, du * d));
                }
            }
        }
    }
    findings
}

#[cfg(test)]
mod tests {
    use super::*;

    fn leaf(shape: &[usize]) -> TapeNode {
        TapeNode { op: TapeOp::Leaf, parents: vec![], shape: shape.to_vec(), aux_shape: None }
    }

    #[test]
    fn clean_chain_passes() {
        // x (4, 3) · w (3, 2) + b (2) fused with tanh, then mean
        let view = TapeView {
            nodes: vec![
                leaf(&[4, 3]),
                leaf(&[3, 2]),
                leaf(&[2]),
                TapeNode {
                    op: TapeOp::Affine { act: Some(Act::Tanh) },
                    parents: vec![0, 1, 2],
                    shape: vec![4, 2],
                    aux_shape: None,
                },
                TapeNode { op: TapeOp::MeanAll, parents: vec![3], shape: vec![], aux_shape: None },
            ],
        };
        assert!(verify(&view).is_empty(), "{:?}", verify(&view));
    }

    #[test]
    fn forward_reference_is_caught() {
        let view = TapeView {
            nodes: vec![
                leaf(&[2, 2]),
                TapeNode { op: TapeOp::Add, parents: vec![0, 5], shape: vec![2, 2], aux_shape: None },
            ],
        };
        let f = verify(&view);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("node 1 (Add)"), "{}", f[0]);
        assert!(f[0].detail.contains("not earlier"), "{}", f[0]);
    }

    #[test]
    fn self_reference_is_caught() {
        let view = TapeView {
            nodes: vec![TapeNode { op: TapeOp::Neg, parents: vec![0], shape: vec![2], aux_shape: None }],
        };
        assert_eq!(verify(&view).len(), 1);
    }

    #[test]
    fn wrong_arity_fused_op_is_caught() {
        // Affine with two parents — the bias got lost in a bad rewrite
        let view = TapeView {
            nodes: vec![
                leaf(&[4, 3]),
                leaf(&[3, 2]),
                TapeNode {
                    op: TapeOp::Affine { act: None },
                    parents: vec![0, 1],
                    shape: vec![4, 2],
                    aux_shape: None,
                },
            ],
        };
        let f = verify(&view);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("(Affine)"), "{}", f[0]);
        assert!(f[0].detail.contains("arity 2 (expected 3)"), "{}", f[0]);
    }

    #[test]
    fn fused_bias_shape_is_checked() {
        // bias (4, 2) is not a row — the fused rewrite would be illegal
        let view = TapeView {
            nodes: vec![
                leaf(&[4, 3]),
                leaf(&[3, 2]),
                leaf(&[4, 2]),
                TapeNode {
                    op: TapeOp::Affine { act: None },
                    parents: vec![0, 1, 2],
                    shape: vec![4, 2],
                    aux_shape: None,
                },
            ],
        };
        let f = verify(&view);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("bias"), "{}", f[0]);
    }

    #[test]
    fn matmul_inner_dim_mismatch_is_caught() {
        let view = TapeView {
            nodes: vec![
                leaf(&[4, 3]),
                leaf(&[5, 2]),
                TapeNode { op: TapeOp::MatMul, parents: vec![0, 1], shape: vec![4, 2], aux_shape: None },
            ],
        };
        let f = verify(&view);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("inner dims"), "{}", f[0]);
    }

    #[test]
    fn dn_conv_batch_layout_is_checked() {
        // rows 30 != batch 4 * n 8
        let view = TapeView {
            nodes: vec![
                leaf(&[30, 1]),
                TapeNode {
                    op: TapeOp::DnConv { n: 8, d: 6, batch: 4 },
                    parents: vec![0],
                    shape: vec![32, 6],
                    aux_shape: None,
                },
            ],
        };
        let f = verify(&view);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("B·n"), "{}", f[0]);
    }

    #[test]
    fn softmax_xent_label_range_is_checked() {
        let view = TapeView {
            nodes: vec![
                leaf(&[4, 2]),
                TapeNode {
                    op: TapeOp::SoftmaxXent { batch: 4, max_label: Some(2) },
                    parents: vec![0],
                    shape: vec![],
                    aux_shape: Some(vec![4, 2]),
                },
            ],
        };
        let f = verify(&view);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("label 2 out of range 2"), "{}", f[0]);
    }

    #[test]
    fn concat_widths_must_match() {
        let view = TapeView {
            nodes: vec![
                leaf(&[2, 3]),
                leaf(&[2, 4]),
                TapeNode {
                    op: TapeOp::ConcatCols { widths: vec![3, 5] },
                    parents: vec![0, 1],
                    shape: vec![2, 8],
                    aux_shape: None,
                },
            ],
        };
        let f = verify(&view);
        assert!(!f.is_empty());
        assert!(f[0].detail.contains("width"), "{}", f[0]);
    }
}
