//! Level-2 audit event sink for the exec pool.
//!
//! Worker threads must be able to record events without contending on a
//! single lock in the hot path, and without perturbing scheduling (the
//! audit must not change which thread claims which chunk more than any
//! profiler would).  So the sink is a classic per-thread log:
//!
//!  * each recording thread owns a `thread_local` `Arc<Mutex<Vec<..>>>`
//!    that only it pushes to (its mutex is therefore uncontended —
//!    `drain` is the only other party, and only at checkpoint time);
//!  * a global registry holds a clone of every thread's Arc so the logs
//!    survive thread exit and can all be drained centrally;
//!  * every event carries a ticket from one global atomic sequence
//!    counter, giving the offline checker a single total order to
//!    replay (the fetch_add is the only cross-thread traffic per event).
//!
//! Nothing here touches f32 values or chunk assignment, so recording
//! cannot change results — CI pins that with a byte-identical
//! fingerprint under `PLMU_VERIFY=2`.

use super::exec_check::PoolEvent;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

type Log = Arc<Mutex<Vec<(u64, PoolEvent)>>>;

/// Global order for events across threads.
static SEQ: AtomicU64 = AtomicU64::new(0);

/// Job ids for [`next_job_id`]; starts at 1 so 0 can mean "audit off"
/// in `JobCore`.
static JOB_IDS: AtomicU64 = AtomicU64::new(1);

/// All thread logs ever registered (threads come and go; Arcs persist).
static REGISTRY: OnceLock<Mutex<Vec<Log>>> = OnceLock::new();

thread_local! {
    static LOCAL: Log = {
        let log: Log = Arc::new(Mutex::new(Vec::new()));
        REGISTRY
            .get_or_init(|| Mutex::new(Vec::new()))
            .lock()
            .unwrap()
            .push(log.clone());
        log
    };
}

/// A fresh nonzero job id for `JobCore` when auditing is on.
pub fn next_job_id() -> u64 {
    JOB_IDS.fetch_add(1, Ordering::Relaxed)
}

/// Record one pool event into the calling thread's log, stamped with
/// the global sequence ticket.  Callers gate on
/// [`super::audit_enabled`] *before* building the event.
pub fn record(ev: PoolEvent) {
    let seq = SEQ.fetch_add(1, Ordering::Relaxed);
    LOCAL.with(|log| log.lock().unwrap().push((seq, ev)));
}

/// Drain every thread's log and return the merged stream sorted by
/// sequence ticket.  Events recorded concurrently with the drain land
/// in the next drain — callers checkpoint at quiescent points (after
/// `pool::run` returns, all chunk events for that job are in).
pub fn drain_pool_events() -> Vec<(u64, PoolEvent)> {
    let mut merged = Vec::new();
    if let Some(reg) = REGISTRY.get() {
        for log in reg.lock().unwrap().iter() {
            merged.append(&mut log.lock().unwrap());
        }
    }
    merged.sort_unstable_by_key(|(seq, _)| *seq);
    merged
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_and_drain_roundtrip() {
        drain_pool_events(); // isolate from other tests on this thread
        let job = next_job_id();
        record(PoolEvent::JobBegin { job, chunks: 2, workers_cap: 1, budget: 1, root: 1 });
        record(PoolEvent::ChunkStart { job, idx: 0, sub_budget: 1 });
        record(PoolEvent::ChunkEnd { job, idx: 0 });
        record(PoolEvent::ChunkStart { job, idx: 1, sub_budget: 1 });
        record(PoolEvent::ChunkEnd { job, idx: 1 });
        record(PoolEvent::JobEnd { job, panicked: false });
        let evs = drain_pool_events();
        let ours: Vec<_> = evs
            .iter()
            .filter(|(_, e)| e.job() == job)
            .collect();
        assert_eq!(ours.len(), 6);
        // sequence tickets strictly increase in the merged stream
        for w in evs.windows(2) {
            assert!(w[0].0 < w[1].0);
        }
        // drained means gone
        assert!(drain_pool_events().iter().all(|(_, e)| e.job() != job));
    }

    #[test]
    fn job_ids_are_nonzero_and_unique() {
        let a = next_job_id();
        let b = next_job_id();
        assert!(a != 0 && b != 0 && a != b);
    }
}
