//! Pass 4: source conformance lint (`plmu lint-src`).
//!
//! A small textual scanner over `rust/src` for repo rules that clippy
//! cannot express — each one guards an invariant another subsystem
//! depends on:
//!
//!  * **thread-spawn** — `thread::spawn` is allowed only under `exec/`:
//!    threads created elsewhere escape the pool's budget accounting,
//!    so the peak-concurrency and budget audits would be blind to them.
//!  * **hashmap** — no `HashMap` on fingerprinted paths (`tensor/`,
//!    `fft/`, `dn/`, `autograd/`, `simd/`, `exec/`, `optim/`,
//!    `train/`, `layers/`): iteration order is nondeterministic, and a
//!    map iterated on a value path silently breaks the bit-exactness
//!    story.  Lookup-only maps are fine — waive them explicitly so the
//!    reviewer sees the claim.
//!  * **env-knob** — `env::var` is read only inside `util::env_knob`:
//!    scattered readers are how the `PLMU_SCAN` silent-fallback bug
//!    happened (accepted spellings drifting per call site).
//!  * **simd-triple** — every explicit simd kernel entry `X_vec` keeps
//!    its `X_scalar` sibling and `X` dispatcher, so the differential
//!    suites always have both lanes to pin against each other.
//!  * **knob-doc** — every `PLMU_*` name passed to a `util::env_knob`
//!    reader must appear in the README's `## Knob reference` table
//!    (`lint_knob_docs`): the table is the one authoritative list of
//!    tuning knobs, and an undocumented knob is a knob nobody can find.
//!    Names starting `PLMU_TEST_` are exempt (test-only fixtures).
//!
//! A rule is waived for a line by the comment `lint-src: allow(<rule>)`
//! on that line or the line directly above.  Comment-only lines are
//! skipped (prose may mention HashMap freely).

use super::{Finding, Pass};
use std::path::Path;

const RULES: [&str; 5] = [
    "thread-spawn",
    "hashmap",
    "env-knob",
    "simd-triple",
    "knob-doc",
];

/// Fingerprinted path prefixes (relative to `rust/src/`) where HashMap
/// iteration could change reported bits.
const FINGERPRINTED: [&str; 9] = [
    "tensor/", "fft/", "dn/", "autograd/", "simd/", "exec/", "optim/", "train/", "layers/",
];

fn waived(lines: &[&str], i: usize, rule: &str) -> bool {
    let needle = format!("lint-src: allow({rule})");
    lines[i].contains(&needle) || (i > 0 && lines[i - 1].contains(&needle))
}

/// True for lines that are only a comment (`//`, `//!`, `///`) — prose,
/// not code.
fn comment_only(line: &str) -> bool {
    line.trim_start().starts_with("//")
}

/// Lint one file's source.  `rel` is the path relative to the scan root
/// (e.g. `exec/pool.rs`), used both for provenance and for the
/// path-scoped rules.
pub fn lint_source(rel: &str, src: &str) -> Vec<Finding> {
    let mut findings = Vec::new();
    // the linter's own source necessarily spells out every needle it
    // scans for (rule strings, messages, tests) — exempt it wholesale,
    // the way `util/env_knob.rs` is exempt from the env-knob rule
    if rel == "analyze/lint.rs" {
        return findings;
    }
    let lines: Vec<&str> = src.lines().collect();
    let in_exec = rel.starts_with("exec/");
    let fingerprinted = FINGERPRINTED.iter().any(|p| rel.starts_with(p));
    let is_knob_home = rel == "util/env_knob.rs";

    for (i, line) in lines.iter().enumerate() {
        if comment_only(line) {
            continue;
        }
        let lineno = i + 1;
        if !in_exec && line.contains("thread::spawn") && !waived(&lines, i, "thread-spawn") {
            findings.push(Finding::new(
                Pass::Lint,
                format!(
                    "{rel}:{lineno}: thread::spawn outside exec/ — threads here escape the pool's \
                     budget accounting (waive with `lint-src: allow(thread-spawn)` if deliberate)"
                ),
            ));
        }
        if fingerprinted && line.contains("HashMap") && !waived(&lines, i, "hashmap") {
            findings.push(Finding::new(
                Pass::Lint,
                format!(
                    "{rel}:{lineno}: HashMap on a fingerprinted path — iteration order is \
                     nondeterministic (waive with `lint-src: allow(hashmap)` if lookup-only)"
                ),
            ));
        }
        if !is_knob_home && line.contains("env::var(") && !waived(&lines, i, "env-knob") {
            findings.push(Finding::new(
                Pass::Lint,
                format!(
                    "{rel}:{lineno}: env::var outside util::env_knob — knob spellings must come \
                     from the one parser (use str_knob/bool_knob/usize_knob/level_knob)"
                ),
            ));
        }
    }

    // simd-triple: per simd/ file, every explicit `fn X_vec` has both an
    // `fn X_scalar` and a dispatcher `fn X(`.  Macro template names
    // ($name / $vec / $scalar) are skipped — the macro guarantees the
    // triple structurally.
    if rel.starts_with("simd/") {
        let mut fns: Vec<String> = Vec::new();
        for line in &lines {
            if comment_only(line) {
                continue;
            }
            let mut rest = *line;
            while let Some(pos) = rest.find("fn ") {
                let after = &rest[pos + 3..];
                let name: String = after
                    .chars()
                    .take_while(|c| c.is_alphanumeric() || *c == '_')
                    .collect();
                if !name.is_empty() {
                    fns.push(name);
                }
                rest = after;
            }
        }
        for name in fns.iter().filter(|n| n.ends_with("_vec")) {
            let base = &name[..name.len() - 4];
            if base.is_empty() || base.starts_with('$') {
                continue;
            }
            let has_scalar = fns.iter().any(|f| f == &format!("{base}_scalar"));
            let has_dispatch = fns.iter().any(|f| f == base);
            if !(has_scalar && has_dispatch) {
                findings.push(Finding::new(
                    Pass::Lint,
                    format!(
                        "{rel}: kernel `{name}` is missing its `{base}_scalar`/`{base}` \
                         dispatch triple — the differential suites need both lanes"
                    ),
                ));
            }
        }
    }
    findings
}

/// Extract the `PLMU_*` knob names documented in the README's
/// `## Knob reference` section — only names between that heading and
/// the next `## ` heading count, so a knob mentioned in passing
/// elsewhere does not satisfy the rule.
pub fn documented_knobs(readme: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_section = false;
    for line in readme.lines() {
        if line.starts_with("## ") {
            in_section = line.trim() == "## Knob reference";
            continue;
        }
        if in_section {
            collect_plmu_names(line, &mut out);
        }
    }
    out.sort();
    out.dedup();
    out
}

/// Append every maximal `PLMU_[A-Z0-9_]*` token in `line` to `out`.
fn collect_plmu_names(line: &str, out: &mut Vec<String>) {
    let mut rest = line;
    while let Some(pos) = rest.find("PLMU_") {
        let name: String = rest[pos..]
            .chars()
            .take_while(|c| c.is_ascii_uppercase() || c.is_ascii_digit() || *c == '_')
            .collect();
        out.push(name);
        rest = &rest[pos + 5..];
    }
}

/// knob-doc: scan one file for `util::env_knob` reader call sites
/// (`str_knob(` / `bool_knob(` / `usize_knob(` / `level_knob(`) and
/// flag any `PLMU_*` name on those lines that is absent from
/// `documented` (the README table, via [`documented_knobs`]).
pub fn check_knob_docs(rel: &str, src: &str, documented: &[String]) -> Vec<Finding> {
    let mut findings = Vec::new();
    // the knob parser's own tests and this linter spell names freely
    if rel == "analyze/lint.rs" || rel == "util/env_knob.rs" {
        return findings;
    }
    let lines: Vec<&str> = src.lines().collect();
    for (i, line) in lines.iter().enumerate() {
        if comment_only(line) || !line.contains("_knob(") || waived(&lines, i, "knob-doc") {
            continue;
        }
        let mut names = Vec::new();
        collect_plmu_names(line, &mut names);
        for name in names {
            if name.starts_with("PLMU_TEST_") {
                continue;
            }
            if !documented.iter().any(|d| d == &name) {
                findings.push(Finding::new(
                    Pass::Lint,
                    format!(
                        "{rel}:{}: knob `{name}` is read here but missing from the README's \
                         `## Knob reference` table — document it there or waive with \
                         `lint-src: allow(knob-doc)`",
                        i + 1
                    ),
                ));
            }
        }
    }
    findings
}

/// Walk `root` like [`lint_tree`] and run the knob-doc rule against the
/// given README contents.  Kept separate from [`lint_tree`] because it
/// needs the README as an input, which the per-file rules do not.
pub fn lint_knob_docs(root: &Path, readme: &str) -> std::io::Result<Vec<Finding>> {
    let documented = documented_knobs(readme);
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for f in files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(&f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&f)?;
        findings.extend(check_knob_docs(&rel, &src, &documented));
    }
    Ok(findings)
}

/// Walk `root` (the `rust/src` directory), lint every `.rs` file in
/// sorted order, and return all findings.
pub fn lint_tree(root: &Path) -> std::io::Result<Vec<Finding>> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut findings = Vec::new();
    for f in files {
        let rel = f
            .strip_prefix(root)
            .unwrap_or(&f)
            .to_string_lossy()
            .replace('\\', "/");
        let src = std::fs::read_to_string(&f)?;
        findings.extend(lint_source(&rel, &src));
    }
    Ok(findings)
}

fn collect_rs(dir: &Path, out: &mut Vec<std::path::PathBuf>) -> std::io::Result<()> {
    for entry in std::fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            collect_rs(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// The rule names, for `plmu lint-src --help`-style output.
pub fn rule_names() -> &'static [&'static str] {
    &RULES
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_outside_exec_is_flagged_and_waivable() {
        let src = "fn f() { std::thread::spawn(|| {}); }\n";
        let f = lint_source("coordinator/server.rs", src);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("thread::spawn"), "{}", f[0]);

        let waived = "// lint-src: allow(thread-spawn)\nfn f() { std::thread::spawn(|| {}); }\n";
        assert!(lint_source("coordinator/server.rs", waived).is_empty());
        // and exec/ itself is always allowed
        assert!(lint_source("exec/pool.rs", src).is_empty());
    }

    #[test]
    fn the_linter_is_exempt_from_itself() {
        let src = "let x = \"thread::spawn env::var( HashMap\";\n";
        assert!(lint_source("analyze/lint.rs", src).is_empty());
    }

    #[test]
    fn hashmap_on_fingerprinted_path_only() {
        let src = "use std::collections::HashMap;\n";
        assert_eq!(lint_source("optim/mod.rs", src).len(), 1);
        assert!(lint_source("metrics/mod.rs", src).is_empty(), "metrics is not fingerprinted");
        // prose mentioning HashMap is fine
        assert!(lint_source("fft/mod.rs", "//! keyed by a HashMap\n").is_empty());
        // same-line waiver
        let waived = "use std::collections::HashMap; // lint-src: allow(hashmap)\n";
        assert!(lint_source("optim/mod.rs", waived).is_empty());
    }

    #[test]
    fn env_var_outside_the_knob_home_is_flagged() {
        let src = "let v = std::env::var(\"PLMU_THREADS\");\n";
        assert_eq!(lint_source("exec/mod.rs", src).len(), 1);
        assert!(lint_source("util/env_knob.rs", src).is_empty());
    }

    #[test]
    fn simd_triple_enforced() {
        let ok = "fn dot(a: f32) {}\nfn dot_vec(a: f32) {}\nfn dot_scalar(a: f32) {}\n";
        assert!(lint_source("simd/mod.rs", ok).is_empty());
        let broken = "fn dot_vec(a: f32) {}\nfn dot_scalar(a: f32) {}\n";
        let f = lint_source("simd/mod.rs", broken);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("dot_vec"), "{}", f[0]);
        // macro templates are skipped
        let mac = "macro_rules! m { ($name:ident, $vec:ident) => { fn $vec() {} } }\n";
        assert!(lint_source("simd/mod.rs", mac).is_empty());
        // the triple rule only applies under simd/
        assert!(lint_source("fft/mod.rs", broken).is_empty());
    }

    const FAKE_README: &str = "\
# demo\n\n## Knob reference\n\n| Knob | Meaning |\n|---|---|\n\
| `PLMU_THREADS` | worker pool size |\n| `PLMU_SIMD` | simd on/off |\n\n\
## Elsewhere\n\n`PLMU_NOT_IN_TABLE` mentioned outside the table does not count.\n";

    #[test]
    fn documented_knobs_parses_only_the_reference_section() {
        let d = documented_knobs(FAKE_README);
        assert_eq!(d, vec!["PLMU_SIMD".to_string(), "PLMU_THREADS".to_string()]);
    }

    #[test]
    fn knob_doc_flags_drift_and_honors_exemptions() {
        let documented = documented_knobs(FAKE_README);
        let ok = "let n = crate::util::env_knob::usize_knob(\"PLMU_THREADS\", 1);\n";
        assert!(check_knob_docs("exec/mod.rs", ok, &documented).is_empty());

        // seeded drift: a knob read in source but absent from the table
        let drift = "let b = crate::util::env_knob::bool_knob(\"PLMU_BOGUS\", false);\n";
        let f = check_knob_docs("exec/mod.rs", drift, &documented);
        assert_eq!(f.len(), 1);
        assert!(f[0].detail.contains("PLMU_BOGUS"), "{}", f[0]);

        // waivable on the line or the line above
        let waived = "let b = bool_knob(\"PLMU_BOGUS\", false); // lint-src: allow(knob-doc)\n";
        assert!(check_knob_docs("exec/mod.rs", waived, &documented).is_empty());
        // test-only fixture names are exempt
        let fixture = "let b = bool_knob(\"PLMU_TEST_FIXTURE\", false);\n";
        assert!(check_knob_docs("exec/mod.rs", fixture, &documented).is_empty());
        // prose mentioning a knob next to `_knob(` is not a call site
        let prose = "// usize_knob(\"PLMU_BOGUS\", 1) would be flagged here\n";
        assert!(check_knob_docs("exec/mod.rs", prose, &documented).is_empty());
        // the knob parser itself spells names freely
        assert!(check_knob_docs("util/env_knob.rs", drift, &documented).is_empty());
    }

    #[test]
    fn real_tree_knobs_are_all_documented() {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        let readme = Path::new(env!("CARGO_MANIFEST_DIR")).join("../README.md");
        let readme = std::fs::read_to_string(readme).expect("README.md beside rust/");
        let f = lint_knob_docs(&src, &readme).unwrap();
        assert!(f.is_empty(), "undocumented knobs: {f:?}");
    }
}
