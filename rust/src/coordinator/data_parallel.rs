//! Data-parallel training coordinator on the shared exec pool.
//!
//! Because the parallel LMU has no sequential dependency inside a training
//! step, scaling out is plain synchronous data parallelism:
//!
//! ```text
//!   coordinator (pool dispatcher)     replica r (pool chunk)
//!   ─────────────────────────────     ──────────────────────
//!   pack canonical params      ───►   unpack into replica store
//!                                     build tape on local shard batch
//!                                     backward, pack gradients
//!   deterministic all-reduce   ◄───   per-replica packed grads
//!   Adam step on canonical store
//!   (repeat)
//! ```
//!
//! Replica steps are **chunks of one job on the `crate::exec` worker
//! pool** — the same pool the tensor/FFT kernels dispatch through — so
//! replica-level and kernel-level parallelism share a single thread
//! budget, hierarchically: the replica fan-out splits the global budget
//! over its chunk slots, so a run with fewer replicas than threads (say
//! 2 replicas on 8 threads) hands each replica a sub-budget of 4 and its
//! nested kernels fan out as first-class pool jobs on the spare threads,
//! while a run with more replicas than threads gives each chunk a unit
//! budget and nested kernels serialize.  Either way replicas ×
//! kernel-threads can never oversubscribe the machine (pinned by
//! `rust/tests/exec_equivalence.rs`).  Replicas are dispatched as more
//! steal-chunks than workers, so uneven shards (ragged tails) rebalance
//! instead of stalling the job on its slowest static chunk.
//!
//! Replica state (parameter store, model, RNG, batch queue, retained
//! graph + buffer arena) is `Send` and migrates between pool threads
//! across steps; the autograd [`Graph`] is reset and re-recorded
//! *inside* a single chunk (between steps it is inert `Send` data like
//! the store), so live tapes never cross threads.  Each replica carries
//! its own [`Arena`], installed for the duration of its chunk, and the
//! coordinator keeps a separate optimizer-side arena for the all-reduce
//! and unpacked-gradient buffers — under `--pipeline` those are two
//! arenas in flight on two threads.  Only packed `Vec<f32>`
//! parameter/gradient buffers move between coordinator and replicas —
//! which is also how a real multi-host version would wire NCCL-style
//! collectives.

use crate::autograd::{Graph, ParamId, ParamStore};
use crate::data::batcher::{Batch, BatchIter, SeqDataset};
use crate::exec;
use crate::exec::arena::{self, Arena};
use crate::optim::{clip_global_norm, Optimizer};
use crate::train::TrainableModel;
use crate::util::Rng;

/// Pack a sparse (ParamId, grad) list into a dense store-ordered flat
/// vector (missing params get zeros) — the "wire format" of the
/// all-reduce.
pub fn pack_grads(store: &ParamStore, grads: &[(ParamId, crate::tensor::Tensor)]) -> Vec<f32> {
    let mut offsets = Vec::with_capacity(store.len());
    let mut total = 0usize;
    for id in store.ids() {
        offsets.push(total);
        total += store.get(id).len();
    }
    let mut flat = vec![0.0f32; total];
    for (pid, g) in grads {
        let ofs = offsets[pid.0];
        for (dst, src) in flat[ofs..ofs + g.len()].iter_mut().zip(g.data()) {
            *dst += src;
        }
    }
    flat
}

/// Unpack a dense flat gradient into (ParamId, Tensor) pairs, inverting
/// [`pack_grads`] (store order defines the layout).
pub fn unpack_grads(store: &ParamStore, flat: &[f32]) -> Vec<(ParamId, crate::tensor::Tensor)> {
    let mut out = Vec::with_capacity(store.len());
    let mut ofs = 0usize;
    for id in store.ids() {
        let t = store.get(id);
        // drawn from the optimizer-side arena when one is in scope
        let g = crate::tensor::Tensor::new(t.shape(), arena::alloc_copy(&flat[ofs..ofs + t.len()]));
        ofs += t.len();
        out.push((id, g));
    }
    out
}

/// Deterministic mean of per-replica packed gradients: `out[i]` sums
/// `parts[0][i], parts[1][i], ...` in replica order and scales by
/// `1 / parts.len()`.  The per-element summation order never depends on
/// the worker count, so the result is bit-identical at every `threads`
/// setting (pinned by `rust/tests/exec_equivalence.rs`); the element
/// range is partitioned across the shared exec pool.
pub fn allreduce_mean(parts: &[&[f32]]) -> Vec<f32> {
    assert!(!parts.is_empty(), "allreduce over zero replicas");
    let len = parts[0].len();
    for p in parts {
        assert_eq!(p.len(), len, "replica gradient length mismatch");
    }
    let inv = 1.0f32 / parts.len() as f32;
    // arena-backed when a scope is installed (the caller releases it);
    // zero-filled either way, so results are identical
    let mut out = arena::alloc_zeroed(len);
    let plan = exec::plan_for(len, len * (parts.len() + 1));
    exec::parallel_rows_mut(&mut out, 1, plan, |i0, block| {
        for (k, o) in block.iter_mut().enumerate() {
            let i = i0 + k;
            let mut acc = 0.0f32;
            for p in parts {
                acc += p[i];
            }
            *o = acc * inv;
        }
    });
    out
}

/// Configuration of one data-parallel run.
#[derive(Clone, Debug)]
pub struct DataParallelConfig {
    /// number of model replicas (one shard each)
    pub workers: usize,
    /// passes over each replica's shard
    pub epochs: usize,
    /// per-replica batch size (clamped to the shard size)
    pub batch_size: usize,
    /// optional global-norm gradient clip applied after the all-reduce
    pub grad_clip: Option<f32>,
    /// base RNG seed; replica `w` shuffles with `seed ^ hash(w)`
    pub seed: u64,
    /// Overlap the optimizer stage of step `k` (pack → all-reduce →
    /// apply → broadcast) with batch `k+1`'s replica forward/backward.
    /// Batch `k+1` then reads the parameters batch `k` read — the
    /// classic staleness-1 pipeline of Martin & Cundy (2018) — through a
    /// double-buffered broadcast arena, so no replica ever observes a
    /// half-updated model.  Off (the default) keeps the bulk-synchronous
    /// path, bit-identical to previous releases; on, runs are
    /// deterministic given this knob (two pipelined runs are
    /// bit-identical to each other at every thread count).
    pub pipeline: bool,
}

impl Default for DataParallelConfig {
    fn default() -> Self {
        DataParallelConfig {
            workers: 2,
            epochs: 1,
            batch_size: 16,
            grad_clip: None,
            seed: 0,
            pipeline: false,
        }
    }
}

/// Coordinator output.
pub struct DataParallelResult {
    /// per-step mean loss across replicas
    pub step_losses: Vec<f32>,
    /// final packed parameters (canonical replica)
    pub final_params: Vec<f32>,
    /// synchronous optimizer steps taken
    pub steps: usize,
}

/// One model replica: `Send` state that migrates between pool threads
/// across steps (the autograd tape lives and dies inside a single step).
struct Replica<M> {
    store: ParamStore,
    model: M,
    shard: SeqDataset,
    rng: Rng,
    batch_size: usize,
    epochs_left: usize,
    /// current epoch's remaining batches, reversed so `pop` yields the
    /// shuffled order
    queue: Vec<Batch>,
    /// batch pulled for the step in flight
    pending: Option<Batch>,
    /// (loss, packed gradient) produced by the step in flight
    out: Option<(f32, Vec<f32>)>,
    /// tape retained across steps (reset + re-recorded each chunk)
    graph: Graph,
    /// this replica's buffer pool, installed while its chunk runs
    arena: Arena,
}

impl<M: TrainableModel> Replica<M> {
    /// Stage the next batch (refilling from the next epoch if needed).
    /// Returns false when the shard is exhausted for every epoch.
    fn pull_batch(&mut self) -> bool {
        loop {
            if let Some(b) = self.queue.pop() {
                self.pending = Some(b);
                return true;
            }
            if self.epochs_left == 0 {
                return false;
            }
            self.epochs_left -= 1;
            let bs = self.batch_size.min(self.shard.len());
            if bs == 0 {
                // degenerate shard or batch_size=0: retire this replica
                // instead of panicking inside a pool chunk
                self.epochs_left = 0;
                return false;
            }
            self.queue = BatchIter::new(&self.shard, bs, &mut self.rng).collect();
            self.queue.reverse();
        }
    }

    /// One local step: unpack broadcast params, forward/backward on the
    /// staged batch, pack gradients.  Runs inside one pool chunk.
    fn step(&mut self, packed_params: &[f32]) {
        if let Some(batch) = self.pending.take() {
            self.store.unpack(packed_params);
            let g = &mut self.graph;
            let (model, store) = (&self.model, &self.store);
            self.out = Some(arena::scope(&mut self.arena, || {
                g.reset();
                let loss = model.loss(g, store, &batch);
                g.backward(loss);
                let lv = g.value(loss).item();
                let grads = g.param_grads();
                (lv, pack_grads(store, &grads))
            }));
        }
    }
}

/// Synchronous data-parallel trainer (see the module docs for the step
/// anatomy and the shared-budget story).
pub struct DataParallelCoordinator;

impl DataParallelCoordinator {
    /// Run synchronous data-parallel training.
    ///
    /// `factory` builds a fresh (store, model) replica — it is called once
    /// for the coordinator's canonical replica (which owns the optimizer
    /// state) and once per worker replica.  All replicas must produce an
    /// identical parameter layout (same construction order), which holds
    /// by construction since they run the same code with the same shapes.
    pub fn run<F, M>(
        factory: F,
        shards: Vec<SeqDataset>,
        opt: &mut dyn Optimizer,
        cfg: &DataParallelConfig,
    ) -> DataParallelResult
    where
        F: Fn() -> (ParamStore, M) + Sync,
        M: TrainableModel + Send,
    {
        assert_eq!(shards.len(), cfg.workers, "one shard per worker");
        let (mut canon_store, _canon_model) = factory();

        // replica construction is itself parallel work (DnFftOperator
        // spectra), so it fans out on the pool too — and with fewer
        // replicas than threads each build chunk gets a sub-budget, so
        // the per-replica spectrum FFTs fan out beneath it
        let k = shards.len();
        let built = exec::parallel_map(k, exec::plan_for(k, usize::MAX), |_| factory());
        let mut replicas: Vec<Replica<M>> = built
            .into_iter()
            .zip(shards)
            .enumerate()
            .map(|(w, ((store, model), shard))| Replica {
                store,
                model,
                shard,
                rng: Rng::new(cfg.seed ^ (w as u64 + 1).wrapping_mul(0x9E37_79B9)),
                batch_size: cfg.batch_size,
                epochs_left: cfg.epochs,
                queue: Vec::new(),
                pending: None,
                out: None,
                graph: Graph::new(),
                arena: Arena::new(),
            })
            .collect();

        if cfg.pipeline {
            run_pipelined(&mut canon_store, &mut replicas, opt, cfg)
        } else {
            run_sync(&mut canon_store, &mut replicas, opt, cfg)
        }
    }
}

/// The bulk-synchronous step loop (see the module docs for the step
/// anatomy) — every step barriers on the all-reduce before the next
/// batch starts.  This is the reference semantics: final parameters are
/// bit-identical at every thread count.
fn run_sync<M: TrainableModel + Send>(
    canon_store: &mut ParamStore,
    replicas: &mut [Replica<M>],
    opt: &mut dyn Optimizer,
    cfg: &DataParallelConfig,
) -> DataParallelResult {
    let mut step_losses = Vec::new();
    let mut steps = 0usize;
    let mut opt_arena = Arena::new();
    loop {
        // stage one batch per replica that still has data, then fan
        // out over the *live* replicas only — with uneven shards the
        // exhausted ones would otherwise hog chunk slots and cluster
        // the remaining work onto fewer threads
        for r in replicas.iter_mut() {
            r.pull_batch();
        }
        let mut live: Vec<&mut Replica<M>> =
            replicas.iter_mut().filter(|r| r.pending.is_some()).collect();
        if live.is_empty() {
            break;
        }
        let live_n = live.len();
        // broadcast: every replica reads the same packed parameters
        let packed = canon_store.pack();
        // replica fan-out: one pool job whose worker count is capped
        // at the thread budget.  With R < threads live replicas each
        // chunk inherits a `threads / R` sub-budget and the kernels
        // inside fan out as nested pool jobs; with R >= threads the
        // sub-budget is 1 and kernels serialize.  One steal-chunk per
        // replica, so replicas that finish early free their thread to
        // the stragglers' nested kernels.
        let plan = exec::plan_for(live_n, usize::MAX);
        exec::parallel_rows_mut(&mut live, 1, plan, |_, block| {
            for r in block.iter_mut() {
                r.step(&packed);
            }
        });
        drop(live);
        // gather + deterministic all-reduce (replica order)
        let parts: Vec<&[f32]> = replicas
            .iter()
            .filter_map(|r| r.out.as_ref().map(|(_, g)| g.as_slice()))
            .collect();
        let loss_sum: f32 =
            replicas.iter().filter_map(|r| r.out.as_ref().map(|(l, _)| *l)).sum();
        let got = parts.len();
        debug_assert_eq!(got, live_n, "every staged replica must produce gradients");
        arena::scope(&mut opt_arena, || {
            let avg = allreduce_mean(&parts);
            let mut grads = unpack_grads(canon_store, &avg);
            if let Some(c) = cfg.grad_clip {
                clip_global_norm(&mut grads, c);
            }
            opt.step(canon_store, &grads);
            arena::release(avg);
        });
        step_losses.push(loss_sum / got as f32);
        steps += 1;
        for r in replicas.iter_mut() {
            r.out = None;
        }
    }
    DataParallelResult { step_losses, final_params: canon_store.pack(), steps }
}

/// The staleness-1 pipelined step loop: while the coordinator consumes
/// batch `k`'s gradients (all-reduce → clip → Adam → pack), the replicas
/// are already running batch `k+1`'s forward/backward as an **async pool
/// job** against the parameter snapshot batch `k` read.
///
/// ```text
///   arena A = θ_k   ──read──►  async replica job (batch k+1)
///   arena B         ◄─write──  optimizer stage   (batch k's grads → θ_(k+1))
///   (swap A/B once the job has drained; repeat)
/// ```
///
/// Two invariants make this safe and reproducible:
///
///  * **Double-buffered broadcast.**  The optimizer packs θ_(k+1) into
///    the arena the *finished* job was reading, never the one the
///    in-flight job reads, so a replica can never observe a half-updated
///    model.  The swap happens only after `JobHandle::wait` — i.e. with
///    zero readers on either arena.
///  * **Budget split across the two in-flight stages.**  The async job
///    is dispatched with an explicit budget of `threads - 1`; the
///    coordinator's own stage runs serially on its thread (the pool's
///    admission gate is held by the async job, so any kernel the
///    optimizer stage dispatches degrades to serial with a unit budget).
///    Peak busy threads therefore stay ≤ `threads` even with both stages
///    in flight — pinned by `rust/tests/exec_equivalence.rs`.
///
/// Gradients are computed on parameters one step stale (batch 0 is the
/// exception: there is nothing to overlap with, so it reads θ_0
/// fresh).  Every batch still contributes exactly one optimizer step in
/// replica order, so pipelined runs are bit-identical to each other at
/// EVERY thread count — with one thread the same schedule simply runs
/// its two stages back-to-back on the caller (no overlap to hide, no
/// extra thread) — and only the staleness schedule differs from the
/// synchronous path.
fn run_pipelined<M: TrainableModel + Send>(
    canon_store: &mut ParamStore,
    replicas: &mut [Replica<M>],
    opt: &mut dyn Optimizer,
    cfg: &DataParallelConfig,
) -> DataParallelResult {
    let threads = exec::threads();
    let replica_budget = threads.saturating_sub(1).max(1);
    let mut read_arena = canon_store.pack();
    let mut write_arena = vec![0.0f32; read_arena.len()];
    let mut step_losses = Vec::new();
    let mut steps = 0usize;
    // optimizer-stage buffer pool: lives on the coordinator thread while
    // each replica's pool rides its chunk — two arenas in flight
    let mut opt_arena = Arena::new();
    // (loss, packed grads) of the batch whose optimizer stage is pending
    let mut pending_outs: Option<Vec<(f32, Vec<f32>)>> = None;
    loop {
        for r in replicas.iter_mut() {
            r.pull_batch();
        }
        let mut live: Vec<&mut Replica<M>> =
            replicas.iter_mut().filter(|r| r.pending.is_some()).collect();
        let live_n = live.len();
        if live_n == 0 {
            break;
        }
        let workers = replica_budget.min(live_n);
        let applied = if threads >= 2 {
            let packed: &[f32] = &read_arena;
            // batch k+1 in flight as an async pool job (one steal-chunk
            // per live replica, sub-budgets summing to `threads - 1`)
            // while the optimizer stage consumes batch k's gradients on
            // the coordinator's reserved thread
            exec::parallel_rows_overlap(
                &mut live,
                1,
                workers,
                replica_budget,
                move |_, block| {
                    for r in block.iter_mut() {
                        r.step(packed);
                    }
                },
                || {
                    optimizer_stage(
                        &mut pending_outs,
                        canon_store,
                        opt,
                        cfg,
                        &mut write_arena,
                        &mut opt_arena,
                        &mut step_losses,
                        &mut steps,
                    )
                },
            )
        } else {
            // one thread: nothing to overlap with — run the two stages
            // back-to-back with the SAME staleness-1 schedule, so
            // pipelined results never depend on the thread count
            let packed: &[f32] = &read_arena;
            for r in live.iter_mut() {
                r.step(packed);
            }
            optimizer_stage(
                &mut pending_outs,
                canon_store,
                opt,
                cfg,
                &mut write_arena,
                &mut opt_arena,
                &mut step_losses,
                &mut steps,
            )
        };
        drop(live);
        let outs: Vec<(f32, Vec<f32>)> =
            replicas.iter_mut().filter_map(|r| r.out.take()).collect();
        debug_assert_eq!(outs.len(), live_n, "every staged replica must produce gradients");
        pending_outs = Some(outs);
        if applied {
            // θ_(k+1) becomes the next dispatch's broadcast source; the
            // arena the drained job was reading becomes the next write
            // target (it has no readers left)
            std::mem::swap(&mut read_arena, &mut write_arena);
        }
    }
    // drain the final in-flight gradient set (nothing left to overlap)
    if let Some(outs) = pending_outs.take() {
        apply_step(canon_store, opt, cfg, &outs, &mut write_arena, &mut opt_arena, &mut step_losses);
        steps += 1;
    }
    DataParallelResult { step_losses, final_params: canon_store.pack(), steps }
}

/// The pipeline's optimizer stage: consume the previous batch's
/// gradients if any are pending; returns whether a step was applied
/// (i.e. whether the arenas should swap).
fn optimizer_stage(
    pending_outs: &mut Option<Vec<(f32, Vec<f32>)>>,
    canon_store: &mut ParamStore,
    opt: &mut dyn Optimizer,
    cfg: &DataParallelConfig,
    arena: &mut Vec<f32>,
    opt_arena: &mut Arena,
    step_losses: &mut Vec<f32>,
    steps: &mut usize,
) -> bool {
    match pending_outs.take() {
        Some(outs) => {
            apply_step(canon_store, opt, cfg, &outs, arena, opt_arena, step_losses);
            *steps += 1;
            true
        }
        None => false,
    }
}

/// One optimizer stage body: deterministic replica-order all-reduce,
/// optional global-norm clip, optimizer update applied to the canonical
/// store and packed into the target broadcast arena.
fn apply_step(
    canon_store: &mut ParamStore,
    opt: &mut dyn Optimizer,
    cfg: &DataParallelConfig,
    outs: &[(f32, Vec<f32>)],
    arena: &mut Vec<f32>,
    opt_arena: &mut Arena,
    step_losses: &mut Vec<f32>,
) {
    let loss_sum: f32 = outs.iter().map(|(l, _)| *l).sum();
    arena::scope(opt_arena, || {
        let parts: Vec<&[f32]> = outs.iter().map(|(_, g)| g.as_slice()).collect();
        let avg = allreduce_mean(&parts);
        let mut grads = unpack_grads(canon_store, &avg);
        if let Some(c) = cfg.grad_clip {
            clip_global_norm(&mut grads, c);
        }
        opt.step_into(canon_store, &grads, arena);
        arena::release(avg);
    });
    step_losses.push(loss_sum / outs.len() as f32);
}

/// Split a dataset into `k` shards (round-robin).
pub fn shard_dataset(xs: Vec<crate::tensor::Tensor>, ys: Vec<usize>, k: usize) -> Vec<SeqDataset> {
    let mut parts: Vec<(Vec<crate::tensor::Tensor>, Vec<usize>)> =
        (0..k).map(|_| (Vec::new(), Vec::new())).collect();
    for (i, (x, y)) in xs.into_iter().zip(ys).enumerate() {
        parts[i % k].0.push(x);
        parts[i % k].1.push(y);
    }
    parts
        .into_iter()
        .map(|(x, y)| SeqDataset::classification(x, y))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Adam;
    use crate::tensor::Tensor;
    use crate::train::{ModelKind, SeqClassifier};

    fn toy_data(n: usize, seq: usize, seed: u64) -> (Vec<Tensor>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..n {
            let sign = if i % 2 == 0 { 0.5f32 } else { -0.5 };
            let mut x = Tensor::randn(&[seq, 1], 0.5, &mut rng);
            x.map_inplace(|v| v + sign);
            xs.push(x);
            ys.push(usize::from(sign > 0.0));
        }
        (xs, ys)
    }

    fn factory(seq: usize) -> impl Fn() -> (ParamStore, SeqClassifier) + Send + Sync + Clone {
        move || {
            let mut store = ParamStore::new();
            let mut rng = Rng::new(42);
            let model =
                SeqClassifier::new(ModelKind::LmuParallel, seq, 1, 4, 8, 2, &mut store, &mut rng);
            (store, model)
        }
    }

    #[test]
    fn pack_unpack_grads_roundtrip() {
        let (store, _model) = factory(8)();
        let mut rng = Rng::new(0);
        let grads: Vec<(ParamId, Tensor)> = store
            .ids()
            .map(|id| (id, Tensor::randn(store.get(id).shape(), 1.0, &mut rng)))
            .collect();
        let packed = pack_grads(&store, &grads);
        assert_eq!(packed.len(), store.num_scalars());
        let back = unpack_grads(&store, &packed);
        for ((id1, g1), (id2, g2)) in grads.iter().zip(&back) {
            assert_eq!(id1, id2);
            assert!(g1.allclose(g2, 0.0));
        }
        // and the inverse direction: unpack then re-pack is the identity
        let repacked = pack_grads(&store, &back);
        assert_eq!(repacked, packed);
    }

    #[test]
    fn pack_grads_zero_fills_missing_params() {
        let (store, _model) = factory(8)();
        // gradient list covering only the first parameter
        let first = store.ids().next().unwrap();
        let g0 = Tensor::zeros(store.get(first).shape());
        let packed = pack_grads(&store, &[(first, g0)]);
        assert_eq!(packed.len(), store.num_scalars());
        assert!(packed.iter().all(|&v| v == 0.0));
        // shapes survive the round trip even with zero-filled params
        let back = unpack_grads(&store, &packed);
        assert_eq!(back.len(), store.len());
        for (id, g) in &back {
            assert_eq!(g.shape(), store.get(*id).shape());
        }
    }

    #[test]
    fn allreduce_mean_matches_scalar_reference() {
        let mut rng = Rng::new(3);
        let len = 1000usize;
        let parts_owned: Vec<Vec<f32>> = (0..3)
            .map(|_| (0..len).map(|_| rng.normal_f32(0.0, 1.0)).collect())
            .collect();
        let parts: Vec<&[f32]> = parts_owned.iter().map(|p| p.as_slice()).collect();
        let got = allreduce_mean(&parts);
        // the contract is a *deterministic* replica-order sum scaled by a
        // precomputed reciprocal — mirror that exact op order here
        // (x * (1/3) differs from x / 3 in the last ulp for ~1/3 of f32s)
        let inv = 1.0f32 / 3.0;
        for i in 0..len {
            let want = (parts_owned[0][i] + parts_owned[1][i] + parts_owned[2][i]) * inv;
            assert!(
                got[i].to_bits() == want.to_bits(),
                "element {i}: {} vs {}",
                got[i],
                want
            );
        }
    }

    #[test]
    fn two_workers_train_and_loss_falls() {
        let (xs, ys) = toy_data(64, 8, 1);
        let shards = shard_dataset(xs, ys, 2);
        let mut opt = Adam::new(5e-3);
        let cfg = DataParallelConfig {
            workers: 2,
            epochs: 4,
            batch_size: 8,
            grad_clip: Some(5.0),
            seed: 0,
            pipeline: false,
        };
        let res = DataParallelCoordinator::run(factory(8), shards, &mut opt, &cfg);
        assert!(res.steps >= 8, "too few steps: {}", res.steps);
        let k = res.step_losses.len();
        let early: f32 = res.step_losses[..3].iter().sum::<f32>() / 3.0;
        let late: f32 = res.step_losses[k - 3..].iter().sum::<f32>() / 3.0;
        assert!(late < early, "loss did not fall: {early} -> {late}");
        assert_eq!(res.final_params.len(), factory(8)().0.num_scalars());
    }

    #[test]
    fn single_worker_equals_plain_training() {
        // workers=1 coordinator ~ serial fit on the same data/seed
        let (xs, ys) = toy_data(32, 8, 2);
        let shards = shard_dataset(xs, ys, 1);
        let mut opt = Adam::new(1e-2);
        let cfg = DataParallelConfig {
            workers: 1,
            epochs: 2,
            batch_size: 8,
            grad_clip: None,
            seed: 0,
            pipeline: false,
        };
        let res = DataParallelCoordinator::run(factory(8), shards, &mut opt, &cfg);
        assert_eq!(res.steps, 8); // 32/8 * 2 epochs
        assert!(res.step_losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn uneven_shards_still_complete() {
        // 3 shards over 10 examples: sizes 4/3/3 — replicas exhaust their
        // shards at different steps and the run must still drain cleanly
        let (xs, ys) = toy_data(10, 8, 5);
        let shards = shard_dataset(xs, ys, 3);
        let mut opt = Adam::new(1e-2);
        let cfg = DataParallelConfig {
            workers: 3,
            epochs: 2,
            batch_size: 3,
            grad_clip: None,
            seed: 0,
            pipeline: false,
        };
        let res = DataParallelCoordinator::run(factory(8), shards, &mut opt, &cfg);
        assert!(res.steps >= 2, "steps {}", res.steps);
        assert!(res.step_losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn pipelined_runs_are_deterministic_and_converge() {
        // pipeline on: staleness-1 gradients, but a fixed deterministic
        // schedule — two runs must agree bit-for-bit, consume exactly as
        // many optimizer steps as the synchronous path, and still learn
        let run = |pipeline: bool| {
            let (xs, ys) = toy_data(64, 8, 1);
            let shards = shard_dataset(xs, ys, 2);
            let mut opt = Adam::new(5e-3);
            let cfg = DataParallelConfig {
                workers: 2,
                epochs: 4,
                batch_size: 8,
                grad_clip: Some(5.0),
                seed: 0,
                pipeline,
            };
            DataParallelCoordinator::run(factory(8), shards, &mut opt, &cfg)
        };
        let a = run(true);
        let b = run(true);
        let sync = run(false);
        assert_eq!(a.steps, sync.steps, "pipelining must not change the step count");
        assert_eq!(a.step_losses.len(), b.step_losses.len());
        for (i, (x, y)) in a.final_params.iter().zip(&b.final_params).enumerate() {
            assert!(
                x.to_bits() == y.to_bits(),
                "pipelined run not reproducible at param {i}: {x} vs {y}"
            );
        }
        for (x, y) in a.step_losses.iter().zip(&b.step_losses) {
            assert!(x.to_bits() == y.to_bits(), "pipelined losses not reproducible");
        }
        let k = a.step_losses.len();
        let early: f32 = a.step_losses[..3].iter().sum::<f32>() / 3.0;
        let late: f32 = a.step_losses[k - 3..].iter().sum::<f32>() / 3.0;
        assert!(late < early, "pipelined loss did not fall: {early} -> {late}");
    }

    #[test]
    fn pipelined_uneven_shards_drain_cleanly() {
        // replicas exhaust their shards at different steps; the pipeline
        // must keep dispatching the shrinking live set and drain the
        // final in-flight gradients
        let (xs, ys) = toy_data(10, 8, 5);
        let shards = shard_dataset(xs, ys, 3);
        let mut opt = Adam::new(1e-2);
        let cfg = DataParallelConfig {
            workers: 3,
            epochs: 2,
            batch_size: 3,
            grad_clip: None,
            seed: 0,
            pipeline: true,
        };
        let res = DataParallelCoordinator::run(factory(8), shards, &mut opt, &cfg);
        assert!(res.steps >= 2, "steps {}", res.steps);
        assert!(res.step_losses.iter().all(|l| l.is_finite()));
        assert_eq!(res.final_params.len(), factory(8)().0.num_scalars());
    }

    #[test]
    fn shard_dataset_balances() {
        let (xs, ys) = toy_data(10, 4, 3);
        let shards = shard_dataset(xs, ys, 3);
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s >= 3));
    }
}
