//! Data-parallel training coordinator.
//!
//! Because the parallel LMU has no sequential dependency inside a training
//! step, scaling out is plain synchronous data parallelism:
//!
//!   coordinator                      worker w (thread)
//!   ───────────                      ─────────────────
//!   broadcast packed params  ───►    unpack into local replica store
//!                                    build tape on local shard batch
//!                                    backward, pack gradients
//!   average gradients        ◄───    send packed grads
//!   Adam step on canonical store
//!   (repeat)
//!
//! Workers own their replicas (the tape's `Rc` internals are not `Send`,
//! so graphs never cross threads — only packed `Vec<f32>` do, which is
//! also how a real multi-host version would wire NCCL/collectives).

use crate::autograd::{Graph, ParamId, ParamStore};
use crate::data::batcher::{BatchIter, SeqDataset};
use crate::optim::{clip_global_norm, Optimizer};
use crate::train::TrainableModel;
use crate::util::Rng;
use std::sync::mpsc;

/// Pack a sparse (ParamId, grad) list into a dense store-ordered flat
/// vector (missing params get zeros) — the "wire format" of the allreduce.
pub fn pack_grads(store: &ParamStore, grads: &[(ParamId, crate::tensor::Tensor)]) -> Vec<f32> {
    let mut offsets = Vec::with_capacity(store.len());
    let mut total = 0usize;
    for id in store.ids() {
        offsets.push(total);
        total += store.get(id).len();
    }
    let mut flat = vec![0.0f32; total];
    for (pid, g) in grads {
        let ofs = offsets[pid.0];
        for (dst, src) in flat[ofs..ofs + g.len()].iter_mut().zip(g.data()) {
            *dst += src;
        }
    }
    flat
}

/// Unpack a dense flat gradient into (ParamId, Tensor) pairs.
pub fn unpack_grads(store: &ParamStore, flat: &[f32]) -> Vec<(ParamId, crate::tensor::Tensor)> {
    let mut out = Vec::with_capacity(store.len());
    let mut ofs = 0usize;
    for id in store.ids() {
        let t = store.get(id);
        let g = crate::tensor::Tensor::new(t.shape(), flat[ofs..ofs + t.len()].to_vec());
        ofs += t.len();
        out.push((id, g));
    }
    out
}

#[derive(Clone, Debug)]
pub struct DataParallelConfig {
    pub workers: usize,
    pub epochs: usize,
    pub batch_size: usize,
    pub grad_clip: Option<f32>,
    pub seed: u64,
}

impl Default for DataParallelConfig {
    fn default() -> Self {
        DataParallelConfig { workers: 2, epochs: 1, batch_size: 16, grad_clip: None, seed: 0 }
    }
}

/// Coordinator output.
pub struct DataParallelResult {
    /// per-step mean loss across workers
    pub step_losses: Vec<f32>,
    /// final packed parameters (canonical replica)
    pub final_params: Vec<f32>,
    pub steps: usize,
}

pub struct DataParallelCoordinator;

impl DataParallelCoordinator {
    /// Run synchronous data-parallel training.
    ///
    /// `factory` builds a fresh (store, model) replica — it is called once
    /// on the coordinator (canonical replica, owns the optimizer state)
    /// and once inside every worker thread.  All replicas must produce an
    /// identical parameter layout (same construction order), which holds
    /// by construction since they run the same code with the same shapes.
    pub fn run<F, M>(
        factory: F,
        shards: Vec<SeqDataset>,
        opt: &mut dyn Optimizer,
        cfg: &DataParallelConfig,
    ) -> DataParallelResult
    where
        F: Fn() -> (ParamStore, M) + Send + Sync + Clone + 'static,
        M: TrainableModel,
    {
        assert_eq!(shards.len(), cfg.workers, "one shard per worker");
        let (mut canon_store, _canon_model) = factory();

        // per-worker command/result channels
        enum Cmd {
            Step(Vec<f32>), // packed params
            Stop,
        }
        struct WorkerOut {
            #[allow(dead_code)]
            worker: usize,
            grads: Vec<f32>,
            loss: f32,
            batches_left: usize,
        }

        let (res_tx, res_rx) = mpsc::channel::<WorkerOut>();
        let mut cmd_txs = Vec::new();
        let mut handles = Vec::new();
        for (w, shard) in shards.into_iter().enumerate() {
            let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
            cmd_txs.push(cmd_tx);
            let res_tx = res_tx.clone();
            let factory = factory.clone();
            let cfg = cfg.clone();
            // replica threads ARE the parallelism: the whole worker body
            // (model construction included — DnFftOperator::new fans out
            // too) runs with the kernel-level exec substrate serialized,
            // so replica count × kernel threads never multiply.
            handles.push(std::thread::spawn(move || {
                crate::exec::run_serialized(|| {
                    let (mut store, model) = factory();
                    let mut rng = Rng::new(cfg.seed ^ (w as u64 + 1).wrapping_mul(0x9E37_79B9));
                    let per_epoch = shard.len() / cfg.batch_size.min(shard.len());
                    let mut remaining = per_epoch * cfg.epochs;
                    'epochs: for _epoch in 0..cfg.epochs {
                        let mut batches: Vec<_> =
                            BatchIter::new(&shard, cfg.batch_size.min(shard.len()), &mut rng)
                                .collect();
                        for batch in batches.drain(..) {
                            // wait for fresh params
                            match cmd_rx.recv() {
                                Ok(Cmd::Step(params)) => store.unpack(&params),
                                _ => break 'epochs,
                            }
                            let mut g = Graph::new();
                            let loss = model.loss(&mut g, &store, &batch);
                            g.backward(loss);
                            let lv = g.value(loss).item();
                            let grads = g.param_grads();
                            let packed = pack_grads(&store, &grads);
                            remaining -= 1;
                            if res_tx
                                .send(WorkerOut {
                                    worker: w,
                                    grads: packed,
                                    loss: lv,
                                    batches_left: remaining,
                                })
                                .is_err()
                            {
                                break 'epochs;
                            }
                        }
                    }
                    // drain any final Stop
                    while let Ok(cmd) = cmd_rx.recv() {
                        if matches!(cmd, Cmd::Stop) {
                            break;
                        }
                    }
                });
            }));
        }
        drop(res_tx);

        let mut step_losses = Vec::new();
        let mut steps = 0usize;
        loop {
            // broadcast current parameters
            let packed = canon_store.pack();
            let mut live = 0usize;
            for tx in &cmd_txs {
                if tx.send(Cmd::Step(packed.clone())).is_ok() {
                    live += 1;
                }
            }
            if live == 0 {
                break;
            }
            // gather gradients from every live worker (synchronous step)
            let mut sum: Option<Vec<f32>> = None;
            let mut losses = 0.0f32;
            let mut got = 0usize;
            let mut done_workers = 0usize;
            for _ in 0..live {
                match res_rx.recv() {
                    Ok(out) => {
                        losses += out.loss;
                        got += 1;
                        if out.batches_left == 0 {
                            done_workers += 1;
                        }
                        match &mut sum {
                            Some(s) => {
                                for (a, b) in s.iter_mut().zip(&out.grads) {
                                    *a += b;
                                }
                            }
                            None => sum = Some(out.grads),
                        }
                    }
                    Err(_) => break,
                }
            }
            if got == 0 {
                break;
            }
            let mut avg = sum.unwrap();
            let inv = 1.0 / got as f32;
            for v in avg.iter_mut() {
                *v *= inv;
            }
            let mut grads = unpack_grads(&canon_store, &avg);
            if let Some(c) = cfg.grad_clip {
                clip_global_norm(&mut grads, c);
            }
            opt.step(&mut canon_store, &grads);
            step_losses.push(losses / got as f32);
            steps += 1;
            if done_workers == got {
                break; // every worker exhausted its shard for all epochs
            }
        }
        for tx in &cmd_txs {
            let _ = tx.send(Cmd::Stop);
        }
        drop(cmd_txs);
        for h in handles {
            let _ = h.join();
        }
        DataParallelResult { step_losses, final_params: canon_store.pack(), steps }
    }
}

/// Split a dataset into `k` shards (round-robin).
pub fn shard_dataset(xs: Vec<crate::tensor::Tensor>, ys: Vec<usize>, k: usize) -> Vec<SeqDataset> {
    let mut parts: Vec<(Vec<crate::tensor::Tensor>, Vec<usize>)> =
        (0..k).map(|_| (Vec::new(), Vec::new())).collect();
    for (i, (x, y)) in xs.into_iter().zip(ys).enumerate() {
        parts[i % k].0.push(x);
        parts[i % k].1.push(y);
    }
    parts
        .into_iter()
        .map(|(x, y)| SeqDataset::classification(x, y))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::Adam;
    use crate::tensor::Tensor;
    use crate::train::{ModelKind, SeqClassifier};

    fn toy_data(n: usize, seq: usize, seed: u64) -> (Vec<Tensor>, Vec<usize>) {
        let mut rng = Rng::new(seed);
        let mut xs = Vec::new();
        let mut ys = Vec::new();
        for i in 0..n {
            let sign = if i % 2 == 0 { 0.5f32 } else { -0.5 };
            let mut x = Tensor::randn(&[seq, 1], 0.5, &mut rng);
            x.map_inplace(|v| v + sign);
            xs.push(x);
            ys.push(usize::from(sign > 0.0));
        }
        (xs, ys)
    }

    fn factory(seq: usize) -> impl Fn() -> (ParamStore, SeqClassifier) + Send + Sync + Clone {
        move || {
            let mut store = ParamStore::new();
            let mut rng = Rng::new(42);
            let model =
                SeqClassifier::new(ModelKind::LmuParallel, seq, 1, 4, 8, 2, &mut store, &mut rng);
            (store, model)
        }
    }

    #[test]
    fn pack_unpack_grads_roundtrip() {
        let (store, _model) = factory(8)();
        let mut rng = Rng::new(0);
        let grads: Vec<(ParamId, Tensor)> = store
            .ids()
            .map(|id| (id, Tensor::randn(store.get(id).shape(), 1.0, &mut rng)))
            .collect();
        let packed = pack_grads(&store, &grads);
        assert_eq!(packed.len(), store.num_scalars());
        let back = unpack_grads(&store, &packed);
        for ((id1, g1), (id2, g2)) in grads.iter().zip(&back) {
            assert_eq!(id1, id2);
            assert!(g1.allclose(g2, 0.0));
        }
    }

    #[test]
    fn two_workers_train_and_loss_falls() {
        let (xs, ys) = toy_data(64, 8, 1);
        let shards = shard_dataset(xs, ys, 2);
        let mut opt = Adam::new(5e-3);
        let cfg = DataParallelConfig {
            workers: 2,
            epochs: 4,
            batch_size: 8,
            grad_clip: Some(5.0),
            seed: 0,
        };
        let res = DataParallelCoordinator::run(factory(8), shards, &mut opt, &cfg);
        assert!(res.steps >= 8, "too few steps: {}", res.steps);
        let k = res.step_losses.len();
        let early: f32 = res.step_losses[..3].iter().sum::<f32>() / 3.0;
        let late: f32 = res.step_losses[k - 3..].iter().sum::<f32>() / 3.0;
        assert!(late < early, "loss did not fall: {early} -> {late}");
        assert_eq!(res.final_params.len(), factory(8)().0.num_scalars());
    }

    #[test]
    fn single_worker_equals_plain_training() {
        // workers=1 coordinator ~ serial fit on the same data/seed
        let (xs, ys) = toy_data(32, 8, 2);
        let shards = shard_dataset(xs, ys, 1);
        let mut opt = Adam::new(1e-2);
        let cfg = DataParallelConfig {
            workers: 1,
            epochs: 2,
            batch_size: 8,
            grad_clip: None,
            seed: 0,
        };
        let res = DataParallelCoordinator::run(factory(8), shards, &mut opt, &cfg);
        assert_eq!(res.steps, 8); // 32/8 * 2 epochs
        assert!(res.step_losses.iter().all(|l| l.is_finite()));
    }

    #[test]
    fn shard_dataset_balances() {
        let (xs, ys) = toy_data(10, 4, 3);
        let shards = shard_dataset(xs, ys, 3);
        let sizes: Vec<usize> = shards.iter().map(|s| s.len()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 10);
        assert!(sizes.iter().all(|&s| s >= 3));
    }
}
