//! Streaming inference engines: one recurrent step (eq. 19 + eq. 18/20)
//! per call, O(d·du + d²) per token, constant memory — the paper's
//! "Recurrent Inference" deployment mode.
//!
//! Two implementations:
//!  * [`NativeStreamingEngine`] — the step evaluated with the native
//!    tensor kernels (no Python, no XLA);
//!  * `PjrtStreamingEngine` (see examples/streaming_inference.rs) — the
//!    same step through the AOT `recurrent_step.hlo.txt` artifact,
//!    proving weight/semantics parity with the L2 jax model.

use crate::dn::DelayNetwork;
use crate::tensor::{matmul::matvec, Tensor};

/// A streaming engine: advances one session's DN state by one input.
pub trait StreamingEngine {
    /// Dimension of the per-session memory state (d·du floats).
    fn state_size(&self) -> usize;
    /// Dimension of the per-step output vector (hidden floats).
    fn output_size(&self) -> usize;
    /// `step(state, x_t) -> output`; `state` is updated in place.
    fn step(&self, state: &mut [f32], x_t: &[f32]) -> Vec<f32>;
    /// Rough scalar-op cost of one [`StreamingEngine::step`] call — the
    /// work estimate the dynamic batcher feeds to
    /// `crate::exec::plan_for` when deciding whether a batch is big
    /// enough to fan out on the worker pool.  The default overestimates
    /// slightly (safe: it only moves the crossover, never correctness);
    /// implementations with exact shape knowledge should override.
    fn step_work(&self) -> usize {
        self.state_size() * (self.state_size() + self.output_size() + 1)
    }
}

/// Our-model single step with explicit weights (eq. 18 -> 19 -> 20).
pub struct NativeStreamingEngine {
    /// input dimension
    pub dx: usize,
    /// DN channels (eq. 18 encoder width)
    pub du: usize,
    /// DN order (memory dimensions per channel)
    pub d: usize,
    /// output width (eq. 20)
    pub hidden: usize,
    abar: Tensor,     // (d, d)
    bbar: Vec<f32>,   // (d,)
    ux: Tensor,       // (dx, du)
    bu: Vec<f32>,     // (du,)
    wm: Tensor,       // (du·d, hidden)  channel-major rows
    wx: Tensor,       // (dx, hidden)
    bo: Vec<f32>,     // (hidden,)
    /// apply tanh in eq. 18 (f1)
    pub nonlin_u: bool,
    /// apply tanh in eq. 20 (f2)
    pub nonlin_o: bool,
}

impl NativeStreamingEngine {
    /// Build from explicit weights (shapes asserted); the DN's discretized
    /// (Ā, B̄) pair is derived from `(d, theta)`.
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        dx: usize,
        du: usize,
        d: usize,
        theta: f64,
        hidden: usize,
        ux: Tensor,
        bu: Vec<f32>,
        wm: Tensor,
        wx: Tensor,
        bo: Vec<f32>,
    ) -> Self {
        let dn = DelayNetwork::new(d, theta);
        assert_eq!(ux.shape(), &[dx, du]);
        assert_eq!(wm.shape(), &[du * d, hidden]);
        assert_eq!(wx.shape(), &[dx, hidden]);
        NativeStreamingEngine {
            dx,
            du,
            d,
            hidden,
            abar: dn.abar_f32.clone(),
            bbar: dn.bbar_f32.clone(),
            ux,
            bu,
            wm,
            wx,
            bo,
            nonlin_u: true,
            nonlin_o: true,
        }
    }

    /// Build from a trained parallel layer's parameters.
    pub fn from_store(
        spec: &crate::layers::lmu::LmuSpec,
        params: &crate::layers::lmu::LmuParams,
        store: &crate::autograd::ParamStore,
    ) -> Self {
        let mut e = NativeStreamingEngine::new(
            spec.dx,
            spec.du,
            spec.d,
            spec.theta,
            spec.hidden,
            store.get(params.ux).clone(),
            store.get(params.bu).data().to_vec(),
            store.get(params.wm).clone(),
            store.get(params.wx).clone(),
            store.get(params.bo).data().to_vec(),
        );
        e.nonlin_u = spec.nonlin_u;
        e.nonlin_o = spec.nonlin_o;
        e
    }
}

impl StreamingEngine for NativeStreamingEngine {
    fn state_size(&self) -> usize {
        self.du * self.d
    }

    fn output_size(&self) -> usize {
        self.hidden
    }

    fn step_work(&self) -> usize {
        // eq. 19 Ā matvec per channel + eq. 20 output map + eq. 18 encoder
        self.du * self.d * self.d
            + self.du * self.d * self.hidden
            + self.dx * (self.du + self.hidden)
    }

    fn step(&self, state: &mut [f32], x_t: &[f32]) -> Vec<f32> {
        assert_eq!(state.len(), self.du * self.d, "state size");
        assert_eq!(x_t.len(), self.dx, "input size");
        let (du, d, hidden) = (self.du, self.d, self.hidden);
        // eq. 18: u = f1(x Ux + bu)
        let mut u = vec![0.0f32; du];
        for c in 0..du {
            let mut acc = self.bu[c];
            for (j, &xv) in x_t.iter().enumerate() {
                acc += xv * self.ux.data()[j * du + c];
            }
            u[c] = if self.nonlin_u { acc.tanh() } else { acc };
        }
        // eq. 19 per channel: m_c = Ā m_c + B̄ u_c  (state stored channel-major)
        for c in 0..du {
            let m_c = &state[c * d..(c + 1) * d];
            let mut new_m = matvec(&self.abar, m_c);
            for (s, nm) in new_m.iter_mut().enumerate() {
                *nm += self.bbar[s] * u[c];
            }
            state[c * d..(c + 1) * d].copy_from_slice(&new_m);
        }
        // eq. 20: o = f2(m Wm + x Wx + bo)
        let mut out = self.bo.clone();
        for (r, &mv) in state.iter().enumerate() {
            if mv == 0.0 {
                continue;
            }
            let wrow = &self.wm.data()[r * hidden..(r + 1) * hidden];
            for (o, &wv) in out.iter_mut().zip(wrow) {
                *o += mv * wv;
            }
        }
        for (j, &xv) in x_t.iter().enumerate() {
            let wrow = &self.wx.data()[j * hidden..(j + 1) * hidden];
            for (o, &wv) in out.iter_mut().zip(wrow) {
                *o += xv * wv;
            }
        }
        if self.nonlin_o {
            for o in out.iter_mut() {
                *o = o.tanh();
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::{Graph, ParamStore};
    use crate::layers::lmu::{LmuParallelLayer, LmuSpec};
    use crate::util::Rng;

    #[test]
    fn streaming_matches_parallel_training_path() {
        // Paper's central deployment claim: the recurrent engine computes
        // exactly what the parallel (training) path computes.
        let mut rng = Rng::new(0);
        let mut store = ParamStore::new();
        let (n, batch) = (24usize, 1usize);
        let spec = LmuSpec::new(3, 2, 8, 24.0, 6);
        let layer = LmuParallelLayer::new(spec.clone(), n, &mut store, &mut rng, "srv");
        let x = Tensor::randn(&[n, 3], 1.0, &mut rng);

        // parallel path (all states)
        let mut g = Graph::new();
        let xi = g.input(x.clone());
        let o_par = layer.forward_all(&mut g, &store, xi, batch);
        let par = g.value(o_par).clone(); // (n, hidden)

        // streaming path
        let engine = NativeStreamingEngine::from_store(&spec, &layer.params, &store);
        let mut state = vec![0.0f32; engine.state_size()];
        let mut max_err = 0.0f32;
        for t in 0..n {
            let out = engine.step(&mut state, &x.data()[t * 3..(t + 1) * 3]);
            for (j, &v) in out.iter().enumerate() {
                max_err = max_err.max((v - par.data()[t * 6 + j]).abs());
            }
        }
        assert!(max_err < 2e-4, "stream vs parallel: {max_err}");
    }

    #[test]
    fn state_isolated_between_sessions() {
        let mut rng = Rng::new(1);
        let mut store = ParamStore::new();
        let spec = LmuSpec::new(1, 1, 4, 8.0, 3);
        let layer = LmuParallelLayer::new(spec.clone(), 8, &mut store, &mut rng, "srv");
        let engine = NativeStreamingEngine::from_store(&spec, &layer.params, &store);
        let mut s1 = vec![0.0f32; engine.state_size()];
        let mut s2 = vec![0.0f32; engine.state_size()];
        // session 1 sees a big impulse, session 2 zeros
        engine.step(&mut s1, &[10.0]);
        engine.step(&mut s2, &[0.0]);
        assert!(s1.iter().any(|&v| v.abs() > 1e-3));
        // fresh state for s2 was never affected by s1's history
        let out2 = engine.step(&mut s2, &[0.0]);
        let mut s2b = vec![0.0f32; engine.state_size()];
        engine.step(&mut s2b, &[0.0]);
        let out2b = engine.step(&mut s2b, &[0.0]);
        for (a, b) in out2.iter().zip(&out2b) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn per_token_cost_is_constant_memory() {
        // state buffer never grows with stream length
        let mut rng = Rng::new(2);
        let mut store = ParamStore::new();
        let spec = LmuSpec::new(1, 1, 6, 16.0, 4);
        let layer = LmuParallelLayer::new(spec.clone(), 16, &mut store, &mut rng, "srv");
        let engine = NativeStreamingEngine::from_store(&spec, &layer.params, &store);
        let mut state = vec![0.0f32; engine.state_size()];
        for t in 0..10_000 {
            let out = engine.step(&mut state, &[(t as f32 * 0.01).sin()]);
            assert_eq!(out.len(), 4);
        }
        assert_eq!(state.len(), engine.state_size());
        assert!(state.iter().all(|v| v.is_finite()));
    }
}
