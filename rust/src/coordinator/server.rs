//! The streaming-inference server: session table, dynamic batcher, and a
//! round-robin router over engine replicas (vllm-router-style, scaled to
//! this paper: the "KV cache" of an LMU is a single (d·du) DN state per
//! session, constant in sequence length — the paper's memory-constrained
//! inference story).
//!
//! ## Thread-budget story
//!
//! Each [`DynamicBatcher`] owns one *control* thread that blocks on its
//! request channel (parked, costing nothing while idle).  The *compute* —
//! executing a filled batch — is dispatched through the shared
//! `crate::exec` worker pool, fanning out across the batch's distinct
//! sessions as work-stealing chunks.  The pool admits one *top-level*
//! dispatcher at a time and splits the configured `threads` budget
//! hierarchically over a job's chunk slots (a batch with fewer sessions
//! than threads hands each session a sub-budget for its nested kernels),
//! so engine replicas × kernel threads can never oversubscribe the
//! machine: concurrent batchers time-share the pool (a batcher that finds
//! the pool busy runs its batch serially on its own control thread).
//!
//! Engines that are not `Sync` (e.g. PJRT-backed engines holding
//! thread-bound handles, built via [`DynamicBatcher::with_factory`]) stay
//! pinned to their control thread and execute serially inside
//! `exec::run_serialized`, so their kernel calls don't fan out either.

use super::engine::StreamingEngine;
use crate::exec;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A deferred engine constructor, run INSIDE the batcher's control
/// thread — the escape hatch for engines that are not `Send`/`Sync`
/// (e.g. PJRT clients holding thread-bound handles).
pub type EngineFactory = Box<dyn FnOnce() -> Box<dyn StreamingEngine> + Send>;

/// A step request: advance `session` with input `x`, reply on `reply`.
pub struct StepRequest {
    /// session id whose DN state this step advances
    pub session: u64,
    /// one input vector (dx floats)
    pub x: Vec<f32>,
    /// channel the [`StepResponse`] is delivered on
    pub reply: mpsc::Sender<StepResponse>,
    /// when the request entered the batcher queue
    pub enqueued: Instant,
}

/// The result of one streaming step.
#[derive(Clone, Debug)]
pub struct StepResponse {
    /// session id the output belongs to
    pub session: u64,
    /// engine output (hidden floats)
    pub output: Vec<f32>,
    /// time from enqueue to completion
    pub latency: Duration,
}

/// Dynamic-batching knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// max requests per batch window
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch
    pub window: Duration,
    /// Pipeline batches: dispatch batch `k+1`'s session fan-out as an
    /// async pool job and deliver batch `k`'s replies while it computes,
    /// so the control thread's reply packing overlaps pool compute
    /// instead of serializing after it.  Per-session outputs and their
    /// order are unchanged (states always advance batch-by-batch); the
    /// cost is up to one extra batch window of reply latency when the
    /// request stream goes idle.  Only `Sync` engines pipeline;
    /// thread-bound (factory) engines always run the serial path.
    pub pipeline: bool,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 32, window: Duration::from_micros(500), pipeline: false }
    }
}

/// Aggregate serving metrics (updated by the batcher thread, read from
/// anywhere through the shared `Arc`).
#[derive(Default)]
pub struct ServerMetrics {
    /// total step requests completed
    pub requests: AtomicU64,
    /// total batch windows executed
    pub batches: AtomicU64,
    /// sum of request latencies in microseconds
    pub total_latency_us: AtomicU64,
}

impl ServerMetrics {
    /// Mean request latency in microseconds (0 before the first request).
    pub fn mean_latency_us(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.total_latency_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    /// Mean number of requests per executed batch (0 before the first).
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

/// Dynamic batcher + session table driving one engine replica.  The
/// control thread blocks on the request channel; batch compute dispatches
/// through the shared exec pool (see the module docs).
pub struct DynamicBatcher {
    tx: mpsc::Sender<BatcherCmd>,
    /// live serving metrics of this replica
    pub metrics: Arc<ServerMetrics>,
    handle: Option<std::thread::JoinHandle<()>>,
}

enum BatcherCmd {
    Step(StepRequest),
    Reset(u64),
    Shutdown,
}

/// How the batcher thread obtains its engine.
enum EngineSource {
    /// a `Sync` engine moved into the thread — batches fan out on the pool
    Shared(Box<dyn StreamingEngine + Send + Sync>),
    /// built inside the thread (thread-bound handles) — batches run serial
    Factory(EngineFactory),
}

/// The engine as held by the running batcher thread.
enum BatchEngine {
    Shared(Box<dyn StreamingEngine + Send + Sync>),
    Local(Box<dyn StreamingEngine>),
}

impl BatchEngine {
    fn engine(&self) -> &dyn StreamingEngine {
        match self {
            BatchEngine::Shared(e) => &**e,
            BatchEngine::Local(e) => &**e,
        }
    }
}

/// One session's share of a batch: its state, its requests (arrival
/// order), and the outputs produced for them.
struct SessionRun {
    session: u64,
    state: Vec<f32>,
    reqs: Vec<StepRequest>,
    outs: Vec<Vec<f32>>,
}

/// Group a window's requests by session (per-session arrival order
/// preserved), pulling each session's state out of the table — or
/// zero-initializing a fresh one — so the independent groups can cross
/// to pool threads.
fn build_groups(
    state_size: usize,
    sessions: &mut HashMap<u64, Vec<f32>>,
    pending: &mut Vec<StepRequest>,
) -> Vec<SessionRun> {
    let mut groups: Vec<SessionRun> = Vec::new();
    let mut index: HashMap<u64, usize> = HashMap::new();
    for req in pending.drain(..) {
        let gi = *index.entry(req.session).or_insert_with(|| {
            let state =
                sessions.remove(&req.session).unwrap_or_else(|| vec![0.0f32; state_size]);
            groups.push(SessionRun { session: req.session, state, reqs: Vec::new(), outs: Vec::new() });
            groups.len() - 1
        });
        groups[gi].reqs.push(req);
    }
    groups
}

/// Return every group's advanced state to the session table.  This must
/// happen before the NEXT batch is grouped (a session present in both
/// batches must see its advanced state), which is why it is split from
/// reply delivery in the pipelined path.
fn reinsert_states(groups: &mut [SessionRun], sessions: &mut HashMap<u64, Vec<f32>>) {
    for g in groups.iter_mut() {
        sessions.insert(g.session, std::mem::take(&mut g.state));
    }
}

/// Send a computed batch's replies (per-session arrival order preserved)
/// and update the request metrics.  In pipelined mode this is the
/// control thread's overlapped stage: it runs while the next batch's
/// session fan-out computes on the pool.
fn deliver_replies(parked: &mut Vec<SessionRun>, metrics: &ServerMetrics) {
    for g in parked.drain(..) {
        for (req, output) in g.reqs.into_iter().zip(g.outs) {
            let latency = req.enqueued.elapsed();
            metrics.requests.fetch_add(1, Ordering::Relaxed);
            metrics
                .total_latency_us
                .fetch_add(latency.as_micros() as u64, Ordering::Relaxed);
            let _ = req.reply.send(StepResponse { session: req.session, output, latency });
        }
    }
}

/// Execute one filled batch synchronously: group requests by session,
/// fan the independent sessions out on the exec pool (shared engines) or
/// run them serialized (thread-bound engines), then reinsert states and
/// deliver replies.
fn execute_batch(
    engine: &BatchEngine,
    sessions: &mut HashMap<u64, Vec<f32>>,
    pending: &mut Vec<StepRequest>,
    metrics: &ServerMetrics,
) {
    let state_size = engine.engine().state_size();
    let mut groups = build_groups(state_size, sessions, pending);
    let total_reqs: usize = groups.iter().map(|g| g.reqs.len()).sum();
    match engine {
        BatchEngine::Shared(e) => {
            let eng: &(dyn StreamingEngine + Send + Sync) = &**e;
            // distinct sessions are independent; requests within a session
            // stay in order inside their chunk.  Fewer sessions than
            // threads hands each session chunk a sub-budget, so a big
            // per-step kernel can still fan out beneath it; session
            // chunks are stolen off the shared counter, so a batch with
            // one long session no longer stalls the whole window on a
            // static partition.
            let plan = exec::plan_for(groups.len(), total_reqs * eng.step_work());
            exec::parallel_rows_mut(&mut groups, 1, plan, |_, block| {
                for g in block.iter_mut() {
                    for req in &g.reqs {
                        g.outs.push(eng.step(&mut g.state, &req.x));
                    }
                }
            });
        }
        BatchEngine::Local(e) => {
            // thread-bound engine: serial, and flagged so nested kernels
            // don't fan out under a control thread
            exec::run_serialized(|| {
                for g in groups.iter_mut() {
                    for req in &g.reqs {
                        g.outs.push(e.step(&mut g.state, &req.x));
                    }
                }
            });
        }
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    reinsert_states(&mut groups, sessions);
    deliver_replies(&mut groups, metrics);
}

/// Execute one filled batch in pipelined mode: the session fan-out is
/// dispatched as an **async** pool job and the previous batch's replies
/// are delivered while it computes.  After the job drains, states return
/// to the session table immediately (the next batch's grouping needs
/// them) and the fresh replies are parked in `undelivered` until the
/// next batch is in flight — or the batcher goes idle, which flushes
/// them within one window.
fn pipelined_batch(
    eng: &(dyn StreamingEngine + Send + Sync),
    sessions: &mut HashMap<u64, Vec<f32>>,
    pending: &mut Vec<StepRequest>,
    undelivered: &mut Vec<SessionRun>,
    metrics: &ServerMetrics,
) {
    let mut groups = build_groups(eng.state_size(), sessions, pending);
    let total_reqs: usize = groups.iter().map(|g| g.reqs.len()).sum();
    let plan = exec::plan_for(groups.len(), total_reqs * eng.step_work());
    if plan.is_serial() {
        // too small to fan out: flush owed replies first (per-session
        // reply order), then compute and deliver inline
        deliver_replies(undelivered, metrics);
        for g in groups.iter_mut() {
            for req in &g.reqs {
                g.outs.push(eng.step(&mut g.state, &req.x));
            }
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        reinsert_states(&mut groups, sessions);
        deliver_replies(&mut groups, metrics);
        return;
    }
    // the control thread reserves itself for reply packing; the session
    // fan-out gets the remaining budget, so both in-flight stages sum to
    // at most the configured thread count
    let budget = exec::threads().saturating_sub(1).max(1);
    let workers = plan.workers.min(budget);
    exec::parallel_rows_overlap(
        &mut groups,
        1,
        workers,
        budget,
        move |_, block| {
            for g in block.iter_mut() {
                for req in &g.reqs {
                    g.outs.push(eng.step(&mut g.state, &req.x));
                }
            }
        },
        // overlapped stage: previous batch's replies go out while this
        // batch computes on the pool
        || deliver_replies(undelivered, metrics),
    );
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    reinsert_states(&mut groups, sessions);
    *undelivered = groups;
}

impl DynamicBatcher {
    /// Build from a shareable engine: batch compute fans out across the
    /// batch's sessions on the shared exec pool.
    pub fn new(engine: Box<dyn StreamingEngine + Send + Sync>, cfg: ServerConfig) -> Self {
        Self::start(EngineSource::Shared(engine), cfg)
    }

    /// Build from a factory that constructs the engine INSIDE the batcher
    /// thread — required for engines that are not `Send`/`Sync` (the PJRT
    /// client holds thread-bound handles).  Batches for such engines run
    /// serially on the control thread.
    pub fn with_factory(factory: EngineFactory, cfg: ServerConfig) -> Self {
        Self::start(EngineSource::Factory(factory), cfg)
    }

    fn start(source: EngineSource, cfg: ServerConfig) -> Self {
        let (tx, rx) = mpsc::channel::<BatcherCmd>();
        let metrics = Arc::new(ServerMetrics::default());
        let m = metrics.clone();
        // lint-src: allow(thread-spawn) — the batcher is a long-lived service
        // thread, deliberately outside the pool's work budget
        let handle = std::thread::spawn(move || {
            let engine = match source {
                EngineSource::Shared(e) => BatchEngine::Shared(e),
                EngineSource::Factory(f) => BatchEngine::Local(f()),
            };
            let mut sessions: HashMap<u64, Vec<f32>> = HashMap::new();
            let mut pending: Vec<StepRequest> = Vec::new();
            // pipelined mode: the last computed batch, states already
            // reinserted, replies not yet sent
            let mut undelivered: Vec<SessionRun> = Vec::new();
            let mut shutdown = false;
            while !shutdown {
                // block for the first request (or control message); with
                // replies still owed, bound the block by one window so an
                // idle channel can never stall them
                let first = if undelivered.is_empty() {
                    match rx.recv() {
                        Ok(cmd) => Some(cmd),
                        Err(_) => break,
                    }
                } else {
                    match rx.recv_timeout(cfg.window) {
                        Ok(cmd) => Some(cmd),
                        Err(mpsc::RecvTimeoutError::Timeout) => None,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            shutdown = true;
                            None
                        }
                    }
                };
                match first {
                    Some(BatcherCmd::Step(r)) => pending.push(r),
                    Some(BatcherCmd::Reset(sid)) => {
                        sessions.remove(&sid);
                        continue;
                    }
                    Some(BatcherCmd::Shutdown) => shutdown = true,
                    None => {}
                }
                if pending.is_empty() {
                    // idle or shutting down: flush owed replies, re-loop
                    deliver_replies(&mut undelivered, &m);
                    continue;
                }
                // fill the window
                let deadline = Instant::now() + cfg.window;
                while pending.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(BatcherCmd::Step(r)) => pending.push(r),
                        Ok(BatcherCmd::Reset(sid)) => {
                            sessions.remove(&sid);
                        }
                        // drain the already-queued requests before exiting,
                        // or their blocked step_blocking callers would
                        // panic on a dropped reply channel
                        Ok(BatcherCmd::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                            shutdown = true;
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                    }
                }
                match (&engine, cfg.pipeline) {
                    (BatchEngine::Shared(e), true) => {
                        pipelined_batch(&**e, &mut sessions, &mut pending, &mut undelivered, &m);
                    }
                    _ => {
                        // per-session reply order: anything a pipelined
                        // batch parked goes out before this batch does
                        deliver_replies(&mut undelivered, &m);
                        execute_batch(&engine, &mut sessions, &mut pending, &m);
                    }
                }
            }
            // shutdown: flush parked replies, then any still-queued batch
            deliver_replies(&mut undelivered, &m);
            if !pending.is_empty() {
                execute_batch(&engine, &mut sessions, &mut pending, &m);
            }
        });
        DynamicBatcher { tx, metrics, handle: Some(handle) }
    }

    /// Enqueue one step; the response arrives on `reply`.
    pub fn submit(&self, session: u64, x: Vec<f32>, reply: mpsc::Sender<StepResponse>) {
        let _ = self.tx.send(BatcherCmd::Step(StepRequest {
            session,
            x,
            reply,
            enqueued: Instant::now(),
        }));
    }

    /// Drop a session's state.
    pub fn reset_session(&self, session: u64) {
        let _ = self.tx.send(BatcherCmd::Reset(session));
    }

    /// Synchronous convenience: submit and wait.
    pub fn step_blocking(&self, session: u64, x: Vec<f32>) -> StepResponse {
        let (tx, rx) = mpsc::channel();
        self.submit(session, x, tx);
        rx.recv().expect("batcher died")
    }
}

impl Drop for DynamicBatcher {
    fn drop(&mut self) {
        let _ = self.tx.send(BatcherCmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Round-robin router over engine replicas, with sticky sessions
/// (a session's DN state lives on exactly one replica).
pub struct Router {
    batchers: Vec<DynamicBatcher>,
    assignment: Mutex<HashMap<u64, usize>>,
    next: AtomicUsize,
}

impl Router {
    /// Build over a non-empty replica set.
    pub fn new(batchers: Vec<DynamicBatcher>) -> Self {
        assert!(!batchers.is_empty());
        Router { batchers, assignment: Mutex::new(HashMap::new()), next: AtomicUsize::new(0) }
    }

    /// Number of engine replicas behind this router.
    pub fn replicas(&self) -> usize {
        self.batchers.len()
    }

    /// Which replica serves this session (assigning round-robin on first
    /// sight — sticky thereafter).
    pub fn route(&self, session: u64) -> usize {
        let mut map = self.assignment.lock().unwrap();
        *map.entry(session).or_insert_with(|| {
            self.next.fetch_add(1, Ordering::Relaxed) % self.batchers.len()
        })
    }

    /// Route, submit, and wait for the response.
    pub fn step_blocking(&self, session: u64, x: Vec<f32>) -> StepResponse {
        let idx = self.route(session);
        self.batchers[idx].step_blocking(session, x)
    }

    /// Forget a session: drop its routing entry and its replica-side state.
    pub fn end_session(&self, session: u64) {
        let idx = {
            let mut map = self.assignment.lock().unwrap();
            map.remove(&session)
        };
        if let Some(i) = idx {
            self.batchers[i].reset_session(session);
        }
    }

    /// Total requests served across all replicas.
    pub fn total_requests(&self) -> u64 {
        self.batchers
            .iter()
            .map(|b| b.metrics.requests.load(Ordering::Relaxed))
            .sum()
    }

    /// Metrics of one replica's batcher.
    pub fn metrics_of(&self, idx: usize) -> &Arc<ServerMetrics> {
        &self.batchers[idx].metrics
    }
}

/// Full server façade: router + config.
pub struct StreamingServer {
    /// the replica router (sticky sessions, round-robin assignment)
    pub router: Router,
}

impl StreamingServer {
    /// Build with `replicas` engines from a factory (engines must be
    /// `Send + Sync`; batch compute shares the exec pool).
    pub fn new<F>(replicas: usize, cfg: ServerConfig, factory: F) -> Self
    where
        F: Fn() -> Box<dyn StreamingEngine + Send + Sync>,
    {
        let batchers = (0..replicas)
            .map(|_| DynamicBatcher::new(factory(), cfg.clone()))
            .collect();
        StreamingServer { router: Router::new(batchers) }
    }

    /// Build from per-replica factories run inside each batcher thread
    /// (for non-`Send` engines, e.g. PJRT-backed ones).
    pub fn with_factories(factories: Vec<EngineFactory>, cfg: ServerConfig) -> Self {
        let batchers = factories
            .into_iter()
            .map(|f| DynamicBatcher::with_factory(f, cfg.clone()))
            .collect();
        StreamingServer { router: Router::new(batchers) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::ParamStore;
    use crate::coordinator::engine::NativeStreamingEngine;
    use crate::layers::lmu::{LmuParallelLayer, LmuSpec};
    use crate::util::Rng;

    fn make_engine(seed: u64) -> NativeStreamingEngine {
        let mut rng = Rng::new(seed);
        let mut store = ParamStore::new();
        let spec = LmuSpec::new(1, 1, 4, 8.0, 3);
        let layer = LmuParallelLayer::new(spec.clone(), 8, &mut store, &mut rng, "srv");
        NativeStreamingEngine::from_store(&spec, &layer.params, &store)
    }

    /// Wide enough that a multi-session batch crosses
    /// `exec::MIN_PARALLEL_WORK`, so the pipelined batcher's ASYNC
    /// fan-out path (not just its serial-degenerate branch) is
    /// exercised whenever the machine has more than one thread.
    fn make_wide_engine(seed: u64) -> NativeStreamingEngine {
        let mut rng = Rng::new(seed);
        let mut store = ParamStore::new();
        let spec = LmuSpec::new(1, 1, 32, 64.0, 32);
        let layer = LmuParallelLayer::new(spec.clone(), 64, &mut store, &mut rng, "srvw");
        NativeStreamingEngine::from_store(&spec, &layer.params, &store)
    }

    #[test]
    fn batcher_roundtrip_and_metrics() {
        let b = DynamicBatcher::new(Box::new(make_engine(0)), ServerConfig::default());
        let r1 = b.step_blocking(1, vec![0.5]);
        assert_eq!(r1.output.len(), 3);
        let r2 = b.step_blocking(1, vec![0.5]);
        // state advanced => different output (DN integrates)
        assert!(r1.output.iter().zip(&r2.output).any(|(a, c)| (a - c).abs() > 1e-7));
        assert_eq!(b.metrics.requests.load(Ordering::Relaxed), 2);
        assert!(b.metrics.mean_latency_us() >= 0.0);
    }

    #[test]
    fn sessions_do_not_interfere() {
        let b = DynamicBatcher::new(Box::new(make_engine(1)), ServerConfig::default());
        // drive session A hard, session B with zeros
        for _ in 0..5 {
            b.step_blocking(100, vec![5.0]);
        }
        let rb = b.step_blocking(200, vec![0.0]);
        // session B's first step from zero state with zero input stays ~bias-only
        let fresh = DynamicBatcher::new(Box::new(make_engine(1)), ServerConfig::default());
        let rf = fresh.step_blocking(7, vec![0.0]);
        for (a, c) in rb.output.iter().zip(&rf.output) {
            assert!((a - c).abs() < 1e-6, "cross-session contamination");
        }
    }

    #[test]
    fn reset_clears_state() {
        let b = DynamicBatcher::new(Box::new(make_engine(2)), ServerConfig::default());
        let first = b.step_blocking(5, vec![1.0]);
        b.step_blocking(5, vec![1.0]);
        b.reset_session(5);
        let after_reset = b.step_blocking(5, vec![1.0]);
        for (a, c) in first.output.iter().zip(&after_reset.output) {
            assert!((a - c).abs() < 1e-6, "reset did not clear DN state");
        }
    }

    #[test]
    fn batched_sessions_match_serial_reference() {
        // many sessions submitted together execute as one pooled batch;
        // each session's stream must be bit-identical to stepping a
        // standalone engine with the same weights serially
        let b = DynamicBatcher::new(Box::new(make_engine(9)), ServerConfig::default());
        let reference = make_engine(9);
        let n_sessions = 6u64;
        let rounds = 4usize;
        let mut rxs: Vec<(u64, mpsc::Receiver<StepResponse>)> = Vec::new();
        for t in 0..rounds {
            let mut round_rx = Vec::new();
            for s in 0..n_sessions {
                let (tx, rx) = mpsc::channel();
                b.submit(s, vec![(s as f32 + 1.0) * 0.1 + t as f32 * 0.01], tx);
                round_rx.push((s, rx));
            }
            rxs.extend(round_rx);
        }
        let mut got: HashMap<u64, Vec<Vec<f32>>> = HashMap::new();
        for (s, rx) in rxs {
            let resp = rx.recv().expect("batcher died");
            assert_eq!(resp.session, s);
            got.entry(s).or_default().push(resp.output);
        }
        for s in 0..n_sessions {
            let mut state = vec![0.0f32; reference.state_size()];
            for (t, out) in got[&s].iter().enumerate() {
                let want =
                    reference.step(&mut state, &[(s as f32 + 1.0) * 0.1 + t as f32 * 0.01]);
                assert_eq!(out.len(), want.len());
                for (a, b) in out.iter().zip(&want) {
                    assert!(
                        a.to_bits() == b.to_bits(),
                        "session {s} step {t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn pipelined_batcher_matches_serial_reference() {
        // pipeline on: batch k+1's fan-out overlaps batch k's reply
        // delivery — every session's stream must still be bit-identical
        // to stepping a standalone engine serially
        let b = DynamicBatcher::new(
            Box::new(make_wide_engine(9)),
            ServerConfig { pipeline: true, ..Default::default() },
        );
        let reference = make_wide_engine(9);
        let n_sessions = 6u64;
        let rounds = 4usize;
        let mut rxs: Vec<(u64, mpsc::Receiver<StepResponse>)> = Vec::new();
        for t in 0..rounds {
            for s in 0..n_sessions {
                let (tx, rx) = mpsc::channel();
                b.submit(s, vec![(s as f32 + 1.0) * 0.1 + t as f32 * 0.01], tx);
                rxs.push((s, rx));
            }
        }
        let mut got: HashMap<u64, Vec<Vec<f32>>> = HashMap::new();
        for (s, rx) in rxs {
            let resp = rx.recv().expect("pipelined batcher died");
            assert_eq!(resp.session, s);
            got.entry(s).or_default().push(resp.output);
        }
        for s in 0..n_sessions {
            let mut state = vec![0.0f32; reference.state_size()];
            for (t, out) in got[&s].iter().enumerate() {
                let want =
                    reference.step(&mut state, &[(s as f32 + 1.0) * 0.1 + t as f32 * 0.01]);
                assert_eq!(out.len(), want.len());
                for (a, b) in out.iter().zip(&want) {
                    assert!(
                        a.to_bits() == b.to_bits(),
                        "pipelined session {s} step {t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn pipelined_sequential_clients_always_get_replies() {
        // sequential step_blocking leaves each reply owed while the
        // channel sits idle — the idle-flush path must deliver it within
        // a window, and outputs must match the synchronous batcher
        // bit-for-bit
        let p = DynamicBatcher::new(
            Box::new(make_engine(5)),
            ServerConfig { pipeline: true, ..Default::default() },
        );
        let s = DynamicBatcher::new(Box::new(make_engine(5)), ServerConfig::default());
        for t in 0..6 {
            let x = vec![(t as f32 * 0.2).cos()];
            let rp = p.step_blocking(3, x.clone());
            let rs = s.step_blocking(3, x);
            assert_eq!(rp.output.len(), rs.output.len());
            for (a, b) in rp.output.iter().zip(&rs.output) {
                assert!(a.to_bits() == b.to_bits(), "pipelined batcher diverged at step {t}");
            }
        }
        assert_eq!(p.metrics.requests.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn router_sticky_and_round_robin() {
        let server = StreamingServer::new(3, ServerConfig::default(), || {
            Box::new(make_engine(3))
        });
        let r = &server.router;
        let a = r.route(10);
        let b = r.route(11);
        let c = r.route(12);
        // three new sessions land on three distinct replicas
        let mut set = vec![a, b, c];
        set.sort_unstable();
        set.dedup();
        assert_eq!(set.len(), 3);
        // sticky
        assert_eq!(r.route(10), a);
        let _ = r.step_blocking(10, vec![0.1]);
        assert_eq!(r.route(10), a);
    }

    #[test]
    fn concurrent_clients_all_served() {
        let server = std::sync::Arc::new(StreamingServer::new(2, ServerConfig::default(), || {
            Box::new(make_engine(4))
        }));
        let mut handles = Vec::new();
        for client in 0..8u64 {
            let s = server.clone();
            // lint-src: allow(thread-spawn) — test clients must be real threads
            handles.push(std::thread::spawn(move || {
                let mut outs = Vec::new();
                for t in 0..20 {
                    let r = s.router.step_blocking(client, vec![(t as f32 * 0.1).sin()]);
                    outs.push(r.output[0]);
                }
                outs
            }));
        }
        for h in handles {
            let outs = h.join().unwrap();
            assert_eq!(outs.len(), 20);
            assert!(outs.iter().all(|v| v.is_finite()));
        }
        assert_eq!(server.router.total_requests(), 8 * 20);
    }
}
