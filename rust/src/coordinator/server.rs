//! The streaming-inference server: bounded session store, admission
//! control, continuous batcher, and a round-robin router over engine
//! replicas (vllm-router-style, scaled to this paper: the "KV cache" of
//! an LMU is a single (d·du) DN state per session, constant in sequence
//! length — the paper's memory-constrained inference story).
//!
//! ## Production shape
//!
//! * Session states live in a byte-budgeted
//!   [`SessionStore`](super::sessions::SessionStore) (`session_mem`)
//!   with LRU + idle-deadline eviction — an evicted session's next step
//!   restarts from the zero state, so memory stays bounded at any
//!   session count.
//! * The request queue is bounded (`queue_cap`); past it, load is shed
//!   per [`ShedPolicy`](super::sessions::ShedPolicy) and the shed
//!   request gets [`StepReply::Rejected`] with a retry-after hint —
//!   overload degrades into rejections, never into OOM.
//! * Each window, the batcher packs the oldest ready steps from the
//!   live sessions into one continuous batch executed by
//!   [`execute_packed`](super::sessions::execute_packed) on the exec
//!   pool — bit-identical to per-session serial stepping.
//! * Per-request latency streams into a constant-memory p50/p95/p99
//!   histogram checked against the `slo_us` knob; the raced mean
//!   counters are read under a seqlock snapshot.
//!
//! ## Thread-budget story
//!
//! Each [`DynamicBatcher`] owns one *control* thread that blocks on its
//! request channel (parked, costing nothing while idle).  The *compute* —
//! executing a filled batch — is dispatched through the shared
//! `crate::exec` worker pool, fanning out across the batch's distinct
//! sessions as work-stealing chunks.  The pool admits one *top-level*
//! dispatcher at a time and splits the configured `threads` budget
//! hierarchically over a job's chunk slots (a batch with fewer sessions
//! than threads hands each session a sub-budget for its nested kernels),
//! so engine replicas × kernel threads can never oversubscribe the
//! machine: concurrent batchers time-share the pool (a batcher that finds
//! the pool busy runs its batch serially on its own control thread).
//!
//! Engines that are not `Sync` (e.g. PJRT-backed engines holding
//! thread-bound handles, built via [`DynamicBatcher::with_factory`]) stay
//! pinned to their control thread and execute serially inside
//! `exec::run_serialized`, so their kernel calls don't fan out either.

use super::engine::StreamingEngine;
use super::sessions::{execute_packed, parse_bytes, PackedRun, SessionStore, ShedPolicy};
use crate::exec;
use crate::metrics::LatencyHistogram;
use crate::util::env_knob;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A deferred engine constructor, run INSIDE the batcher's control
/// thread — the escape hatch for engines that are not `Send`/`Sync`
/// (e.g. PJRT clients holding thread-bound handles).
pub type EngineFactory = Box<dyn FnOnce() -> Box<dyn StreamingEngine> + Send>;

/// A step request: advance `session` with input `x`, reply on `reply`.
pub struct StepRequest {
    /// session id whose DN state this step advances
    pub session: u64,
    /// one input vector (dx floats); taken into the batch's
    /// [`PackedRun`] when the request is grouped
    pub x: Vec<f32>,
    /// channel the [`StepReply`] is delivered on
    pub reply: mpsc::Sender<StepReply>,
    /// when the request entered the batcher queue
    pub enqueued: Instant,
}

/// The result of one streaming step.
#[derive(Clone, Debug)]
pub struct StepResponse {
    /// session id the output belongs to
    pub session: u64,
    /// engine output (hidden floats)
    pub output: Vec<f32>,
    /// time from enqueue to completion
    pub latency: Duration,
}

/// What comes back on a request's reply channel: the step's output, or
/// a load-shed rejection carrying the retry-after hint.  Admission
/// control means *every* submitted request gets exactly one reply —
/// overload degrades into rejections, never into silence or OOM.
#[derive(Clone, Debug)]
pub enum StepReply {
    /// the step executed; here is its output
    Output(StepResponse),
    /// the request was shed by admission control — resubmit no sooner
    /// than `retry_after`
    Rejected {
        /// client back-off hint (the server's configured `retry_after`)
        retry_after: Duration,
    },
}

/// Dynamic-batching knobs.
#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// max requests per batch window
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch
    pub window: Duration,
    /// Pipeline batches: dispatch batch `k+1`'s session fan-out as an
    /// async pool job and deliver batch `k`'s replies while it computes,
    /// so the control thread's reply packing overlaps pool compute
    /// instead of serializing after it.  Per-session outputs and their
    /// order are unchanged (states always advance batch-by-batch); the
    /// cost is up to one extra batch window of reply latency when the
    /// request stream goes idle.  Only `Sync` engines pipeline;
    /// thread-bound (factory) engines always run the serial path.
    pub pipeline: bool,
    /// Bounded request-queue depth (admission control): at most this
    /// many steps may be queued or in flight; beyond it, `shed`
    /// decides who gets the [`StepReply::Rejected`].
    pub queue_cap: usize,
    /// what load-shedding does when the queue is full
    pub shed: ShedPolicy,
    /// back-off hint carried by rejections
    pub retry_after: Duration,
    /// session-store byte budget (`usize::MAX` = unbounded); over it,
    /// least-recently-used session states are evicted and those
    /// sessions restart from the zero state on their next step
    pub session_mem: usize,
    /// evict sessions untouched for this many batch windows
    pub idle_batches: Option<u64>,
    /// latency SLO in µs; requests over it count as
    /// `ServerMetrics::slo_violations`
    pub slo_us: u64,
}

impl Default for ServerConfig {
    /// Defaults, overridable by env knobs (see README "Knob
    /// reference"): `PLMU_SESSION_MEM` (byte budget, `64M`-style
    /// suffixes), `PLMU_QUEUE_CAP`, `PLMU_SLO_US`.
    fn default() -> Self {
        let session_mem = env_knob::str_knob("PLMU_SESSION_MEM")
            .as_deref()
            .and_then(parse_bytes)
            .unwrap_or(usize::MAX);
        let queue_cap = env_knob::usize_knob("PLMU_QUEUE_CAP", 1).unwrap_or(4096);
        let slo_us = env_knob::usize_knob("PLMU_SLO_US", 1).unwrap_or(10_000) as u64;
        ServerConfig {
            max_batch: 32,
            window: Duration::from_micros(500),
            pipeline: false,
            queue_cap,
            shed: ShedPolicy::RejectNew,
            retry_after: Duration::from_micros(200),
            session_mem,
            idle_batches: None,
            slo_us,
        }
    }
}

/// Aggregate serving metrics (updated by the batcher's control thread,
/// read from anywhere through the shared `Arc`).
///
/// The raced pair — `requests` and `total_latency_us` — is guarded by
/// a sequence lock: the control thread (the *only* writer of the pair)
/// brackets each batch of updates with `seq` increments, and
/// [`snapshot`](Self::snapshot) retries until it reads an even,
/// unchanged `seq` on both sides.  A reader can no longer observe a
/// request count without its latency sum (the bug the old two-relaxed-
/// loads `mean_latency_us` had).  `shed` is written by submitting
/// threads and deliberately lives outside the seqlock.
#[derive(Default)]
pub struct ServerMetrics {
    /// total step requests completed
    pub requests: AtomicU64,
    /// total batch windows executed
    pub batches: AtomicU64,
    /// sum of request latencies in microseconds
    pub total_latency_us: AtomicU64,
    /// seqlock guarding the (`requests`, `total_latency_us`) pair:
    /// odd while the control thread updates them
    seq: AtomicU64,
    /// requests shed by admission control (written by submitters)
    pub shed: AtomicU64,
    /// replies whose receiver had gone away (counted, not silently
    /// discarded — a leak of abandoned clients shows up here)
    pub dropped_replies: AtomicU64,
    /// completed requests whose latency exceeded the SLO
    pub slo_violations: AtomicU64,
    /// streaming p50/p95/p99 latency histogram (µs)
    pub latency: LatencyHistogram,
    /// gauge: session states currently resident in the store
    pub store_sessions: AtomicU64,
    /// gauge: bytes currently resident in the store
    pub store_bytes: AtomicU64,
    /// high-water mark of `store_bytes`
    pub store_peak_bytes: AtomicU64,
    /// cumulative LRU (byte-budget) evictions
    pub evicted_lru: AtomicU64,
    /// cumulative idle-deadline evictions
    pub evicted_idle: AtomicU64,
}

/// One consistent read of a batcher's [`ServerMetrics`].
#[derive(Clone, Copy, Debug, Default)]
pub struct MetricsSnapshot {
    /// completed requests
    pub requests: u64,
    /// executed batch windows
    pub batches: u64,
    /// sum of request latencies, µs (consistent with `requests`)
    pub total_latency_us: u64,
    /// shed requests
    pub shed: u64,
    /// replies dropped because the receiver went away
    pub dropped_replies: u64,
    /// requests over the SLO
    pub slo_violations: u64,
    /// median latency, µs
    pub p50_us: u64,
    /// 95th-percentile latency, µs
    pub p95_us: u64,
    /// 99th-percentile latency, µs
    pub p99_us: u64,
    /// worst latency, µs
    pub max_us: u64,
    /// resident sessions (gauge)
    pub store_sessions: u64,
    /// resident store bytes (gauge)
    pub store_bytes: u64,
    /// peak resident store bytes
    pub store_peak_bytes: u64,
    /// cumulative LRU evictions
    pub evicted_lru: u64,
    /// cumulative idle evictions
    pub evicted_idle: u64,
}

impl ServerMetrics {
    /// Control-thread side of the seqlock: run `f`'s updates to the
    /// guarded pair between two `seq` increments.
    fn write_locked(&self, f: impl FnOnce()) {
        self.seq.fetch_add(1, Ordering::Release); // odd: write in progress
        f();
        self.seq.fetch_add(1, Ordering::Release); // even: stable
    }

    /// Consistent read of the raced (`requests`, `total_latency_us`)
    /// pair; spins while the writer is mid-update.
    fn read_pair(&self) -> (u64, u64) {
        loop {
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 & 1 == 1 {
                std::hint::spin_loop();
                continue;
            }
            let n = self.requests.load(Ordering::Acquire);
            let t = self.total_latency_us.load(Ordering::Acquire);
            if self.seq.load(Ordering::Acquire) == s1 {
                return (n, t);
            }
        }
    }

    /// Mean request latency in microseconds (0 before the first
    /// request), read under a consistent snapshot.
    pub fn mean_latency_us(&self) -> f64 {
        let (n, t) = self.read_pair();
        if n == 0 {
            0.0
        } else {
            t as f64 / n as f64
        }
    }

    /// Mean number of requests per executed batch (0 before the first).
    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.read_pair().0 as f64 / b as f64
        }
    }

    /// One consistent view of everything, for status prints and the
    /// bench record.
    pub fn snapshot(&self) -> MetricsSnapshot {
        let (requests, total_latency_us) = self.read_pair();
        MetricsSnapshot {
            requests,
            batches: self.batches.load(Ordering::Relaxed),
            total_latency_us,
            shed: self.shed.load(Ordering::Relaxed),
            dropped_replies: self.dropped_replies.load(Ordering::Relaxed),
            slo_violations: self.slo_violations.load(Ordering::Relaxed),
            p50_us: self.latency.quantile_us(0.50),
            p95_us: self.latency.quantile_us(0.95),
            p99_us: self.latency.quantile_us(0.99),
            max_us: self.latency.max_us(),
            store_sessions: self.store_sessions.load(Ordering::Relaxed),
            store_bytes: self.store_bytes.load(Ordering::Relaxed),
            store_peak_bytes: self.store_peak_bytes.load(Ordering::Relaxed),
            evicted_lru: self.evicted_lru.load(Ordering::Relaxed),
            evicted_idle: self.evicted_idle.load(Ordering::Relaxed),
        }
    }
}

/// Dynamic batcher + session table driving one engine replica.  The
/// control thread blocks on the request channel; batch compute dispatches
/// through the shared exec pool (see the module docs).
pub struct DynamicBatcher {
    tx: mpsc::Sender<BatcherCmd>,
    /// live serving metrics of this replica
    pub metrics: Arc<ServerMetrics>,
    /// queued + in-flight requests, shared with the control thread —
    /// the submit-side admission gate reads it
    depth: Arc<AtomicUsize>,
    queue_cap: usize,
    shed: ShedPolicy,
    retry_after: Duration,
    handle: Option<std::thread::JoinHandle<()>>,
}

enum BatcherCmd {
    Step(StepRequest),
    Reset(u64),
    Shutdown,
}

/// How the batcher thread obtains its engine.
enum EngineSource {
    /// a `Sync` engine moved into the thread — batches fan out on the pool
    Shared(Box<dyn StreamingEngine + Send + Sync>),
    /// built inside the thread (thread-bound handles) — batches run serial
    Factory(EngineFactory),
}

/// The engine as held by the running batcher thread.
enum BatchEngine {
    Shared(Box<dyn StreamingEngine + Send + Sync>),
    Local(Box<dyn StreamingEngine>),
}

impl BatchEngine {
    fn engine(&self) -> &dyn StreamingEngine {
        match self {
            BatchEngine::Shared(e) => &**e,
            BatchEngine::Local(e) => &**e,
        }
    }
}

/// A grouped continuous batch: `runs[i]` is one session's packed steps
/// (state + inputs — this is what crosses to pool threads), `reqs[i]`
/// its requests in arrival order (reply channels stay on the control
/// thread).  The two vectors are index-aligned.
#[derive(Default)]
struct BatchGroups {
    runs: Vec<PackedRun>,
    reqs: Vec<Vec<StepRequest>>,
}

impl BatchGroups {
    fn is_empty(&self) -> bool {
        self.runs.is_empty()
    }
}

/// Group the oldest `take` queued requests by session (per-session
/// arrival order preserved), pulling each session's state out of the
/// store — or zero-initializing a fresh one: an *evicted* session is
/// indistinguishable from a new one and restarts from the zero state,
/// the documented degradation under memory pressure.
fn build_groups(
    state_size: usize,
    store: &mut SessionStore,
    pending: &mut std::collections::VecDeque<StepRequest>,
    take: usize,
) -> BatchGroups {
    let mut g = BatchGroups::default();
    let mut index: HashMap<u64, usize> = HashMap::new();
    for mut req in pending.drain(..take) {
        let gi = *index.entry(req.session).or_insert_with(|| {
            let state =
                store.take(req.session).unwrap_or_else(|| vec![0.0f32; state_size]);
            g.runs.push(PackedRun {
                session: req.session,
                state,
                xs: Vec::new(),
                outs: Vec::new(),
            });
            g.reqs.push(Vec::new());
            g.runs.len() - 1
        });
        g.runs[gi].xs.push(std::mem::take(&mut req.x));
        g.reqs[gi].push(req);
    }
    g
}

/// Return every run's advanced state to the store at tick `tick`
/// (refreshing its LRU/idle position).  This must happen before the
/// NEXT batch is grouped (a session present in both batches must see
/// its advanced state), which is why it is split from reply delivery
/// in the pipelined path.
fn reinsert_states(groups: &mut BatchGroups, store: &mut SessionStore, tick: u64) {
    for r in groups.runs.iter_mut() {
        store.put(r.session, std::mem::take(&mut r.state), tick);
    }
}

/// Send a computed batch's replies (per-session arrival order
/// preserved) and update the request metrics: the latency histogram
/// and SLO counter per request, then the raced (`requests`,
/// `total_latency_us`) pair once per flush under the seqlock.  Sends
/// whose receiver has gone away are **counted** in `dropped_replies`,
/// not silently discarded.  In pipelined mode this is the control
/// thread's overlapped stage: it runs while the next batch's session
/// fan-out computes on the pool — it is always the control thread, so
/// the seqlock keeps its single writer.
fn deliver_replies(
    parked: &mut BatchGroups,
    metrics: &ServerMetrics,
    depth: &AtomicUsize,
    slo_us: u64,
) {
    let runs = std::mem::take(&mut parked.runs);
    let reqs = std::mem::take(&mut parked.reqs);
    let mut delivered = 0u64;
    let mut latency_sum_us = 0u64;
    for (run, rs) in runs.into_iter().zip(reqs) {
        for (req, output) in rs.into_iter().zip(run.outs) {
            let latency = req.enqueued.elapsed();
            let us = latency.as_micros() as u64;
            delivered += 1;
            latency_sum_us += us;
            metrics.latency.record_us(us);
            if us > slo_us {
                metrics.slo_violations.fetch_add(1, Ordering::Relaxed);
            }
            depth.fetch_sub(1, Ordering::Relaxed);
            let resp = StepResponse { session: req.session, output, latency };
            if req.reply.send(StepReply::Output(resp)).is_err() {
                metrics.dropped_replies.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
    if delivered > 0 {
        metrics.write_locked(|| {
            metrics.requests.fetch_add(delivered, Ordering::Relaxed);
            metrics.total_latency_us.fetch_add(latency_sum_us, Ordering::Relaxed);
        });
    }
}

/// Enforce the bounded queue on the control thread: everything beyond
/// `queue_cap` is shed with a [`StepReply::Rejected`].  `RejectNew`
/// sheds from the back (newest arrivals), `DropOldest` from the front.
/// This is the backstop behind the submit-side fast reject — several
/// submitters can race past that gate, the backlog cannot grow past
/// the cap here.
fn shed_overflow(
    pending: &mut std::collections::VecDeque<StepRequest>,
    cfg: &ServerConfig,
    metrics: &ServerMetrics,
    depth: &AtomicUsize,
) {
    while pending.len() > cfg.queue_cap {
        let req = match cfg.shed {
            ShedPolicy::RejectNew => pending.pop_back(),
            ShedPolicy::DropOldest => pending.pop_front(),
        };
        let Some(req) = req else { break };
        metrics.shed.fetch_add(1, Ordering::Relaxed);
        depth.fetch_sub(1, Ordering::Relaxed);
        if req
            .reply
            .send(StepReply::Rejected { retry_after: cfg.retry_after })
            .is_err()
        {
            metrics.dropped_replies.fetch_add(1, Ordering::Relaxed);
        }
    }
}

/// Mirror the (single-threaded) store's gauges into the shared metrics
/// after each batch, so observers see occupancy without touching the
/// control thread's state.
fn mirror_store_gauges(store: &SessionStore, metrics: &ServerMetrics) {
    let stats = store.stats();
    metrics.store_sessions.store(store.len() as u64, Ordering::Relaxed);
    metrics.store_bytes.store(store.bytes() as u64, Ordering::Relaxed);
    metrics.store_peak_bytes.store(stats.peak_bytes, Ordering::Relaxed);
    metrics.evicted_lru.store(stats.evicted_lru, Ordering::Relaxed);
    metrics.evicted_idle.store(stats.evicted_idle, Ordering::Relaxed);
}

/// Execute one continuous batch synchronously: group the oldest ready
/// steps by session, fan the independent sessions out on the exec pool
/// via [`execute_packed`] (shared engines) or run them serialized
/// (thread-bound engines), then reinsert states and deliver replies.
#[allow(clippy::too_many_arguments)]
fn execute_batch(
    engine: &BatchEngine,
    store: &mut SessionStore,
    pending: &mut std::collections::VecDeque<StepRequest>,
    take: usize,
    tick: u64,
    metrics: &ServerMetrics,
    depth: &AtomicUsize,
    slo_us: u64,
) {
    let state_size = engine.engine().state_size();
    let mut groups = build_groups(state_size, store, pending, take);
    match engine {
        BatchEngine::Shared(e) => {
            // the continuous-batching kernel shared with the load sim:
            // distinct sessions are independent rows, requests within a
            // session stay in order inside their chunk, and the
            // partition depends only on the run count — bit-identical
            // to per-session serial stepping at any thread count
            execute_packed(&**e, &mut groups.runs);
        }
        BatchEngine::Local(e) => {
            // thread-bound engine: serial, and flagged so nested kernels
            // don't fan out under a control thread
            exec::run_serialized(|| {
                for r in groups.runs.iter_mut() {
                    for x in &r.xs {
                        r.outs.push(e.step(&mut r.state, x));
                    }
                }
            });
        }
    }
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    reinsert_states(&mut groups, store, tick);
    deliver_replies(&mut groups, metrics, depth, slo_us);
}

/// Execute one continuous batch in pipelined mode: the session fan-out
/// is dispatched as an **async** pool job and the previous batch's
/// replies are delivered while it computes.  After the job drains,
/// states return to the store immediately (the next batch's grouping
/// needs them) and the fresh replies are parked in `undelivered` until
/// the next batch is in flight — or the batcher goes idle, which
/// flushes them within one window.
#[allow(clippy::too_many_arguments)]
fn pipelined_batch(
    eng: &(dyn StreamingEngine + Send + Sync),
    store: &mut SessionStore,
    pending: &mut std::collections::VecDeque<StepRequest>,
    take: usize,
    tick: u64,
    undelivered: &mut BatchGroups,
    metrics: &ServerMetrics,
    depth: &AtomicUsize,
    slo_us: u64,
) {
    let mut groups = build_groups(eng.state_size(), store, pending, take);
    let total_steps: usize = groups.runs.iter().map(|r| r.xs.len()).sum();
    let plan = exec::plan_for(groups.runs.len(), total_steps * eng.step_work());
    if plan.is_serial() {
        // too small to fan out: flush owed replies first (per-session
        // reply order), then compute and deliver inline
        deliver_replies(undelivered, metrics, depth, slo_us);
        for r in groups.runs.iter_mut() {
            for x in &r.xs {
                r.outs.push(eng.step(&mut r.state, x));
            }
        }
        metrics.batches.fetch_add(1, Ordering::Relaxed);
        reinsert_states(&mut groups, store, tick);
        deliver_replies(&mut groups, metrics, depth, slo_us);
        return;
    }
    // the control thread reserves itself for reply packing; the session
    // fan-out gets the remaining budget, so both in-flight stages sum to
    // at most the configured thread count
    let budget = exec::threads().saturating_sub(1).max(1);
    let workers = plan.workers.min(budget);
    exec::parallel_rows_overlap(
        &mut groups.runs,
        1,
        workers,
        budget,
        move |_, block| {
            for r in block.iter_mut() {
                for x in &r.xs {
                    r.outs.push(eng.step(&mut r.state, x));
                }
            }
        },
        // overlapped stage: previous batch's replies go out while this
        // batch computes on the pool
        || deliver_replies(undelivered, metrics, depth, slo_us),
    );
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    reinsert_states(&mut groups, store, tick);
    *undelivered = groups;
}

impl DynamicBatcher {
    /// Build from a shareable engine: batch compute fans out across the
    /// batch's sessions on the shared exec pool.
    pub fn new(engine: Box<dyn StreamingEngine + Send + Sync>, cfg: ServerConfig) -> Self {
        Self::start(EngineSource::Shared(engine), cfg)
    }

    /// Build from a factory that constructs the engine INSIDE the batcher
    /// thread — required for engines that are not `Send`/`Sync` (the PJRT
    /// client holds thread-bound handles).  Batches for such engines run
    /// serially on the control thread.
    pub fn with_factory(factory: EngineFactory, cfg: ServerConfig) -> Self {
        Self::start(EngineSource::Factory(factory), cfg)
    }

    fn start(source: EngineSource, cfg: ServerConfig) -> Self {
        let (tx, rx) = mpsc::channel::<BatcherCmd>();
        let metrics = Arc::new(ServerMetrics::default());
        let depth = Arc::new(AtomicUsize::new(0));
        let (queue_cap, shed, retry_after) = (cfg.queue_cap, cfg.shed, cfg.retry_after);
        let m = metrics.clone();
        let d = depth.clone();
        // lint-src: allow(thread-spawn) — the batcher is a long-lived service
        // thread, deliberately outside the pool's work budget
        let handle = std::thread::spawn(move || {
            let engine = match source {
                EngineSource::Shared(e) => BatchEngine::Shared(e),
                EngineSource::Factory(f) => BatchEngine::Local(f()),
            };
            let state_size = engine.engine().state_size();
            let mut store = SessionStore::new(state_size, cfg.session_mem, cfg.idle_batches);
            // the bounded backlog: requests not yet batched.  A batch
            // takes the oldest `max_batch`; the rest persists here,
            // clamped to `queue_cap` by `shed_overflow`.
            let mut pending: std::collections::VecDeque<StepRequest> =
                std::collections::VecDeque::new();
            // pipelined mode: the last computed batch, states already
            // reinserted, replies not yet sent
            let mut undelivered = BatchGroups::default();
            // logical batch clock: drives the store's LRU timestamps and
            // the idle deadline (deterministic in the request stream)
            let mut tick: u64 = 0;
            let mut shutdown = false;
            while !shutdown {
                // block for the first request (or control message); with
                // replies owed or a backlog queued, bound the block by one
                // window so an idle channel can never stall them
                let first = if undelivered.is_empty() && pending.is_empty() {
                    match rx.recv() {
                        Ok(cmd) => Some(cmd),
                        Err(_) => break,
                    }
                } else {
                    match rx.recv_timeout(cfg.window) {
                        Ok(cmd) => Some(cmd),
                        Err(mpsc::RecvTimeoutError::Timeout) => None,
                        Err(mpsc::RecvTimeoutError::Disconnected) => {
                            shutdown = true;
                            None
                        }
                    }
                };
                match first {
                    Some(BatcherCmd::Step(r)) => pending.push_back(r),
                    Some(BatcherCmd::Reset(sid)) => {
                        store.remove(sid);
                        continue;
                    }
                    Some(BatcherCmd::Shutdown) => shutdown = true,
                    None => {}
                }
                if pending.is_empty() {
                    // idle or shutting down: flush owed replies, re-loop
                    deliver_replies(&mut undelivered, &m, &d, cfg.slo_us);
                    continue;
                }
                // fill the window
                let deadline = Instant::now() + cfg.window;
                while pending.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(BatcherCmd::Step(r)) => pending.push_back(r),
                        Ok(BatcherCmd::Reset(sid)) => {
                            store.remove(sid);
                        }
                        // drain the already-queued requests before exiting,
                        // or their blocked step_blocking callers would
                        // panic on a dropped reply channel
                        Ok(BatcherCmd::Shutdown) | Err(mpsc::RecvTimeoutError::Disconnected) => {
                            shutdown = true;
                            break;
                        }
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                    }
                }
                // admission backstop: clamp the backlog to queue_cap
                shed_overflow(&mut pending, &cfg, &m, &d);
                // continuous batch: the oldest ready steps; the rest of
                // the backlog persists into the next window
                let take = pending.len().min(cfg.max_batch);
                if take == 0 {
                    continue;
                }
                tick += 1;
                match (&engine, cfg.pipeline) {
                    (BatchEngine::Shared(e), true) => {
                        pipelined_batch(
                            &**e,
                            &mut store,
                            &mut pending,
                            take,
                            tick,
                            &mut undelivered,
                            &m,
                            &d,
                            cfg.slo_us,
                        );
                    }
                    _ => {
                        // per-session reply order: anything a pipelined
                        // batch parked goes out before this batch does
                        deliver_replies(&mut undelivered, &m, &d, cfg.slo_us);
                        execute_batch(
                            &engine,
                            &mut store,
                            &mut pending,
                            take,
                            tick,
                            &m,
                            &d,
                            cfg.slo_us,
                        );
                    }
                }
                store.sweep_idle(tick);
                mirror_store_gauges(&store, &m);
            }
            // shutdown: flush parked replies, then drain the backlog
            deliver_replies(&mut undelivered, &m, &d, cfg.slo_us);
            while !pending.is_empty() {
                let take = pending.len().min(cfg.max_batch);
                tick += 1;
                execute_batch(&engine, &mut store, &mut pending, take, tick, &m, &d, cfg.slo_us);
            }
            mirror_store_gauges(&store, &m);
        });
        DynamicBatcher { tx, metrics, depth, queue_cap, shed, retry_after, handle: Some(handle) }
    }

    /// Enqueue one step; exactly one [`StepReply`] arrives on `reply`.
    ///
    /// Admission control: under [`ShedPolicy::RejectNew`], a full
    /// queue rejects right here — `Rejected { retry_after }` comes
    /// back immediately and the control thread is never touched.  The
    /// gate is a relaxed read, so a handful of concurrent submitters
    /// can slip past it; the control thread's `shed_overflow` backstop
    /// still clamps the backlog to `queue_cap`.
    pub fn submit(&self, session: u64, x: Vec<f32>, reply: mpsc::Sender<StepReply>) {
        if self.shed == ShedPolicy::RejectNew
            && self.depth.load(Ordering::Relaxed) >= self.queue_cap
        {
            self.metrics.shed.fetch_add(1, Ordering::Relaxed);
            if reply
                .send(StepReply::Rejected { retry_after: self.retry_after })
                .is_err()
            {
                self.metrics.dropped_replies.fetch_add(1, Ordering::Relaxed);
            }
            return;
        }
        self.depth.fetch_add(1, Ordering::Relaxed);
        let _ = self.tx.send(BatcherCmd::Step(StepRequest {
            session,
            x,
            reply,
            enqueued: Instant::now(),
        }));
    }

    /// Queued + in-flight requests right now (the admission gauge).
    pub fn queue_depth(&self) -> usize {
        self.depth.load(Ordering::Relaxed)
    }

    /// Drop a session's state.
    pub fn reset_session(&self, session: u64) {
        let _ = self.tx.send(BatcherCmd::Reset(session));
    }

    /// Synchronous convenience: submit and wait, backing off and
    /// resubmitting whenever admission control rejects.
    pub fn step_blocking(&self, session: u64, x: Vec<f32>) -> StepResponse {
        loop {
            let (tx, rx) = mpsc::channel();
            self.submit(session, x.clone(), tx);
            match rx.recv().expect("batcher died") {
                StepReply::Output(resp) => return resp,
                StepReply::Rejected { retry_after } => std::thread::sleep(retry_after),
            }
        }
    }
}

impl Drop for DynamicBatcher {
    fn drop(&mut self) {
        let _ = self.tx.send(BatcherCmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Round-robin router over engine replicas, with sticky sessions
/// (a session's DN state lives on exactly one replica).
pub struct Router {
    batchers: Vec<DynamicBatcher>,
    assignment: Mutex<HashMap<u64, usize>>,
    next: AtomicUsize,
}

impl Router {
    /// Build over a non-empty replica set.
    pub fn new(batchers: Vec<DynamicBatcher>) -> Self {
        assert!(!batchers.is_empty());
        Router { batchers, assignment: Mutex::new(HashMap::new()), next: AtomicUsize::new(0) }
    }

    /// Number of engine replicas behind this router.
    pub fn replicas(&self) -> usize {
        self.batchers.len()
    }

    /// Which replica serves this session (assigning round-robin on first
    /// sight — sticky thereafter).
    pub fn route(&self, session: u64) -> usize {
        let mut map = self.assignment.lock().unwrap();
        *map.entry(session).or_insert_with(|| {
            self.next.fetch_add(1, Ordering::Relaxed) % self.batchers.len()
        })
    }

    /// Route, submit, and wait for the response.
    pub fn step_blocking(&self, session: u64, x: Vec<f32>) -> StepResponse {
        let idx = self.route(session);
        self.batchers[idx].step_blocking(session, x)
    }

    /// Forget a session: drop its routing entry and its replica-side state.
    pub fn end_session(&self, session: u64) {
        let idx = {
            let mut map = self.assignment.lock().unwrap();
            map.remove(&session)
        };
        if let Some(i) = idx {
            self.batchers[i].reset_session(session);
        }
    }

    /// Total requests served across all replicas.
    pub fn total_requests(&self) -> u64 {
        self.batchers
            .iter()
            .map(|b| b.metrics.requests.load(Ordering::Relaxed))
            .sum()
    }

    /// Metrics of one replica's batcher.
    pub fn metrics_of(&self, idx: usize) -> &Arc<ServerMetrics> {
        &self.batchers[idx].metrics
    }
}

/// Full server façade: router + config.
pub struct StreamingServer {
    /// the replica router (sticky sessions, round-robin assignment)
    pub router: Router,
}

impl StreamingServer {
    /// Build with `replicas` engines from a factory (engines must be
    /// `Send + Sync`; batch compute shares the exec pool).
    pub fn new<F>(replicas: usize, cfg: ServerConfig, factory: F) -> Self
    where
        F: Fn() -> Box<dyn StreamingEngine + Send + Sync>,
    {
        let batchers = (0..replicas)
            .map(|_| DynamicBatcher::new(factory(), cfg.clone()))
            .collect();
        StreamingServer { router: Router::new(batchers) }
    }

    /// Build from per-replica factories run inside each batcher thread
    /// (for non-`Send` engines, e.g. PJRT-backed ones).
    pub fn with_factories(factories: Vec<EngineFactory>, cfg: ServerConfig) -> Self {
        let batchers = factories
            .into_iter()
            .map(|f| DynamicBatcher::with_factory(f, cfg.clone()))
            .collect();
        StreamingServer { router: Router::new(batchers) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::ParamStore;
    use crate::coordinator::engine::NativeStreamingEngine;
    use crate::layers::lmu::{LmuParallelLayer, LmuSpec};
    use crate::util::Rng;

    fn make_engine(seed: u64) -> NativeStreamingEngine {
        let mut rng = Rng::new(seed);
        let mut store = ParamStore::new();
        let spec = LmuSpec::new(1, 1, 4, 8.0, 3);
        let layer = LmuParallelLayer::new(spec.clone(), 8, &mut store, &mut rng, "srv");
        NativeStreamingEngine::from_store(&spec, &layer.params, &store)
    }

    /// Unwrap a reply that must be an executed step.
    fn out(reply: StepReply) -> StepResponse {
        match reply {
            StepReply::Output(r) => r,
            StepReply::Rejected { .. } => panic!("unexpected rejection"),
        }
    }

    /// Wide enough that a multi-session batch crosses
    /// `exec::MIN_PARALLEL_WORK`, so the pipelined batcher's ASYNC
    /// fan-out path (not just its serial-degenerate branch) is
    /// exercised whenever the machine has more than one thread.
    fn make_wide_engine(seed: u64) -> NativeStreamingEngine {
        let mut rng = Rng::new(seed);
        let mut store = ParamStore::new();
        let spec = LmuSpec::new(1, 1, 32, 64.0, 32);
        let layer = LmuParallelLayer::new(spec.clone(), 64, &mut store, &mut rng, "srvw");
        NativeStreamingEngine::from_store(&spec, &layer.params, &store)
    }

    #[test]
    fn batcher_roundtrip_and_metrics() {
        let b = DynamicBatcher::new(Box::new(make_engine(0)), ServerConfig::default());
        let r1 = b.step_blocking(1, vec![0.5]);
        assert_eq!(r1.output.len(), 3);
        let r2 = b.step_blocking(1, vec![0.5]);
        // state advanced => different output (DN integrates)
        assert!(r1.output.iter().zip(&r2.output).any(|(a, c)| (a - c).abs() > 1e-7));
        assert_eq!(b.metrics.requests.load(Ordering::Relaxed), 2);
        assert!(b.metrics.mean_latency_us() >= 0.0);
    }

    #[test]
    fn sessions_do_not_interfere() {
        let b = DynamicBatcher::new(Box::new(make_engine(1)), ServerConfig::default());
        // drive session A hard, session B with zeros
        for _ in 0..5 {
            b.step_blocking(100, vec![5.0]);
        }
        let rb = b.step_blocking(200, vec![0.0]);
        // session B's first step from zero state with zero input stays ~bias-only
        let fresh = DynamicBatcher::new(Box::new(make_engine(1)), ServerConfig::default());
        let rf = fresh.step_blocking(7, vec![0.0]);
        for (a, c) in rb.output.iter().zip(&rf.output) {
            assert!((a - c).abs() < 1e-6, "cross-session contamination");
        }
    }

    #[test]
    fn reset_clears_state() {
        let b = DynamicBatcher::new(Box::new(make_engine(2)), ServerConfig::default());
        let first = b.step_blocking(5, vec![1.0]);
        b.step_blocking(5, vec![1.0]);
        b.reset_session(5);
        let after_reset = b.step_blocking(5, vec![1.0]);
        for (a, c) in first.output.iter().zip(&after_reset.output) {
            assert!((a - c).abs() < 1e-6, "reset did not clear DN state");
        }
    }

    #[test]
    fn batched_sessions_match_serial_reference() {
        // many sessions submitted together execute as one pooled batch;
        // each session's stream must be bit-identical to stepping a
        // standalone engine with the same weights serially
        let b = DynamicBatcher::new(Box::new(make_engine(9)), ServerConfig::default());
        let reference = make_engine(9);
        let n_sessions = 6u64;
        let rounds = 4usize;
        let mut rxs: Vec<(u64, mpsc::Receiver<StepReply>)> = Vec::new();
        for t in 0..rounds {
            let mut round_rx = Vec::new();
            for s in 0..n_sessions {
                let (tx, rx) = mpsc::channel();
                b.submit(s, vec![(s as f32 + 1.0) * 0.1 + t as f32 * 0.01], tx);
                round_rx.push((s, rx));
            }
            rxs.extend(round_rx);
        }
        let mut got: HashMap<u64, Vec<Vec<f32>>> = HashMap::new();
        for (s, rx) in rxs {
            let resp = out(rx.recv().expect("batcher died"));
            assert_eq!(resp.session, s);
            got.entry(s).or_default().push(resp.output);
        }
        for s in 0..n_sessions {
            let mut state = vec![0.0f32; reference.state_size()];
            for (t, out) in got[&s].iter().enumerate() {
                let want =
                    reference.step(&mut state, &[(s as f32 + 1.0) * 0.1 + t as f32 * 0.01]);
                assert_eq!(out.len(), want.len());
                for (a, b) in out.iter().zip(&want) {
                    assert!(
                        a.to_bits() == b.to_bits(),
                        "session {s} step {t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn pipelined_batcher_matches_serial_reference() {
        // pipeline on: batch k+1's fan-out overlaps batch k's reply
        // delivery — every session's stream must still be bit-identical
        // to stepping a standalone engine serially
        let b = DynamicBatcher::new(
            Box::new(make_wide_engine(9)),
            ServerConfig { pipeline: true, ..Default::default() },
        );
        let reference = make_wide_engine(9);
        let n_sessions = 6u64;
        let rounds = 4usize;
        let mut rxs: Vec<(u64, mpsc::Receiver<StepReply>)> = Vec::new();
        for t in 0..rounds {
            for s in 0..n_sessions {
                let (tx, rx) = mpsc::channel();
                b.submit(s, vec![(s as f32 + 1.0) * 0.1 + t as f32 * 0.01], tx);
                rxs.push((s, rx));
            }
        }
        let mut got: HashMap<u64, Vec<Vec<f32>>> = HashMap::new();
        for (s, rx) in rxs {
            let resp = out(rx.recv().expect("pipelined batcher died"));
            assert_eq!(resp.session, s);
            got.entry(s).or_default().push(resp.output);
        }
        for s in 0..n_sessions {
            let mut state = vec![0.0f32; reference.state_size()];
            for (t, out) in got[&s].iter().enumerate() {
                let want =
                    reference.step(&mut state, &[(s as f32 + 1.0) * 0.1 + t as f32 * 0.01]);
                assert_eq!(out.len(), want.len());
                for (a, b) in out.iter().zip(&want) {
                    assert!(
                        a.to_bits() == b.to_bits(),
                        "pipelined session {s} step {t}: {a} vs {b}"
                    );
                }
            }
        }
    }

    #[test]
    fn pipelined_sequential_clients_always_get_replies() {
        // sequential step_blocking leaves each reply owed while the
        // channel sits idle — the idle-flush path must deliver it within
        // a window, and outputs must match the synchronous batcher
        // bit-for-bit
        let p = DynamicBatcher::new(
            Box::new(make_engine(5)),
            ServerConfig { pipeline: true, ..Default::default() },
        );
        let s = DynamicBatcher::new(Box::new(make_engine(5)), ServerConfig::default());
        for t in 0..6 {
            let x = vec![(t as f32 * 0.2).cos()];
            let rp = p.step_blocking(3, x.clone());
            let rs = s.step_blocking(3, x);
            assert_eq!(rp.output.len(), rs.output.len());
            for (a, b) in rp.output.iter().zip(&rs.output) {
                assert!(a.to_bits() == b.to_bits(), "pipelined batcher diverged at step {t}");
            }
        }
        assert_eq!(p.metrics.requests.load(Ordering::Relaxed), 6);
    }

    #[test]
    fn router_sticky_and_round_robin() {
        let server = StreamingServer::new(3, ServerConfig::default(), || {
            Box::new(make_engine(3))
        });
        let r = &server.router;
        let a = r.route(10);
        let b = r.route(11);
        let c = r.route(12);
        // three new sessions land on three distinct replicas
        let mut set = vec![a, b, c];
        set.sort_unstable();
        set.dedup();
        assert_eq!(set.len(), 3);
        // sticky
        assert_eq!(r.route(10), a);
        let _ = r.step_blocking(10, vec![0.1]);
        assert_eq!(r.route(10), a);
    }

    #[test]
    fn concurrent_clients_all_served() {
        let server = std::sync::Arc::new(StreamingServer::new(2, ServerConfig::default(), || {
            Box::new(make_engine(4))
        }));
        let mut handles = Vec::new();
        for client in 0..8u64 {
            let s = server.clone();
            // lint-src: allow(thread-spawn) — test clients must be real threads
            handles.push(std::thread::spawn(move || {
                let mut outs = Vec::new();
                for t in 0..20 {
                    let r = s.router.step_blocking(client, vec![(t as f32 * 0.1).sin()]);
                    outs.push(r.output[0]);
                }
                outs
            }));
        }
        for h in handles {
            let outs = h.join().unwrap();
            assert_eq!(outs.len(), 20);
            assert!(outs.iter().all(|v| v.is_finite()));
        }
        assert_eq!(server.router.total_requests(), 8 * 20);
    }

    #[test]
    fn full_queue_rejects_with_retry_after() {
        let cfg = ServerConfig {
            queue_cap: 0, // every request is over the admission limit
            retry_after: Duration::from_micros(123),
            ..Default::default()
        };
        let b = DynamicBatcher::new(Box::new(make_engine(13)), cfg);
        let (tx, rx) = mpsc::channel();
        b.submit(1, vec![0.1], tx);
        match rx.recv().expect("no reply") {
            StepReply::Rejected { retry_after } => {
                assert_eq!(retry_after, Duration::from_micros(123));
            }
            StepReply::Output(_) => panic!("request should have been shed"),
        }
        assert!(b.metrics.shed.load(Ordering::Relaxed) >= 1);
        assert_eq!(b.metrics.snapshot().requests, 0);
    }

    #[test]
    fn drop_oldest_policy_sheds_queued_request() {
        let cfg = ServerConfig {
            queue_cap: 0,
            shed: ShedPolicy::DropOldest,
            ..Default::default()
        };
        let b = DynamicBatcher::new(Box::new(make_engine(14)), cfg);
        // DropOldest admits at submit time; the control thread's
        // backstop sheds it from the queue front
        let (tx, rx) = mpsc::channel();
        b.submit(1, vec![0.5], tx);
        match rx.recv().expect("no reply") {
            StepReply::Rejected { .. } => {}
            StepReply::Output(_) => panic!("cap 0 must shed every request"),
        }
        assert_eq!(b.metrics.shed.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn evicted_session_restarts_from_zeros() {
        use crate::coordinator::sessions::session_bytes;
        let state_size = make_engine(11).state_size();
        let cfg = ServerConfig {
            session_mem: session_bytes(state_size), // exactly one resident session
            ..Default::default()
        };
        let b = DynamicBatcher::new(Box::new(make_engine(11)), cfg);
        let first = b.step_blocking(1, vec![0.7]);
        b.step_blocking(1, vec![0.7]); // session 1's state is now nonzero
        b.step_blocking(2, vec![0.3]); // inserting 2 evicts 1 (budget = 1 session)
        // documented semantics: the evicted session restarts from the
        // zero state — bit-identical to its very first step
        let again = b.step_blocking(1, vec![0.7]);
        assert_eq!(first.output.len(), again.output.len());
        for (a, c) in first.output.iter().zip(&again.output) {
            assert!(a.to_bits() == c.to_bits(), "evicted session did not restart from zeros");
        }
        let snap = b.metrics.snapshot();
        assert!(snap.evicted_lru >= 1);
        assert!(snap.store_bytes <= session_bytes(state_size) as u64);
    }

    #[test]
    fn idle_deadline_fires_before_lru_budget() {
        let cfg = ServerConfig {
            // unbounded memory: only the idle deadline can evict
            idle_batches: Some(2),
            ..Default::default()
        };
        let b = DynamicBatcher::new(Box::new(make_engine(12)), cfg);
        let first = b.step_blocking(1, vec![0.4]);
        for _ in 0..4 {
            b.step_blocking(2, vec![0.2]); // batch ticks pass; session 1 idles out
        }
        let again = b.step_blocking(1, vec![0.4]);
        for (a, c) in first.output.iter().zip(&again.output) {
            assert!(a.to_bits() == c.to_bits(), "idle session was not evicted to zeros");
        }
        let snap = b.metrics.snapshot();
        assert!(snap.evicted_idle >= 1, "idle deadline did not fire");
        assert_eq!(snap.evicted_lru, 0, "idle deadline must fire before any LRU eviction");
    }

    #[test]
    fn dropped_reply_receivers_are_counted() {
        let b = DynamicBatcher::new(Box::new(make_engine(6)), ServerConfig::default());
        let (tx, rx) = mpsc::channel();
        drop(rx); // client abandoned before the step executed
        b.submit(9, vec![0.1], tx);
        // this later request completes only after the abandoned one's
        // batch was delivered (channel FIFO, per-batch delivery order)
        let _ = b.step_blocking(10, vec![0.2]);
        assert_eq!(b.metrics.dropped_replies.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn metrics_seqlock_never_tears() {
        let m = Arc::new(ServerMetrics::default());
        let w = m.clone();
        // lint-src: allow(thread-spawn) — racing a real reader against the
        // writer is the point of this test
        let writer = std::thread::spawn(move || {
            for _ in 0..100_000 {
                w.write_locked(|| {
                    w.requests.fetch_add(1, Ordering::Relaxed);
                    w.total_latency_us.fetch_add(7, Ordering::Relaxed);
                });
            }
        });
        // every request adds exactly 7µs, so any consistent snapshot has
        // total == 7 * requests; the old two-relaxed-loads read could
        // observe a count without its latency
        for _ in 0..20_000 {
            let (n, t) = m.read_pair();
            assert_eq!(t, 7 * n, "seqlock snapshot tore: n={n} t={t}");
        }
        writer.join().unwrap();
        assert!((m.mean_latency_us() - 7.0).abs() < 1e-12);
        assert_eq!(m.snapshot().total_latency_us, 7 * m.snapshot().requests);
    }
}
