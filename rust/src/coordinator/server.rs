//! The streaming-inference server: session table, dynamic batcher, and a
//! round-robin router over engine replicas (vllm-router-style, scaled to
//! this paper: the "KV cache" of an LMU is a single (d·du) DN state per
//! session, constant in sequence length — the paper's memory-constrained
//! inference story).

use super::engine::StreamingEngine;
use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A step request: advance `session` with input `x`, reply on `reply`.
pub struct StepRequest {
    pub session: u64,
    pub x: Vec<f32>,
    pub reply: mpsc::Sender<StepResponse>,
    pub enqueued: Instant,
}

#[derive(Clone, Debug)]
pub struct StepResponse {
    pub session: u64,
    pub output: Vec<f32>,
    /// time from enqueue to completion
    pub latency: Duration,
}

#[derive(Clone, Debug)]
pub struct ServerConfig {
    /// max requests per batch window
    pub max_batch: usize,
    /// how long the batcher waits to fill a batch
    pub window: Duration,
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig { max_batch: 32, window: Duration::from_micros(500) }
    }
}

/// Aggregate serving metrics.
#[derive(Default)]
pub struct ServerMetrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub total_latency_us: AtomicU64,
}

impl ServerMetrics {
    pub fn mean_latency_us(&self) -> f64 {
        let n = self.requests.load(Ordering::Relaxed);
        if n == 0 {
            0.0
        } else {
            self.total_latency_us.load(Ordering::Relaxed) as f64 / n as f64
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            0.0
        } else {
            self.requests.load(Ordering::Relaxed) as f64 / b as f64
        }
    }
}

/// Dynamic batcher + session table driving one engine on its own thread.
pub struct DynamicBatcher {
    tx: mpsc::Sender<BatcherCmd>,
    pub metrics: Arc<ServerMetrics>,
    handle: Option<std::thread::JoinHandle<()>>,
}

enum BatcherCmd {
    Step(StepRequest),
    Reset(u64),
    Shutdown,
}

impl DynamicBatcher {
    /// Build from a `Send` engine (native engines).
    pub fn new(engine: Box<dyn StreamingEngine + Send>, cfg: ServerConfig) -> Self {
        Self::with_factory(Box::new(move || engine as Box<dyn StreamingEngine>), cfg)
    }

    /// Build from a factory that constructs the engine INSIDE the batcher
    /// thread — required for engines that are not `Send` (the PJRT client
    /// holds thread-bound handles).
    pub fn with_factory(
        factory: Box<dyn FnOnce() -> Box<dyn StreamingEngine> + Send>,
        cfg: ServerConfig,
    ) -> Self {
        let (tx, rx) = mpsc::channel::<BatcherCmd>();
        let metrics = Arc::new(ServerMetrics::default());
        let m = metrics.clone();
        let handle = std::thread::spawn(move || {
            let engine = factory();
            let mut sessions: HashMap<u64, Vec<f32>> = HashMap::new();
            let mut pending: Vec<StepRequest> = Vec::new();
            loop {
                // block for the first request (or control message)
                let first = match rx.recv() {
                    Ok(BatcherCmd::Step(r)) => Some(r),
                    Ok(BatcherCmd::Reset(sid)) => {
                        sessions.remove(&sid);
                        continue;
                    }
                    Ok(BatcherCmd::Shutdown) | Err(_) => break,
                };
                if let Some(r) = first {
                    pending.push(r);
                }
                // fill the window
                let deadline = Instant::now() + cfg.window;
                while pending.len() < cfg.max_batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(BatcherCmd::Step(r)) => pending.push(r),
                        Ok(BatcherCmd::Reset(sid)) => {
                            sessions.remove(&sid);
                        }
                        Ok(BatcherCmd::Shutdown) => return,
                        Err(mpsc::RecvTimeoutError::Timeout) => break,
                        Err(_) => return,
                    }
                }
                // execute the batch (one engine pass per request; the DN
                // state update itself is the batched compute unit)
                m.batches.fetch_add(1, Ordering::Relaxed);
                for req in pending.drain(..) {
                    let state = sessions
                        .entry(req.session)
                        .or_insert_with(|| vec![0.0f32; engine.state_size()]);
                    let output = engine.step(state, &req.x);
                    let latency = req.enqueued.elapsed();
                    m.requests.fetch_add(1, Ordering::Relaxed);
                    m.total_latency_us
                        .fetch_add(latency.as_micros() as u64, Ordering::Relaxed);
                    let _ = req.reply.send(StepResponse { session: req.session, output, latency });
                }
            }
        });
        DynamicBatcher { tx, metrics, handle: Some(handle) }
    }

    pub fn submit(&self, session: u64, x: Vec<f32>, reply: mpsc::Sender<StepResponse>) {
        let _ = self.tx.send(BatcherCmd::Step(StepRequest {
            session,
            x,
            reply,
            enqueued: Instant::now(),
        }));
    }

    /// Drop a session's state.
    pub fn reset_session(&self, session: u64) {
        let _ = self.tx.send(BatcherCmd::Reset(session));
    }

    /// Synchronous convenience: submit and wait.
    pub fn step_blocking(&self, session: u64, x: Vec<f32>) -> StepResponse {
        let (tx, rx) = mpsc::channel();
        self.submit(session, x, tx);
        rx.recv().expect("batcher died")
    }
}

impl Drop for DynamicBatcher {
    fn drop(&mut self) {
        let _ = self.tx.send(BatcherCmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

/// Round-robin router over engine replicas, with sticky sessions
/// (a session's DN state lives on exactly one replica).
pub struct Router {
    batchers: Vec<DynamicBatcher>,
    assignment: Mutex<HashMap<u64, usize>>,
    next: AtomicUsize,
}

impl Router {
    pub fn new(batchers: Vec<DynamicBatcher>) -> Self {
        assert!(!batchers.is_empty());
        Router { batchers, assignment: Mutex::new(HashMap::new()), next: AtomicUsize::new(0) }
    }

    pub fn replicas(&self) -> usize {
        self.batchers.len()
    }

    /// Which replica serves this session (assigning round-robin on first
    /// sight — sticky thereafter).
    pub fn route(&self, session: u64) -> usize {
        let mut map = self.assignment.lock().unwrap();
        *map.entry(session).or_insert_with(|| {
            self.next.fetch_add(1, Ordering::Relaxed) % self.batchers.len()
        })
    }

    pub fn step_blocking(&self, session: u64, x: Vec<f32>) -> StepResponse {
        let idx = self.route(session);
        self.batchers[idx].step_blocking(session, x)
    }

    pub fn end_session(&self, session: u64) {
        let idx = {
            let mut map = self.assignment.lock().unwrap();
            map.remove(&session)
        };
        if let Some(i) = idx {
            self.batchers[i].reset_session(session);
        }
    }

    pub fn total_requests(&self) -> u64 {
        self.batchers
            .iter()
            .map(|b| b.metrics.requests.load(Ordering::Relaxed))
            .sum()
    }

    /// Metrics of one replica's batcher.
    pub fn metrics_of(&self, idx: usize) -> &Arc<ServerMetrics> {
        &self.batchers[idx].metrics
    }
}

/// Full server façade: router + config.
pub struct StreamingServer {
    pub router: Router,
}

impl StreamingServer {
    /// Build with `replicas` engines from a factory (engines must be Send).
    pub fn new<F>(replicas: usize, cfg: ServerConfig, factory: F) -> Self
    where
        F: Fn() -> Box<dyn StreamingEngine + Send>,
    {
        let batchers = (0..replicas)
            .map(|_| DynamicBatcher::new(factory(), cfg.clone()))
            .collect();
        StreamingServer { router: Router::new(batchers) }
    }

    /// Build from per-replica factories run inside each batcher thread
    /// (for non-`Send` engines, e.g. PJRT-backed ones).
    pub fn with_factories(
        factories: Vec<Box<dyn FnOnce() -> Box<dyn StreamingEngine> + Send>>,
        cfg: ServerConfig,
    ) -> Self {
        let batchers = factories
            .into_iter()
            .map(|f| DynamicBatcher::with_factory(f, cfg.clone()))
            .collect();
        StreamingServer { router: Router::new(batchers) }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::autograd::ParamStore;
    use crate::coordinator::engine::NativeStreamingEngine;
    use crate::layers::lmu::{LmuParallelLayer, LmuSpec};
    use crate::util::Rng;

    fn make_engine(seed: u64) -> NativeStreamingEngine {
        let mut rng = Rng::new(seed);
        let mut store = ParamStore::new();
        let spec = LmuSpec::new(1, 1, 4, 8.0, 3);
        let layer = LmuParallelLayer::new(spec.clone(), 8, &mut store, &mut rng, "srv");
        NativeStreamingEngine::from_store(&spec, &layer.params, &store)
    }

    #[test]
    fn batcher_roundtrip_and_metrics() {
        let b = DynamicBatcher::new(Box::new(make_engine(0)), ServerConfig::default());
        let r1 = b.step_blocking(1, vec![0.5]);
        assert_eq!(r1.output.len(), 3);
        let r2 = b.step_blocking(1, vec![0.5]);
        // state advanced => different output (DN integrates)
        assert!(r1.output.iter().zip(&r2.output).any(|(a, c)| (a - c).abs() > 1e-7));
        assert_eq!(b.metrics.requests.load(Ordering::Relaxed), 2);
        assert!(b.metrics.mean_latency_us() >= 0.0);
    }

    #[test]
    fn sessions_do_not_interfere() {
        let b = DynamicBatcher::new(Box::new(make_engine(1)), ServerConfig::default());
        // drive session A hard, session B with zeros
        for _ in 0..5 {
            b.step_blocking(100, vec![5.0]);
        }
        let rb = b.step_blocking(200, vec![0.0]);
        // session B's first step from zero state with zero input stays ~bias-only
        let fresh = DynamicBatcher::new(Box::new(make_engine(1)), ServerConfig::default());
        let rf = fresh.step_blocking(7, vec![0.0]);
        for (a, c) in rb.output.iter().zip(&rf.output) {
            assert!((a - c).abs() < 1e-6, "cross-session contamination");
        }
    }

    #[test]
    fn reset_clears_state() {
        let b = DynamicBatcher::new(Box::new(make_engine(2)), ServerConfig::default());
        let first = b.step_blocking(5, vec![1.0]);
        b.step_blocking(5, vec![1.0]);
        b.reset_session(5);
        let after_reset = b.step_blocking(5, vec![1.0]);
        for (a, c) in first.output.iter().zip(&after_reset.output) {
            assert!((a - c).abs() < 1e-6, "reset did not clear DN state");
        }
    }

    #[test]
    fn router_sticky_and_round_robin() {
        let server = StreamingServer::new(3, ServerConfig::default(), || {
            Box::new(make_engine(3))
        });
        let r = &server.router;
        let a = r.route(10);
        let b = r.route(11);
        let c = r.route(12);
        // three new sessions land on three distinct replicas
        let mut set = vec![a, b, c];
        set.sort_unstable();
        set.dedup();
        assert_eq!(set.len(), 3);
        // sticky
        assert_eq!(r.route(10), a);
        let _ = r.step_blocking(10, vec![0.1]);
        assert_eq!(r.route(10), a);
    }

    #[test]
    fn concurrent_clients_all_served() {
        let server = std::sync::Arc::new(StreamingServer::new(2, ServerConfig::default(), || {
            Box::new(make_engine(4))
        }));
        let mut handles = Vec::new();
        for client in 0..8u64 {
            let s = server.clone();
            handles.push(std::thread::spawn(move || {
                let mut outs = Vec::new();
                for t in 0..20 {
                    let r = s.router.step_blocking(client, vec![(t as f32 * 0.1).sin()]);
                    outs.push(r.output[0]);
                }
                outs
            }));
        }
        for h in handles {
            let outs = h.join().unwrap();
            assert_eq!(outs.len(), 20);
            assert!(outs.iter().all(|v| v.is_finite()));
        }
        assert_eq!(server.router.total_requests(), 8 * 20);
    }
}
