//! L3 coordination: the data-parallel training coordinator and the
//! streaming-inference serving stack.
//!
//! The paper's systems story has two halves and so does this module:
//!
//!  * **training** (`data_parallel`): the parallel form makes each
//!    training step a big batched feed-forward computation, so scaling is
//!    plain data parallelism — worker replicas compute gradients on
//!    shards, the coordinator all-reduces (averages) and steps Adam, then
//!    broadcasts fresh parameters;
//!  * **serving** (`server`, `engine`): the *same* trained weights run in
//!    the recurrent form (eq. 19) for O(d) per-token streaming inference —
//!    sessions hold DN state, a dynamic batcher groups concurrent step
//!    requests, and a router spreads sessions across engine replicas.

pub mod data_parallel;
pub mod engine;
pub mod server;

pub use data_parallel::{pack_grads, DataParallelConfig, DataParallelCoordinator};
pub use engine::{NativeStreamingEngine, StreamingEngine};
pub use server::{DynamicBatcher, Router, ServerConfig, StreamingServer};
