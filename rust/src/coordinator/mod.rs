//! L3 coordination: the data-parallel training coordinator and the
//! streaming-inference serving stack.
//!
//! The paper's systems story has two halves and so does this module:
//!
//!  * **training** (`data_parallel`): the parallel form makes each
//!    training step a big batched feed-forward computation, so scaling is
//!    plain data parallelism — replica steps run as chunks of one job on
//!    the shared `crate::exec` worker pool, the coordinator all-reduces
//!    (deterministic replica-order mean) and steps Adam, then broadcasts
//!    fresh parameters;
//!  * **serving** (`server`, `sessions`, `engine`): the *same* trained
//!    weights run in the recurrent form (eq. 19) for O(d) per-token
//!    streaming inference — session DN states live in a byte-budgeted
//!    LRU/idle-deadline store (`sessions::SessionStore`), a bounded
//!    request queue sheds load under overload (`sessions::ShedPolicy`),
//!    a dynamic batcher continuously packs ready steps from live
//!    sessions into one pool fan-out (`sessions::execute_packed`), a
//!    router spreads sessions across engine replicas, and per-request
//!    latency streams into p50/p95/p99 histograms against an SLO.
//!
//! Both halves dispatch their thread-level fan-out through `crate::exec`,
//! so replica-level and kernel-level parallelism share one process-wide
//! thread budget (the `--threads` / `[train] threads` / `PLMU_THREADS`
//! knob) instead of multiplying.

pub mod data_parallel;
pub mod engine;
pub mod server;
pub mod sessions;

pub use data_parallel::{
    allreduce_mean, pack_grads, unpack_grads, DataParallelConfig, DataParallelCoordinator,
};
pub use engine::{NativeStreamingEngine, StreamingEngine};
pub use server::{
    DynamicBatcher, EngineFactory, MetricsSnapshot, Router, ServerConfig, StepReply,
    StreamingServer,
};
pub use sessions::{
    execute_packed, run_load_sim, LoadSimConfig, LoadSimReport, PackedRun, SessionStore,
    ShedPolicy,
};
